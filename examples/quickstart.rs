//! Quickstart: build a layered QMC Ising workload, run the fully
//! vectorized A.4 sweep engine, and watch the energy relax.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use vectorising::ising::builder::torus_workload;
use vectorising::sweep::{make_sweeper, SweepKind, Sweeper};

fn main() {
    // 8x8 torus base graph (64 spins/layer), 32 layers -> 2,048 spins.
    let wl = torus_workload(8, 8, 32, 1, 0.3);
    println!(
        "model: {} spins/layer x {} layers = {} spins, {} space edges/layer",
        wl.model.base.n,
        wl.model.n_layers,
        wl.model.n_spins(),
        wl.model.base.edges.len()
    );

    // The widest rung this host has a backend for (A.4w8 on AVX2 CPUs).
    let kind = SweepKind::preferred_cpu();
    println!("rung: {} ({} lanes)", kind.label(), kind.group_width());
    let mut sim = make_sweeper(kind, &wl.model, &wl.s0, 5489).expect("cpu sweeper");
    let beta = 1.2f32;
    println!("initial energy: {:.2}", sim.energy());
    for round in 1..=10 {
        let stats = sim.run(50, beta);
        println!(
            "after {:4} sweeps: E = {:9.2}   P(flip) = {:.4}   quad wait = {:.4}",
            round * 50,
            sim.energy(),
            stats.flip_prob(),
            stats.wait_prob()
        );
    }
    // the incremental effective-field bookkeeping must still be exact
    let drift = sim.validate();
    println!("h_eff consistency after 500 sweeps: {drift:.2e} (must be ~0)");
    assert!(drift < 1e-3);
}
