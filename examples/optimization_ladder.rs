//! The paper's story in one binary: run the same workload through every
//! CPU rung of the optimization ladder and print the speedups
//! (a miniature of Fig 13 / Table 2).
//!
//! ```bash
//! cargo run --release --example optimization_ladder
//! ```

use std::time::Instant;

use vectorising::ising::builder::torus_workload;
use vectorising::sweep::{make_sweeper, SweepKind, Sweeper};

fn main() {
    let sweeps = 300;
    let beta = 0.8f32;
    println!("timing {sweeps} sweeps of a 64x32 (2,048-spin) model per rung\n");

    let mut results = Vec::new();
    for kind in SweepKind::all_cpu_wide() {
        let wl = torus_workload(8, 8, 32, 1, 0.3);
        let mut sw = make_sweeper(kind, &wl.model, &wl.s0, 5489).expect("cpu sweeper");
        sw.run(20, beta); // warm-up
        let t0 = Instant::now();
        let stats = sw.run(sweeps, beta);
        let dt = t0.elapsed().as_secs_f64();
        let per_update = dt / (sweeps as f64 * wl.model.n_spins() as f64) * 1e9;
        results.push((kind, dt, per_update, stats.flip_prob(), sw.energy()));
    }

    let baseline = results[0].1;
    println!("{:6} {:>9} {:>12} {:>9} {:>10} {:>10}", "rung", "seconds", "ns/update", "speedup", "P(flip)", "energy");
    for (kind, dt, per_update, pflip, energy) in &results {
        println!(
            "{:6} {:9.3} {:12.2} {:8.2}x {:10.4} {:10.1}",
            kind.label(),
            dt,
            per_update,
            baseline / dt,
            pflip,
            energy
        );
    }
    println!(
        "\npaper (Table 2, 1 core): A.2b = 3.16x over A.1b, A.3 = 5.95x, A.4 = 10.0x (1/0.1)"
    );
    println!("paper's exact A.1b row: A.2b 3.748x, A.3 7.053x, A.4 11.860x");
}
