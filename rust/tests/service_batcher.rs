//! Property tests for the lane-batching scheduler's packing invariants:
//!
//! 1. no batch ever mixes incompatible shapes,
//! 2. FIFO order is preserved within a shape bucket,
//! 3. the deadline flush fires on a lone job (and never early),
//! 4. padded lanes never leak into results — a padded batch answers
//!    exactly its real jobs, each bit-exact to the scalar reference.
//!
//! The batcher takes time as a parameter, so the deadline machinery is
//! driven with a synthetic clock — no sleeps.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use vectorising::service::batcher::{Batcher, Dispatch, DispatchWork};
use vectorising::service::executor::Executor;
use vectorising::service::job::{JobSpec, ShapeKey};
use vectorising::sweep::ExpMode;

fn spec(id: &str, shape: (usize, usize, usize), sweeps: usize, seed: u32) -> JobSpec {
    JobSpec {
        id: id.to_string(),
        width: shape.0,
        height: shape.1,
        layers: shape.2,
        model_seed: 7 + seed as u64,
        jtau: 0.3,
        sweeps,
        beta: 0.8,
        seed,
        trace_every: 0,
        want_state: true,
        want_timing: false,
        sampler: None,
    }
}

const SHAPES: [(usize, usize, usize); 3] = [(4, 4, 8), (6, 4, 8), (4, 4, 2)];

/// Deterministic pseudo-random stream of jobs over three shapes.
fn job_stream(n: usize) -> Vec<JobSpec> {
    let mut x = 0x2545f491u64;
    (0..n)
        .map(|i| {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let shape = SHAPES[(x >> 33) as usize % SHAPES.len()];
            spec(&format!("j{i}"), shape, 10 + (x >> 40) as usize % 20, i as u32)
        })
        .collect()
}

#[test]
fn batches_never_mix_shapes() {
    let mut b = Batcher::new(4, Duration::from_millis(50));
    let t0 = Instant::now();
    let mut dispatches = Vec::new();
    for (i, job) in job_stream(120).into_iter().enumerate() {
        b.push(job, None, t0 + Duration::from_millis(i as u64));
        dispatches.extend(b.poll(t0 + Duration::from_millis(i as u64)));
    }
    // Advance past every deadline: the stragglers flush too.
    dispatches.extend(b.poll(t0 + Duration::from_secs(10)));
    assert_eq!(b.queued(), 0);
    let total: usize = dispatches.iter().map(|d| d.occupancy()).sum();
    assert_eq!(total, 120, "every job dispatched exactly once");
    for d in &dispatches {
        let jobs = match &d.work {
            DispatchWork::Batch(jobs) => {
                assert!(jobs.len() >= 2 && jobs.len() <= 4, "batch arity");
                jobs
            }
            DispatchWork::Single(_) => continue,
        };
        let shape0: ShapeKey = jobs[0].spec.shape();
        assert!(
            jobs.iter().all(|j| j.spec.shape() == shape0),
            "a batch must never mix shapes"
        );
    }
}

#[test]
fn fifo_order_is_preserved_within_a_bucket() {
    let mut b = Batcher::new(4, Duration::from_millis(50));
    let t0 = Instant::now();
    // Interleave two shapes; within each shape the ids are ordered.
    for i in 0..11 {
        let shape = SHAPES[i % 2];
        b.push(spec(&format!("j{i}"), shape, 10, i as u32), None, t0);
    }
    let mut dispatches = b.poll(t0);
    dispatches.extend(b.poll(t0 + Duration::from_secs(1)));
    let mut per_shape: BTreeMap<ShapeKey, Vec<u64>> = BTreeMap::new();
    for d in dispatches {
        for job in d.into_jobs() {
            per_shape.entry(job.spec.shape()).or_default().push(job.seq);
        }
    }
    assert_eq!(per_shape.len(), 2);
    for (shape, seqs) in per_shape {
        let mut sorted = seqs.clone();
        sorted.sort_unstable();
        assert_eq!(seqs, sorted, "bucket {shape} must dispatch FIFO: {seqs:?}");
    }
}

#[test]
fn deadline_flush_fires_on_a_lone_job_and_never_early() {
    let deadline = Duration::from_millis(100);
    let mut b = Batcher::new(4, deadline);
    let t0 = Instant::now();
    b.push(spec("lone", (4, 4, 8), 10, 1), None, t0);
    assert_eq!(b.next_deadline(), Some(t0 + deadline));
    assert!(b.poll(t0).is_empty(), "no flush at admission time");
    assert!(
        b.poll(t0 + deadline - Duration::from_millis(1)).is_empty(),
        "no flush before the deadline"
    );
    let ds = b.poll(t0 + deadline);
    assert_eq!(ds.len(), 1);
    assert!(
        matches!(ds[0].work, DispatchWork::Single(_)),
        "a lone job flushes to the scalar fallback"
    );
    assert!(ds[0].deadline_forced, "the deadline, not a pin, forced this single out");
    assert_eq!(b.queued(), 0);
    assert_eq!(b.next_deadline(), None);
}

#[test]
fn deadline_flushes_two_stragglers_as_a_padded_batch() {
    let deadline = Duration::from_millis(100);
    let mut b = Batcher::new(4, deadline);
    let t0 = Instant::now();
    b.push(spec("s0", (4, 4, 8), 10, 1), None, t0);
    b.push(spec("s1", (4, 4, 8), 12, 2), None, t0 + Duration::from_millis(30));
    assert!(b.poll(t0 + Duration::from_millis(99)).is_empty());
    // The *oldest* job's age controls the flush, not the newest's.
    let ds = b.poll(t0 + deadline);
    assert_eq!(ds.len(), 1);
    match &ds[0].work {
        DispatchWork::Batch(jobs) => assert_eq!(jobs.len(), 2, "both stragglers share one batch"),
        DispatchWork::Single(_) => panic!(">= 2 stragglers must go out as a padded batch"),
    }
    assert!(ds[0].deadline_forced, "a padded flush counts as a deadline flush");
}

/// Padded lanes never leak: a 2-job dispatch at W=4 answers exactly its
/// two jobs, and each answer is bit-exact to the scalar A.2 reference —
/// including with different sweep counts inside one batch (the chunked
/// capture machinery).
#[test]
fn padded_lanes_never_leak_into_results() {
    let exec = Executor::new(4, ExpMode::Fast).unwrap();
    let a = spec("a", (4, 4, 8), 30, 11);
    let b = spec("b", (4, 4, 8), 50, 22); // different sweeps, same batch
    let mut batcher = Batcher::new(4, Duration::from_millis(1));
    let t0 = Instant::now();
    batcher.push(a.clone(), None, t0);
    batcher.push(b.clone(), None, t0);
    let mut ds = batcher.poll(t0 + Duration::from_secs(1));
    assert_eq!(ds.len(), 1);
    let dispatch = ds.remove(0);
    assert_eq!(dispatch.occupancy(), 2);

    let served = exec.run_dispatch(dispatch);
    assert_eq!(served.len(), 2, "exactly the real jobs are answered");
    for (job, outcome) in served {
        let got = outcome.unwrap();
        assert_eq!(got.lanes, 4);
        assert_eq!(got.occupancy, 2);
        let reference = exec.run_single(&job.spec).unwrap();
        assert_eq!(got.id, reference.id);
        assert_eq!(got.stats.flips, reference.stats.flips, "job {}", got.id);
        assert_eq!(got.stats.attempts, reference.stats.attempts, "job {}", got.id);
        assert_eq!(
            got.energy.to_bits(),
            reference.energy.to_bits(),
            "job {} energy must be bit-exact to the scalar run",
            got.id
        );
        assert_eq!(got.state, reference.state, "job {} state", got.id);
    }
}

/// Energy traces from a lane-batch match the scalar reference, point for
/// point, even when the trace grid forces extra chunk boundaries.
#[test]
fn batched_energy_traces_match_scalar_reference() {
    let exec = Executor::new(4, ExpMode::Fast).unwrap();
    let mut a = spec("ta", (4, 4, 8), 40, 31);
    a.trace_every = 8;
    let mut b = spec("tb", (4, 4, 8), 25, 32);
    b.trace_every = 10;
    let served = exec.run_dispatch(Dispatch::batch(
        vec![pending(a.clone()), pending(b.clone())],
        true,
    ));
    for (job, outcome) in served {
        let got = outcome.unwrap();
        let reference = exec.run_single(&job.spec).unwrap();
        assert_eq!(got.energy_trace.len(), reference.energy_trace.len(), "job {}", got.id);
        for (x, y) in got.energy_trace.iter().zip(&reference.energy_trace) {
            assert_eq!(x.to_bits(), y.to_bits(), "job {} trace point", got.id);
        }
    }
}

fn pending(spec: JobSpec) -> vectorising::service::batcher::PendingJob {
    let now = Instant::now();
    vectorising::service::batcher::PendingJob {
        spec,
        reply: None,
        enqueued: now,
        seq: 0,
        timeline: vectorising::obs::Timeline::new(now, now),
    }
}
