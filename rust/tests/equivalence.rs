//! Cross-rung equivalence tests — the core correctness argument of the
//! optimization ladder: every rung is *the same algorithm*.
//!
//! * A.1 and A.2 differ only in data structures (and default exp mode);
//!   with the exp mode pinned they must produce identical trajectories.
//! * A.3 and A.4 differ only in how updates are applied; they must be
//!   bit-identical always — at width 4 and at width 8.
//! * The width-8 rungs run a different (8-generator) RNG schedule, so
//!   they cannot match the width-4 trajectories bit-for-bit; they must
//!   sample the same distribution (checked statistically, under
//!   `ExpMode::Exact` like the W=4 rungs).
//! * Every rung must keep its incremental effective fields consistent
//!   with a from-scratch recomputation (the paper's h_eff bookkeeping).

use vectorising::ising::builder::{diag_torus_workload, torus_workload};
use vectorising::sweep::{try_make_sweeper_with_exp, ExpMode, SweepKind, Sweeper};

#[test]
fn a1_equals_a2_with_same_exp_mode() {
    for exp in [ExpMode::Exact, ExpMode::Fast, ExpMode::Accurate] {
        let wl = torus_workload(6, 4, 8, 3, 0.3);
        let mut a1 =
            try_make_sweeper_with_exp(SweepKind::A1Original, &wl.model, &wl.s0, 42, exp).unwrap();
        let mut a2 = try_make_sweeper_with_exp(SweepKind::A2Basic, &wl.model, &wl.s0, 42, exp).unwrap();
        for round in 0..20 {
            let s1 = a1.run(1, 0.8);
            let s2 = a2.run(1, 0.8);
            assert_eq!(s1.flips, s2.flips, "round {round} exp {exp:?}");
            assert_eq!(a1.state(), a2.state(), "round {round} exp {exp:?}");
        }
    }
}

#[test]
fn a3_equals_a4_bitexact() {
    for (w, h, l, seed) in [(4usize, 4usize, 8usize, 1u32), (6, 4, 16, 7), (8, 8, 32, 99)] {
        let wl = torus_workload(w, h, l, seed as u64, 0.3);
        let mut a3 =
            try_make_sweeper_with_exp(SweepKind::A3VecRng, &wl.model, &wl.s0, seed, ExpMode::Fast)
                .unwrap();
        let mut a4 = try_make_sweeper_with_exp(SweepKind::A4Full, &wl.model, &wl.s0, seed, ExpMode::Fast)
            .unwrap();
        for round in 0..10 {
            let beta = 0.2 + 0.2 * (round % 4) as f32;
            let s3 = a3.run(1, beta);
            let s4 = a4.run(1, beta);
            assert_eq!(s3.flips, s4.flips, "cfg ({w},{h},{l}) round {round}");
            assert_eq!(s3.groups_with_flip, s4.groups_with_flip);
            let st3 = a3.state();
            let st4 = a4.state();
            assert_eq!(st3, st4, "cfg ({w},{h},{l}) round {round}");
        }
    }
}

#[test]
fn a3_w8_equals_a4_w8_bitexact() {
    // The width-8 twin of the test above: same interlaced RNG and decision
    // math, different update mechanics — trajectories must be identical
    // whether the backend is AVX2 or the portable octet lanes.
    for (w, h, l, seed) in [(4usize, 4usize, 16usize, 1u32), (6, 4, 24, 7), (8, 8, 32, 99)] {
        let wl = torus_workload(w, h, l, seed as u64, 0.3);
        let mut a3 =
            try_make_sweeper_with_exp(SweepKind::A3VecRngW8, &wl.model, &wl.s0, seed, ExpMode::Fast)
                .unwrap();
        let mut a4 =
            try_make_sweeper_with_exp(SweepKind::A4FullW8, &wl.model, &wl.s0, seed, ExpMode::Fast)
                .unwrap();
        for round in 0..10 {
            let beta = 0.2 + 0.2 * (round % 4) as f32;
            let s3 = a3.run(1, beta);
            let s4 = a4.run(1, beta);
            assert_eq!(s3.flips, s4.flips, "cfg ({w},{h},{l}) round {round}");
            assert_eq!(s3.groups_with_flip, s4.groups_with_flip);
            assert_eq!(a3.state(), a4.state(), "cfg ({w},{h},{l}) round {round}");
        }
    }
}

#[test]
fn a3_a4_also_agree_on_degree6_graph() {
    let wl = diag_torus_workload(6, 4, 12, 5, 0.25);
    let mut a3 =
        try_make_sweeper_with_exp(SweepKind::A3VecRng, &wl.model, &wl.s0, 11, ExpMode::Fast).unwrap();
    let mut a4 =
        try_make_sweeper_with_exp(SweepKind::A4Full, &wl.model, &wl.s0, 11, ExpMode::Fast).unwrap();
    for _ in 0..8 {
        a3.run(1, 0.6);
        a4.run(1, 0.6);
    }
    assert_eq!(a3.state(), a4.state());
}

#[test]
fn a3_a4_w8_also_agree_on_degree6_graph() {
    let wl = diag_torus_workload(6, 4, 16, 5, 0.25);
    let mut a3 =
        try_make_sweeper_with_exp(SweepKind::A3VecRngW8, &wl.model, &wl.s0, 11, ExpMode::Fast).unwrap();
    let mut a4 =
        try_make_sweeper_with_exp(SweepKind::A4FullW8, &wl.model, &wl.s0, 11, ExpMode::Fast).unwrap();
    for _ in 0..8 {
        a3.run(1, 0.6);
        a4.run(1, 0.6);
    }
    assert_eq!(a3.state(), a4.state());
}

#[test]
fn effective_fields_stay_consistent_on_every_rung() {
    let wl = torus_workload(6, 6, 16, 13, 0.35);
    for kind in SweepKind::all_cpu_wide() {
        let mut sw =
            try_make_sweeper_with_exp(kind, &wl.model, &wl.s0, 77, kind.default_exp()).unwrap();
        sw.run(25, 0.7);
        let err = sw.validate();
        assert!(err < 1e-3, "{kind:?} h_eff drift {err}");
    }
}

#[test]
fn all_rungs_sample_the_same_distribution() {
    // Statistical equivalence across *all six* CPU rungs, including the
    // width-8 variants: long runs at the same β with the exact exp must
    // produce mean energies within a few percent of each other.  This is
    // the acceptance check that a4-full-w8 matches the A.1/A.2
    // trajectories in distribution, exactly like the W=4 rungs do.
    let beta = 0.9f32;
    let mut means = Vec::new();
    for kind in SweepKind::all_cpu_wide() {
        let wl = torus_workload(4, 4, 16, 21, 0.3);
        let mut sw = try_make_sweeper_with_exp(kind, &wl.model, &wl.s0, 5489, ExpMode::Exact).unwrap();
        sw.run(200, beta); // burn-in
        let mut acc = 0.0;
        let n = 300;
        for _ in 0..n {
            sw.run(2, beta);
            acc += sw.energy();
        }
        means.push(acc / n as f64);
    }
    let avg = means.iter().sum::<f64>() / means.len() as f64;
    for (kind, m) in SweepKind::all_cpu_wide().iter().zip(&means) {
        let rel = (m - avg).abs() / avg.abs();
        assert!(rel < 0.05, "{kind:?}: mean energy {m} vs ensemble {avg}");
    }
}

#[test]
fn fast_exp_mode_does_not_bias_sampling() {
    // The paper uses the fast approximation in production; its ±4% error
    // on probabilities must not visibly shift the sampled energy.
    let beta = 0.8f32;
    let mut res = Vec::new();
    for exp in [ExpMode::Exact, ExpMode::Fast, ExpMode::Accurate] {
        let wl = torus_workload(4, 4, 8, 33, 0.3);
        let mut sw = try_make_sweeper_with_exp(SweepKind::A2Basic, &wl.model, &wl.s0, 123, exp).unwrap();
        sw.run(200, beta);
        let mut acc = 0.0;
        let n = 300;
        for _ in 0..n {
            sw.run(2, beta);
            acc += sw.energy();
        }
        res.push(acc / n as f64);
    }
    let rel_fast = (res[1] - res[0]).abs() / res[0].abs();
    let rel_acc = (res[2] - res[0]).abs() / res[0].abs();
    assert!(rel_fast < 0.05, "fast-exp bias {rel_fast}");
    assert!(rel_acc < 0.05, "accurate-exp bias {rel_acc}");
}

#[test]
fn set_state_resets_trajectory() {
    for kind in [SweepKind::A4Full, SweepKind::A4FullW8] {
        let wl = torus_workload(4, 4, 16, 8, 0.3);
        let mut sw = try_make_sweeper_with_exp(kind, &wl.model, &wl.s0, 9, ExpMode::Fast).unwrap();
        sw.run(5, 0.5);
        let snapshot = sw.state();
        sw.run(5, 0.5);
        assert_ne!(sw.state(), snapshot, "{kind:?}");
        sw.set_state(&snapshot);
        assert_eq!(sw.state(), snapshot, "{kind:?}");
        assert!(sw.validate() < 1e-4, "{kind:?}");
    }
}

#[test]
fn flip_probability_monotone_in_temperature() {
    let wl = torus_workload(6, 4, 8, 17, 0.3);
    let mut probs = Vec::new();
    for beta in [3.0f32, 1.0, 0.2] {
        let mut sw =
            try_make_sweeper_with_exp(SweepKind::A4Full, &wl.model, &wl.s0, 50, ExpMode::Fast).unwrap();
        sw.run(10, beta); // settle
        let st = sw.run(30, beta);
        probs.push(st.flip_prob());
    }
    assert!(probs[0] < probs[1] && probs[1] < probs[2], "{probs:?}");
}
