//! Checkpoint round-trip: save → (serialize → disk → load) → resume must
//! continue the *identical* trajectory an uninterrupted run produces —
//! the property that makes checkpointing transparent to a long tempering
//! run.  Verified for a scalar rung (A.2) and a replica-batch C-rung,
//! through the full JSON + file path, including the exchange RNG and the
//! even/odd round parity.

use vectorising::coordinator::{self, Checkpoint, RunConfig};
use vectorising::sweep::SweepKind;

fn cfg() -> RunConfig {
    RunConfig { n_models: 5, sweeps: 60, sweeps_per_round: 10, ..RunConfig::default() }
}

#[test]
fn scalar_rung_resume_is_bit_exact() {
    let cfg = cfg();
    let kind = SweepKind::A2Basic;

    // Uninterrupted reference: 3 rounds, checkpoint, 3 more rounds.
    let mut reference = coordinator::build_ensemble(&cfg, kind).unwrap();
    for _ in 0..3 {
        reference.round(cfg.sweeps_per_round);
    }
    let ck = Checkpoint::capture(kind, 3, 30, &cfg, &mut reference);
    for _ in 0..3 {
        reference.round(cfg.sweeps_per_round);
    }

    // Interrupted run: rebuild from scratch, restore through the full
    // disk round-trip, then the same 3 remaining rounds.
    let dir = std::env::temp_dir().join("vectorising_resume_test_scalar");
    let path = dir.join("run.ckpt.json");
    ck.save(&path).unwrap();
    let loaded = Checkpoint::load(&path).unwrap();
    assert_eq!(loaded.kind, "A.2");
    assert_eq!(loaded.rngs.len(), cfg.n_models, "RNG payload captured per replica");

    let mut resumed = coordinator::build_ensemble(&cfg, kind).unwrap();
    loaded.restore(&mut resumed).unwrap();
    for _ in 0..3 {
        resumed.round(cfg.sweeps_per_round);
    }

    for i in 0..cfg.n_models {
        assert_eq!(
            reference.state_of(i),
            resumed.state_of(i),
            "replica {i}: resumed trajectory diverged"
        );
    }
    let a = reference.reports();
    let b = resumed.reports();
    for i in 0..cfg.n_models {
        assert_eq!(a[i].energy.to_bits(), b[i].energy.to_bits(), "replica {i}: energy");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn c_rung_resume_is_bit_exact() {
    let cfg = cfg(); // 5 replicas at W=4 -> 2 batches, padded tail
    let kind = SweepKind::C1ReplicaBatch;

    let mut reference = coordinator::build_batched_ensemble(&cfg, kind).unwrap();
    for _ in 0..3 {
        reference.round(cfg.sweeps_per_round);
    }
    let ck = Checkpoint::capture_batched(3, 30, &cfg, &mut reference);
    for _ in 0..3 {
        reference.round(cfg.sweeps_per_round);
    }

    let dir = std::env::temp_dir().join("vectorising_resume_test_batched");
    let path = dir.join("run.ckpt.json");
    ck.save(&path).unwrap();
    let loaded = Checkpoint::load(&path).unwrap();
    assert_eq!(loaded.kind, "C.1");
    assert_eq!(loaded.states.len(), cfg.n_models, "states per active replica only");
    assert_eq!(loaded.rngs.len(), 2, "RNG payload per lane-batch");

    let mut resumed = coordinator::build_batched_ensemble(&cfg, kind).unwrap();
    loaded.restore_batched(&mut resumed).unwrap();
    for _ in 0..3 {
        resumed.round(cfg.sweeps_per_round);
    }

    // Padded lanes may differ (their states are not checkpointed); every
    // *active* replica must be bit-identical to the uninterrupted run.
    for i in 0..cfg.n_models {
        assert_eq!(
            reference.state_of(i),
            resumed.state_of(i),
            "replica {i}: resumed trajectory diverged"
        );
    }
    let a = reference.reports();
    let b = resumed.reports();
    for i in 0..cfg.n_models {
        assert_eq!(a[i].energy.to_bits(), b[i].energy.to_bits(), "replica {i}: energy");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn resume_without_rng_payload_still_restores_states() {
    // A states-only checkpoint (the pre-RNG format) restores states and
    // leaves the generators as the rebuilt ensemble seeded them.  (A real
    // resume must derive *fresh* sweeper seeds for the continued segment
    // — see the checkpoint module docs; this test only exercises the
    // states-only restore path.)
    let cfg = cfg();
    let mut pt = coordinator::build_ensemble(&cfg, SweepKind::A2Basic).unwrap();
    pt.round(cfg.sweeps_per_round);
    let mut ck = Checkpoint::capture(SweepKind::A2Basic, 1, 10, &cfg, &mut pt);
    let states = ck.states.clone();
    ck.rngs.clear();
    ck.swap_rng.clear();
    let mut fresh = coordinator::build_ensemble(&cfg, SweepKind::A2Basic).unwrap();
    ck.restore(&mut fresh).unwrap();
    for (i, s) in states.iter().enumerate() {
        assert_eq!(&fresh.state_of(i), s);
    }
}
