//! Checkpoint round-trip: save → (serialize → disk → load) → resume must
//! continue the *identical* trajectory an uninterrupted run produces —
//! the property that makes checkpointing transparent to a long tempering
//! run.  Verified for a scalar rung (A.2) and replica-batch C-rungs at
//! W ∈ {4, 8, 16} (the portable w16 plan has no legacy spelling — it
//! only exists through the spec-carrying schema v2), through the full
//! JSON + file path, including the exchange RNG and the even/odd round
//! parity.  Schema-v1 migration fixtures (hand-written v1 JSON, and a
//! stripped-to-v1 capture with full RNG payloads) pin the
//! `kind`-label → `SweepKind` → spec lowering path.

use vectorising::coordinator::{self, Checkpoint, RunConfig, RunOptions, RunSpec};
use vectorising::engine::{BackendPref, Rung, SamplerSpec};
use vectorising::sweep::SweepKind;

fn cfg() -> RunConfig {
    RunConfig { n_models: 5, sweeps: 60, sweeps_per_round: 10, ..RunConfig::default() }
}

#[test]
fn scalar_rung_resume_is_bit_exact() {
    let cfg = cfg();
    let kind = SweepKind::A2Basic;

    // Uninterrupted reference: 3 rounds, checkpoint, 3 more rounds.
    let mut reference = coordinator::build_ensemble(&cfg, kind).unwrap();
    for _ in 0..3 {
        reference.round(cfg.sweeps_per_round);
    }
    let ck = Checkpoint::capture(kind, 3, 30, &cfg, &mut reference);
    for _ in 0..3 {
        reference.round(cfg.sweeps_per_round);
    }

    // Interrupted run: rebuild from scratch, restore through the full
    // disk round-trip, then the same 3 remaining rounds.
    let dir = std::env::temp_dir().join("vectorising_resume_test_scalar");
    let path = dir.join("run.ckpt.json");
    ck.save(&path).unwrap();
    let loaded = Checkpoint::load(&path).unwrap();
    assert_eq!(loaded.kind, "A.2");
    assert_eq!(loaded.rngs.len(), cfg.n_models, "RNG payload captured per replica");

    let mut resumed = coordinator::build_ensemble(&cfg, kind).unwrap();
    loaded.restore(&mut resumed).unwrap();
    for _ in 0..3 {
        resumed.round(cfg.sweeps_per_round);
    }

    for i in 0..cfg.n_models {
        assert_eq!(
            reference.state_of(i),
            resumed.state_of(i),
            "replica {i}: resumed trajectory diverged"
        );
    }
    let a = reference.reports();
    let b = resumed.reports();
    for i in 0..cfg.n_models {
        assert_eq!(a[i].energy.to_bits(), b[i].energy.to_bits(), "replica {i}: energy");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn c_rung_resume_is_bit_exact() {
    let cfg = cfg(); // 5 replicas at W=4 -> 2 batches, padded tail
    let kind = SweepKind::C1ReplicaBatch;

    let mut reference = coordinator::build_batched_ensemble(&cfg, kind).unwrap();
    for _ in 0..3 {
        reference.round(cfg.sweeps_per_round);
    }
    let ck = Checkpoint::capture_batched(3, 30, &cfg, &mut reference);
    for _ in 0..3 {
        reference.round(cfg.sweeps_per_round);
    }

    let dir = std::env::temp_dir().join("vectorising_resume_test_batched");
    let path = dir.join("run.ckpt.json");
    ck.save(&path).unwrap();
    let loaded = Checkpoint::load(&path).unwrap();
    assert_eq!(loaded.kind, "C.1");
    assert_eq!(loaded.states.len(), cfg.n_models, "states per active replica only");
    assert_eq!(loaded.rngs.len(), 2, "RNG payload per lane-batch");

    let mut resumed = coordinator::build_batched_ensemble(&cfg, kind).unwrap();
    loaded.restore_batched(&mut resumed).unwrap();
    for _ in 0..3 {
        resumed.round(cfg.sweeps_per_round);
    }

    // Padded lanes may differ (their states are not checkpointed); every
    // *active* replica must be bit-identical to the uninterrupted run.
    for i in 0..cfg.n_models {
        assert_eq!(
            reference.state_of(i),
            resumed.state_of(i),
            "replica {i}: resumed trajectory diverged"
        );
    }
    let a = reference.reports();
    let b = resumed.reports();
    for i in 0..cfg.n_models {
        assert_eq!(a[i].energy.to_bits(), b[i].energy.to_bits(), "replica {i}: energy");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn portable_c1w16_resume_is_bit_exact() {
    // The schema-v2 unlock: a plan the legacy enum cannot spell
    // (portable 16-lane replica batch) saves and resumes bit-exactly.
    let cfg = cfg(); // 5 replicas at W=16 -> 1 padded group
    let spec = SamplerSpec::rung(Rung::C1).w(16).on(BackendPref::Portable);

    let mut reference = coordinator::build_batched_ensemble(&cfg, spec).unwrap();
    for _ in 0..3 {
        reference.round(cfg.sweeps_per_round);
    }
    let ck = Checkpoint::capture_batched(3, 30, &cfg, &mut reference);
    for _ in 0..3 {
        reference.round(cfg.sweeps_per_round);
    }

    let dir = std::env::temp_dir().join("vectorising_resume_test_w16");
    let path = dir.join("run.ckpt.json");
    ck.save(&path).unwrap();
    let loaded = Checkpoint::load(&path).unwrap();
    assert_eq!(loaded.kind, "C.1w16");
    assert_eq!(loaded.plans.len(), 1, "one resolved plan per group");
    assert_eq!(loaded.plans[0].resolved.width, 16);
    assert_eq!(loaded.plans[0].replicas, 5);
    assert_eq!(loaded.sampler.unwrap(), spec, "the requested spec rides in the checkpoint");
    assert_eq!(loaded.rngs.len(), 1, "RNG payload per lane-group");

    let mut resumed = coordinator::build_batched_ensemble(&cfg, spec).unwrap();
    loaded.restore_batched(&mut resumed).unwrap();
    for _ in 0..3 {
        resumed.round(cfg.sweeps_per_round);
    }

    for i in 0..cfg.n_models {
        assert_eq!(
            reference.state_of(i),
            resumed.state_of(i),
            "replica {i}: resumed trajectory diverged"
        );
    }
    let a = reference.reports();
    let b = resumed.reports();
    for i in 0..cfg.n_models {
        assert_eq!(a[i].energy.to_bits(), b[i].energy.to_bits(), "replica {i}: energy");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn v1_checkpoint_with_rng_payload_migrates_and_resumes_bit_exactly() {
    // A faithful v1 file: capture under a legacy kind, then strip every
    // schema-v2 field — exactly what a v1 writer produced.  It must load
    // (schema defaults to 1), lower its kind label onto the spec via
    // From<SweepKind>, and resume the identical trajectory.
    let cfg = cfg();
    let kind = SweepKind::C1ReplicaBatch;
    let mut reference = coordinator::build_batched_ensemble(&cfg, kind).unwrap();
    for _ in 0..3 {
        reference.round(cfg.sweeps_per_round);
    }
    let ck = Checkpoint::capture_batched(3, 30, &cfg, &mut reference);
    for _ in 0..3 {
        reference.round(cfg.sweeps_per_round);
    }
    let v = vectorising::util::json::Value::parse(&ck.to_json()).unwrap();
    let mut m = match v {
        vectorising::util::json::Value::Obj(m) => m,
        _ => unreachable!(),
    };
    m.remove("schema");
    m.remove("sampler");
    m.remove("plans");
    let v1_text = vectorising::util::json::Value::Obj(m).to_string();

    let loaded = Checkpoint::from_json(&v1_text).unwrap();
    assert_eq!(loaded.schema, 1);
    assert!(loaded.sampler.is_none() && loaded.plans.is_empty());
    // The migration path: kind label -> SweepKind -> spec.
    let rs = loaded.run_spec().unwrap();
    assert_eq!(rs.sampler.rung, Rung::C1);

    // Resume through the spec-driven coordinator entry point.
    let resumed_report = coordinator::run_spec_with(
        &rs,
        &RunOptions { resume: Some(loaded), ..RunOptions::default() },
    )
    .unwrap();
    assert_eq!(resumed_report.sweeps, 30, "rounds 4..6 ran");
    let ref_reports = reference.reports();
    for i in 0..cfg.n_models {
        assert_eq!(
            ref_reports[i].energy.to_bits(),
            resumed_report.energies[i].to_bits(),
            "replica {i}: v1-migrated resume diverged"
        );
    }
}

#[test]
fn hand_written_v1_fixture_loads_and_resumes() {
    // A v1 checkpoint as the earliest writers produced it: a bare kind
    // label, no schema/sampler/plans, states only (no RNG payloads).
    let fixture = r#"{
        "kind": "C.1",
        "epoch": 1,
        "sweeps_done": 10,
        "config": {"width": 4, "height": 4, "layers": 2, "n_models": 2,
                   "sweeps": 20, "sweeps_per_round": 10, "threads": 1,
                   "beta_cold": 3.0, "beta_hot": 0.5, "jtau": 0.3, "seed": 1},
        "states": ["01010101010101010101010101010101",
                   "10101010101010101010101010101010"]
    }"#;
    let ck = Checkpoint::from_json(fixture).unwrap();
    assert_eq!(ck.schema, 1);
    assert_eq!(ck.kind, "C.1");
    let rs = ck.run_spec().unwrap();
    assert_eq!(rs.sampler.rung, Rung::C1);
    assert_eq!(rs.config.n_models, 2);
    // States restore into a spec-built ensemble and the run completes.
    let report = coordinator::run_spec_with(
        &rs,
        &RunOptions { resume: Some(ck), ..RunOptions::default() },
    )
    .unwrap();
    assert_eq!(report.sweeps, 10, "one round remained");
    assert_eq!(report.n_models, 2);
    assert!(report.total_attempts > 0);
}

#[test]
fn heterogeneous_ladder_checkpoints_and_echoes_both_plans() {
    // 10 replicas under `--rung c1 --width auto`: on an 8-wide host the
    // partitioner schedules a w8 group + a w4 tail group; everywhere it
    // must cover all replicas and round-trip through a checkpoint.
    let dir = std::env::temp_dir().join("vectorising_resume_test_hetero");
    let _ = std::fs::remove_dir_all(&dir);
    let cfg = RunConfig { n_models: 10, sweeps: 40, sweeps_per_round: 10, ..RunConfig::default() };
    let rs = RunSpec::new(cfg.clone(), SamplerSpec::rung(Rung::C1));
    let ck_path = dir.join("full.ck.json");
    let full = coordinator::run_spec_with(
        &rs,
        &RunOptions {
            checkpoint: Some(ck_path.clone()),
            checkpoint_every: 2,
            resume: None,
        },
    )
    .unwrap();
    let covered: usize = full.plans.iter().map(|p| p.replicas).sum();
    assert_eq!(covered, 10, "the plans echo covers every replica: {:?}", full.plans);
    if vectorising::simd::widest_supported_width() == 8 {
        let widths: Vec<usize> = full.plans.iter().map(|p| p.resolved.width).collect();
        assert!(
            widths.contains(&8) && widths.contains(&4),
            "8-wide host should schedule a w8 group + w4 tail: {widths:?}"
        );
        assert!(full.kind.contains('+'), "heterogeneous label: {}", full.kind);
    }

    // Save at round 2 (via a half-length run), resume spec-driven from
    // the file, and diff energies bit-exactly against the full run.
    let half = RunSpec::new(RunConfig { sweeps: 20, ..cfg.clone() }, rs.sampler);
    let half_path = dir.join("half.ck.json");
    coordinator::run_spec_with(
        &half,
        &RunOptions {
            checkpoint: Some(half_path.clone()),
            checkpoint_every: 2,
            resume: None,
        },
    )
    .unwrap();
    let resumed = coordinator::resume_run(
        &half_path,
        |mut r| {
            r.config.sweeps = 40;
            r
        },
        &RunOptions { checkpoint: Some(ck_path), checkpoint_every: 2, resume: None },
    )
    .unwrap();
    assert_eq!(resumed.plans, full.plans, "resume rebuilds the same group layout");
    for (i, (a, b)) in full.energies.iter().zip(&resumed.energies).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "replica {i}: heterogeneous resume diverged");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn resume_without_rng_payload_still_restores_states() {
    // A states-only checkpoint (the pre-RNG format) restores states and
    // leaves the generators as the rebuilt ensemble seeded them.  (A real
    // resume must derive *fresh* sweeper seeds for the continued segment
    // — see the checkpoint module docs; this test only exercises the
    // states-only restore path.)
    let cfg = cfg();
    let mut pt = coordinator::build_ensemble(&cfg, SweepKind::A2Basic).unwrap();
    pt.round(cfg.sweeps_per_round);
    let mut ck = Checkpoint::capture(SweepKind::A2Basic, 1, 10, &cfg, &mut pt);
    let states = ck.states.clone();
    ck.rngs.clear();
    ck.swap_rng.clear();
    let mut fresh = coordinator::build_ensemble(&cfg, SweepKind::A2Basic).unwrap();
    ck.restore(&mut fresh).unwrap();
    for (i, s) in states.iter().enumerate() {
        assert_eq!(&fresh.state_of(i), s);
    }
}
