//! Integration tests over the software device (the in-process
//! reproduction of the paper's GPU half, `crate::device`).
//!
//! The load-bearing property is §3.2's own control: B.1 and B.2 differ
//! *only* in memory layout, so for the same seed both must retire the
//! identical trajectory — and because the device walks spins in scalar
//! A.2's order off one scalar MT19937, that trajectory must be
//! bit-identical to the CPU oracle too.  On top of that: the transaction
//! counters must actually separate the layouts (B.2 coalesces, B.1
//! serializes), and checkpoint/resume through the coordinator must be
//! transparent for both device rungs.

use vectorising::coordinator::{self, RunConfig, RunOptions, RunSpec};
use vectorising::engine::{BackendPref, EngineBuilder, Rung, SamplerSpec};
use vectorising::ising::builder::torus_workload;
use vectorising::sweep::{try_make_sweeper, SweepKind};

fn cfg() -> RunConfig {
    RunConfig { n_models: 4, sweeps: 40, sweeps_per_round: 10, ..RunConfig::default() }
}

#[test]
fn b1_and_b2_are_bit_exact_to_scalar_a2() {
    // Same seed, same workload, three betas: the two device layouts and
    // the scalar oracle must agree spin-for-spin and bit-for-bit.
    let wl = torus_workload(8, 8, 32, 1, 0.3);
    let mut a2 = try_make_sweeper(SweepKind::A2Basic, &wl.model, &wl.s0, 5489).unwrap();
    let mut b1 = try_make_sweeper(SweepKind::B1Accel, &wl.model, &wl.s0, 5489).unwrap();
    let mut b2 = try_make_sweeper(SweepKind::B2Accel, &wl.model, &wl.s0, 5489).unwrap();
    for (round, beta) in [0.5f32, 1.1, 2.0].into_iter().enumerate() {
        let sa = a2.run(10, beta);
        let s1 = b1.run(10, beta);
        let s2 = b2.run(10, beta);
        assert_eq!(sa.flips, s1.flips, "round {round}: B.1 flips diverged from A.2");
        assert_eq!(sa.flips, s2.flips, "round {round}: B.2 flips diverged from A.2");
        let ra = a2.state();
        assert_eq!(ra, b1.state(), "round {round}: B.1 state diverged");
        assert_eq!(ra, b2.state(), "round {round}: B.2 state diverged");
        assert_eq!(
            a2.energy().to_bits(),
            b2.energy().to_bits(),
            "round {round}: B.2 energy diverged"
        );
    }
    // The RNG streams stayed in lockstep too: identical 625-word
    // Mt19937 payloads after identical trajectories.
    assert_eq!(a2.rng_state(), b1.rng_state());
    assert_eq!(a2.rng_state(), b2.rng_state());
}

#[test]
fn transaction_counters_separate_the_layouts() {
    let wl = torus_workload(8, 8, 32, 1, 0.3);
    let before = vectorising::device::global_totals();
    let mut b1 = try_make_sweeper(SweepKind::B1Accel, &wl.model, &wl.s0, 7).unwrap();
    let mut b2 = try_make_sweeper(SweepKind::B2Accel, &wl.model, &wl.s0, 7).unwrap();
    b1.run(5, 0.8);
    b2.run(5, 0.8);
    let d1 = b1.device_stats().expect("B.1 exposes device stats");
    let d2 = b2.device_stats().expect("B.2 exposes device stats");
    assert!(d1.warps > 0 && d2.warps > 0);
    assert_eq!(d1.warps, d2.warps, "same grid, same warp count");
    // The paper's axis: the naive layout serializes warp accesses, the
    // coalesced layout turns them into few wide transactions.
    assert!(
        d2.coalescing_efficiency() > d1.coalescing_efficiency(),
        "B.2 must coalesce better than B.1: {:?} vs {:?}",
        d2,
        d1
    );
    assert!(d1.strided > d2.strided, "B.1 is the strided layout: {d1:?} vs {d2:?}");
    assert!(d2.transactions() < d1.transactions(), "coalescing must reduce total traffic");
    // Both sweepers flushed into the process-wide totals the metrics
    // endpoint exports.
    let after = vectorising::device::global_totals();
    assert!(after.0 >= before.0 + d2.coalesced);
    assert!(after.1 >= before.1 + d1.strided);
}

#[test]
fn device_rungs_resume_bit_exactly_through_the_coordinator() {
    for rung in [Rung::B1, Rung::B2] {
        let dir = std::env::temp_dir().join(format!("vectorising_device_resume_{rung:?}"));
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = cfg();
        let rs = RunSpec::new(cfg.clone(), SamplerSpec::rung(rung).on(BackendPref::Accel));
        let full = coordinator::run_spec_with(&rs, &RunOptions::default()).unwrap();
        assert_eq!(full.kind, rung.label());
        assert_eq!(full.plans[0].resolved.width, 32);

        // Save at the halfway point, then resume to the full length.
        let half = RunSpec::new(RunConfig { sweeps: 20, ..cfg.clone() }, rs.sampler);
        let half_path = dir.join("half.ck.json");
        coordinator::run_spec_with(
            &half,
            &RunOptions {
                checkpoint: Some(half_path.clone()),
                checkpoint_every: 2,
                resume: None,
            },
        )
        .unwrap();
        let resumed = coordinator::resume_run(
            &half_path,
            |mut r| {
                r.config.sweeps = 40;
                r
            },
            &RunOptions::default(),
        )
        .unwrap();
        assert_eq!(resumed.plans, full.plans, "{rung:?}: resume rebuilds the same plan");
        for (i, (a, b)) in full.energies.iter().zip(&resumed.energies).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{rung:?} replica {i}: resume diverged");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn device_runs_match_the_scalar_oracle_through_the_coordinator() {
    // The acceptance check, end to end: `--rung b2 --backend accel` and
    // the scalar A.2 ladder produce bit-identical ensembles (same
    // per-replica seeds, same tempering schedule, same trajectories).
    let cfg = cfg();
    let accel = coordinator::run_spec_with(
        &RunSpec::new(cfg.clone(), SamplerSpec::rung(Rung::B2).on(BackendPref::Accel)),
        &RunOptions::default(),
    )
    .unwrap();
    let scalar = coordinator::run_spec_with(
        &RunSpec::new(cfg, SamplerSpec::rung(Rung::A2)),
        &RunOptions::default(),
    )
    .unwrap();
    assert_eq!(accel.energies.len(), scalar.energies.len());
    for (i, (a, s)) in accel.energies.iter().zip(&scalar.energies).enumerate() {
        assert_eq!(a.to_bits(), s.to_bits(), "replica {i}: device diverged from scalar A.2");
    }
    assert_eq!(accel.total_attempts, scalar.total_attempts);
}

#[test]
fn odd_depth_b2_names_the_nearest_runnable_accel_config() {
    use vectorising::engine::UnsupportedGeometry;
    let err = EngineBuilder::new(SamplerSpec::rung(Rung::B2).on(BackendPref::Accel))
        .layers(9)
        .plan()
        .err()
        .expect("odd tau depth cannot pair-pack");
    let ug = err.downcast_ref::<UnsupportedGeometry>().expect("structured geometry error");
    assert_eq!(ug.layers, 9);
    let first = ug.alternatives.first().expect("alternatives offered");
    assert_eq!(first.rung, Rung::B1, "nearest accel config first");
    assert_eq!(first.backend, BackendPref::Accel);
    assert!(EngineBuilder::new(*first).layers(9).plan().is_ok(), "and it actually resolves");
}
