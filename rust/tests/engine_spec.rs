//! Engine API v1 contract tests.
//!
//! * Every legacy `SweepKind` CLI spelling round-trips onto the
//!   equivalent orthogonal `SamplerSpec` (and back through the plan's
//!   `legacy_kind`).
//! * `EngineBuilder`-built sweepers are **bit-exact** with the legacy
//!   `try_make_sweeper` constructors for all single-model rungs ×
//!   W ∈ {4, 8}, and for the C-rung lane-batches.
//! * Negotiation: the acceptance scenario (`c1`/auto/layers=2) explains
//!   the A-rung rejections; geometry failures downcast to
//!   `UnsupportedGeometry` with usable alternatives.

use std::str::FromStr;

use vectorising::engine::{
    Backend, BackendPref, EngineBuilder, Rung, SamplerSpec, UnsupportedGeometry,
};
use vectorising::ising::builder::torus_workload;
use vectorising::sweep::c1_replica_batch::{make_batch_sweeper, BatchSweeper};
use vectorising::sweep::{try_make_sweeper, ExpMode, SweepKind, Sweeper};

/// Every CLI spelling of every legacy kind, with the spec it must lower
/// to.  (The table mirrors `SweepKind::from_str` exhaustively.)
fn spelling_table() -> Vec<(&'static str, SamplerSpec)> {
    let s = SamplerSpec::rung;
    vec![
        ("a1-original", s(Rung::A1).w(1)),
        ("a1", s(Rung::A1).w(1)),
        ("A.1", s(Rung::A1).w(1)),
        ("a2-basic", s(Rung::A2).w(1)),
        ("a2", s(Rung::A2).w(1)),
        ("A.2", s(Rung::A2).w(1)),
        ("a3-vec-rng", s(Rung::A3).w(4)),
        ("a3-vecrng", s(Rung::A3).w(4)),
        ("a3", s(Rung::A3).w(4)),
        ("A.3", s(Rung::A3).w(4)),
        ("a3-vec-rng-w4", s(Rung::A3).w(4)),
        ("a3-w4", s(Rung::A3).w(4)),
        ("a4-full", s(Rung::A4).w(4)),
        ("a4", s(Rung::A4).w(4)),
        ("A.4", s(Rung::A4).w(4)),
        ("a4-full-w4", s(Rung::A4).w(4)),
        ("a4-w4", s(Rung::A4).w(4)),
        ("a3-vec-rng-w8", s(Rung::A3).w(8)),
        ("a3-vecrng-w8", s(Rung::A3).w(8)),
        ("a3-w8", s(Rung::A3).w(8)),
        ("A.3w8", s(Rung::A3).w(8)),
        ("a4-full-w8", s(Rung::A4).w(8)),
        ("a4-w8", s(Rung::A4).w(8)),
        ("A.4w8", s(Rung::A4).w(8)),
        ("c1-replica-batch", s(Rung::C1).w(4)),
        ("c1", s(Rung::C1).w(4)),
        ("C.1", s(Rung::C1).w(4)),
        ("c1-replica-batch-w4", s(Rung::C1).w(4)),
        ("c1-w4", s(Rung::C1).w(4)),
        ("c1-replica-batch-w8", s(Rung::C1).w(8)),
        ("c1-w8", s(Rung::C1).w(8)),
        ("C.1w8", s(Rung::C1).w(8)),
        ("b1-accel", s(Rung::B1).w(32).on(BackendPref::Accel)),
        ("b1", s(Rung::B1).w(32).on(BackendPref::Accel)),
        ("B.1", s(Rung::B1).w(32).on(BackendPref::Accel)),
        ("b2-accel", s(Rung::B2).w(32).on(BackendPref::Accel)),
        ("b2", s(Rung::B2).w(32).on(BackendPref::Accel)),
        ("B.2", s(Rung::B2).w(32).on(BackendPref::Accel)),
    ]
}

#[test]
fn every_legacy_spelling_lowers_to_the_equivalent_spec() {
    for (spelling, want) in spelling_table() {
        let kind = SweepKind::from_str(spelling).unwrap_or_else(|e| {
            panic!("legacy spelling {spelling:?} must still parse: {e}");
        });
        assert_eq!(kind.spec(), want, "spelling {spelling:?}");
    }
}

#[test]
fn plans_round_trip_back_to_the_legacy_kind() {
    // For every legacy kind whose plan is resolvable without hardware
    // (i.e. the CPU rungs), the negotiated plan names that same kind.
    let layers = 16; // supports both the w4 and w8 interlacing
    for kind in [
        SweepKind::A1Original,
        SweepKind::A2Basic,
        SweepKind::A3VecRng,
        SweepKind::A4Full,
        SweepKind::A3VecRngW8,
        SweepKind::A4FullW8,
        SweepKind::C1ReplicaBatch,
        SweepKind::C1ReplicaBatchW8,
    ] {
        let plan = EngineBuilder::new(kind.spec()).layers(layers).plan().unwrap();
        assert_eq!(plan.legacy_kind(), Some(kind), "{kind:?}");
        assert_eq!(plan.label(), kind.label(), "{kind:?}");
        assert_eq!(plan.width, kind.group_width(), "{kind:?}");
    }
}

/// Drive a legacy-built and a builder-built sweeper through the same
/// schedule and require bit-identical trajectories.
fn assert_bit_exact(kind: SweepKind, spec: SamplerSpec, layers: usize) {
    let wl = torus_workload(4, 4, layers, 3, 0.3);
    let mut legacy = try_make_sweeper(kind, &wl.model, &wl.s0, 41).unwrap();
    let mut built = EngineBuilder::new(spec).build(&wl.model, &wl.s0, 41).unwrap();
    assert_eq!(built.plan.legacy_kind(), Some(kind));
    for &beta in &[0.4f32, 0.9, 1.5] {
        let sl = legacy.run(7, beta);
        let sb = built.run(7, beta);
        assert_eq!(sl.flips, sb.flips, "{kind:?} flips at beta={beta}");
        assert_eq!(sl.attempts, sb.attempts);
        assert_eq!(
            legacy.energy().to_bits(),
            built.energy().to_bits(),
            "{kind:?} energy at beta={beta}"
        );
    }
    let state_l: Vec<u32> = legacy.state().iter().map(|x| x.to_bits()).collect();
    let state_b: Vec<u32> = built.state().iter().map(|x| x.to_bits()).collect();
    assert_eq!(state_l, state_b, "{kind:?} final state");
    assert_eq!(legacy.rng_state(), built.rng_state(), "{kind:?} rng stream position");
}

#[test]
fn builder_is_bit_exact_with_legacy_constructors_for_all_rungs() {
    let layers = 16; // divisible into 4 and 8 sections of >= 2 layers
    assert_bit_exact(SweepKind::A1Original, SamplerSpec::rung(Rung::A1), layers);
    assert_bit_exact(SweepKind::A2Basic, SamplerSpec::rung(Rung::A2), layers);
    assert_bit_exact(SweepKind::A3VecRng, SamplerSpec::rung(Rung::A3).w(4), layers);
    assert_bit_exact(SweepKind::A4Full, SamplerSpec::rung(Rung::A4).w(4), layers);
    assert_bit_exact(SweepKind::A3VecRngW8, SamplerSpec::rung(Rung::A3).w(8), layers);
    assert_bit_exact(SweepKind::A4FullW8, SamplerSpec::rung(Rung::A4).w(8), layers);
}

#[test]
fn builder_batches_are_bit_exact_with_legacy_batch_constructor() {
    for (kind, w) in [(SweepKind::C1ReplicaBatch, 4usize), (SweepKind::C1ReplicaBatchW8, 8)] {
        let wls: Vec<_> = (0..w).map(|k| torus_workload(4, 4, 4, k as u64, 0.3)).collect();
        let models: Vec<_> = wls.iter().map(|wl| wl.model.clone()).collect();
        let states: Vec<_> = wls.iter().map(|wl| wl.s0.clone()).collect();
        let seeds: Vec<u32> = (0..w as u32).map(|k| 7000 + k).collect();
        let betas: Vec<f32> = (0..w).map(|k| 0.4 + 0.1 * k as f32).collect();

        let mut legacy =
            make_batch_sweeper(kind, &models, &states, &seeds, ExpMode::Fast).unwrap();
        let mut built = EngineBuilder::new(kind.spec())
            .exp(ExpMode::Fast)
            .build_batch(&models, &states, &seeds)
            .unwrap();
        assert_eq!(built.plan.width, w);
        let sl = legacy.run(9, &betas);
        let sb = built.run(9, &betas);
        for k in 0..w {
            assert_eq!(sl[k].flips, sb[k].flips, "lane {k} of {kind:?}");
            assert_eq!(
                legacy.energy_of(k).to_bits(),
                built.energy_of(k).to_bits(),
                "lane {k} of {kind:?}"
            );
            let a: Vec<u32> = legacy.state_of(k).iter().map(|x| x.to_bits()).collect();
            let b: Vec<u32> = built.state_of(k).iter().map(|x| x.to_bits()).collect();
            assert_eq!(a, b, "lane {k} of {kind:?}");
        }
        assert_eq!(legacy.rng_state(), built.rng_state(), "{kind:?}");
    }
}

#[test]
fn pinned_portable_backend_is_bit_exact_with_the_intrinsic_one() {
    // The portable lanes are the differential oracle: pinning them via
    // the spec must reproduce the auto-negotiated intrinsic backend bit
    // for bit (same algorithm, different instructions).
    let wl = torus_workload(4, 4, 16, 5, 0.3);
    for width in [4usize, 8] {
        let auto_spec = SamplerSpec::rung(Rung::A4).w(width);
        let portable_spec = auto_spec.on(BackendPref::Portable);
        let mut auto_built = EngineBuilder::new(auto_spec).build(&wl.model, &wl.s0, 9).unwrap();
        let mut portable =
            EngineBuilder::new(portable_spec).build(&wl.model, &wl.s0, 9).unwrap();
        assert_eq!(portable.plan.backend, Backend::Portable);
        auto_built.run(11, 0.8);
        portable.run(11, 0.8);
        assert_eq!(
            auto_built.energy().to_bits(),
            portable.energy().to_bits(),
            "width {width}: portable and {} must agree bit-for-bit",
            auto_built.plan.backend
        );
        let a: Vec<u32> = auto_built.state().iter().map(|x| x.to_bits()).collect();
        let b: Vec<u32> = portable.state().iter().map(|x| x.to_bits()).collect();
        assert_eq!(a, b, "width {width} state");
    }
}

#[test]
fn acceptance_c1_auto_plan_at_layers_2() {
    // `repro plan --rung c1 --width auto --layers 2` in API form: the
    // plan names a concrete backend, the effective width, and the reason
    // the A-rungs were rejected.
    let plan = EngineBuilder::new(SamplerSpec::rung(Rung::C1)).layers(2).plan().unwrap();
    assert!(matches!(plan.backend, Backend::Sse2 | Backend::Avx2 | Backend::Portable));
    assert!(plan.width == 4 || plan.width == 8);
    assert!(
        plan.rejected
            .iter()
            .any(|r| matches!(r.rung, Rung::A3 | Rung::A4) && r.code == "layer-interlace"),
        "missing A-rung rejection reasons: {:?}",
        plan.rejected
    );
    let json = plan.to_json();
    assert!(json.contains("\"protocol_version\":1"), "{json}");
    assert!(json.contains("layer-interlace"), "{json}");
}

#[test]
fn geometry_errors_are_structured_with_alternatives() {
    let wl = torus_workload(4, 4, 12, 1, 0.3); // 12 % 8 != 0
    let err = EngineBuilder::new(SamplerSpec::rung(Rung::A4).w(8))
        .build(&wl.model, &wl.s0, 1)
        .err()
        .unwrap();
    let ug = err.downcast_ref::<UnsupportedGeometry>().expect("structured geometry error");
    assert_eq!((ug.rung, ug.width, ug.layers), (Rung::A4, 8, 12));
    // The alternatives actually work at this geometry.
    let alt = ug.alternatives.first().expect("at least one alternative");
    assert!(EngineBuilder::new(*alt).layers(12).plan().is_ok(), "alternative {alt} must plan");
    assert!(ug.alternatives.iter().any(|a| a.rung == Rung::C1));
    // And the legacy shim surfaces the same structured error.
    let err2 = try_make_sweeper(SweepKind::A4FullW8, &wl.model, &wl.s0, 1).err().unwrap();
    assert!(err2.downcast_ref::<UnsupportedGeometry>().is_some());
}

#[test]
fn portable_width_16_builds_and_samples() {
    // Widths beyond the intrinsic backends come free via the
    // const-generic portable lanes: no new enum variant, just a spec.
    let wl = torus_workload(4, 4, 32, 1, 0.3);
    let spec = SamplerSpec::rung(Rung::A4).w(16);
    let mut engine = EngineBuilder::new(spec).build(&wl.model, &wl.s0, 77).unwrap();
    assert_eq!(engine.plan.backend, Backend::Portable);
    assert_eq!(engine.plan.width, 16);
    assert_eq!(engine.plan.label(), "A.4w16");
    assert_eq!(engine.width(), 16, "Sweeper::width reports the true lane count");
    let stats = engine.run(20, 0.8);
    assert_eq!(stats.attempts, 20 * 4 * 4 * 32);
    assert!(stats.flips > 0, "a hot sweep must flip something");
    assert!(engine.validate() < 1e-3, "incremental fields stay exact at W=16");
    // And C.1 at 16 lanes (16 independent replicas in lockstep).
    let wls: Vec<_> = (0..16).map(|k| torus_workload(4, 4, 2, k as u64, 0.3)).collect();
    let models: Vec<_> = wls.iter().map(|wl| wl.model.clone()).collect();
    let states: Vec<_> = wls.iter().map(|wl| wl.s0.clone()).collect();
    let seeds: Vec<u32> = (0..16).collect();
    let betas = vec![0.8f32; 16];
    let mut batch = EngineBuilder::new(SamplerSpec::rung(Rung::C1).w(16))
        .build_batch(&models, &states, &seeds)
        .unwrap();
    assert_eq!(batch.plan.label(), "C.1w16");
    let per_lane = batch.run(5, &betas);
    assert_eq!(per_lane.len(), 16);
    assert!(batch.validate() < 1e-3);
}

#[test]
fn width_auto_respects_the_host_and_geometry() {
    let widest = vectorising::simd::widest_supported_width();
    let plan = EngineBuilder::new(SamplerSpec::rung(Rung::A4)).layers(32).plan().unwrap();
    assert_eq!(plan.width, widest, "auto width picks the host's widest backend");
    // layers=12 rejects w8, so auto narrows to 4 — same decision the old
    // `preferred_cpu_for_layers` made, now with the reason recorded.
    let narrowed = EngineBuilder::new(SamplerSpec::rung(Rung::A4)).layers(12).plan().unwrap();
    assert_eq!(narrowed.width, 4);
}
