//! Integration tests over the PJRT runtime + accelerator sweeps.
//!
//! These need `make artifacts` to have run; if no artifacts are present
//! the tests report that loudly via panic with a clear message (the
//! Makefile always builds artifacts before `cargo test`).

use std::path::PathBuf;

use vectorising::ising::builder::torus_workload;
use vectorising::runtime::{artifact, Runtime};
use vectorising::sweep::accel::{AccelSweeper, AccelVariant};
use vectorising::sweep::{try_make_sweeper, SweepKind, Sweeper};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = artifact::default_dir();
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping accel test: no artifacts at {dir:?} (run `make artifacts`)");
        None
    }
}

fn default_workload() -> vectorising::ising::builder::Workload {
    torus_workload(8, 8, 32, 1, 0.3)
}

#[test]
fn manifest_lists_both_variants() {
    let Some(dir) = artifacts_dir() else { return };
    let man = artifact::Manifest::load(&dir).unwrap();
    assert!(man.get("b1_naive_default").is_ok());
    assert!(man.get("b2_coalesced_default").is_ok());
    for a in &man.artifacts {
        assert!(dir.join(&a.hlo_file).exists(), "missing {:?}", a.hlo_file);
        assert!(a.hlo_bytes > 1000);
    }
}

#[test]
fn b1_and_b2_produce_identical_trajectories() {
    // The paper's B.1/B.2 differ only in memory layout; our artifacts
    // consume the same RNG stream, so trajectories must be bit-equal.
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu().unwrap();
    let wl = default_workload();
    let mut b1 = AccelSweeper::new(&rt, &dir, "default", AccelVariant::B1Naive, &wl, 5489).unwrap();
    let mut b2 = AccelSweeper::new(&rt, &dir, "default", AccelVariant::B2Coalesced, &wl, 5489).unwrap();
    for round in 0..3 {
        let s1 = b1.run(10, 0.5);
        let s2 = b2.run(10, 0.5);
        assert_eq!(s1.flips, s2.flips, "round {round}");
        assert_eq!(b1.state(), b2.state(), "round {round}");
    }
}

#[test]
fn accel_is_deterministic() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu().unwrap();
    let wl = default_workload();
    let mut x = AccelSweeper::new(&rt, &dir, "default", AccelVariant::B2Coalesced, &wl, 7).unwrap();
    let mut y = AccelSweeper::new(&rt, &dir, "default", AccelVariant::B2Coalesced, &wl, 7).unwrap();
    x.run(20, 0.8);
    y.run(20, 0.8);
    assert_eq!(x.state(), y.state());
    assert_eq!(x.energy(), y.energy());
}

#[test]
fn artifact_energy_matches_host_energy() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu().unwrap();
    let wl = default_workload();
    for variant in [AccelVariant::B1Naive, AccelVariant::B2Coalesced] {
        let mut sw = AccelSweeper::new(&rt, &dir, "default", variant, &wl, 11).unwrap();
        sw.run(10, 0.6);
        let diff = sw.validate();
        assert!(diff < 0.05, "{variant:?}: |E_artifact - E_host| = {diff}");
    }
}

#[test]
fn accel_matches_cpu_rungs_statistically() {
    // B.2 and A.4 run different schedules (checkerboard vs sequential) but
    // sample the same Boltzmann distribution; equilibrium energies at the
    // same β must agree within a few percent.
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu().unwrap();
    let beta = 0.9f32;
    let wl = default_workload();
    let mut b2 = AccelSweeper::new(&rt, &dir, "default", AccelVariant::B2Coalesced, &wl, 3).unwrap();
    b2.run(100, beta);
    let mut acc_b = 0.0;
    for _ in 0..20 {
        b2.run(10, beta);
        acc_b += b2.energy();
    }
    let e_accel = acc_b / 20.0;

    let mut a4 = try_make_sweeper(SweepKind::A4Full, &wl.model, &wl.s0, 3).unwrap();
    a4.run(100, beta);
    let mut acc_a = 0.0;
    for _ in 0..40 {
        a4.run(5, beta);
        acc_a += a4.energy();
    }
    let e_cpu = acc_a / 40.0;
    let rel = (e_accel - e_cpu).abs() / e_cpu.abs();
    assert!(rel < 0.05, "accel {e_accel} vs cpu {e_cpu} (rel {rel})");
}

#[test]
fn geometry_mismatch_is_rejected() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu().unwrap();
    let wrong = torus_workload(4, 4, 8, 1, 0.3); // artifact is 64x32
    let err = AccelSweeper::new(&rt, &dir, "default", AccelVariant::B2Coalesced, &wrong, 1);
    assert!(err.is_err());
    let msg = format!("{:#}", err.err().unwrap());
    assert!(msg.contains("workload"), "unhelpful error: {msg}");
}

#[test]
fn missing_artifact_is_a_clean_error() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu().unwrap();
    let err = match rt.load_artifact(&dir, "b9_nonexistent") {
        Err(e) => e,
        Ok(_) => panic!("expected missing-artifact error"),
    };
    assert!(format!("{err:#}").contains("not in manifest"));
}

#[test]
fn corrupt_hlo_is_a_clean_error() {
    let Some(dir) = artifacts_dir() else { return };
    // Copy the manifest entry but point it at a garbage HLO file.
    let tmp = std::env::temp_dir().join("vectorising_corrupt_artifacts");
    std::fs::create_dir_all(&tmp).unwrap();
    std::fs::write(tmp.join("bad.hlo.txt"), "HloModule nonsense ENTRY { broken").unwrap();
    let man = artifact::Manifest::load(&dir).unwrap();
    let mut meta = man.get("b2_coalesced_default").unwrap().clone();
    meta.hlo_file = "bad.hlo.txt".to_string();
    let rt = Runtime::cpu().unwrap();
    let err = match rt.compile_meta(&tmp, meta) {
        Err(e) => e,
        Ok(_) => panic!("expected corrupt-HLO error"),
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("parse HLO") || msg.contains("compile"), "{msg}");
    let _ = std::fs::remove_dir_all(&tmp);
}

#[test]
fn set_state_roundtrip_on_accel() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu().unwrap();
    let wl = default_workload();
    let mut sw = AccelSweeper::new(&rt, &dir, "default", AccelVariant::B2Coalesced, &wl, 5).unwrap();
    sw.run(10, 0.5);
    let snap = sw.state();
    sw.run(10, 0.5);
    sw.set_state(&snap);
    assert_eq!(sw.state(), snap);
}
