//! End-to-end acceptance of the shard router (ISSUE 10): a router
//! fronting two *real* worker processes (this crate's own binary
//! running `repro serve`) must
//!
//! * serve a mixed-shape burst **bit-exact** to the scalar oracle,
//!   with zero client changes (the wire protocol is the workers' own),
//! * aggregate `hello`/`stats`/`metrics`/`trace` cluster-wide (exact
//!   histogram merges, per-worker Prometheus labels),
//! * propagate backpressure: a job is rejected only when *every*
//!   replica refused it, with the merged `retry_after_ms` hint,
//! * and lose **zero admitted jobs** when a worker is killed
//!   mid-burst — its in-flight jobs replay onto the survivor.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::process::{Child, Command, Stdio};
use std::thread;
use std::time::Duration;

use vectorising::coordinator::{self, RunConfig, RunOptions, RunReport, RunSpec};
use vectorising::engine::{Rung, SamplerSpec};
use vectorising::router::{self, RouterConfig};
use vectorising::service::executor::Executor;
use vectorising::service::job::{JobResult, JobSpec, RunJob};
use vectorising::sweep::ExpMode;
use vectorising::util::json::Value;

fn spec(id: &str, shape: (usize, usize, usize), seed: u32, sweeps: usize) -> JobSpec {
    JobSpec {
        id: id.to_string(),
        width: shape.0,
        height: shape.1,
        layers: shape.2,
        model_seed: 1 + seed as u64,
        jtau: 0.3,
        sweeps,
        beta: 0.6 + 0.05 * (seed % 4) as f32,
        seed,
        trace_every: 0,
        want_state: true,
        want_timing: false,
        sampler: None,
    }
}

/// Boot one worker process (`repro serve --listen 127.0.0.1:0 ...`) and
/// parse its bound address from the serve banner.
fn spawn_worker(extra: &[&str]) -> (String, Child) {
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_repro"));
    cmd.args(["serve", "--listen", "127.0.0.1:0"]).args(extra);
    cmd.stdin(Stdio::null()).stdout(Stdio::null()).stderr(Stdio::piped());
    let mut child = cmd.spawn().expect("spawn worker");
    let stderr = child.stderr.take().expect("piped stderr");
    let mut reader = BufReader::new(stderr);
    let mut line = String::new();
    let addr = loop {
        line.clear();
        let n = reader.read_line(&mut line).expect("worker stderr");
        assert!(n > 0, "worker exited before announcing its address");
        if let Some(rest) = line.split("listening on ").nth(1) {
            break rest.split(" (").next().unwrap_or(rest).trim().to_string();
        }
    };
    // Keep draining stderr so the worker can never block on a full pipe.
    thread::spawn(move || {
        let mut sink = String::new();
        loop {
            sink.clear();
            match reader.read_line(&mut sink) {
                Ok(0) | Err(_) => break,
                Ok(_) => {}
            }
        }
    });
    (addr, child)
}

/// Start the router tier in-process, fronting `workers`.
fn start_router(
    workers: Vec<String>,
    replicas: usize,
    health_ms: u64,
) -> (SocketAddr, thread::JoinHandle<()>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let cfg = RouterConfig { replicas, health_ms };
    let handle = thread::spawn(move || router::serve(listener, &workers, &cfg).unwrap());
    (addr, handle)
}

/// Open a connection, send every line, half-close, read lines until the
/// server closes — identical to how a client talks to a single worker.
fn roundtrip(addr: SocketAddr, lines: &[String]) -> Vec<String> {
    let stream = TcpStream::connect(addr).unwrap();
    {
        let mut w = std::io::BufWriter::new(stream.try_clone().unwrap());
        for line in lines {
            writeln!(w, "{line}").unwrap();
        }
        w.flush().unwrap();
    }
    stream.shutdown(Shutdown::Write).unwrap();
    BufReader::new(stream)
        .lines()
        .map(|l| l.unwrap())
        .filter(|l| !l.trim().is_empty())
        .collect()
}

fn assert_bit_exact(served: &[String], reference: &Executor, expect: &[JobSpec]) {
    let mut by_id: BTreeMap<String, JobResult> = BTreeMap::new();
    for line in served {
        let r = JobResult::from_line(line).unwrap_or_else(|e| panic!("{e:#}: {line}"));
        by_id.insert(r.id.clone(), r);
    }
    assert_eq!(by_id.len(), expect.len(), "one result per job");
    for spec in expect {
        let got = &by_id[&spec.id];
        let want = reference.run_single(spec).unwrap();
        assert_eq!(
            got.energy.to_bits(),
            want.energy.to_bits(),
            "job {}: routed result must be bit-exact to the scalar oracle",
            spec.id
        );
        assert_eq!(got.stats.flips, want.stats.flips, "job {}: flips", spec.id);
        assert_eq!(got.stats.attempts, want.stats.attempts, "job {}: attempts", spec.id);
        assert_eq!(got.state, want.state, "job {}: final state", spec.id);
    }
}

fn kill_all(children: Vec<Child>) {
    for mut c in children {
        let _ = c.kill();
        let _ = c.wait();
    }
}

#[test]
fn routed_burst_is_bit_exact_and_control_ops_aggregate_cluster_wide() {
    let (addr_a, child_a) = spawn_worker(&["--lanes", "4", "--threads", "1", "--flush-ms", "50"]);
    let (addr_b, child_b) = spawn_worker(&["--lanes", "4", "--threads", "1", "--flush-ms", "50"]);
    let (router_addr, router_thread) =
        start_router(vec![addr_a.clone(), addr_b.clone()], 2, 300);
    let reference = Executor::new(4, ExpMode::Fast).unwrap();

    // Handshake: the router's capability view covers every worker.
    let hello = roundtrip(router_addr, &["{\"op\":\"hello\"}".to_string()]);
    assert_eq!(hello.len(), 1, "{hello:?}");
    let v = Value::parse(&hello[0]).unwrap();
    assert_eq!(v.get("op").unwrap().as_str().unwrap(), "hello");
    assert!(v.get("router").unwrap().as_bool().unwrap(), "{}", hello[0]);
    assert_eq!(v.get("replicas").unwrap().as_usize().unwrap(), 2);
    let workers = v.get("workers").unwrap().as_arr().unwrap();
    assert_eq!(workers.len(), 2);
    for w in workers {
        assert!(w.get("alive").unwrap().as_bool().unwrap());
        assert_eq!(w.get("protocol_version").unwrap().as_usize().unwrap(), 1);
        assert!(!w.get("rungs").unwrap().as_arr().unwrap().is_empty(), "{}", hello[0]);
    }

    // Mixed-shape burst, 2×W per shape plus a lone odd shape — the
    // acceptance burst, sent exactly as a client would send it to a
    // single worker.
    let mut jobs: Vec<JobSpec> = Vec::new();
    for i in 0..8 {
        jobs.push(spec(&format!("s{i}"), (4, 4, 8), 100 + i as u32, 30 + (i % 3) * 10));
    }
    for i in 0..8 {
        jobs.push(spec(&format!("t{i}"), (4, 4, 2), 200 + i as u32, 40));
    }
    jobs.push(spec("lone", (6, 4, 8), 300, 30));
    let served = roundtrip(router_addr, &jobs.iter().map(|s| s.to_line()).collect::<Vec<_>>());
    assert_eq!(served.len(), jobs.len(), "{served:?}");
    assert_bit_exact(&served, &reference, &jobs);

    // A run job routes too (to the least-loaded worker) and stays
    // bit-exact to the coordinator oracle.
    let rs = RunSpec::new(
        RunConfig { n_models: 3, sweeps: 20, sweeps_per_round: 10, ..RunConfig::default() },
        SamplerSpec::rung(Rung::C1),
    );
    let run =
        RunJob { id: "run1".into(), spec: rs.clone(), checkpoint: None, want_checkpoint: false };
    let run_served = roundtrip(router_addr, &[run.to_line()]);
    assert_eq!(run_served.len(), 1, "{run_served:?}");
    let rv = Value::parse(&run_served[0]).unwrap();
    assert_eq!(rv.get("status").unwrap().as_str().unwrap(), "ok", "{run_served:?}");
    assert_eq!(rv.get("id").unwrap().as_str().unwrap(), "run1");
    let report = RunReport::from_value(rv.get("run_report").unwrap()).unwrap();
    let local = coordinator::run_spec_with(&rs, &RunOptions::default()).unwrap();
    for (i, (a, b)) in local.energies.iter().zip(&report.energies).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "replica {i}: routed run diverged");
    }

    // Cluster stats: counters summed over workers, histograms merged
    // exactly, per-worker roll call, router section.
    let stats = roundtrip(router_addr, &["{\"op\":\"stats\"}".to_string()]);
    assert_eq!(stats.len(), 1);
    let v = Value::parse(&stats[0]).unwrap();
    assert_eq!(v.get("op").unwrap().as_str().unwrap(), "stats");
    assert_eq!(v.get("protocol_version").unwrap().as_usize().unwrap(), 1);
    let total = jobs.len() + 1; // burst + run
    assert_eq!(v.get("jobs_completed").unwrap().as_usize().unwrap(), total, "{}", stats[0]);
    assert_eq!(v.get("runs_executed").unwrap().as_usize().unwrap(), 1);
    assert_eq!(v.get("jobs_in_system").unwrap().as_usize().unwrap(), 0);
    assert!(v.get("lane_fill_ratio").unwrap().as_f64().unwrap() > 0.0);
    let e2e = v.get("latency_us").unwrap().get("e2e").unwrap();
    assert!(
        e2e.get("count").unwrap().as_usize().unwrap() >= jobs.len(),
        "cluster e2e histogram counts the whole burst: {}",
        stats[0]
    );
    assert!(e2e.get("p50_us").unwrap().as_f64().unwrap() > 0.0);
    let worker_rows = v.get("workers").unwrap().as_arr().unwrap();
    assert_eq!(worker_rows.len(), 2);
    let completed_split: Vec<usize> = worker_rows
        .iter()
        .map(|w| {
            assert!(w.get("alive").unwrap().as_bool().unwrap());
            w.get("jobs_completed").unwrap().as_usize().unwrap()
        })
        .collect();
    assert_eq!(completed_split.iter().sum::<usize>(), total, "split: {completed_split:?}");
    let router_v = v.get("router").unwrap();
    assert_eq!(router_v.get("jobs_routed").unwrap().as_usize().unwrap(), jobs.len());
    assert_eq!(router_v.get("runs_routed").unwrap().as_usize().unwrap(), 1);
    assert_eq!(router_v.get("replies_relayed").unwrap().as_usize().unwrap(), total);
    assert_eq!(router_v.get("workers_alive").unwrap().as_usize().unwrap(), 2);
    assert_eq!(router_v.get("workers_lost").unwrap().as_usize().unwrap(), 0);

    // Cluster Prometheus: one header per family, every sample labeled
    // with its worker, router families under worker="router".
    let m = roundtrip(router_addr, &["{\"op\":\"metrics\"}".to_string()]);
    let v = Value::parse(&m[0]).unwrap();
    assert_eq!(v.get("op").unwrap().as_str().unwrap(), "metrics");
    let text = v.get("text").unwrap().as_str().unwrap().to_string();
    assert_eq!(
        text.matches("# TYPE repro_jobs_completed_total counter").count(),
        1,
        "one family header despite two workers:\n{text}"
    );
    assert!(text.contains(&format!("worker=\"{addr_a}\"")), "{text}");
    assert!(text.contains(&format!("worker=\"{addr_b}\"")), "{text}");
    assert!(text.contains("repro_router_jobs_routed_total{worker=\"router\""), "{text}");
    assert!(text.contains("# TYPE repro_router_workers_alive gauge"), "{text}");
    for line in text.lines().filter(|l| !l.starts_with('#') && !l.is_empty()) {
        assert!(line.contains("worker=\""), "unlabeled sample: {line}");
    }

    // Cluster trace: entries from both workers, each tagged.
    let tr = roundtrip(router_addr, &["{\"op\":\"trace\",\"last\":50}".to_string()]);
    let v = Value::parse(&tr[0]).unwrap();
    assert_eq!(v.get("op").unwrap().as_str().unwrap(), "trace");
    let traces = v.get("traces").unwrap().as_arr().unwrap();
    assert!(traces.len() >= jobs.len(), "{}", tr[0]);
    let mut seen_workers: Vec<&str> = traces
        .iter()
        .map(|t| t.get("worker").unwrap().as_str().unwrap())
        .collect();
    seen_workers.sort_unstable();
    seen_workers.dedup();
    assert_eq!(seen_workers.len(), 2, "both workers contributed traces: {seen_workers:?}");

    // Front-door validation without touching the cluster.
    let errs = roundtrip(router_addr, &["not json".to_string()]);
    assert_eq!(errs.len(), 1);
    let v = Value::parse(&errs[0]).unwrap();
    assert_eq!(v.get("status").unwrap().as_str().unwrap(), "error");

    let ack = roundtrip(router_addr, &["{\"op\":\"shutdown\"}".to_string()]);
    assert!(ack.iter().any(|l| l.contains("shutdown")), "ack: {ack:?}");
    router_thread.join().unwrap();
    kill_all(vec![child_a, child_b]);
}

/// The acceptance kill-test: a worker dies mid-burst and every admitted
/// job still answers — bit-exact — because the router replays the dead
/// worker's in-flight jobs onto the survivor (seeded jobs are bit-exact
/// wherever they run, so replay is safe by construction).
#[test]
fn killing_a_worker_mid_burst_loses_no_admitted_jobs() {
    let (addr_a, mut child_a) =
        spawn_worker(&["--lanes", "4", "--threads", "1", "--flush-ms", "50"]);
    let (addr_b, child_b) = spawn_worker(&["--lanes", "4", "--threads", "1", "--flush-ms", "50"]);
    let (router_addr, router_thread) =
        start_router(vec![addr_a.clone(), addr_b.clone()], 2, 100);
    let reference = Executor::new(4, ExpMode::Fast).unwrap();

    // Heavy enough that the burst is still in flight when the worker
    // dies (~8M spin updates per job).
    let jobs: Vec<JobSpec> =
        (0..16).map(|i| spec(&format!("k{i}"), (8, 8, 32), 400 + i as u32, 4000)).collect();

    let stream = TcpStream::connect(router_addr).unwrap();
    {
        let mut w = std::io::BufWriter::new(stream.try_clone().unwrap());
        for job in &jobs {
            writeln!(w, "{}", job.to_line()).unwrap();
        }
        w.flush().unwrap();
    }
    stream.shutdown(Shutdown::Write).unwrap();

    // Let the router forward the burst, then kill one worker abruptly
    // (SIGKILL: no graceful drain, its in-flight jobs just vanish).
    thread::sleep(Duration::from_millis(30));
    child_a.kill().unwrap();
    let _ = child_a.wait();

    // Read to EOF: the router answers every admitted job or this hangs.
    let served: Vec<String> = BufReader::new(stream)
        .lines()
        .map(|l| l.unwrap())
        .filter(|l| !l.trim().is_empty())
        .collect();
    assert_eq!(served.len(), jobs.len(), "zero lost jobs: {served:?}");
    assert_bit_exact(&served, &reference, &jobs);

    // The cluster degraded but stayed consistent: one worker lost, all
    // replies relayed, survivor marked alive.
    let stats = roundtrip(router_addr, &["{\"op\":\"stats\"}".to_string()]);
    let v = Value::parse(&stats[0]).unwrap();
    let router_v = v.get("router").unwrap();
    assert_eq!(router_v.get("workers_alive").unwrap().as_usize().unwrap(), 1, "{}", stats[0]);
    assert_eq!(router_v.get("workers_lost").unwrap().as_usize().unwrap(), 1);
    assert_eq!(
        router_v.get("replies_relayed").unwrap().as_usize().unwrap(),
        jobs.len(),
        "{}",
        stats[0]
    );
    let worker_rows = v.get("workers").unwrap().as_arr().unwrap();
    let alive_flags: Vec<bool> =
        worker_rows.iter().map(|w| w.get("alive").unwrap().as_bool().unwrap()).collect();
    assert_eq!(alive_flags.iter().filter(|&&a| a).count(), 1, "{alive_flags:?}");

    // The degraded cluster still serves.
    let more = roundtrip(router_addr, &[spec("after", (4, 4, 8), 900, 30).to_line()]);
    assert_eq!(more.len(), 1, "{more:?}");
    assert_bit_exact(&more, &reference, &[spec("after", (4, 4, 8), 900, 30)]);

    let ack = roundtrip(router_addr, &["{\"op\":\"shutdown\"}".to_string()]);
    assert!(ack.iter().any(|l| l.contains("shutdown")), "ack: {ack:?}");
    router_thread.join().unwrap();
    kill_all(vec![child_b]);
}

/// Backpressure propagation: with every replica at its admission cap, a
/// job is rejected to the client only after *all* replicas refused it,
/// carrying the merged (minimum) `retry_after_ms` — and every admitted
/// job still completes bit-exact.
#[test]
fn overload_rejects_only_after_every_replica_refused() {
    let worker_flags =
        ["--lanes", "4", "--threads", "1", "--flush-ms", "400", "--max-queue", "1"];
    let (addr_a, child_a) = spawn_worker(&worker_flags);
    let (addr_b, child_b) = spawn_worker(&worker_flags);
    let (router_addr, router_thread) = start_router(vec![addr_a, addr_b], 2, 300);
    let reference = Executor::new(4, ExpMode::Fast).unwrap();

    // Ten same-shape jobs in one burst: each worker admits one (cap 1)
    // and holds it to the 400 ms flush; the rest must be refused by
    // BOTH replicas before the client sees a rejection.
    let jobs: Vec<JobSpec> =
        (0..10).map(|i| spec(&format!("o{i}"), (4, 4, 8), 500 + i as u32, 30)).collect();
    let served = roundtrip(router_addr, &jobs.iter().map(|s| s.to_line()).collect::<Vec<_>>());
    assert_eq!(served.len(), jobs.len(), "every job answered, admitted or not: {served:?}");
    let mut ok_lines = Vec::new();
    let mut rejected = 0usize;
    for line in &served {
        let v = Value::parse(line).unwrap();
        if v.get("status").unwrap().as_str().unwrap() == "ok" {
            ok_lines.push(line.clone());
            continue;
        }
        rejected += 1;
        assert_eq!(v.get("error").unwrap().as_str().unwrap(), "overloaded", "{line}");
        assert_eq!(v.get("protocol_version").unwrap().as_usize().unwrap(), 1);
        assert!(!v.get("id").unwrap().as_str().unwrap().is_empty(), "{line}");
        let retry = v.get("retry_after_ms").unwrap().as_usize().unwrap();
        assert!(retry >= 1, "a usable backoff hint: {line}");
    }
    assert!(ok_lines.len() >= 2, "each worker admitted at least one job: {served:?}");
    assert!(rejected >= 1, "the burst must overflow a cap of 1+1: {served:?}");
    let admitted: Vec<JobSpec> = jobs
        .iter()
        .filter(|s| ok_lines.iter().any(|l| l.contains(&format!("\"id\":\"{}\"", s.id))))
        .cloned()
        .collect();
    assert_eq!(admitted.len(), ok_lines.len());
    assert_bit_exact(&ok_lines, &reference, &admitted);

    // Router accounting: every client-visible rejection implies at
    // least one failover (the job tried the other replica first).
    let stats = roundtrip(router_addr, &["{\"op\":\"stats\"}".to_string()]);
    let v = Value::parse(&stats[0]).unwrap();
    let router_v = v.get("router").unwrap();
    assert_eq!(router_v.get("rejections").unwrap().as_usize().unwrap(), rejected);
    assert!(
        router_v.get("failovers").unwrap().as_usize().unwrap() >= rejected,
        "{}",
        stats[0]
    );

    let ack = roundtrip(router_addr, &["{\"op\":\"shutdown\"}".to_string()]);
    assert!(ack.iter().any(|l| l.contains("shutdown")), "ack: {ack:?}");
    router_thread.join().unwrap();
    kill_all(vec![child_a, child_b]);
}
