//! Differential tests for the C-rungs (tier-1): every SIMD lane of a
//! replica batch must be *bit-exact* to the same replica swept by the
//! scalar A.2 rung — flips, energy trajectory and spin state — for
//! W ∈ {4, 8}, on every backend this host can run, including replicas
//! with different coupling realizations and different per-lane β.
//!
//! This is the correctness contract that makes lane-per-replica batching
//! a pure performance transformation: under `ExpMode::Exact` the batch
//! *is* W scalar A.2 sweeps running in lockstep.

use vectorising::ising::builder::torus_workload;
use vectorising::ising::QmcModel;
use vectorising::simd::{avx2_available, portable, SimdU32};
use vectorising::sweep::c1_replica_batch::{BatchSweeper, C1ReplicaBatch};
use vectorising::sweep::{try_make_sweeper_with_exp, ExpMode, SweepKind, Sweeper};
use vectorising::tempering::{BatchedPtEnsemble, Ladder, PtEnsemble};

/// Per-lane inputs: W identically-shaped models with *different* coupling
/// realizations (distinct workload seeds), distinct initial states,
/// distinct RNG seeds and a ladder of distinct βs.
fn lane_inputs(w: usize, layers: usize) -> (Vec<QmcModel>, Vec<Vec<f32>>, Vec<u32>, Vec<f32>) {
    let wls: Vec<_> = (0..w).map(|k| torus_workload(4, 4, layers, 10 + k as u64, 0.3)).collect();
    let models = wls.iter().map(|wl| wl.model.clone()).collect();
    let states: Vec<Vec<f32>> = wls.iter().map(|wl| wl.s0.clone()).collect();
    let seeds: Vec<u32> = (0..w as u32).map(|k| 4000 + 17 * k).collect();
    let ladder = Ladder::geometric(2.5, 0.4, w);
    let betas = ladder.betas().to_vec();
    (models, states, seeds, betas)
}

/// The differential itself, generic over the backend: run the batch and
/// the W scalar A.2 references side by side, sweep by sweep, under
/// `ExpMode::Exact`, and require bit-identical lanes throughout.
fn assert_lanes_match_a2<U: SimdU32>(layers: usize) {
    let w = U::LANES;
    let (models, states, seeds, betas) = lane_inputs(w, layers);
    let mut batch = C1ReplicaBatch::<U>::new(&models, &states, &seeds, ExpMode::Exact).unwrap();
    let mut scalars: Vec<Box<dyn Sweeper + Send>> = (0..w)
        .map(|k| {
            try_make_sweeper_with_exp(SweepKind::A2Basic, &models[k], &states[k], seeds[k], ExpMode::Exact)
                .unwrap()
        })
        .collect();
    for round in 0..8 {
        let per_lane = batch.run(1, &betas);
        for k in 0..w {
            let s = scalars[k].run(1, betas[k]);
            assert_eq!(per_lane[k].flips, s.flips, "W={w} round {round} lane {k}: flips");
            assert_eq!(per_lane[k].attempts, s.attempts, "W={w} round {round} lane {k}: attempts");
            let batch_state = batch.state_of(k);
            let scalar_state = scalars[k].state();
            assert_eq!(batch_state, scalar_state, "W={w} round {round} lane {k}: state");
            // Energies are f64 reductions of identical f32 states on the
            // same model — identical bits.
            assert_eq!(
                batch.energy_of(k).to_bits(),
                scalars[k].energy().to_bits(),
                "W={w} round {round} lane {k}: energy"
            );
        }
    }
    assert!(batch.validate() < 1e-4);
}

#[test]
fn w4_portable_lanes_are_bit_exact_to_a2() {
    assert_lanes_match_a2::<portable::U32xN<4>>(8);
}

#[test]
fn w8_portable_lanes_are_bit_exact_to_a2() {
    assert_lanes_match_a2::<portable::U32xN<8>>(8);
}

#[cfg(target_arch = "x86_64")]
#[test]
fn w4_sse_lanes_are_bit_exact_to_a2() {
    assert_lanes_match_a2::<vectorising::simd::U32x4>(8);
}

#[cfg(target_arch = "x86_64")]
#[test]
fn w8_avx2_lanes_are_bit_exact_to_a2() {
    if !avx2_available() {
        eprintln!("skipping avx2 replica-batch differential: host has no AVX2");
        return;
    }
    assert_lanes_match_a2::<vectorising::simd::avx2::U32x8>(8);
}

#[test]
fn shallow_two_layer_lanes_are_bit_exact_to_a2() {
    // layers = 2 — the geometry the A.3/A.4 interlacing must reject; the
    // replica axis vectorizes it anyway, and each lane still matches A.2
    // (whose scalar sweep has no layer constraint).
    assert_lanes_match_a2::<portable::U32xN<4>>(2);
    assert_lanes_match_a2::<portable::U32xN<8>>(2);
}

#[test]
fn batched_ensemble_matches_scalar_ensemble_through_exchanges() {
    // Full-system differential: the same 6-rung ladder run (a) as a
    // per-replica A.2 ensemble and (b) as a C.1 lane-batched ensemble
    // (two batches, padded tail), with identical seed conventions and
    // ExpMode::Exact.  Sweeps are lane-exact and exchange decisions
    // consume the same swap-RNG stream on identical f64 energies, so the
    // two engines must agree bit-for-bit at every round.
    let n = 6;
    let wl = torus_workload(4, 4, 8, 7, 0.3);
    let ladder = Ladder::geometric(2.0, 0.2, n);
    let seeds: Vec<u32> = (0..n as u32).map(|i| 100 + i).collect();

    let scalars: Vec<Box<dyn Sweeper + Send>> = (0..n)
        .map(|i| {
            try_make_sweeper_with_exp(SweepKind::A2Basic, &wl.model, &wl.s0, seeds[i], ExpMode::Exact)
                .unwrap()
        })
        .collect();
    let mut scalar_pt = PtEnsemble::new(ladder.clone(), scalars, 999);

    let models = vec![wl.model.clone(); n];
    let states = vec![wl.s0.clone(); n];
    let mut batched_pt = BatchedPtEnsemble::new(
        ladder,
        SweepKind::C1ReplicaBatch,
        &models,
        &states,
        &seeds,
        999,
        ExpMode::Exact,
    )
    .unwrap();

    for round in 0..6 {
        scalar_pt.round(5);
        batched_pt.round(5);
        let a = scalar_pt.reports();
        let b = batched_pt.reports();
        for i in 0..n {
            assert_eq!(a[i].stats.flips, b[i].stats.flips, "round {round} replica {i}: flips");
            assert_eq!(
                a[i].energy.to_bits(),
                b[i].energy.to_bits(),
                "round {round} replica {i}: energy"
            );
            assert_eq!(
                scalar_pt.state_of(i),
                batched_pt.state_of(i),
                "round {round} replica {i}: state"
            );
        }
    }
    assert_eq!(scalar_pt.swap_acceptance(), batched_pt.swap_acceptance());
}
