//! End-to-end acceptance test of the sampling service (ISSUE 3): submit
//! ≥ 2×W jobs of two different shapes to a *running* service over TCP
//! and require
//!
//! * one result line per job, each **bit-exact** (energy bits, flip
//!   counts, final state) to a standalone scalar A.2 run with the same
//!   seed, and
//! * a reported lane-fill ratio > 0.9 for the uniform-shape stream.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::thread;

use vectorising::coordinator::{self, Checkpoint, RunConfig, RunOptions, RunReport, RunSpec};
use vectorising::engine::{Rung, SamplerSpec};
use vectorising::obs::HistogramSnapshot;
use vectorising::service::executor::Executor;
use vectorising::service::job::{JobResult, JobSpec, RunJob};
use vectorising::service::{server, ServiceConfig};
use vectorising::simd::widest_supported_width;
use vectorising::sweep::ExpMode;
use vectorising::util::json::Value;

fn spec(id: &str, shape: (usize, usize, usize), seed: u32) -> JobSpec {
    JobSpec {
        id: id.to_string(),
        width: shape.0,
        height: shape.1,
        layers: shape.2,
        model_seed: 1 + seed as u64,
        jtau: 0.3,
        sweeps: 30 + (seed as usize % 3) * 10, // mixed sweep counts batch too
        beta: 0.6 + 0.05 * (seed % 4) as f32,
        seed,
        trace_every: 0,
        want_state: true,
        want_timing: false,
        sampler: None,
    }
}

/// Open a connection, send every line, half-close, read lines until the
/// server closes.
fn roundtrip(addr: SocketAddr, lines: &[String]) -> Vec<String> {
    let stream = TcpStream::connect(addr).unwrap();
    {
        let mut w = std::io::BufWriter::new(stream.try_clone().unwrap());
        for line in lines {
            writeln!(w, "{line}").unwrap();
        }
        w.flush().unwrap();
    }
    stream.shutdown(Shutdown::Write).unwrap();
    BufReader::new(stream)
        .lines()
        .map(|l| l.unwrap())
        .filter(|l| !l.trim().is_empty())
        .collect()
}

fn assert_bit_exact(served: &[String], reference: &Executor, expect: &[JobSpec]) {
    let mut by_id: BTreeMap<String, JobResult> = BTreeMap::new();
    for line in served {
        let r = JobResult::from_line(line).unwrap_or_else(|e| panic!("{e:#}: {line}"));
        by_id.insert(r.id.clone(), r);
    }
    assert_eq!(by_id.len(), expect.len(), "one result per job");
    for spec in expect {
        let got = &by_id[&spec.id];
        let want = reference.run_single(spec).unwrap();
        assert_eq!(
            got.energy.to_bits(),
            want.energy.to_bits(),
            "job {}: served energy must be bit-exact to the scalar A.2 run",
            spec.id
        );
        assert_eq!(got.stats.flips, want.stats.flips, "job {}: flips", spec.id);
        assert_eq!(got.stats.attempts, want.stats.attempts, "job {}: attempts", spec.id);
        assert_eq!(got.state, want.state, "job {}: final state", spec.id);
    }
}

#[test]
fn served_jobs_are_bit_exact_and_uniform_streams_fill_lanes() {
    let w = widest_supported_width();
    // A long flush deadline, so a slow CI machine cannot split a full
    // bucket into padded flushes: full batches dispatch immediately, and
    // only the phase-2 lone job pays the deadline.
    let cfg = ServiceConfig {
        lanes: w,
        threads: 2,
        flush_ms: 300,
        exp: ExpMode::Fast,
        ..ServiceConfig::default()
    };
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server_thread = thread::spawn(move || server::serve_tcp(listener, &cfg).unwrap());
    let reference = Executor::new(w, ExpMode::Fast).unwrap();

    // Phase 1 — uniform-shape stream: 2W jobs of one shape -> two full
    // lane-batches, lane fill 1.0.
    let uniform: Vec<JobSpec> =
        (0..2 * w).map(|i| spec(&format!("u{i}"), (4, 4, 8), 100 + i as u32)).collect();
    let served = roundtrip(addr, &uniform.iter().map(|s| s.to_line()).collect::<Vec<_>>());
    assert_bit_exact(&served, &reference, &uniform);
    for line in &served {
        let r = JobResult::from_line(line).unwrap();
        assert!(r.kind.starts_with("C.1"), "uniform job served by a C-rung, got {}", r.kind);
        assert_eq!(r.lanes, w);
        assert_eq!(r.occupancy, w, "uniform stream must fill whole batches");
        // Protocol v1: every response is versioned and echoes the plan.
        let v = Value::parse(line).unwrap();
        assert_eq!(v.get("protocol_version").unwrap().as_usize().unwrap(), 1);
        let plan = r.plan.as_ref().expect("v1 results echo the resolved plan");
        assert_eq!(plan.rung, "c1");
        assert_eq!(plan.width, w);
        assert!(["sse2", "avx2", "portable"].contains(&plan.backend.as_str()), "{plan:?}");
    }
    let stats = roundtrip(addr, &["{\"op\":\"stats\"}".to_string()]);
    assert_eq!(stats.len(), 1);
    let v = Value::parse(&stats[0]).unwrap();
    assert_eq!(v.get("protocol_version").unwrap().as_usize().unwrap(), 1);
    let fill = v.get("lane_fill_ratio").unwrap().as_f64().unwrap();
    assert!(fill > 0.9, "uniform-shape stream must report lane fill > 0.9, got {fill}");
    assert_eq!(v.get("jobs_completed").unwrap().as_usize().unwrap(), 2 * w);

    // Phase 2 — mixed shapes: a second full-width shape (shallow
    // layers=2, which the A-rungs reject) plus a lone odd shape that
    // must fall back to the scalar path.
    let mut mixed: Vec<JobSpec> =
        (0..w).map(|i| spec(&format!("m{i}"), (4, 4, 2), 200 + i as u32)).collect();
    mixed.push(spec("lone", (6, 4, 8), 300));
    let served = roundtrip(addr, &mixed.iter().map(|s| s.to_line()).collect::<Vec<_>>());
    assert_bit_exact(&served, &reference, &mixed);
    for line in &served {
        let r = JobResult::from_line(line).unwrap();
        if r.id == "lone" {
            assert_eq!(r.kind, "A.2", "a peerless job falls back to the scalar rung");
            assert_eq!(r.occupancy, 1);
        } else {
            assert!(r.kind.starts_with("C.1"), "shallow jobs batch on the C-rungs");
        }
    }

    // Phase 3 — v1 envelopes: jobs carrying sampler specs.  A c1/auto
    // sampler batches as usual; an a2 sampler pins the scalar path even
    // with lane-mates available; an incompatible width is refused with a
    // structured error line.
    let v1_lines: Vec<String> = (0..w)
        .map(|i| {
            format!(
                r#"{{"protocol_version":1,"op":"submit","job":{{"id":"v{i}","width":4,"height":4,"layers":8,"model_seed":{},"sweeps":30,"beta":0.7,"seed":{},"want_state":true,"sampler":{{"rung":"c1","width":"auto","backend":"auto"}}}}}}"#,
                1 + 400 + i,
                400 + i
            )
        })
        .chain(std::iter::once(
            r#"{"protocol_version":1,"id":"vscalar","width":4,"height":4,"layers":8,"model_seed":450,"sweeps":30,"beta":0.7,"seed":449,"want_state":true,"sampler":{"rung":"a2"}}"#
                .to_string(),
        ))
        .chain(std::iter::once(format!(
            r#"{{"protocol_version":1,"id":"vbad","layers":8,"sampler":{{"rung":"c1","width":{}}}}}"#,
            w + 1
        )))
        .collect();
    let served = roundtrip(addr, &v1_lines);
    assert_eq!(served.len(), w + 2, "one line per v1 request: {served:?}");
    let mut saw_scalar = false;
    let mut saw_bad = false;
    for line in &served {
        let v = Value::parse(line).unwrap();
        assert_eq!(v.get("protocol_version").unwrap().as_usize().unwrap(), 1);
        match v.get("id").unwrap().as_str().unwrap() {
            "vscalar" => {
                saw_scalar = true;
                let r = JobResult::from_line(line).unwrap();
                assert_eq!(r.kind, "A.2", "a2 sampler pins the scalar path");
                assert_eq!(r.plan.as_ref().unwrap().backend, "scalar");
            }
            "vbad" => {
                saw_bad = true;
                assert_eq!(v.get("status").unwrap().as_str().unwrap(), "error");
                let msg = v.get("error").unwrap().as_str().unwrap().to_string();
                assert!(msg.contains("width"), "useful rejection: {msg}");
            }
            _ => {
                let r = JobResult::from_line(line).unwrap();
                assert!(r.kind.starts_with("C.1"), "c1/auto sampler batches: {}", r.kind);
                assert_eq!(r.plan.as_ref().unwrap().rung, "c1");
            }
        }
    }
    assert!(saw_scalar && saw_bad, "{served:?}");

    // Malformed and invalid lines get error results, not silence.
    let errs = roundtrip(
        addr,
        &["not json".to_string(), r#"{"id":"bad","layers":1}"#.to_string()],
    );
    assert_eq!(errs.len(), 2);
    for line in &errs {
        let v = Value::parse(line).unwrap();
        assert_eq!(v.get("status").unwrap().as_str().unwrap(), "error");
    }

    // Shutdown stops the server; serve_tcp returns cleanly.
    let ack = roundtrip(addr, &["{\"op\":\"shutdown\"}".to_string()]);
    assert!(ack.iter().any(|l| l.contains("shutdown")), "ack: {ack:?}");
    server_thread.join().unwrap();
}

/// The observability surface over the wire (ISSUE 8): a
/// `"want_timing":true` job echoes consecutive per-stage durations
/// whose sum is bounded by its end-to-end latency; `{"op":"stats"}`
/// grows latency percentiles, rates and a config echo while keeping
/// every pre-existing field; `{"op":"trace"}` returns the recent job
/// traces from the bounded ring; and `{"op":"metrics"}` returns a
/// Prometheus text exposition whose e2e histogram count equals the
/// completed-jobs counter.
#[test]
fn observability_ops_expose_timings_traces_and_prometheus_text() {
    let cfg = ServiceConfig { lanes: 4, threads: 1, flush_ms: 50, ..ServiceConfig::default() };
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server_thread = thread::spawn(move || server::serve_tcp(listener, &cfg).unwrap());

    // One full lane-batch of timed jobs plus one untimed straggler that
    // flushes on the deadline.
    let mut jobs: Vec<JobSpec> = (0..4)
        .map(|i| {
            let mut s = spec(&format!("t{i}"), (4, 4, 8), 600 + i as u32);
            s.want_timing = true;
            s
        })
        .collect();
    jobs.push(spec("plain", (4, 4, 8), 700));
    let served = roundtrip(addr, &jobs.iter().map(|s| s.to_line()).collect::<Vec<_>>());
    assert_eq!(served.len(), 5, "{served:?}");
    for line in &served {
        let r = JobResult::from_line(line).unwrap();
        if r.id == "plain" {
            assert!(r.timing.is_none(), "timing echo is opt-in: {line}");
        } else {
            let t = r.timing.unwrap_or_else(|| panic!("want_timing job echoes timing: {line}"));
            assert!(
                t.stage_sum_us() <= t.e2e_us,
                "job {}: stage sum {} exceeds e2e {}",
                r.id,
                t.stage_sum_us(),
                t.e2e_us
            );
            assert!(t.e2e_us > 0, "a swept job takes measurable time: {line}");
            assert!(t.sweep_us > 0, "the sweep stage is stamped: {line}");
        }
    }

    // Stats: every pre-existing field still present, plus the
    // observability extensions.
    let stats = roundtrip(addr, &["{\"op\":\"stats\"}".to_string()]);
    let v = Value::parse(&stats[0]).unwrap();
    assert_eq!(v.get("protocol_version").unwrap().as_usize().unwrap(), 1);
    assert_eq!(v.get("jobs_completed").unwrap().as_usize().unwrap(), 5);
    assert_eq!(v.get("jobs_in_system").unwrap().as_usize().unwrap(), 0);
    assert!(v.get("lane_fill_ratio").unwrap().as_f64().unwrap() > 0.0);
    let e2e = v.get("latency_us").unwrap().get("e2e").unwrap();
    assert_eq!(
        e2e.get("count").unwrap().as_usize().unwrap(),
        5,
        "the e2e histogram counts every completed job: {}",
        stats[0]
    );
    let p50 = e2e.get("p50_us").unwrap().as_f64().unwrap();
    let p99 = e2e.get("p99_us").unwrap().as_f64().unwrap();
    assert!(p50 > 0.0 && p50 <= p99, "ordered positive percentiles: p50={p50} p99={p99}");
    let cfg_echo = v.get("config").unwrap();
    assert_eq!(cfg_echo.get("lanes").unwrap().as_usize().unwrap(), 4);
    assert_eq!(cfg_echo.get("flush_ms").unwrap().as_usize().unwrap(), 50);
    assert!(v.get("uptime_ms").unwrap().as_f64().unwrap() >= 0.0);
    assert!(v.get("started_at_ms").unwrap().as_f64().unwrap() > 0.0);
    assert!(v.get("rate").unwrap().get("jobs_per_sec").unwrap().as_f64().unwrap() >= 0.0);

    // Trace: the three most recent of the five recorded traces.
    let tr = roundtrip(addr, &["{\"op\":\"trace\",\"last\":3}".to_string()]);
    let v = Value::parse(&tr[0]).unwrap();
    assert_eq!(v.get("op").unwrap().as_str().unwrap(), "trace");
    assert_eq!(v.get("traces_recorded").unwrap().as_usize().unwrap(), 5);
    assert_eq!(v.get("count").unwrap().as_usize().unwrap(), 3);
    let traces = match v.get("traces").unwrap() {
        Value::Arr(a) => a,
        other => panic!("traces must be an array: {other:?}"),
    };
    for t in traces {
        assert!(t.get("ok").unwrap().as_bool().unwrap(), "{tr:?}");
        assert_eq!(t.get("shape").unwrap().as_str().unwrap(), "4x4x8");
        let timing = t.get("timing").unwrap();
        assert!(timing.get("e2e_us").unwrap().as_usize().unwrap() > 0);
    }

    // Metrics: Prometheus text riding in a JSON envelope, counters
    // agreeing with the stats counters.
    let m = roundtrip(addr, &["{\"op\":\"metrics\"}".to_string()]);
    let v = Value::parse(&m[0]).unwrap();
    assert_eq!(v.get("op").unwrap().as_str().unwrap(), "metrics");
    assert!(
        v.get("content_type").unwrap().as_str().unwrap().starts_with("text/plain"),
        "{}",
        m[0]
    );
    let text = v.get("text").unwrap().as_str().unwrap().to_string();
    assert!(text.contains("# TYPE repro_jobs_completed_total counter"), "{text}");
    assert!(text.contains("# TYPE repro_e2e_seconds histogram"), "{text}");
    assert!(text.contains("le=\"+Inf\""), "{text}");
    assert!(text.contains("repro_build_info"), "{text}");
    assert!(text.contains("repro_lane_occupancy_total"), "{text}");
    let completed = text
        .lines()
        .find(|l| l.starts_with("repro_jobs_completed_total"))
        .unwrap_or_else(|| panic!("missing completed counter:\n{text}"));
    assert!(completed.ends_with(" 5"), "{completed}");
    let e2e_count = text
        .lines()
        .find(|l| l.starts_with("repro_e2e_seconds_count"))
        .unwrap_or_else(|| panic!("missing e2e histogram count:\n{text}"));
    assert!(e2e_count.ends_with(" 5"), "histogram count == jobs completed: {e2e_count}");

    let ack = roundtrip(addr, &["{\"op\":\"shutdown\"}".to_string()]);
    assert!(ack.iter().any(|l| l.contains("shutdown")), "ack: {ack:?}");
    server_thread.join().unwrap();
}

/// The cluster-enabling wire surface (ISSUE 10 satellites): the
/// `{"op":"hello"}` handshake advertises protocol version, host
/// capability fingerprint, servable rungs and the resolved serving
/// config; `{"op":"stats"}` carries the per-shape `buckets` array and
/// the mergeable sparse `latency_hist` whose counts agree with the
/// `latency_us` summaries; and the `overloaded` rejection line is
/// pinned to carry `protocol_version` and the job `id` — what a shard
/// router needs for capability discovery, placement and failover.
#[test]
fn hello_buckets_and_rejection_lines_serve_router_needs() {
    let cfg = ServiceConfig { lanes: 4, threads: 1, flush_ms: 50, ..ServiceConfig::default() };
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server_thread = thread::spawn(move || server::serve_tcp(listener, &cfg).unwrap());

    // Handshake before any job: capabilities are static facts.
    let hello = roundtrip(addr, &["{\"op\":\"hello\"}".to_string()]);
    assert_eq!(hello.len(), 1, "{hello:?}");
    let v = Value::parse(&hello[0]).unwrap();
    assert_eq!(v.get("op").unwrap().as_str().unwrap(), "hello");
    assert_eq!(v.get("protocol_version").unwrap().as_usize().unwrap(), 1);
    assert!(!v.get("host").unwrap().as_str().unwrap().is_empty(), "host fingerprint");
    let rungs: Vec<&str> = v
        .get("rungs")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|r| r.as_str().unwrap())
        .collect();
    assert_eq!(rungs, ["a2", "c1", "m1", "b1", "b2"], "{}", hello[0]);
    assert_eq!(v.get("lanes").unwrap().as_usize().unwrap(), 4);
    assert_eq!(v.get("max_queue").unwrap().as_usize().unwrap(), 1024);
    assert!(!v.get("backend").unwrap().as_str().unwrap().is_empty(), "{}", hello[0]);

    // A full lane-batch, so the latency histograms have content.
    let jobs: Vec<JobSpec> =
        (0..4).map(|i| spec(&format!("h{i}"), (4, 4, 8), 800 + i as u32)).collect();
    let served = roundtrip(addr, &jobs.iter().map(|s| s.to_line()).collect::<Vec<_>>());
    assert_eq!(served.len(), 4, "{served:?}");

    let stats = roundtrip(addr, &["{\"op\":\"stats\"}".to_string()]);
    let v = Value::parse(&stats[0]).unwrap();
    // The buckets array is always present; after the queue drained it
    // may be empty, but any entry carries the full per-bucket signal.
    let buckets = v.get("buckets").unwrap().as_arr().unwrap();
    for b in buckets {
        assert!(!b.get("shape").unwrap().as_str().unwrap().is_empty());
        b.get("depth").unwrap().as_usize().unwrap();
        b.get("oldest_age_us").unwrap().as_usize().unwrap();
        assert!(b.get("lanes").unwrap().as_usize().unwrap() >= 1);
    }
    // The sparse mergeable histograms ride next to the summaries and
    // agree with them — the contract cluster aggregation merges on.
    let hist = v.get("latency_hist").unwrap();
    let summaries = v.get("latency_us").unwrap();
    for key in ["queue_wait", "exec", "e2e", "pool_task"] {
        let snap = HistogramSnapshot::from_value(hist.get(key).unwrap())
            .unwrap_or_else(|e| panic!("{key}: {e:#}"));
        let summary_count =
            summaries.get(key).unwrap().get("count").unwrap().as_usize().unwrap();
        assert_eq!(snap.count() as usize, summary_count, "{key}: wire hist vs summary");
    }
    let e2e = HistogramSnapshot::from_value(hist.get("e2e").unwrap()).unwrap();
    assert_eq!(e2e.count(), 4, "every completed job counted: {}", stats[0]);

    // Pinned rejection-line shape: failover correlation needs the id,
    // version-gating needs protocol_version — on every rejection.
    let line = JobResult::overloaded_line("jid-9", 123);
    let r = Value::parse(&line).unwrap();
    assert_eq!(r.get("id").unwrap().as_str().unwrap(), "jid-9");
    assert_eq!(r.get("protocol_version").unwrap().as_usize().unwrap(), 1);
    assert_eq!(r.get("status").unwrap().as_str().unwrap(), "error");
    assert_eq!(r.get("error").unwrap().as_str().unwrap(), "overloaded");
    assert_eq!(r.get("retry_after_ms").unwrap().as_usize().unwrap(), 123);

    let ack = roundtrip(addr, &["{\"op\":\"shutdown\"}".to_string()]);
    assert!(ack.iter().any(|l| l.contains("shutdown")), "ack: {ack:?}");
    server_thread.join().unwrap();
}

/// The serving hot path never blocks on a run: a long `{"op":"run"}`
/// job executes on the sweep pool, so an interleaved `{"op":"stats"}`
/// on the *same connection* is answered while the run sweeps — and the
/// pool-executed run stays bit-exact to the coordinator oracle.
#[test]
fn long_run_does_not_block_its_connection() {
    let cfg = ServiceConfig { lanes: 4, threads: 1, flush_ms: 50, ..ServiceConfig::default() };
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server_thread = thread::spawn(move || server::serve_tcp(listener, &cfg).unwrap());

    // ~98M spin-updates (16×16×32 × 4 replicas × 3000 sweeps): well
    // under the admission work cap, but hundreds of milliseconds of
    // sweeping — plenty of time for the interleaved stats round-trip.
    let run_cfg = RunConfig {
        width: 16,
        height: 16,
        layers: 32,
        n_models: 4,
        sweeps: 3000,
        sweeps_per_round: 1000,
        ..RunConfig::default()
    };
    let rs = RunSpec::new(run_cfg, SamplerSpec::rung(Rung::C1));
    let job = RunJob { id: "long".into(), spec: rs.clone(), checkpoint: None, want_checkpoint: false };
    let served = roundtrip(addr, &[job.to_line(), "{\"op\":\"stats\"}".to_string()]);
    assert_eq!(served.len(), 2, "{served:?}");

    // The stats reply must arrive FIRST: the reader loop submitted the
    // run and moved on instead of executing it in place.
    let first = Value::parse(&served[0]).unwrap();
    assert_eq!(
        first.get("op").unwrap().as_str().unwrap(),
        "stats",
        "stats must be answered while the run sweeps: {served:?}"
    );
    assert!(
        first.get("jobs_in_system").unwrap().as_usize().unwrap() >= 1,
        "the in-flight run is visible to stats: {}",
        served[0]
    );
    let run_line = Value::parse(&served[1]).unwrap();
    assert_eq!(run_line.get("status").unwrap().as_str().unwrap(), "ok", "{served:?}");
    let report = RunReport::from_value(run_line.get("run_report").unwrap()).unwrap();

    // Pool-executed rounds keep the run bit-exact to the coordinator.
    let local = coordinator::run_spec_with(&rs, &RunOptions::default()).unwrap();
    for (i, (a, b)) in local.energies.iter().zip(&report.energies).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "replica {i}: pool-executed run diverged");
    }

    let stats = roundtrip(addr, &["{\"op\":\"stats\"}".to_string()]);
    let v = Value::parse(&stats[0]).unwrap();
    assert_eq!(v.get("runs_executed").unwrap().as_usize().unwrap(), 1);
    assert_eq!(v.get("jobs_submitted").unwrap().as_usize().unwrap(), 1);
    assert_eq!(v.get("jobs_completed").unwrap().as_usize().unwrap(), 1);
    assert_eq!(v.get("jobs_in_system").unwrap().as_usize().unwrap(), 0);
    assert_eq!(v.get("dispatches_in_flight").unwrap().as_usize().unwrap(), 0);

    let ack = roundtrip(addr, &["{\"op\":\"shutdown\"}".to_string()]);
    assert!(ack.iter().any(|l| l.contains("shutdown")), "ack: {ack:?}");
    server_thread.join().unwrap();
}

/// Bounded admission over the wire: a burst past `max_queue` gets
/// structured `{"error":"overloaded","retry_after_ms":...}` rejections,
/// while every admitted job completes bit-exact to the scalar oracle.
#[test]
fn overload_returns_structured_backpressure_and_completes_admitted_jobs() {
    let cfg = ServiceConfig {
        lanes: 4,
        threads: 1,
        flush_ms: 400,
        max_queue: 2,
        ..ServiceConfig::default()
    };
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server_thread = thread::spawn(move || server::serve_tcp(listener, &cfg).unwrap());
    let reference = Executor::new(4, ExpMode::Fast).unwrap();

    // Four same-shape jobs in one burst: the first two fill the cap and
    // hold it until the 400 ms flush; the rest are refused immediately.
    let burst: Vec<JobSpec> =
        (0..4).map(|i| spec(&format!("q{i}"), (4, 4, 8), 500 + i as u32)).collect();
    let served = roundtrip(addr, &burst.iter().map(|s| s.to_line()).collect::<Vec<_>>());
    assert_eq!(served.len(), 4, "every request answered, admitted or not: {served:?}");
    let mut ok_lines = Vec::new();
    let mut rejected = 0;
    for line in &served {
        let v = Value::parse(line).unwrap();
        if v.get("status").unwrap().as_str().unwrap() == "ok" {
            ok_lines.push(line.clone());
            continue;
        }
        rejected += 1;
        assert_eq!(v.get("error").unwrap().as_str().unwrap(), "overloaded", "{line}");
        let retry = v.get("retry_after_ms").unwrap().as_usize().unwrap();
        assert!(
            (400..=60_000).contains(&retry),
            "retry hint covers at least one flush deadline: {retry}"
        );
    }
    assert_eq!(rejected, 2, "the burst overflows the cap by exactly two: {served:?}");
    // The two admitted jobs were never dropped — and stayed bit-exact.
    let admitted: Vec<JobSpec> = burst
        .iter()
        .filter(|s| ok_lines.iter().any(|l| l.contains(&format!("\"id\":\"{}\"", s.id))))
        .cloned()
        .collect();
    assert_eq!(admitted.len(), 2);
    assert_bit_exact(&ok_lines, &reference, &admitted);

    let stats = roundtrip(addr, &["{\"op\":\"stats\"}".to_string()]);
    let v = Value::parse(&stats[0]).unwrap();
    assert_eq!(v.get("jobs_overloaded").unwrap().as_usize().unwrap(), 2);
    assert_eq!(v.get("jobs_completed").unwrap().as_usize().unwrap(), 2);
    assert_eq!(v.get("jobs_in_system").unwrap().as_usize().unwrap(), 0);

    let ack = roundtrip(addr, &["{\"op\":\"shutdown\"}".to_string()]);
    assert!(ack.iter().any(|l| l.contains("shutdown")), "ack: {ack:?}");
    server_thread.join().unwrap();
}

/// The Run API over the wire: an `{"op":"run"}` job executes a whole
/// spec-driven tempering run server-side, returns its RunReport (plans
/// echo included) plus an inline schema-v2 checkpoint, and a second run
/// job resuming from that checkpoint continues **bit-exactly** what the
/// coordinator produces locally for the same two segments.
#[test]
fn run_op_executes_checkpointable_runs_over_the_wire() {
    let cfg = ServiceConfig { lanes: 4, threads: 1, flush_ms: 50, ..ServiceConfig::default() };
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server_thread = thread::spawn(move || server::serve_tcp(listener, &cfg).unwrap());

    let run_cfg =
        RunConfig { n_models: 5, sweeps: 20, sweeps_per_round: 10, ..RunConfig::default() };
    let rs = RunSpec::new(run_cfg.clone(), SamplerSpec::rung(Rung::C1));

    // Segment 1: 20 sweeps, final checkpoint returned inline.
    let job1 = RunJob { id: "seg1".into(), spec: rs.clone(), checkpoint: None, want_checkpoint: true };
    let served = roundtrip(addr, &[job1.to_line()]);
    assert_eq!(served.len(), 1, "{served:?}");
    let v = Value::parse(&served[0]).unwrap();
    assert_eq!(v.get("status").unwrap().as_str().unwrap(), "ok", "{served:?}");
    assert_eq!(v.get("protocol_version").unwrap().as_usize().unwrap(), 1);
    let report1 = RunReport::from_value(v.get("run_report").unwrap()).unwrap();
    let covered: usize = report1.plans.iter().map(|p| p.replicas).sum();
    assert_eq!(covered, 5, "run results echo the resolved per-group plans");
    let ck = Checkpoint::from_value(v.get("checkpoint").unwrap()).unwrap();
    assert_eq!(ck.sweeps_done, 20);
    assert!(ck.sampler.is_some() && !ck.plans.is_empty(), "schema-v2 checkpoint");

    // Segment 2: resume from the inline checkpoint, extend to 40 sweeps.
    let mut rs2 = rs.clone();
    rs2.config.sweeps = 40;
    let job2 =
        RunJob { id: "seg2".into(), spec: rs2.clone(), checkpoint: Some(ck), want_checkpoint: false };
    let served2 = roundtrip(addr, &[job2.to_line()]);
    assert_eq!(served2.len(), 1, "{served2:?}");
    let v2 = Value::parse(&served2[0]).unwrap();
    assert_eq!(v2.get("status").unwrap().as_str().unwrap(), "ok", "{served2:?}");
    let report2 = RunReport::from_value(v2.get("run_report").unwrap()).unwrap();
    assert_eq!(report2.sweeps, 20, "the resumed segment ran rounds 3..4");

    // Local oracle: the identical two segments through the coordinator.
    let (local1, local_ck) = coordinator::run_spec_capturing(&rs, &RunOptions::default()).unwrap();
    for (a, b) in local1.energies.iter().zip(&report1.energies) {
        assert_eq!(a.to_bits(), b.to_bits(), "segment-1 energies must match the coordinator");
    }
    let local2 = coordinator::run_spec_with(
        &rs2,
        &RunOptions { resume: Some(local_ck), ..RunOptions::default() },
    )
    .unwrap();
    for (i, (a, b)) in local2.energies.iter().zip(&report2.energies).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "replica {i}: served resume diverged");
    }

    // Admission caps apply to run jobs too: an over-heavy run is refused
    // with an error line, not executed.
    let heavy = RunJob {
        id: "heavy".into(),
        spec: RunSpec::new(
            RunConfig {
                width: 32,
                height: 32,
                layers: 64,
                n_models: 40,
                sweeps: 100_000,
                sweeps_per_round: 100,
                ..RunConfig::default()
            },
            SamplerSpec::rung(Rung::C1),
        ),
        checkpoint: None,
        want_checkpoint: false,
    };
    let refused = roundtrip(addr, &[heavy.to_line()]);
    assert_eq!(refused.len(), 1);
    let rv = Value::parse(&refused[0]).unwrap();
    assert_eq!(rv.get("status").unwrap().as_str().unwrap(), "error");
    assert!(rv.get("error").unwrap().as_str().unwrap().contains("too heavy"), "{refused:?}");

    let ack = roundtrip(addr, &["{\"op\":\"shutdown\"}".to_string()]);
    assert!(ack.iter().any(|l| l.contains("shutdown")), "ack: {ack:?}");
    server_thread.join().unwrap();
}
