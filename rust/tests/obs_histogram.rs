//! Concurrency soak for the lock-free latency histogram: writer threads
//! hammer `record()` while a reader loop snapshots, asserting that
//! every snapshot is *internally consistent* — the derived count equals
//! the bucket sum by construction, totals only grow, and the final
//! tally accounts for every recorded value exactly once.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use vectorising::obs::{Histogram, HistogramSnapshot};

const WRITERS: usize = 4;
const PER_WRITER: u64 = 50_000;

#[test]
fn concurrent_records_never_tear_snapshots() {
    let hist = Arc::new(Histogram::new());
    let stop = Arc::new(AtomicBool::new(false));

    let writers: Vec<_> = (0..WRITERS)
        .map(|w| {
            let hist = Arc::clone(&hist);
            std::thread::spawn(move || {
                // Deterministic per-writer value stream spanning many
                // buckets (1µs .. ~1s), with a known total sum.
                let mut sum = 0u64;
                for i in 0..PER_WRITER {
                    let v = 1 + ((i * 37 + w as u64 * 13) % 1_000_000);
                    hist.record(v);
                    sum += v;
                }
                sum
            })
        })
        .collect();

    // Reader loop: snapshot continuously while the writers run.  Every
    // snapshot must satisfy the invariants regardless of interleaving.
    let reader = {
        let hist = Arc::clone(&hist);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut last_count = 0u64;
            let mut snapshots = 0u64;
            while !stop.load(Ordering::Acquire) {
                let snap = hist.snapshot();
                assert_invariants(&snap);
                let count = snap.count();
                assert!(
                    count >= last_count,
                    "totals must be monotonic across snapshots: {count} < {last_count}"
                );
                last_count = count;
                snapshots += 1;
            }
            snapshots
        })
    };

    let mut expected_sum = 0u64;
    for w in writers {
        expected_sum += w.join().expect("writer thread");
    }
    stop.store(true, Ordering::Release);
    let snapshots = reader.join().expect("reader thread");
    assert!(snapshots > 0, "the reader observed at least one snapshot");

    // Quiescent final state: every record accounted for exactly once.
    let last = hist.snapshot();
    assert_invariants(&last);
    assert_eq!(last.count(), (WRITERS as u64) * PER_WRITER);
    assert_eq!(last.sum_us, expected_sum);
    let (p50, p90, p99) = last.percentiles_us();
    assert!(p50 <= p90 && p90 <= p99, "quantiles must be ordered: {p50} {p90} {p99}");
    assert!(p50 > 0.0);
}

/// The invariants every snapshot must satisfy, torn reads included:
/// count is *derived* as the bucket sum (so it can never disagree with
/// the buckets), and the mean lies within the recorded value range.
fn assert_invariants(snap: &HistogramSnapshot) {
    let bucket_sum: u64 = snap.buckets.iter().sum();
    assert_eq!(snap.count(), bucket_sum, "count must equal the bucket sum");
    // NOTE: no `count == 0 => sum_us == 0` check here — the sum is read
    // after the buckets, so a concurrent snapshot can legitimately see a
    // sum from a record whose bucket increment it missed.  Quiescent
    // tests check the exact sum separately.
    let mean = snap.mean_us();
    assert!(mean >= 0.0, "mean cannot be negative: {mean}");
}

/// Merging two concurrent snapshots preserves counts and sums — the
/// property a sharded scrape aggregator relies on.
#[test]
fn merged_snapshots_add_exactly() {
    let a = Histogram::new();
    let b = Histogram::new();
    for i in 0..1000u64 {
        a.record(1 + i % 100);
        b.record(1 + i % 10_000);
    }
    let mut merged = a.snapshot();
    merged.merge(&b.snapshot());
    assert_eq!(merged.count(), 2000);
    assert_eq!(merged.sum_us, a.snapshot().sum_us + b.snapshot().sum_us);
    assert_invariants(&merged);
}

/// The property the shard router's stats aggregation stands on: merging
/// two workers' histogram snapshots and *then* taking percentiles gives
/// exactly the percentiles of one histogram fed the combined stream.
/// (Averaging per-worker p99s — the summary-of-summaries shortcut —
/// does not have this property; bucketwise merging does, because the
/// log2 bucket layouts are identical.)
#[test]
fn merged_percentiles_equal_combined_stream_percentiles() {
    // Two deliberately different latency profiles: worker A fast with a
    // tail, worker B uniformly slow — the case where averaging p99s is
    // most wrong.
    let a = Histogram::new();
    let b = Histogram::new();
    let combined = Histogram::new();
    for i in 0..5_000u64 {
        let fast = 1 + (i * 7) % 300; // ~µs-scale with spread
        let tail = if i % 100 == 0 { 200_000 + i } else { fast };
        a.record(tail);
        combined.record(tail);
    }
    for i in 0..2_000u64 {
        let slow = 50_000 + (i * 31) % 40_000;
        b.record(slow);
        combined.record(slow);
    }
    let mut merged = a.snapshot();
    merged.merge(&b.snapshot());
    let reference = combined.snapshot();
    assert_eq!(merged.count(), reference.count());
    assert_eq!(merged.sum_us, reference.sum_us);
    assert_eq!(merged.buckets, reference.buckets, "merge must be bucketwise-exact");
    let (m50, m90, m99) = merged.percentiles_us();
    let (r50, r90, r99) = reference.percentiles_us();
    assert_eq!(m50, r50, "merged p50 must equal combined-stream p50");
    assert_eq!(m90, r90, "merged p90 must equal combined-stream p90");
    assert_eq!(m99, r99, "merged p99 must equal combined-stream p99");
    // And the sparse wire form (what actually crosses the router <->
    // worker boundary) round-trips the merged state exactly.
    let wire = HistogramSnapshot::from_value(&merged.to_value()).expect("wire roundtrip");
    assert_eq!(wire.buckets, merged.buckets);
    assert_eq!(wire.sum_us, merged.sum_us);
    assert_eq!(wire.percentiles_us(), merged.percentiles_us());
}
