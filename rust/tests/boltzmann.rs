//! Statistical correctness: every rung must sample the exact Boltzmann
//! distribution of a small, exactly-enumerable model.
//!
//! The model (2x2 torus base graph x 8 layers = 32 spins) is too big for
//! state-space enumeration, so we check exact *observables* on an even
//! smaller 2-spin-per-layer chain by comparing against full enumeration
//! over 2^8 states of a 4-layer model, using total-variation distance of
//! the energy histogram.

use std::collections::HashMap;

use vectorising::ising::graph::BaseGraph;
use vectorising::ising::QmcModel;
use vectorising::sweep::c1_replica_batch::{make_batch_sweeper, BatchSweeper};
use vectorising::sweep::{try_make_sweeper_with_exp, ExpMode, SweepKind, Sweeper};

/// Exact Boltzmann distribution over energies of a tiny model (<= 2^16
/// states), as a map from energy bits to probability.
fn exact_energy_distribution(m: &QmcModel, beta: f64) -> HashMap<i64, f64> {
    let n = m.n_spins();
    assert!(n <= 16, "enumeration limit");
    let mut z = 0.0f64;
    let mut acc: HashMap<i64, f64> = HashMap::new();
    for mask in 0u32..(1 << n) {
        let s: Vec<f32> = (0..n).map(|i| if mask >> i & 1 == 1 { 1.0 } else { -1.0 }).collect();
        let e = m.total_energy(&s);
        let w = (-beta * e).exp();
        z += w;
        *acc.entry(quantize(e)).or_insert(0.0) += w;
    }
    for v in acc.values_mut() {
        *v /= z;
    }
    acc
}

fn quantize(e: f64) -> i64 {
    (e * 1024.0).round() as i64
}

fn tv_distance(p: &HashMap<i64, f64>, q: &HashMap<i64, f64>) -> f64 {
    let keys: std::collections::BTreeSet<i64> = p.keys().chain(q.keys()).copied().collect();
    keys.iter()
        .map(|k| (p.get(k).unwrap_or(&0.0) - q.get(k).unwrap_or(&0.0)).abs())
        .sum::<f64>()
        / 2.0
}

fn tiny_model() -> QmcModel {
    // 2 vertices with one coupling, 8 layers -> 16 spins, 2^16 states.
    let base = BaseGraph::new(2, vec![0.25, -0.15], vec![(0, 1, 0.6)]);
    QmcModel::new(base, 8, 0.35)
}

fn sampled_energy_distribution(
    kind: SweepKind,
    exp: ExpMode,
    beta: f32,
    n_samples: usize,
) -> HashMap<i64, f64> {
    let m = tiny_model();
    let s0 = vec![1.0f32; m.n_spins()];
    let mut sw = try_make_sweeper_with_exp(kind, &m, &s0, 4242, exp).unwrap();
    sw.run(500, beta); // burn-in
    let mut acc: HashMap<i64, f64> = HashMap::new();
    for _ in 0..n_samples {
        sw.run(3, beta); // decorrelate
        *acc.entry(quantize(sw.energy())).or_insert(0.0) += 1.0;
    }
    for v in acc.values_mut() {
        *v /= n_samples as f64;
    }
    acc
}

#[test]
fn a1_samples_boltzmann() {
    let exact = exact_energy_distribution(&tiny_model(), 0.7);
    let got = sampled_energy_distribution(SweepKind::A1Original, ExpMode::Exact, 0.7, 12000);
    let tv = tv_distance(&exact, &got);
    assert!(tv < 0.05, "A.1 TV distance {tv}");
}

#[test]
fn a2_samples_boltzmann_with_fast_exp() {
    // The fast approximation perturbs acceptance ratios by up to ~4%; the
    // sampled distribution stays close but a looser bound applies.
    let exact = exact_energy_distribution(&tiny_model(), 0.7);
    let got = sampled_energy_distribution(SweepKind::A2Basic, ExpMode::Fast, 0.7, 12000);
    let tv = tv_distance(&exact, &got);
    assert!(tv < 0.06, "A.2(fast) TV distance {tv}");
}

#[test]
fn a4_samples_boltzmann() {
    let exact = exact_energy_distribution(&tiny_model(), 0.7);
    let got = sampled_energy_distribution(SweepKind::A4Full, ExpMode::Exact, 0.7, 12000);
    let tv = tv_distance(&exact, &got);
    assert!(tv < 0.05, "A.4 TV distance {tv}");
}

/// Sample the energy distribution of `n_samples` draws from a C-rung
/// batch in which every lane is an independent chain of the same model at
/// the same β (the ensemble view: W chains, one histogram).
fn sampled_energy_distribution_c1(
    m: &QmcModel,
    kind: SweepKind,
    beta: f32,
    n_samples: usize,
) -> HashMap<i64, f64> {
    let w = kind.group_width();
    let models = vec![m.clone(); w];
    let states = vec![vec![1.0f32; m.n_spins()]; w];
    let seeds: Vec<u32> = (0..w as u32).map(|k| 4242 + 31 * k).collect();
    let betas = vec![beta; w];
    let mut sw = make_batch_sweeper(kind, &models, &states, &seeds, ExpMode::Exact).unwrap();
    sw.run(500, &betas); // burn-in
    let mut acc: HashMap<i64, f64> = HashMap::new();
    let rounds = n_samples / w;
    for _ in 0..rounds {
        sw.run(3, &betas); // decorrelate
        for k in 0..w {
            *acc.entry(quantize(sw.energy_of(k))).or_insert(0.0) += 1.0;
        }
    }
    for v in acc.values_mut() {
        *v /= (rounds * w) as f64;
    }
    acc
}

#[test]
fn c1_batch_samples_boltzmann() {
    // Same tolerance as the scalar rungs: the C.1 ensemble (4 lanes of
    // the tiny model at one β) must reproduce the exact distribution.
    let exact = exact_energy_distribution(&tiny_model(), 0.7);
    let got =
        sampled_energy_distribution_c1(&tiny_model(), SweepKind::C1ReplicaBatch, 0.7, 12000);
    let tv = tv_distance(&exact, &got);
    assert!(tv < 0.05, "C.1 TV distance {tv}");
}

#[test]
fn c1w8_batch_samples_boltzmann_on_shallow_model() {
    // layers = 2 — the shallow geometry only the C-rungs can vectorize:
    // 2 vertices x 2 layers = 4 spins, fully enumerable.  Note the L = 2
    // degenerate tau structure (up == down neighbour) is exercised here.
    let base = BaseGraph::new(2, vec![0.25, -0.15], vec![(0, 1, 0.6)]);
    let shallow = QmcModel::new(base, 2, 0.35);
    let exact = exact_energy_distribution(&shallow, 0.7);
    let got =
        sampled_energy_distribution_c1(&shallow, SweepKind::C1ReplicaBatchW8, 0.7, 12000);
    let tv = tv_distance(&exact, &got);
    assert!(tv < 0.05, "C.1w8 shallow TV distance {tv}");
}

#[test]
fn magnetization_tracks_field_sign() {
    // h > 0 on vertex 0 must bias <s_0> positive at low temperature.
    let m = tiny_model();
    let s0 = vec![-1.0f32; m.n_spins()];
    let mut sw = try_make_sweeper_with_exp(SweepKind::A4Full, &m, &s0, 7, ExpMode::Exact).unwrap();
    sw.run(500, 1.5);
    let mut mag0 = 0.0f64;
    let n = 2000;
    for _ in 0..n {
        sw.run(2, 1.5);
        let st = sw.state();
        // vertex 0 across layers: indices l*2
        mag0 += (0..8).map(|l| st[l * 2] as f64).sum::<f64>() / 8.0;
    }
    mag0 /= n as f64;
    assert!(mag0 > 0.2, "<s_0> = {mag0}, expected positive (h_0 = +0.25)");
}
