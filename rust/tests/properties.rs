//! Randomized property tests over the coordinator-level invariants.
//!
//! The offline build image has no `proptest`, so these use the crate's
//! own deterministic LCG to drive many randomized cases per property —
//! same methodology (generate, check invariant, shrink by rerunning the
//! failing seed manually), reproducible by construction.

use vectorising::ising::builder::{torus_workload, Workload};
use vectorising::ising::lcg::Lcg;
use vectorising::ising::reorder::InterlaceW;
use vectorising::rng::{Mt19937, Mt19937Simd};
use vectorising::simd::{portable, SimdU32};
use vectorising::sweep::{try_make_sweeper_with_exp, ExpMode, SweepKind, Sweeper};
use vectorising::tempering::{exchange_pass, Ladder, PtEnsemble, ReplicaSet};
use vectorising::util::json::Value;

fn random_workload(rng: &mut Lcg) -> Workload {
    let dims = [(4usize, 4usize), (6, 4), (8, 4), (6, 6)];
    let layers = [8usize, 12, 16, 32];
    let (w, h) = dims[(rng.next_u64() % 4) as usize];
    let l = layers[(rng.next_u64() % 4) as usize];
    torus_workload(w, h, l, rng.next_u64() % 1000, 0.1 + 0.4 * (rng.next_unit().abs()))
}

/// Valid interlace widths for a layer count (of the two SIMD widths).
fn valid_widths(l: usize) -> Vec<usize> {
    [4usize, 8].iter().copied().filter(|&w| l % w == 0 && l / w >= 2).collect()
}

/// Property: the W-way interlace is a permutation that round-trips any
/// state, for every valid (geometry, width) pair.
#[test]
fn prop_interlace_roundtrips() {
    let mut rng = Lcg::new(2024);
    for case in 0..40 {
        let wl = random_workload(&mut rng);
        for w in valid_widths(wl.model.n_layers) {
            let it = InterlaceW::new(&wl.model, w);
            let s = wl.model.random_state(&mut rng);
            let back = it.to_original(&it.to_interlaced(&s));
            assert_eq!(back, s, "case {case} w={w}");
            // permutation bijectivity
            let mut seen = vec![false; s.len()];
            for &p in &it.perm {
                assert!(!seen[p as usize], "case {case} w={w}: duplicate");
                seen[p as usize] = true;
            }
        }
    }
}

/// Property: each lane of the SIMD MT19937 is bit-exact to a scalar
/// generator with that lane's seed — for W = 4 and W = 8, across block
/// boundaries, from random base seeds.
#[test]
fn prop_simd_mt19937_lane_exact_for_w4_and_w8() {
    fn check<U: SimdU32>(base: u32) {
        let seeds: Vec<u32> = (0..U::LANES as u32).map(|k| base.wrapping_add(k)).collect();
        let mut simd = Mt19937Simd::<U>::new(&seeds);
        let mut scalars: Vec<Mt19937> = seeds.iter().map(|&s| Mt19937::new(s)).collect();
        let mut row = vec![0u32; U::LANES];
        for step in 0..700 {
            simd.next_into(&mut row);
            for (k, &v) in row.iter().enumerate() {
                assert_eq!(v, scalars[k].next_u32(), "base {base} step {step} lane {k}");
            }
        }
    }
    let mut rng = Lcg::new(1312);
    for _ in 0..6 {
        let base = (rng.next_u64() >> 16) as u32;
        check::<portable::U32xN<4>>(base);
        check::<portable::U32xN<8>>(base);
        check::<vectorising::simd::U32x4>(base);
    }
    #[cfg(target_arch = "x86_64")]
    {
        if vectorising::simd::avx2_available() {
            let mut rng = Lcg::new(1729);
            for _ in 0..6 {
                check::<vectorising::simd::avx2::U32x8>((rng.next_u64() >> 16) as u32);
            }
        }
    }
}

/// Pick a CPU rung compatible with the workload's layer count.
fn random_cpu_kind(rng: &mut Lcg, l: usize) -> SweepKind {
    let pool = SweepKind::all_cpu_wide();
    let kind = pool[(rng.next_u64() % pool.len() as u64) as usize];
    if kind.group_width() > 1 && !valid_widths(l).contains(&kind.group_width()) {
        SweepKind::A4Full // every random workload supports width 4
    } else {
        kind
    }
}

/// Property: incremental h_eff equals recomputation after arbitrary sweep
/// sequences with arbitrary β schedules, on every rung (both widths).
#[test]
fn prop_heff_consistency_under_random_schedules() {
    let mut rng = Lcg::new(777);
    for case in 0..12 {
        let wl = random_workload(&mut rng);
        let kind = random_cpu_kind(&mut rng, wl.model.n_layers);
        let mut sw =
            try_make_sweeper_with_exp(kind, &wl.model, &wl.s0, case as u32, ExpMode::Fast).unwrap();
        for _ in 0..5 {
            let beta = 0.1 + rng.next_unit().abs() * 2.0;
            let n = 1 + (rng.next_u64() % 4) as usize;
            sw.run(n, beta);
        }
        let err = sw.validate();
        assert!(err < 1e-3, "case {case} {kind:?}: h_eff drift {err}");
    }
}

/// Property: states remain ±1 and flip counts stay within attempts.
#[test]
fn prop_stats_and_domain_invariants() {
    let mut rng = Lcg::new(31337);
    for case in 0..12 {
        let wl = random_workload(&mut rng);
        let kind = random_cpu_kind(&mut rng, wl.model.n_layers);
        let mut sw =
            try_make_sweeper_with_exp(kind, &wl.model, &wl.s0, 1 + case as u32, ExpMode::Fast).unwrap();
        let stats = sw.run(4, 0.9);
        assert_eq!(stats.attempts, 4 * wl.model.n_spins() as u64, "case {case}");
        assert!(stats.flips <= stats.attempts);
        assert!(stats.groups_with_flip <= stats.groups);
        assert!(sw.state().iter().all(|&s| s == 1.0 || s == -1.0), "case {case}");
    }
}

/// Property: replica exchange permutes states (never invents or loses
/// one) and preserves per-rung β assignment, under random ladders.
#[test]
fn prop_exchange_preserves_state_multiset() {
    let mut rng = Lcg::new(99);
    for case in 0..8 {
        let n = 3 + (rng.next_u64() % 6) as usize;
        let ladder = Ladder::geometric(2.0 + rng.next_unit().abs(), 0.1, n);
        let betas: Vec<f32> = (0..n).map(|i| ladder.beta(i)).collect();
        let replicas = (0..n)
            .map(|i| {
                let wl = torus_workload(4, 4, 8, 5, 0.3);
                try_make_sweeper_with_exp(
                    SweepKind::A2Basic,
                    &wl.model,
                    &wl.s0,
                    case as u32 * 100 + i as u32,
                    ExpMode::Fast,
                )
                .unwrap()
            })
            .collect();
        let mut pt = PtEnsemble::new(ladder, replicas, case as u32);
        pt.sweep_all(3);
        let fingerprint = |pt: &mut PtEnsemble| -> Vec<Vec<u32>> {
            (0..pt.len())
                .map(|i| pt.state_of(i).iter().map(|x| x.to_bits()).collect())
                .collect()
        };
        let mut before = fingerprint(&mut pt);
        pt.exchange();
        let mut after = fingerprint(&mut pt);
        before.sort();
        after.sort();
        assert_eq!(before, after, "case {case}");
        // β assignment per rung is unchanged
        let reports = pt.reports();
        for (i, r) in reports.iter().enumerate() {
            assert_eq!(r.beta, betas[i]);
        }
    }
}

/// Property: geometric ladders hit both endpoints, decrease strictly
/// monotonically, and keep a constant ratio — for random ranges and rung
/// counts (the invariants `Ladder::geometric`'s doc promises).
#[test]
fn prop_ladder_geometric_invariants() {
    let mut rng = Lcg::new(20_26);
    for case in 0..60 {
        let beta_hot = 0.05 + rng.next_unit().abs();
        let beta_cold = beta_hot + 0.1 + 3.0 * rng.next_unit().abs();
        let n = 2 + (rng.next_u64() % 120) as usize;
        let l = Ladder::geometric(beta_cold, beta_hot, n);
        assert_eq!(l.len(), n, "case {case}");
        let rel = |a: f32, b: f32| ((a - b) / b).abs();
        assert!(rel(l.beta(0), beta_cold) < 1e-5, "case {case}: cold endpoint");
        assert!(rel(l.beta(n - 1), beta_hot) < 1e-4, "case {case}: hot endpoint");
        let r0 = (l.beta(1) / l.beta(0)) as f64;
        for i in 1..n {
            assert!(l.beta(i) < l.beta(i - 1), "case {case}: monotone at {i}");
            assert!(l.beta(i) > 0.0, "case {case}: positive at {i}");
            let r = (l.beta(i) / l.beta(i - 1)) as f64;
            assert!((r - r0).abs() < 1e-4, "case {case}: ratio at {i}: {r} vs {r0}");
        }
        // degenerate single-rung ladder: just the cold endpoint
        let single = Ladder::geometric(beta_cold, beta_hot, 1);
        assert_eq!(single.len(), 1);
        assert_eq!(single.beta(0), beta_cold);
    }
}

/// A [`ReplicaSet`] with pinned per-rung energies: `energy_of` is a pure
/// function of the rung index, so the exchange acceptance probability of
/// a pair is constant across repeated passes and its empirical frequency
/// can be checked against the Metropolis rule.
struct PinnedEnergies {
    betas: Vec<f32>,
    energies: Vec<f64>,
    states: Vec<Vec<f32>>,
}

impl ReplicaSet for PinnedEnergies {
    fn n_replicas(&self) -> usize {
        self.betas.len()
    }

    fn beta_of(&self, i: usize) -> f32 {
        self.betas[i]
    }

    fn energy_of(&mut self, i: usize) -> f64 {
        self.energies[i]
    }

    fn state_of(&mut self, i: usize) -> Vec<f32> {
        self.states[i].clone()
    }

    fn set_state_of(&mut self, i: usize, s: &[f32]) {
        self.states[i] = s.to_vec();
    }
}

/// Property (detailed balance): the empirical exchange acceptance rate of
/// a pair with energy gap ΔE and inverse-temperature gap Δβ matches
/// `min(1, exp(Δβ·ΔE))` within binomial error bounds, and the
/// `log_acc >= 0` branch accepts always.
#[test]
fn prop_exchange_acceptance_matches_metropolis_rule() {
    // (E_cold, E_hot, beta_cold, beta_hot) cases spanning both branches
    // and acceptance rates from ~8% to 100%.
    let cases = [
        (-10.0f64, -5.0f64, 1.0f32, 0.5f32), // log_acc = 0.5*(-5) -> p ~ 0.082
        (-6.0, -4.0, 1.2, 0.8),              // p = exp(-0.8) ~ 0.449
        (-5.0, -4.5, 0.9, 0.7),              // p = exp(-0.1) ~ 0.905
        (-4.0, -9.0, 1.0, 0.4),              // ΔE > 0 -> always accept
    ];
    for (case, &(e_cold, e_hot, b_cold, b_hot)) in cases.iter().enumerate() {
        let p_expect = ((b_cold - b_hot) as f64 * (e_cold - e_hot)).exp().min(1.0);
        let mut set = PinnedEnergies {
            betas: vec![b_cold, b_hot],
            energies: vec![e_cold, e_hot],
            states: vec![vec![1.0; 4], vec![-1.0; 4]],
        };
        let mut rng = Mt19937::new(777 + case as u32);
        let n_rounds = 4000u64;
        let (mut attempted, mut accepted) = (0u64, 0u64);
        for _ in 0..n_rounds {
            let (a, c) = exchange_pass(&mut set, &mut rng, 0);
            attempted += a;
            accepted += c;
        }
        assert_eq!(attempted, n_rounds, "case {case}: one pair per even pass");
        let p_got = accepted as f64 / attempted as f64;
        if p_expect >= 1.0 {
            assert_eq!(accepted, attempted, "case {case}: ΔE > 0 must always accept");
        } else {
            // 4.5σ binomial bound: false-failure odds < 1e-5 per case.
            let sigma = (p_expect * (1.0 - p_expect) / n_rounds as f64).sqrt();
            assert!(
                (p_got - p_expect).abs() < 4.5 * sigma + 1e-9,
                "case {case}: empirical {p_got} vs Metropolis {p_expect} (σ {sigma})"
            );
        }
    }
}

/// Property: an exchange pass from either parity only ever transposes the
/// designated adjacent pairs — states are permuted, never invented — and
/// the odd parity leaves pair (0,1) alone.
#[test]
fn prop_exchange_pass_only_swaps_adjacent_pairs() {
    let mut rng = Mt19937::new(31);
    for n in [2usize, 3, 5, 8] {
        for start in [0usize, 1] {
            let mut set = PinnedEnergies {
                betas: (0..n).map(|i| 2.0 - i as f32 * 0.2).collect(),
                energies: (0..n).map(|i| -(i as f64)).collect(),
                states: (0..n).map(|i| vec![i as f32; 3]).collect(),
            };
            exchange_pass(&mut set, &mut rng, start);
            // Each state i must sit at i-1, i or i+1, with the pairing
            // parity respected.
            for (slot, st) in set.states.iter().enumerate() {
                let origin = st[0] as usize;
                let d = slot.abs_diff(origin);
                assert!(d <= 1, "n={n} start={start}: state {origin} moved to {slot}");
                if d == 1 {
                    let pair_lo = slot.min(origin);
                    assert_eq!(pair_lo % 2, start % 2, "n={n} start={start}: wrong parity swap");
                }
            }
        }
    }
}

/// Property: the JSON substrate round-trips every value it can produce.
#[test]
fn prop_json_roundtrip_fuzz() {
    let mut rng = Lcg::new(4096);
    for case in 0..200 {
        let v = random_json(&mut rng, 3);
        let text = v.to_string();
        let back = Value::parse(&text).unwrap_or_else(|e| panic!("case {case}: {e}\n{text}"));
        assert_eq!(back, v, "case {case}");
    }
}

fn random_json(rng: &mut Lcg, depth: usize) -> Value {
    match rng.next_u64() % if depth == 0 { 4 } else { 6 } {
        0 => Value::Null,
        1 => Value::Bool(rng.next_u64() % 2 == 0),
        2 => Value::Num((rng.next_u64() % 1_000_000) as f64 / 8.0),
        3 => Value::Str(format!("s{}-\"quoted\"\n\t λ", rng.next_u64() % 100)),
        4 => Value::Arr((0..rng.next_u64() % 5).map(|_| random_json(rng, depth - 1)).collect()),
        _ => Value::Obj(
            (0..rng.next_u64() % 5)
                .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                .collect(),
        ),
    }
}
