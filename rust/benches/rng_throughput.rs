//! RNG throughput: scalar MT19937 vs the 4-way SSE-interlaced generator
//! vs the W-way generator — the paper's §3 claim that interlacing gives
//! "nearly a 4x speedup of the random number generation".

mod support;

use vectorising::rng::{Mt19937, Mt19937Wide, Mt19937x4};

const N: usize = 1 << 20; // numbers per run
const REPS: usize = 30;

fn main() {
    let mut sink = 0u32;

    let scalar = {
        let mut rng = Mt19937::new(5489);
        support::time_reps(2, REPS, || {
            let mut acc = 0u32;
            for _ in 0..N {
                acc = acc.wrapping_add(rng.next_u32());
            }
            sink ^= acc;
        })
    };

    let x4 = {
        let mut rng = Mt19937x4::new([5489, 5490, 5491, 5492]);
        support::time_reps(2, REPS, || {
            let mut acc = 0u32;
            for _ in 0..N / 4 {
                let q = rng.next4_u32();
                acc = acc.wrapping_add(q[0]).wrapping_add(q[1]).wrapping_add(q[2]).wrapping_add(q[3]);
            }
            sink ^= acc;
        })
    };

    let wide32 = {
        let seeds: Vec<u32> = (0..32).map(|k| 5489 + k).collect();
        let mut rng = Mt19937Wide::new(&seeds);
        support::time_reps(2, REPS, || {
            let mut acc = 0u32;
            for _ in 0..N / 32 {
                for &v in rng.next_row() {
                    acc = acc.wrapping_add(v);
                }
            }
            sink ^= acc;
        })
    };

    println!("MT19937 throughput ({N} numbers/run, {REPS} runs; Mnum = 1e6 numbers/s):");
    let work = N as f64;
    support::report("mt19937 scalar", &scalar, work, "Mnum");
    support::report("mt19937 x4 SSE-interlaced", &x4, work, "Mnum");
    support::report("mt19937 32-lane interlaced", &wide32, work, "Mnum");
    println!(
        "\nx4 speedup over scalar: {:.2}x   (paper: 'nearly a 4x speedup')",
        support::mean(&scalar) / support::mean(&x4)
    );
    std::hint::black_box(sink);
}
