//! RNG throughput: scalar MT19937 vs the SIMD-interlaced generator at
//! widths 4 and 8 vs the W-way scalar-interlaced generator — the paper's
//! §3 claim that interlacing gives "nearly a 4x speedup of the random
//! number generation", extended along the vector-width axis.

mod support;

use vectorising::rng::{Mt19937, Mt19937Simd, Mt19937Wide};
use vectorising::simd::{portable, SimdU32, U32x4};

const N: usize = 1 << 20; // numbers per run
const REPS: usize = 30;

/// Time the SIMD generator on backend `U`, consuming `N` numbers per run.
fn time_simd<U: SimdU32>(sink: &mut u32) -> Vec<f64> {
    let seeds: Vec<u32> = (0..U::LANES as u32).map(|k| 5489 + k).collect();
    let mut rng = Mt19937Simd::<U>::new(&seeds);
    let mut row = vec![0u32; U::LANES];
    support::time_reps(2, REPS, || {
        let mut acc = 0u32;
        for _ in 0..N / U::LANES {
            rng.next_into(&mut row);
            for &v in &row {
                acc = acc.wrapping_add(v);
            }
        }
        *sink ^= acc;
    })
}

fn main() {
    let mut sink = 0u32;

    let scalar = {
        let mut rng = Mt19937::new(5489);
        support::time_reps(2, REPS, || {
            let mut acc = 0u32;
            for _ in 0..N {
                acc = acc.wrapping_add(rng.next_u32());
            }
            sink ^= acc;
        })
    };

    let x4 = time_simd::<U32x4>(&mut sink);
    let (x8, x8_label) = {
        #[cfg(target_arch = "x86_64")]
        {
            if vectorising::simd::avx2_available() {
                (
                    time_simd::<vectorising::simd::avx2::U32x8>(&mut sink),
                    "mt19937 x8 AVX2-interlaced",
                )
            } else {
                (time_simd::<portable::U32xN<8>>(&mut sink), "mt19937 x8 portable-interlaced")
            }
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            (time_simd::<portable::U32xN<8>>(&mut sink), "mt19937 x8 portable-interlaced")
        }
    };

    let wide32 = {
        let seeds: Vec<u32> = (0..32).map(|k| 5489 + k).collect();
        let mut rng = Mt19937Wide::new(&seeds);
        support::time_reps(2, REPS, || {
            let mut acc = 0u32;
            for _ in 0..N / 32 {
                for &v in rng.next_row() {
                    acc = acc.wrapping_add(v);
                }
            }
            sink ^= acc;
        })
    };

    println!("MT19937 throughput ({N} numbers/run, {REPS} runs; Mnum = 1e6 numbers/s):");
    let work = N as f64;
    support::report("mt19937 scalar", &scalar, work, "Mnum");
    support::report("mt19937 x4 SSE-interlaced", &x4, work, "Mnum");
    support::report(x8_label, &x8, work, "Mnum");
    support::report("mt19937 32-lane interlaced", &wide32, work, "Mnum");
    println!(
        "\nx4 speedup over scalar: {:.2}x   (paper: 'nearly a 4x speedup')",
        support::mean(&scalar) / support::mean(&x4)
    );
    println!("x8 speedup over scalar: {:.2}x", support::mean(&scalar) / support::mean(&x8));
    println!("x8 speedup over x4:     {:.2}x", support::mean(&x4) / support::mean(&x8));
    std::hint::black_box(sink);
}
