//! Bench for paper Fig 17 + §2.4: accuracy table of the exponential
//! approximations and throughput of `exp` vs fast vs accurate (the
//! paper's 83 vs 4 vs 11 clock-cycle claim, here as ns/op and per-op
//! speedup on this machine).

mod support;

use vectorising::expapprox::{exp_accurate, exp_fast, simd};
use vectorising::harness::fig17;
use vectorising::simd::F32x4;

const N: usize = 1 << 16;
const REPS: usize = 200;

fn main() {
    // --- accuracy (the figure itself) ---
    print!("{}", fig17::run(Some(std::path::Path::new("results/fig17.csv"))).unwrap());

    // --- throughput ---
    let xs: Vec<f32> = (0..N).map(|i| -20.0 + 40.0 * (i as f32) / N as f32).collect();
    let mut sink = 0.0f32;

    let libm = support::time_reps(3, REPS, || {
        let mut acc = 0.0f32;
        for &x in &xs {
            acc += x.exp();
        }
        sink += acc;
    });
    let fast = support::time_reps(3, REPS, || {
        let mut acc = 0.0f32;
        for &x in &xs {
            acc += exp_fast(x);
        }
        sink += acc;
    });
    let accurate = support::time_reps(3, REPS, || {
        let mut acc = 0.0f32;
        for &x in &xs {
            acc += exp_accurate(x);
        }
        sink += acc;
    });
    let fast_x4 = support::time_reps(3, REPS, || {
        let mut acc = F32x4::zero();
        for chunk in xs.chunks_exact(4) {
            acc = acc + simd::exp_fast_x4(F32x4::load(chunk));
        }
        sink += acc.to_array()[0];
    });
    let accurate_x4 = support::time_reps(3, REPS, || {
        let mut acc = F32x4::zero();
        for chunk in xs.chunks_exact(4) {
            acc = acc + simd::exp_accurate_x4(F32x4::load(chunk));
        }
        sink += acc.to_array()[0];
    });

    println!("\nthroughput ({N} evaluations/run, {REPS} runs; Mops = 1e6 evals/s):");
    let work = N as f64;
    support::report("exp: libm f32::exp", &libm, work, "Mops");
    support::report("exp: fast approx (scalar)", &fast, work, "Mops");
    support::report("exp: accurate approx (scalar)", &accurate, work, "Mops");
    support::report("exp: fast approx (SSE x4)", &fast_x4, work, "Mops");
    support::report("exp: accurate approx (SSE x4)", &accurate_x4, work, "Mops");
    println!(
        "\nspeedup over libm: fast {:.1}x, accurate {:.1}x, fast-x4 {:.1}x  (paper: ~20x, ~7.5x per the 83/4/11-cycle counts)",
        support::mean(&libm) / support::mean(&fast),
        support::mean(&libm) / support::mean(&accurate),
        support::mean(&libm) / support::mean(&fast_x4),
    );
    std::hint::black_box(sink);
}
