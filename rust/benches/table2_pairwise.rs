//! Bench for paper Table 2 (+ Fig 15): pairwise speedups between the CPU
//! rungs on one core.  The A.1a/A.2a (compiler-optimization-disabled)
//! rows come from the `opt0`-profile binary when it exists
//! (`make opt0`); otherwise the optimized 2x2 core of the table is
//! printed alone.

mod support;

use std::path::Path;

use vectorising::coordinator::RunConfig;
use vectorising::harness::table2;

fn main() {
    let cfg = RunConfig {
        n_models: std::env::var("TABLE2_MODELS").ok().and_then(|v| v.parse().ok()).unwrap_or(4),
        sweeps: std::env::var("TABLE2_SWEEPS").ok().and_then(|v| v.parse().ok()).unwrap_or(150),
        sweeps_per_round: 10,
        threads: 1,
        ..RunConfig::default()
    };
    println!(
        "Table 2 | {} models x {} spins x {} sweeps | 1 thread",
        cfg.n_models,
        cfg.n_spins_per_model(),
        cfg.sweeps
    );
    let mut rungs = table2::measure_optimized(&cfg).expect("optimized rungs");
    let opt0 = Path::new("target/opt0/repro");
    if opt0.exists() {
        let mut un = table2::measure_unoptimized(&cfg, opt0).expect("opt0 rungs");
        un.append(&mut rungs);
        rungs = un;
    } else {
        println!("(no {opt0:?}; run `make opt0` for the A.1a/A.2a rows)");
    }
    print!("{}", table2::render(&rungs, Some(Path::new("results/table2.csv"))).unwrap());
}
