//! Replica-batched sweeping (C.1/C.1w8) vs per-replica scalar/vector
//! rungs on a paper-scale 115-replica tempering ladder — replicas/sec,
//! i.e. how many full replica-sweeps of the ladder the engine retires
//! per second.
//!
//! Two workloads:
//!
//! * **paper-scale** (96 x 256 spins): the A-rungs can interlace layers
//!   here, so this measures lane-per-replica batching against the
//!   strongest per-replica baselines (A.2 scalar and the widest A.4);
//! * **shallow** (`layers = 2`, 96 x 2 spins): the geometry the A.3/A.4
//!   interlacing must reject — per-replica sweeping degrades to scalar
//!   A.2, while the C-rungs keep their full vector width.  This is the
//!   ISSUE-2 acceptance scenario: C.1w8 must beat per-replica A.2 by
//!   >= 2x replicas/sec.

//! Set `REPRO_BENCH_DIR` to also emit one machine-readable
//! `BENCH_<rung>.json` artifact per paper-scale row (see
//! `harness::bench`).

mod support;

use vectorising::coordinator::RunConfig;
use vectorising::engine::Rung;
use vectorising::harness::bench::{self, BenchArtifact, HostCaps, BENCH_SCHEMA_VERSION};
use vectorising::ising::builder::torus_workload;
use vectorising::simd::{avx2_available, widest_supported_width};
use vectorising::sweep::{try_make_sweeper, SweepKind, Sweeper};
use vectorising::tempering::{BatchedPtEnsemble, Ladder};

const N_REPLICAS: usize = 115;

/// Emit the machine-readable artifact for one paper-scale row when
/// REPRO_BENCH_DIR is set.
fn emit(kind: SweepKind, sc: &Scenario, secs: &[f64], n_spins: usize) {
    let Ok(dir) = std::env::var("REPRO_BENCH_DIR") else { return };
    if sc.layers != 256 {
        return; // only the paper-scale scenario is a canonical artifact
    }
    let rung = match kind {
        SweepKind::A2Basic => Rung::A2,
        SweepKind::C1ReplicaBatch | SweepKind::C1ReplicaBatchW8 => Rung::C1,
        _ => Rung::A4,
    };
    let cfg = RunConfig {
        width: 12,
        height: 8,
        layers: sc.layers,
        n_models: N_REPLICAS,
        ..RunConfig::default()
    };
    let updates = (N_REPLICAS * sc.sweeps * n_spins) as f64;
    let art = BenchArtifact {
        schema: BENCH_SCHEMA_VERSION,
        rung: kind.label().to_string(),
        threads: 1,
        sweeps: sc.sweeps,
        seconds: support::mean(secs),
        spins_per_sec: updates / support::mean(secs),
        lane_width: kind.group_width(),
        lane_fill: bench::lane_fill(rung, kind.group_width(), &cfg),
        torus_width: 12,
        torus_height: 8,
        layers: sc.layers,
        n_models: N_REPLICAS,
        host: HostCaps::detect(),
        git_sha: bench::git_sha(),
        provenance: "measured".into(),
    };
    match art.write_to(std::path::Path::new(&dir)) {
        Ok(path) => println!("  -> wrote {}", path.display()),
        Err(e) => eprintln!("  -> artifact write failed: {e:#}"),
    }
}

struct Scenario {
    name: &'static str,
    layers: usize,
    sweeps: usize,
    reps: usize,
}

/// Per-replica baseline: one boxed sweeper per ladder rung, swept
/// serially (the single-thread view of the scalar ensemble engine).
fn time_per_replica(kind: SweepKind, sc: &Scenario, ladder: &Ladder) -> Option<Vec<f64>> {
    let wl = torus_workload(12, 8, sc.layers, 1, 0.3);
    if !kind.supports_layers(wl.model.n_layers) {
        return None;
    }
    let mut sweepers: Vec<Box<dyn Sweeper + Send>> = (0..N_REPLICAS)
        .map(|i| try_make_sweeper(kind, &wl.model, &wl.s0, 1 + 1000 * i as u32).unwrap())
        .collect();
    // settle into a representative flip regime
    for (i, sw) in sweepers.iter_mut().enumerate() {
        sw.run(2, ladder.beta(i));
    }
    Some(support::time_reps(1, sc.reps, || {
        for (i, sw) in sweepers.iter_mut().enumerate() {
            sw.run(sc.sweeps, ladder.beta(i));
        }
    }))
}

/// C-rung: the ladder grouped into lane-batches, swept serially batch by
/// batch (same single-thread view; the pool parallelises both engines
/// identically).
fn time_batched(kind: SweepKind, sc: &Scenario, ladder: &Ladder) -> Vec<f64> {
    let wl = torus_workload(12, 8, sc.layers, 1, 0.3);
    let models = vec![wl.model.clone(); N_REPLICAS];
    let states = vec![wl.s0.clone(); N_REPLICAS];
    let seeds: Vec<u32> = (0..N_REPLICAS as u32).map(|i| 1 + 1000 * i).collect();
    let mut pt = BatchedPtEnsemble::new(
        ladder.clone(),
        kind,
        &models,
        &states,
        &seeds,
        0x5a5a,
        kind.default_exp(),
    )
    .unwrap();
    pt.sweep_all(2); // settle
    support::time_reps(1, sc.reps, || {
        pt.sweep_all(sc.sweeps);
    })
}

fn main() {
    println!(
        "replica batching: {N_REPLICAS}-replica ladder (paper §4 count), 12x8 torus base, \
         replica-sweeps/sec"
    );
    println!(
        "host: avx2={}  widest backend width={}\n",
        avx2_available(),
        widest_supported_width()
    );
    let ladder = Ladder::geometric(3.0, 0.5, N_REPLICAS);

    let scenarios = [
        Scenario { name: "paper-scale (96x256)", layers: 256, sweeps: 2, reps: 3 },
        Scenario { name: "shallow (96x2, A-rungs can't widen)", layers: 2, sweeps: 200, reps: 5 },
    ];

    for sc in &scenarios {
        println!("== {} ==", sc.name);
        // work unit: one sweep of one replica
        let replica_sweeps = (N_REPLICAS * sc.sweeps) as f64;
        let mut means: Vec<(&str, f64)> = Vec::new();
        for kind in [
            SweepKind::A2Basic,
            SweepKind::A4Full,
            SweepKind::A4FullW8,
            SweepKind::C1ReplicaBatch,
            SweepKind::C1ReplicaBatchW8,
        ] {
            let secs = if kind.is_replica_batch() {
                Some(time_batched(kind, sc, &ladder))
            } else {
                time_per_replica(kind, sc, &ladder)
            };
            match secs {
                Some(secs) => {
                    support::report(
                        &format!("{} (w={})", kind.label(), kind.group_width()),
                        &secs,
                        replica_sweeps,
                        "replica-sweeps",
                    );
                    emit(kind, sc, &secs, 96 * sc.layers);
                    means.push((kind.label(), support::mean(&secs)));
                }
                None => println!(
                    "{:38} (skipped: layers={} unsupported)",
                    kind.label(),
                    sc.layers
                ),
            }
        }
        let mean_of = |label: &str| means.iter().find(|(l, _)| *l == label).map(|(_, m)| *m);
        if let (Some(a2), Some(c1w8)) = (mean_of("A.2"), mean_of("C.1w8")) {
            println!(
                "\nC.1w8 over per-replica A.2: {:.2}x replicas/sec{}\n",
                a2 / c1w8,
                if avx2_available() { "" } else { "   (portable fallback — no AVX2)" }
            );
        }
    }
}
