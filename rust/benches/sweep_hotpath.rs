//! Hot-path microbenchmark: ns per single-spin Metropolis update for each
//! rung on one model (no tempering, no threading) — the number the whole
//! paper is about.  Also times the accelerator rungs per-update when
//! artifacts are present.

mod support;

use vectorising::ising::builder::torus_workload;
use vectorising::runtime::{artifact, Runtime};
use vectorising::sweep::accel::{AccelSweeper, AccelVariant};
use vectorising::sweep::{try_make_sweeper, SweepKind, Sweeper};

const SWEEPS: usize = 100;
const REPS: usize = 10;

fn main() {
    let beta = 0.8f32;
    println!("per-update cost, 64x32 model (2,048 spins), {SWEEPS} sweeps/run, {REPS} runs\n");
    let updates = (SWEEPS * 2048) as f64;

    for kind in SweepKind::all_cpu_wide() {
        let wl = torus_workload(8, 8, 32, 1, 0.3);
        let mut sw = try_make_sweeper(kind, &wl.model, &wl.s0, 5489).expect("cpu sweeper");
        sw.run(20, beta);
        let secs = support::time_reps(1, REPS, || {
            sw.run(SWEEPS, beta);
        });
        let ns = support::mean(&secs) / updates * 1e9;
        support::report(&format!("sweep {} ({ns:.2} ns/update)", kind.label()), &secs, updates, "Mupd");
    }

    let dir = artifact::default_dir();
    if dir.join("manifest.json").exists() {
        let rt = Runtime::cpu().expect("pjrt");
        for (variant, label) in [(AccelVariant::B1Naive, "B.1"), (AccelVariant::B2Coalesced, "B.2")] {
            let wl = torus_workload(8, 8, 32, 1, 0.3);
            let mut sw = AccelSweeper::new(&rt, &dir, "default", variant, &wl, 5489).expect("accel");
            sw.run(20, beta);
            let secs = support::time_reps(1, REPS, || {
                sw.run(SWEEPS, beta);
            });
            let ns = support::mean(&secs) / updates * 1e9;
            support::report(&format!("sweep {label} ({ns:.2} ns/update)"), &secs, updates, "Mupd");
        }
    } else {
        println!("(artifacts missing; run `make artifacts` for B.1/B.2 rows)");
    }
}
