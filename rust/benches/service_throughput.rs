//! Service throughput: jobs/sec and lane-fill ratio of the batching
//! scheduler + executor for uniform vs. mixed-shape job streams at
//! W ∈ {4, 8}.
//!
//! A uniform stream packs full lane-batches (fill 1.0); a mixed stream
//! spreads the same job count over three shapes, so drain-time flushes
//! pad some batches — the jobs/sec gap between the two rows is the price
//! of shape diversity at a given vector width.  Run with
//! `cargo bench --bench service_throughput`.

mod support;

use std::time::{Duration, Instant};

use vectorising::coordinator::SweepPool;
use vectorising::service::batcher::{Batcher, Dispatch};
use vectorising::service::executor::Executor;
use vectorising::service::job::JobSpec;
use vectorising::sweep::ExpMode;

const N_JOBS: usize = 64;
const SWEEPS: usize = 150;

fn spec(id: usize, shape: (usize, usize, usize)) -> JobSpec {
    JobSpec {
        id: format!("j{id}"),
        width: shape.0,
        height: shape.1,
        layers: shape.2,
        model_seed: 1 + id as u64,
        jtau: 0.3,
        sweeps: SWEEPS,
        beta: 0.8,
        seed: 100 + id as u32,
        trace_every: 0,
        want_state: false,
        want_timing: false,
        sampler: None,
    }
}

fn jobs(mixed: bool) -> Vec<JobSpec> {
    let shapes: &[(usize, usize, usize)] =
        if mixed { &[(4, 4, 8), (6, 4, 8), (4, 4, 2)] } else { &[(4, 4, 8)] };
    (0..N_JOBS).map(|i| spec(i, shapes[i % shapes.len()])).collect()
}

/// Push the whole stream, pack it, execute every dispatch on the pool;
/// returns (seconds, lane-fill ratio over batch dispatches).
fn run_stream(lanes: usize, stream: &[JobSpec], pool: &SweepPool) -> (f64, f64) {
    let exec = Executor::new(lanes, ExpMode::Fast).unwrap();
    let mut batcher = Batcher::new(lanes, Duration::from_millis(1));
    let t0 = Instant::now();
    let now = Instant::now();
    for spec in stream {
        batcher.push(spec.clone(), None, now);
    }
    let mut dispatches = batcher.poll(now);
    dispatches.extend(batcher.drain());
    let (mut occupied, mut padded) = (0usize, 0usize);
    for d in &dispatches {
        if d.is_batch() {
            occupied += d.occupancy();
            padded += lanes - d.occupancy();
        }
    }
    let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = dispatches
        .into_iter()
        .map(|d| {
            Box::new(move || {
                for (_job, outcome) in exec.run_dispatch(d) {
                    outcome.expect("bench jobs are valid");
                }
            }) as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    pool.run_batch(tasks);
    let fill = if occupied + padded == 0 {
        1.0
    } else {
        occupied as f64 / (occupied + padded) as f64
    };
    (t0.elapsed().as_secs_f64(), fill)
}

fn bench_row(name: &str, lanes: usize, mixed: bool, threads: usize) {
    let stream = jobs(mixed);
    let pool = SweepPool::new(threads);
    // warm-up
    let _ = run_stream(lanes, &stream, &pool);
    let reps = 3;
    let mut secs = Vec::with_capacity(reps);
    let mut fill = 1.0;
    for _ in 0..reps {
        let (s, f) = run_stream(lanes, &stream, &pool);
        secs.push(s);
        fill = f;
    }
    let mean = support::mean(&secs);
    println!(
        "{name:44} {mean:8.4} s ± {:6.4}   {:10.1} jobs/s   lane-fill {fill:.3}",
        support::stddev(&secs),
        N_JOBS as f64 / mean,
    );
}

fn main() {
    println!(
        "service throughput: {N_JOBS} jobs x {SWEEPS} sweeps per stream \
         (uniform = one shape, mixed = three shapes)"
    );
    for threads in [1usize, 4] {
        for lanes in [4usize, 8] {
            bench_row(
                &format!("uniform  W={lanes} threads={threads}"),
                lanes,
                false,
                threads,
            );
            bench_row(&format!("mixed    W={lanes} threads={threads}"), lanes, true, threads);
        }
    }
}
