//! Bench for paper Fig 14: per-replica probability of having to wait for
//! a spin flip, measured over a tempering ladder and compared with the
//! analytic `1 - (1-p)^w` curves for w = 1 (A.1), 4 (A.4), 32 (GPU warp).

mod support;

use vectorising::coordinator::RunConfig;
use vectorising::harness::fig14;

fn main() {
    let cfg = RunConfig {
        n_models: std::env::var("FIG14_MODELS").ok().and_then(|v| v.parse().ok()).unwrap_or(16),
        sweeps: std::env::var("FIG14_SWEEPS").ok().and_then(|v| v.parse().ok()).unwrap_or(200),
        sweeps_per_round: 10,
        ..RunConfig::default()
    };
    println!(
        "Fig 14 | ladder of {} replicas x {} spins x {} sweeps",
        cfg.n_models,
        cfg.n_spins_per_model(),
        cfg.sweeps
    );
    print!(
        "{}",
        fig14::run(&cfg, Some(std::path::Path::new("results/fig14.csv"))).expect("fig14")
    );
}
