//! Minimal benchmarking support (the offline image has no criterion):
//! warm-up + repeated timed runs with mean/stddev, printed in a fixed
//! format the EXPERIMENTS.md tables are built from.
#![allow(dead_code)] // each bench binary uses a subset

use std::time::Instant;

/// Run `f` `reps` times after `warmup` untimed runs; returns per-run
/// seconds.
pub fn time_reps<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    (0..reps)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect()
}

pub fn mean(xs: &[f64]) -> f64 {
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn stddev(xs: &[f64]) -> f64 {
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len().max(2) - 1) as f64).sqrt()
}

/// Print one benchmark line: `name  mean ± sd seconds  (rate unit)`.
pub fn report(name: &str, secs: &[f64], work: f64, unit: &str) {
    let m = mean(secs);
    let sd = stddev(secs);
    println!(
        "{:38} {:10.4} s ± {:7.4}   {:12.3} {unit}",
        name,
        m,
        sd,
        work / m / 1e6
    );
}
