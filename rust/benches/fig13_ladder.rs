//! Bench for paper Fig 13: relative performance of the optimization
//! ladder across thread counts (+ B.1/B.2 when artifacts are present).
//!
//! `cargo bench --bench fig13_ladder` prints the same rows as
//! `repro fig13 --accel`; the workload is the scaled default (override
//! scale via env: FIG13_SWEEPS, FIG13_MODELS, FIG13_THREADS="1,2,4").

mod support;

use vectorising::coordinator::RunConfig;
use vectorising::harness::fig13;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let cfg = RunConfig {
        n_models: env_usize("FIG13_MODELS", 4),
        sweeps: env_usize("FIG13_SWEEPS", 100),
        sweeps_per_round: 10,
        ..RunConfig::default()
    };
    let threads: Vec<usize> = std::env::var("FIG13_THREADS")
        .unwrap_or_else(|_| "1,2,4,6,8".to_string())
        .split(',')
        .filter_map(|s| s.trim().parse().ok())
        .collect();
    let with_accel = vectorising::runtime::artifact::default_dir().join("manifest.json").exists();
    println!(
        "Fig 13 | {} models x {} spins x {} sweeps | threads {:?} | accel: {}",
        cfg.n_models,
        cfg.n_spins_per_model(),
        cfg.sweeps,
        threads,
        with_accel
    );
    let rows = fig13::compute(&cfg, &threads, with_accel).expect("fig13");
    print!("{}", fig13::render(&rows, Some(std::path::Path::new("results/fig13.csv"))).unwrap());
    println!("\npaper shape: A.2 ~3x over A.1, A.4 ~9-12x; B.2 ~6.8x over B.1; A.4 >= B.2");
}
