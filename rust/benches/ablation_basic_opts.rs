//! Ablation bench for the paper's §2 basic optimizations: each ingredient
//! of the A.1 -> A.2 jump toggled cumulatively and independently, timing
//! the same workload (the paper's narrative: branch elimination "large
//! impact", structure simplification "large impact", caching "slight but
//! noticeable", plus the exp approximation).

mod support;

use vectorising::ising::builder::torus_workload;
use vectorising::sweep::ablation::{BasicOptAblation, BasicOptFlags};
use vectorising::sweep::{ExpMode, Sweeper};

const SWEEPS: usize = 150;
const REPS: usize = 8;

fn main() {
    let beta = 0.8f32;
    println!("basic-optimization ablation, 64x32 model, {SWEEPS} sweeps/run, {REPS} runs\n");
    let updates = (SWEEPS * 2048) as f64;

    let cumulative = [
        BasicOptFlags::none(),
        BasicOptFlags { branch_free: true, ..BasicOptFlags::none() },
        BasicOptFlags { branch_free: true, flat_layout: true, exp: ExpMode::Exact, cache_two_smul: false },
        BasicOptFlags { branch_free: true, flat_layout: true, cache_two_smul: true, exp: ExpMode::Exact },
        BasicOptFlags::all(),
    ];
    let labels = ["A.1 baseline", "+ branch elimination (S2.1)", "+ flat tau-last layout (S2.2)",
                  "+ result caching (S2.3)", "+ fast exp = A.2 (S2.4)"];

    let mut baseline = None;
    for (flags, label) in cumulative.iter().zip(labels) {
        let wl = torus_workload(8, 8, 32, 1, 0.3);
        let mut sw = BasicOptAblation::new(&wl.model, &wl.s0, 5489, *flags);
        sw.run(20, beta);
        let secs = support::time_reps(1, REPS, || {
            sw.run(SWEEPS, beta);
        });
        let m = support::mean(&secs);
        let base = *baseline.get_or_insert(m);
        println!("{label:35} {:8.2} ns/update   {:5.2}x", m / updates * 1e9, base / m);
    }

    println!("\nindividual toggles (one at a time over A.1):");
    let singles = [
        BasicOptFlags { branch_free: true, ..BasicOptFlags::none() },
        BasicOptFlags { flat_layout: true, ..BasicOptFlags::none() },
        BasicOptFlags { cache_two_smul: true, branch_free: true, ..BasicOptFlags::none() },
        BasicOptFlags { exp: ExpMode::Fast, ..BasicOptFlags::none() },
        BasicOptFlags { exp: ExpMode::Accurate, ..BasicOptFlags::none() },
    ];
    for flags in singles {
        let wl = torus_workload(8, 8, 32, 1, 0.3);
        let mut sw = BasicOptAblation::new(&wl.model, &wl.s0, 5489, flags);
        sw.run(20, beta);
        let secs = support::time_reps(1, REPS, || {
            sw.run(SWEEPS, beta);
        });
        let m = support::mean(&secs);
        println!("{:35} {:8.2} ns/update   {:5.2}x", flags.label(), m / updates * 1e9, baseline.unwrap() / m);
    }
}
