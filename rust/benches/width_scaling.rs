//! Width scaling: the A.3/A.4 rungs at lane widths 4 (SSE2) and 8 (AVX2
//! when the host has it, portable lanes otherwise) on a paper-scale
//! workload — the vector-width axis the ISSUE-1 refactor opens.
//!
//! Reports spin-updates/sec per (rung, width) and the W=8-over-W=4
//! speedup.  On AVX2 hosts the W=8 rows should be at least as fast as
//! W=4 (wider registers, same instruction count per group); without AVX2
//! the portable fallback documents the cost of not having the backend.

mod support;

use vectorising::ising::builder::torus_workload;
use vectorising::simd::{avx2_available, widest_supported_width};
use vectorising::sweep::{try_make_sweeper, SweepKind, Sweeper};

const SWEEPS: usize = 40;
const REPS: usize = 8;

fn time_kind(kind: SweepKind, beta: f32) -> (Vec<f64>, f64) {
    // Paper geometry per model: 96 base spins x 256 layers = 24,576 spins
    // (256 is divisible by both widths with >= 2 layers per section).
    let wl = torus_workload(12, 8, 256, 1, 0.3);
    let updates = (SWEEPS * wl.model.n_spins()) as f64;
    let mut sw = try_make_sweeper(kind, &wl.model, &wl.s0, 5489).expect("cpu sweeper");
    sw.run(10, beta); // reach a representative flip regime
    let secs = support::time_reps(1, REPS, || {
        sw.run(SWEEPS, beta);
    });
    (secs, updates)
}

fn main() {
    let beta = 0.8f32;
    println!(
        "width scaling, 96x256 paper-scale model (24,576 spins), {SWEEPS} sweeps/run, {REPS} runs"
    );
    println!(
        "host: avx2={}  widest backend width={}\n",
        avx2_available(),
        widest_supported_width()
    );

    let mut means = std::collections::HashMap::new();
    for kind in [
        SweepKind::A3VecRng,
        SweepKind::A3VecRngW8,
        SweepKind::A4Full,
        SweepKind::A4FullW8,
    ] {
        let (secs, updates) = time_kind(kind, beta);
        let ns = support::mean(&secs) / updates * 1e9;
        support::report(
            &format!("{} w={} ({ns:.2} ns/update)", kind.label(), kind.group_width()),
            &secs,
            updates,
            "Mupd",
        );
        means.insert(kind.label(), support::mean(&secs));
    }

    let speedup = |w4: &str, w8: &str| means[w4] / means[w8];
    println!(
        "\nA.3: w8 over w4 speedup {:.2}x   A.4: w8 over w4 speedup {:.2}x{}",
        speedup("A.3", "A.3w8"),
        speedup("A.4", "A.4w8"),
        if avx2_available() { "" } else { "   (portable fallback — no AVX2 on this host)" }
    );
}
