//! Width scaling: the A.3/A.4 rungs at lane widths 4 (SSE2), 8 (AVX2
//! when the host has it, portable lanes otherwise) and 16 (AVX-512,
//! skipped gracefully without `avx512f`) on a paper-scale workload,
//! plus the M.1 multi-spin rung (64 bit-lanes across the layers on the
//! ±1-coupling analogue of the same geometry).
//!
//! Reports spin-updates/sec per (rung, width) and the W=8-over-W=4
//! speedup.  On AVX2 hosts the W=8 rows should be at least as fast as
//! W=4 (wider registers, same instruction count per group); without AVX2
//! the portable fallback documents the cost of not having the backend.
//!
//! Set `REPRO_BENCH_DIR` to also emit one machine-readable
//! `BENCH_<rung>.json` artifact per row (see `harness::bench`).

mod support;

use vectorising::coordinator::RunConfig;
use vectorising::engine::{EngineBuilder, Rung};
use vectorising::harness::bench::{self, BenchArtifact, HostCaps, BENCH_SCHEMA_VERSION};
use vectorising::ising::builder::{pm_torus_workload, torus_workload};
use vectorising::simd::{avx2_available, avx512_available, widest_supported_width};
use vectorising::sweep::{try_make_sweeper, SweepKind, Sweeper};

const SWEEPS: usize = 40;
const REPS: usize = 8;
const GEOM: (usize, usize, usize) = (12, 8, 256);

fn time_sweeper(mut sw: Box<dyn Sweeper + Send>, n_spins: usize, beta: f32) -> (Vec<f64>, f64) {
    let updates = (SWEEPS * n_spins) as f64;
    sw.run(10, beta); // reach a representative flip regime
    let secs = support::time_reps(1, REPS, || {
        sw.run(SWEEPS, beta);
    });
    (secs, updates)
}

fn time_kind(kind: SweepKind, beta: f32) -> (Vec<f64>, f64) {
    // Paper geometry per model: 96 base spins x 256 layers = 24,576 spins
    // (256 is divisible by both widths with >= 2 layers per section).
    let (w, h, l) = GEOM;
    let wl = torus_workload(w, h, l, 1, 0.3);
    let sw = try_make_sweeper(kind, &wl.model, &wl.s0, 5489).expect("cpu sweeper");
    time_sweeper(sw, wl.model.n_spins(), beta)
}

/// Engine-negotiated rows the legacy kind enum cannot spell (W=16, M.1).
fn time_spec(rung: Rung, width: usize, beta: f32) -> (Vec<f64>, f64) {
    let (w, h, l) = GEOM;
    let wl = if rung.is_multispin() {
        pm_torus_workload(w, h, l, 1, 0.5)
    } else {
        torus_workload(w, h, l, 1, 0.3)
    };
    let sw = EngineBuilder::new(rung.spec().w(width))
        .build(&wl.model, &wl.s0, 5489)
        .expect("engine sweeper")
        .into_sweeper();
    time_sweeper(sw, wl.model.n_spins(), beta)
}

/// Emit the machine-readable artifact for one row when REPRO_BENCH_DIR
/// is set (the bench-side producer of the BENCH_<rung>.json trajectory).
fn emit(label: &str, rung: Rung, lane_width: usize, secs: &[f64], updates: f64) {
    let Ok(dir) = std::env::var("REPRO_BENCH_DIR") else { return };
    let (w, h, l) = GEOM;
    let cfg = RunConfig { width: w, height: h, layers: l, n_models: 1, ..RunConfig::default() };
    let art = BenchArtifact {
        schema: BENCH_SCHEMA_VERSION,
        rung: label.to_string(),
        threads: 1,
        sweeps: SWEEPS,
        seconds: support::mean(secs),
        spins_per_sec: updates / support::mean(secs),
        lane_width,
        lane_fill: bench::lane_fill(rung, lane_width, &cfg),
        torus_width: w,
        torus_height: h,
        layers: l,
        n_models: 1,
        host: HostCaps::detect(),
        git_sha: bench::git_sha(),
        provenance: "measured".into(),
    };
    match art.write_to(std::path::Path::new(&dir)) {
        Ok(path) => println!("  -> wrote {}", path.display()),
        Err(e) => eprintln!("  -> artifact write failed: {e:#}"),
    }
}

fn main() {
    let beta = 0.8f32;
    println!(
        "width scaling, 96x256 paper-scale model (24,576 spins), {SWEEPS} sweeps/run, {REPS} runs"
    );
    println!(
        "host: avx2={}  avx512={}  widest backend width={}\n",
        avx2_available(),
        avx512_available(),
        widest_supported_width()
    );

    let mut means = std::collections::HashMap::new();
    for kind in [
        SweepKind::A3VecRng,
        SweepKind::A3VecRngW8,
        SweepKind::A4Full,
        SweepKind::A4FullW8,
    ] {
        let (secs, updates) = time_kind(kind, beta);
        let ns = support::mean(&secs) / updates * 1e9;
        support::report(
            &format!("{} w={} ({ns:.2} ns/update)", kind.label(), kind.group_width()),
            &secs,
            updates,
            "Mupd",
        );
        let rung = if matches!(kind, SweepKind::A3VecRng | SweepKind::A3VecRngW8) {
            Rung::A3
        } else {
            Rung::A4
        };
        emit(kind.label(), rung, kind.group_width(), &secs, updates);
        means.insert(kind.label().to_string(), support::mean(&secs));
    }

    // W=16: AVX-512 rows when the host + toolchain provide them.
    if avx512_available() {
        for (rung, label) in [(Rung::A3, "A.3w16"), (Rung::A4, "A.4w16")] {
            let (secs, updates) = time_spec(rung, 16, beta);
            let ns = support::mean(&secs) / updates * 1e9;
            support::report(&format!("{label} w=16 ({ns:.2} ns/update)"), &secs, updates, "Mupd");
            emit(label, rung, 16, &secs, updates);
            means.insert(label.to_string(), support::mean(&secs));
        }
    } else {
        println!("{:38} (skipped: no avx512f on this host)", "A.3w16 / A.4w16");
    }

    // M.1: 64 bit-lanes across the layers, ±1 couplings, bin thresholds.
    let (secs, updates) = time_spec(Rung::M1, 64, beta);
    let ns = support::mean(&secs) / updates * 1e9;
    support::report(&format!("M.1 w=64 ({ns:.2} ns/update)"), &secs, updates, "Mupd");
    emit("M.1", Rung::M1, 64, &secs, updates);
    means.insert("M.1".to_string(), support::mean(&secs));

    let speedup = |w4: &str, w8: &str| means[w4] / means[w8];
    println!(
        "\nA.3: w8 over w4 speedup {:.2}x   A.4: w8 over w4 speedup {:.2}x{}",
        speedup("A.3", "A.3w8"),
        speedup("A.4", "A.4w8"),
        if avx2_available() { "" } else { "   (portable fallback — no AVX2 on this host)" }
    );
    println!("M.1 over A.4w8: {:.2}x spins/sec", speedup("A.4w8", "M.1"));
}
