//! The software device — the reproduction of the paper's GPU half.
//!
//! The paper's §3.2 compares two CUDA kernels that differ *only* in
//! global-memory layout: B.1 transplants the CPU data structure (slow,
//! gathered access) and B.2 reorganizes it so warp accesses coalesce
//! ("this reorganization of memory was the only difference between the
//! two GPU versions").  Without CUDA hardware in the loop, this module
//! executes that comparison on the CPU under a faithful execution model
//! instead of an opaque artifact:
//!
//! * [`grid`] — the launch hierarchy: a [`DeviceGrid`] of 256-thread
//!   blocks, each running 32-lane warps in SIMT lockstep, one thread per
//!   spin in A.2's layer-major order;
//! * [`layout`] — the two §3.2 memory organizations over the same
//!   logical state: [`DeviceLayout::B1Naive`] (AoS records behind an
//!   index-table gather) and [`DeviceLayout::B2Coalesced`] (SoA streams
//!   staged through the block's shared tile);
//! * [`memory`] — the transaction model that makes coalescing a
//!   *measured observable*: contiguous warp accesses cost one
//!   transaction per 128-byte segment, gathers/scatters serialize per
//!   lane ([`DeviceStats::coalescing_efficiency`] is the device-side
//!   analogue of the CPU rungs' lane-fill metric);
//! * [`sweeper`] — the kernel itself: [`DeviceSweeper`] maps warps onto
//!   the host [`crate::simd`] backends (B.2's candidate pass runs
//!   `exp_fast_wide` on real vector units; B.1's gathered records force
//!   per-lane evaluation) with serialized in-warp conflict replay, so
//!   both rungs are bit-exact to scalar A.2 for the same seed.
//!
//! `EngineBuilder` negotiates `backend: accel` onto this device (see
//! `engine::builder`); the PJRT path in [`crate::sweep::accel`] remains
//! for running real compiled artifacts when a `runtime::Runtime` is
//! provided explicitly.

pub mod grid;
pub mod layout;
pub mod memory;
pub mod sweeper;

pub use grid::{BlockSpan, DeviceGrid, WarpSpan, BLOCK_THREADS, WARP_WIDTH};
pub use layout::{DeviceLayout, GlobalMemory};
pub use memory::{DeviceStats, SEGMENT_BYTES};
pub use sweeper::DeviceSweeper;

use std::sync::atomic::{AtomicU64, Ordering};

static COALESCED_TOTAL: AtomicU64 = AtomicU64::new(0);
static STRIDED_TOTAL: AtomicU64 = AtomicU64::new(0);
static REPLAYS_TOTAL: AtomicU64 = AtomicU64::new(0);

/// Add a per-run counter delta to the process-wide totals (called by
/// [`DeviceSweeper`] at the end of every `run`).  The totals feed the
/// `repro_device_transactions_total{kind}` Prometheus family.
pub fn flush_global(delta: &DeviceStats) {
    COALESCED_TOTAL.fetch_add(delta.coalesced, Ordering::Relaxed);
    STRIDED_TOTAL.fetch_add(delta.strided, Ordering::Relaxed);
    REPLAYS_TOTAL.fetch_add(delta.divergent_replays, Ordering::Relaxed);
}

/// Process-wide `(coalesced, strided, divergent_replays)` totals across
/// every device sweeper that has run in this process.
pub fn global_totals() -> (u64, u64, u64) {
    (
        COALESCED_TOTAL.load(Ordering::Relaxed),
        STRIDED_TOTAL.load(Ordering::Relaxed),
        REPLAYS_TOTAL.load(Ordering::Relaxed),
    )
}
