//! The two global-memory layouts of the paper's §3.2 GPU comparison.
//!
//! Per the paper, the *only* difference between the B.1 and B.2 kernels
//! is how the spin/field state is organized in global memory:
//!
//! * [`DeviceLayout::B1Naive`] — the CPU data structure transplanted
//!   verbatim: one 16-byte per-spin record `{s, h_space, h_tau, pad}`
//!   (array-of-structs), reached through an index table the way the
//!   naive kernel dereferences its neighbour lists.  A warp touching 32
//!   records gathers 32 disjoint 16-byte chunks — every access
//!   serializes into per-lane transactions, and there is no
//!   shared-memory staging.
//! * [`DeviceLayout::B2Coalesced`] — the reorganized version: separate
//!   contiguous `s` / `h_space` / `h_tau` arrays (struct-of-arrays), so
//!   a warp's 32 lanes read 32 adjacent words in one coalesced
//!   transaction per 128-byte segment, staged once into the block's
//!   shared tile and then fed to the vector units.
//!
//! Both layouts store bit-identical f32 values in the same logical
//! (layer-major) index space; only addressing differs, which is the
//! invariant the differential tests pin.

use super::grid::WarpSpan;
use super::memory::DeviceStats;

/// Words per B.1 record: `{s, h_space, h_tau, pad}`.
pub const RECORD_WORDS: usize = 4;

/// Which of the paper's two GPU memory organizations a device run uses.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum DeviceLayout {
    /// Array-of-structs records behind an index-table gather (B.1).
    B1Naive,
    /// Struct-of-arrays contiguous streams (B.2).
    B2Coalesced,
}

impl DeviceLayout {
    pub fn label(&self) -> &'static str {
        match self {
            DeviceLayout::B1Naive => "naive (AoS records + index gather)",
            DeviceLayout::B2Coalesced => "coalesced (SoA streams + shared tile)",
        }
    }
}

/// The device's global memory holding spins and effective fields in one
/// of the two layouts.  All indices are logical layer-major spin ids;
/// the layout decides the physical address and the transaction cost.
pub enum GlobalMemory {
    Naive {
        /// `RECORD_WORDS` f32 words per spin.
        records: Vec<f32>,
        /// Index table `spin id -> record id` (identity here, but the
        /// kernel still loads it and gathers through it, exactly like
        /// the naive port's neighbour-table indirection).
        index: Vec<u32>,
    },
    Coalesced {
        s: Vec<f32>,
        h_space: Vec<f32>,
        h_tau: Vec<f32>,
    },
}

impl GlobalMemory {
    /// Upload `s0` and its effective fields into a fresh device
    /// allocation in the given layout.
    pub fn build(layout: DeviceLayout, s0: &[f32], hs: Vec<f32>, ht: Vec<f32>) -> GlobalMemory {
        let n = s0.len();
        debug_assert_eq!(hs.len(), n);
        debug_assert_eq!(ht.len(), n);
        match layout {
            DeviceLayout::B1Naive => {
                let mut records = vec![0f32; n * RECORD_WORDS];
                for i in 0..n {
                    let r = i * RECORD_WORDS;
                    records[r] = s0[i];
                    records[r + 1] = hs[i];
                    records[r + 2] = ht[i];
                }
                let index = (0..n as u32).collect();
                GlobalMemory::Naive { records, index }
            }
            DeviceLayout::B2Coalesced => GlobalMemory::Coalesced {
                s: s0.to_vec(),
                h_space: hs,
                h_tau: ht,
            },
        }
    }

    pub fn layout(&self) -> DeviceLayout {
        match self {
            GlobalMemory::Naive { .. } => DeviceLayout::B1Naive,
            GlobalMemory::Coalesced { .. } => DeviceLayout::B2Coalesced,
        }
    }

    pub fn n_spins(&self) -> usize {
        match self {
            GlobalMemory::Naive { index, .. } => index.len(),
            GlobalMemory::Coalesced { s, .. } => s.len(),
        }
    }

    /// Uncounted register/tile-resident read of a spin (the lane already
    /// holds it from its candidate fetch).
    #[inline]
    pub fn s_raw(&self, i: usize) -> f32 {
        match self {
            GlobalMemory::Naive { records, index } => {
                records[index[i] as usize * RECORD_WORDS]
            }
            GlobalMemory::Coalesced { s, .. } => s[i],
        }
    }

    /// Uncounted read of a spin's effective-field sum, in A.2's
    /// `h_space[i] + h_tau[i]` evaluation order.
    #[inline]
    pub fn hsum_raw(&self, i: usize) -> f32 {
        match self {
            GlobalMemory::Naive { records, index } => {
                let r = index[i] as usize * RECORD_WORDS;
                records[r + 1] + records[r + 2]
            }
            GlobalMemory::Coalesced { s: _, h_space, h_tau } => h_space[i] + h_tau[i],
        }
    }

    /// Per-lane gather of one spin's record from global memory — the
    /// B.1 candidate path and both layouts' divergent replays.  Always
    /// a serialized transaction.
    #[inline]
    pub fn gather_spin(&self, i: usize, dev: &mut DeviceStats) -> (f32, f32) {
        dev.strided_access(1);
        (self.s_raw(i), self.hsum_raw(i))
    }

    /// Model the B.1 kernel's coalesced read of a warp's index-table row
    /// (the one access the naive port *does* get to coalesce).
    #[inline]
    pub fn read_index_row(&self, warp: WarpSpan, dev: &mut DeviceStats) {
        if let GlobalMemory::Naive { .. } = self {
            dev.coalesced_access(warp.start as u64 * 4, warp.lanes as u64 * 4);
        }
    }

    /// Stage a warp's spins and field sums into the block's shared tile
    /// (B.2 only — the naive kernel never copies to shared memory).
    /// Counts one coalesced stream per global array plus the shared
    /// stores that fill the tile.
    pub fn stage_warp(
        &self,
        warp: WarpSpan,
        s_tile: &mut [f32],
        hsum_tile: &mut [f32],
        dev: &mut DeviceStats,
    ) {
        let (start, w) = (warp.start, warp.lanes);
        match self {
            GlobalMemory::Coalesced { s, h_space, h_tau } => {
                s_tile[..w].copy_from_slice(&s[start..start + w]);
                for k in 0..w {
                    hsum_tile[k] = h_space[start + k] + h_tau[start + k];
                }
                let (off, len) = (start as u64 * 4, w as u64 * 4);
                dev.coalesced_access(off, len); // s stream
                dev.coalesced_access(off, len); // h_space stream
                dev.coalesced_access(off, len); // h_tau stream
                dev.shared_stores += 2 * w as u64; // s tile + hsum tile
            }
            GlobalMemory::Naive { .. } => {
                unreachable!("the naive kernel has no shared-memory staging")
            }
        }
    }

    /// Negate a spin after an accepted flip.  B.1 writes its record
    /// per-thread (serialized); B.2 defers to the warp's single
    /// coalesced write-back ([`GlobalMemory::write_back_s`]).
    #[inline]
    pub fn flip_s(&mut self, i: usize, dev: &mut DeviceStats) {
        match self {
            GlobalMemory::Naive { records, index } => {
                let r = index[i] as usize * RECORD_WORDS;
                records[r] = -records[r];
                dev.strided_access(1);
            }
            GlobalMemory::Coalesced { s, .. } => s[i] = -s[i],
        }
    }

    /// B.2's once-per-warp coalesced store of the (possibly flipped)
    /// spin lane values back to the `s` stream.
    #[inline]
    pub fn write_back_s(&self, warp: WarpSpan, dev: &mut DeviceStats) {
        if let GlobalMemory::Coalesced { .. } = self {
            dev.coalesced_access(warp.start as u64 * 4, warp.lanes as u64 * 4);
            dev.shared_loads += warp.lanes as u64;
        }
    }

    /// Scatter-subtract into a neighbour's spatial field.  Random single
    /// -word RMW traffic — serialized in both layouts (the coalescing
    /// axis is the streaming access, not the neighbour scatter).
    #[inline]
    pub fn sub_h_space(&mut self, i: usize, v: f32, dev: &mut DeviceStats) {
        dev.strided_access(1);
        match self {
            GlobalMemory::Naive { records, index } => {
                let r = index[i] as usize * RECORD_WORDS;
                records[r + 1] -= v;
            }
            GlobalMemory::Coalesced { h_space, .. } => h_space[i] -= v,
        }
    }

    /// Scatter-subtract into a neighbour's imaginary-time field.
    #[inline]
    pub fn sub_h_tau(&mut self, i: usize, v: f32, dev: &mut DeviceStats) {
        dev.strided_access(1);
        match self {
            GlobalMemory::Naive { records, index } => {
                let r = index[i] as usize * RECORD_WORDS;
                records[r + 2] -= v;
            }
            GlobalMemory::Coalesced { h_tau, .. } => h_tau[i] -= v,
        }
    }

    /// Download the spin state back to host (layer-major) order.
    pub fn state_vec(&self) -> Vec<f32> {
        let n = self.n_spins();
        (0..n).map(|i| self.s_raw(i)).collect()
    }

    /// Download both effective-field arrays (for `validate`).
    pub fn field_vecs(&self) -> (Vec<f32>, Vec<f32>) {
        let n = self.n_spins();
        match self {
            GlobalMemory::Naive { records, index } => {
                let mut hs = Vec::with_capacity(n);
                let mut ht = Vec::with_capacity(n);
                for i in 0..n {
                    let r = index[i] as usize * RECORD_WORDS;
                    hs.push(records[r + 1]);
                    ht.push(records[r + 2]);
                }
                (hs, ht)
            }
            GlobalMemory::Coalesced { s: _, h_space, h_tau } => {
                (h_space.clone(), h_tau.clone())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo() -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let s: Vec<f32> = (0..40).map(|i| if i % 3 == 0 { 1.0 } else { -1.0 }).collect();
        let hs: Vec<f32> = (0..40).map(|i| i as f32 * 0.25).collect();
        let ht: Vec<f32> = (0..40).map(|i| 1.0 - i as f32 * 0.125).collect();
        (s, hs, ht)
    }

    #[test]
    fn both_layouts_store_identical_logical_values() {
        let (s, hs, ht) = demo();
        let b1 = GlobalMemory::build(DeviceLayout::B1Naive, &s, hs.clone(), ht.clone());
        let b2 = GlobalMemory::build(DeviceLayout::B2Coalesced, &s, hs, ht);
        for i in 0..s.len() {
            assert_eq!(b1.s_raw(i).to_bits(), b2.s_raw(i).to_bits());
            assert_eq!(b1.hsum_raw(i).to_bits(), b2.hsum_raw(i).to_bits());
        }
        assert_eq!(b1.state_vec(), b2.state_vec());
    }

    #[test]
    fn mutation_paths_agree_across_layouts() {
        let (s, hs, ht) = demo();
        let mut b1 = GlobalMemory::build(DeviceLayout::B1Naive, &s, hs.clone(), ht.clone());
        let mut b2 = GlobalMemory::build(DeviceLayout::B2Coalesced, &s, hs, ht);
        let mut d1 = DeviceStats::default();
        let mut d2 = DeviceStats::default();
        b1.flip_s(7, &mut d1);
        b2.flip_s(7, &mut d2);
        b1.sub_h_space(3, 0.5, &mut d1);
        b2.sub_h_space(3, 0.5, &mut d2);
        b1.sub_h_tau(11, -2.0, &mut d1);
        b2.sub_h_tau(11, -2.0, &mut d2);
        assert_eq!(b1.state_vec(), b2.state_vec());
        assert_eq!(b1.field_vecs(), b2.field_vecs());
        // B.1 pays a serialized transaction for the record flip; B.2's
        // flip rides the warp write-back instead.
        assert_eq!(d1.strided, 3);
        assert_eq!(d2.strided, 2);
    }

    #[test]
    fn staging_counts_coalesced_segments() {
        let (s, hs, ht) = demo();
        let b2 = GlobalMemory::build(DeviceLayout::B2Coalesced, &s, hs, ht);
        let warp = WarpSpan { start: 0, lanes: 32 };
        let mut tile_s = [0f32; 32];
        let mut tile_h = [0f32; 32];
        let mut dev = DeviceStats::default();
        b2.stage_warp(warp, &mut tile_s, &mut tile_h, &mut dev);
        // 32 aligned f32 lanes per stream = exactly 1 segment each.
        assert_eq!(dev.coalesced, 3);
        assert_eq!(dev.strided, 0);
        assert_eq!(dev.shared_stores, 64);
        assert_eq!(tile_s[5], b2.s_raw(5));
        assert_eq!(tile_h[5], b2.hsum_raw(5));
    }
}
