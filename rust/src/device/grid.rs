//! Grid/block/warp decomposition of the lattice.
//!
//! The software device mirrors the CUDA execution hierarchy: a kernel
//! launch covers the whole lattice with a **grid** of equally sized
//! **blocks**; each block executes its threads in **warps** of 32 in
//! SIMT lockstep and owns a shared-memory staging tile
//! ([`crate::device::sweeper`] reuses one tile warp-by-warp).  One
//! device thread owns one spin, in the same flat layer-major order the
//! scalar A.2 reference walks — the decomposition changes *where* the
//! data lives and *how* it is fetched, never the visit order, which is
//! what keeps B.1/B.2 bit-exact to A.2.

/// Threads per warp — the SIMT lockstep width (fixed by the model; the
/// host SIMD backend tiles it in 4/8/16-lane chunks).
pub const WARP_WIDTH: usize = 32;

/// Threads per block (8 warps), the shared-memory cooperation domain.
pub const BLOCK_THREADS: usize = 256;

/// A kernel-launch geometry over `n_threads` spins.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct DeviceGrid {
    /// Total threads = total spins.
    pub n_threads: usize,
    /// Threads per block.
    pub block_threads: usize,
    /// Blocks in the grid (last one may be partial).
    pub n_blocks: usize,
}

impl DeviceGrid {
    /// Launch geometry covering `n_threads` spins with [`BLOCK_THREADS`]
    /// threads per block.
    pub fn over(n_threads: usize) -> DeviceGrid {
        let block_threads = BLOCK_THREADS;
        let n_blocks = n_threads.div_ceil(block_threads).max(1);
        DeviceGrid { n_threads, block_threads, n_blocks }
    }

    /// Warps in a full block.
    pub fn warps_per_block(&self) -> usize {
        self.block_threads.div_ceil(WARP_WIDTH)
    }

    /// Total warps launched (partial trailing warp included).
    pub fn n_warps(&self) -> usize {
        self.n_threads.div_ceil(WARP_WIDTH)
    }

    /// Iterate the grid's blocks in launch order.
    pub fn blocks(&self) -> impl Iterator<Item = BlockSpan> + '_ {
        let g = *self;
        (0..g.n_blocks).map(move |b| {
            let start = b * g.block_threads;
            let len = g.block_threads.min(g.n_threads - start);
            BlockSpan { index: b, start, len }
        })
    }

    /// CUDA-style launch summary, used in plan notes and `repro plan`.
    pub fn describe(&self) -> String {
        format!(
            "grid<<<{}, {}>>> ({} warps of {})",
            self.n_blocks,
            self.block_threads,
            self.n_warps(),
            WARP_WIDTH
        )
    }
}

/// One block's slice of the thread range.
#[derive(Copy, Clone, Debug)]
pub struct BlockSpan {
    pub index: usize,
    pub start: usize,
    pub len: usize,
}

impl BlockSpan {
    /// The block's warps in order; the last may be partial.
    pub fn warps(&self) -> impl Iterator<Item = WarpSpan> + '_ {
        let (start, len) = (self.start, self.len);
        (0..len.div_ceil(WARP_WIDTH)).map(move |w| {
            let off = w * WARP_WIDTH;
            WarpSpan {
                start: start + off,
                lanes: WARP_WIDTH.min(len - off),
            }
        })
    }
}

/// One warp's contiguous lane→spin assignment.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct WarpSpan {
    /// First spin index owned by lane 0.
    pub start: usize,
    /// Active lanes (≤ 32; < 32 only for the grid's trailing warp).
    pub lanes: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_partitions_every_thread_exactly_once() {
        for n in [1usize, 31, 32, 33, 255, 256, 257, 1024, 4096, 5000] {
            let g = DeviceGrid::over(n);
            let mut next = 0usize;
            let mut warps = 0usize;
            for b in g.blocks() {
                assert_eq!(b.start, next);
                for w in b.warps() {
                    assert_eq!(w.start, next);
                    assert!(w.lanes >= 1 && w.lanes <= WARP_WIDTH);
                    next += w.lanes;
                    warps += 1;
                }
            }
            assert_eq!(next, n, "n={n}");
            assert_eq!(warps, g.n_warps(), "n={n}");
        }
    }

    #[test]
    fn describe_is_cuda_flavoured() {
        let g = DeviceGrid::over(4096);
        assert_eq!(g.n_blocks, 16);
        assert_eq!(g.describe(), "grid<<<16, 256>>> (128 warps of 32)");
    }
}
