//! The device's global-memory access model: coalesced vs strided
//! transaction accounting, the GPU half of the paper's central
//! observable.
//!
//! The CPU side reports "fraction of vector width utilized" (lane fill);
//! the device side's equivalent is **coalescing efficiency** — the
//! fraction of global-memory transactions that service a whole warp at
//! once.  The model follows the classic (compute-1.x) coalescing rules
//! the paper's 2010-era GPUs enforced:
//!
//! * a warp's access to a **contiguous, in-order** range is serviced in
//!   segment-sized transactions ([`SEGMENT_BYTES`] = 128): one
//!   transaction per 128-byte segment the range touches;
//! * any **non-contiguous** (gathered/scattered) warp access serializes
//!   into one transaction *per lane* — the 16× traffic blow-up that makes
//!   the paper's B.1 layout slow.
//!
//! Counters accumulate into [`DeviceStats`], surfaced per-sweeper through
//! `Sweeper::device_stats` and process-wide through
//! [`crate::device::global_totals`] (the Prometheus
//! `repro_device_transactions_total{kind}` family).

/// Global-memory transaction segment size in bytes (the compute-1.x
/// coalescing granularity for 4-byte words).
pub const SEGMENT_BYTES: u64 = 128;

/// Execution counters of the software device, accumulated across
/// [`crate::device::DeviceSweeper`] runs.
#[derive(Copy, Clone, Debug, Default, PartialEq, Eq)]
pub struct DeviceStats {
    /// Whole-warp (contiguous) global-memory transactions.
    pub coalesced: u64,
    /// Serialized per-lane (gathered/scattered) global-memory transactions.
    pub strided: u64,
    /// Reads from the per-block shared-memory staging tile.
    pub shared_loads: u64,
    /// Writes into the per-block shared-memory staging tile.
    pub shared_stores: u64,
    /// Lanes whose flip decision had to be replayed serially because an
    /// earlier lane's flip in the same warp dirtied their effective
    /// field — the SIMT-divergence cost of intra-warp conflicts (the
    /// device-side analogue of the paper's Fig-14 "must wait for a
    /// flip" event).
    pub divergent_replays: u64,
    /// Warps executed.
    pub warps: u64,
}

impl DeviceStats {
    /// Total global-memory transactions issued.
    pub fn transactions(&self) -> u64 {
        self.coalesced + self.strided
    }

    /// Fraction of global-memory transactions that were coalesced —
    /// the measured observable the B.1 → B.2 comparison is about
    /// (1.0 on an empty device: no traffic means nothing was wasted).
    pub fn coalescing_efficiency(&self) -> f64 {
        let t = self.transactions();
        if t == 0 {
            1.0
        } else {
            self.coalesced as f64 / t as f64
        }
    }

    pub fn merge(&mut self, o: &DeviceStats) {
        self.coalesced += o.coalesced;
        self.strided += o.strided;
        self.shared_loads += o.shared_loads;
        self.shared_stores += o.shared_stores;
        self.divergent_replays += o.divergent_replays;
        self.warps += o.warps;
    }

    /// The per-field difference `self - earlier` (both cumulative
    /// snapshots of the same counter set).
    pub fn delta_since(&self, earlier: &DeviceStats) -> DeviceStats {
        DeviceStats {
            coalesced: self.coalesced - earlier.coalesced,
            strided: self.strided - earlier.strided,
            shared_loads: self.shared_loads - earlier.shared_loads,
            shared_stores: self.shared_stores - earlier.shared_stores,
            divergent_replays: self.divergent_replays - earlier.divergent_replays,
            warps: self.warps - earlier.warps,
        }
    }

    /// Record a contiguous in-order warp access of `byte_len` bytes at
    /// `byte_off`: one transaction per 128-byte segment touched.
    #[inline]
    pub fn coalesced_access(&mut self, byte_off: u64, byte_len: u64) {
        if byte_len == 0 {
            return;
        }
        let first = byte_off / SEGMENT_BYTES;
        let last = (byte_off + byte_len - 1) / SEGMENT_BYTES;
        self.coalesced += last - first + 1;
    }

    /// Record a gathered/scattered warp access: the hardware serializes
    /// it into one transaction per participating lane.
    #[inline]
    pub fn strided_access(&mut self, lanes: u64) {
        self.strided += lanes;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coalesced_access_counts_segments_touched() {
        let mut d = DeviceStats::default();
        // 32 f32 lanes = 128 bytes, segment-aligned: exactly 1 transaction.
        d.coalesced_access(0, 128);
        assert_eq!(d.coalesced, 1);
        // Misaligned by one word: spans 2 segments.
        d.coalesced_access(4, 128);
        assert_eq!(d.coalesced, 3);
        // A short (partial-warp) access still costs a full segment.
        d.coalesced_access(256, 16);
        assert_eq!(d.coalesced, 4);
        // Zero-length access is free.
        d.coalesced_access(0, 0);
        assert_eq!(d.coalesced, 4);
    }

    #[test]
    fn strided_access_serializes_per_lane() {
        let mut d = DeviceStats::default();
        d.strided_access(32);
        d.strided_access(7);
        assert_eq!(d.strided, 39);
        assert_eq!(d.transactions(), 39);
        assert!(d.coalescing_efficiency() < 1e-12);
    }

    #[test]
    fn efficiency_is_coalesced_fraction() {
        let mut d = DeviceStats::default();
        assert_eq!(d.coalescing_efficiency(), 1.0, "no traffic, nothing wasted");
        d.coalesced_access(0, 128);
        d.strided_access(3);
        assert!((d.coalescing_efficiency() - 0.25).abs() < 1e-12);
        let snap = d;
        d.coalesced_access(0, 128);
        let delta = d.delta_since(&snap);
        assert_eq!(delta.coalesced, 1);
        assert_eq!(delta.strided, 0);
    }
}
