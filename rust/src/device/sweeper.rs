//! The device kernel: Metropolis sweeps executed under the SIMT model.
//!
//! One launch per sweep.  Each warp owns 32 consecutive spins of the
//! flat layer-major state and runs a two-phase body:
//!
//! 1. **candidate phase** — every lane draws its uniform (one shared
//!    host-order MT19937 stream, drawn in lane order, so the trajectory
//!    is A.2's), fetches its spin + effective-field sum (B.1: per-lane
//!    record gather; B.2: coalesced stream staged into the block's
//!    shared tile), and evaluates the flip probability.  B.2's fast-exp
//!    candidates run on the host vector units via [`exp_fast_wide`];
//!    B.1's gathered records cannot feed contiguous vector loads, so its
//!    lanes evaluate serially — the same scalar-vs-vector gap the paper
//!    measures between the two kernels.
//! 2. **commit phase** — lanes retire in order.  A lane whose effective
//!    field was dirtied by an earlier lane's flip in the same warp takes
//!    the divergent path: it replays its decision against the updated
//!    field with the *same* uniform (counted in
//!    [`DeviceStats::divergent_replays`]).  This serial conflict
//!    resolution is exactly the scalar A.2 update order, which is what
//!    makes both layouts bit-exact to the reference.
//!
//! The kernel never reorders visits; B.1 vs B.2 differ only in memory
//! addressing — the paper's "this reorganization of memory was the only
//! difference between the two GPU versions".

use std::marker::PhantomData;

use crate::expapprox::simd::exp_fast_wide;
use crate::ising::layout::CsrLayout;
use crate::ising::QmcModel;
use crate::rng::Mt19937;
use crate::simd::{SimdF32, SimdU32};
use crate::sweep::{ExpMode, SweepKind, SweepStats, Sweeper};

use super::grid::{DeviceGrid, WARP_WIDTH};
use super::layout::{DeviceLayout, GlobalMemory};
use super::memory::DeviceStats;

/// The software device executing the B.1/B.2 accelerator rungs, generic
/// over the host SIMD backend `U` that stands in for the vector ALUs
/// (the warp's 32 lanes are tiled in `U::F::LANES`-wide chunks).
pub struct DeviceSweeper<U: SimdU32> {
    kind: SweepKind,
    model: QmcModel,
    lay: CsrLayout,
    grid: DeviceGrid,
    mem: GlobalMemory,
    rng: Mt19937,
    exp: ExpMode,
    /// Cumulative device counters since construction.
    dev: DeviceStats,
    /// Portion of `dev` already flushed to the process-wide totals.
    flushed: DeviceStats,
    /// Per-spin warp-stamp: `dirty[i] == stamp` marks lane conflicts
    /// within the currently executing warp.
    dirty: Vec<u64>,
    stamp: u64,
    _backend: PhantomData<U>,
}

impl<U: SimdU32> DeviceSweeper<U> {
    pub fn new(
        kind: SweepKind,
        model: &QmcModel,
        s0: &[f32],
        seed: u32,
        exp: ExpMode,
    ) -> crate::Result<Self> {
        let layout = match kind {
            SweepKind::B1Accel => DeviceLayout::B1Naive,
            SweepKind::B2Accel => DeviceLayout::B2Coalesced,
            other => anyhow::bail!("DeviceSweeper runs the accelerator rungs, not {other:?}"),
        };
        anyhow::ensure!(
            s0.len() == model.n_spins(),
            "initial state has {} spins, model has {}",
            s0.len(),
            model.n_spins()
        );
        anyhow::ensure!(
            kind.supports_layers(model.n_layers),
            "{} does not support {} layers (resolve the spec through \
             EngineBuilder for a structured geometry error)",
            kind.label(),
            model.n_layers
        );
        let lay = CsrLayout::build(model);
        let (hs, ht) = model.effective_fields(s0);
        Ok(Self {
            kind,
            model: model.clone(),
            lay,
            grid: DeviceGrid::over(s0.len()),
            mem: GlobalMemory::build(layout, s0, hs, ht),
            rng: Mt19937::new(seed),
            exp,
            dev: DeviceStats::default(),
            flushed: DeviceStats::default(),
            dirty: vec![0u64; s0.len()],
            stamp: 0,
            _backend: PhantomData,
        })
    }

    /// The launch geometry this sweeper runs with.
    pub fn grid(&self) -> DeviceGrid {
        self.grid
    }

    /// Which of the paper's memory layouts the device state uses.
    pub fn layout(&self) -> DeviceLayout {
        self.mem.layout()
    }

    /// Cumulative device counters since construction.
    pub fn stats(&self) -> DeviceStats {
        self.dev
    }

    /// B.2's vectorized candidate pass: `U::F::LANES` flip probabilities
    /// per step over the staged warp tile.  Lane-exact to the scalar
    /// `ExpMode::Fast` evaluation (`exp_fast_wide` is bit-identical to
    /// `exp_fast` per lane), so vectorization never changes trajectories.
    #[inline(always)]
    fn candidate_vector(
        neg_beta: f32,
        s_tile: &[f32; WARP_WIDTH],
        hsum_tile: &[f32; WARP_WIDTH],
        u_tile: &[f32; WARP_WIDTH],
    ) -> u32 {
        let mut bits = 0u32;
        let mut off = 0usize;
        while off < WARP_WIDTH {
            let s = <U::F as SimdF32>::load(&s_tile[off..]);
            let h = <U::F as SimdF32>::load(&hsum_tile[off..]);
            let de = <U::F as SimdF32>::splat(2.0) * s * h;
            let arg = (<U::F as SimdF32>::splat(neg_beta) * de)
                .max(<U::F as SimdF32>::splat(-80.0));
            let p = exp_fast_wide(arg);
            let u = <U::F as SimdF32>::load(&u_tile[off..]);
            bits |= u.lt(p).movemask() << off;
            off += <U::F as SimdF32>::LANES;
        }
        bits
    }

    fn sweep_once(&mut self, beta: f32, stats: &mut SweepStats) {
        let neg_beta = -beta;
        // The block's shared-memory staging tile, reused warp by warp.
        let mut s_tile = [0f32; WARP_WIDTH];
        let mut hsum_tile = [0f32; WARP_WIDTH];
        let mut u_tile = [0f32; WARP_WIDTH];
        let layout = self.mem.layout();
        for block in self.grid.blocks() {
            for warp in block.warps() {
                self.stamp += 1;
                let (start, w) = (warp.start, warp.lanes);

                // One uniform per lane, drawn in lane (= A.2 visit) order.
                for u in u_tile.iter_mut().take(w) {
                    *u = self.rng.next_f32();
                }

                // Candidate phase.
                let accept_bits = match layout {
                    DeviceLayout::B2Coalesced => {
                        self.mem.stage_warp(warp, &mut s_tile, &mut hsum_tile, &mut self.dev);
                        self.dev.shared_loads += 2 * w as u64;
                        if self.exp == ExpMode::Fast {
                            Self::candidate_vector(neg_beta, &s_tile, &hsum_tile, &u_tile)
                        } else {
                            // Exact/Accurate modes evaluate per lane (the
                            // test-alignment modes, not the benchmarked path).
                            let mut bits = 0u32;
                            for k in 0..w {
                                let de = 2.0 * s_tile[k] * hsum_tile[k];
                                if u_tile[k] < self.exp.eval(neg_beta * de) {
                                    bits |= 1 << k;
                                }
                            }
                            bits
                        }
                    }
                    DeviceLayout::B1Naive => {
                        // The naive kernel: index-table indirection, then a
                        // per-lane record gather that serializes the warp —
                        // no staging, no vector evaluation possible.
                        self.mem.read_index_row(warp, &mut self.dev);
                        let mut bits = 0u32;
                        for k in 0..w {
                            let (s, hsum) = self.mem.gather_spin(start + k, &mut self.dev);
                            s_tile[k] = s;
                            let de = 2.0 * s * hsum;
                            if u_tile[k] < self.exp.eval(neg_beta * de) {
                                bits |= 1 << k;
                            }
                        }
                        bits
                    }
                };

                // Commit phase: lanes retire in order; conflicted lanes
                // replay divergently against the updated fields.
                let warp_end = start + w;
                let mut warp_flips = 0u64;
                for k in 0..w {
                    let i = start + k;
                    let accept = if self.dirty[i] == self.stamp {
                        self.dev.divergent_replays += 1;
                        let (s, hsum) = self.mem.gather_spin(i, &mut self.dev);
                        let de = 2.0 * s * hsum;
                        u_tile[k] < self.exp.eval(neg_beta * de)
                    } else {
                        accept_bits >> k & 1 == 1
                    };
                    if accept {
                        warp_flips += 1;
                        // A.2's flip body, addressed through the layout.
                        let two_s_mul = 2.0 * self.mem.s_raw(i);
                        self.mem.flip_s(i, &mut self.dev);
                        let (lo, hi) =
                            (self.lay.offsets[i] as usize, self.lay.offsets[i + 1] as usize);
                        let targets = &self.lay.edge_target[lo..hi];
                        let js = &self.lay.edge_j[lo..hi];
                        let kk = targets.len();
                        for e in 0..kk - 2 {
                            let t = targets[e] as usize;
                            self.mem.sub_h_space(t, two_s_mul * js[e], &mut self.dev);
                            if t > i && t < warp_end {
                                self.dirty[t] = self.stamp;
                            }
                        }
                        for e in [kk - 2, kk - 1] {
                            let t = targets[e] as usize;
                            self.mem.sub_h_tau(t, two_s_mul * js[e], &mut self.dev);
                            if t > i && t < warp_end {
                                self.dirty[t] = self.stamp;
                            }
                        }
                    }
                }
                stats.attempts += w as u64;
                stats.flips += warp_flips;
                stats.groups += 1;
                if warp_flips > 0 {
                    stats.groups_with_flip += 1;
                    self.mem.write_back_s(warp, &mut self.dev);
                }
                self.dev.warps += 1;
            }
        }
    }
}

impl<U: SimdU32> Sweeper for DeviceSweeper<U> {
    fn kind(&self) -> SweepKind {
        self.kind
    }

    fn run(&mut self, n_sweeps: usize, beta: f32) -> SweepStats {
        let mut stats = SweepStats::default();
        U::with_features(|| {
            for _ in 0..n_sweeps {
                self.sweep_once(beta, &mut stats);
            }
        });
        let delta = self.dev.delta_since(&self.flushed);
        super::flush_global(&delta);
        self.flushed = self.dev;
        stats
    }

    fn energy(&mut self) -> f64 {
        self.model.total_energy(&self.mem.state_vec())
    }

    fn state(&mut self) -> Vec<f32> {
        self.mem.state_vec()
    }

    fn set_state(&mut self, s: &[f32]) {
        assert_eq!(s.len(), self.model.n_spins());
        let (hs, ht) = self.model.effective_fields(s);
        self.mem = GlobalMemory::build(self.mem.layout(), s, hs, ht);
        // `stamp` keeps counting up, so stale `dirty` entries can never
        // collide with a future warp's stamp.
    }

    fn validate(&mut self) -> f64 {
        let state = self.mem.state_vec();
        let (hs, ht) = self.model.effective_fields(&state);
        let (dev_hs, dev_ht) = self.mem.field_vecs();
        let mut worst = 0.0f64;
        for i in 0..state.len() {
            worst = worst
                .max((hs[i] - dev_hs[i]).abs() as f64)
                .max((ht[i] - dev_ht[i]).abs() as f64);
        }
        worst
    }

    fn rng_state(&self) -> Option<Vec<u32>> {
        Some(self.rng.state_words())
    }

    fn set_rng_state(&mut self, words: &[u32]) -> bool {
        self.rng.restore_words(words)
    }

    fn device_stats(&self) -> Option<DeviceStats> {
        Some(self.dev)
    }
}
