//! # vectorising — explicit-vectorization reproduction
//!
//! A production reproduction of Dickson, Karimi & Hamze,
//! *Importance of Explicit Vectorization for CPU and GPU Software
//! Performance* (2010): a Metropolis Monte Carlo engine for layered (QMC)
//! Ising models with the paper's full explicit-optimization ladder —
//!
//! * **A.1** original scalar code (branchy inner loop, nested edge tables,
//!   library `exp`),
//! * **A.2** + basic optimizations (branch elimination, flat edge arrays
//!   with tau edges last, result caching, bit-trick `exp` approximation),
//! * **A.3** + explicitly vectorized MT19937 (4 interlaced generators,
//!   SSE2) and vectorized flip decisions over spin quadruplets,
//! * **A.4** + fully vectorized neighbour updates via 4-way layer
//!   interlacing of the spin order,
//! * **B.1/B.2** the accelerator ports (XLA artifacts AOT-compiled from
//!   JAX+Pallas, executed through PJRT): naive gathered layout vs
//!   coalesced interlaced layout.
//!
//! On top of the sweep ladder sit the systems the paper's workload needs:
//! a parallel-tempering engine ([`tempering`]), a multi-threaded
//! coordinator ([`coordinator`]), the PJRT runtime ([`runtime`]) and the
//! benchmark harness that regenerates every table and figure of the
//! paper's evaluation ([`harness`]).
//!
//! ## Quickstart
//!
//! ```no_run
//! use vectorising::ising::builder::torus_workload;
//! use vectorising::sweep::{self, SweepKind};
//!
//! let wl = torus_workload(8, 8, 32, 1, 0.3);
//! let mut sim = sweep::make_sweeper(SweepKind::A4Full, &wl.model, &wl.s0, 5489);
//! sim.run(100, 0.5);
//! println!("energy = {}", sim.energy());
//! ```

pub mod coordinator;
pub mod expapprox;
pub mod harness;
pub mod ising;
pub mod rng;
pub mod runtime;
pub mod simd;
pub mod stats;
pub mod sweep;
pub mod tempering;
pub mod util;

/// Crate-wide error type (wraps IO, JSON and XLA failures).
pub type Error = anyhow::Error;
/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
