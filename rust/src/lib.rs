//! # vectorising — explicit-vectorization reproduction
//!
//! A production reproduction of Dickson, Karimi & Hamze,
//! *Importance of Explicit Vectorization for CPU and GPU Software
//! Performance* (2010): a Metropolis Monte Carlo engine for layered (QMC)
//! Ising models with the paper's full explicit-optimization ladder —
//!
//! * **A.1** original scalar code (branchy inner loop, nested edge tables,
//!   library `exp`),
//! * **A.2** + basic optimizations (branch elimination, flat edge arrays
//!   with tau edges last, result caching, bit-trick `exp` approximation),
//! * **A.3** + explicitly vectorized MT19937 (W interlaced generators)
//!   and vectorized flip decisions over spin groups,
//! * **A.4** + fully vectorized neighbour updates via W-way layer
//!   interlacing of the spin order,
//! * **A.3w8/A.4w8** the same rungs at 8 lanes — AVX2 when the host has
//!   it (runtime-detected), portable lanes otherwise,
//! * **A.3w16/A.4w16** the same rungs at 16 lanes — AVX-512 when the
//!   toolchain and host provide it, portable 16-lanes otherwise,
//! * **C.1/C.1w8/C.1w16** replica-batched vectorization: one SIMD lane
//!   per tempering replica (per-lane β, per-lane RNG stream), so even
//!   shallow models the A-rungs reject sweep at full vector width,
//! * **M.1** multi-spin coding on the ±1-coupling family: 64 layers
//!   bit-packed per word, XOR-parity neighbour sums through a carry-save
//!   adder network, exact Metropolis acceptance via per-energy-bin
//!   24-bit threshold tables — zero floating point in the hot loop,
//! * **B.1/B.2** the accelerator ports, executed on the in-process
//!   software [`device`] (a GPU-style grid/block/warp SIMT model with
//!   counted coalesced-vs-strided memory transactions): naive gathered
//!   layout vs coalesced layout — bit-exact to scalar A.2.  Real
//!   AOT-compiled XLA artifacts can still run through PJRT via
//!   [`sweep::accel::AccelSweeper`] when a runtime is supplied.
//!
//! The whole CPU vector stack ([`simd`], [`rng`], [`expapprox`],
//! [`ising::reorder`], [`sweep`]) is generic over the lane width `W`:
//! SSE2 backs width 4, AVX2 width 8, AVX-512 width 16 (runtime-detected,
//! toolchain-gated), and a const-generic portable implementation backs
//! every other width and architecture.  The width-generic MT19937
//! regenerates its state block in ILP-unrolled independent accumulator
//! chains, bit-exact to the rolled recurrence.
//!
//! Construction goes through the **Engine API v1** ([`engine`]): a
//! [`engine::SamplerSpec`] names the three orthogonal axes — *rung* ×
//! *width* × *backend* — and [`engine::EngineBuilder`] negotiates it
//! against host capabilities and model geometry into an explicit
//! [`engine::Plan`] (chosen backend, effective width, machine-readable
//! fallback reasons).  The legacy width-baked [`sweep::SweepKind`]
//! spellings all lower onto specs, so old call sites keep working.
//!
//! On top of the sweep ladder sit the systems the paper's workload needs:
//! a parallel-tempering engine ([`tempering`], with heterogeneous
//! per-group plans — an AVX2 `C.1w8` group next to an SSE2 `C.1` tail),
//! a multi-threaded coordinator ([`coordinator`]) whose **Run API v1**
//! describes runs as versioned [`coordinator::RunSpec`]s and persists
//! them through spec-carrying schema-v2 [`coordinator::Checkpoint`]s
//! (bit-exact resume at any instantiable width, `repro run
//! --checkpoint/--resume`), the PJRT runtime ([`runtime`]), the
//! benchmark harness that regenerates every table and figure of the
//! paper's evaluation ([`harness`]), and the sampling [`service`] — a
//! job queue + dynamic lane-batching scheduler that packs independent
//! sampling jobs onto C-rung lane-batches (`repro serve` / `repro
//! submit`), speaking the versioned v1 wire protocol (jobs carry a
//! sampler spec, results echo the resolved plan, and `{"op":"run"}`
//! executes whole checkpointable runs with inline checkpoints).  The
//! [`router`] tier (`repro route`) scales that service out: shape
//! buckets are consistent-hashed across replicated worker processes,
//! with least-loaded replica selection, overload failover, zero-loss
//! replay on worker death, and exact cluster-wide stats/Prometheus
//! aggregation behind the same wire protocol.  Perf
//! itself is a tracked artifact: [`harness::bench`] emits machine-readable
//! `BENCH_<rung>.json` measurements and `repro bench --check` gates CI on
//! the trajectory (M.1 ≥ 3× C.1w8 spins/sec, ≤ 10% regression against
//! same-host measured baselines).
//!
//! ## Quickstart
//!
//! ```no_run
//! use vectorising::engine::{EngineBuilder, Rung, SamplerSpec};
//! use vectorising::ising::builder::torus_workload;
//! use vectorising::sweep::Sweeper;
//!
//! let wl = torus_workload(8, 8, 32, 1, 0.3);
//! // Rung A.4, width and backend negotiated (AVX2 octets when the host
//! // has them, SSE quadruplets otherwise, portable lanes as fallback).
//! let spec = SamplerSpec::rung(Rung::A4);
//! let mut sim = EngineBuilder::new(spec).build(&wl.model, &wl.s0, 5489).unwrap();
//! println!("running {} on {}", sim.plan.label(), sim.plan.backend);
//! sim.run(100, 0.5);
//! println!("energy = {}", sim.energy());
//! ```
//!
//! Migration from the legacy surface:
//!
//! | v0 (width-baked)                          | v1 (orthogonal spec)                       |
//! |-------------------------------------------|--------------------------------------------|
//! | `try_make_sweeper(SweepKind::A4Full, ..)` | `EngineBuilder::new(Rung::A4.spec().w(4)).build(..)` |
//! | `SweepKind::A4FullW8`                     | `Rung::A4.spec().w(8)`                     |
//! | `SweepKind::preferred_cpu()`              | `Rung::A4.spec()` (width auto)             |
//! | `make_batch_sweeper(C1ReplicaBatchW8, ..)`| `EngineBuilder::new(Rung::C1.spec().w(8)).build_batch(..)` |
//! | `VECTORISING_FORCE_PORTABLE=1`            | same env var, or `.on(BackendPref::Portable)` |

pub mod coordinator;
pub mod device;
pub mod engine;
pub mod expapprox;
pub mod harness;
pub mod ising;
pub mod obs;
pub mod rng;
pub mod router;
pub mod runtime;
pub mod service;
pub mod simd;
pub mod stats;
pub mod sweep;
pub mod tempering;
pub mod util;

/// Crate-wide error type (wraps IO, JSON and XLA failures).
pub type Error = anyhow::Error;
/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
