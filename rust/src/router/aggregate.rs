//! Cluster-wide control-op aggregation.
//!
//! The router answers `hello`/`stats`/`metrics`/`trace` itself by
//! fanning the op out to every alive worker over short-lived
//! connections (the same `submit_lines` client `repro submit` uses) and
//! merging the replies:
//!
//! * **stats** — counters summed, gauges summed, high-water marks
//!   maxed, and latency histograms merged *bucketwise* through their
//!   sparse [`HistogramSnapshot`] wire form, so cluster p50/p90/p99 are
//!   exact percentiles of the combined stream — not averages of
//!   per-worker summaries.  Per-shape queue buckets merge by
//!   `(shape, lanes)`.  The reply keeps the worker stats-line shape, so
//!   existing clients read a router the same way they read a worker.
//! * **metrics** — each worker's Prometheus text is re-grouped per
//!   family (one `# HELP`/`# TYPE` header each) with a `worker` label
//!   injected into every sample, plus the router's own families under
//!   `worker="router"`.
//! * **trace** — per-worker trace rings concatenated, each entry tagged
//!   with its worker.
//! * **hello** — the router's capability view: every worker's handshake
//!   under its address.

use std::collections::BTreeMap;

use crate::obs::prometheus::PromWriter;
use crate::obs::HistogramSnapshot;
use crate::service::job::PROTOCOL_VERSION;
use crate::service::metrics::{build_labels, latency_summary};
use crate::service::server::submit_lines;
use crate::util::json::{self, Value};
use crate::Result;

use super::forward::RouterCore;

/// Stats-line counters that sum across workers (gauges like
/// `queue_depth` sum too: cluster depth is the total backlog).
const SUMMED_KEYS: [&str; 15] = [
    "jobs_submitted",
    "jobs_completed",
    "jobs_failed",
    "jobs_rejected",
    "batches_dispatched",
    "singles_dispatched",
    "deadline_flushes",
    "lanes_occupied",
    "lanes_padded",
    "queue_depth",
    "runs_executed",
    "jobs_overloaded",
    "jobs_in_system",
    "dispatches_in_flight",
    "spins_attempted",
];

const HIST_KEYS: [&str; 4] = ["queue_wait", "exec", "e2e", "pool_task"];

/// Send one control op to `addr` on a short-lived connection and parse
/// the single reply line.
fn fetch_op(addr: &str, op_line: &str) -> Result<Value> {
    let mut buf: Vec<u8> = Vec::new();
    submit_lines(addr, vec![op_line.to_string()], &mut buf)?;
    let text = String::from_utf8_lossy(&buf);
    let line = text
        .lines()
        .find(|l| !l.trim().is_empty())
        .ok_or_else(|| anyhow::anyhow!("worker {addr}: empty reply to {op_line}"))?;
    Value::parse(line.trim())
}

/// Fan `op_line` out to every alive worker; returns one slot per
/// upstream (`None`: dead or fetch failed — the prober, not the
/// aggregator, owns declaring deaths).
fn fetch_all(core: &RouterCore, op_line: &str) -> Vec<Option<Value>> {
    core.upstreams
        .iter()
        .map(|up| {
            if !up.alive() {
                return None;
            }
            fetch_op(&up.addr, op_line).ok()
        })
        .collect()
}

fn get_f64(v: &Value, key: &str) -> f64 {
    v.opt(key).and_then(|x| x.as_f64().ok()).unwrap_or(0.0)
}

/// Cluster `{"op":"stats"}`: the worker stats-line shape with every
/// figure aggregated, plus appended `workers` and `router` sections.
pub fn stats_line(core: &RouterCore) -> String {
    let replies = fetch_all(core, "{\"op\":\"stats\"}");
    let respondents: Vec<&Value> = replies.iter().flatten().collect();
    let mut fields: Vec<(&str, Value)> = vec![
        ("protocol_version", json::num(PROTOCOL_VERSION as f64)),
        ("op", json::str_v("stats")),
    ];
    for key in SUMMED_KEYS {
        let total: f64 = respondents.iter().map(|v| get_f64(v, key)).sum();
        fields.push((key, json::num(total)));
    }
    // Derived figures recomputed from the summed inputs, never averaged.
    let occupied: f64 = respondents.iter().map(|v| get_f64(v, "lanes_occupied")).sum();
    let padded: f64 = respondents.iter().map(|v| get_f64(v, "lanes_padded")).sum();
    let fill = if occupied + padded == 0.0 { 1.0 } else { occupied / (occupied + padded) };
    fields.push(("lane_fill_ratio", json::num(fill)));
    let max_depth =
        respondents.iter().map(|v| get_f64(v, "max_queue_depth")).fold(0.0_f64, f64::max);
    fields.push(("max_queue_depth", json::num(max_depth)));
    let uptime = respondents.iter().map(|v| get_f64(v, "uptime_ms")).fold(0.0_f64, f64::max);
    fields.push(("uptime_ms", json::num(uptime)));
    let started = respondents
        .iter()
        .map(|v| get_f64(v, "started_at_ms"))
        .filter(|&ms| ms > 0.0)
        .fold(f64::INFINITY, f64::min);
    fields.push(("started_at_ms", json::num(if started.is_finite() { started } else { 0.0 })));
    // Exact cluster latency percentiles: merge the sparse histograms
    // bucketwise, then summarize the merged stream.
    let mut hist_fields: Vec<(&str, Value)> = Vec::new();
    let mut summary_fields: Vec<(&str, Value)> = Vec::new();
    for key in HIST_KEYS {
        let mut merged = HistogramSnapshot::empty();
        for v in &respondents {
            if let Some(h) = v.opt("latency_hist").and_then(|lh| lh.opt(key)) {
                if let Ok(snap) = HistogramSnapshot::from_value(h) {
                    merged.merge(&snap);
                }
            }
        }
        hist_fields.push((key, merged.to_value()));
        summary_fields.push((key, latency_summary(&merged)));
    }
    fields.push(("latency_hist", json::obj(hist_fields)));
    fields.push(("latency_us", json::obj(summary_fields)));
    // Windowed rates sum (same window on every worker).
    let window = respondents
        .iter()
        .find_map(|v| v.opt("rate").map(|r| get_f64(r, "window_secs")))
        .unwrap_or(10.0);
    let jobs_rate: f64 =
        respondents.iter().filter_map(|v| v.opt("rate")).map(|r| get_f64(r, "jobs_per_sec")).sum();
    let spins_rate: f64 =
        respondents.iter().filter_map(|v| v.opt("rate")).map(|r| get_f64(r, "spins_per_sec")).sum();
    fields.push((
        "rate",
        json::obj(vec![
            ("window_secs", json::num(window)),
            ("jobs_per_sec", json::num(jobs_rate)),
            ("spins_per_sec", json::num(spins_rate)),
        ]),
    ));
    // Per-shape queue buckets merged by (shape, lanes): cluster backlog
    // per bucket, staleness of the oldest head anywhere.
    let mut buckets: BTreeMap<(String, u64), (f64, f64)> = BTreeMap::new();
    for v in &respondents {
        let Some(arr) = v.opt("buckets").and_then(|b| b.as_arr().ok()) else { continue };
        for b in arr {
            let Some(shape) = b.opt("shape").and_then(|s| s.as_str().ok()) else { continue };
            let lanes = get_f64(b, "lanes") as u64;
            let entry = buckets.entry((shape.to_string(), lanes)).or_insert((0.0, 0.0));
            entry.0 += get_f64(b, "depth");
            entry.1 = entry.1.max(get_f64(b, "oldest_age_us"));
        }
    }
    fields.push((
        "buckets",
        Value::Arr(
            buckets
                .iter()
                .map(|((shape, lanes), (depth, oldest))| {
                    json::obj(vec![
                        ("shape", json::str_v(shape)),
                        ("depth", json::num(*depth)),
                        ("oldest_age_us", json::num(*oldest)),
                        ("lanes", json::num(*lanes as f64)),
                    ])
                })
                .collect(),
        ),
    ));
    // Per-worker roll call: liveness, routing load, key figures.
    let workers: Vec<Value> = core
        .upstreams
        .iter()
        .zip(replies.iter())
        .map(|(up, reply)| {
            let mut w = vec![
                ("index", json::num(up.index as f64)),
                ("addr", json::str_v(&up.addr)),
                ("alive", Value::Bool(up.alive())),
                (
                    "in_flight",
                    json::num(up.in_flight.load(std::sync::atomic::Ordering::Relaxed) as f64),
                ),
            ];
            if let Some(v) = reply {
                w.push(("jobs_completed", json::num(get_f64(v, "jobs_completed"))));
                w.push(("queue_depth", json::num(get_f64(v, "queue_depth"))));
                w.push(("lane_fill_ratio", json::num(get_f64(v, "lane_fill_ratio"))));
                if let Some(backend) =
                    v.opt("config").and_then(|c| c.opt("backend")).and_then(|b| b.as_str().ok())
                {
                    w.push(("backend", json::str_v(backend)));
                }
            }
            json::obj(w)
        })
        .collect();
    fields.push(("workers", Value::Arr(workers)));
    fields.push(("router", router_section(core)));
    json::obj(fields).to_string()
}

/// The router's own counters as a stats sub-object.
fn router_section(core: &RouterCore) -> Value {
    use std::sync::atomic::Ordering::Relaxed;
    let m = &core.metrics;
    json::obj(vec![
        ("workers_total", json::num(core.upstreams.len() as f64)),
        ("workers_alive", json::num(core.alive_count() as f64)),
        ("replicas", json::num(core.replicas as f64)),
        ("jobs_routed", json::num(m.jobs_routed.load(Relaxed) as f64)),
        ("runs_routed", json::num(m.runs_routed.load(Relaxed) as f64)),
        ("replies_relayed", json::num(m.replies_relayed.load(Relaxed) as f64)),
        ("failovers", json::num(m.failovers.load(Relaxed) as f64)),
        ("replays", json::num(m.replays.load(Relaxed) as f64)),
        ("rejections", json::num(m.rejections.load(Relaxed) as f64)),
        ("routing_errors", json::num(m.routing_errors.load(Relaxed) as f64)),
        ("workers_lost", json::num(m.workers_lost.load(Relaxed) as f64)),
        ("jobs_pending", json::num(core.pending_total() as f64)),
    ])
}

/// One Prometheus metric family being re-grouped across workers.
#[derive(Default)]
struct Family {
    help: String,
    kind: String,
    samples: Vec<String>,
}

/// Re-groups several workers' Prometheus expositions into one valid
/// exposition: each family's `# HELP`/`# TYPE` header appears once, and
/// every sample gains a `worker` label.  Without the re-grouping, naive
/// concatenation would repeat family headers (invalid) and interleave
/// different workers' histogram bucket series (unreadable).
#[derive(Default)]
struct PromAggregator {
    order: Vec<String>,
    families: BTreeMap<String, Family>,
}

impl PromAggregator {
    fn family_mut(&mut self, name: &str) -> &mut Family {
        if !self.families.contains_key(name) {
            self.order.push(name.to_string());
            self.families.insert(name.to_string(), Family::default());
        }
        self.families.get_mut(name).expect("just inserted")
    }

    /// The family a sample series belongs to: histogram series
    /// `<fam>_bucket/_sum/_count` fold into `<fam>` when `<fam>` is a
    /// declared histogram (its header always precedes its samples in a
    /// worker's exposition).
    fn family_of(&self, series: &str) -> String {
        for suffix in ["_bucket", "_sum", "_count"] {
            if let Some(base) = series.strip_suffix(suffix) {
                if self.families.get(base).map(|f| f.kind.as_str()) == Some("histogram") {
                    return base.to_string();
                }
            }
        }
        series.to_string()
    }

    fn add(&mut self, worker: &str, text: &str) {
        for line in text.lines() {
            if let Some(rest) = line.strip_prefix("# HELP ") {
                let (name, help) = rest.split_once(' ').unwrap_or((rest, ""));
                let name = name.to_string();
                let fam = self.family_mut(&name);
                if fam.help.is_empty() {
                    fam.help = help.to_string();
                }
            } else if let Some(rest) = line.strip_prefix("# TYPE ") {
                let (name, kind) = rest.split_once(' ').unwrap_or((rest, "untyped"));
                let name = name.to_string();
                let fam = self.family_mut(&name);
                if fam.kind.is_empty() {
                    fam.kind = kind.to_string();
                }
            } else if !line.trim().is_empty() && !line.starts_with('#') {
                let name_end =
                    line.find(|c| c == '{' || c == ' ').unwrap_or(line.len());
                let series = &line[..name_end];
                let fam_name = self.family_of(series);
                let labeled = inject_worker_label(line, name_end, worker);
                self.family_mut(&fam_name).samples.push(labeled);
            }
        }
    }

    fn finish(self) -> String {
        let mut out = String::new();
        for name in &self.order {
            let fam = &self.families[name];
            let kind = if fam.kind.is_empty() { "untyped" } else { &fam.kind };
            out.push_str(&format!("# HELP {name} {}\n", fam.help));
            out.push_str(&format!("# TYPE {name} {kind}\n"));
            for s in &fam.samples {
                out.push_str(s);
                out.push('\n');
            }
        }
        out
    }
}

/// Insert `worker="..."` as the first label of a sample line whose
/// metric name ends at `name_end`.
fn inject_worker_label(line: &str, name_end: usize, worker: &str) -> String {
    if line.as_bytes().get(name_end) == Some(&b'{') {
        format!("{}{{worker=\"{worker}\",{}", &line[..name_end], &line[name_end + 1..])
    } else {
        format!("{}{{worker=\"{worker}\"}}{}", &line[..name_end], &line[name_end..])
    }
}

/// The router's own families, labeled like any worker's (`host`/`sha`
/// common labels) so the aggregated exposition stays uniform.
fn router_prometheus(core: &RouterCore) -> String {
    use std::sync::atomic::Ordering::Relaxed;
    let (host, sha) = build_labels();
    let mut w = PromWriter::new(&[("host", host), ("sha", sha)]);
    let m = &core.metrics;
    let counters: &[(&str, &str, u64)] = &[
        ("repro_router_jobs_routed_total", "Sampling jobs accepted at the front door.", m.jobs_routed.load(Relaxed)),
        ("repro_router_runs_routed_total", "Run jobs accepted at the front door.", m.runs_routed.load(Relaxed)),
        ("repro_router_replies_relayed_total", "Worker replies relayed to clients.", m.replies_relayed.load(Relaxed)),
        ("repro_router_failovers_total", "Overloaded jobs moved to another replica.", m.failovers.load(Relaxed)),
        ("repro_router_replays_total", "In-flight jobs replayed after a worker death.", m.replays.load(Relaxed)),
        ("repro_router_rejections_total", "Jobs rejected: every replica overloaded.", m.rejections.load(Relaxed)),
        ("repro_router_routing_errors_total", "Jobs failed: no alive worker.", m.routing_errors.load(Relaxed)),
        ("repro_router_workers_lost_total", "Workers declared dead.", m.workers_lost.load(Relaxed)),
    ];
    for &(name, help, value) in counters {
        w.counter(name, help, value);
    }
    w.gauge("repro_router_workers_alive", "Workers currently alive.", core.alive_count() as f64);
    w.gauge(
        "repro_router_workers_total",
        "Workers configured at start.",
        core.upstreams.len() as f64,
    );
    w.gauge("repro_router_replicas", "Replication factor per bucket.", core.replicas as f64);
    w.gauge(
        "repro_router_jobs_pending",
        "Jobs forwarded and not yet answered.",
        core.pending_total() as f64,
    );
    w.finish()
}

/// Cluster `{"op":"metrics"}`: every worker's exposition re-grouped
/// under `worker` labels plus the router's own families.
pub fn metrics_line(core: &RouterCore) -> String {
    let replies = fetch_all(core, "{\"op\":\"metrics\"}");
    let mut agg = PromAggregator::default();
    for (up, reply) in core.upstreams.iter().zip(replies.iter()) {
        let Some(v) = reply else { continue };
        let Some(text) = v.opt("text").and_then(|t| t.as_str().ok()) else { continue };
        agg.add(&up.addr, text);
    }
    agg.add("router", &router_prometheus(core));
    json::obj(vec![
        ("protocol_version", json::num(PROTOCOL_VERSION as f64)),
        ("op", json::str_v("metrics")),
        ("content_type", json::str_v("text/plain; version=0.0.4")),
        ("text", json::str_v(&agg.finish())),
    ])
    .to_string()
}

/// Cluster `{"op":"trace"}`: per-worker rings concatenated in worker
/// order, each entry tagged with its worker's address.
pub fn trace_line(core: &RouterCore, last: usize) -> String {
    let op = format!("{{\"op\":\"trace\",\"last\":{last}}}");
    let replies = fetch_all(core, &op);
    let mut traces: Vec<Value> = Vec::new();
    let mut recorded = 0.0;
    for (up, reply) in core.upstreams.iter().zip(replies.iter()) {
        let Some(v) = reply else { continue };
        recorded += get_f64(v, "traces_recorded");
        let Some(arr) = v.opt("traces").and_then(|t| t.as_arr().ok()) else { continue };
        for t in arr {
            let mut t = t.clone();
            if let Value::Obj(m) = &mut t {
                m.insert("worker".to_string(), json::str_v(&up.addr));
            }
            traces.push(t);
        }
    }
    json::obj(vec![
        ("protocol_version", json::num(PROTOCOL_VERSION as f64)),
        ("op", json::str_v("trace")),
        ("traces_recorded", json::num(recorded)),
        ("count", json::num(traces.len() as f64)),
        ("traces", Value::Arr(traces)),
    ])
    .to_string()
}

/// Cluster `{"op":"hello"}`: the router's capability view — its own
/// identity plus every worker's handshake under its address.
pub fn hello_line(core: &RouterCore) -> String {
    let replies = fetch_all(core, "{\"op\":\"hello\"}");
    let workers: Vec<Value> = core
        .upstreams
        .iter()
        .zip(replies.into_iter())
        .map(|(up, reply)| {
            let mut v = reply.unwrap_or_else(|| json::obj(vec![]));
            if let Value::Obj(m) = &mut v {
                m.insert("addr".to_string(), json::str_v(&up.addr));
                m.insert("alive".to_string(), Value::Bool(up.alive()));
            }
            v
        })
        .collect();
    json::obj(vec![
        ("protocol_version", json::num(PROTOCOL_VERSION as f64)),
        ("op", json::str_v("hello")),
        ("router", Value::Bool(true)),
        ("replicas", json::num(core.replicas as f64)),
        ("workers", Value::Arr(workers)),
    ])
    .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn worker_label_injection_handles_both_sample_shapes() {
        assert_eq!(
            inject_worker_label("repro_queue_depth 3", "repro_queue_depth".len(), "w0"),
            "repro_queue_depth{worker=\"w0\"} 3"
        );
        assert_eq!(
            inject_worker_label(
                "repro_e2e_seconds_bucket{host=\"x\",le=\"0.1\"} 7",
                "repro_e2e_seconds_bucket".len(),
                "127.0.0.1:9000"
            ),
            "repro_e2e_seconds_bucket{worker=\"127.0.0.1:9000\",host=\"x\",le=\"0.1\"} 7"
        );
    }

    #[test]
    fn aggregator_emits_one_header_per_family_and_labels_every_sample() {
        let worker_text = "# HELP repro_jobs_completed_total Jobs answered ok.\n\
             # TYPE repro_jobs_completed_total counter\n\
             repro_jobs_completed_total{host=\"h\"} 5\n\
             # HELP repro_e2e_seconds Admission to reply latency.\n\
             # TYPE repro_e2e_seconds histogram\n\
             repro_e2e_seconds_bucket{host=\"h\",le=\"+Inf\"} 5\n\
             repro_e2e_seconds_sum{host=\"h\"} 0.2\n\
             repro_e2e_seconds_count{host=\"h\"} 5\n";
        let mut agg = PromAggregator::default();
        agg.add("a:1", worker_text);
        agg.add("b:2", worker_text);
        let out = agg.finish();
        // One header pair per family, despite two workers.
        assert_eq!(out.matches("# TYPE repro_jobs_completed_total counter").count(), 1);
        assert_eq!(out.matches("# TYPE repro_e2e_seconds histogram").count(), 1);
        // Histogram suffix series folded under the declared family, in
        // per-worker groups, each labeled.
        assert_eq!(out.matches("worker=\"a:1\"").count(), 4);
        assert_eq!(out.matches("worker=\"b:2\"").count(), 4);
        // Every sample line carries a worker label.
        for line in out.lines().filter(|l| !l.starts_with('#') && !l.is_empty()) {
            assert!(line.contains("worker=\""), "unlabeled sample: {line}");
        }
        // Headers precede their samples.
        let type_pos = out.find("# TYPE repro_e2e_seconds histogram").unwrap();
        let sample_pos = out.find("repro_e2e_seconds_bucket").unwrap();
        assert!(type_pos < sample_pos);
    }
}
