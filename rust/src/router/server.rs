//! The router's front door: the same JSON-lines TCP protocol the
//! workers speak, so existing clients (`repro submit`, `submit_lines`)
//! need zero changes to talk to a cluster.
//!
//! Job and run lines are validated with the same `parse_request` the
//! workers use, then handed to [`RouterCore`]; control ops answer with
//! cluster-wide aggregations; `{"op":"shutdown"}` acks, drains in-flight
//! jobs and returns from [`serve`].  Reply streaming keeps the worker
//! semantics: results arrive per-job as they complete (correlate by
//! `id`), and half-closing the write side makes "read until EOF"
//! collect exactly this connection's results.
//!
//! [`spawn_workers`] boots an owned local fleet (`repro route --spawn
//! N`): each worker is this same binary running `serve --listen
//! 127.0.0.1:0`, its bound port parsed from the serve banner line.

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use crate::service::job::{parse_request, JobResult, Request, PROTOCOL_VERSION};
use crate::util::json::{self, Value};
use crate::Result;

use super::aggregate;
use super::forward::RouterCore;
use super::health;
use super::RouterConfig;

/// Serve the cluster front door on `listener` until a shutdown request:
/// connect the worker fleet, start health probing, route jobs.
pub fn serve(listener: TcpListener, workers: &[String], cfg: &RouterConfig) -> Result<()> {
    listener.set_nonblocking(true)?;
    let core = RouterCore::connect(workers, cfg.replicas)?;
    let prober = health::spawn_prober(Arc::clone(&core), cfg.health_ms);
    let shutdown = Arc::new(AtomicBool::new(false));
    let mut connections: Vec<thread::JoinHandle<()>> = Vec::new();
    let mut accept_error: Option<std::io::Error> = None;
    while !shutdown.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                connections.retain(|conn| !conn.is_finished());
                let core = Arc::clone(&core);
                let flag = Arc::clone(&shutdown);
                connections.push(thread::spawn(move || {
                    let _ = handle_conn(stream, core, flag);
                }));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                connections.retain(|conn| !conn.is_finished());
                thread::sleep(Duration::from_millis(5));
            }
            Err(e) => {
                shutdown.store(true, Ordering::SeqCst);
                accept_error = Some(e);
            }
        }
    }
    // Stop accepting; open connections poll the flag and wind down,
    // then the core waits out its in-flight ledger and disconnects.
    for conn in connections {
        let _ = conn.join();
    }
    core.shutdown();
    let _ = prober.join();
    match accept_error {
        Some(e) => Err(e.into()),
        None => Ok(()),
    }
}

/// One client connection: requests in, per-job result lines out
/// (order not guaranteed — correlate by `id`), same as a worker.
fn handle_conn(stream: TcpStream, core: Arc<RouterCore>, shutdown: Arc<AtomicBool>) -> Result<()> {
    stream.set_read_timeout(Some(Duration::from_millis(100)))?;
    let write_half = stream.try_clone()?;
    let (line_tx, line_rx) = channel::<String>();
    let writer = thread::spawn(move || {
        let mut out = BufWriter::new(write_half);
        for line in line_rx {
            if out.write_all(line.as_bytes()).is_err()
                || out.write_all(b"\n").is_err()
                || out.flush().is_err()
            {
                break;
            }
        }
        if let Ok(inner) = out.into_inner() {
            let _ = inner.shutdown(Shutdown::Write);
        }
    });
    let mut reader = BufReader::new(stream);
    let mut buf = String::new();
    loop {
        match reader.read_line(&mut buf) {
            Ok(0) => break,
            Ok(_) => {
                let line = buf.trim();
                if !line.is_empty() {
                    handle_line(line, &core, &line_tx, &shutdown);
                }
                buf.clear();
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                if shutdown.load(Ordering::SeqCst) {
                    break;
                }
            }
            Err(_) => break,
        }
    }
    // The writer exits once every job this connection routed has been
    // answered — each pending forward holds a sender clone.
    drop(line_tx);
    let _ = writer.join();
    Ok(())
}

fn handle_line(
    line: &str,
    core: &Arc<RouterCore>,
    line_tx: &Sender<String>,
    shutdown: &AtomicBool,
) {
    match parse_request(line) {
        Ok(Request::Job(spec)) => core.route_job(spec, line_tx.clone()),
        Ok(Request::Run(job)) => core.route_run(*job, line_tx.clone()),
        Ok(Request::Hello) => {
            let _ = line_tx.send(aggregate::hello_line(core));
        }
        Ok(Request::Stats) => {
            let _ = line_tx.send(aggregate::stats_line(core));
        }
        Ok(Request::Metrics) => {
            let _ = line_tx.send(aggregate::metrics_line(core));
        }
        Ok(Request::Trace { last }) => {
            let _ = line_tx.send(aggregate::trace_line(core, last));
        }
        Ok(Request::Shutdown) => {
            shutdown.store(true, Ordering::SeqCst);
            let ack = json::obj(vec![
                ("ok", Value::Bool(true)),
                ("op", json::str_v("shutdown")),
                ("protocol_version", json::num(PROTOCOL_VERSION as f64)),
            ]);
            let _ = line_tx.send(ack.to_string());
        }
        Err(e) => {
            // Same front-door validation a worker applies: bad lines
            // never reach the cluster.
            let id = Value::parse(line)
                .ok()
                .and_then(|v| v.opt("id").and_then(|x| x.as_str().ok().map(String::from)))
                .unwrap_or_default();
            let _ = line_tx.send(JobResult::error_line(&id, &format!("{e:#}")));
        }
    }
}

/// One worker process owned by `repro route --spawn`.
pub struct SpawnedWorker {
    pub addr: String,
    pub child: Child,
}

/// Boot `n` local workers: this same binary running `serve --listen
/// 127.0.0.1:0`, each worker's bound address parsed from its serve
/// banner.  `serve_flags` are passed through verbatim (lane width,
/// threads, queue cap...).
pub fn spawn_workers(n: usize, serve_flags: &[String]) -> Result<Vec<SpawnedWorker>> {
    let exe = std::env::current_exe()?;
    let mut spawned = Vec::with_capacity(n);
    for i in 0..n {
        let mut cmd = Command::new(&exe);
        cmd.arg("serve").arg("--listen").arg("127.0.0.1:0");
        cmd.args(serve_flags);
        cmd.stdin(Stdio::null()).stdout(Stdio::null()).stderr(Stdio::piped());
        let mut child = cmd
            .spawn()
            .map_err(|e| anyhow::anyhow!("spawning worker {i}: {e}"))?;
        let stderr = child.stderr.take().expect("stderr was piped");
        match read_banner_addr(stderr, i) {
            Ok(addr) => {
                eprintln!("repro route: worker {i} listening on {addr} (pid {})", child.id());
                spawned.push(SpawnedWorker { addr, child });
            }
            Err(e) => {
                let _ = child.kill();
                let _ = child.wait();
                // Tear down the workers that did come up.
                for mut w in spawned {
                    let _ = w.child.kill();
                    let _ = w.child.wait();
                }
                return Err(e);
            }
        }
    }
    Ok(spawned)
}

/// Read a spawned worker's stderr until the serve banner names its
/// bound address, then keep draining the pipe in a background thread
/// (prefixed, so worker logs stay attributable).
fn read_banner_addr(stderr: std::process::ChildStderr, index: usize) -> Result<String> {
    let mut reader = BufReader::new(stderr);
    let mut line = String::new();
    loop {
        line.clear();
        let n = reader.read_line(&mut line)?;
        anyhow::ensure!(n > 0, "worker {index} exited before announcing its address");
        if let Some(rest) = line.split("listening on ").nth(1) {
            let addr = rest.split(" (").next().unwrap_or(rest).trim().to_string();
            anyhow::ensure!(!addr.is_empty(), "worker {index}: malformed serve banner: {line}");
            thread::spawn(move || {
                let mut buf = String::new();
                loop {
                    buf.clear();
                    match reader.read_line(&mut buf) {
                        Ok(0) | Err(_) => break,
                        Ok(_) => eprint!("[worker {index}] {buf}"),
                    }
                }
            });
            return Ok(addr);
        }
        // Not the banner (e.g. a warning) — surface it and keep waiting.
        eprint!("[worker {index}] {line}");
    }
}

/// Ask every owned worker to shut down (best effort), then reap the
/// child processes.
pub fn shutdown_workers(workers: Vec<SpawnedWorker>) {
    for w in &workers {
        let mut sink = Vec::new();
        let _ = crate::service::server::submit_lines(
            &w.addr,
            vec!["{\"op\":\"shutdown\"}".to_string()],
            &mut sink,
        );
    }
    for mut w in workers {
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        loop {
            match w.child.try_wait() {
                Ok(Some(_)) => break,
                Ok(None) if std::time::Instant::now() < deadline => {
                    thread::sleep(Duration::from_millis(50));
                }
                _ => {
                    let _ = w.child.kill();
                    let _ = w.child.wait();
                    break;
                }
            }
        }
    }
}
