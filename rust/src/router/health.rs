//! Active worker health checking.
//!
//! Connection loss on the persistent job connection already detects
//! most deaths (the reader thread's EOF runs the death protocol), but a
//! wedged worker — accepting connections yet never answering — would
//! otherwise strand jobs.  The prober opens a short-lived connection to
//! each alive worker on a period and requires a `{"op":"stats"}` answer
//! within a hard timeout; a failed probe runs the same
//! [`RouterCore::worker_died`] path as a dropped connection.

use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, TcpStream, ToSocketAddrs};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use super::forward::RouterCore;

/// Probe connect/read budget: a healthy worker answers `stats` from
/// memory, so anything slower than this is wedged, not busy.
const PROBE_TIMEOUT: Duration = Duration::from_millis(2000);

/// Spawn the prober thread; it exits when the core starts shutting
/// down (polled in 50 ms steps so teardown never waits out a period).
pub fn spawn_prober(core: Arc<RouterCore>, every_ms: u64) -> thread::JoinHandle<()> {
    thread::spawn(move || {
        let period = Duration::from_millis(every_ms.max(50));
        let step = Duration::from_millis(50);
        let mut next = Instant::now() + period;
        while !core.is_shutting_down() {
            thread::sleep(step.min(period));
            if Instant::now() < next {
                continue;
            }
            next = Instant::now() + period;
            for (w, up) in core.upstreams.iter().enumerate() {
                if core.is_shutting_down() {
                    return;
                }
                if up.alive() && !probe(&up.addr) {
                    eprintln!("repro route: health probe failed for worker {w} ({})", up.addr);
                    core.worker_died(w);
                }
            }
        }
    })
}

/// One health probe: short-lived connection, `{"op":"stats"}`, any
/// non-empty reply line within the timeout counts as alive.
fn probe(addr: &str) -> bool {
    let Ok(mut addrs) = addr.to_socket_addrs() else { return false };
    let Some(sock_addr) = addrs.next() else { return false };
    let Ok(stream) = TcpStream::connect_timeout(&sock_addr, PROBE_TIMEOUT) else {
        return false;
    };
    if stream.set_read_timeout(Some(PROBE_TIMEOUT)).is_err()
        || stream.set_write_timeout(Some(PROBE_TIMEOUT)).is_err()
    {
        return false;
    }
    let Ok(write_half) = stream.try_clone() else { return false };
    {
        let mut w = write_half;
        if w.write_all(b"{\"op\":\"stats\"}\n").is_err() {
            return false;
        }
        let _ = w.shutdown(Shutdown::Write);
    }
    let mut line = String::new();
    match BufReader::new(stream).read_line(&mut line) {
        Ok(n) if n > 0 => !line.trim().is_empty(),
        _ => false,
    }
}
