//! The routing core: consistent-hash placement, least-in-flight replica
//! selection, overload failover, and death-replay.
//!
//! Every job travels as a [`PendingForward`]: the router rewrites its
//! wire id to a cluster-unique `r<seq>`, renders the forward line once
//! (via the spec's own `to_line`, so a replay is byte-identical), and
//! registers it with the chosen upstream *before* writing.  A reply
//! relays back under the client's original id; an `overloaded`
//! rejection moves the job to the next untried replica; a dead worker's
//! whole ledger replays onto survivors.  Only when every alive replica
//! has refused does the client see a merged rejection — and because
//! seeded jobs are bit-exact wherever they execute, a duplicate
//! execution during failover is harmless: the first registered reply
//! wins, later ones find no pending entry and are dropped.

use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use crate::service::job::{JobResult, JobSpec, RunJob};
use crate::util::json::{self, Value};
use crate::Result;

use super::ring::{bucket_key, Ring};
use super::upstream::{lock, PendingForward, Upstream};

/// Fallback backoff hint when a worker's rejection carried none.
const DEFAULT_RETRY_MS: u64 = 50;

/// Router-level counters, exported through `stats`/`metrics`
/// aggregation alongside the summed worker counters.
#[derive(Default)]
pub struct RouterMetrics {
    /// Sampling jobs accepted at the front door.
    pub jobs_routed: AtomicU64,
    /// Full-run jobs accepted at the front door.
    pub runs_routed: AtomicU64,
    /// Worker replies relayed back to clients.
    pub replies_relayed: AtomicU64,
    /// Overload rejections that moved a job to another replica.
    pub failovers: AtomicU64,
    /// Jobs replayed because their worker died with them in flight.
    pub replays: AtomicU64,
    /// Jobs rejected to the client (every replica refused).
    pub rejections: AtomicU64,
    /// Jobs answered with an error line (no alive worker at all).
    pub routing_errors: AtomicU64,
    /// Workers declared dead (connection loss or failed health probe).
    pub workers_lost: AtomicU64,
}

/// Shared state of a running router: the worker set, the ring, and the
/// in-flight ledger behind zero-loss failover.
pub struct RouterCore {
    pub upstreams: Vec<Arc<Upstream>>,
    ring: Ring,
    pub replicas: usize,
    seq: AtomicU64,
    pub metrics: RouterMetrics,
    shutting_down: AtomicBool,
}

impl RouterCore {
    /// Connect a persistent job connection to every worker and spawn
    /// its reply-reader thread.  Fails if any worker is unreachable —
    /// the cluster starts whole; degradation is a runtime event.
    pub fn connect(addrs: &[String], replicas: usize) -> Result<Arc<Self>> {
        anyhow::ensure!(!addrs.is_empty(), "router needs at least one worker");
        let replicas = replicas.clamp(1, addrs.len());
        let mut upstreams = Vec::with_capacity(addrs.len());
        let mut readers = Vec::with_capacity(addrs.len());
        for (i, addr) in addrs.iter().enumerate() {
            let (up, read_half) = Upstream::connect(addr, i)?;
            upstreams.push(Arc::new(up));
            readers.push(read_half);
        }
        let core = Arc::new(Self {
            ring: Ring::new(upstreams.len()),
            upstreams,
            replicas,
            seq: AtomicU64::new(0),
            metrics: RouterMetrics::default(),
            shutting_down: AtomicBool::new(false),
        });
        for (i, read_half) in readers.into_iter().enumerate() {
            let c = Arc::clone(&core);
            thread::spawn(move || reader_loop(c, i, read_half));
        }
        Ok(core)
    }

    pub fn is_shutting_down(&self) -> bool {
        self.shutting_down.load(Ordering::SeqCst)
    }

    pub fn alive_count(&self) -> usize {
        self.upstreams.iter().filter(|u| u.alive()).count()
    }

    pub fn pending_total(&self) -> usize {
        self.upstreams.iter().map(|u| u.pending_len()).sum()
    }

    /// Route one sampling job: hash its (rung class, shape) bucket onto
    /// the ring and forward to the least-loaded alive replica.
    pub fn route_job(&self, spec: JobSpec, reply: Sender<String>) {
        self.metrics.jobs_routed.fetch_add(1, Ordering::Relaxed);
        let class = if spec.wants_scalar() {
            "a2"
        } else if spec.wants_multispin() {
            "m1"
        } else if spec.wants_accel() {
            "accel"
        } else {
            "c1" // C-rung lane batching (the batcher's own bucket axis)
        };
        let shape = spec.shape();
        let key = bucket_key(class, shape.width, shape.height, shape.layers);
        let rid = self.next_rid();
        let client_id = spec.id.clone();
        let mut forward = spec;
        forward.id = format!("r{rid}");
        self.forward(PendingForward {
            rid,
            client_id,
            forward_line: forward.to_line(),
            bucket: Some(key),
            reply,
            tried: Vec::new(),
            min_retry_ms: None,
        });
    }

    /// Route one full-run job: runs are not lane-batched, so they skip
    /// the ring and go to the globally least-loaded alive worker.
    pub fn route_run(&self, job: RunJob, reply: Sender<String>) {
        self.metrics.runs_routed.fetch_add(1, Ordering::Relaxed);
        let rid = self.next_rid();
        let client_id = job.id.clone();
        let mut forward = job;
        forward.id = format!("r{rid}");
        self.forward(PendingForward {
            rid,
            client_id,
            forward_line: forward.to_line(),
            bucket: None,
            reply,
            tried: Vec::new(),
            min_retry_ms: None,
        });
    }

    fn next_rid(&self) -> u64 {
        self.seq.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// The untried alive candidates for `pending`, least-in-flight
    /// first.  Bucketed jobs draw from the ring's replica set (ring
    /// order breaks in-flight ties — stable sort), run jobs from the
    /// whole alive set.
    fn candidates(&self, pending: &PendingForward) -> Vec<usize> {
        let alive = |w: usize| self.upstreams[w].alive();
        let mut c: Vec<usize> = match pending.bucket {
            Some(key) => self.ring.replicas(key, self.replicas, alive),
            None => (0..self.upstreams.len()).filter(|&w| alive(w)).collect(),
        };
        c.retain(|w| !pending.tried.contains(w));
        c.sort_by_key(|&w| self.upstreams[w].in_flight.load(Ordering::Relaxed));
        c
    }

    /// Forward `pending` to its best candidate, registering it in the
    /// upstream's ledger *before* the write so the reply (or the
    /// worker's death) can always find it.
    pub(crate) fn forward(&self, mut pending: PendingForward) {
        loop {
            let Some(&w) = self.candidates(&pending).first() else {
                return self.finish_unroutable(pending);
            };
            pending.tried.push(w);
            let up = &self.upstreams[w];
            let rid = pending.rid;
            let line = pending.forward_line.clone();
            up.in_flight.fetch_add(1, Ordering::Relaxed);
            lock(&up.pending).insert(rid, pending);
            if up.send_line(&line) {
                return;
            }
            // The write failed: reclaim our entry (unless a concurrent
            // death-drain already replayed it), declare the worker dead,
            // and try the next candidate.
            let reclaimed = lock(&up.pending).remove(&rid);
            if reclaimed.is_some() {
                // Undo only when we still owned the entry: a concurrent
                // drain has already zeroed the gauge otherwise.
                decrement_in_flight(up);
            }
            self.worker_died(w);
            match reclaimed {
                Some(p) => pending = p,
                None => return, // death-replay already re-forwarded it
            }
        }
    }

    /// No candidate left: answer the client with the merged rejection
    /// (when at least one replica said `overloaded`) or an error line.
    fn finish_unroutable(&self, pending: PendingForward) {
        let line = match pending.min_retry_ms {
            Some(retry_ms) => {
                self.metrics.rejections.fetch_add(1, Ordering::Relaxed);
                JobResult::overloaded_line(&pending.client_id, retry_ms)
            }
            None => {
                self.metrics.routing_errors.fetch_add(1, Ordering::Relaxed);
                JobResult::error_line(
                    &pending.client_id,
                    "no alive worker can serve this job",
                )
            }
        };
        let _ = pending.reply.send(line);
    }

    /// Handle one reply line from worker `w`'s persistent connection.
    fn on_reply(&self, w: usize, line: &str) {
        let Ok(v) = Value::parse(line) else { return };
        let Some(rid) = v
            .opt("id")
            .and_then(|x| x.as_str().ok())
            .and_then(parse_rid)
        else {
            return; // not one of ours (or already failed over) — drop
        };
        let up = &self.upstreams[w];
        let Some(mut pending) = lock(&up.pending).remove(&rid) else {
            return; // duplicate after failover: first reply won
        };
        decrement_in_flight(up);
        if is_overloaded(&v) {
            // Backpressure propagation: remember the smallest backoff
            // hint, then fail over to the next untried replica.  Only
            // when every replica refuses does the client see the
            // merged rejection (in `finish_unroutable`).
            let retry = v
                .opt("retry_after_ms")
                .and_then(|x| x.as_f64().ok())
                .map(|ms| ms as u64)
                .unwrap_or(DEFAULT_RETRY_MS);
            pending.min_retry_ms =
                Some(pending.min_retry_ms.map_or(retry, |m| m.min(retry)));
            self.metrics.failovers.fetch_add(1, Ordering::Relaxed);
            self.forward(pending);
            return;
        }
        // Relay under the client's original id.  The rewrite goes
        // through the same value-exact JSON layer the worker used, so
        // result payloads (energies, magnetisations, timings) survive
        // bit-exactly.
        let mut v = v;
        if let Value::Obj(m) = &mut v {
            m.insert("id".to_string(), json::str_v(&pending.client_id));
        }
        self.metrics.replies_relayed.fetch_add(1, Ordering::Relaxed);
        let _ = pending.reply.send(v.to_string());
    }

    /// Declare worker `w` dead: close its connection, take its pending
    /// ledger, and replay every unanswered job onto survivors.  Safe to
    /// call from any thread and any number of times — the alive CAS
    /// picks one winner.
    pub fn worker_died(&self, w: usize) {
        let up = &self.upstreams[w];
        if !up.mark_dead() {
            return; // someone else is (or was) handling this death
        }
        up.close();
        let drained = up.drain_pending();
        if self.is_shutting_down() {
            // Planned teardown: no replay, but no silent drops either.
            for p in drained {
                let _ = p
                    .reply
                    .send(JobResult::error_line(&p.client_id, "router shutting down"));
            }
            return;
        }
        self.metrics.workers_lost.fetch_add(1, Ordering::Relaxed);
        eprintln!(
            "repro route: worker {} ({}) lost, replaying {} in-flight job(s)",
            w,
            up.addr,
            drained.len()
        );
        for mut p in drained {
            // Fresh attempt ledger: the dead worker is excluded by
            // liveness, and survivors that once said `overloaded` may
            // have drained since.
            p.tried.clear();
            self.metrics.replays.fetch_add(1, Ordering::Relaxed);
            self.forward(p);
        }
    }

    /// Begin teardown: stop replaying, give in-flight jobs a grace
    /// period to answer, then close every upstream connection (which
    /// unblocks the reader threads).
    pub fn shutdown(&self) {
        self.shutting_down.store(true, Ordering::SeqCst);
        let deadline = Instant::now() + Duration::from_secs(60);
        while self.pending_total() > 0 && Instant::now() < deadline {
            thread::sleep(Duration::from_millis(10));
        }
        for up in &self.upstreams {
            up.mark_dead();
            up.close();
        }
    }
}

/// `in_flight -= 1`, saturating: a concurrent death-drain stores 0, so
/// a straggling decrement must not wrap to u64::MAX and poison
/// least-in-flight selection forever.
fn decrement_in_flight(up: &Upstream) {
    let _ = up
        .in_flight
        .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |n| n.checked_sub(1));
}

/// Parse a router wire id (`r<seq>`) back to its sequence number.
fn parse_rid(id: &str) -> Option<u64> {
    id.strip_prefix('r')?.parse().ok()
}

fn is_overloaded(v: &Value) -> bool {
    v.opt("status").and_then(|x| x.as_str().ok()) == Some("error")
        && v.opt("error").and_then(|x| x.as_str().ok()) == Some("overloaded")
}

/// Drain worker `w`'s reply stream until the connection dies, then run
/// the death protocol (which replays its pending jobs).
fn reader_loop(core: Arc<RouterCore>, w: usize, stream: TcpStream) {
    use std::io::BufRead;
    let mut reader = std::io::BufReader::new(stream);
    let mut buf = String::new();
    loop {
        buf.clear();
        match reader.read_line(&mut buf) {
            Ok(0) => break,
            Ok(_) => {
                let line = buf.trim();
                if !line.is_empty() {
                    core.on_reply(w, line);
                }
            }
            Err(_) => break,
        }
    }
    core.worker_died(w);
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn rid_wire_format_roundtrips() {
        assert_eq!(parse_rid("r42"), Some(42));
        assert_eq!(parse_rid("client-7"), None);
        assert_eq!(parse_rid("r"), None);
        assert_eq!(parse_rid("rx"), None);
    }

    #[test]
    fn overload_detection_matches_the_rejection_line() {
        let line = JobResult::overloaded_line("j1", 25);
        let v = Value::parse(&line).unwrap();
        assert!(is_overloaded(&v));
        let ok = Value::parse(r#"{"id":"j1","status":"ok"}"#).unwrap();
        assert!(!is_overloaded(&ok));
        let other_err =
            Value::parse(r#"{"id":"j1","status":"error","error":"bad width"}"#).unwrap();
        assert!(!is_overloaded(&other_err));
    }

    /// An unroutable job with no overload history gets an error line;
    /// with overload history it gets the merged rejection carrying the
    /// *minimum* backoff hint.
    #[test]
    fn unroutable_jobs_answer_with_merged_rejection() {
        let core = RouterCore {
            ring: Ring::new(0),
            upstreams: Vec::new(),
            replicas: 1,
            seq: AtomicU64::new(0),
            metrics: RouterMetrics::default(),
            shutting_down: AtomicBool::new(false),
        };
        let (tx, rx) = channel();
        core.finish_unroutable(PendingForward {
            rid: 1,
            client_id: "job-a".into(),
            forward_line: String::new(),
            bucket: None,
            reply: tx.clone(),
            tried: Vec::new(),
            min_retry_ms: None,
        });
        let v = Value::parse(&rx.recv().unwrap()).unwrap();
        assert_eq!(v.get("id").unwrap().as_str().unwrap(), "job-a");
        assert_eq!(v.get("status").unwrap().as_str().unwrap(), "error");
        assert_eq!(core.metrics.routing_errors.load(Ordering::Relaxed), 1);

        core.finish_unroutable(PendingForward {
            rid: 2,
            client_id: "job-b".into(),
            forward_line: String::new(),
            bucket: None,
            reply: tx,
            tried: Vec::new(),
            min_retry_ms: Some(40),
        });
        let v = Value::parse(&rx.recv().unwrap()).unwrap();
        assert_eq!(v.get("id").unwrap().as_str().unwrap(), "job-b");
        assert_eq!(v.get("error").unwrap().as_str().unwrap(), "overloaded");
        assert_eq!(v.get("retry_after_ms").unwrap().as_f64().unwrap(), 40.0);
        assert!(v.get("protocol_version").is_ok());
        assert_eq!(core.metrics.rejections.load(Ordering::Relaxed), 1);
    }
}
