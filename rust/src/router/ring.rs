//! Consistent-hash ring over worker indices, keyed by shape bucket.
//!
//! The ring is **immutable after construction**: each worker owns
//! [`Ring::VNODES`] pseudo-random points, and a lookup walks clockwise
//! from the bucket's hash collecting the first `r` *distinct alive*
//! workers.  Death is handled at lookup time (dead workers are skipped,
//! never removed), which gives the minimal-disruption property for
//! free: a bucket whose replica set did not include the dead worker
//! resolves to exactly the same workers after the loss — only buckets
//! the dead worker owned remap, onto the next point clockwise.  The
//! distributed analogue of keeping vector lanes full: stable bucket →
//! worker placement is what lets each worker's batcher see deep,
//! uniform shape buckets.

/// FNV-1a 64-bit — dependency-free, stable across builds (placement
/// must not change under a recompile).
pub fn hash64(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The routing key of one job: its batcher bucket (torus dims × layers)
/// *plus* the rung class, so m1/accel singles hash away from the C-rung
/// lane buckets they would otherwise pollute.
pub fn bucket_key(class: &str, width: usize, height: usize, layers: usize) -> u64 {
    hash64(&format!("{class}:{width}x{height}x{layers}"))
}

/// A consistent-hash ring over `workers` worker indices.
pub struct Ring {
    /// Sorted (point hash, worker index) pairs.
    points: Vec<(u64, usize)>,
    workers: usize,
}

impl Ring {
    /// Virtual nodes per worker: enough that ownership spreads evenly
    /// over a handful of workers without making lookups expensive.
    pub const VNODES: usize = 64;

    pub fn new(workers: usize) -> Self {
        let mut points = Vec::with_capacity(workers * Self::VNODES);
        for w in 0..workers {
            for v in 0..Self::VNODES {
                points.push((hash64(&format!("worker{w}#vnode{v}")), w));
            }
        }
        points.sort_unstable();
        Self { points, workers }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// The first `r` distinct workers clockwise from `key` for which
    /// `alive` holds, in ring order (the first entry is the bucket's
    /// primary).  Returns fewer than `r` when fewer distinct alive
    /// workers exist.
    pub fn replicas(&self, key: u64, r: usize, alive: impl Fn(usize) -> bool) -> Vec<usize> {
        let mut out = Vec::with_capacity(r.min(self.workers));
        if self.points.is_empty() || r == 0 {
            return out;
        }
        let start = self.points.partition_point(|&(h, _)| h < key);
        for i in 0..self.points.len() {
            let (_, w) = self.points[(start + i) % self.points.len()];
            if alive(w) && !out.contains(&w) {
                out.push(w);
                if out.len() == r {
                    break;
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn replica_sets_are_distinct_and_sized() {
        let ring = Ring::new(4);
        for i in 0..200u64 {
            let key = hash64(&format!("bucket{i}"));
            let reps = ring.replicas(key, 2, |_| true);
            assert_eq!(reps.len(), 2);
            assert_ne!(reps[0], reps[1]);
            // More replicas than workers: every worker, once.
            let all = ring.replicas(key, 10, |_| true);
            assert_eq!(all.len(), 4);
            let mut sorted = all.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 4, "replica walk repeated a worker: {all:?}");
        }
    }

    #[test]
    fn placement_spreads_over_workers() {
        let ring = Ring::new(3);
        let mut counts = [0usize; 3];
        for i in 0..600u64 {
            let key = hash64(&format!("shape{i}"));
            counts[ring.replicas(key, 1, |_| true)[0]] += 1;
        }
        for (w, &c) in counts.iter().enumerate() {
            assert!(c > 60, "worker {w} owns only {c}/600 buckets: {counts:?}");
        }
    }

    /// The satellite contract: losing a worker remaps only the buckets
    /// that worker owned — every other bucket keeps its primary.
    #[test]
    fn worker_loss_remaps_only_the_dead_workers_buckets() {
        let ring = Ring::new(4);
        let dead = 2usize;
        let mut remapped = 0;
        for i in 0..500u64 {
            let key = bucket_key("c1", 4 + (i as usize % 6) * 2, 4, 8 + i as usize);
            let before = ring.replicas(key, 1, |_| true)[0];
            let after = ring.replicas(key, 1, |w| w != dead)[0];
            if before == dead {
                remapped += 1;
                assert_ne!(after, dead);
            } else {
                assert_eq!(after, before, "bucket {i} moved although its owner survived");
            }
        }
        assert!(remapped > 0, "the dead worker owned no buckets — test has no teeth");
    }

    /// Replica failover order is stable: the surviving members of a
    /// replica set keep their relative order when one dies.
    #[test]
    fn replica_sets_degrade_in_order() {
        let ring = Ring::new(3);
        for i in 0..100u64 {
            let key = hash64(&format!("k{i}"));
            let full = ring.replicas(key, 3, |_| true);
            let without_first = ring.replicas(key, 2, |w| w != full[0]);
            assert_eq!(without_first, vec![full[1], full[2]]);
        }
    }

    #[test]
    fn bucket_keys_separate_rung_classes() {
        // Same shape, different class: different buckets, so m1 singles
        // never ride the C-rung bucket's placement.
        assert_ne!(bucket_key("c1", 4, 4, 8), bucket_key("m1", 4, 4, 8));
        assert_ne!(bucket_key("c1", 4, 4, 8), bucket_key("accel", 4, 4, 8));
        // Same class + shape: stable.
        assert_eq!(bucket_key("c1", 4, 4, 8), bucket_key("c1", 4, 4, 8));
    }
}
