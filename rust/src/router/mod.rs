//! Shard router: a front-door tier that routes shape buckets across
//! replicated worker processes (`repro route`).
//!
//! One service instance batches jobs of equal shape onto SIMD lanes;
//! its lane-fill ratio — the serving analogue of the paper's "fraction
//! of vector width utilized" — degrades when traffic spreads thin over
//! many shapes.  The router restores bucket depth at cluster scale: it
//! consistent-hashes each job's `(rung class, torus_w, torus_h,
//! layers)` bucket onto a worker ring ([`ring`]), so all jobs of one
//! shape land on the same few workers and their batchers see deep,
//! mostly-full lane batches again, while different shapes spread over
//! the fleet.
//!
//! The tier speaks the workers' own JSON-lines protocol on both sides
//! — clients need zero changes — and adds:
//!
//! * **replication** — each bucket maps to `--replicas` workers;
//!   forwarding picks the least-in-flight one ([`forward`]),
//! * **backpressure propagation** — a worker's `overloaded` rejection
//!   moves the job to the next replica; the client sees a rejection
//!   only when *every* replica refused, carrying the smallest
//!   `retry_after_ms` seen,
//! * **zero-loss failover** — worker death (connection loss, or a
//!   failed [`health`] probe) replays that worker's unanswered jobs
//!   onto survivors; seeded jobs are bit-exact wherever they run, so
//!   replay is safe by construction,
//! * **cluster observability** — `stats`/`metrics`/`trace`/`hello`
//!   answer with exact aggregations ([`aggregate`]): counters summed,
//!   latency histograms merged bucketwise for true cluster
//!   percentiles, Prometheus samples re-labeled per worker.

pub mod aggregate;
pub mod forward;
pub mod health;
pub mod ring;
pub mod server;
pub mod upstream;

pub use forward::RouterCore;
pub use ring::{bucket_key, Ring};
pub use server::{serve, shutdown_workers, spawn_workers, SpawnedWorker};

/// Front-door configuration (`repro route` flags).
#[derive(Clone, Copy, Debug)]
pub struct RouterConfig {
    /// Workers per bucket: 1 disables replication, 2 (the default)
    /// survives any single worker loss without remapping.
    pub replicas: usize,
    /// Health-probe period in milliseconds.
    pub health_ms: u64,
}

impl Default for RouterConfig {
    fn default() -> Self {
        Self { replicas: 2, health_ms: 500 }
    }
}
