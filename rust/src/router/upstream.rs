//! One worker as seen from the router: a persistent job connection, a
//! pending-forward ledger, and liveness.
//!
//! The persistent TCP connection carries **only job lines** (submits
//! and run jobs); control ops (`stats`/`metrics`/`trace`/`hello`,
//! health probes) go over short-lived connections so aggregation can
//! never interleave with the reply stream.  Each forwarded job is
//! registered in [`Upstream::pending`] under its router-assigned id
//! before the line is written, so a reply (or the worker's death) can
//! always find the job's client reply channel — the invariant behind
//! zero-loss failover.

use std::collections::HashMap;
use std::io::Write;
use std::net::{Shutdown, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Mutex, MutexGuard};

use crate::Result;

/// Poison-tolerant lock: a panicking holder must not wedge routing.
pub(crate) fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// A job the router has forwarded (or is about to) and not yet answered
/// to its client.
pub struct PendingForward {
    /// Router-assigned wire id (`r<seq>`), unique across the cluster's
    /// lifetime — replies correlate on this, never on client ids (two
    /// clients may reuse the same id).
    pub rid: u64,
    /// The client's original id, restored into the relayed reply.
    pub client_id: String,
    /// The fully-rendered forward line (id already rewritten to
    /// `r<rid>`), reused verbatim for failover and death-replay — safe
    /// because seeded jobs are bit-exact wherever they run.
    pub forward_line: String,
    /// Consistent-hash bucket (`None` for run jobs, which go to the
    /// globally least-loaded worker).
    pub bucket: Option<u64>,
    /// The owning connection's reply channel.
    pub reply: Sender<String>,
    /// Workers already attempted (reset on death-replay: the dead
    /// worker is excluded by liveness, survivors get a fresh chance).
    pub tried: Vec<usize>,
    /// Smallest `retry_after_ms` seen across overloaded rejections —
    /// the merged hint if every replica refuses.
    pub min_retry_ms: Option<u64>,
}

/// Router-side state of one worker process.
pub struct Upstream {
    pub addr: String,
    pub index: usize,
    /// Write half of the persistent job connection (`None` after
    /// death/close).  One writer lock per forwarded line.
    writer: Mutex<Option<TcpStream>>,
    /// Forwarded-and-unanswered jobs by router id.
    pub pending: Mutex<HashMap<u64, PendingForward>>,
    /// Jobs currently forwarded to this worker — the least-in-flight
    /// replica selector reads this.
    pub in_flight: AtomicU64,
    alive: AtomicBool,
}

impl Upstream {
    /// Connect the persistent job connection; returns the upstream and
    /// the read half for the caller's reader thread.
    pub fn connect(addr: &str, index: usize) -> Result<(Self, TcpStream)> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| anyhow::anyhow!("worker {addr}: connect failed: {e}"))?;
        stream.set_nodelay(true).ok();
        let read_half = stream.try_clone()?;
        let up = Self {
            addr: addr.to_string(),
            index,
            writer: Mutex::new(Some(stream)),
            pending: Mutex::new(HashMap::new()),
            in_flight: AtomicU64::new(0),
            alive: AtomicBool::new(true),
        };
        Ok((up, read_half))
    }

    pub fn alive(&self) -> bool {
        self.alive.load(Ordering::SeqCst)
    }

    /// Mark dead; returns whether this call was the transition (the
    /// caller that wins runs the replay, everyone else backs off).
    pub fn mark_dead(&self) -> bool {
        self.alive.swap(false, Ordering::SeqCst)
    }

    /// Write one line on the persistent connection.  `false` means the
    /// connection is gone — the caller re-routes.
    pub fn send_line(&self, line: &str) -> bool {
        let mut g = lock(&self.writer);
        let Some(stream) = g.as_mut() else { return false };
        let mut framed = String::with_capacity(line.len() + 1);
        framed.push_str(line);
        framed.push('\n');
        if stream.write_all(framed.as_bytes()).is_err() {
            *g = None;
            return false;
        }
        true
    }

    /// Tear down the persistent connection (unblocks the reader thread).
    pub fn close(&self) {
        if let Some(stream) = lock(&self.writer).take() {
            let _ = stream.shutdown(Shutdown::Both);
        }
    }

    /// Take every pending forward (death-replay / shutdown drain).
    pub fn drain_pending(&self) -> Vec<PendingForward> {
        let drained: Vec<PendingForward> =
            lock(&self.pending).drain().map(|(_, p)| p).collect();
        self.in_flight.store(0, Ordering::SeqCst);
        drained
    }

    pub fn pending_len(&self) -> usize {
        lock(&self.pending).len()
    }
}
