//! AVX-512 implementations of the 16-wide primitives (x86_64).
//!
//! The same operation set as the SSE2/AVX2 backends, twice as wide again:
//! one `__m512i` / `__m512` register holds 16 lanes, so the MT19937
//! recurrence, the bit-trick exponential and the Figure-10 mask sequence
//! all run on 16 lanes per instruction.  Everything here sticks to the
//! AVX-512 *Foundation* subset (`avx512f`) — no DQ/BW/VL instructions —
//! so any AVX-512 host qualifies:
//!
//! * comparisons produce a `__mmask16` instead of a lane mask; the trait
//!   surface wants lane masks, so `k`-results are widened back through
//!   `VPBROADCASTD {z}` (`_mm512_maskz_set1_epi32`);
//! * `movemask` has no direct 512-bit form in `avx512f` (`VPMOVD2M` is
//!   DQ), so it is a signed compare-against-zero `k`-mask;
//! * float negation runs in the integer domain (`VPXORD`) because the
//!   float bitwise ops (`VXORPS zmm`) are DQ.
//!
//! These types must only be constructed after [`super::avx512_available`]
//! returned `true`; the engine builder does that runtime dispatch, and
//! hot loops run inside [`SimdU32::with_features`] so the intrinsics
//! inline into one contiguous vector loop.
//!
//! The module itself is additionally gated on the build-script-probed
//! `has_avx512_intrinsics` cfg: the `_mm512_*` intrinsics stabilized in
//! Rust 1.89, and older stable toolchains must still build this crate
//! (they negotiate the portable W=16 lanes instead).

#![allow(clippy::missing_safety_doc)]

use core::arch::x86_64::*;
use std::ops::{Add, BitAnd, BitOr, BitXor, Mul, Sub};

use super::{SimdF32, SimdU32};

/// Debug-build guard on every constructor: all `U32x16`/`F32x16` values
/// originate from a splat/zero/load/`From`, so asserting detection here
/// catches safe-code misuse on non-AVX-512 hosts before it reaches UB.
/// Release builds compile this away (the construction invariant is
/// upheld by the engine builder's runtime dispatch).
#[inline(always)]
fn debug_check_avx512() {
    debug_assert!(
        super::avx512_available(),
        "avx512::U32x16/F32x16 constructed on a host without AVX-512F — gate on \
         simd::avx512_available()"
    );
}

/// Sixteen packed `u32` lanes (one `__m512i`).
#[derive(Copy, Clone)]
pub struct U32x16(pub(crate) __m512i);

/// Sixteen packed `f32` lanes (one `__m512`).
#[derive(Copy, Clone)]
pub struct F32x16(pub(crate) __m512);

impl From<[u32; 16]> for U32x16 {
    #[inline(always)]
    fn from(a: [u32; 16]) -> Self {
        debug_check_avx512();
        // `read_unaligned` compiles to VMOVDQU64 and sidesteps the
        // `_mm512_loadu_si512` pointer-type churn across stdarch versions.
        unsafe { Self(core::ptr::read_unaligned(a.as_ptr() as *const __m512i)) }
    }
}

impl From<[f32; 16]> for F32x16 {
    #[inline(always)]
    fn from(a: [f32; 16]) -> Self {
        debug_check_avx512();
        unsafe { Self(_mm512_loadu_ps(a.as_ptr())) }
    }
}

impl U32x16 {
    /// All sixteen lanes set to `v` (VPBROADCASTD).
    #[inline(always)]
    pub fn splat(v: u32) -> Self {
        debug_check_avx512();
        unsafe { Self(_mm512_set1_epi32(v as i32)) }
    }

    #[inline(always)]
    pub fn zero() -> Self {
        debug_check_avx512();
        unsafe { Self(_mm512_setzero_si512()) }
    }

    /// Unaligned load of 16 consecutive values.
    #[inline(always)]
    pub fn load(src: &[u32]) -> Self {
        debug_check_avx512();
        debug_assert!(src.len() >= 16);
        unsafe { Self(core::ptr::read_unaligned(src.as_ptr() as *const __m512i)) }
    }

    /// Unaligned store of the 16 lanes.
    #[inline(always)]
    pub fn store(self, dst: &mut [u32]) {
        debug_assert!(dst.len() >= 16);
        unsafe { core::ptr::write_unaligned(dst.as_mut_ptr() as *mut __m512i, self.0) }
    }

    #[inline(always)]
    pub fn to_array(self) -> [u32; 16] {
        let mut out = [0u32; 16];
        unsafe { core::ptr::write_unaligned(out.as_mut_ptr() as *mut __m512i, self.0) };
        out
    }

    /// Logical shift right by a count (VPSRLD).
    #[inline(always)]
    pub fn shr(self, count: i32) -> Self {
        unsafe { Self(_mm512_srl_epi32(self.0, _mm_cvtsi32_si128(count))) }
    }

    /// Logical shift left by a count (VPSLLD).
    #[inline(always)]
    pub fn shl(self, count: i32) -> Self {
        unsafe { Self(_mm512_sll_epi32(self.0, _mm_cvtsi32_si128(count))) }
    }

    /// Wrapping lane-wise addition (VPADDD).
    #[inline(always)]
    pub fn wrapping_add(self, rhs: Self) -> Self {
        unsafe { Self(_mm512_add_epi32(self.0, rhs.0)) }
    }

    /// `mask ? a : b` per lane — the Figure-10 ternary as
    /// `(mask & a) | (andnot(mask) & b)`.
    #[inline(always)]
    pub fn select(mask: Self, a: Self, b: Self) -> Self {
        unsafe {
            Self(_mm512_or_si512(_mm512_and_si512(mask.0, a.0), _mm512_andnot_si512(mask.0, b.0)))
        }
    }

    /// Lane mask: all-ones where `(lane & 1) == 1` (VPANDD + VPCMPEQD to
    /// `k`, widened back with VPBROADCASTD {z}).
    #[inline(always)]
    pub fn lsb_mask(self) -> Self {
        unsafe {
            let one = _mm512_set1_epi32(1);
            let k = _mm512_cmpeq_epi32_mask(_mm512_and_si512(self.0, one), one);
            Self(_mm512_maskz_set1_epi32(k, -1))
        }
    }

    /// Reinterpret the 512 bits as 16 floats (no conversion).
    #[inline(always)]
    pub fn bitcast_f32(self) -> F32x16 {
        unsafe { F32x16(_mm512_castsi512_ps(self.0)) }
    }

    /// Signed-i32 lane view of a store.
    #[inline(always)]
    pub fn to_array_i32(self) -> [i32; 16] {
        self.to_array().map(|x| x as i32)
    }

    /// Convert each lane's *signed* value to f32 (VCVTDQ2PS).
    #[inline(always)]
    pub fn to_f32_from_i32(self) -> F32x16 {
        unsafe { F32x16(_mm512_cvtepi32_ps(self.0)) }
    }

    /// 16-bit mask of each lane's sign bit.  `avx512f` has no 512-bit
    /// MOVMSKPS (VPMOVD2M is DQ), so this is a signed `< 0` compare into
    /// a `k`-register — bit k of the result = sign bit of lane k.
    #[inline(always)]
    pub fn movemask(self) -> u32 {
        unsafe { _mm512_cmplt_epi32_mask(self.0, _mm512_setzero_si512()) as u32 }
    }
}

impl BitAnd for U32x16 {
    type Output = Self;
    #[inline(always)]
    fn bitand(self, rhs: Self) -> Self {
        unsafe { Self(_mm512_and_si512(self.0, rhs.0)) }
    }
}

impl BitOr for U32x16 {
    type Output = Self;
    #[inline(always)]
    fn bitor(self, rhs: Self) -> Self {
        unsafe { Self(_mm512_or_si512(self.0, rhs.0)) }
    }
}

impl BitXor for U32x16 {
    type Output = Self;
    #[inline(always)]
    fn bitxor(self, rhs: Self) -> Self {
        unsafe { Self(_mm512_xor_si512(self.0, rhs.0)) }
    }
}

impl F32x16 {
    #[inline(always)]
    pub fn splat(v: f32) -> Self {
        debug_check_avx512();
        unsafe { Self(_mm512_set1_ps(v)) }
    }

    #[inline(always)]
    pub fn zero() -> Self {
        debug_check_avx512();
        unsafe { Self(_mm512_setzero_ps()) }
    }

    #[inline(always)]
    pub fn load(src: &[f32]) -> Self {
        debug_check_avx512();
        debug_assert!(src.len() >= 16);
        unsafe { Self(_mm512_loadu_ps(src.as_ptr())) }
    }

    #[inline(always)]
    pub fn store(self, dst: &mut [f32]) {
        debug_assert!(dst.len() >= 16);
        unsafe { _mm512_storeu_ps(dst.as_mut_ptr(), self.0) }
    }

    #[inline(always)]
    pub fn to_array(self) -> [f32; 16] {
        let mut out = [0f32; 16];
        unsafe { _mm512_storeu_ps(out.as_mut_ptr(), self.0) };
        out
    }

    /// Unchecked load of 16 values at `src[off..off+16]`.
    ///
    /// # Safety
    /// Caller guarantees `off + 16 <= src.len()`.
    #[inline(always)]
    pub unsafe fn load_unchecked(src: &[f32], off: usize) -> Self {
        debug_check_avx512();
        debug_assert!(off + 16 <= src.len());
        Self(_mm512_loadu_ps(src.as_ptr().add(off)))
    }

    /// Unchecked store of the 16 lanes to `dst[off..off+16]`.
    ///
    /// # Safety
    /// Caller guarantees `off + 16 <= dst.len()`.
    #[inline(always)]
    pub unsafe fn store_unchecked(self, dst: &mut [f32], off: usize) {
        debug_assert!(off + 16 <= dst.len());
        _mm512_storeu_ps(dst.as_mut_ptr().add(off), self.0)
    }

    /// Lane mask (all-ones u32) where `self < rhs` (VCMPPS to `k` with
    /// the LT_OS predicate, widened back with VPBROADCASTD {z}).
    #[inline(always)]
    pub fn lt(self, rhs: Self) -> U32x16 {
        unsafe {
            let k = _mm512_cmp_ps_mask::<_CMP_LT_OS>(self.0, rhs.0);
            U32x16(_mm512_maskz_set1_epi32(k, -1))
        }
    }

    /// Truncating float→int conversion (VCVTTPS2DQ) — C cast semantics.
    #[inline(always)]
    pub fn to_i32_trunc(self) -> U32x16 {
        unsafe { U32x16(_mm512_cvttps_epi32(self.0)) }
    }

    /// Reinterpret the 512 bits as 16 u32 lanes (no conversion).
    #[inline(always)]
    pub fn bitcast_u32(self) -> U32x16 {
        unsafe { U32x16(_mm512_castps_si512(self.0)) }
    }

    /// Approximate reciprocal square root (VRSQRT14PS) — tighter error
    /// spec (2^-14) than the SSE/AVX RSQRTPS (1.5 * 2^-12), so the
    /// accurate-exp error bound still holds; only the `Accurate` exp
    /// mode observes the difference (the fast mode never calls this).
    #[inline(always)]
    pub fn rsqrt_approx(self) -> Self {
        unsafe { Self(_mm512_rsqrt14_ps(self.0)) }
    }

    /// Exact lane-wise square root (VSQRTPS).
    #[inline(always)]
    pub fn sqrt(self) -> Self {
        unsafe { Self(_mm512_sqrt_ps(self.0)) }
    }

    #[inline(always)]
    pub fn max(self, rhs: Self) -> Self {
        unsafe { Self(_mm512_max_ps(self.0, rhs.0)) }
    }

    #[inline(always)]
    pub fn min(self, rhs: Self) -> Self {
        unsafe { Self(_mm512_min_ps(self.0, rhs.0)) }
    }

    /// Lane-wise negation.  The 512-bit float XOR (VXORPS zmm) is an
    /// AVX-512DQ instruction, so the sign-bit flip runs in the integer
    /// domain (VPXORD) — bit-identical result.
    #[inline(always)]
    pub fn neg(self) -> Self {
        unsafe {
            let sign = _mm512_set1_epi32(i32::MIN);
            Self(_mm512_castsi512_ps(_mm512_xor_si512(_mm512_castps_si512(self.0), sign)))
        }
    }

    /// Rotate values one lane upward: `out[k] = in[(k+15) % 16]` — each
    /// value moves to the next-higher lane, lane 15 wraps to lane 0
    /// (VPERMPS, full-width lane crossing).
    #[inline(always)]
    pub fn rot_up(self) -> Self {
        unsafe {
            let idx = _mm512_setr_epi32(15, 0, 1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14);
            Self(_mm512_permutexvar_ps(idx, self.0))
        }
    }

    /// Rotate values one lane downward: `out[k] = in[(k+1) % 16]` (lane 0
    /// wraps to lane 15) — the inverse boundary wrap.
    #[inline(always)]
    pub fn rot_down(self) -> Self {
        unsafe {
            let idx = _mm512_setr_epi32(1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14, 15, 0);
            Self(_mm512_permutexvar_ps(idx, self.0))
        }
    }
}

impl Add for F32x16 {
    type Output = Self;
    #[inline(always)]
    fn add(self, rhs: Self) -> Self {
        unsafe { Self(_mm512_add_ps(self.0, rhs.0)) }
    }
}

impl Sub for F32x16 {
    type Output = Self;
    #[inline(always)]
    fn sub(self, rhs: Self) -> Self {
        unsafe { Self(_mm512_sub_ps(self.0, rhs.0)) }
    }
}

impl Mul for F32x16 {
    type Output = Self;
    #[inline(always)]
    fn mul(self, rhs: Self) -> Self {
        unsafe { Self(_mm512_mul_ps(self.0, rhs.0)) }
    }
}

// ---- width-generic trait plumbing (delegates to the inherent methods) ----

impl SimdU32 for U32x16 {
    const LANES: usize = 16;
    type F = F32x16;

    #[inline(always)]
    fn splat(v: u32) -> Self {
        U32x16::splat(v)
    }
    #[inline(always)]
    fn zero() -> Self {
        U32x16::zero()
    }
    #[inline(always)]
    fn load(src: &[u32]) -> Self {
        U32x16::load(src)
    }
    #[inline(always)]
    fn store(self, dst: &mut [u32]) {
        U32x16::store(self, dst)
    }
    #[inline(always)]
    fn shr(self, count: i32) -> Self {
        U32x16::shr(self, count)
    }
    #[inline(always)]
    fn shl(self, count: i32) -> Self {
        U32x16::shl(self, count)
    }
    #[inline(always)]
    fn wrapping_add(self, rhs: Self) -> Self {
        U32x16::wrapping_add(self, rhs)
    }
    #[inline(always)]
    fn select(mask: Self, a: Self, b: Self) -> Self {
        U32x16::select(mask, a, b)
    }
    #[inline(always)]
    fn lsb_mask(self) -> Self {
        U32x16::lsb_mask(self)
    }
    #[inline(always)]
    fn bitcast_f32(self) -> F32x16 {
        U32x16::bitcast_f32(self)
    }
    #[inline(always)]
    fn to_f32_from_i32(self) -> F32x16 {
        U32x16::to_f32_from_i32(self)
    }
    #[inline(always)]
    fn movemask(self) -> u32 {
        U32x16::movemask(self)
    }

    /// Re-enter codegen with AVX-512F enabled so the wrapped intrinsics
    /// inline into one contiguous vector loop.
    ///
    /// The debug assertion (not a runtime branch in release builds)
    /// documents the construction invariant: `U32x16` values only exist
    /// after [`super::avx512_available`] returned `true`.
    #[inline(always)]
    fn with_features<R, G: FnOnce() -> R>(f: G) -> R {
        #[target_feature(enable = "avx512f")]
        unsafe fn vectorized<R, G: FnOnce() -> R>(f: G) -> R {
            f()
        }
        debug_assert!(super::avx512_available());
        // SAFETY: callers uphold the module invariant that AVX-512F was
        // detected before any U32x16/F32x16 value was created.
        unsafe { vectorized(f) }
    }
}

impl SimdF32 for F32x16 {
    const LANES: usize = 16;
    type U = U32x16;

    #[inline(always)]
    fn splat(v: f32) -> Self {
        F32x16::splat(v)
    }
    #[inline(always)]
    fn zero() -> Self {
        F32x16::zero()
    }
    #[inline(always)]
    fn load(src: &[f32]) -> Self {
        F32x16::load(src)
    }
    #[inline(always)]
    fn store(self, dst: &mut [f32]) {
        F32x16::store(self, dst)
    }
    #[inline(always)]
    unsafe fn load_unchecked(src: &[f32], off: usize) -> Self {
        F32x16::load_unchecked(src, off)
    }
    #[inline(always)]
    unsafe fn store_unchecked(self, dst: &mut [f32], off: usize) {
        F32x16::store_unchecked(self, dst, off)
    }
    #[inline(always)]
    fn lt(self, rhs: Self) -> U32x16 {
        F32x16::lt(self, rhs)
    }
    #[inline(always)]
    fn to_i32_trunc(self) -> U32x16 {
        F32x16::to_i32_trunc(self)
    }
    #[inline(always)]
    fn bitcast_u32(self) -> U32x16 {
        F32x16::bitcast_u32(self)
    }
    #[inline(always)]
    fn rsqrt_approx(self) -> Self {
        F32x16::rsqrt_approx(self)
    }
    #[inline(always)]
    fn max(self, rhs: Self) -> Self {
        F32x16::max(self, rhs)
    }
    #[inline(always)]
    fn min(self, rhs: Self) -> Self {
        F32x16::min(self, rhs)
    }
    #[inline(always)]
    fn neg(self) -> Self {
        F32x16::neg(self)
    }
    #[inline(always)]
    fn rot_up(self) -> Self {
        F32x16::rot_up(self)
    }
    #[inline(always)]
    fn rot_down(self) -> Self {
        F32x16::rot_down(self)
    }
}
