//! SSE2 implementations of the 4-wide primitives (x86_64).
//!
//! Every method is a single instruction (or two for `select`) from the set
//! the paper's hand-written assembly uses.  SSE2 is part of the x86_64
//! baseline, so no runtime feature detection is needed — exactly the
//! "present on modern commodity CPUs since 2001" situation the paper
//! describes.

#![allow(clippy::missing_safety_doc)]

use core::arch::x86_64::*;
use std::ops::{Add, BitAnd, BitOr, BitXor, Mul, Sub};

/// Four packed `u32` lanes (one `__m128i`).
#[derive(Copy, Clone)]
pub struct U32x4(pub(crate) __m128i);

/// Four packed `f32` lanes (one `__m128`).
#[derive(Copy, Clone)]
pub struct F32x4(pub(crate) __m128);

impl From<[u32; 4]> for U32x4 {
    #[inline(always)]
    fn from(a: [u32; 4]) -> Self {
        unsafe { Self(_mm_loadu_si128(a.as_ptr() as *const __m128i)) }
    }
}

impl From<[f32; 4]> for F32x4 {
    #[inline(always)]
    fn from(a: [f32; 4]) -> Self {
        unsafe { Self(_mm_loadu_ps(a.as_ptr())) }
    }
}

impl U32x4 {
    /// All four lanes set to `v` (PSHUFD broadcast).
    #[inline(always)]
    pub fn splat(v: u32) -> Self {
        unsafe { Self(_mm_set1_epi32(v as i32)) }
    }

    #[inline(always)]
    pub fn zero() -> Self {
        unsafe { Self(_mm_setzero_si128()) }
    }

    /// Unaligned load of 4 consecutive values.
    #[inline(always)]
    pub fn load(src: &[u32]) -> Self {
        debug_assert!(src.len() >= 4);
        unsafe { Self(_mm_loadu_si128(src.as_ptr() as *const __m128i)) }
    }

    /// Unaligned store of the 4 lanes.
    #[inline(always)]
    pub fn store(self, dst: &mut [u32]) {
        debug_assert!(dst.len() >= 4);
        unsafe { _mm_storeu_si128(dst.as_mut_ptr() as *mut __m128i, self.0) }
    }

    #[inline(always)]
    pub fn to_array(self) -> [u32; 4] {
        let mut out = [0u32; 4];
        unsafe { _mm_storeu_si128(out.as_mut_ptr() as *mut __m128i, self.0) };
        out
    }

    /// Logical shift right by an immediate count (PSRLD).
    #[inline(always)]
    pub fn shr(self, count: i32) -> Self {
        unsafe { Self(_mm_srl_epi32(self.0, _mm_cvtsi32_si128(count))) }
    }

    /// Logical shift left by an immediate count (PSLLD).
    #[inline(always)]
    pub fn shl(self, count: i32) -> Self {
        unsafe { Self(_mm_sll_epi32(self.0, _mm_cvtsi32_si128(count))) }
    }

    /// Wrapping lane-wise addition (PADDD).
    #[inline(always)]
    pub fn wrapping_add(self, rhs: Self) -> Self {
        unsafe { Self(_mm_add_epi32(self.0, rhs.0)) }
    }

    /// `mask ? a : b` per lane — the paper's Figure-10 ternary: since SSE2
    /// has no blend, this is `(mask & a) | (andnot(mask) & b)`.
    #[inline(always)]
    pub fn select(mask: Self, a: Self, b: Self) -> Self {
        unsafe { Self(_mm_or_si128(_mm_and_si128(mask.0, a.0), _mm_andnot_si128(mask.0, b.0))) }
    }

    /// Lane mask: all-ones where `(lane & 1) == 1` — the MT19937 `y & 1 ?
    /// MATRIX_A : 0` condition, computed branch-free by comparing the low
    /// bit against 1 (PCMPEQD).
    #[inline(always)]
    pub fn lsb_mask(self) -> Self {
        unsafe {
            let one = _mm_set1_epi32(1);
            Self(_mm_cmpeq_epi32(_mm_and_si128(self.0, one), one))
        }
    }

    /// Reinterpret the 128 bits as 4 floats (no conversion).
    #[inline(always)]
    pub fn bitcast_f32(self) -> F32x4 {
        unsafe { F32x4(_mm_castsi128_ps(self.0)) }
    }

    /// Signed-i32 lane view of a store (for the exp trick's PADDD result).
    #[inline(always)]
    pub fn to_array_i32(self) -> [i32; 4] {
        let a = self.to_array();
        [a[0] as i32, a[1] as i32, a[2] as i32, a[3] as i32]
    }

    /// Convert each lane's *signed* value to f32 (CVTDQ2PS).
    #[inline(always)]
    pub fn to_f32_from_i32(self) -> F32x4 {
        unsafe { F32x4(_mm_cvtepi32_ps(self.0)) }
    }

    /// 4-bit mask of each lane's sign bit (MOVMSKPS) — bit k set iff the
    /// top bit of lane k is set.  Comparison results are all-ones/all-zero
    /// lanes, so this extracts a flip mask in one instruction.
    #[inline(always)]
    pub fn movemask(self) -> u32 {
        unsafe { _mm_movemask_ps(_mm_castsi128_ps(self.0)) as u32 }
    }
}

impl BitAnd for U32x4 {
    type Output = Self;
    #[inline(always)]
    fn bitand(self, rhs: Self) -> Self {
        unsafe { Self(_mm_and_si128(self.0, rhs.0)) }
    }
}

impl BitOr for U32x4 {
    type Output = Self;
    #[inline(always)]
    fn bitor(self, rhs: Self) -> Self {
        unsafe { Self(_mm_or_si128(self.0, rhs.0)) }
    }
}

impl BitXor for U32x4 {
    type Output = Self;
    #[inline(always)]
    fn bitxor(self, rhs: Self) -> Self {
        unsafe { Self(_mm_xor_si128(self.0, rhs.0)) }
    }
}

impl F32x4 {
    #[inline(always)]
    pub fn splat(v: f32) -> Self {
        unsafe { Self(_mm_set1_ps(v)) }
    }

    #[inline(always)]
    pub fn zero() -> Self {
        unsafe { Self(_mm_setzero_ps()) }
    }

    #[inline(always)]
    pub fn load(src: &[f32]) -> Self {
        debug_assert!(src.len() >= 4);
        unsafe { Self(_mm_loadu_ps(src.as_ptr())) }
    }

    #[inline(always)]
    pub fn store(self, dst: &mut [f32]) {
        debug_assert!(dst.len() >= 4);
        unsafe { _mm_storeu_ps(dst.as_mut_ptr(), self.0) }
    }

    #[inline(always)]
    pub fn to_array(self) -> [f32; 4] {
        let mut out = [0f32; 4];
        unsafe { _mm_storeu_ps(out.as_mut_ptr(), self.0) };
        out
    }

    /// Unchecked load of 4 values at `src[off..off+4]`.
    ///
    /// # Safety
    /// Caller guarantees `off + 4 <= src.len()`.
    #[inline(always)]
    pub unsafe fn load_unchecked(src: &[f32], off: usize) -> Self {
        debug_assert!(off + 4 <= src.len());
        Self(_mm_loadu_ps(src.as_ptr().add(off)))
    }

    /// Unchecked store of the 4 lanes to `dst[off..off+4]`.
    ///
    /// # Safety
    /// Caller guarantees `off + 4 <= dst.len()`.
    #[inline(always)]
    pub unsafe fn store_unchecked(self, dst: &mut [f32], off: usize) {
        debug_assert!(off + 4 <= dst.len());
        _mm_storeu_ps(dst.as_mut_ptr().add(off), self.0)
    }

    /// Lane mask (all-ones u32) where `self < rhs` (CMPLTPS).
    #[inline(always)]
    pub fn lt(self, rhs: Self) -> U32x4 {
        unsafe { U32x4(_mm_castps_si128(_mm_cmplt_ps(self.0, rhs.0))) }
    }

    /// Truncating float→int conversion (CVTTPS2DQ) — C cast semantics,
    /// matching both `x as i32` and jnp's `astype(int32)`.
    #[inline(always)]
    pub fn to_i32_trunc(self) -> U32x4 {
        unsafe { U32x4(_mm_cvttps_epi32(self.0)) }
    }

    /// Reinterpret the 128 bits as 4 u32 lanes (no conversion).
    #[inline(always)]
    pub fn bitcast_u32(self) -> U32x4 {
        unsafe { U32x4(_mm_castps_si128(self.0)) }
    }

    /// Approximate reciprocal square root (RSQRTPS) — the instruction the
    /// paper's accurate exp variant builds its 4th root from.  Max relative
    /// error 1.5 * 2^-12.
    #[inline(always)]
    pub fn rsqrt_approx(self) -> Self {
        unsafe { Self(_mm_rsqrt_ps(self.0)) }
    }

    /// Exact lane-wise square root (SQRTPS).
    #[inline(always)]
    pub fn sqrt(self) -> Self {
        unsafe { Self(_mm_sqrt_ps(self.0)) }
    }

    #[inline(always)]
    pub fn max(self, rhs: Self) -> Self {
        unsafe { Self(_mm_max_ps(self.0, rhs.0)) }
    }

    #[inline(always)]
    pub fn min(self, rhs: Self) -> Self {
        unsafe { Self(_mm_min_ps(self.0, rhs.0)) }
    }

    /// Lane-wise negation (sign-bit XOR — one PXOR).
    #[inline(always)]
    pub fn neg(self) -> Self {
        unsafe {
            Self(_mm_xor_ps(self.0, _mm_castsi128_ps(_mm_set1_epi32(i32::MIN))))
        }
    }

    /// Rotate values one lane upward: `out[k] = in[(k+3) % 4]`, i.e. each
    /// value moves to the next-higher lane (lane 3 wraps to lane 0).  Used
    /// by the A.4 boundary-row tau update: section `m` wraps to `m+1`.
    #[inline(always)]
    pub fn rot_up(self) -> Self {
        unsafe { Self(_mm_shuffle_ps::<0x93>(self.0, self.0)) }
    }

    /// Rotate values one lane downward: `out[k] = in[(k+1) % 4]` (lane 0
    /// wraps to lane 3) — the inverse boundary wrap.
    #[inline(always)]
    pub fn rot_down(self) -> Self {
        unsafe { Self(_mm_shuffle_ps::<0x39>(self.0, self.0)) }
    }
}

// ---- width-generic trait plumbing (delegates to the inherent methods) ----

impl super::SimdU32 for U32x4 {
    const LANES: usize = 4;
    type F = F32x4;

    #[inline(always)]
    fn splat(v: u32) -> Self {
        U32x4::splat(v)
    }
    #[inline(always)]
    fn zero() -> Self {
        U32x4::zero()
    }
    #[inline(always)]
    fn load(src: &[u32]) -> Self {
        U32x4::load(src)
    }
    #[inline(always)]
    fn store(self, dst: &mut [u32]) {
        U32x4::store(self, dst)
    }
    #[inline(always)]
    fn shr(self, count: i32) -> Self {
        U32x4::shr(self, count)
    }
    #[inline(always)]
    fn shl(self, count: i32) -> Self {
        U32x4::shl(self, count)
    }
    #[inline(always)]
    fn wrapping_add(self, rhs: Self) -> Self {
        U32x4::wrapping_add(self, rhs)
    }
    #[inline(always)]
    fn select(mask: Self, a: Self, b: Self) -> Self {
        U32x4::select(mask, a, b)
    }
    #[inline(always)]
    fn lsb_mask(self) -> Self {
        U32x4::lsb_mask(self)
    }
    #[inline(always)]
    fn bitcast_f32(self) -> F32x4 {
        U32x4::bitcast_f32(self)
    }
    #[inline(always)]
    fn to_f32_from_i32(self) -> F32x4 {
        U32x4::to_f32_from_i32(self)
    }
    #[inline(always)]
    fn movemask(self) -> u32 {
        U32x4::movemask(self)
    }
}

impl super::SimdF32 for F32x4 {
    const LANES: usize = 4;
    type U = U32x4;

    #[inline(always)]
    fn splat(v: f32) -> Self {
        F32x4::splat(v)
    }
    #[inline(always)]
    fn zero() -> Self {
        F32x4::zero()
    }
    #[inline(always)]
    fn load(src: &[f32]) -> Self {
        F32x4::load(src)
    }
    #[inline(always)]
    fn store(self, dst: &mut [f32]) {
        F32x4::store(self, dst)
    }
    #[inline(always)]
    unsafe fn load_unchecked(src: &[f32], off: usize) -> Self {
        F32x4::load_unchecked(src, off)
    }
    #[inline(always)]
    unsafe fn store_unchecked(self, dst: &mut [f32], off: usize) {
        F32x4::store_unchecked(self, dst, off)
    }
    #[inline(always)]
    fn lt(self, rhs: Self) -> U32x4 {
        F32x4::lt(self, rhs)
    }
    #[inline(always)]
    fn to_i32_trunc(self) -> U32x4 {
        F32x4::to_i32_trunc(self)
    }
    #[inline(always)]
    fn bitcast_u32(self) -> U32x4 {
        F32x4::bitcast_u32(self)
    }
    #[inline(always)]
    fn rsqrt_approx(self) -> Self {
        F32x4::rsqrt_approx(self)
    }
    #[inline(always)]
    fn max(self, rhs: Self) -> Self {
        F32x4::max(self, rhs)
    }
    #[inline(always)]
    fn min(self, rhs: Self) -> Self {
        F32x4::min(self, rhs)
    }
    #[inline(always)]
    fn neg(self) -> Self {
        F32x4::neg(self)
    }
    #[inline(always)]
    fn rot_up(self) -> Self {
        F32x4::rot_up(self)
    }
    #[inline(always)]
    fn rot_down(self) -> Self {
        F32x4::rot_down(self)
    }
}

impl Add for F32x4 {
    type Output = Self;
    #[inline(always)]
    fn add(self, rhs: Self) -> Self {
        unsafe { Self(_mm_add_ps(self.0, rhs.0)) }
    }
}

impl Sub for F32x4 {
    type Output = Self;
    #[inline(always)]
    fn sub(self, rhs: Self) -> Self {
        unsafe { Self(_mm_sub_ps(self.0, rhs.0)) }
    }
}

impl Mul for F32x4 {
    type Output = Self;
    #[inline(always)]
    fn mul(self, rhs: Self) -> Self {
        unsafe { Self(_mm_mul_ps(self.0, rhs.0)) }
    }
}
