//! AVX2 implementations of the 8-wide primitives (x86_64).
//!
//! The same operation set as the SSE2 backend, twice as wide: one `__m256i`
//! / `__m256` register holds a whole octet, so the paper's Figure-10 mask
//! sequence, the MT19937 recurrence, and the bit-trick exponential all run
//! on 8 lanes per instruction.  Unlike SSE2, AVX2 is *not* part of the
//! x86_64 baseline, so these types must only be constructed after
//! [`super::avx2_available`] returned `true`; the engine builder and the
//! benches do that runtime dispatch.
//!
//! The hot loops that use these wrappers run inside
//! [`SimdU32::with_features`], which re-enters codegen with
//! `#[target_feature(enable = "avx2")]` so the intrinsics inline instead of
//! staying opaque calls.

#![allow(clippy::missing_safety_doc)]

use core::arch::x86_64::*;
use std::ops::{Add, BitAnd, BitOr, BitXor, Mul, Sub};

use super::{SimdF32, SimdU32};

/// Debug-build guard on every constructor: all `U32x8`/`F32x8` values
/// originate from a splat/zero/load/`From`, so asserting detection here
/// catches safe-code misuse on non-AVX2 hosts before it reaches UB.
/// Release builds compile this away (the construction invariant is
/// upheld by the engine builder's runtime dispatch).
#[inline(always)]
fn debug_check_avx2() {
    debug_assert!(
        super::avx2_available(),
        "avx2::U32x8/F32x8 constructed on a host without AVX2 — gate on simd::avx2_available()"
    );
}

/// Eight packed `u32` lanes (one `__m256i`).
#[derive(Copy, Clone)]
pub struct U32x8(pub(crate) __m256i);

/// Eight packed `f32` lanes (one `__m256`).
#[derive(Copy, Clone)]
pub struct F32x8(pub(crate) __m256);

impl From<[u32; 8]> for U32x8 {
    #[inline(always)]
    fn from(a: [u32; 8]) -> Self {
        debug_check_avx2();
        unsafe { Self(_mm256_loadu_si256(a.as_ptr() as *const __m256i)) }
    }
}

impl From<[f32; 8]> for F32x8 {
    #[inline(always)]
    fn from(a: [f32; 8]) -> Self {
        debug_check_avx2();
        unsafe { Self(_mm256_loadu_ps(a.as_ptr())) }
    }
}

impl U32x8 {
    /// All eight lanes set to `v` (VPBROADCASTD).
    #[inline(always)]
    pub fn splat(v: u32) -> Self {
        debug_check_avx2();
        unsafe { Self(_mm256_set1_epi32(v as i32)) }
    }

    #[inline(always)]
    pub fn zero() -> Self {
        debug_check_avx2();
        unsafe { Self(_mm256_setzero_si256()) }
    }

    /// Unaligned load of 8 consecutive values.
    #[inline(always)]
    pub fn load(src: &[u32]) -> Self {
        debug_check_avx2();
        debug_assert!(src.len() >= 8);
        unsafe { Self(_mm256_loadu_si256(src.as_ptr() as *const __m256i)) }
    }

    /// Unaligned store of the 8 lanes.
    #[inline(always)]
    pub fn store(self, dst: &mut [u32]) {
        debug_assert!(dst.len() >= 8);
        unsafe { _mm256_storeu_si256(dst.as_mut_ptr() as *mut __m256i, self.0) }
    }

    #[inline(always)]
    pub fn to_array(self) -> [u32; 8] {
        let mut out = [0u32; 8];
        unsafe { _mm256_storeu_si256(out.as_mut_ptr() as *mut __m256i, self.0) };
        out
    }

    /// Logical shift right by a count (VPSRLD).
    #[inline(always)]
    pub fn shr(self, count: i32) -> Self {
        unsafe { Self(_mm256_srl_epi32(self.0, _mm_cvtsi32_si128(count))) }
    }

    /// Logical shift left by a count (VPSLLD).
    #[inline(always)]
    pub fn shl(self, count: i32) -> Self {
        unsafe { Self(_mm256_sll_epi32(self.0, _mm_cvtsi32_si128(count))) }
    }

    /// Wrapping lane-wise addition (VPADDD).
    #[inline(always)]
    pub fn wrapping_add(self, rhs: Self) -> Self {
        unsafe { Self(_mm256_add_epi32(self.0, rhs.0)) }
    }

    /// `mask ? a : b` per lane — the Figure-10 ternary as
    /// `(mask & a) | (andnot(mask) & b)`.
    #[inline(always)]
    pub fn select(mask: Self, a: Self, b: Self) -> Self {
        unsafe {
            Self(_mm256_or_si256(_mm256_and_si256(mask.0, a.0), _mm256_andnot_si256(mask.0, b.0)))
        }
    }

    /// Lane mask: all-ones where `(lane & 1) == 1` (VPAND + VPCMPEQD).
    #[inline(always)]
    pub fn lsb_mask(self) -> Self {
        unsafe {
            let one = _mm256_set1_epi32(1);
            Self(_mm256_cmpeq_epi32(_mm256_and_si256(self.0, one), one))
        }
    }

    /// Reinterpret the 256 bits as 8 floats (no conversion).
    #[inline(always)]
    pub fn bitcast_f32(self) -> F32x8 {
        unsafe { F32x8(_mm256_castsi256_ps(self.0)) }
    }

    /// Signed-i32 lane view of a store.
    #[inline(always)]
    pub fn to_array_i32(self) -> [i32; 8] {
        self.to_array().map(|x| x as i32)
    }

    /// Convert each lane's *signed* value to f32 (VCVTDQ2PS).
    #[inline(always)]
    pub fn to_f32_from_i32(self) -> F32x8 {
        unsafe { F32x8(_mm256_cvtepi32_ps(self.0)) }
    }

    /// 8-bit mask of each lane's sign bit (VMOVMSKPS).
    #[inline(always)]
    pub fn movemask(self) -> u32 {
        unsafe { _mm256_movemask_ps(_mm256_castsi256_ps(self.0)) as u32 }
    }
}

impl BitAnd for U32x8 {
    type Output = Self;
    #[inline(always)]
    fn bitand(self, rhs: Self) -> Self {
        unsafe { Self(_mm256_and_si256(self.0, rhs.0)) }
    }
}

impl BitOr for U32x8 {
    type Output = Self;
    #[inline(always)]
    fn bitor(self, rhs: Self) -> Self {
        unsafe { Self(_mm256_or_si256(self.0, rhs.0)) }
    }
}

impl BitXor for U32x8 {
    type Output = Self;
    #[inline(always)]
    fn bitxor(self, rhs: Self) -> Self {
        unsafe { Self(_mm256_xor_si256(self.0, rhs.0)) }
    }
}

impl F32x8 {
    #[inline(always)]
    pub fn splat(v: f32) -> Self {
        debug_check_avx2();
        unsafe { Self(_mm256_set1_ps(v)) }
    }

    #[inline(always)]
    pub fn zero() -> Self {
        debug_check_avx2();
        unsafe { Self(_mm256_setzero_ps()) }
    }

    #[inline(always)]
    pub fn load(src: &[f32]) -> Self {
        debug_check_avx2();
        debug_assert!(src.len() >= 8);
        unsafe { Self(_mm256_loadu_ps(src.as_ptr())) }
    }

    #[inline(always)]
    pub fn store(self, dst: &mut [f32]) {
        debug_assert!(dst.len() >= 8);
        unsafe { _mm256_storeu_ps(dst.as_mut_ptr(), self.0) }
    }

    #[inline(always)]
    pub fn to_array(self) -> [f32; 8] {
        let mut out = [0f32; 8];
        unsafe { _mm256_storeu_ps(out.as_mut_ptr(), self.0) };
        out
    }

    /// Unchecked load of 8 values at `src[off..off+8]`.
    ///
    /// # Safety
    /// Caller guarantees `off + 8 <= src.len()`.
    #[inline(always)]
    pub unsafe fn load_unchecked(src: &[f32], off: usize) -> Self {
        debug_check_avx2();
        debug_assert!(off + 8 <= src.len());
        Self(_mm256_loadu_ps(src.as_ptr().add(off)))
    }

    /// Unchecked store of the 8 lanes to `dst[off..off+8]`.
    ///
    /// # Safety
    /// Caller guarantees `off + 8 <= dst.len()`.
    #[inline(always)]
    pub unsafe fn store_unchecked(self, dst: &mut [f32], off: usize) {
        debug_assert!(off + 8 <= dst.len());
        _mm256_storeu_ps(dst.as_mut_ptr().add(off), self.0)
    }

    /// Lane mask (all-ones u32) where `self < rhs` (VCMPPS, LT_OS — the
    /// predicate `_mm_cmplt_ps` encodes).
    #[inline(always)]
    pub fn lt(self, rhs: Self) -> U32x8 {
        unsafe { U32x8(_mm256_castps_si256(_mm256_cmp_ps::<_CMP_LT_OS>(self.0, rhs.0))) }
    }

    /// Truncating float→int conversion (VCVTTPS2DQ) — C cast semantics.
    #[inline(always)]
    pub fn to_i32_trunc(self) -> U32x8 {
        unsafe { U32x8(_mm256_cvttps_epi32(self.0)) }
    }

    /// Reinterpret the 256 bits as 8 u32 lanes (no conversion).
    #[inline(always)]
    pub fn bitcast_u32(self) -> U32x8 {
        unsafe { U32x8(_mm256_castps_si256(self.0)) }
    }

    /// Approximate reciprocal square root (VRSQRTPS) — same 1.5 * 2^-12
    /// error spec as the SSE instruction.
    #[inline(always)]
    pub fn rsqrt_approx(self) -> Self {
        unsafe { Self(_mm256_rsqrt_ps(self.0)) }
    }

    /// Exact lane-wise square root (VSQRTPS).
    #[inline(always)]
    pub fn sqrt(self) -> Self {
        unsafe { Self(_mm256_sqrt_ps(self.0)) }
    }

    #[inline(always)]
    pub fn max(self, rhs: Self) -> Self {
        unsafe { Self(_mm256_max_ps(self.0, rhs.0)) }
    }

    #[inline(always)]
    pub fn min(self, rhs: Self) -> Self {
        unsafe { Self(_mm256_min_ps(self.0, rhs.0)) }
    }

    /// Lane-wise negation (sign-bit XOR — one VXORPS).
    #[inline(always)]
    pub fn neg(self) -> Self {
        unsafe { Self(_mm256_xor_ps(self.0, _mm256_castsi256_ps(_mm256_set1_epi32(i32::MIN)))) }
    }

    /// Rotate values one lane upward: `out[k] = in[(k+7) % 8]` — each value
    /// moves to the next-higher lane, lane 7 wraps to lane 0
    /// (VPERMPS crosses the 128-bit halves, which VSHUFPS cannot).
    #[inline(always)]
    pub fn rot_up(self) -> Self {
        unsafe {
            let idx = _mm256_setr_epi32(7, 0, 1, 2, 3, 4, 5, 6);
            Self(_mm256_permutevar8x32_ps(self.0, idx))
        }
    }

    /// Rotate values one lane downward: `out[k] = in[(k+1) % 8]` (lane 0
    /// wraps to lane 7) — the inverse boundary wrap.
    #[inline(always)]
    pub fn rot_down(self) -> Self {
        unsafe {
            let idx = _mm256_setr_epi32(1, 2, 3, 4, 5, 6, 7, 0);
            Self(_mm256_permutevar8x32_ps(self.0, idx))
        }
    }
}

impl Add for F32x8 {
    type Output = Self;
    #[inline(always)]
    fn add(self, rhs: Self) -> Self {
        unsafe { Self(_mm256_add_ps(self.0, rhs.0)) }
    }
}

impl Sub for F32x8 {
    type Output = Self;
    #[inline(always)]
    fn sub(self, rhs: Self) -> Self {
        unsafe { Self(_mm256_sub_ps(self.0, rhs.0)) }
    }
}

impl Mul for F32x8 {
    type Output = Self;
    #[inline(always)]
    fn mul(self, rhs: Self) -> Self {
        unsafe { Self(_mm256_mul_ps(self.0, rhs.0)) }
    }
}

// ---- width-generic trait plumbing (delegates to the inherent methods) ----

impl SimdU32 for U32x8 {
    const LANES: usize = 8;
    type F = F32x8;

    #[inline(always)]
    fn splat(v: u32) -> Self {
        U32x8::splat(v)
    }
    #[inline(always)]
    fn zero() -> Self {
        U32x8::zero()
    }
    #[inline(always)]
    fn load(src: &[u32]) -> Self {
        U32x8::load(src)
    }
    #[inline(always)]
    fn store(self, dst: &mut [u32]) {
        U32x8::store(self, dst)
    }
    #[inline(always)]
    fn shr(self, count: i32) -> Self {
        U32x8::shr(self, count)
    }
    #[inline(always)]
    fn shl(self, count: i32) -> Self {
        U32x8::shl(self, count)
    }
    #[inline(always)]
    fn wrapping_add(self, rhs: Self) -> Self {
        U32x8::wrapping_add(self, rhs)
    }
    #[inline(always)]
    fn select(mask: Self, a: Self, b: Self) -> Self {
        U32x8::select(mask, a, b)
    }
    #[inline(always)]
    fn lsb_mask(self) -> Self {
        U32x8::lsb_mask(self)
    }
    #[inline(always)]
    fn bitcast_f32(self) -> F32x8 {
        U32x8::bitcast_f32(self)
    }
    #[inline(always)]
    fn to_f32_from_i32(self) -> F32x8 {
        U32x8::to_f32_from_i32(self)
    }
    #[inline(always)]
    fn movemask(self) -> u32 {
        U32x8::movemask(self)
    }

    /// Re-enter codegen with AVX2 enabled so the wrapped intrinsics
    /// inline into one contiguous vector loop.
    ///
    /// The debug assertion (not a runtime branch in release builds)
    /// documents the construction invariant: `U32x8` values only exist
    /// after [`super::avx2_available`] returned `true`.
    #[inline(always)]
    fn with_features<R, G: FnOnce() -> R>(f: G) -> R {
        #[target_feature(enable = "avx2")]
        unsafe fn vectorized<R, G: FnOnce() -> R>(f: G) -> R {
            f()
        }
        debug_assert!(super::avx2_available());
        // SAFETY: callers uphold the module invariant that AVX2 was
        // detected before any U32x8/F32x8 value was created.
        unsafe { vectorized(f) }
    }
}

impl SimdF32 for F32x8 {
    const LANES: usize = 8;
    type U = U32x8;

    #[inline(always)]
    fn splat(v: f32) -> Self {
        F32x8::splat(v)
    }
    #[inline(always)]
    fn zero() -> Self {
        F32x8::zero()
    }
    #[inline(always)]
    fn load(src: &[f32]) -> Self {
        F32x8::load(src)
    }
    #[inline(always)]
    fn store(self, dst: &mut [f32]) {
        F32x8::store(self, dst)
    }
    #[inline(always)]
    unsafe fn load_unchecked(src: &[f32], off: usize) -> Self {
        F32x8::load_unchecked(src, off)
    }
    #[inline(always)]
    unsafe fn store_unchecked(self, dst: &mut [f32], off: usize) {
        F32x8::store_unchecked(self, dst, off)
    }
    #[inline(always)]
    fn lt(self, rhs: Self) -> U32x8 {
        F32x8::lt(self, rhs)
    }
    #[inline(always)]
    fn to_i32_trunc(self) -> U32x8 {
        F32x8::to_i32_trunc(self)
    }
    #[inline(always)]
    fn bitcast_u32(self) -> U32x8 {
        F32x8::bitcast_u32(self)
    }
    #[inline(always)]
    fn rsqrt_approx(self) -> Self {
        F32x8::rsqrt_approx(self)
    }
    #[inline(always)]
    fn max(self, rhs: Self) -> Self {
        F32x8::max(self, rhs)
    }
    #[inline(always)]
    fn min(self, rhs: Self) -> Self {
        F32x8::min(self, rhs)
    }
    #[inline(always)]
    fn neg(self) -> Self {
        F32x8::neg(self)
    }
    #[inline(always)]
    fn rot_up(self) -> Self {
        F32x8::rot_up(self)
    }
    #[inline(always)]
    fn rot_down(self) -> Self {
        F32x8::rot_down(self)
    }
}
