//! 4-wide SIMD primitives — the substrate for the paper's §3 explicit
//! vectorization.
//!
//! The paper hand-writes SSE assembly because "C++ compilers do not yet
//! natively provide operators on 128-bit data types".  Stable Rust exposes
//! the same instructions through `core::arch::x86_64`, so [`U32x4`] and
//! [`F32x4`] are thin, safe, `#[inline(always)]` wrappers over exactly the
//! intrinsics the paper's assembly uses (PAND/POR/PXOR/PSRLD/PSLLD for the
//! Mersenne Twister, CVTTPS2DQ/PADDD/MULPS for the exponential trick,
//! CMPLTPS + mask blending for the Figure-10 ternary operator).
//!
//! A portable scalar-quad fallback keeps every other architecture working
//! (and doubles as a differential-testing oracle on x86_64).

#[cfg(target_arch = "x86_64")]
mod sse;
#[cfg(target_arch = "x86_64")]
pub use sse::{F32x4, U32x4};

#[cfg(not(target_arch = "x86_64"))]
mod portable;
#[cfg(not(target_arch = "x86_64"))]
pub use portable::{F32x4, U32x4};

// The portable implementation is always compiled on x86_64 too, as a
// differential oracle for the SSE wrappers.
#[cfg(target_arch = "x86_64")]
pub mod portable;

#[cfg(test)]
mod tests {
    use super::*;

    const US: [[u32; 4]; 4] = [
        [0, 1, 0x8000_0000, 0xffff_ffff],
        [0x9908_b0df, 0x7fff_ffff, 2, 0x1234_5678],
        [1, 1, 1, 1],
        [0xdead_beef, 0, 0xffff_fffe, 42],
    ];

    #[test]
    fn u32_bit_ops_match_scalar() {
        for a in US {
            for b in US {
                let (va, vb) = (U32x4::from(a), U32x4::from(b));
                assert_eq!((va & vb).to_array(), [a[0] & b[0], a[1] & b[1], a[2] & b[2], a[3] & b[3]]);
                assert_eq!((va | vb).to_array(), [a[0] | b[0], a[1] | b[1], a[2] | b[2], a[3] | b[3]]);
                assert_eq!((va ^ vb).to_array(), [a[0] ^ b[0], a[1] ^ b[1], a[2] ^ b[2], a[3] ^ b[3]]);
                assert_eq!(
                    va.wrapping_add(vb).to_array(),
                    [
                        a[0].wrapping_add(b[0]),
                        a[1].wrapping_add(b[1]),
                        a[2].wrapping_add(b[2]),
                        a[3].wrapping_add(b[3])
                    ]
                );
            }
        }
    }

    #[test]
    fn u32_shifts_match_scalar() {
        for a in US {
            let v = U32x4::from(a);
            for sh in [1u32, 7, 11, 15, 18, 30] {
                assert_eq!(v.shr(sh as i32).to_array(), a.map(|x| x >> sh));
                assert_eq!(v.shl(sh as i32).to_array(), a.map(|x| x << sh));
            }
        }
    }

    #[test]
    fn select_is_figure_10_ternary() {
        // mask ? a : b with an all-ones/all-zeros lane mask.
        let mask = U32x4::from([0xffff_ffff, 0, 0xffff_ffff, 0]);
        let a = U32x4::from([1, 2, 3, 4]);
        let b = U32x4::from([10, 20, 30, 40]);
        assert_eq!(U32x4::select(mask, a, b).to_array(), [1, 20, 3, 40]);
    }

    #[test]
    fn f32_arith_matches_scalar() {
        let a = F32x4::from([1.5, -2.0, 0.0, 1e8]);
        let b = F32x4::from([0.5, 4.0, -1.0, 2.0]);
        assert_eq!((a * b).to_array(), [0.75, -8.0, -0.0, 2e8]);
        assert_eq!((a + b).to_array(), [2.0, 2.0, -1.0, 1e8 + 2.0]);
        assert_eq!((a - b).to_array(), [1.0, -6.0, 1.0, 1e8 - 2.0]);
    }

    #[test]
    fn f32_compare_produces_lane_masks() {
        let a = F32x4::from([1.0, 5.0, -1.0, 2.0]);
        let b = F32x4::from([2.0, 4.0, -1.0, 3.0]);
        assert_eq!(a.lt(b).to_array(), [0xffff_ffff, 0, 0, 0xffff_ffff]);
    }

    #[test]
    fn truncating_convert_matches_as_cast() {
        let a = F32x4::from([1.9, -1.9, 123.456, -0.4]);
        assert_eq!(a.to_i32_trunc().to_array_i32(), [1, -1, 123, 0]);
    }

    #[test]
    fn bitcasts_roundtrip() {
        let a = F32x4::from([1.0, -2.5, 0.0, 3.14]);
        assert_eq!(a.bitcast_u32().bitcast_f32().to_array(), a.to_array());
        let u = U32x4::from([0x3f80_0000, 0x4000_0000, 0, 0xc000_0000]);
        assert_eq!(u.bitcast_f32().to_array(), [1.0, 2.0, 0.0, -2.0]);
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn sse_matches_portable_on_random_inputs() {
        // Differential test: every op, SSE vs the portable oracle.
        let mut st = 0x1234_5678_9abc_def0u64;
        let mut next = move || {
            st = st.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            (st >> 32) as u32
        };
        for _ in 0..2000 {
            let a: [u32; 4] = [next(), next(), next(), next()];
            let b: [u32; 4] = [next(), next(), next(), next()];
            let (sa, sb) = (U32x4::from(a), U32x4::from(b));
            let (pa, pb) = (portable::U32x4::from(a), portable::U32x4::from(b));
            assert_eq!((sa & sb).to_array(), (pa & pb).to_array());
            assert_eq!((sa | sb).to_array(), (pa | pb).to_array());
            assert_eq!((sa ^ sb).to_array(), (pa ^ pb).to_array());
            assert_eq!(sa.wrapping_add(sb).to_array(), pa.wrapping_add(pb).to_array());
            assert_eq!(sa.shr(11).to_array(), pa.shr(11).to_array());
            assert_eq!(sa.shl(7).to_array(), pa.shl(7).to_array());
            let fa = [a[0] as f32 / 1e4, a[1] as f32 / 1e4, a[2] as f32 / 1e4, a[3] as f32 / 1e4];
            let sfa = F32x4::from(fa);
            let pfa = portable::F32x4::from(fa);
            assert_eq!(sfa.to_i32_trunc().to_array_i32(), pfa.to_i32_trunc().to_array_i32());
            assert_eq!(sfa.bitcast_u32().to_array(), pfa.bitcast_u32().to_array());
        }
    }
}
