//! Width-generic SIMD primitives — the substrate for the paper's §3
//! explicit vectorization.
//!
//! The paper hand-writes 4-lane SSE assembly because "C++ compilers do not
//! yet natively provide operators on 128-bit data types".  Stable Rust
//! exposes the same instructions through `core::arch::x86_64`, and this
//! module generalizes them over the lane count `W`:
//!
//! * [`SimdU32`] / [`SimdF32`] — the operation set every backend provides
//!   (exactly the instructions the paper's assembly uses: PAND/POR/PXOR/
//!   PSRLD/PSLLD for the Mersenne Twister, CVTTPS2DQ/PADDD/MULPS for the
//!   exponential trick, CMPLTPS + mask blending for the Figure-10 ternary);
//! * [`sse`] — the 4-lane SSE2 backend (x86_64 baseline, no detection
//!   needed — the paper's "present on modern commodity CPUs since 2001");
//! * [`avx2`] — the 8-lane AVX2 backend (runtime-detected via
//!   [`avx2_available`]);
//! * [`avx512`] — the 16-lane AVX-512F backend (runtime-detected via
//!   [`avx512_available`]; additionally gated on the build-script probe
//!   `has_avx512_intrinsics`, since the `_mm512_*` intrinsics only
//!   stabilized in Rust 1.89 — older toolchains fall back to the
//!   portable 16-lane implementation);
//! * [`portable`] — const-generic scalar lanes for *any* `W`: the real
//!   implementation on non-x86_64 targets, the fallback for widths without
//!   a hand-written backend, and the differential-testing oracle.
//!
//! Code that should run at any width is written against the traits; the
//! concrete backend is chosen once at construction time (see
//! `engine::EngineBuilder`), never per operation.

use std::ops::{Add, BitAnd, BitOr, BitXor, Mul, Sub};

pub mod portable;

#[cfg(target_arch = "x86_64")]
pub mod avx2;
#[cfg(all(target_arch = "x86_64", has_avx512_intrinsics))]
pub mod avx512;
#[cfg(target_arch = "x86_64")]
pub mod sse;
#[cfg(target_arch = "x86_64")]
pub use sse::{F32x4, U32x4};

#[cfg(not(target_arch = "x86_64"))]
pub use portable::{F32x4, U32x4};

/// Upper bound on the lane count of any backend (sizes the stack buffers
/// generic code uses for per-lane fallbacks).
pub const MAX_LANES: usize = 32;

/// True when the `VECTORISING_FORCE_PORTABLE` environment variable is set
/// (to anything but `0` or the empty string): every runtime dispatch point
/// then picks the const-generic portable lanes instead of the SSE2/AVX2
/// intrinsic backends.  This is how CI exercises the portable code paths
/// on x86_64 hosts; results are bit-identical by construction, only slower.
pub fn force_portable() -> bool {
    static FORCED: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *FORCED.get_or_init(|| {
        std::env::var("VECTORISING_FORCE_PORTABLE")
            .map(|v| !v.is_empty() && v != "0")
            .unwrap_or(false)
    })
}

/// True when the 8-lane AVX2 backend can run on this host (and the
/// portable override is not in force).
pub fn avx2_available() -> bool {
    if force_portable() {
        return false;
    }
    #[cfg(target_arch = "x86_64")]
    {
        std::arch::is_x86_feature_detected!("avx2")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// True when the 16-lane AVX-512F backend can run on this host: the
/// toolchain has the stabilized `_mm512_*` intrinsics (build-script
/// probe), the CPU reports `avx512f`, and the portable override is not
/// in force.
pub fn avx512_available() -> bool {
    if force_portable() {
        return false;
    }
    #[cfg(all(target_arch = "x86_64", has_avx512_intrinsics))]
    {
        std::arch::is_x86_feature_detected!("avx512f")
    }
    #[cfg(not(all(target_arch = "x86_64", has_avx512_intrinsics)))]
    {
        false
    }
}

/// Widest lane count with a hand-written intrinsic backend on this host
/// *for the legacy `SweepKind` surface* (8 with AVX2, otherwise the
/// SSE2/portable width 4).  The 16-lane AVX-512 backend is negotiated
/// only through the engine's `SamplerSpec` width resolution (see
/// `engine::EngineBuilder`), which consults [`avx512_available`]
/// directly — the legacy kinds stop at W=8.
pub fn widest_supported_width() -> usize {
    if avx2_available() {
        8
    } else {
        4
    }
}

/// `W` unsigned 32-bit lanes — the integer half of a SIMD backend.
///
/// Implementations are thin wrappers over single instructions; every
/// method is `#[inline(always)]` so the traits add no call overhead once
/// the surrounding loop is monomorphized.
pub trait SimdU32:
    Copy + Send + Sync + 'static + BitAnd<Output = Self> + BitOr<Output = Self> + BitXor<Output = Self>
{
    /// Lane count `W`.
    const LANES: usize;
    /// The float type sharing this backend's registers.
    type F: SimdF32<U = Self>;

    fn splat(v: u32) -> Self;
    fn zero() -> Self;
    /// Unaligned load of `W` consecutive values from `src[..W]`.
    fn load(src: &[u32]) -> Self;
    /// Unaligned store of the `W` lanes to `dst[..W]`.
    fn store(self, dst: &mut [u32]);
    /// Logical shift right of every lane.
    fn shr(self, count: i32) -> Self;
    /// Logical shift left of every lane.
    fn shl(self, count: i32) -> Self;
    fn wrapping_add(self, rhs: Self) -> Self;
    /// `mask ? a : b` per lane (mask lanes all-ones or all-zero).
    fn select(mask: Self, a: Self, b: Self) -> Self;
    /// All-ones where `(lane & 1) == 1` — the MT19937 ternary mask.
    fn lsb_mask(self) -> Self;
    fn bitcast_f32(self) -> Self::F;
    /// Convert each lane's *signed* value to f32.
    fn to_f32_from_i32(self) -> Self::F;
    /// Bit k of the result = sign bit of lane k.
    fn movemask(self) -> u32;

    /// Run `f` inside a function compiled with this backend's target
    /// features enabled, so the wrapped intrinsics inline into one
    /// contiguous vector loop.  The default is a plain call (SSE2 and the
    /// portable lanes need no extra features); the AVX2 backend overrides
    /// it with an `#[target_feature(enable = "avx2")]` trampoline.
    #[inline(always)]
    fn with_features<R, G: FnOnce() -> R>(f: G) -> R {
        f()
    }
}

/// `W` `f32` lanes — the float half of a SIMD backend.
pub trait SimdF32:
    Copy + Send + Sync + 'static + Add<Output = Self> + Sub<Output = Self> + Mul<Output = Self>
{
    /// Lane count `W`.
    const LANES: usize;
    /// The integer type sharing this backend's registers.
    type U: SimdU32<F = Self>;

    fn splat(v: f32) -> Self;
    fn zero() -> Self;
    /// Unaligned load of `W` consecutive values from `src[..W]`.
    fn load(src: &[f32]) -> Self;
    /// Unaligned store of the `W` lanes to `dst[..W]`.
    fn store(self, dst: &mut [f32]);
    /// Unchecked load of `W` values at `src[off..off+W]`.
    ///
    /// # Safety
    /// Caller guarantees `off + W <= src.len()`.
    unsafe fn load_unchecked(src: &[f32], off: usize) -> Self;
    /// Unchecked store of the `W` lanes to `dst[off..off+W]`.
    ///
    /// # Safety
    /// Caller guarantees `off + W <= dst.len()`.
    unsafe fn store_unchecked(self, dst: &mut [f32], off: usize);
    /// Lane mask (all-ones u32) where `self < rhs`.
    fn lt(self, rhs: Self) -> Self::U;
    /// Truncating float→int conversion (CVTTPS2DQ semantics).
    fn to_i32_trunc(self) -> Self::U;
    fn bitcast_u32(self) -> Self::U;
    /// Approximate reciprocal square root (RSQRTPS error spec).
    fn rsqrt_approx(self) -> Self;
    fn max(self, rhs: Self) -> Self;
    fn min(self, rhs: Self) -> Self;
    /// Lane-wise negation (sign-bit XOR).
    fn neg(self) -> Self;
    /// `out[k] = in[(k+W-1) % W]` — values move one lane up (the A.4
    /// boundary-row tau wrap: section `m` to `m+1`).
    fn rot_up(self) -> Self;
    /// `out[k] = in[(k+1) % W]` — the inverse boundary wrap.
    fn rot_down(self) -> Self;

    /// `mask ? a : b` on float payloads (bitwise select).
    #[inline(always)]
    fn select_bits(mask: Self::U, a: Self, b: Self) -> Self {
        <Self::U as SimdU32>::select(mask, a.bitcast_u32(), b.bitcast_u32()).bitcast_f32()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const US: [[u32; 4]; 4] = [
        [0, 1, 0x8000_0000, 0xffff_ffff],
        [0x9908_b0df, 0x7fff_ffff, 2, 0x1234_5678],
        [1, 1, 1, 1],
        [0xdead_beef, 0, 0xffff_fffe, 42],
    ];

    #[test]
    fn u32_bit_ops_match_scalar() {
        for a in US {
            for b in US {
                let (va, vb) = (U32x4::from(a), U32x4::from(b));
                assert_eq!((va & vb).to_array(), [a[0] & b[0], a[1] & b[1], a[2] & b[2], a[3] & b[3]]);
                assert_eq!((va | vb).to_array(), [a[0] | b[0], a[1] | b[1], a[2] | b[2], a[3] | b[3]]);
                assert_eq!((va ^ vb).to_array(), [a[0] ^ b[0], a[1] ^ b[1], a[2] ^ b[2], a[3] ^ b[3]]);
                assert_eq!(
                    va.wrapping_add(vb).to_array(),
                    [
                        a[0].wrapping_add(b[0]),
                        a[1].wrapping_add(b[1]),
                        a[2].wrapping_add(b[2]),
                        a[3].wrapping_add(b[3])
                    ]
                );
            }
        }
    }

    #[test]
    fn u32_shifts_match_scalar() {
        for a in US {
            let v = U32x4::from(a);
            for sh in [1u32, 7, 11, 15, 18, 30] {
                assert_eq!(v.shr(sh as i32).to_array(), a.map(|x| x >> sh));
                assert_eq!(v.shl(sh as i32).to_array(), a.map(|x| x << sh));
            }
        }
    }

    #[test]
    fn select_is_figure_10_ternary() {
        // mask ? a : b with an all-ones/all-zeros lane mask.
        let mask = U32x4::from([0xffff_ffff, 0, 0xffff_ffff, 0]);
        let a = U32x4::from([1, 2, 3, 4]);
        let b = U32x4::from([10, 20, 30, 40]);
        assert_eq!(U32x4::select(mask, a, b).to_array(), [1, 20, 3, 40]);
    }

    #[test]
    fn f32_arith_matches_scalar() {
        let a = F32x4::from([1.5, -2.0, 0.0, 1e8]);
        let b = F32x4::from([0.5, 4.0, -1.0, 2.0]);
        assert_eq!((a * b).to_array(), [0.75, -8.0, -0.0, 2e8]);
        assert_eq!((a + b).to_array(), [2.0, 2.0, -1.0, 1e8 + 2.0]);
        assert_eq!((a - b).to_array(), [1.0, -6.0, 1.0, 1e8 - 2.0]);
    }

    #[test]
    fn f32_compare_produces_lane_masks() {
        let a = F32x4::from([1.0, 5.0, -1.0, 2.0]);
        let b = F32x4::from([2.0, 4.0, -1.0, 3.0]);
        assert_eq!(a.lt(b).to_array(), [0xffff_ffff, 0, 0, 0xffff_ffff]);
    }

    #[test]
    fn truncating_convert_matches_as_cast() {
        let a = F32x4::from([1.9, -1.9, 123.456, -0.4]);
        assert_eq!(a.to_i32_trunc().to_array_i32(), [1, -1, 123, 0]);
    }

    #[test]
    fn bitcasts_roundtrip() {
        let a = F32x4::from([1.0, -2.5, 0.0, 3.14]);
        assert_eq!(a.bitcast_u32().bitcast_f32().to_array(), a.to_array());
        let u = U32x4::from([0x3f80_0000, 0x4000_0000, 0, 0xc000_0000]);
        assert_eq!(u.bitcast_f32().to_array(), [1.0, 2.0, 0.0, -2.0]);
    }

    #[test]
    fn portable_rotations_generalize_to_any_width() {
        let v8 = portable::F32xN::<8>::from([0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0]);
        assert_eq!(v8.rot_up().to_array(), [7.0, 0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(v8.rot_down().to_array(), [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 0.0]);
        let v4 = portable::F32xN::<4>::from([0.0, 1.0, 2.0, 3.0]);
        assert_eq!(v4.rot_up().to_array(), [3.0, 0.0, 1.0, 2.0]);
        assert_eq!(v4.rot_down().to_array(), [1.0, 2.0, 3.0, 0.0]);
    }

    #[test]
    fn portable_w8_ops_match_scalar() {
        let mut st = 0x9e37_79b9_7f4a_7c15u64;
        let mut next = move || {
            st = st.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            (st >> 32) as u32
        };
        for _ in 0..500 {
            let a: [u32; 8] = std::array::from_fn(|_| next());
            let b: [u32; 8] = std::array::from_fn(|_| next());
            let (va, vb) = (portable::U32xN::<8>::from(a), portable::U32xN::<8>::from(b));
            assert_eq!((va & vb).to_array(), std::array::from_fn(|k| a[k] & b[k]));
            assert_eq!((va ^ vb).to_array(), std::array::from_fn(|k| a[k] ^ b[k]));
            assert_eq!(
                va.wrapping_add(vb).to_array(),
                std::array::from_fn(|k| a[k].wrapping_add(b[k]))
            );
            assert_eq!(va.shr(11).to_array(), a.map(|x| x >> 11));
            assert_eq!(va.lsb_mask().to_array(), a.map(|x| if x & 1 == 1 { !0u32 } else { 0 }));
            let expect_mm = (0..8).map(|k| (a[k] >> 31) << k).sum::<u32>();
            assert_eq!(va.movemask(), expect_mm);
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn sse_matches_portable_on_random_inputs() {
        // Differential test: every op, SSE vs the portable oracle.
        let mut st = 0x1234_5678_9abc_def0u64;
        let mut next = move || {
            st = st.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            (st >> 32) as u32
        };
        for _ in 0..2000 {
            let a: [u32; 4] = [next(), next(), next(), next()];
            let b: [u32; 4] = [next(), next(), next(), next()];
            let (sa, sb) = (U32x4::from(a), U32x4::from(b));
            let (pa, pb) = (portable::U32x4::from(a), portable::U32x4::from(b));
            assert_eq!((sa & sb).to_array(), (pa & pb).to_array());
            assert_eq!((sa | sb).to_array(), (pa | pb).to_array());
            assert_eq!((sa ^ sb).to_array(), (pa ^ pb).to_array());
            assert_eq!(sa.wrapping_add(sb).to_array(), pa.wrapping_add(pb).to_array());
            assert_eq!(sa.shr(11).to_array(), pa.shr(11).to_array());
            assert_eq!(sa.shl(7).to_array(), pa.shl(7).to_array());
            let fa = [a[0] as f32 / 1e4, a[1] as f32 / 1e4, a[2] as f32 / 1e4, a[3] as f32 / 1e4];
            let sfa = F32x4::from(fa);
            let pfa = portable::F32x4::from(fa);
            assert_eq!(sfa.to_i32_trunc().to_array_i32(), pfa.to_i32_trunc().to_array_i32());
            assert_eq!(sfa.bitcast_u32().to_array(), pfa.bitcast_u32().to_array());
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx2_matches_portable_on_random_inputs() {
        // Differential test: every op, AVX2 vs the 8-lane portable oracle.
        if !avx2_available() {
            eprintln!("skipping avx2 differential test: host has no AVX2");
            return;
        }
        let mut st = 0x0dd0_2d2a_1357_9bdfu64;
        let mut next = move || {
            st = st.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            (st >> 32) as u32
        };
        for _ in 0..2000 {
            let a: [u32; 8] = std::array::from_fn(|_| next());
            let b: [u32; 8] = std::array::from_fn(|_| next());
            let (va, vb) = (avx2::U32x8::from(a), avx2::U32x8::from(b));
            let (pa, pb) = (portable::U32xN::<8>::from(a), portable::U32xN::<8>::from(b));
            assert_eq!((va & vb).to_array(), (pa & pb).to_array());
            assert_eq!((va | vb).to_array(), (pa | pb).to_array());
            assert_eq!((va ^ vb).to_array(), (pa ^ pb).to_array());
            assert_eq!(va.wrapping_add(vb).to_array(), pa.wrapping_add(pb).to_array());
            for sh in [1, 7, 8, 11, 15, 18, 30] {
                assert_eq!(va.shr(sh).to_array(), pa.shr(sh).to_array());
                assert_eq!(va.shl(sh).to_array(), pa.shl(sh).to_array());
            }
            assert_eq!(va.lsb_mask().to_array(), pa.lsb_mask().to_array());
            assert_eq!(va.movemask(), pa.movemask());
            assert_eq!(
                avx2::U32x8::select(va.lsb_mask(), va, vb).to_array(),
                portable::U32xN::<8>::select(pa.lsb_mask(), pa, pb).to_array()
            );

            let fa: [f32; 8] = std::array::from_fn(|k| a[k] as f32 / 1e4 - 100_000.0);
            let fb: [f32; 8] = std::array::from_fn(|k| b[k] as f32 / 1e4 - 100_000.0);
            let (vfa, vfb) = (avx2::F32x8::from(fa), avx2::F32x8::from(fb));
            let (pfa, pfb) = (portable::F32xN::<8>::from(fa), portable::F32xN::<8>::from(fb));
            assert_eq!((vfa + vfb).to_array(), (pfa + pfb).to_array());
            assert_eq!((vfa - vfb).to_array(), (pfa - pfb).to_array());
            assert_eq!((vfa * vfb).to_array(), (pfa * pfb).to_array());
            assert_eq!(vfa.lt(vfb).to_array(), pfa.lt(pfb).to_array());
            assert_eq!(vfa.max(vfb).to_array(), pfa.max(pfb).to_array());
            assert_eq!(vfa.min(vfb).to_array(), pfa.min(pfb).to_array());
            assert_eq!(vfa.neg().to_array(), pfa.neg().to_array());
            assert_eq!(vfa.to_i32_trunc().to_array_i32(), pfa.to_i32_trunc().to_array_i32());
            assert_eq!(vfa.bitcast_u32().to_array(), pfa.bitcast_u32().to_array());
            assert_eq!(vfa.rot_up().to_array(), pfa.rot_up().to_array());
            assert_eq!(vfa.rot_down().to_array(), pfa.rot_down().to_array());
        }
    }

    #[cfg(all(target_arch = "x86_64", has_avx512_intrinsics))]
    #[test]
    fn avx512_matches_portable_on_random_inputs() {
        // Differential test: every op, AVX-512 vs the 16-lane portable
        // oracle.  The fast-exp / MT19937 paths only use ops covered
        // here, so lane-exactness of those kernels across backends
        // follows from this op-level equivalence.
        if !avx512_available() {
            eprintln!("skipping avx512 differential test: host has no AVX-512F");
            return;
        }
        let mut st = 0x5851_f42d_4c95_7f2du64;
        let mut next = move || {
            st = st.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            (st >> 32) as u32
        };
        for _ in 0..2000 {
            let a: [u32; 16] = std::array::from_fn(|_| next());
            let b: [u32; 16] = std::array::from_fn(|_| next());
            let (va, vb) = (avx512::U32x16::from(a), avx512::U32x16::from(b));
            let (pa, pb) = (portable::U32xN::<16>::from(a), portable::U32xN::<16>::from(b));
            assert_eq!((va & vb).to_array(), (pa & pb).to_array());
            assert_eq!((va | vb).to_array(), (pa | pb).to_array());
            assert_eq!((va ^ vb).to_array(), (pa ^ pb).to_array());
            assert_eq!(va.wrapping_add(vb).to_array(), pa.wrapping_add(pb).to_array());
            for sh in [1, 7, 8, 11, 15, 18, 30] {
                assert_eq!(va.shr(sh).to_array(), pa.shr(sh).to_array());
                assert_eq!(va.shl(sh).to_array(), pa.shl(sh).to_array());
            }
            assert_eq!(va.lsb_mask().to_array(), pa.lsb_mask().to_array());
            assert_eq!(va.movemask(), pa.movemask());
            assert_eq!(
                avx512::U32x16::select(va.lsb_mask(), va, vb).to_array(),
                portable::U32xN::<16>::select(pa.lsb_mask(), pa, pb).to_array()
            );

            let fa: [f32; 16] = std::array::from_fn(|k| a[k] as f32 / 1e4 - 100_000.0);
            let fb: [f32; 16] = std::array::from_fn(|k| b[k] as f32 / 1e4 - 100_000.0);
            let (vfa, vfb) = (avx512::F32x16::from(fa), avx512::F32x16::from(fb));
            let (pfa, pfb) = (portable::F32xN::<16>::from(fa), portable::F32xN::<16>::from(fb));
            assert_eq!((vfa + vfb).to_array(), (pfa + pfb).to_array());
            assert_eq!((vfa - vfb).to_array(), (pfa - pfb).to_array());
            assert_eq!((vfa * vfb).to_array(), (pfa * pfb).to_array());
            assert_eq!(vfa.lt(vfb).to_array(), pfa.lt(pfb).to_array());
            assert_eq!(vfa.max(vfb).to_array(), pfa.max(pfb).to_array());
            assert_eq!(vfa.min(vfb).to_array(), pfa.min(pfb).to_array());
            assert_eq!(vfa.neg().to_array(), pfa.neg().to_array());
            assert_eq!(vfa.to_i32_trunc().to_array_i32(), pfa.to_i32_trunc().to_array_i32());
            assert_eq!(vfa.bitcast_u32().to_array(), pfa.bitcast_u32().to_array());
            assert_eq!(vfa.rot_up().to_array(), pfa.rot_up().to_array());
            assert_eq!(vfa.rot_down().to_array(), pfa.rot_down().to_array());
        }
    }

    #[test]
    fn widest_width_is_sane() {
        let w = widest_supported_width();
        assert!(w == 4 || w == 8);
        if avx2_available() {
            assert_eq!(w, 8);
        }
    }
}
