//! Portable width-generic implementations of the SIMD primitives.
//!
//! [`U32xN<W>`]/[`F32xN<W>`] carry `W` scalar lanes in a plain array and
//! implement every operation with per-lane scalar code, semantics-identical
//! to the intrinsic backends (the x86_64 test suite checks this
//! differentially against both SSE2 and AVX2).  They are
//!
//! * the real implementation on non-x86_64 targets (any `W`),
//! * the universal fallback for widths without a hand-written backend
//!   (e.g. `W = 8` on x86_64 CPUs without AVX2), and
//! * the differential-testing oracle for the intrinsic backends.

use std::ops::{Add, BitAnd, BitOr, BitXor, Mul, Sub};

use super::{SimdF32, SimdU32};

/// `W` `u32` lanes.
#[derive(Copy, Clone)]
pub struct U32xN<const W: usize>(pub [u32; W]);

/// `W` `f32` lanes.
#[derive(Copy, Clone)]
pub struct F32xN<const W: usize>(pub [f32; W]);

/// The 4-lane instantiation (the paper's SSE width).
pub type U32x4 = U32xN<4>;
/// The 4-lane instantiation (the paper's SSE width).
pub type F32x4 = F32xN<4>;

/// The 8-lane instantiation (the AVX2 width).
pub type U32x8 = U32xN<8>;
/// The 8-lane instantiation (the AVX2 width).
pub type F32x8 = F32xN<8>;

impl<const W: usize> From<[u32; W]> for U32xN<W> {
    #[inline(always)]
    fn from(a: [u32; W]) -> Self {
        Self(a)
    }
}

impl<const W: usize> From<[f32; W]> for F32xN<W> {
    #[inline(always)]
    fn from(a: [f32; W]) -> Self {
        Self(a)
    }
}

impl<const W: usize> U32xN<W> {
    #[inline(always)]
    fn zip(self, rhs: Self, f: impl Fn(u32, u32) -> u32) -> Self {
        Self(std::array::from_fn(|k| f(self.0[k], rhs.0[k])))
    }

    #[inline(always)]
    pub fn splat(v: u32) -> Self {
        Self([v; W])
    }

    #[inline(always)]
    pub fn zero() -> Self {
        Self([0; W])
    }

    #[inline(always)]
    pub fn load(src: &[u32]) -> Self {
        Self(std::array::from_fn(|k| src[k]))
    }

    #[inline(always)]
    pub fn store(self, dst: &mut [u32]) {
        dst[..W].copy_from_slice(&self.0);
    }

    #[inline(always)]
    pub fn to_array(self) -> [u32; W] {
        self.0
    }

    #[inline(always)]
    pub fn shr(self, count: i32) -> Self {
        Self(self.0.map(|x| x >> count))
    }

    #[inline(always)]
    pub fn shl(self, count: i32) -> Self {
        Self(self.0.map(|x| x << count))
    }

    #[inline(always)]
    pub fn wrapping_add(self, rhs: Self) -> Self {
        self.zip(rhs, u32::wrapping_add)
    }

    #[inline(always)]
    pub fn select(mask: Self, a: Self, b: Self) -> Self {
        Self(std::array::from_fn(|k| (mask.0[k] & a.0[k]) | (!mask.0[k] & b.0[k])))
    }

    #[inline(always)]
    pub fn lsb_mask(self) -> Self {
        Self(self.0.map(|x| if x & 1 == 1 { 0xffff_ffff } else { 0 }))
    }

    #[inline(always)]
    pub fn bitcast_f32(self) -> F32xN<W> {
        F32xN(self.0.map(f32::from_bits))
    }

    #[inline(always)]
    pub fn to_array_i32(self) -> [i32; W] {
        self.0.map(|x| x as i32)
    }

    #[inline(always)]
    pub fn to_f32_from_i32(self) -> F32xN<W> {
        F32xN(self.0.map(|x| x as i32 as f32))
    }

    /// Bit k set iff the top bit of lane k is set (MOVMSKPS semantics).
    #[inline(always)]
    pub fn movemask(self) -> u32 {
        (0..W).map(|k| (self.0[k] >> 31) << k).sum()
    }
}

impl<const W: usize> BitAnd for U32xN<W> {
    type Output = Self;
    #[inline(always)]
    fn bitand(self, rhs: Self) -> Self {
        self.zip(rhs, |a, b| a & b)
    }
}

impl<const W: usize> BitOr for U32xN<W> {
    type Output = Self;
    #[inline(always)]
    fn bitor(self, rhs: Self) -> Self {
        self.zip(rhs, |a, b| a | b)
    }
}

impl<const W: usize> BitXor for U32xN<W> {
    type Output = Self;
    #[inline(always)]
    fn bitxor(self, rhs: Self) -> Self {
        self.zip(rhs, |a, b| a ^ b)
    }
}

impl<const W: usize> F32xN<W> {
    #[inline(always)]
    fn zip(self, rhs: Self, f: impl Fn(f32, f32) -> f32) -> Self {
        Self(std::array::from_fn(|k| f(self.0[k], rhs.0[k])))
    }

    #[inline(always)]
    pub fn splat(v: f32) -> Self {
        Self([v; W])
    }

    #[inline(always)]
    pub fn zero() -> Self {
        Self([0.0; W])
    }

    #[inline(always)]
    pub fn load(src: &[f32]) -> Self {
        Self(std::array::from_fn(|k| src[k]))
    }

    #[inline(always)]
    pub fn store(self, dst: &mut [f32]) {
        dst[..W].copy_from_slice(&self.0);
    }

    #[inline(always)]
    pub fn to_array(self) -> [f32; W] {
        self.0
    }

    /// Unchecked load (portable form still range-checked in debug).
    ///
    /// # Safety
    /// Caller guarantees `off + W <= src.len()`.
    #[inline(always)]
    pub unsafe fn load_unchecked(src: &[f32], off: usize) -> Self {
        debug_assert!(off + W <= src.len());
        Self(std::array::from_fn(|k| *src.get_unchecked(off + k)))
    }

    /// Unchecked store.
    ///
    /// # Safety
    /// Caller guarantees `off + W <= dst.len()`.
    #[inline(always)]
    pub unsafe fn store_unchecked(self, dst: &mut [f32], off: usize) {
        debug_assert!(off + W <= dst.len());
        for k in 0..W {
            *dst.get_unchecked_mut(off + k) = self.0[k];
        }
    }

    #[inline(always)]
    pub fn lt(self, rhs: Self) -> U32xN<W> {
        U32xN(std::array::from_fn(|k| if self.0[k] < rhs.0[k] { 0xffff_ffffu32 } else { 0 }))
    }

    /// Truncating conversion with x86 CVTTPS2DQ out-of-range semantics
    /// (0x8000_0000 for unrepresentable values — only hit outside the exp
    /// approximations' documented domains).
    #[inline(always)]
    pub fn to_i32_trunc(self) -> U32xN<W> {
        U32xN(self.0.map(|x| {
            if x.is_nan() || x >= 2_147_483_648.0 || x < -2_147_483_648.0 {
                0x8000_0000u32
            } else {
                (x as i32) as u32
            }
        }))
    }

    #[inline(always)]
    pub fn bitcast_u32(self) -> U32xN<W> {
        U32xN(self.0.map(f32::to_bits))
    }

    /// Models RSQRTPS/VRSQRTPS within its error spec using the exact
    /// computation (portable targets have no approximate instruction to
    /// match).
    #[inline(always)]
    pub fn rsqrt_approx(self) -> Self {
        Self(self.0.map(|x| 1.0 / x.sqrt()))
    }

    #[inline(always)]
    pub fn sqrt(self) -> Self {
        Self(self.0.map(f32::sqrt))
    }

    #[inline(always)]
    pub fn max(self, rhs: Self) -> Self {
        self.zip(rhs, |a, b| if a > b { a } else { b })
    }

    #[inline(always)]
    pub fn min(self, rhs: Self) -> Self {
        self.zip(rhs, |a, b| if a < b { a } else { b })
    }

    /// Lane-wise negation.
    #[inline(always)]
    pub fn neg(self) -> Self {
        Self(self.0.map(|x| f32::from_bits(x.to_bits() ^ 0x8000_0000)))
    }

    /// `out[k] = in[(k+W-1) % W]` — values move one lane up.
    #[inline(always)]
    pub fn rot_up(self) -> Self {
        Self(std::array::from_fn(|k| self.0[(k + W - 1) % W]))
    }

    /// `out[k] = in[(k+1) % W]` — values move one lane down.
    #[inline(always)]
    pub fn rot_down(self) -> Self {
        Self(std::array::from_fn(|k| self.0[(k + 1) % W]))
    }
}

impl<const W: usize> Add for F32xN<W> {
    type Output = Self;
    #[inline(always)]
    fn add(self, rhs: Self) -> Self {
        self.zip(rhs, |a, b| a + b)
    }
}

impl<const W: usize> Sub for F32xN<W> {
    type Output = Self;
    #[inline(always)]
    fn sub(self, rhs: Self) -> Self {
        self.zip(rhs, |a, b| a - b)
    }
}

impl<const W: usize> Mul for F32xN<W> {
    type Output = Self;
    #[inline(always)]
    fn mul(self, rhs: Self) -> Self {
        self.zip(rhs, |a, b| a * b)
    }
}

// ---- width-generic trait plumbing (delegates to the inherent methods) ----

impl<const W: usize> SimdU32 for U32xN<W> {
    const LANES: usize = W;
    type F = F32xN<W>;

    #[inline(always)]
    fn splat(v: u32) -> Self {
        U32xN::splat(v)
    }
    #[inline(always)]
    fn zero() -> Self {
        U32xN::zero()
    }
    #[inline(always)]
    fn load(src: &[u32]) -> Self {
        U32xN::load(src)
    }
    #[inline(always)]
    fn store(self, dst: &mut [u32]) {
        U32xN::store(self, dst)
    }
    #[inline(always)]
    fn shr(self, count: i32) -> Self {
        U32xN::shr(self, count)
    }
    #[inline(always)]
    fn shl(self, count: i32) -> Self {
        U32xN::shl(self, count)
    }
    #[inline(always)]
    fn wrapping_add(self, rhs: Self) -> Self {
        U32xN::wrapping_add(self, rhs)
    }
    #[inline(always)]
    fn select(mask: Self, a: Self, b: Self) -> Self {
        U32xN::select(mask, a, b)
    }
    #[inline(always)]
    fn lsb_mask(self) -> Self {
        U32xN::lsb_mask(self)
    }
    #[inline(always)]
    fn bitcast_f32(self) -> F32xN<W> {
        U32xN::bitcast_f32(self)
    }
    #[inline(always)]
    fn to_f32_from_i32(self) -> F32xN<W> {
        U32xN::to_f32_from_i32(self)
    }
    #[inline(always)]
    fn movemask(self) -> u32 {
        U32xN::movemask(self)
    }
}

impl<const W: usize> SimdF32 for F32xN<W> {
    const LANES: usize = W;
    type U = U32xN<W>;

    #[inline(always)]
    fn splat(v: f32) -> Self {
        F32xN::splat(v)
    }
    #[inline(always)]
    fn zero() -> Self {
        F32xN::zero()
    }
    #[inline(always)]
    fn load(src: &[f32]) -> Self {
        F32xN::load(src)
    }
    #[inline(always)]
    fn store(self, dst: &mut [f32]) {
        F32xN::store(self, dst)
    }
    #[inline(always)]
    unsafe fn load_unchecked(src: &[f32], off: usize) -> Self {
        F32xN::load_unchecked(src, off)
    }
    #[inline(always)]
    unsafe fn store_unchecked(self, dst: &mut [f32], off: usize) {
        F32xN::store_unchecked(self, dst, off)
    }
    #[inline(always)]
    fn lt(self, rhs: Self) -> U32xN<W> {
        F32xN::lt(self, rhs)
    }
    #[inline(always)]
    fn to_i32_trunc(self) -> U32xN<W> {
        F32xN::to_i32_trunc(self)
    }
    #[inline(always)]
    fn bitcast_u32(self) -> U32xN<W> {
        F32xN::bitcast_u32(self)
    }
    #[inline(always)]
    fn rsqrt_approx(self) -> Self {
        F32xN::rsqrt_approx(self)
    }
    #[inline(always)]
    fn max(self, rhs: Self) -> Self {
        F32xN::max(self, rhs)
    }
    #[inline(always)]
    fn min(self, rhs: Self) -> Self {
        F32xN::min(self, rhs)
    }
    #[inline(always)]
    fn neg(self) -> Self {
        F32xN::neg(self)
    }
    #[inline(always)]
    fn rot_up(self) -> Self {
        F32xN::rot_up(self)
    }
    #[inline(always)]
    fn rot_down(self) -> Self {
        F32xN::rot_down(self)
    }
}
