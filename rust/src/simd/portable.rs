//! Portable scalar-quad implementations of the 4-wide primitives.
//!
//! Semantics-identical to the SSE versions (the x86_64 test suite checks
//! this differentially).  Used as the real implementation on non-x86_64
//! targets and as an oracle on x86_64.

use std::ops::{Add, BitAnd, BitOr, BitXor, Mul, Sub};

/// Four `u32` lanes.
#[derive(Copy, Clone)]
pub struct U32x4(pub [u32; 4]);

/// Four `f32` lanes.
#[derive(Copy, Clone)]
pub struct F32x4(pub [f32; 4]);

impl From<[u32; 4]> for U32x4 {
    #[inline(always)]
    fn from(a: [u32; 4]) -> Self {
        Self(a)
    }
}

impl From<[f32; 4]> for F32x4 {
    #[inline(always)]
    fn from(a: [f32; 4]) -> Self {
        Self(a)
    }
}

macro_rules! lanes {
    ($a:expr, $b:expr, $op:expr) => {{
        let (a, b) = ($a, $b);
        [$op(a[0], b[0]), $op(a[1], b[1]), $op(a[2], b[2]), $op(a[3], b[3])]
    }};
}

impl U32x4 {
    #[inline(always)]
    pub fn splat(v: u32) -> Self {
        Self([v; 4])
    }

    #[inline(always)]
    pub fn zero() -> Self {
        Self([0; 4])
    }

    #[inline(always)]
    pub fn load(src: &[u32]) -> Self {
        Self([src[0], src[1], src[2], src[3]])
    }

    #[inline(always)]
    pub fn store(self, dst: &mut [u32]) {
        dst[..4].copy_from_slice(&self.0);
    }

    #[inline(always)]
    pub fn to_array(self) -> [u32; 4] {
        self.0
    }

    #[inline(always)]
    pub fn shr(self, count: i32) -> Self {
        Self(self.0.map(|x| x >> count))
    }

    #[inline(always)]
    pub fn shl(self, count: i32) -> Self {
        Self(self.0.map(|x| x << count))
    }

    #[inline(always)]
    pub fn wrapping_add(self, rhs: Self) -> Self {
        Self(lanes!(self.0, rhs.0, u32::wrapping_add))
    }

    #[inline(always)]
    pub fn select(mask: Self, a: Self, b: Self) -> Self {
        Self(lanes!(
            lanes!(mask.0, a.0, |m: u32, x: u32| m & x),
            lanes!(mask.0, b.0, |m: u32, x: u32| !m & x),
            |x: u32, y: u32| x | y
        ))
    }

    #[inline(always)]
    pub fn lsb_mask(self) -> Self {
        Self(self.0.map(|x| if x & 1 == 1 { 0xffff_ffff } else { 0 }))
    }

    #[inline(always)]
    pub fn bitcast_f32(self) -> F32x4 {
        F32x4(self.0.map(f32::from_bits))
    }

    #[inline(always)]
    pub fn to_array_i32(self) -> [i32; 4] {
        self.0.map(|x| x as i32)
    }

    #[inline(always)]
    pub fn to_f32_from_i32(self) -> F32x4 {
        F32x4(self.0.map(|x| x as i32 as f32))
    }

    /// Bit k set iff the top bit of lane k is set (MOVMSKPS semantics).
    #[inline(always)]
    pub fn movemask(self) -> u32 {
        (0..4).map(|k| ((self.0[k] >> 31) as u32) << k).sum()
    }
}

impl BitAnd for U32x4 {
    type Output = Self;
    #[inline(always)]
    fn bitand(self, rhs: Self) -> Self {
        Self(lanes!(self.0, rhs.0, |a: u32, b: u32| a & b))
    }
}

impl BitOr for U32x4 {
    type Output = Self;
    #[inline(always)]
    fn bitor(self, rhs: Self) -> Self {
        Self(lanes!(self.0, rhs.0, |a: u32, b: u32| a | b))
    }
}

impl BitXor for U32x4 {
    type Output = Self;
    #[inline(always)]
    fn bitxor(self, rhs: Self) -> Self {
        Self(lanes!(self.0, rhs.0, |a: u32, b: u32| a ^ b))
    }
}

impl F32x4 {
    #[inline(always)]
    pub fn splat(v: f32) -> Self {
        Self([v; 4])
    }

    #[inline(always)]
    pub fn zero() -> Self {
        Self([0.0; 4])
    }

    #[inline(always)]
    pub fn load(src: &[f32]) -> Self {
        Self([src[0], src[1], src[2], src[3]])
    }

    #[inline(always)]
    pub fn store(self, dst: &mut [f32]) {
        dst[..4].copy_from_slice(&self.0);
    }

    #[inline(always)]
    pub fn to_array(self) -> [f32; 4] {
        self.0
    }

    /// Unchecked load (portable form still range-checked in debug).
    ///
    /// # Safety
    /// Caller guarantees `off + 4 <= src.len()`.
    #[inline(always)]
    pub unsafe fn load_unchecked(src: &[f32], off: usize) -> Self {
        debug_assert!(off + 4 <= src.len());
        Self([
            *src.get_unchecked(off),
            *src.get_unchecked(off + 1),
            *src.get_unchecked(off + 2),
            *src.get_unchecked(off + 3),
        ])
    }

    /// Unchecked store.
    ///
    /// # Safety
    /// Caller guarantees `off + 4 <= dst.len()`.
    #[inline(always)]
    pub unsafe fn store_unchecked(self, dst: &mut [f32], off: usize) {
        debug_assert!(off + 4 <= dst.len());
        for k in 0..4 {
            *dst.get_unchecked_mut(off + k) = self.0[k];
        }
    }

    #[inline(always)]
    pub fn lt(self, rhs: Self) -> U32x4 {
        U32x4(lanes!(self.0, rhs.0, |a: f32, b: f32| if a < b { 0xffff_ffffu32 } else { 0 }))
    }

    /// Truncating conversion with x86 CVTTPS2DQ out-of-range semantics
    /// (0x8000_0000 for unrepresentable values — only hit outside the exp
    /// approximations' documented domains).
    #[inline(always)]
    pub fn to_i32_trunc(self) -> U32x4 {
        U32x4(self.0.map(|x| {
            if x.is_nan() || x >= 2_147_483_648.0 || x < -2_147_483_648.0 {
                0x8000_0000u32
            } else {
                (x as i32) as u32
            }
        }))
    }

    #[inline(always)]
    pub fn bitcast_u32(self) -> U32x4 {
        U32x4(self.0.map(f32::to_bits))
    }

    /// Models RSQRTPS within its error spec using the exact computation
    /// (portable targets have no approximate instruction to match).
    #[inline(always)]
    pub fn rsqrt_approx(self) -> Self {
        Self(self.0.map(|x| 1.0 / x.sqrt()))
    }

    #[inline(always)]
    pub fn sqrt(self) -> Self {
        Self(self.0.map(f32::sqrt))
    }

    #[inline(always)]
    pub fn max(self, rhs: Self) -> Self {
        Self(lanes!(self.0, rhs.0, |a: f32, b: f32| if a > b { a } else { b }))
    }

    #[inline(always)]
    pub fn min(self, rhs: Self) -> Self {
        Self(lanes!(self.0, rhs.0, |a: f32, b: f32| if a < b { a } else { b }))
    }

    /// Lane-wise negation.
    #[inline(always)]
    pub fn neg(self) -> Self {
        Self(self.0.map(|x| f32::from_bits(x.to_bits() ^ 0x8000_0000)))
    }

    /// `out[k] = in[(k+3) % 4]` — values move one lane up.
    #[inline(always)]
    pub fn rot_up(self) -> Self {
        let a = self.0;
        Self([a[3], a[0], a[1], a[2]])
    }

    /// `out[k] = in[(k+1) % 4]` — values move one lane down.
    #[inline(always)]
    pub fn rot_down(self) -> Self {
        let a = self.0;
        Self([a[1], a[2], a[3], a[0]])
    }
}

impl Add for F32x4 {
    type Output = Self;
    #[inline(always)]
    fn add(self, rhs: Self) -> Self {
        Self(lanes!(self.0, rhs.0, |a: f32, b: f32| a + b))
    }
}

impl Sub for F32x4 {
    type Output = Self;
    #[inline(always)]
    fn sub(self, rhs: Self) -> Self {
        Self(lanes!(self.0, rhs.0, |a: f32, b: f32| a - b))
    }
}

impl Mul for F32x4 {
    type Output = Self;
    #[inline(always)]
    fn mul(self, rhs: Self) -> Self {
        Self(lanes!(self.0, rhs.0, |a: f32, b: f32| a * b))
    }
}
