//! Memory layouts of the optimization ladder.
//!
//! [`OriginalLayout`] reproduces the paper's Figure 4 — the "complex
//! layout in memory" the original code used: a global edge table indexed
//! through per-spin incident-edge lists, a parallel `J` array, and the
//! `isATauEdge` flag array that the branchy Figure-2 loop consults.
//!
//! [`CsrLayout`] reproduces Figures 5/6 — "eliminating the middle man":
//! per-spin flat `(target_spin, J)` arrays with the two tau edges
//! reordered to the end so the flag disappears and the inner loop becomes
//! one line.

use super::model::QmcModel;

/// Figure-4 data structures (A.1).  Deliberately pointer-heavy: each
/// access pattern in the A.1 sweep goes through the same indirections the
/// paper's original code did.
#[derive(Clone)]
pub struct OriginalLayout {
    /// Per-edge endpoint pairs (global spin indices, original order).
    pub graph_edges: Vec<[u32; 2]>,
    /// Per-edge coupling, parallel to `graph_edges`.
    pub j: Vec<f32>,
    /// Per-edge tau flag, parallel to `graph_edges`.
    pub is_a_tau_edge: Vec<bool>,
    /// Per-spin list of incident edge indices (nested allocation — the
    /// "middle man" the paper later eliminates).
    pub incident_edges: Vec<Vec<u32>>,
    /// Per-spin field (h of the vertex, replicated per layer).
    pub h: Vec<f32>,
}

impl OriginalLayout {
    pub fn build(m: &QmcModel) -> Self {
        let n = m.base.n;
        let ns = m.n_spins();
        let mut graph_edges = Vec::new();
        let mut j = Vec::new();
        let mut is_tau = Vec::new();
        let mut incident: Vec<Vec<u32>> = vec![Vec::new(); ns];

        // Space edges, replicated per layer — interleaved with tau edges in
        // an arbitrary order, as in the original code ("edges can appear in
        // any order").
        for l in 0..m.n_layers {
            for &(u, v, jj) in &m.base.edges {
                let (a, b) = (m.spin_index(l, u as usize), m.spin_index(l, v as usize));
                let e = graph_edges.len() as u32;
                graph_edges.push([a as u32, b as u32]);
                j.push(jj);
                is_tau.push(false);
                incident[a].push(e);
                incident[b].push(e);
            }
            // tau edges to the next layer
            for v in 0..n {
                let (a, b) = (m.spin_index(l, v), m.spin_index((l + 1) % m.n_layers, v));
                let e = graph_edges.len() as u32;
                graph_edges.push([a as u32, b as u32]);
                j.push(m.jtau);
                is_tau.push(true);
                incident[a].push(e);
                incident[b].push(e);
            }
        }

        let mut h = Vec::with_capacity(ns);
        for _l in 0..m.n_layers {
            h.extend_from_slice(&m.base.h);
        }
        Self { graph_edges, j, is_a_tau_edge: is_tau, incident_edges: incident, h }
    }
}

/// Figure-5/6 data structures (A.2 and the scalar part of A.3): one flat
/// `(target, J)` edge array per spin, space edges first, the **two tau
/// edges always last** (paper §2.2's ahead-of-time edge reordering that
/// eliminates `isATauEdge`).
#[derive(Clone)]
pub struct CsrLayout {
    /// Edge targets, flattened; spin `i`'s edges at `offsets[i]..offsets[i+1]`.
    pub edge_target: Vec<u32>,
    /// Couplings, parallel to `edge_target`.
    pub edge_j: Vec<f32>,
    /// Per-spin slice starts (`n_spins + 1` entries).
    pub offsets: Vec<u32>,
    /// Per-spin field.
    pub h: Vec<f32>,
}

impl CsrLayout {
    pub fn build(m: &QmcModel) -> Self {
        let ns = m.n_spins();
        let adj = m.base.adjacency();
        let mut edge_target = Vec::new();
        let mut edge_j = Vec::new();
        let mut offsets = Vec::with_capacity(ns + 1);
        offsets.push(0u32);
        for l in 0..m.n_layers {
            for v in 0..m.base.n {
                for &(u, j) in &adj[v] {
                    edge_target.push(m.spin_index(l, u as usize) as u32);
                    edge_j.push(j);
                }
                // the two tau edges, always last
                let down = m.spin_index((l + m.n_layers - 1) % m.n_layers, v);
                let up = m.spin_index((l + 1) % m.n_layers, v);
                edge_target.push(down as u32);
                edge_j.push(m.jtau);
                edge_target.push(up as u32);
                edge_j.push(m.jtau);
                offsets.push(edge_target.len() as u32);
            }
        }
        let mut h = Vec::with_capacity(ns);
        for _ in 0..m.n_layers {
            h.extend_from_slice(&m.base.h);
        }
        Self { edge_target, edge_j, offsets, h }
    }

    /// Edge slice of spin `i`: space edges followed by exactly 2 tau edges.
    #[inline]
    pub fn edges_of(&self, i: usize) -> (&[u32], &[f32]) {
        let (a, b) = (self.offsets[i] as usize, self.offsets[i + 1] as usize);
        (&self.edge_target[a..b], &self.edge_j[a..b])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ising::graph::BaseGraph;

    fn model() -> QmcModel {
        let base = BaseGraph::new(3, vec![0.1, 0.2, 0.3], vec![(0, 1, 1.0), (1, 2, -1.0)]);
        QmcModel::new(base, 4, 0.5)
    }

    #[test]
    fn original_layout_counts() {
        let m = model();
        let lay = OriginalLayout::build(&m);
        // per layer: 2 space + 3 tau edges
        assert_eq!(lay.graph_edges.len(), 4 * (2 + 3));
        assert_eq!(lay.h.len(), 12);
        // vertex 1 has 2 space edges + 2 tau edges incident per layer
        assert_eq!(lay.incident_edges[m.spin_index(0, 1)].len(), 4);
        // every spin has exactly 2 incident tau edges
        for i in 0..m.n_spins() {
            let taus = lay.incident_edges[i]
                .iter()
                .filter(|&&e| lay.is_a_tau_edge[e as usize])
                .count();
            assert_eq!(taus, 2, "spin {i}");
        }
    }

    #[test]
    fn csr_layout_tau_edges_last() {
        let m = model();
        let lay = CsrLayout::build(&m);
        for i in 0..m.n_spins() {
            let (targets, js) = lay.edges_of(i);
            let k = targets.len();
            assert!(k >= 3, "spin {i} has space + 2 tau edges");
            // last two edges are tau: same vertex, adjacent layers
            let v = i % m.base.n;
            for &t in &targets[k - 2..] {
                assert_eq!(t as usize % m.base.n, v, "tau edge keeps vertex");
            }
            assert_eq!(js[k - 2], m.jtau);
            assert_eq!(js[k - 1], m.jtau);
        }
    }

    #[test]
    fn layouts_agree_on_edge_multiset() {
        // Every undirected edge appears exactly twice in CSR (once per
        // endpoint) and once in the original edge table.
        let m = model();
        let orig = OriginalLayout::build(&m);
        let csr = CsrLayout::build(&m);
        let mut orig_pairs: Vec<(u32, u32)> = orig
            .graph_edges
            .iter()
            .flat_map(|&[a, b]| [(a, b), (b, a)])
            .collect();
        let mut csr_pairs: Vec<(u32, u32)> = (0..m.n_spins())
            .flat_map(|i| {
                let (t, _) = csr.edges_of(i);
                t.iter().map(move |&u| (i as u32, u)).collect::<Vec<_>>()
            })
            .collect();
        orig_pairs.sort_unstable();
        csr_pairs.sort_unstable();
        assert_eq!(orig_pairs, csr_pairs);
    }
}
