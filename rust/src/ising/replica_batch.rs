//! Lane-per-replica memory layout — the CPU analog of the paper's §3.2
//! memory-coalescing insight, applied across the *ensemble* axis.
//!
//! The paper's real workload is 115 copies of the same Ising model at
//! different temperatures (§4).  The A.3/A.4 rungs vectorize *within* one
//! model by interlacing its layers, which requires `L % W == 0` with at
//! least two layers per section; shallow models degrade to scalar
//! sweeping.  A [`ReplicaBatchModel`] instead interleaves `W`
//! *identically-shaped* replicas lane-major — value `i` of replica `k`
//! lives at index `W*i + k` — so one vector load fetches the same spin of
//! all `W` replicas, exactly like one coalesced accelerator load fetches
//! the same spin of `W` interlaced layers:
//!
//! ```text
//! replica 0:  s0[0] s0[1] s0[2] …          ┐
//! replica 1:  s1[0] s1[1] s1[2] …          │  W independent replicas,
//!   …                                      │  identical topology
//! replica W-1: s{W-1}[0] …                 ┘
//!
//! lane-major: [s0[0] s1[0] … s{W-1}[0]] [s0[1] s1[1] … s{W-1}[1]] …
//!              └───── one vector ─────┘
//! ```
//!
//! Because the replicas never interact (tempering exchanges swap whole
//! states on the coordinator thread, between sweep rounds), every lane of
//! a vector op belongs to a different Markov chain: there are no
//! intra-group adjacency constraints at all, so *any* layer count ≥ 2
//! works — including the shallow models the A-rungs must reject.
//!
//! The per-spin edge structure is shared across lanes (identical
//! topology, via [`CsrLayout`]); couplings are interleaved lane-major so
//! replicas with different `J` realizations batch just as well.  The
//! lane-major interleave itself is the [`super::reorder::interlace_w`]
//! transpose with the replica index as the fastest-varying dimension.

use super::layout::CsrLayout;
use super::model::QmcModel;
use super::reorder::interlace_w;

/// `W` identically-shaped [`QmcModel`]s interleaved lane-major, sharing
/// one CSR edge topology (space edges first, the two tau edges last —
/// the Figure-5/6 ordering, per spin).
#[derive(Clone)]
pub struct ReplicaBatchModel {
    /// Per-lane models (lane `k`'s couplings/fields — used for energy and
    /// effective-field recomputation).
    pub models: Vec<QmcModel>,
    /// Lane count `W`.
    pub lanes: usize,
    /// Spins per replica.
    pub n_spins: usize,
    /// Shared per-spin edge slice starts (`n_spins + 1` entries).
    pub offsets: Vec<u32>,
    /// Shared edge targets (per-replica spin indices); spin `i`'s edges at
    /// `offsets[i]..offsets[i+1]`, space edges first, two tau edges last.
    pub edge_target: Vec<u32>,
    /// Lane-major couplings: edge `e` of lane `k` at `edge_j[W*e + k]`.
    pub edge_j: Vec<f32>,
}

impl ReplicaBatchModel {
    /// Batch `W = models.len()` replicas.  All models must share the same
    /// shape: spin count, layer count, and the exact CSR edge structure
    /// (targets and offsets); couplings may differ per lane.
    pub fn new(models: &[QmcModel]) -> crate::Result<Self> {
        let w = models.len();
        anyhow::ensure!(w >= 2, "a replica batch needs at least 2 lanes (got {w})");
        let lay0 = CsrLayout::build(&models[0]);
        let n_spins = models[0].n_spins();
        let n_edges = lay0.edge_target.len();
        let mut edge_j = vec![0.0f32; w * n_edges];
        for (k, m) in models.iter().enumerate() {
            anyhow::ensure!(
                m.n_spins() == n_spins && m.n_layers == models[0].n_layers,
                "replica {k}: shape mismatch ({} spins / {} layers vs {} / {})",
                m.n_spins(),
                m.n_layers,
                n_spins,
                models[0].n_layers
            );
            let lay = CsrLayout::build(m);
            anyhow::ensure!(
                lay.offsets == lay0.offsets && lay.edge_target == lay0.edge_target,
                "replica {k}: edge topology differs from replica 0"
            );
            for (e, &j) in lay.edge_j.iter().enumerate() {
                edge_j[w * e + k] = j;
            }
        }
        Ok(Self {
            models: models.to_vec(),
            lanes: w,
            n_spins,
            offsets: lay0.offsets,
            edge_target: lay0.edge_target,
            edge_j,
        })
    }

    /// Batch `lanes` copies of one model — the parallel-tempering case
    /// (identical system, per-lane temperature).
    pub fn uniform(model: &QmcModel, lanes: usize) -> crate::Result<Self> {
        Self::new(&vec![model.clone(); lanes])
    }

    /// Interleave per-lane vectors into the lane-major order.  This is the
    /// [`interlace_w`] transpose `(k, i) -> W*i + k` applied to the
    /// replica axis.
    pub fn interleave(&self, per_lane: &[Vec<f32>]) -> Vec<f32> {
        assert_eq!(per_lane.len(), self.lanes, "one vector per lane");
        let perm = interlace_w(self.n_spins, self.lanes);
        let mut out = vec![0.0f32; self.lanes * self.n_spins];
        for (k, lane) in per_lane.iter().enumerate() {
            assert_eq!(lane.len(), self.n_spins, "lane {k} length");
            for (i, &v) in lane.iter().enumerate() {
                out[perm[k * self.n_spins + i] as usize] = v;
            }
        }
        out
    }

    /// Extract lane `k`'s vector from a lane-major array.
    pub fn extract_lane(&self, batched: &[f32], lane: usize) -> Vec<f32> {
        assert!(lane < self.lanes);
        (0..self.n_spins).map(|i| batched[self.lanes * i + lane]).collect()
    }

    /// Overwrite lane `k`'s values in a lane-major array.
    pub fn scatter_lane(&self, batched: &mut [f32], lane: usize, values: &[f32]) {
        assert!(lane < self.lanes);
        assert_eq!(values.len(), self.n_spins);
        for (i, &v) in values.iter().enumerate() {
            batched[self.lanes * i + lane] = v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ising::builder::torus_workload;
    use crate::ising::graph::BaseGraph;

    #[test]
    fn uniform_batch_shares_topology_and_couplings() {
        let wl = torus_workload(4, 4, 8, 3, 0.3);
        let rb = ReplicaBatchModel::uniform(&wl.model, 4).unwrap();
        let lay = CsrLayout::build(&wl.model);
        assert_eq!(rb.offsets, lay.offsets);
        assert_eq!(rb.edge_target, lay.edge_target);
        for (e, &j) in lay.edge_j.iter().enumerate() {
            for k in 0..4 {
                assert_eq!(rb.edge_j[4 * e + k], j, "edge {e} lane {k}");
            }
        }
    }

    #[test]
    fn per_lane_couplings_are_interleaved() {
        // Same topology, different coupling realizations per lane.
        let models: Vec<QmcModel> =
            (0..4).map(|s| torus_workload(4, 4, 8, s, 0.3).model).collect();
        let rb = ReplicaBatchModel::new(&models).unwrap();
        for (k, m) in models.iter().enumerate() {
            let lay = CsrLayout::build(m);
            for (e, &j) in lay.edge_j.iter().enumerate() {
                assert_eq!(rb.edge_j[4 * e + k], j, "edge {e} lane {k}");
            }
        }
    }

    #[test]
    fn shallow_two_layer_models_batch_fine() {
        let base = BaseGraph::new(2, vec![0.1, -0.2], vec![(0, 1, 0.5)]);
        let m = QmcModel::new(base, 2, 0.3);
        let rb = ReplicaBatchModel::uniform(&m, 8).unwrap();
        assert_eq!(rb.n_spins, 4);
        assert_eq!(rb.lanes, 8);
    }

    #[test]
    fn shape_mismatch_is_rejected() {
        let a = torus_workload(4, 4, 8, 1, 0.3).model;
        let b = torus_workload(4, 4, 16, 1, 0.3).model;
        assert!(ReplicaBatchModel::new(&[a.clone(), b]).is_err());
        assert!(ReplicaBatchModel::new(&[a]).is_err()); // < 2 lanes
    }

    #[test]
    fn interleave_extract_roundtrip() {
        let wl = torus_workload(4, 4, 8, 3, 0.3);
        let rb = ReplicaBatchModel::uniform(&wl.model, 4).unwrap();
        let lanes: Vec<Vec<f32>> = (0..4)
            .map(|k| (0..rb.n_spins).map(|i| (k * 1000 + i) as f32).collect())
            .collect();
        let batched = rb.interleave(&lanes);
        // lane-major: value i of lane k at W*i + k
        assert_eq!(batched[0], 0.0);
        assert_eq!(batched[1], 1000.0);
        assert_eq!(batched[4], 1.0);
        for k in 0..4 {
            assert_eq!(rb.extract_lane(&batched, k), lanes[k], "lane {k}");
        }
        let mut b2 = batched.clone();
        rb.scatter_lane(&mut b2, 2, &lanes[0]);
        assert_eq!(rb.extract_lane(&b2, 2), lanes[0]);
        assert_eq!(rb.extract_lane(&b2, 1), lanes[1]);
    }
}
