//! Base graphs: the per-layer Ising model (vertices, fields, space edges).

/// An undirected weighted graph with per-vertex fields — one layer of a
/// QMC model.  Edges are stored once with `u < v`.
#[derive(Clone, Debug)]
pub struct BaseGraph {
    /// Number of vertices (spins per layer).
    pub n: usize,
    /// Per-vertex longitudinal field `h_v`.
    pub h: Vec<f32>,
    /// Undirected space edges `(u, v, J_uv)` with `u < v`.
    pub edges: Vec<(u32, u32, f32)>,
}

impl BaseGraph {
    /// Construct, normalising edge order and validating indices.
    pub fn new(n: usize, h: Vec<f32>, mut edges: Vec<(u32, u32, f32)>) -> Self {
        assert_eq!(h.len(), n, "field vector length mismatch");
        for e in edges.iter_mut() {
            assert!(e.0 != e.1, "self loop {e:?}");
            assert!((e.0 as usize) < n && (e.1 as usize) < n, "vertex out of range {e:?}");
            if e.0 > e.1 {
                *e = (e.1, e.0, e.2);
            }
        }
        Self { n, h, edges }
    }

    /// Adjacency lists: for each vertex, `(neighbour, J)` pairs.
    pub fn adjacency(&self) -> Vec<Vec<(u32, f32)>> {
        let mut adj = vec![Vec::new(); self.n];
        for &(u, v, j) in &self.edges {
            adj[u as usize].push((v, j));
            adj[v as usize].push((u, j));
        }
        adj
    }

    /// Maximum vertex degree (space edges only).
    pub fn max_degree(&self) -> usize {
        self.adjacency().iter().map(Vec::len).max().unwrap_or(0)
    }

    /// Greedy colouring in vertex order; returns `(colour per vertex,
    /// number of colours)`.  For bipartite graphs generated here (e.g.
    /// even tori) this finds the optimal 2-colouring, which the
    /// accelerator artifacts require.
    pub fn greedy_coloring(&self) -> (Vec<u32>, usize) {
        let adj = self.adjacency();
        let mut color = vec![u32::MAX; self.n];
        let mut n_colors = 0usize;
        for v in 0..self.n {
            let mut used = 0u64;
            for &(u, _) in &adj[v] {
                let c = color[u as usize];
                if c != u32::MAX && c < 64 {
                    used |= 1 << c;
                }
            }
            let c = (0..64).find(|&c| used & (1 << c) == 0).expect("degree < 64");
            color[v] = c as u32;
            n_colors = n_colors.max(c + 1);
        }
        (color, n_colors)
    }

    /// Check that a colouring is proper (no edge inside one class).
    pub fn is_proper_coloring(&self, color: &[u32]) -> bool {
        self.edges.iter().all(|&(u, v, _)| color[u as usize] != color[v as usize])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn path3() -> BaseGraph {
        BaseGraph::new(3, vec![0.0; 3], vec![(0, 1, 1.0), (2, 1, -0.5)])
    }

    #[test]
    fn edges_normalised() {
        let g = path3();
        assert_eq!(g.edges[1], (1, 2, -0.5));
    }

    #[test]
    fn adjacency_symmetric() {
        let g = path3();
        let adj = g.adjacency();
        assert_eq!(adj[0], vec![(1, 1.0)]);
        assert_eq!(adj[1], vec![(0, 1.0), (2, -0.5)]);
        assert_eq!(g.max_degree(), 2);
    }

    #[test]
    fn coloring_is_proper_and_minimal_on_path() {
        let g = path3();
        let (color, nc) = g.greedy_coloring();
        assert!(g.is_proper_coloring(&color));
        assert_eq!(nc, 2);
    }

    #[test]
    fn coloring_triangle_needs_three() {
        let g = BaseGraph::new(3, vec![0.0; 3], vec![(0, 1, 1.0), (1, 2, 1.0), (0, 2, 1.0)]);
        let (color, nc) = g.greedy_coloring();
        assert!(g.is_proper_coloring(&color));
        assert_eq!(nc, 3);
    }

    #[test]
    #[should_panic(expected = "self loop")]
    fn rejects_self_loops() {
        BaseGraph::new(2, vec![0.0; 2], vec![(1, 1, 1.0)]);
    }
}
