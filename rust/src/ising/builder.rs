//! Synthetic QMC workload builder (the paper's AQUA@Home workload
//! substitute — see DESIGN.md §2.2).
//!
//! Produces the structure the paper describes: each spin adjacent to 6–8
//! others (4–6 space neighbours + exactly 2 tau neighbours), L identical
//! layers, couplings and fields from the deterministic [`super::lcg::Lcg`]
//! so the python twin (`python/compile/workload.py`) can generate
//! bit-identical inputs.  The paper-scale configuration is 96 spins ×
//! 256 layers × 115 tempering replicas (§4).

use super::graph::BaseGraph;
use super::lcg::Lcg;
use super::model::QmcModel;

/// A ready-to-simulate model plus the ancillary data the accelerator path
/// needs (vertex colouring) and a reproducible initial state.
#[derive(Clone)]
pub struct Workload {
    pub model: QmcModel,
    /// Proper colouring of the base graph (accelerator checkerboard).
    pub colors: Vec<u32>,
    pub n_colors: usize,
    /// Initial ±1 state in original (layer-major) order.
    pub s0: Vec<f32>,
}

/// Toroidal `width × height` grid base graph (degree 4, bipartite when
/// both dims are even) — mirrors `workload.build_torus_workload` in
/// python, including LCG call order.
pub fn torus_workload(width: usize, height: usize, n_layers: usize, seed: u64, jtau: f32) -> Workload {
    assert!(width % 2 == 0 && height % 2 == 0, "torus dims must be even for a 2-colouring");
    let n = width * height;
    let mut rng = Lcg::new(seed);
    let vid = |x: usize, y: usize| (y % height) * width + (x % width);

    // Couplings on the canonical (+x, +y) edges, generated in (y, x) order
    // with jx before jy — identical to the python twin.
    let mut jx = vec![0.0f32; n];
    let mut jy = vec![0.0f32; n];
    for y in 0..height {
        for x in 0..width {
            jx[vid(x, y)] = rng.next_unit();
            jy[vid(x, y)] = rng.next_unit();
        }
    }
    let mut edges = Vec::with_capacity(2 * n);
    for y in 0..height {
        for x in 0..width {
            let v = vid(x, y);
            edges.push((v as u32, vid(x + 1, y) as u32, jx[v]));
            edges.push((v as u32, vid(x, y + 1) as u32, jy[v]));
        }
    }
    let h: Vec<f32> = (0..n).map(|_| rng.next_unit() * 0.5).collect();
    let base = BaseGraph::new(n, h, edges);

    let mut colors = vec![0u32; n];
    for y in 0..height {
        for x in 0..width {
            colors[vid(x, y)] = ((x + y) % 2) as u32;
        }
    }
    debug_assert!(base.is_proper_coloring(&colors));

    let model = QmcModel::new(base, n_layers, jtau);
    let mut s0 = Vec::with_capacity(model.n_spins());
    for _v in 0..n {
        for _l in 0..n_layers {
            s0.push(rng.next_sign());
        }
    }
    // The python twin generates s0 in (v, l) order for its (N, L) array;
    // convert to original (layer-major) order here.
    let mut s0_orig = vec![0.0f32; model.n_spins()];
    for v in 0..n {
        for l in 0..n_layers {
            s0_orig[l * n + v] = s0[v * n_layers + l];
        }
    }

    Workload { model, colors, n_colors: 2, s0: s0_orig }
}

/// Torus with added diagonals (degree 6 → 8 total neighbours with tau) —
/// the denser end of the paper's "6, 7, or 8" connectivity.  Not
/// bipartite; greedy colouring gives ≤ 4 classes, so this workload is for
/// the CPU rungs (the shipped accelerator artifacts bake C = 2).
pub fn diag_torus_workload(width: usize, height: usize, n_layers: usize, seed: u64, jtau: f32) -> Workload {
    assert!(width % 2 == 0 && height % 2 == 0);
    let n = width * height;
    let mut rng = Lcg::new(seed);
    let vid = |x: usize, y: usize| (y % height) * width + (x % width);

    let mut edges = Vec::with_capacity(3 * n);
    for y in 0..height {
        for x in 0..width {
            let v = vid(x, y) as u32;
            edges.push((v, vid(x + 1, y) as u32, rng.next_unit()));
            edges.push((v, vid(x, y + 1) as u32, rng.next_unit()));
            edges.push((v, vid(x + 1, y + 1) as u32, rng.next_unit()));
        }
    }
    let h: Vec<f32> = (0..n).map(|_| rng.next_unit() * 0.5).collect();
    let base = BaseGraph::new(n, h, edges);
    let (colors, n_colors) = base.greedy_coloring();
    assert!(base.is_proper_coloring(&colors));

    let model = QmcModel::new(base, n_layers, jtau);
    let mut lcg2 = Lcg::new(seed ^ 0x5eed);
    let s0 = model.random_state(&mut lcg2);
    Workload { model, colors, n_colors, s0 }
}

/// The paper's §4 benchmark geometry: 96 spins per layer (12×8 torus),
/// 256 layers → 24,576 spins per model.
pub fn paper_workload(seed: u64) -> Workload {
    torus_workload(12, 8, 256, seed, 0.3)
}

/// Toroidal grid with **±1 couplings and zero on-site fields** — the
/// discrete spin-glass workload the multi-spin rung M.1 requires (its
/// flip energies then take a handful of values, one acceptance threshold
/// per value — see `sweep::m1_multispin`).
///
/// Same graph, colouring, LCG call order and s0 conventions as
/// [`torus_workload`]; the only differences are that every coupling is
/// `±1` (sign drawn where the continuous builder draws a magnitude) and
/// `h ≡ 0` (the field draws are skipped entirely).  `jtau` should be
/// exactly representable (e.g. `0.5`) so per-bin threshold evaluation is
/// bit-equal to per-spin evaluation.
pub fn pm_torus_workload(
    width: usize,
    height: usize,
    n_layers: usize,
    seed: u64,
    jtau: f32,
) -> Workload {
    assert!(width % 2 == 0 && height % 2 == 0, "torus dims must be even for a 2-colouring");
    let n = width * height;
    let mut rng = Lcg::new(seed);
    let vid = |x: usize, y: usize| (y % height) * width + (x % width);

    // ±1 couplings on the canonical (+x, +y) edges, same (y, x) order as
    // the continuous builder.
    let mut jx = vec![0.0f32; n];
    let mut jy = vec![0.0f32; n];
    for y in 0..height {
        for x in 0..width {
            jx[vid(x, y)] = rng.next_sign();
            jy[vid(x, y)] = rng.next_sign();
        }
    }
    let mut edges = Vec::with_capacity(2 * n);
    for y in 0..height {
        for x in 0..width {
            let v = vid(x, y);
            edges.push((v as u32, vid(x + 1, y) as u32, jx[v]));
            edges.push((v as u32, vid(x, y + 1) as u32, jy[v]));
        }
    }
    let h = vec![0.0f32; n];
    let base = BaseGraph::new(n, h, edges);

    let mut colors = vec![0u32; n];
    for y in 0..height {
        for x in 0..width {
            colors[vid(x, y)] = ((x + y) % 2) as u32;
        }
    }
    debug_assert!(base.is_proper_coloring(&colors));

    let model = QmcModel::new(base, n_layers, jtau);
    let mut s0 = Vec::with_capacity(model.n_spins());
    for _v in 0..n {
        for _l in 0..n_layers {
            s0.push(rng.next_sign());
        }
    }
    let mut s0_orig = vec![0.0f32; model.n_spins()];
    for v in 0..n {
        for l in 0..n_layers {
            s0_orig[l * n + v] = s0[v * n_layers + l];
        }
    }

    Workload { model, colors, n_colors: 2, s0: s0_orig }
}

/// The §4 benchmark geometry on the ±J discrete workload (the M.1
/// benchmark input): 12×8 torus × 256 layers → 24,576 spins per model.
pub fn pm_paper_workload(seed: u64) -> Workload {
    pm_torus_workload(12, 8, 256, seed, 0.5)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn torus_degrees_and_counts() {
        let w = torus_workload(6, 4, 8, 1, 0.3);
        assert_eq!(w.model.base.n, 24);
        assert_eq!(w.model.base.edges.len(), 2 * 24);
        assert_eq!(w.model.base.max_degree(), 4);
        assert_eq!(w.model.n_spins(), 24 * 8);
        assert_eq!(w.s0.len(), w.model.n_spins());
        assert!(w.s0.iter().all(|&s| s == 1.0 || s == -1.0));
    }

    #[test]
    fn torus_coloring_proper() {
        let w = torus_workload(6, 4, 8, 1, 0.3);
        assert!(w.model.base.is_proper_coloring(&w.colors));
        assert_eq!(w.n_colors, 2);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = torus_workload(4, 4, 8, 9, 0.3);
        let b = torus_workload(4, 4, 8, 9, 0.3);
        let c = torus_workload(4, 4, 8, 10, 0.3);
        assert_eq!(a.s0, b.s0);
        assert_eq!(a.model.base.h, b.model.base.h);
        assert_ne!(a.model.base.h, c.model.base.h);
    }

    #[test]
    fn diag_torus_degree_six() {
        let w = diag_torus_workload(4, 4, 8, 2, 0.3);
        assert_eq!(w.model.base.max_degree(), 6);
        assert!(w.model.base.is_proper_coloring(&w.colors));
        assert!(w.n_colors <= 4);
    }

    #[test]
    fn paper_geometry() {
        let w = paper_workload(1);
        assert_eq!(w.model.base.n, 96);
        assert_eq!(w.model.n_layers, 256);
        assert_eq!(w.model.n_spins(), 24_576);
    }

    #[test]
    fn pm_torus_is_discrete_and_deterministic() {
        let w = pm_torus_workload(6, 4, 8, 3, 0.5);
        assert_eq!(w.model.base.n, 24);
        assert_eq!(w.model.base.edges.len(), 2 * 24);
        assert!(w.model.base.edges.iter().all(|e| e.2 == 1.0 || e.2 == -1.0));
        assert!(w.model.base.h.iter().all(|&h| h == 0.0));
        assert!(w.s0.iter().all(|&s| s == 1.0 || s == -1.0));
        assert!(w.model.base.is_proper_coloring(&w.colors));
        let b = pm_torus_workload(6, 4, 8, 3, 0.5);
        assert_eq!(w.s0, b.s0);
        let c = pm_torus_workload(6, 4, 8, 4, 0.5);
        assert_ne!(w.s0, c.s0);
        // Both coupling signs occur (a degenerate all-ferromagnet draw
        // would hide sign-handling bugs in the m1 bond masks).
        assert!(w.model.base.edges.iter().any(|e| e.2 == 1.0));
        assert!(w.model.base.edges.iter().any(|e| e.2 == -1.0));
    }

    #[test]
    fn pm_paper_geometry() {
        let w = pm_paper_workload(1);
        assert_eq!(w.model.n_spins(), 24_576);
        assert_eq!(w.model.jtau, 0.5);
    }
}
