//! Ising-model substrate: base graphs, layered QMC models, memory layouts
//! and the spin-reordering transformations of the paper.
//!
//! The paper's workload is a set of layered Ising models ("all of our
//! simulated Ising models consist of many (≥64) identical copies of a
//! smaller Ising model, with edges connecting corresponding spins in
//! adjacent layers, with a wrap-around", §3.1).  This module builds that
//! structure and the three memory layouts the optimization ladder needs:
//!
//! * [`layout::OriginalLayout`] — the paper's Figure-4 nested edge tables
//!   (A.1: `graph_edges`, `incident_edges`, `isATauEdge`, per-edge `J`);
//! * [`layout::CsrLayout`]     — the Figure-5/6 flat per-spin edge arrays
//!   with the two tau edges reordered last (A.2);
//! * [`reorder::InterlaceW`]   — the §3.1 W-way layer interlacing under
//!   which groups of corresponding spins are adjacent in memory (W = 4
//!   for the SSE rungs, W = 8 for AVX2), plus the W = L interlacing used
//!   by the accelerator artifacts (B.2);
//! * [`replica_batch::ReplicaBatchModel`] — the lane-per-replica
//!   interleave of W identically-shaped models (the C-rungs): the same
//!   coalescing idea applied across the tempering ensemble instead of
//!   across layers, so even shallow (`layers = 2`) models vectorize.

pub mod builder;
pub mod graph;
pub mod layout;
pub mod lcg;
pub mod model;
pub mod reorder;
pub mod replica_batch;

pub use builder::{
    diag_torus_workload, pm_paper_workload, pm_torus_workload, torus_workload, Workload,
};
pub use graph::BaseGraph;
pub use model::QmcModel;
pub use replica_batch::ReplicaBatchModel;
