//! Layered QMC Ising models (paper §1, §3.1).
//!
//! A [`QmcModel`] is `L` identical copies of a [`BaseGraph`] with tau
//! edges of uniform coupling `jtau` joining spin `(l, v)` to
//! `((l±1) mod L, v)`.  Spin `(l, v)` has *original-order* index
//! `l * n + v` — the layer-major order the unoptimized (A.1/A.2, B.1)
//! implementations operate in.

use super::graph::BaseGraph;
use super::lcg::Lcg;

/// A layered (path-integral) Ising model.
#[derive(Clone, Debug)]
pub struct QmcModel {
    pub base: BaseGraph,
    /// Number of layers `L` (≥ 2; tau edges wrap `L-1 → 0`).
    pub n_layers: usize,
    /// Uniform inter-layer coupling.
    pub jtau: f32,
}

impl QmcModel {
    pub fn new(base: BaseGraph, n_layers: usize, jtau: f32) -> Self {
        assert!(n_layers >= 2, "need at least 2 layers");
        Self { base, n_layers, jtau }
    }

    /// Total spin count `L * n`.
    pub fn n_spins(&self) -> usize {
        self.n_layers * self.base.n
    }

    /// Original-order index of spin `(layer, vertex)`.
    #[inline]
    pub fn spin_index(&self, layer: usize, vertex: usize) -> usize {
        layer * self.base.n + vertex
    }

    /// Random ±1 state in original order, synthesised from the given LCG.
    pub fn random_state(&self, rng: &mut Lcg) -> Vec<f32> {
        (0..self.n_spins()).map(|_| rng.next_sign()).collect()
    }

    /// Total energy of an original-order state:
    /// `E = -Σ h_v s_{l,v} - Σ_space J s s' - jtau Σ_tau s s'`.
    pub fn total_energy(&self, s: &[f32]) -> f64 {
        assert_eq!(s.len(), self.n_spins());
        let n = self.base.n;
        let mut e = 0.0f64;
        for l in 0..self.n_layers {
            let row = &s[l * n..(l + 1) * n];
            for v in 0..n {
                e -= self.base.h[v] as f64 * row[v] as f64;
            }
            for &(u, v, j) in &self.base.edges {
                e -= j as f64 * row[u as usize] as f64 * row[v as usize] as f64;
            }
            let up = &s[((l + 1) % self.n_layers) * n..((l + 1) % self.n_layers) * n + n];
            for v in 0..n {
                e -= self.jtau as f64 * row[v] as f64 * up[v] as f64;
            }
        }
        e
    }

    /// Effective fields of every spin recomputed from scratch (the
    /// invariant the incremental bookkeeping of every sweep rung must
    /// maintain): returns `(h_eff_space, h_eff_tau)` in original order,
    /// where `h_eff_space[i] = h_v + Σ_space J s_j` and
    /// `h_eff_tau[i] = jtau (s_down + s_up)`.
    pub fn effective_fields(&self, s: &[f32]) -> (Vec<f32>, Vec<f32>) {
        let n = self.base.n;
        let ns = self.n_spins();
        let mut hs = vec![0.0f32; ns];
        let mut ht = vec![0.0f32; ns];
        let adj = self.base.adjacency();
        for l in 0..self.n_layers {
            for v in 0..n {
                let i = self.spin_index(l, v);
                let mut acc = self.base.h[v];
                for &(u, j) in &adj[v] {
                    acc += j * s[self.spin_index(l, u as usize)];
                }
                hs[i] = acc;
                let down = s[self.spin_index((l + self.n_layers - 1) % self.n_layers, v)];
                let up = s[self.spin_index((l + 1) % self.n_layers, v)];
                ht[i] = self.jtau * (down + up);
            }
        }
        (hs, ht)
    }

    /// Energy change of flipping spin `i` (for oracle tests):
    /// `ΔE = 2 s_i (h_eff_space_i + h_eff_tau_i)`.
    pub fn flip_delta(&self, s: &[f32], i: usize) -> f64 {
        let (hs, ht) = self.effective_fields(s);
        2.0 * s[i] as f64 * (hs[i] as f64 + ht[i] as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> QmcModel {
        // 2-vertex base graph, 4 layers.
        let base = BaseGraph::new(2, vec![0.3, -0.2], vec![(0, 1, 0.7)]);
        QmcModel::new(base, 4, 0.4)
    }

    #[test]
    fn energy_of_uniform_state() {
        let m = tiny();
        let s = vec![1.0f32; 8];
        // per layer: -h0 - h1 - J = -0.3 + 0.2 - 0.7 = -0.8; tau: -0.4 * 2 per layer
        let want = 4.0 * (-0.8) + 4.0 * (-0.4 * 2.0);
        assert!((m.total_energy(&s) - want).abs() < 1e-6);
    }

    #[test]
    fn flip_delta_matches_energy_difference() {
        let m = tiny();
        let mut rng = Lcg::new(11);
        let mut s = m.random_state(&mut rng);
        for i in 0..m.n_spins() {
            let e0 = m.total_energy(&s);
            let de = m.flip_delta(&s, i);
            s[i] = -s[i];
            let e1 = m.total_energy(&s);
            s[i] = -s[i];
            assert!((e1 - e0 - de).abs() < 1e-5, "spin {i}: {} vs {}", e1 - e0, de);
        }
    }

    #[test]
    fn effective_fields_match_definition() {
        let m = tiny();
        let s = vec![1.0, -1.0, -1.0, 1.0, 1.0, 1.0, -1.0, -1.0f32];
        let (hs, ht) = m.effective_fields(&s);
        // spin (0,0): h=0.3, space nbr (0,1) = -1 with J=0.7 -> 0.3-0.7
        assert!((hs[0] - (0.3 - 0.7)).abs() < 1e-6);
        // tau: layers 3 and 1 vertex 0: s=-1 (l=3 idx 6), s=-1 (l=1 idx 2)
        assert!((ht[0] - 0.4 * (-1.0 + -1.0)).abs() < 1e-6);
    }

    #[test]
    fn wraparound_tau_edges_present() {
        let m = tiny();
        // Flipping a spin changes tau energy with both neighbours incl. wrap.
        let s0 = vec![1.0f32; 8];
        let mut s1 = s0.clone();
        s1[0] = -1.0; // layer 0, vertex 0: tau partners at layers 1 and 3
        let de = m.total_energy(&s1) - m.total_energy(&s0);
        // dE = 2*s*(h + J*s_nbr + jtau*(up+down)) = 2*(0.3+0.7+0.8) = 3.6
        assert!((de - 3.6).abs() < 1e-6);
    }
}
