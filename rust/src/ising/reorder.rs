//! Spin reordering — the enabling transformation for explicit
//! vectorization (paper §3.1, Figure 12), generic over the lane width.
//!
//! [`InterlaceW`] splits the `L` layers into `W` sections and interlaces
//! them: spin `(l, v)` with `l = m·L/W + r` (section `m`, row `r`) moves
//! to index `(r·n + v)·W + m`.  The `W` spins of a *group*
//! `g = r·n + v` are then corresponding spins of the `W` sections — at
//! least `L/W ≥ 2` layers apart, hence never adjacent — and sit in `W`
//! consecutive memory cells, so
//!
//! * flip decisions for a group are one `W`-lane vector op (A.3), and
//! * a group's tau neighbours form *another group* ("they also always
//!   update spins that form another quadruplet, except when an update
//!   wraps around between the first and last layers"), so neighbour
//!   updates are vector ops too (A.4); the section boundaries (`r = 0`
//!   and `r = L/W − 1`) wrap with a lane rotation.
//!
//! `W = 4` is the paper's SSE quadruplet layout, `W = 8` the AVX2 octet
//! layout.  The same construction with `W = L` ([`interlace_w`]) is the
//! accelerator's memory-coalescing reorder (§3.2).

use super::model::QmcModel;

/// W-way layer interlacing of a [`QmcModel`]'s spin order.
#[derive(Clone)]
pub struct InterlaceW {
    pub n_base: usize,
    pub n_layers: usize,
    /// Lane count (number of sections).
    pub w: usize,
    /// Rows per section, `L / W`.
    pub rows: usize,
    /// `perm[original_index] = new_index`.
    pub perm: Vec<u32>,
    /// `inv[new_index] = original_index`.
    pub inv: Vec<u32>,
}

impl InterlaceW {
    pub fn new(m: &QmcModel, w: usize) -> Self {
        let (n, l) = (m.base.n, m.n_layers);
        assert!(w >= 2, "need at least 2 sections");
        assert!(l % w == 0, "L must be a multiple of {w} for {w}-way interlacing");
        assert!(l / w >= 2, "sections must hold >= 2 layers so group spins are non-adjacent");
        let rows = l / w;
        let ns = n * l;
        let mut perm = vec![0u32; ns];
        let mut inv = vec![0u32; ns];
        for layer in 0..l {
            let (m_sec, r) = (layer / rows, layer % rows);
            for v in 0..n {
                let orig = layer * n + v;
                let new = (r * n + v) * w + m_sec;
                perm[orig] = new as u32;
                inv[new] = orig as u32;
            }
        }
        Self { n_base: n, n_layers: l, w, rows, perm, inv }
    }

    /// Number of groups (`rows * n_base`).
    pub fn n_groups(&self) -> usize {
        self.rows * self.n_base
    }

    /// Group id of row `r`, vertex `v`.
    #[inline]
    pub fn group(&self, r: usize, v: usize) -> usize {
        r * self.n_base + v
    }

    /// Apply the permutation to an original-order state.
    pub fn to_interlaced(&self, s: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0f32; s.len()];
        for (orig, &new) in self.perm.iter().enumerate() {
            out[new as usize] = s[orig];
        }
        out
    }

    /// Invert the permutation back to original order.
    pub fn to_original(&self, s: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0f32; s.len()];
        for (new, &orig) in self.inv.iter().enumerate() {
            out[orig as usize] = s[new];
        }
        out
    }
}

/// W-way interlacing permutation for the accelerator's coalesced layout
/// (B.2): spin `(l, v)` maps to `v * L + l` when `W = L` — i.e. the
/// layer index becomes the fastest-varying (lane) dimension, the rust-side
/// mirror of the artifact's `(N, L)` state.  Returns
/// `perm[original] = new`.
pub fn interlace_w(n_base: usize, n_layers: usize) -> Vec<u32> {
    let mut perm = vec![0u32; n_base * n_layers];
    for l in 0..n_layers {
        for v in 0..n_base {
            perm[l * n_base + v] = (v * n_layers + l) as u32;
        }
    }
    perm
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ising::graph::BaseGraph;
    use crate::ising::lcg::Lcg;

    fn model(n: usize, l: usize) -> QmcModel {
        let edges = (0..n as u32 - 1).map(|i| (i, i + 1, 0.5)).collect();
        QmcModel::new(BaseGraph::new(n, vec![0.0; n], edges), l, 0.3)
    }

    #[test]
    fn is_a_permutation_at_both_widths() {
        for (l, w) in [(12, 4), (16, 8), (32, 8)] {
            let m = model(5, l);
            let it = InterlaceW::new(&m, w);
            let mut seen = vec![false; m.n_spins()];
            for &p in &it.perm {
                assert!(!seen[p as usize], "w={w}: duplicate target {p}");
                seen[p as usize] = true;
            }
            assert!(seen.iter().all(|&b| b));
        }
    }

    #[test]
    fn roundtrips() {
        for w in [4usize, 8] {
            let m = model(4, 16);
            let it = InterlaceW::new(&m, w);
            let mut rng = Lcg::new(3);
            let s = m.random_state(&mut rng);
            assert_eq!(it.to_original(&it.to_interlaced(&s)), s);
        }
    }

    #[test]
    fn group_members_are_section_corresponding_spins() {
        for w in [4usize, 8] {
            let m = model(3, 4 * w); // rows = 4
            let it = InterlaceW::new(&m, w);
            for r in 0..it.rows {
                for v in 0..3 {
                    let g = it.group(r, v);
                    for lane in 0..w {
                        let orig = it.inv[w * g + lane] as usize;
                        let (layer, vert) = (orig / 3, orig % 3);
                        assert_eq!(vert, v);
                        assert_eq!(layer, lane * it.rows + r);
                    }
                }
            }
        }
    }

    #[test]
    fn group_spins_never_adjacent() {
        // Members of one group are >= rows >= 2 layers apart and on the
        // same vertex, so no tau or space edge can join them.
        for w in [4usize, 8] {
            let m = model(4, 2 * w);
            let it = InterlaceW::new(&m, w);
            for g in 0..it.n_groups() {
                let layers: Vec<usize> =
                    (0..w).map(|k| it.inv[w * g + k] as usize / 4).collect();
                for a in 0..w {
                    for b in (a + 1)..w {
                        let d = layers[a].abs_diff(layers[b]);
                        let wrap = m.n_layers - d;
                        assert!(d.min(wrap) >= 2, "w={w} group {g}: layers {layers:?}");
                    }
                }
            }
        }
    }

    #[test]
    fn tau_neighbours_form_groups_off_boundary() {
        for w in [4usize, 8] {
            let m = model(3, 4 * w);
            let it = InterlaceW::new(&m, w);
            // For rows 0 < r < rows-1: the up-neighbour group of (r, v) is
            // (r+1, v), lane-aligned.
            for r in 1..it.rows - 1 {
                for v in 0..3 {
                    let g = it.group(r, v);
                    for lane in 0..w {
                        let orig = it.inv[w * g + lane] as usize;
                        let (layer, vert) = (orig / 3, orig % 3);
                        let up_orig = ((layer + 1) % m.n_layers) * 3 + vert;
                        assert_eq!(
                            it.perm[up_orig] as usize,
                            w * it.group(r + 1, v) + lane,
                            "w={w}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn boundary_wrap_is_lane_rotation() {
        // At r = rows-1 the up-neighbour is lane+1 of group (0, v)
        // (section m -> m+1; the last section wraps to layer 0 = section 0).
        for w in [4usize, 8] {
            let m = model(3, 4 * w);
            let it = InterlaceW::new(&m, w);
            let r = it.rows - 1;
            for v in 0..3 {
                let g = it.group(r, v);
                for lane in 0..w {
                    let orig = it.inv[w * g + lane] as usize;
                    let (layer, vert) = (orig / 3, orig % 3);
                    let up_orig = ((layer + 1) % m.n_layers) * 3 + vert;
                    assert_eq!(
                        it.perm[up_orig] as usize,
                        w * it.group(0, v) + (lane + 1) % w,
                        "w={w} lane {lane}"
                    );
                }
            }
        }
    }

    #[test]
    fn invalid_widths_are_rejected() {
        let m = model(3, 12);
        assert!(std::panic::catch_unwind(|| InterlaceW::new(&m, 8)).is_err()); // 12 % 8 != 0
        let m2 = model(3, 8);
        assert!(std::panic::catch_unwind(|| InterlaceW::new(&m2, 8)).is_err()); // rows = 1
    }

    #[test]
    fn interlace_w_is_transpose() {
        let perm = interlace_w(3, 4);
        // spin (l=1, v=2) at original 1*3+2=5 -> new 2*4+1=9
        assert_eq!(perm[5], 9);
        let mut seen = vec![false; 12];
        for &p in &perm {
            assert!(!seen[p as usize]);
            seen[p as usize] = true;
        }
    }
}
