//! The PJRT execution path for B.1 / B.2: AOT-compiled XLA artifacts
//! executed through a real runtime.  This is the *optional* artifact
//! path — `--rung b1|b2` / `--backend accel` resolve onto the
//! in-process software device ([`crate::device`]), which needs no
//! artifacts, checkpoints bit-exactly and serves; an [`AccelSweeper`]
//! only exists when the caller supplies a [`Runtime`] explicitly
//! (`repro artifacts-check`, the `accelerator_serving` example).
//!
//! Both artifact variants run the same algorithm with the same
//! interlaced MT19937 stream; they differ *only* in memory layout — B.1
//! keeps the original layer-major flat order and reaches every
//! neighbour through an index table (irregular gathers), B.2 stores the
//! state interlaced (vertex-major, layer = lane) so every access is a
//! contiguous vector op.  This mirrors the paper's §3.2: "this
//! reorganization of memory was the only difference between the two GPU
//! versions".  Note the artifact kernels are *not* trajectory-identical
//! to the software device or the CPU rungs (checkerboard schedule,
//! on-device RNG) — `validate()` checks energies, not bits.

use std::path::Path;

use crate::ising::builder::Workload;
use crate::ising::QmcModel;
use crate::rng::Mt19937Wide;
use crate::runtime::executor::Input;
use crate::runtime::{Executor, Runtime, StaticCfg};
use crate::Result;

use super::{SweepKind, SweepStats, Sweeper};

/// Which artifact variant a sweeper runs.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum AccelVariant {
    B1Naive,
    B2Coalesced,
}

impl AccelVariant {
    pub fn artifact_prefix(self) -> &'static str {
        match self {
            AccelVariant::B1Naive => "b1_naive",
            AccelVariant::B2Coalesced => "b2_coalesced",
        }
    }

    pub fn kind(self) -> SweepKind {
        match self {
            AccelVariant::B1Naive => SweepKind::B1Accel,
            AccelVariant::B2Coalesced => SweepKind::B2Accel,
        }
    }
}

pub struct AccelSweeper {
    variant: AccelVariant,
    exec: Executor,
    cfg: StaticCfg,
    model: QmcModel,
    /// State in the artifact's own layout (see `to_artifact_layout`).
    s: Vec<f32>,
    mt: Vec<u32>,
    buf: Vec<u32>,
    cur: i32,
    /// Constant inputs (layout depends on variant).
    consts: ConstInputs,
    /// Energy reported by the last execute (artifact-side f32), used by
    /// `validate` against the host-side recomputation.
    last_artifact_energy: Option<f64>,
}

enum ConstInputs {
    B2 { h: Vec<f32>, nbr_idx: Vec<i32>, nbr_j: Vec<f32>, masks: Vec<f32>, jtau: f32 },
    B1 { h_flat: Vec<f32>, fnbr_idx: Vec<i32>, fnbr_j: Vec<f32>, masks: Vec<f32> },
}

impl AccelSweeper {
    /// Load the artifact matching `variant` + `config` from `dir`,
    /// validate it against the workload's geometry, and initialise state.
    pub fn new(
        rt: &Runtime,
        dir: &Path,
        config: &str,
        variant: AccelVariant,
        wl: &Workload,
        seed: u32,
    ) -> Result<Self> {
        let name = format!("{}_{}", variant.artifact_prefix(), config);
        let exec = rt.load_artifact(dir, &name)?;
        let cfg = exec.meta.static_cfg.clone();
        let m = &wl.model;
        if cfg.n_base != m.base.n || cfg.n_layers != m.n_layers {
            anyhow::bail!(
                "artifact {name} is {}x{} but workload is {}x{}",
                cfg.n_base, cfg.n_layers, m.base.n, m.n_layers
            );
        }
        if m.base.max_degree() > cfg.max_degree {
            anyhow::bail!("workload degree {} exceeds artifact K={}", m.base.max_degree(), cfg.max_degree);
        }
        if wl.n_colors > cfg.n_colors {
            anyhow::bail!("workload needs {} colours, artifact bakes {}", wl.n_colors, cfg.n_colors);
        }

        let consts = match variant {
            AccelVariant::B2Coalesced => build_b2_consts(wl, &cfg),
            AccelVariant::B1Naive => build_b1_consts(wl, &cfg),
        };

        // Interlaced MT19937, one lane per layer, seeds seed..seed+L-1 —
        // identical to the python side's `workload.fresh_rng`.
        let seeds: Vec<u32> = (0..cfg.n_layers as u32).map(|j| seed.wrapping_add(j)).collect();
        let wide = Mt19937Wide::new(&seeds);
        let mt = wide.state_rows().to_vec();
        let buf = vec![0u32; mt.len()];

        let mut sw = Self {
            variant,
            exec,
            cfg,
            model: m.clone(),
            s: Vec::new(),
            mt,
            buf,
            cur: 624, // cursor == N_STATE forces a refill on the first draw
            consts,
            last_artifact_energy: None,
        };
        sw.set_state(&wl.s0);
        Ok(sw)
    }

    fn to_artifact_layout(&self, s_orig: &[f32]) -> Vec<f32> {
        let (n, l) = (self.cfg.n_base, self.cfg.n_layers);
        match self.variant {
            // (N, L): s[v*L + l] = s_orig[l*n + v]
            AccelVariant::B2Coalesced => {
                let mut out = vec![0.0f32; n * l];
                for layer in 0..l {
                    for v in 0..n {
                        out[v * l + layer] = s_orig[layer * n + v];
                    }
                }
                out
            }
            AccelVariant::B1Naive => s_orig.to_vec(),
        }
    }

    fn to_original_layout(&self, s_art: &[f32]) -> Vec<f32> {
        let (n, l) = (self.cfg.n_base, self.cfg.n_layers);
        match self.variant {
            AccelVariant::B2Coalesced => {
                let mut out = vec![0.0f32; n * l];
                for layer in 0..l {
                    for v in 0..n {
                        out[layer * n + v] = s_art[v * l + layer];
                    }
                }
                out
            }
            AccelVariant::B1Naive => s_art.to_vec(),
        }
    }

    /// One execute() — `sweeps_per_call` Metropolis sweeps on-device.
    fn call(&mut self, beta: f32) -> Result<f64> {
        let cur_arr = [self.cur];
        let beta_arr = [beta];
        let outs = match &self.consts {
            ConstInputs::B2 { h, nbr_idx, nbr_j, masks, jtau } => {
                let jtau_arr = [*jtau];
                self.exec.execute(&[
                    Input::F32(&self.s),
                    Input::U32(&self.mt),
                    Input::U32(&self.buf),
                    Input::I32(&cur_arr),
                    Input::F32(h),
                    Input::I32(nbr_idx),
                    Input::F32(nbr_j),
                    Input::F32(masks),
                    Input::F32(&beta_arr),
                    Input::F32(&jtau_arr),
                ])?
            }
            ConstInputs::B1 { h_flat, fnbr_idx, fnbr_j, masks } => self.exec.execute(&[
                Input::F32(&self.s),
                Input::U32(&self.mt),
                Input::U32(&self.buf),
                Input::I32(&cur_arr),
                Input::F32(h_flat),
                Input::I32(fnbr_idx),
                Input::F32(fnbr_j),
                Input::F32(masks),
                Input::F32(&beta_arr),
            ])?,
        };
        self.s = outs[0].to_vec::<f32>().map_err(|e| anyhow::anyhow!("fetch s: {e}"))?;
        self.mt = outs[1].to_vec::<u32>().map_err(|e| anyhow::anyhow!("fetch mt: {e}"))?;
        self.buf = outs[2].to_vec::<u32>().map_err(|e| anyhow::anyhow!("fetch buf: {e}"))?;
        self.cur = outs[3].to_vec::<i32>().map_err(|e| anyhow::anyhow!("fetch cur: {e}"))?[0];
        let flips = outs[4].to_vec::<f32>().map_err(|e| anyhow::anyhow!("fetch flips: {e}"))?[0];
        let energy = outs[5].to_vec::<f32>().map_err(|e| anyhow::anyhow!("fetch energy: {e}"))?[0];
        self.last_artifact_energy = Some(energy as f64);
        Ok(flips as f64)
    }

    /// Energy as computed on-device by the last call (f32 precision).
    pub fn artifact_energy(&self) -> Option<f64> {
        self.last_artifact_energy
    }

    /// Debug: checksums of every input buffer (cross-language comparison).
    pub fn debug_input_checksums(&self) -> String {
        let fsum = |v: &[f32]| v.iter().map(|&x| x as f64).sum::<f64>();
        let isum = |v: &[i32]| v.iter().map(|&x| x as i64).sum::<i64>();
        let usum = |v: &[u32]| v.iter().map(|&x| x as u64).sum::<u64>();
        let mut out = format!(
            "s.sum={} s[..4]={:?} mt.sum={} mt[..4]={:?} cur={}",
            fsum(&self.s),
            &self.s[..4],
            usum(&self.mt),
            &self.mt[..4],
            self.cur
        );
        match &self.consts {
            ConstInputs::B2 { h, nbr_idx, nbr_j, masks, jtau } => {
                out += &format!(
                    " | B2 h.sum={} nbr_idx.sum={} nbr_idx[..8]={:?} nbr_j.sum={} masks.sum={} jtau={}",
                    fsum(h), isum(nbr_idx), &nbr_idx[..8], fsum(nbr_j), fsum(masks), jtau
                );
            }
            ConstInputs::B1 { h_flat, fnbr_idx, fnbr_j, masks } => {
                out += &format!(
                    " | B1 h.sum={} fnbr_idx.sum={} fnbr_j.sum={} masks.sum={}",
                    fsum(h_flat), isum(fnbr_idx), fsum(fnbr_j), fsum(masks)
                );
            }
        }
        out
    }
}

fn build_b2_consts(wl: &Workload, cfg: &StaticCfg) -> ConstInputs {
    let m = &wl.model;
    let (n, k, c) = (cfg.n_base, cfg.max_degree, cfg.n_colors);
    let adj = m.base.adjacency();
    let mut nbr_idx = vec![0i32; n * k];
    let mut nbr_j = vec![0.0f32; n * k];
    for v in 0..n {
        for (slot, &(u, j)) in adj[v].iter().enumerate() {
            nbr_idx[v * k + slot] = u as i32;
            nbr_j[v * k + slot] = j;
        }
        // padding stays (idx 0, J 0.0): contributes 0 to the field sum
    }
    // Per-phase sublattice masks (2C, N, L), phase = parity*C + colour.
    // Runtime inputs rather than in-graph constants — mirrors the paper's
    // ahead-of-time reordering, and works around an xla_extension 0.5.1
    // miscompile of the constant-folded broadcast (see model.py docstring).
    let l = cfg.n_layers;
    let phases = cfg.phases_per_sweep();
    let mut masks = vec![0.0f32; phases * n * l];
    for layer in 0..l {
        for v in 0..n {
            let ph = (layer % 2) * c + wl.colors[v] as usize;
            masks[(ph * n + v) * l + layer] = 1.0;
        }
    }
    ConstInputs::B2 { h: m.base.h.clone(), nbr_idx, nbr_j, masks, jtau: m.jtau }
}

fn build_b1_consts(wl: &Workload, cfg: &StaticCfg) -> ConstInputs {
    let m = &wl.model;
    let (n, l, k) = (cfg.n_base, cfg.n_layers, cfg.max_degree);
    let kk = k + 2;
    let total = n * l;
    let adj = m.base.adjacency();
    let mut h_flat = vec![0.0f32; total];
    let mut fnbr_idx = vec![0i32; total * kk];
    let mut fnbr_j = vec![0.0f32; total * kk];
    for layer in 0..l {
        for v in 0..n {
            let f = layer * n + v;
            h_flat[f] = m.base.h[v];
            for (slot, &(u, j)) in adj[v].iter().enumerate() {
                fnbr_idx[f * kk + slot] = (layer * n + u as usize) as i32;
                fnbr_j[f * kk + slot] = j;
            }
            // the two tau edges, last (paper §2.2)
            fnbr_idx[f * kk + kk - 2] = (((layer + l - 1) % l) * n + v) as i32;
            fnbr_idx[f * kk + kk - 1] = (((layer + 1) % l) * n + v) as i32;
            fnbr_j[f * kk + kk - 2] = m.jtau;
            fnbr_j[f * kk + kk - 1] = m.jtau;
        }
    }
    let phases = cfg.phases_per_sweep();
    let mut masks = vec![0.0f32; phases * total];
    for layer in 0..l {
        for v in 0..n {
            let ph = (layer % 2) * cfg.n_colors + wl.colors[v] as usize;
            masks[ph * total + layer * n + v] = 1.0;
        }
    }
    ConstInputs::B1 { h_flat, fnbr_idx, fnbr_j, masks }
}

impl Sweeper for AccelSweeper {
    fn kind(&self) -> SweepKind {
        self.variant.kind()
    }

    fn granularity(&self) -> usize {
        self.cfg.sweeps_per_call
    }

    fn run(&mut self, n_sweeps: usize, beta: f32) -> SweepStats {
        assert!(
            n_sweeps % self.cfg.sweeps_per_call == 0,
            "n_sweeps={} must be a multiple of sweeps_per_call={}",
            n_sweeps,
            self.cfg.sweeps_per_call
        );
        let mut stats = SweepStats::default();
        let calls = n_sweeps / self.cfg.sweeps_per_call;
        for _ in 0..calls {
            let flips = self.call(beta).expect("artifact execution failed");
            stats.flips += flips as u64;
            stats.attempts += (self.cfg.n_spins() * self.cfg.sweeps_per_call) as u64;
            // Group (warp-width) wait statistics are analytic for the
            // accelerator (Fig 14): groups stay 0 and the harness derives
            // P(wait) = 1 - (1-p)^W from the flip probability.
        }
        stats
    }

    fn energy(&mut self) -> f64 {
        let orig = self.to_original_layout(&self.s);
        self.model.total_energy(&orig)
    }

    fn state(&mut self) -> Vec<f32> {
        self.to_original_layout(&self.s)
    }

    fn set_state(&mut self, s: &[f32]) {
        assert_eq!(s.len(), self.cfg.n_spins());
        self.s = self.to_artifact_layout(s);
        self.last_artifact_energy = None;
    }

    /// For the accelerator, `validate` compares the artifact's on-device
    /// energy against the host recomputation (f32 tolerance).
    fn validate(&mut self) -> f64 {
        match self.last_artifact_energy {
            Some(e) => (e - self.energy()).abs(),
            None => 0.0,
        }
    }
}
