//! Ablation of the paper's §2 *basic optimizations* — each ingredient of
//! the A.1 → A.2 jump toggled independently:
//!
//! * **branch elimination** (§2.1): Figure-2 branchy endpoint/tau selection
//!   vs the Figure-3 branch-free form ("this optimization had a large
//!   impact");
//! * **data-structure simplification** (§2.2): Figure-4 nested edge tables
//!   vs the Figure-5/6 flat tau-last layout ("a large performance impact
//!   on top of the branch elimination");
//! * **result caching** (§2.3): recomputing `2*S_mul*J` per edge vs
//!   hoisting `2*S_mul` ("improved performance slightly, but noticeably");
//! * **exponential approximation** (§2.4): library `exp` vs the fast
//!   bit-trick variant.
//!
//! `bench ablation_basic_opts` measures the 2^-style ladder the paper
//! narrates, quantifying each ingredient on this machine.

use crate::ising::layout::{CsrLayout, OriginalLayout};
use crate::ising::QmcModel;
use crate::rng::Mt19937;

use super::{ExpMode, SweepKind, SweepStats, Sweeper};

/// Which §2 ingredients are enabled.
#[derive(Copy, Clone, Debug)]
pub struct BasicOptFlags {
    /// §2.1 — branch-free inner update loop.
    pub branch_free: bool,
    /// §2.2 — flat tau-last edge layout (implies branch-free tau handling).
    pub flat_layout: bool,
    /// §2.3 — hoist `2 * S_mul` out of the update loop.
    pub cache_two_smul: bool,
    /// §2.4 — exponential mode.
    pub exp: ExpMode,
}

impl BasicOptFlags {
    /// A.1: nothing enabled, library exp.
    pub fn none() -> Self {
        Self { branch_free: false, flat_layout: false, cache_two_smul: false, exp: ExpMode::Exact }
    }

    /// A.2: everything enabled, fast exp.
    pub fn all() -> Self {
        Self { branch_free: true, flat_layout: true, cache_two_smul: true, exp: ExpMode::Fast }
    }

    pub fn label(&self) -> String {
        if !self.branch_free && !self.flat_layout && !self.cache_two_smul && self.exp == ExpMode::Exact {
            return "A.1 (none)".to_string();
        }
        if self.branch_free && self.flat_layout && self.cache_two_smul && self.exp == ExpMode::Fast {
            return "A.2 (all)".to_string();
        }
        let mut parts = Vec::new();
        if self.branch_free {
            parts.push("branchfree");
        }
        if self.flat_layout {
            parts.push("flat");
        }
        if self.cache_two_smul {
            parts.push("cache");
        }
        match self.exp {
            ExpMode::Fast => parts.push("fastexp"),
            ExpMode::Accurate => parts.push("accexp"),
            ExpMode::Exact => {}
        }
        format!("+{}", parts.join("+"))
    }
}

/// A.1-to-A.2 sweeper with individually toggleable optimizations.
pub struct BasicOptAblation {
    model: QmcModel,
    flags: BasicOptFlags,
    orig: OriginalLayout,
    csr: CsrLayout,
    s: Vec<f32>,
    h_eff_space: Vec<f32>,
    h_eff_tau: Vec<f32>,
    rng: Mt19937,
}

impl BasicOptAblation {
    pub fn new(model: &QmcModel, s0: &[f32], seed: u32, flags: BasicOptFlags) -> Self {
        let (h_eff_space, h_eff_tau) = model.effective_fields(s0);
        Self {
            model: model.clone(),
            flags,
            orig: OriginalLayout::build(model),
            csr: CsrLayout::build(model),
            s: s0.to_vec(),
            h_eff_space,
            h_eff_tau,
            rng: Mt19937::new(seed),
        }
    }

    #[inline]
    fn update_original_branchy(&mut self, curr_spin: usize, s_mul: f32) {
        // Figure 2, verbatim (including the in-loop 2*S_mul*J).
        let incident = &self.orig.incident_edges[curr_spin];
        for edge_index in 0..incident.len() {
            let curr_edge = incident[edge_index] as usize;
            let ge = &self.orig.graph_edges[curr_edge];
            let curr_nbr;
            if ge[0] == curr_spin as u32 {
                curr_nbr = ge[1] as usize;
            } else {
                curr_nbr = ge[0] as usize;
            }
            if self.orig.is_a_tau_edge[curr_edge] {
                self.h_eff_tau[curr_nbr] -= 2.0 * s_mul * self.orig.j[curr_edge];
            } else {
                self.h_eff_space[curr_nbr] -= 2.0 * s_mul * self.orig.j[curr_edge];
            }
        }
    }

    #[inline]
    fn update_original_branchfree(&mut self, curr_spin: usize, s_mul: f32, cache: bool) {
        // Figure 3: endpoint select by boolean index, tau/space select by
        // conditional pointer — still the nested Figure-4 structures.
        let two_s_mul = 2.0 * s_mul;
        let incident = &self.orig.incident_edges[curr_spin];
        for &e in incident.iter() {
            let curr_edge = e as usize;
            let ge = &self.orig.graph_edges[curr_edge];
            let curr_nbr = ge[(ge[0] == curr_spin as u32) as usize] as usize;
            let h_eff = if self.orig.is_a_tau_edge[curr_edge] {
                &mut self.h_eff_tau
            } else {
                &mut self.h_eff_space
            };
            if cache {
                h_eff[curr_nbr] -= two_s_mul * self.orig.j[curr_edge];
            } else {
                h_eff[curr_nbr] -= 2.0 * s_mul * self.orig.j[curr_edge];
            }
        }
    }

    #[inline]
    fn update_flat(&mut self, i: usize, s_mul: f32, cache: bool) {
        // Figure 6: flat slice, space edges then exactly two tau edges.
        let (lo, hi) = (self.csr.offsets[i] as usize, self.csr.offsets[i + 1] as usize);
        let k = hi - lo;
        let two_s_mul = 2.0 * s_mul;
        for e in lo..hi - 2 {
            let t = self.csr.edge_target[e] as usize;
            if cache {
                self.h_eff_space[t] -= two_s_mul * self.csr.edge_j[e];
            } else {
                self.h_eff_space[t] -= 2.0 * s_mul * self.csr.edge_j[e];
            }
        }
        let _ = k;
        let (t1, t2) = (self.csr.edge_target[hi - 2] as usize, self.csr.edge_target[hi - 1] as usize);
        if cache {
            self.h_eff_tau[t1] -= two_s_mul * self.csr.edge_j[hi - 2];
            self.h_eff_tau[t2] -= two_s_mul * self.csr.edge_j[hi - 1];
        } else {
            self.h_eff_tau[t1] -= 2.0 * s_mul * self.csr.edge_j[hi - 2];
            self.h_eff_tau[t2] -= 2.0 * s_mul * self.csr.edge_j[hi - 1];
        }
    }

    fn sweep_once(&mut self, beta: f32, stats: &mut SweepStats) {
        let n_spins = self.s.len();
        for i in 0..n_spins {
            let u = self.rng.next_f32();
            let de = 2.0 * self.s[i] * (self.h_eff_space[i] + self.h_eff_tau[i]);
            let p = self.flags.exp.eval(-beta * de);
            stats.attempts += 1;
            stats.groups += 1;
            if u < p {
                stats.flips += 1;
                stats.groups_with_flip += 1;
                let s_mul = self.s[i];
                self.s[i] = -s_mul;
                match (self.flags.flat_layout, self.flags.branch_free) {
                    (true, _) => self.update_flat(i, s_mul, self.flags.cache_two_smul),
                    (false, true) => {
                        self.update_original_branchfree(i, s_mul, self.flags.cache_two_smul)
                    }
                    (false, false) => self.update_original_branchy(i, s_mul),
                }
            }
        }
    }
}

impl Sweeper for BasicOptAblation {
    fn kind(&self) -> SweepKind {
        // Ablations report as A.2 (they live between A.1 and A.2).
        SweepKind::A2Basic
    }

    fn run(&mut self, n_sweeps: usize, beta: f32) -> SweepStats {
        let mut stats = SweepStats::default();
        for _ in 0..n_sweeps {
            self.sweep_once(beta, &mut stats);
        }
        stats
    }

    fn energy(&mut self) -> f64 {
        self.model.total_energy(&self.s)
    }

    fn state(&mut self) -> Vec<f32> {
        self.s.clone()
    }

    fn set_state(&mut self, s: &[f32]) {
        self.s.copy_from_slice(s);
        let (hs, ht) = self.model.effective_fields(s);
        self.h_eff_space = hs;
        self.h_eff_tau = ht;
    }

    fn validate(&mut self) -> f64 {
        let (hs, ht) = self.model.effective_fields(&self.s);
        let mut worst = 0.0f64;
        for i in 0..self.s.len() {
            worst = worst
                .max((hs[i] - self.h_eff_space[i]).abs() as f64)
                .max((ht[i] - self.h_eff_tau[i]).abs() as f64);
        }
        worst
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ising::builder::torus_workload;

    /// Every flag combination computes the exact same trajectory — the
    /// optimizations are purely mechanical.
    #[test]
    fn all_ablations_are_trajectory_identical() {
        let wl = torus_workload(6, 4, 8, 9, 0.3);
        let combos: Vec<BasicOptFlags> = (0..8)
            .map(|bits| BasicOptFlags {
                branch_free: bits & 1 != 0,
                flat_layout: bits & 2 != 0,
                cache_two_smul: bits & 4 != 0,
                exp: ExpMode::Fast,
            })
            .collect();
        let mut reference: Option<Vec<f32>> = None;
        for flags in combos {
            let mut sw = BasicOptAblation::new(&wl.model, &wl.s0, 31, flags);
            sw.run(15, 0.7);
            let state = sw.state();
            assert!(sw.validate() < 1e-3, "{}", flags.label());
            match &reference {
                None => reference = Some(state),
                Some(r) => assert_eq!(&state, r, "{} diverged", flags.label()),
            }
        }
    }

    #[test]
    fn none_matches_a1_and_all_matches_a2() {
        use crate::sweep::{try_make_sweeper_with_exp, SweepKind};
        let wl = torus_workload(4, 4, 8, 2, 0.3);
        let mut none = BasicOptAblation::new(&wl.model, &wl.s0, 5, BasicOptFlags::none());
        let mut a1 =
            try_make_sweeper_with_exp(SweepKind::A1Original, &wl.model, &wl.s0, 5, ExpMode::Exact)
                .unwrap();
        none.run(10, 0.8);
        a1.run(10, 0.8);
        assert_eq!(none.state(), a1.state());

        let mut all = BasicOptAblation::new(&wl.model, &wl.s0, 5, BasicOptFlags::all());
        let mut a2 = try_make_sweeper_with_exp(SweepKind::A2Basic, &wl.model, &wl.s0, 5, ExpMode::Fast)
            .unwrap();
        all.run(10, 0.8);
        a2.run(10, 0.8);
        assert_eq!(all.state(), a2.state());
    }

    #[test]
    fn labels_are_informative() {
        assert_eq!(BasicOptFlags::none().label(), "A.1 (none)");
        assert_eq!(BasicOptFlags::all().label(), "A.2 (all)");
        let one = BasicOptFlags { branch_free: true, ..BasicOptFlags::none() };
        assert_eq!(one.label(), "+branchfree");
    }
}
