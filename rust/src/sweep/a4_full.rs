//! A.4 — full vectorization (paper §3.1): flip decisions *and* neighbour
//! updates are `W`-wide.
//!
//! Because the `W` lanes of a group are corresponding spins of identical
//! layer sections, the spins they must update after flipping "always
//! update spins that form another quadruplet": every space edge becomes
//! one masked vector FMA on `h_eff_space`, and the two tau edges become
//! one each on `h_eff_tau` — with the section-boundary rows (`r = 0` and
//! `r = rows−1`) handled by a lane rotation, the paper's "first and last
//! layers treated as a special case to handle wrapping".
//!
//! Trajectory-identical to A.3 *at the same width* by construction (same
//! interlaced RNG, same decision math); only the update mechanics differ.
//! The test suite asserts this bit-for-bit for W = 4 and W = 8.

use crate::ising::QmcModel;
use crate::rng::Mt19937Simd;
use crate::simd::{SimdF32, SimdU32};

use super::a3_vecrng::probs_wide;
use super::interlaced::InterlacedModel;
use super::{ExpMode, SweepKind, SweepStats, Sweeper};

pub struct A4Full<U: SimdU32> {
    model: QmcModel,
    im: InterlacedModel,
    s: Vec<f32>,
    hs: Vec<f32>,
    ht: Vec<f32>,
    rng: Mt19937Simd<U>,
    exp: ExpMode,
}

impl<U: SimdU32> A4Full<U> {
    pub fn new(model: &QmcModel, s0: &[f32], seed: u32, exp: ExpMode) -> Self {
        assert_eq!(s0.len(), model.n_spins());
        let im = InterlacedModel::build_w(model, U::LANES);
        let s = im.it.to_interlaced(s0);
        let (hs0, ht0) = model.effective_fields(s0);
        let hs = im.it.to_interlaced(&hs0);
        let ht = im.it.to_interlaced(&ht0);
        let rng = Mt19937Simd::from_base_seed(seed);
        Self { model: model.clone(), im, s, hs, ht, rng, exp }
    }

    #[inline(always)]
    fn sweep_once(&mut self, beta: f32, stats: &mut SweepStats) {
        let w = U::LANES;
        let n_groups = self.im.n_groups();
        let neg_beta = <U::F as SimdF32>::splat(-beta);
        let two = <U::F as SimdF32>::splat(2.0);
        let jtau = <U::F as SimdF32>::splat(self.im.jtau);
        for g in 0..n_groups {
            let u = self.rng.next_vec_f32();
            // Perf: the three group loads and the edge-table walk are the
            // hot path; bounds checks cost ~8% here (see EXPERIMENTS.md
            // §Perf).  All indices are structurally in range: g < n_groups
            // and every group-edge target is W*(group id) by construction
            // (validated by InterlacedModel's tests and debug asserts).
            debug_assert!(w * g + w <= self.s.len());
            let sv = unsafe { <U::F as SimdF32>::load_unchecked(&self.s, w * g) };
            let hsv = unsafe { <U::F as SimdF32>::load_unchecked(&self.hs, w * g) };
            let htv = unsafe { <U::F as SimdF32>::load_unchecked(&self.ht, w * g) };
            let de = two * sv * (hsv + htv);
            let p = probs_wide(self.exp, neg_beta * de);
            let mask = u.lt(p);
            let mm = mask.movemask();
            stats.attempts += w as u64;
            stats.groups += 1;
            if mm == 0 {
                continue;
            }
            stats.groups_with_flip += 1;
            stats.flips += mm.count_ones() as u64;

            // Masked vector flip (Figure 10 style): s' = mask ? -s : s.
            let s_new = <U::F as SimdF32>::select_bits(mask, sv.neg(), sv);
            unsafe { s_new.store_unchecked(&mut self.s, w * g) };

            // Masked update vector: 2*s_old on flipped lanes, 0 elsewhere.
            let upd =
                <U::F as SimdF32>::select_bits(mask, two * sv, <U::F as SimdF32>::zero());

            // One vector op per space edge — all `W` lanes at once.
            let (lo, hi) = (self.im.qoffsets[g] as usize, self.im.qoffsets[g + 1] as usize);
            for e in lo..hi {
                let t = unsafe { *self.im.qedge_target.get_unchecked(e) } as usize;
                let j = <U::F as SimdF32>::splat(unsafe { *self.im.qedge_j.get_unchecked(e) });
                debug_assert!(t + w <= self.hs.len());
                let cur = unsafe { <U::F as SimdF32>::load_unchecked(&self.hs, t) };
                unsafe { (cur - upd * j).store_unchecked(&mut self.hs, t) };
            }

            // Tau edges: lane-aligned in the bulk, lane-rotated at the
            // section boundaries.
            let tau_upd = upd * jtau;
            match self.im.up_base(g) {
                Some(b) => {
                    let cur = <U::F as SimdF32>::load(&self.ht[b..]);
                    (cur - tau_upd).store(&mut self.ht[b..b + w]);
                }
                None => {
                    let b = self.im.up_wrap_base(g);
                    let cur = <U::F as SimdF32>::load(&self.ht[b..]);
                    (cur - tau_upd.rot_up()).store(&mut self.ht[b..b + w]);
                }
            }
            match self.im.down_base(g) {
                Some(b) => {
                    let cur = <U::F as SimdF32>::load(&self.ht[b..]);
                    (cur - tau_upd).store(&mut self.ht[b..b + w]);
                }
                None => {
                    let b = self.im.down_wrap_base(g);
                    let cur = <U::F as SimdF32>::load(&self.ht[b..]);
                    (cur - tau_upd.rot_down()).store(&mut self.ht[b..b + w]);
                }
            }
        }
    }
}

impl<U: SimdU32> Sweeper for A4Full<U> {
    fn kind(&self) -> SweepKind {
        SweepKind::a4_for_width(U::LANES)
    }

    fn width(&self) -> usize {
        U::LANES
    }

    fn run(&mut self, n_sweeps: usize, beta: f32) -> SweepStats {
        let mut stats = SweepStats::default();
        U::with_features(|| {
            for _ in 0..n_sweeps {
                self.sweep_once(beta, &mut stats);
            }
        });
        stats
    }

    fn energy(&mut self) -> f64 {
        self.model.total_energy(&self.im.it.to_original(&self.s))
    }

    fn state(&mut self) -> Vec<f32> {
        self.im.it.to_original(&self.s)
    }

    fn set_state(&mut self, s: &[f32]) {
        self.s = self.im.it.to_interlaced(s);
        let (hs0, ht0) = self.model.effective_fields(s);
        self.hs = self.im.it.to_interlaced(&hs0);
        self.ht = self.im.it.to_interlaced(&ht0);
    }

    fn validate(&mut self) -> f64 {
        let orig = self.im.it.to_original(&self.s);
        let (hs0, ht0) = self.model.effective_fields(&orig);
        let hs = self.im.it.to_interlaced(&hs0);
        let ht = self.im.it.to_interlaced(&ht0);
        let mut worst = 0.0f64;
        for i in 0..self.s.len() {
            worst = worst
                .max((hs[i] - self.hs[i]).abs() as f64)
                .max((ht[i] - self.ht[i]).abs() as f64);
        }
        worst
    }

    fn rng_state(&self) -> Option<Vec<u32>> {
        Some(self.rng.state_words())
    }

    fn set_rng_state(&mut self, words: &[u32]) -> bool {
        self.rng.restore_words(words)
    }
}
