//! A.4 — full vectorization (paper §3.1): flip decisions *and* neighbour
//! updates are 4-wide.
//!
//! Because the four lanes of a quadruplet are corresponding spins of
//! identical layer sections, the spins they must update after flipping
//! "always update spins that form another quadruplet": every space edge
//! becomes one masked vector FMA on `h_eff_space`, and the two tau edges
//! become one each on `h_eff_tau` — with the section-boundary rows
//! (`r = 0` and `r = rows−1`) handled by a lane rotation, the paper's
//! "first and last layers treated as a special case to handle wrapping".
//!
//! Trajectory-identical to A.3 by construction (same interlaced RNG, same
//! decision math); only the update mechanics differ.  The test suite
//! asserts this bit-for-bit.

use crate::ising::QmcModel;
use crate::rng::Mt19937x4;
use crate::simd::F32x4;

use super::a3_vecrng::probs_x4;
use super::interlaced::InterlacedModel;
use super::{ExpMode, SweepKind, SweepStats, Sweeper};

pub struct A4Full {
    model: QmcModel,
    im: InterlacedModel,
    s: Vec<f32>,
    hs: Vec<f32>,
    ht: Vec<f32>,
    rng: Mt19937x4,
    exp: ExpMode,
}

impl A4Full {
    pub fn new(model: &QmcModel, s0: &[f32], seed: u32, exp: ExpMode) -> Self {
        assert_eq!(s0.len(), model.n_spins());
        let im = InterlacedModel::build(model);
        let s = im.it.to_interlaced(s0);
        let (hs0, ht0) = model.effective_fields(s0);
        let hs = im.it.to_interlaced(&hs0);
        let ht = im.it.to_interlaced(&ht0);
        let rng = Mt19937x4::new([seed, seed.wrapping_add(1), seed.wrapping_add(2), seed.wrapping_add(3)]);
        Self { model: model.clone(), im, s, hs, ht, rng, exp }
    }

    fn sweep_once(&mut self, beta: f32, stats: &mut SweepStats) {
        let n_quads = self.im.n_quads();
        let neg_beta = F32x4::splat(-beta);
        let two = F32x4::splat(2.0);
        let jtau = F32x4::splat(self.im.jtau);
        for q in 0..n_quads {
            let u4 = self.rng.next4_f32();
            // Perf: the three quadruplet loads and the edge-table walk are
            // the hot path; bounds checks cost ~8% here (see EXPERIMENTS.md
            // §Perf).  All indices are structurally in range: q < n_quads
            // and every quad-edge target is 4*(quad id) by construction
            // (validated by InterlacedModel's tests and debug asserts).
            debug_assert!(4 * q + 4 <= self.s.len());
            let s4 = unsafe { F32x4::load_unchecked(&self.s, 4 * q) };
            let hs4 = unsafe { F32x4::load_unchecked(&self.hs, 4 * q) };
            let ht4 = unsafe { F32x4::load_unchecked(&self.ht, 4 * q) };
            let de4 = two * s4 * (hs4 + ht4);
            let p4 = probs_x4(self.exp, neg_beta * de4);
            let mask = u4.lt(p4);
            let mm = mask.movemask();
            stats.attempts += 4;
            stats.groups += 1;
            if mm == 0 {
                continue;
            }
            stats.groups_with_flip += 1;
            stats.flips += mm.count_ones() as u64;

            // Masked vector flip (Figure 10 style): s' = mask ? -s : s.
            let s_new = F32x4::from_bits_select(mask, s4.neg(), s4);
            unsafe { s_new.store_unchecked(&mut self.s, 4 * q) };

            // Masked update vector: 2*s_old on flipped lanes, 0 elsewhere.
            let upd = F32x4::from_bits_select(mask, two * s4, F32x4::zero());

            // One vector op per space edge — all four lanes at once.
            let (lo, hi) = (self.im.qoffsets[q] as usize, self.im.qoffsets[q + 1] as usize);
            for e in lo..hi {
                let t = unsafe { *self.im.qedge_target.get_unchecked(e) } as usize;
                let j = F32x4::splat(unsafe { *self.im.qedge_j.get_unchecked(e) });
                debug_assert!(t + 4 <= self.hs.len());
                let cur = unsafe { F32x4::load_unchecked(&self.hs, t) };
                unsafe { (cur - upd * j).store_unchecked(&mut self.hs, t) };
            }

            // Tau edges: lane-aligned in the bulk, lane-rotated at the
            // section boundaries.
            let tau_upd = upd * jtau;
            match self.im.up_quad(q) {
                Some(b) => {
                    let cur = F32x4::load(&self.ht[b..]);
                    (cur - tau_upd).store(&mut self.ht[b..b + 4]);
                }
                None => {
                    let b = self.im.up_wrap_quad(q);
                    let cur = F32x4::load(&self.ht[b..]);
                    (cur - tau_upd.rot_up()).store(&mut self.ht[b..b + 4]);
                }
            }
            match self.im.down_quad(q) {
                Some(b) => {
                    let cur = F32x4::load(&self.ht[b..]);
                    (cur - tau_upd).store(&mut self.ht[b..b + 4]);
                }
                None => {
                    let b = self.im.down_wrap_quad(q);
                    let cur = F32x4::load(&self.ht[b..]);
                    (cur - tau_upd.rot_down()).store(&mut self.ht[b..b + 4]);
                }
            }
        }
    }
}

impl Sweeper for A4Full {
    fn kind(&self) -> SweepKind {
        SweepKind::A4Full
    }

    fn run(&mut self, n_sweeps: usize, beta: f32) -> SweepStats {
        let mut stats = SweepStats::default();
        for _ in 0..n_sweeps {
            self.sweep_once(beta, &mut stats);
        }
        stats
    }

    fn energy(&mut self) -> f64 {
        self.model.total_energy(&self.im.it.to_original(&self.s))
    }

    fn state(&mut self) -> Vec<f32> {
        self.im.it.to_original(&self.s)
    }

    fn set_state(&mut self, s: &[f32]) {
        self.s = self.im.it.to_interlaced(s);
        let (hs0, ht0) = self.model.effective_fields(s);
        self.hs = self.im.it.to_interlaced(&hs0);
        self.ht = self.im.it.to_interlaced(&ht0);
    }

    fn validate(&mut self) -> f64 {
        let orig = self.im.it.to_original(&self.s);
        let (hs0, ht0) = self.model.effective_fields(&orig);
        let hs = self.im.it.to_interlaced(&hs0);
        let ht = self.im.it.to_interlaced(&ht0);
        let mut worst = 0.0f64;
        for i in 0..self.s.len() {
            worst = worst
                .max((hs[i] - self.hs[i]).abs() as f64)
                .max((ht[i] - self.ht[i]).abs() as f64);
        }
        worst
    }
}
