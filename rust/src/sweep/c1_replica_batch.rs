//! C.1 — replica-batched vectorization: one SIMD lane per tempering
//! replica.
//!
//! Where A.3/A.4 vectorize *within* one model (W interlaced layer
//! sections), C.1 sweeps `W` independent replicas in lockstep over the
//! lane-major [`ReplicaBatchModel`] layout: one vector of uniforms from
//! the interlaced generator decides the same spin of all `W` replicas at
//! once, each lane at its own inverse temperature β.  Both the decision
//! *and* the neighbour updates are full-width vector ops — lanes belong
//! to different Markov chains, so there are no wrap/rotation special
//! cases at all, and any layer count ≥ 2 works (the shallow models the
//! A-rungs must reject).
//!
//! Lane `k` executes, operation for operation, the A.2 scalar sweep of
//! replica `k`: the same MT19937 stream (lane-exact interlaced
//! generator), the same `ΔE = 2s(h_space + h_tau)` arithmetic, the same
//! tau-last update order.  Under `ExpMode::Exact` every lane is therefore
//! bit-exact to [`super::a2_basic::A2Basic`] — the differential test
//! suite asserts this for W ∈ {4, 8} on every backend.

use crate::ising::replica_batch::ReplicaBatchModel;
use crate::ising::QmcModel;
use crate::rng::Mt19937Simd;
use crate::simd::{MAX_LANES, SimdF32, SimdU32};

use super::a3_vecrng::probs_wide;
use super::{ExpMode, SweepKind, SweepStats};

/// A sweep engine over a lane-batch of `W` tempering replicas — the
/// batch-level counterpart of [`super::Sweeper`].  `run` takes one β per
/// lane and returns one [`SweepStats`] per lane; state/energy accessors
/// are per lane so the coordinator can exchange replica states across
/// batch boundaries.
pub trait BatchSweeper {
    /// Which C-rung this is.
    fn kind(&self) -> SweepKind;
    /// Lane count `W`.
    fn lanes(&self) -> usize;
    /// Execute `n_sweeps` Metropolis sweeps on every lane, lane `k` at
    /// inverse temperature `betas[k]`; returns per-lane statistics.
    fn run(&mut self, n_sweeps: usize, betas: &[f32]) -> Vec<SweepStats>;
    /// Current total energy of lane `lane`'s replica.
    fn energy_of(&mut self, lane: usize) -> f64;
    /// Lane `lane`'s state in the replica's original (layer-major) order.
    fn state_of(&mut self, lane: usize) -> Vec<f32>;
    /// Replace lane `lane`'s state (original order) — tempering exchange.
    fn set_state_of(&mut self, lane: usize, s: &[f32]);
    /// Worst incremental-field inconsistency across all lanes.
    fn validate(&mut self) -> f64;
    /// Serialized interlaced-RNG state for bit-exact checkpoint resume.
    fn rng_state(&self) -> Vec<u32>;
    /// Restore a state captured by [`Self::rng_state`]; `false` on a
    /// malformed payload.
    fn set_rng_state(&mut self, words: &[u32]) -> bool;
}

/// The C.1 sweeper, generic over the SIMD backend (`U32x4` → the SSE
/// quadruplet batch, `avx2::U32x8` → the AVX2 octet batch, portable lanes
/// → any width anywhere).
pub struct C1ReplicaBatch<U: SimdU32> {
    rb: ReplicaBatchModel,
    /// Lane-major spins (`W * n_spins`).
    s: Vec<f32>,
    /// Lane-major effective space fields.
    hs: Vec<f32>,
    /// Lane-major effective tau fields.
    ht: Vec<f32>,
    rng: Mt19937Simd<U>,
    exp: ExpMode,
}

impl<U: SimdU32> C1ReplicaBatch<U> {
    /// Batch the replicas `(models[k], states[k])`, lane `k` seeded with
    /// `seeds[k]` — the same seed a scalar A.2 sweeper of that replica
    /// would use.
    pub fn new(
        models: &[QmcModel],
        states: &[Vec<f32>],
        seeds: &[u32],
        exp: ExpMode,
    ) -> crate::Result<Self> {
        let w = U::LANES;
        anyhow::ensure!(
            models.len() == w && states.len() == w && seeds.len() == w,
            "need exactly {w} models/states/seeds for a {w}-lane batch (got {}/{}/{})",
            models.len(),
            states.len(),
            seeds.len()
        );
        let rb = ReplicaBatchModel::new(models)?;
        for (k, st) in states.iter().enumerate() {
            anyhow::ensure!(st.len() == rb.n_spins, "state {k}: {} spins, model has {}", st.len(), rb.n_spins);
        }
        let s = rb.interleave(states);
        let mut hs_lanes = Vec::with_capacity(w);
        let mut ht_lanes = Vec::with_capacity(w);
        for (k, st) in states.iter().enumerate() {
            let (h_space, h_tau) = rb.models[k].effective_fields(st);
            hs_lanes.push(h_space);
            ht_lanes.push(h_tau);
        }
        let hs = rb.interleave(&hs_lanes);
        let ht = rb.interleave(&ht_lanes);
        let rng = Mt19937Simd::new(seeds);
        Ok(Self { rb, s, hs, ht, rng, exp })
    }

    #[inline(always)]
    fn sweep_once(&mut self, neg_betas: &[f32], flips: &mut [u64; MAX_LANES]) {
        let w = U::LANES;
        let n = self.rb.n_spins;
        let neg_beta = <U::F as SimdF32>::load(neg_betas);
        let two = <U::F as SimdF32>::splat(2.0);
        for i in 0..n {
            let u = self.rng.next_vec_f32();
            debug_assert!(w * i + w <= self.s.len());
            let sv = unsafe { <U::F as SimdF32>::load_unchecked(&self.s, w * i) };
            let hsv = unsafe { <U::F as SimdF32>::load_unchecked(&self.hs, w * i) };
            let htv = unsafe { <U::F as SimdF32>::load_unchecked(&self.ht, w * i) };
            let de = two * sv * (hsv + htv);
            let p = probs_wide(self.exp, neg_beta * de);
            let mask = u.lt(p);
            let mm = mask.movemask();
            if mm == 0 {
                continue;
            }
            for (k, f) in flips.iter_mut().enumerate().take(w) {
                *f += ((mm >> k) & 1) as u64;
            }

            // A.2's `two_s_mul` per lane, from the pre-flip spins.
            let two_s = two * sv;
            let s_new = <U::F as SimdF32>::select_bits(mask, sv.neg(), sv);
            unsafe { s_new.store_unchecked(&mut self.s, w * i) };

            // Every edge update is one full-width masked vector op: the
            // delta is selected *before* the subtract so unflipped lanes
            // subtract an exact +0.0 (bit-preserving).  Space edges first,
            // the two tau edges last — A.2's Figure-6 order per lane.
            let (lo, hi) = (self.rb.offsets[i] as usize, self.rb.offsets[i + 1] as usize);
            for e in lo..hi - 2 {
                let t = unsafe { *self.rb.edge_target.get_unchecked(e) } as usize;
                let jv = unsafe { <U::F as SimdF32>::load_unchecked(&self.rb.edge_j, w * e) };
                let delta =
                    <U::F as SimdF32>::select_bits(mask, two_s * jv, <U::F as SimdF32>::zero());
                debug_assert!(w * t + w <= self.hs.len());
                let cur = unsafe { <U::F as SimdF32>::load_unchecked(&self.hs, w * t) };
                unsafe { (cur - delta).store_unchecked(&mut self.hs, w * t) };
            }
            for e in hi - 2..hi {
                let t = unsafe { *self.rb.edge_target.get_unchecked(e) } as usize;
                let jv = unsafe { <U::F as SimdF32>::load_unchecked(&self.rb.edge_j, w * e) };
                let delta =
                    <U::F as SimdF32>::select_bits(mask, two_s * jv, <U::F as SimdF32>::zero());
                debug_assert!(w * t + w <= self.ht.len());
                let cur = unsafe { <U::F as SimdF32>::load_unchecked(&self.ht, w * t) };
                unsafe { (cur - delta).store_unchecked(&mut self.ht, w * t) };
            }
        }
    }
}

impl<U: SimdU32> BatchSweeper for C1ReplicaBatch<U> {
    fn kind(&self) -> SweepKind {
        SweepKind::c1_for_width(U::LANES)
    }

    fn lanes(&self) -> usize {
        U::LANES
    }

    fn run(&mut self, n_sweeps: usize, betas: &[f32]) -> Vec<SweepStats> {
        let w = U::LANES;
        assert_eq!(betas.len(), w, "one beta per lane");
        let mut neg_betas = [0.0f32; MAX_LANES];
        for (k, &b) in betas.iter().enumerate() {
            neg_betas[k] = -b;
        }
        let mut flips = [0u64; MAX_LANES];
        {
            // Whole-loop guard: `update` includes nested RNG block
            // regeneration (exclusive update time = update - rng).
            let _g = crate::obs::phase::timed(crate::obs::phase::Phase::Update);
            U::with_features(|| {
                for _ in 0..n_sweeps {
                    self.sweep_once(&neg_betas[..w], &mut flips);
                }
            });
        }
        // Per-lane A.2 semantics: one spin per decision, so groups ==
        // attempts and a "group with flip" is just a flip.
        let per_lane_attempts = (n_sweeps * self.rb.n_spins) as u64;
        (0..w)
            .map(|k| SweepStats {
                attempts: per_lane_attempts,
                flips: flips[k],
                groups: per_lane_attempts,
                groups_with_flip: flips[k],
            })
            .collect()
    }

    fn energy_of(&mut self, lane: usize) -> f64 {
        let _g = crate::obs::phase::timed(crate::obs::phase::Phase::Reduce);
        let st = self.rb.extract_lane(&self.s, lane);
        self.rb.models[lane].total_energy(&st)
    }

    fn state_of(&mut self, lane: usize) -> Vec<f32> {
        self.rb.extract_lane(&self.s, lane)
    }

    fn set_state_of(&mut self, lane: usize, s: &[f32]) {
        assert_eq!(s.len(), self.rb.n_spins);
        self.rb.scatter_lane(&mut self.s, lane, s);
        let (h_space, h_tau) = self.rb.models[lane].effective_fields(s);
        self.rb.scatter_lane(&mut self.hs, lane, &h_space);
        self.rb.scatter_lane(&mut self.ht, lane, &h_tau);
    }

    fn validate(&mut self) -> f64 {
        let mut worst = 0.0f64;
        for lane in 0..U::LANES {
            let st = self.rb.extract_lane(&self.s, lane);
            let (h_space, h_tau) = self.rb.models[lane].effective_fields(&st);
            for i in 0..self.rb.n_spins {
                let w = self.rb.lanes;
                worst = worst
                    .max((h_space[i] - self.hs[w * i + lane]).abs() as f64)
                    .max((h_tau[i] - self.ht[w * i + lane]).abs() as f64);
            }
        }
        worst
    }

    fn rng_state(&self) -> Vec<u32> {
        self.rng.state_words()
    }

    fn set_rng_state(&mut self, words: &[u32]) -> bool {
        self.rng.restore_words(words)
    }
}

/// Construct a C-rung batch sweeper.  A shim over
/// [`crate::engine::EngineBuilder::build_batch`] — takes anything that
/// lowers onto a [`crate::engine::SamplerSpec`] (a legacy C-rung
/// [`SweepKind`] or a `c1` spec), and the builder negotiates the backend
/// (SSE2 at 4 lanes, AVX2 at 8 when detected, portable lanes otherwise
/// or when `VECTORISING_FORCE_PORTABLE` is set).
pub fn make_batch_sweeper(
    spec: impl Into<crate::engine::SamplerSpec>,
    models: &[QmcModel],
    states: &[Vec<f32>],
    seeds: &[u32],
    exp: ExpMode,
) -> crate::Result<Box<dyn BatchSweeper + Send>> {
    let spec = spec.into();
    anyhow::ensure!(
        spec.rung.is_replica_batch(),
        "{} is not a replica-batch rung (expected c1-replica-batch or c1-replica-batch-w8, \
         i.e. --rung c1)",
        spec.rung.label()
    );
    Ok(crate::engine::EngineBuilder::new(spec)
        .exp(exp)
        .build_batch(models, states, seeds)?
        .into_sweeper())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ising::builder::torus_workload;

    fn batch_inputs(w: usize) -> (Vec<QmcModel>, Vec<Vec<f32>>, Vec<u32>) {
        let wls: Vec<_> = (0..w).map(|k| torus_workload(4, 4, 8, k as u64, 0.3)).collect();
        let models = wls.iter().map(|wl| wl.model.clone()).collect();
        let states = wls.iter().map(|wl| wl.s0.clone()).collect();
        let seeds = (0..w as u32).map(|k| 900 + k).collect();
        (models, states, seeds)
    }

    #[test]
    fn batch_sweeper_runs_and_reports_per_lane() {
        for kind in [SweepKind::C1ReplicaBatch, SweepKind::C1ReplicaBatchW8] {
            let w = kind.group_width();
            let (models, states, seeds) = batch_inputs(w);
            let mut b = make_batch_sweeper(kind, &models, &states, &seeds, ExpMode::Fast).unwrap();
            assert_eq!(b.kind(), kind);
            assert_eq!(b.lanes(), w);
            let betas = vec![0.8f32; w];
            let stats = b.run(3, &betas);
            assert_eq!(stats.len(), w);
            for (k, s) in stats.iter().enumerate() {
                assert_eq!(s.attempts, 3 * 4 * 4 * 8, "lane {k}");
                assert!(s.flips <= s.attempts);
            }
            assert!(b.validate() < 1e-3, "{kind:?}");
        }
    }

    #[test]
    fn set_state_resets_lane_trajectory() {
        let (models, states, seeds) = batch_inputs(4);
        let mut b =
            make_batch_sweeper(SweepKind::C1ReplicaBatch, &models, &states, &seeds, ExpMode::Fast)
                .unwrap();
        let betas = [0.6f32; 4];
        b.run(4, &betas);
        let snap = b.state_of(2);
        let other = b.state_of(1);
        b.run(4, &betas);
        b.set_state_of(2, &snap);
        assert_eq!(b.state_of(2), snap);
        assert_ne!(b.state_of(1), other); // untouched lanes keep evolving
        assert!(b.validate() < 1e-4);
    }

    #[test]
    fn wrong_arity_and_wrong_kind_error() {
        let (models, states, seeds) = batch_inputs(4);
        assert!(make_batch_sweeper(
            SweepKind::C1ReplicaBatchW8,
            &models,
            &states,
            &seeds,
            ExpMode::Fast
        )
        .is_err());
        assert!(
            make_batch_sweeper(SweepKind::A2Basic, &models, &states, &seeds, ExpMode::Fast)
                .is_err()
        );
    }

    #[test]
    fn rng_state_roundtrips_through_batch() {
        let (models, states, seeds) = batch_inputs(4);
        let mut b =
            make_batch_sweeper(SweepKind::C1ReplicaBatch, &models, &states, &seeds, ExpMode::Fast)
                .unwrap();
        let betas = [0.7f32; 4];
        b.run(2, &betas);
        let words = b.rng_state();
        assert!(b.set_rng_state(&words));
        assert!(!b.set_rng_state(&words[..10]));
    }
}
