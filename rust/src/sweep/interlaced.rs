//! Shared substrate of the A.3/A.4 rungs: the model rebuilt in the 4-way
//! interlaced spin order of [`crate::ising::reorder::Interlace4`].
//!
//! A quadruplet `q = r·n + v` holds the four corresponding spins of the 4
//! layer sections at consecutive indices `4q .. 4q+4`.  Because all
//! layers are identical, the four lanes of a quadruplet share one edge
//! structure:
//!
//! * each space edge of vertex `v` maps to a *quad edge* `(4·(r·n+u), J)`
//!   — a vector of 4 adjacent targets;
//! * the tau up/down neighbours are the lane-aligned quadruplets
//!   `(r±1, v)`, except at the section boundaries `r = 0` (down wraps
//!   with a lane rotation) and `r = rows−1` (up wraps likewise).

use crate::ising::reorder::Interlace4;
use crate::ising::QmcModel;

/// Per-quadruplet edge tables + interlaced field bookkeeping.
pub struct InterlacedModel {
    pub it: Interlace4,
    pub jtau: f32,
    /// Flattened quad-edge targets (base index `4*q_u`), grouped per quad.
    pub qedge_target: Vec<u32>,
    /// Couplings parallel to `qedge_target`.
    pub qedge_j: Vec<f32>,
    /// Per-quad slice starts into the above (`n_quads + 1`).
    pub qoffsets: Vec<u32>,
}

impl InterlacedModel {
    pub fn build(m: &QmcModel) -> Self {
        let it = Interlace4::new(m);
        let n = m.base.n;
        let adj = m.base.adjacency();
        let mut qedge_target = Vec::new();
        let mut qedge_j = Vec::new();
        let mut qoffsets = Vec::with_capacity(it.n_quads() + 1);
        qoffsets.push(0u32);
        for r in 0..it.rows {
            for v in 0..n {
                for &(u, j) in &adj[v] {
                    qedge_target.push((4 * it.quad(r, u as usize)) as u32);
                    qedge_j.push(j);
                }
                qoffsets.push(qedge_target.len() as u32);
            }
        }
        Self { it, jtau: m.jtau, qedge_target, qedge_j, qoffsets }
    }

    pub fn n_quads(&self) -> usize {
        self.it.n_quads()
    }

    /// Space quad-edges of quadruplet `q`: `(targets, js)`.
    #[inline]
    pub fn qedges(&self, q: usize) -> (&[u32], &[f32]) {
        let (a, b) = (self.qoffsets[q] as usize, self.qoffsets[q + 1] as usize);
        (&self.qedge_target[a..b], &self.qedge_j[a..b])
    }

    /// Row and vertex of quadruplet `q`.
    #[inline]
    pub fn row_vertex(&self, q: usize) -> (usize, usize) {
        (q / self.it.n_base, q % self.it.n_base)
    }

    /// Base index (`4*quad`) of the lane-aligned up-neighbour quadruplet,
    /// or `None` at the wrapping boundary (`r = rows-1`).
    #[inline]
    pub fn up_quad(&self, q: usize) -> Option<usize> {
        let (r, v) = self.row_vertex(q);
        if r + 1 < self.it.rows {
            Some(4 * self.it.quad(r + 1, v))
        } else {
            None
        }
    }

    /// Base index of the lane-aligned down-neighbour quadruplet, or
    /// `None` at the wrapping boundary (`r = 0`).
    #[inline]
    pub fn down_quad(&self, q: usize) -> Option<usize> {
        let (r, v) = self.row_vertex(q);
        if r > 0 {
            Some(4 * self.it.quad(r - 1, v))
        } else {
            None
        }
    }

    /// Boundary targets: the up-neighbour of lane `m` at `r = rows-1` is
    /// lane `(m+1) % 4` of quadruplet `(0, v)`.
    #[inline]
    pub fn up_wrap_quad(&self, q: usize) -> usize {
        let (_, v) = self.row_vertex(q);
        4 * self.it.quad(0, v)
    }

    /// The down-neighbour of lane `m` at `r = 0` is lane `(m+3) % 4` of
    /// quadruplet `(rows-1, v)`.
    #[inline]
    pub fn down_wrap_quad(&self, q: usize) -> usize {
        let (_, v) = self.row_vertex(q);
        4 * self.it.quad(self.it.rows - 1, v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ising::builder::torus_workload;

    #[test]
    fn quad_edges_mirror_base_adjacency() {
        let w = torus_workload(4, 4, 8, 3, 0.25);
        let im = InterlacedModel::build(&w.model);
        let adj = w.model.base.adjacency();
        for q in 0..im.n_quads() {
            let (r, v) = im.row_vertex(q);
            let (targets, js) = im.qedges(q);
            assert_eq!(targets.len(), adj[v].len());
            for (k, &(u, j)) in adj[v].iter().enumerate() {
                assert_eq!(targets[k] as usize, 4 * im.it.quad(r, u as usize));
                assert_eq!(js[k], j);
            }
        }
    }

    #[test]
    fn tau_quads_consistent_with_permutation() {
        let w = torus_workload(4, 4, 16, 3, 0.25);
        let m = &w.model;
        let im = InterlacedModel::build(m);
        let n = m.base.n;
        for q in 0..im.n_quads() {
            for lane in 0..4 {
                let orig = im.it.inv[4 * q + lane] as usize;
                let (layer, v) = (orig / n, orig % n);
                let up_orig = ((layer + 1) % m.n_layers) * n + v;
                let up_new = im.it.perm[up_orig] as usize;
                match im.up_quad(q) {
                    Some(base) => assert_eq!(up_new, base + lane),
                    None => assert_eq!(up_new, im.up_wrap_quad(q) + (lane + 1) % 4),
                }
                let down_orig = ((layer + m.n_layers - 1) % m.n_layers) * n + v;
                let down_new = im.it.perm[down_orig] as usize;
                match im.down_quad(q) {
                    Some(base) => assert_eq!(down_new, base + lane),
                    None => assert_eq!(down_new, im.down_wrap_quad(q) + (lane + 3) % 4),
                }
            }
        }
    }
}
