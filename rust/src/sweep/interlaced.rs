//! Shared substrate of the A.3/A.4 rungs: the model rebuilt in the W-way
//! interlaced spin order of [`crate::ising::reorder::InterlaceW`].
//!
//! A group `g = r·n + v` holds the `W` corresponding spins of the `W`
//! layer sections at consecutive indices `W·g .. W·g+W`.  Because all
//! layers are identical, the `W` lanes of a group share one edge
//! structure:
//!
//! * each space edge of vertex `v` maps to a *group edge* `(W·(r·n+u), J)`
//!   — a vector of `W` adjacent targets;
//! * the tau up/down neighbours are the lane-aligned groups `(r±1, v)`,
//!   except at the section boundaries `r = 0` (down wraps with a lane
//!   rotation) and `r = rows−1` (up wraps likewise).
//!
//! `W = 4` reproduces the paper's quadruplet tables bit-for-bit; `W = 8`
//! is the AVX2 octet layout.

use crate::ising::reorder::InterlaceW;
use crate::ising::QmcModel;

/// Per-group edge tables + interlaced field bookkeeping.
pub struct InterlacedModel {
    pub it: InterlaceW,
    pub jtau: f32,
    /// Flattened group-edge targets (base index `W*g_u`), grouped per group.
    pub qedge_target: Vec<u32>,
    /// Couplings parallel to `qedge_target`.
    pub qedge_j: Vec<f32>,
    /// Per-group slice starts into the above (`n_groups + 1`).
    pub qoffsets: Vec<u32>,
}

impl InterlacedModel {
    /// Build at the paper's width (4 — the SSE quadruplet layout).
    pub fn build(m: &QmcModel) -> Self {
        Self::build_w(m, 4)
    }

    /// Build at lane width `w` (requires `L % w == 0` and `L / w >= 2`).
    pub fn build_w(m: &QmcModel, w: usize) -> Self {
        let it = InterlaceW::new(m, w);
        let n = m.base.n;
        let adj = m.base.adjacency();
        let mut qedge_target = Vec::new();
        let mut qedge_j = Vec::new();
        let mut qoffsets = Vec::with_capacity(it.n_groups() + 1);
        qoffsets.push(0u32);
        for r in 0..it.rows {
            for v in 0..n {
                for &(u, j) in &adj[v] {
                    qedge_target.push((w * it.group(r, u as usize)) as u32);
                    qedge_j.push(j);
                }
                qoffsets.push(qedge_target.len() as u32);
            }
        }
        Self { it, jtau: m.jtau, qedge_target, qedge_j, qoffsets }
    }

    /// Lane width of this layout.
    #[inline]
    pub fn w(&self) -> usize {
        self.it.w
    }

    pub fn n_groups(&self) -> usize {
        self.it.n_groups()
    }

    /// Space group-edges of group `g`: `(targets, js)`.
    #[inline]
    pub fn group_edges(&self, g: usize) -> (&[u32], &[f32]) {
        let (a, b) = (self.qoffsets[g] as usize, self.qoffsets[g + 1] as usize);
        (&self.qedge_target[a..b], &self.qedge_j[a..b])
    }

    /// Row and vertex of group `g`.
    #[inline]
    pub fn row_vertex(&self, g: usize) -> (usize, usize) {
        (g / self.it.n_base, g % self.it.n_base)
    }

    /// Base index (`W*group`) of the lane-aligned up-neighbour group, or
    /// `None` at the wrapping boundary (`r = rows-1`).
    #[inline]
    pub fn up_base(&self, g: usize) -> Option<usize> {
        let (r, v) = self.row_vertex(g);
        if r + 1 < self.it.rows {
            Some(self.it.w * self.it.group(r + 1, v))
        } else {
            None
        }
    }

    /// Base index of the lane-aligned down-neighbour group, or `None` at
    /// the wrapping boundary (`r = 0`).
    #[inline]
    pub fn down_base(&self, g: usize) -> Option<usize> {
        let (r, v) = self.row_vertex(g);
        if r > 0 {
            Some(self.it.w * self.it.group(r - 1, v))
        } else {
            None
        }
    }

    /// Boundary targets: the up-neighbour of lane `m` at `r = rows-1` is
    /// lane `(m+1) % W` of group `(0, v)`.
    #[inline]
    pub fn up_wrap_base(&self, g: usize) -> usize {
        let (_, v) = self.row_vertex(g);
        self.it.w * self.it.group(0, v)
    }

    /// The down-neighbour of lane `m` at `r = 0` is lane `(m+W-1) % W` of
    /// group `(rows-1, v)`.
    #[inline]
    pub fn down_wrap_base(&self, g: usize) -> usize {
        let (_, v) = self.row_vertex(g);
        self.it.w * self.it.group(self.it.rows - 1, v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ising::builder::torus_workload;

    #[test]
    fn group_edges_mirror_base_adjacency() {
        for w in [4usize, 8] {
            let wl = torus_workload(4, 4, 4 * w, 3, 0.25);
            let im = InterlacedModel::build_w(&wl.model, w);
            let adj = wl.model.base.adjacency();
            for g in 0..im.n_groups() {
                let (r, v) = im.row_vertex(g);
                let (targets, js) = im.group_edges(g);
                assert_eq!(targets.len(), adj[v].len());
                for (k, &(u, j)) in adj[v].iter().enumerate() {
                    assert_eq!(targets[k] as usize, w * im.it.group(r, u as usize), "w={w}");
                    assert_eq!(js[k], j);
                }
            }
        }
    }

    #[test]
    fn tau_groups_consistent_with_permutation() {
        for w in [4usize, 8] {
            let wl = torus_workload(4, 4, 4 * w, 3, 0.25);
            let m = &wl.model;
            let im = InterlacedModel::build_w(m, w);
            let n = m.base.n;
            for g in 0..im.n_groups() {
                for lane in 0..w {
                    let orig = im.it.inv[w * g + lane] as usize;
                    let (layer, v) = (orig / n, orig % n);
                    let up_orig = ((layer + 1) % m.n_layers) * n + v;
                    let up_new = im.it.perm[up_orig] as usize;
                    match im.up_base(g) {
                        Some(base) => assert_eq!(up_new, base + lane, "w={w}"),
                        None => assert_eq!(up_new, im.up_wrap_base(g) + (lane + 1) % w, "w={w}"),
                    }
                    let down_orig = ((layer + m.n_layers - 1) % m.n_layers) * n + v;
                    let down_new = im.it.perm[down_orig] as usize;
                    match im.down_base(g) {
                        Some(base) => assert_eq!(down_new, base + lane, "w={w}"),
                        None => {
                            assert_eq!(down_new, im.down_wrap_base(g) + (lane + w - 1) % w, "w={w}")
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn default_build_is_width_4() {
        let wl = torus_workload(4, 4, 16, 3, 0.25);
        let im = InterlacedModel::build(&wl.model);
        assert_eq!(im.w(), 4);
    }
}
