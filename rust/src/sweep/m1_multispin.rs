//! M.1 — multi-spin coding: 64 spins bit-packed per machine word.
//!
//! The paper's ladder vectorizes the *arithmetic* of one flip decision
//! (A.3/A.4) or runs replicas in lane-lockstep (C.1).  Multi-spin coding
//! is the complementary classic (Jacobs & Rebbi 1981; Weigel &
//! Yavors'kii's GPU spin-glass kernels): restrict the workload to ±1
//! couplings and zero on-site fields, and a spin becomes one *bit*, a
//! local field a popcount of XOR words, and 64 Metropolis proposals
//! become a handful of bitwise ops.
//!
//! Layout: bit `b` of word `j` of vertex `v` holds the spin at layer
//! `64*j + b` (bit = 1 ⇔ spin = −1), `ceil(L/64)` words per vertex.  A
//! sweep runs two checkerboard phases — phase `p` updates the spins with
//! `(layer + colour(v)) % 2 == p`, whose neighbours (4 space + 2 tau) all
//! sit in the opposite class, so every flip inside a phase commutes and a
//! whole word of 32 active spins is decided in one pass:
//!
//! * tau disagreements come from the word shifted by one bit (with
//!   cross-word / wrap-around carries),
//! * space disagreements from `w ^ w_nbr ^ m_e` where the bond mask
//!   `m_e` is all-ones iff `J_e = −1`,
//! * the 4 space disagreements are summed *bit-sliced* by a carry-save
//!   adder network (`ones`/`twos`/`fours` planes), the 2 tau ones by a
//!   half adder,
//! * the flip energy takes one of 15 values
//!   `ΔE = (8 − 4·u_space) + jtau·(4 − 4·u_tau)`, so the Boltzmann
//!   factor is evaluated **once per bin** instead of once per spin, and
//!   acceptance `u < p` becomes an integer compare `(r >> 8) < T[bin]`
//!   with `T[bin] = ceil(p · 2^24)` — bit-equal to the per-spin A.2 rule
//!   because the 24-bit uniform `u = (r >> 8) / 2^24` is exact in f32.
//!
//! No effective-field arrays are maintained (the neighbour sums are
//! recomputed per phase from the packed words), so [`Sweeper::validate`]
//! is exactly 0 by construction.  Uniforms are drawn one per *active*
//! spin, in (vertex, word, ascending bit) order, from the same interlaced
//! [`Mt19937Simd`] rows the A.3/A.4 rungs use; leftovers in the last row
//! of a phase are discarded so checkpoint payloads never straddle a
//! partially-consumed row.
//!
//! The workload contract (±1 couplings, `h ≡ 0`, even layer count,
//! degree-4 base graph) is what [`crate::ising::builder::pm_torus_workload`]
//! produces; construction rejects anything else with a pointer there.

use std::collections::VecDeque;

use crate::ising::graph::BaseGraph;
use crate::ising::QmcModel;
use crate::rng::Mt19937Simd;
use crate::simd::{MAX_LANES, SimdU32};

use super::{ExpMode, SweepKind, SweepStats, Sweeper};

/// Bits 0, 2, 4, … — the even layers of a word (layer parity equals bit
/// parity because every word starts at a multiple of 64).
const EVEN_BITS: u64 = 0x5555_5555_5555_5555;

/// Flip-energy bin of bit `b` from the bit-sliced counter planes:
/// `u_space | u_tau << 3`.
#[inline(always)]
fn bin_at(b: u32, ones: u64, twos: u64, fours: u64, t_ones: u64, t_twos: u64) -> usize {
    let us = ((ones >> b) & 1) | (((twos >> b) & 1) << 1) | (((fours >> b) & 1) << 2);
    let ut = ((t_ones >> b) & 1) | (((t_twos >> b) & 1) << 1);
    (us | (ut << 3)) as usize
}

/// The multi-spin sweeper.  `U` picks the backend of the internal
/// interlaced uniform generator only (the word sweep itself is scalar ALU
/// work); all backends stream bit-identically, so `U` never changes a
/// flip decision.
pub struct M1MultiSpin<U: SimdU32> {
    model: QmcModel,
    exp: ExpMode,
    /// BFS 2-colouring of the base graph (checkerboard classes).
    colors: Vec<u32>,
    /// Exactly four `(neighbour, bond mask)` pairs per vertex; the mask
    /// is all-ones iff the coupling is antiferromagnetic.
    nbrs: Vec<[(u32, u64); 4]>,
    /// `spins[v*nw + j]`, bit `b` ⇔ layer `64j + b` of vertex `v` is −1.
    spins: Vec<u64>,
    /// Words per vertex, `ceil(L/64)`.
    nw: usize,
    /// Valid bits in the last word (`L − 64·(nw−1)`, even, in 2..=64);
    /// the bits above stay zero as an invariant.
    rbits: u32,
    rng: Mt19937Simd<U>,
    row: [u32; MAX_LANES],
    cursor: usize,
    /// `(beta bits, T)` — per-bin acceptance thresholds for the last
    /// beta seen, `T[u_space | u_tau << 3] = ceil(p · 2^24)`.
    cache: Option<(u32, [u32; 32])>,
}

/// Deterministic BFS bipartition (colour of the lowest-numbered vertex of
/// each component is 0 — on the torus this reproduces the builder's
/// `(x + y) % 2` colouring exactly).
fn two_coloring(base: &BaseGraph) -> crate::Result<Vec<u32>> {
    let adj = base.adjacency();
    let mut colors = vec![u32::MAX; base.n];
    let mut queue = VecDeque::new();
    for start in 0..base.n {
        if colors[start] != u32::MAX {
            continue;
        }
        colors[start] = 0;
        queue.push_back(start);
        while let Some(v) = queue.pop_front() {
            for &(u, _) in &adj[v] {
                let u = u as usize;
                if colors[u] == u32::MAX {
                    colors[u] = 1 - colors[v];
                    queue.push_back(u);
                } else if colors[u] == colors[v] {
                    anyhow::bail!(
                        "m1-multispin needs a bipartite (2-colourable) base graph for its \
                         checkerboard phases, but vertices {v} and {u} are adjacent with the \
                         same class — build the workload with ising::builder::pm_torus_workload"
                    );
                }
            }
        }
    }
    Ok(colors)
}

impl<U: SimdU32> M1MultiSpin<U> {
    pub fn new(model: &QmcModel, s0: &[f32], seed: u32, exp: ExpMode) -> crate::Result<Self> {
        assert_eq!(s0.len(), model.n_spins());
        let layers = model.n_layers;
        anyhow::ensure!(
            layers >= 2 && layers % 2 == 0,
            "m1-multispin needs an even layer count >= 2 (got {layers}): the (layer + colour) \
             checkerboard parity classes must close under the tau wrap-around"
        );
        if let Some(v) = model.base.h.iter().position(|&h| h != 0.0) {
            anyhow::bail!(
                "m1-multispin requires zero on-site fields, but h[{v}] = {} — build the \
                 workload with ising::builder::pm_torus_workload",
                model.base.h[v]
            );
        }
        if let Some(e) = model.base.edges.iter().find(|e| e.2 != 1.0 && e.2 != -1.0) {
            anyhow::bail!(
                "m1-multispin requires couplings of exactly +1 or -1, but edge ({}, {}) has \
                 J = {} — build the workload with ising::builder::pm_torus_workload",
                e.0,
                e.1,
                e.2
            );
        }
        let adj = model.base.adjacency();
        if let Some(v) = adj.iter().position(|a| a.len() != 4) {
            anyhow::bail!(
                "m1-multispin's bit-sliced adder assumes exactly 4 space neighbours per vertex \
                 (a torus base graph), but vertex {v} has {} — build the workload with \
                 ising::builder::pm_torus_workload",
                adj[v].len()
            );
        }
        anyhow::ensure!(
            s0.iter().all(|&s| s == 1.0 || s == -1.0),
            "m1-multispin packs spins into single bits; the initial state must be exactly ±1"
        );
        let colors = two_coloring(&model.base)?;
        let nbrs: Vec<[(u32, u64); 4]> = adj
            .iter()
            .map(|a| {
                let mut row = [(0u32, 0u64); 4];
                for (slot, &(u, j)) in row.iter_mut().zip(a.iter()) {
                    *slot = (u, if j < 0.0 { !0u64 } else { 0u64 });
                }
                row
            })
            .collect();
        let nw = layers.div_ceil(64);
        let rbits = (layers - 64 * (nw - 1)) as u32;
        let mut this = Self {
            model: model.clone(),
            exp,
            colors,
            nbrs,
            spins: vec![0u64; model.base.n * nw],
            nw,
            rbits,
            rng: Mt19937Simd::from_base_seed(seed),
            row: [0u32; MAX_LANES],
            cursor: 0,
            cache: None,
        };
        this.pack_state(s0);
        Ok(this)
    }

    /// Per-bin integer acceptance thresholds for `beta` (cached on the
    /// beta bits).  `T[bin] = ceil(p · 2^24)` capped at `2^24` makes
    /// `(r >> 8) < T[bin]` decide exactly like the per-spin `u < p`: with
    /// `k = r >> 8` a 24-bit integer and `x = p · 2^24` (exact in f64),
    /// `k < x ⇔ k < ceil(x)` whether or not `x` is an integer.
    fn thresholds(&mut self, beta: f32) -> [u32; 32] {
        if let Some((bits, t)) = self.cache {
            if bits == beta.to_bits() {
                return t;
            }
        }
        let mut t = [0u32; 32];
        for us in 0..=4i32 {
            for ut in 0..=2i32 {
                let de = (8 - 4 * us) as f32 + self.model.jtau * (4 - 4 * ut) as f32;
                let p = self.exp.eval(-beta * de);
                let scaled = (f64::from(p) * 16_777_216.0).ceil();
                t[(us | (ut << 3)) as usize] =
                    if scaled >= 16_777_216.0 { 1 << 24 } else { scaled.max(0.0) as u32 };
            }
        }
        self.cache = Some((beta.to_bits(), t));
        t
    }

    /// Next 24-bit uniform, refilling one interlaced row at a time.
    #[inline]
    fn next_r24(&mut self) -> u32 {
        if self.cursor >= U::LANES {
            self.rng.next_into(&mut self.row[..U::LANES]);
            self.cursor = 0;
        }
        let r = self.row[self.cursor];
        self.cursor += 1;
        r >> 8
    }

    fn sweep_once(&mut self, table: &[u32; 32], stats: &mut SweepStats) {
        let n = self.model.base.n;
        let nw = self.nw;
        let rshift = self.rbits - 1;
        for phase in 0..2usize {
            // Fresh rows per phase: leftover uniforms are discarded so the
            // serialized RNG state fully describes the stream position.
            self.cursor = U::LANES;
            for v in 0..n {
                let nb = self.nbrs[v];
                let base_mask = if (self.colors[v] as usize + phase) % 2 == 0 {
                    EVEN_BITS
                } else {
                    EVEN_BITS << 1
                };
                let row0 = v * nw;
                let last = row0 + nw - 1;
                for j in 0..nw {
                    let w = self.spins[row0 + j];
                    // Tau neighbours: the same column shifted by one layer,
                    // with cross-word and wrap-around carries.
                    let prev_bit = if j == 0 {
                        (self.spins[last] >> rshift) & 1
                    } else {
                        self.spins[row0 + j - 1] >> 63
                    };
                    let down = (w << 1) | prev_bit;
                    let up = if j + 1 == nw {
                        (w >> 1) | ((self.spins[row0] & 1) << rshift)
                    } else {
                        (w >> 1) | ((self.spins[row0 + j + 1] & 1) << 63)
                    };
                    let d_down = w ^ down;
                    let d_up = w ^ up;
                    let t_ones = d_down ^ d_up;
                    let t_twos = d_down & d_up;
                    // Space neighbours: same word index, XOR with the bond
                    // mask turns "bits differ" into "bond unsatisfied".
                    let x0 = w ^ self.spins[nb[0].0 as usize * nw + j] ^ nb[0].1;
                    let x1 = w ^ self.spins[nb[1].0 as usize * nw + j] ^ nb[1].1;
                    let x2 = w ^ self.spins[nb[2].0 as usize * nw + j] ^ nb[2].1;
                    let x3 = w ^ self.spins[nb[3].0 as usize * nw + j] ^ nb[3].1;
                    // Carry-save adder: u_space per bit as 3 bit-planes.
                    let (s_a, c_a) = (x0 ^ x1, x0 & x1);
                    let (s_b, c_b) = (x2 ^ x3, x2 & x3);
                    let ones = s_a ^ s_b;
                    let c_c = s_a & s_b;
                    let twos = c_a ^ c_b ^ c_c;
                    let fours = (c_a & c_b) | (c_c & (c_a ^ c_b));
                    let valid = if j + 1 == nw && self.rbits < 64 {
                        (1u64 << self.rbits) - 1
                    } else {
                        !0u64
                    };
                    let active = base_mask & valid;
                    let mut bits = active;
                    let mut accept = 0u64;
                    while bits != 0 {
                        let b = bits.trailing_zeros();
                        bits &= bits - 1;
                        let bin = bin_at(b, ones, twos, fours, t_ones, t_twos);
                        if self.next_r24() < table[bin] {
                            accept |= 1u64 << b;
                        }
                    }
                    self.spins[row0 + j] = w ^ accept;
                    stats.attempts += u64::from(active.count_ones());
                    stats.flips += u64::from(accept.count_ones());
                    stats.groups += 1;
                    stats.groups_with_flip += u64::from(accept != 0);
                }
            }
        }
    }

    fn pack_state(&mut self, s: &[f32]) {
        let n = self.model.base.n;
        for w in &mut self.spins {
            *w = 0;
        }
        for l in 0..self.model.n_layers {
            for v in 0..n {
                if s[l * n + v] < 0.0 {
                    self.spins[v * self.nw + l / 64] |= 1u64 << (l % 64);
                }
            }
        }
    }

    fn unpack_state(&self) -> Vec<f32> {
        let n = self.model.base.n;
        let mut s = vec![0.0f32; self.model.n_spins()];
        for l in 0..self.model.n_layers {
            for v in 0..n {
                let bit = (self.spins[v * self.nw + l / 64] >> (l % 64)) & 1;
                s[l * n + v] = 1.0 - 2.0 * bit as f32;
            }
        }
        s
    }
}

impl<U: SimdU32> Sweeper for M1MultiSpin<U> {
    fn kind(&self) -> SweepKind {
        SweepKind::M1MultiSpin
    }

    fn width(&self) -> usize {
        64
    }

    fn run(&mut self, n_sweeps: usize, beta: f32) -> SweepStats {
        let mut stats = SweepStats::default();
        let table = self.thresholds(beta);
        // Whole-loop guard: `update` includes nested RNG regeneration
        // (exclusive update time = update - rng).
        let _g = crate::obs::phase::timed(crate::obs::phase::Phase::Update);
        for _ in 0..n_sweeps {
            self.sweep_once(&table, &mut stats);
        }
        stats
    }

    fn energy(&mut self) -> f64 {
        let _g = crate::obs::phase::timed(crate::obs::phase::Phase::Reduce);
        let s = self.unpack_state();
        self.model.total_energy(&s)
    }

    fn state(&mut self) -> Vec<f32> {
        self.unpack_state()
    }

    fn set_state(&mut self, s: &[f32]) {
        assert_eq!(s.len(), self.model.n_spins());
        self.pack_state(s);
    }

    /// Always exactly 0: nothing is incrementally maintained — every
    /// phase recomputes the neighbour sums from the packed words.
    fn validate(&mut self) -> f64 {
        0.0
    }

    fn rng_state(&self) -> Option<Vec<u32>> {
        Some(self.rng.state_words())
    }

    fn set_rng_state(&mut self, words: &[u32]) -> bool {
        self.rng.restore_words(words)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ising::builder::{pm_torus_workload, torus_workload};
    use crate::rng::u32_to_unit_f32;
    use crate::simd::portable::U32xN;

    type M1 = M1MultiSpin<U32xN<8>>;

    /// Independent per-spin oracle: replays the documented visit order
    /// (phase, vertex, ascending layer within the active class) with the
    /// same interlaced uniform stream, but decides each flip with the
    /// per-spin A.2 rule `u < exp(-beta ΔE)` on freshly summed f32
    /// neighbour fields — no bit packing, no bins, no thresholds.
    fn oracle_run(
        model: &QmcModel,
        colors: &[u32],
        s0: &[f32],
        seed: u32,
        exp: ExpMode,
        n_sweeps: usize,
        beta: f32,
    ) -> (Vec<f32>, u64) {
        let n = model.base.n;
        let layers = model.n_layers;
        let adj = model.base.adjacency();
        let mut s = s0.to_vec();
        let mut rng = Mt19937Simd::<U32xN<8>>::from_base_seed(seed);
        let mut row = [0u32; 8];
        let mut flips = 0u64;
        for _ in 0..n_sweeps {
            for phase in 0..2usize {
                let mut cursor = 8; // discard leftovers, like the sweeper
                for v in 0..n {
                    for l in 0..layers {
                        if (l + colors[v] as usize) % 2 != phase {
                            continue;
                        }
                        if cursor == 8 {
                            rng.next_into(&mut row);
                            cursor = 0;
                        }
                        let u = u32_to_unit_f32(row[cursor]);
                        cursor += 1;
                        let mut hs = 0.0f32;
                        for &(nb, j) in &adj[v] {
                            hs += j * s[l * n + nb as usize];
                        }
                        let down = s[((l + layers - 1) % layers) * n + v];
                        let upv = s[((l + 1) % layers) * n + v];
                        let i = l * n + v;
                        let de = 2.0 * s[i] * (hs + model.jtau * (down + upv));
                        if u < exp.eval(-beta * de) {
                            s[i] = -s[i];
                            flips += 1;
                        }
                    }
                }
            }
        }
        (s, flips)
    }

    #[test]
    fn m1_decisions_match_the_per_spin_oracle_bit_exactly() {
        // Geometries covering every word path: one partial word (L=12),
        // one exactly-full word (L=64), and multiple words with a short
        // wrap-around tail (L=66 → rbits=2).
        for layers in [12usize, 64, 66] {
            let wl = pm_torus_workload(4, 4, layers, 5, 0.5);
            for exp in [ExpMode::Fast, ExpMode::Exact] {
                let mut m1 = M1::new(&wl.model, &wl.s0, 9, exp).unwrap();
                let colors = m1.colors.clone();
                let stats = m1.run(3, 0.7);
                let (want_s, want_flips) = oracle_run(&wl.model, &colors, &wl.s0, 9, exp, 3, 0.7);
                assert_eq!(m1.state(), want_s, "state diverged (L={layers}, {exp:?})");
                assert_eq!(stats.flips, want_flips, "flip count (L={layers}, {exp:?})");
                assert_eq!(stats.attempts, 3 * wl.model.n_spins() as u64);
                assert!(stats.flips > 0, "vacuous run (L={layers})");
            }
        }
    }

    #[test]
    fn builder_coloring_matches_the_internal_bfs_bipartition() {
        let wl = pm_torus_workload(6, 4, 8, 2, 0.5);
        let m1 = M1::new(&wl.model, &wl.s0, 1, ExpMode::Fast).unwrap();
        assert_eq!(m1.colors, wl.colors);
    }

    #[test]
    fn per_bin_thresholds_reproduce_per_spin_acceptance() {
        let wl = pm_torus_workload(4, 4, 8, 1, 0.5);
        let mut m1 = M1::new(&wl.model, &wl.s0, 1, ExpMode::Fast).unwrap();
        let beta = 0.44f32;
        let t = m1.thresholds(beta);
        for us in 0..=4i32 {
            for ut in 0..=2i32 {
                let de = (8 - 4 * us) as f32 + wl.model.jtau * (4 - 4 * ut) as f32;
                let p = ExpMode::Fast.eval(-beta * de);
                let thr = t[(us | (ut << 3)) as usize];
                let check = |r24: u32| {
                    let per_spin = (r24 as f32 * (1.0 / 16_777_216.0)) < p;
                    assert_eq!(r24 < thr, per_spin, "bin us={us} ut={ut} r24={r24}");
                };
                // Boundary scan plus a coarse sweep of the uniform range.
                for d in 0..4u32 {
                    check(thr.saturating_sub(d).min((1 << 24) - 1));
                    check((thr + d).min((1 << 24) - 1));
                }
                for r24 in (0..(1u32 << 24)).step_by(65_537) {
                    check(r24);
                }
            }
        }
    }

    #[test]
    fn checkpoint_roundtrip_resumes_bit_exactly() {
        let wl = pm_torus_workload(4, 4, 66, 2, 0.5);
        let mut a = M1::new(&wl.model, &wl.s0, 3, ExpMode::Fast).unwrap();
        a.run(2, 0.9);
        let snap_rng = a.rng_state().unwrap();
        let snap_s = a.state();
        a.run(3, 0.9);
        let want = a.state();
        let mut b = M1::new(&wl.model, &wl.s0, 99, ExpMode::Fast).unwrap();
        b.set_state(&snap_s);
        assert!(b.set_rng_state(&snap_rng));
        b.run(3, 0.9);
        assert_eq!(b.state(), want);
        assert_eq!(a.energy(), b.energy());
        assert!(!b.set_rng_state(&snap_rng[..snap_rng.len() - 1]));
    }

    #[test]
    fn construction_rejects_non_pm_workloads() {
        // Continuous couplings and nonzero fields (the default builder).
        let continuous = torus_workload(4, 4, 8, 1, 0.3);
        let err = M1::new(&continuous.model, &continuous.s0, 1, ExpMode::Fast).unwrap_err();
        assert!(format!("{err:#}").contains("pm_torus_workload"), "{err:#}");

        // A single continuous coupling on an otherwise ±J workload.
        let mut mixed = pm_torus_workload(4, 4, 8, 1, 0.5);
        mixed.model.base.edges[0].2 = 0.5;
        let err = M1::new(&mixed.model, &mixed.s0, 1, ExpMode::Fast).unwrap_err();
        assert!(format!("{err:#}").contains("couplings"), "{err:#}");

        // Odd layer counts break the checkerboard tau wrap.
        let odd = pm_torus_workload(4, 4, 9, 1, 0.5);
        let err = M1::new(&odd.model, &odd.s0, 1, ExpMode::Fast).unwrap_err();
        assert!(format!("{err:#}").contains("even layer count"), "{err:#}");
    }

    #[test]
    fn stats_energy_and_state_are_consistent() {
        let wl = pm_torus_workload(4, 4, 12, 4, 0.5);
        let mut m1 = M1::new(&wl.model, &wl.s0, 7, ExpMode::Fast).unwrap();
        assert_eq!(m1.kind(), SweepKind::M1MultiSpin);
        assert_eq!(m1.width(), 64);
        // Pack → unpack is the identity on ±1 states.
        assert_eq!(m1.state(), wl.s0);
        let stats = m1.run(5, 0.6);
        let n_spins = wl.model.n_spins() as u64;
        assert_eq!(stats.attempts, 5 * n_spins);
        // One decision group per (phase, vertex, word) visit.
        assert_eq!(stats.groups, 5 * 2 * (wl.model.base.n * m1.nw) as u64);
        assert!(stats.flips > 0 && stats.flips <= stats.attempts);
        assert!(stats.groups_with_flip <= stats.groups);
        assert_eq!(m1.validate(), 0.0);
        let e = m1.energy();
        assert_eq!(e, wl.model.total_energy(&m1.state()));
        // The padding bits above the last valid layer stay zero.
        for v in 0..wl.model.base.n {
            assert_eq!(m1.spins[(v + 1) * m1.nw - 1] >> m1.rbits, 0, "vertex {v}");
        }
    }
}
