//! A.1 — the paper's *original* implementation: sequential scalar sweep,
//! branchy inner loop (Figure 2), Figure-4 nested data structures, and
//! the library exponential.
//!
//! This rung is deliberately written the way the original code was —
//! endpoint disambiguation with an `if`, a tau/space `if` consulting the
//! `isATauEdge` flag array, and re-computing `2 * S_mul * J` inside the
//! loop — because it *is* the baseline being measured.  Do not "clean it
//! up": every inefficiency here is load-bearing for the reproduction.

use crate::ising::layout::OriginalLayout;
use crate::ising::QmcModel;
use crate::rng::Mt19937;

use super::{ExpMode, SweepKind, SweepStats, Sweeper};

pub struct A1Original {
    model: QmcModel,
    lay: OriginalLayout,
    s: Vec<f32>,
    h_eff_space: Vec<f32>,
    h_eff_tau: Vec<f32>,
    rng: Mt19937,
    exp: ExpMode,
}

impl A1Original {
    pub fn new(model: &QmcModel, s0: &[f32], seed: u32, exp: ExpMode) -> Self {
        assert_eq!(s0.len(), model.n_spins());
        let lay = OriginalLayout::build(model);
        let (h_eff_space, h_eff_tau) = model.effective_fields(s0);
        Self {
            model: model.clone(),
            lay,
            s: s0.to_vec(),
            h_eff_space,
            h_eff_tau,
            rng: Mt19937::new(seed),
            exp,
        }
    }

    fn sweep_once(&mut self, beta: f32, stats: &mut SweepStats) {
        let n_spins = self.s.len();
        for curr_spin in 0..n_spins {
            // Figure 1: "if uniform(0,1) random number < probability of
            // flipping"; one uniform consumed per spin.
            let u = self.rng.next_f32();
            let de = 2.0 * self.s[curr_spin] * (self.h_eff_space[curr_spin] + self.h_eff_tau[curr_spin]);
            let p = self.exp.eval(-beta * de);
            stats.attempts += 1;
            stats.groups += 1;
            if u < p {
                stats.flips += 1;
                stats.groups_with_flip += 1;
                let s_mul = self.s[curr_spin];
                self.s[curr_spin] = -s_mul;
                // Figure 2 — the original inner loop, branches and all.
                let incident = &self.lay.incident_edges[curr_spin];
                for edge_index in 0..incident.len() {
                    let curr_edge = incident[edge_index] as usize;
                    let ge = &self.lay.graph_edges[curr_edge];
                    let curr_nbr;
                    if ge[0] == curr_spin as u32 {
                        curr_nbr = ge[1] as usize;
                    } else {
                        curr_nbr = ge[0] as usize;
                    }
                    if self.lay.is_a_tau_edge[curr_edge] {
                        self.h_eff_tau[curr_nbr] -= 2.0 * s_mul * self.lay.j[curr_edge];
                    } else {
                        self.h_eff_space[curr_nbr] -= 2.0 * s_mul * self.lay.j[curr_edge];
                    }
                }
            }
        }
    }
}

impl Sweeper for A1Original {
    fn kind(&self) -> SweepKind {
        SweepKind::A1Original
    }

    fn run(&mut self, n_sweeps: usize, beta: f32) -> SweepStats {
        let mut stats = SweepStats::default();
        for _ in 0..n_sweeps {
            self.sweep_once(beta, &mut stats);
        }
        stats
    }

    fn energy(&mut self) -> f64 {
        self.model.total_energy(&self.s)
    }

    fn state(&mut self) -> Vec<f32> {
        self.s.clone()
    }

    fn set_state(&mut self, s: &[f32]) {
        assert_eq!(s.len(), self.s.len());
        self.s.copy_from_slice(s);
        let (hs, ht) = self.model.effective_fields(s);
        self.h_eff_space = hs;
        self.h_eff_tau = ht;
    }

    fn validate(&mut self) -> f64 {
        let (hs, ht) = self.model.effective_fields(&self.s);
        let mut worst = 0.0f64;
        for i in 0..self.s.len() {
            worst = worst
                .max((hs[i] - self.h_eff_space[i]).abs() as f64)
                .max((ht[i] - self.h_eff_tau[i]).abs() as f64);
        }
        worst
    }

    fn rng_state(&self) -> Option<Vec<u32>> {
        Some(self.rng.state_words())
    }

    fn set_rng_state(&mut self, words: &[u32]) -> bool {
        self.rng.restore_words(words)
    }
}
