//! A.3 — vectorized MT19937 and vectorized flip decisions (paper §3),
//! generic over the SIMD lane width.
//!
//! Spins are processed in the W-way interlaced order, one *group* per
//! step: `W` uniforms arrive as one vector register from the interlaced
//! generator, `W` energy deltas and `W` flip probabilities are computed
//! with `W`-wide ops, and the accept comparison produces a lane mask
//! (Figure 10).  The neighbour updates, however, are still the scalar
//! Figure-6 loop per flipped lane — that is precisely what A.4 adds.
//!
//! `A3VecRng<U32x4>` is the paper's SSE rung; `A3VecRng<avx2::U32x8>` the
//! AVX2 octet form; the portable lanes run any width on any arch.

use crate::expapprox::simd::exp_fast_wide;
use crate::ising::QmcModel;
use crate::rng::Mt19937Simd;
use crate::simd::{MAX_LANES, SimdF32, SimdU32};

use super::interlaced::InterlacedModel;
use super::{ExpMode, SweepKind, SweepStats, Sweeper};

pub struct A3VecRng<U: SimdU32> {
    model: QmcModel,
    im: InterlacedModel,
    /// Spins in interlaced order.
    s: Vec<f32>,
    /// Effective fields in interlaced order.
    hs: Vec<f32>,
    ht: Vec<f32>,
    rng: Mt19937Simd<U>,
    exp: ExpMode,
}

/// Compute `W` flip probabilities for `x = -beta*dE` lanes.
#[inline(always)]
pub(super) fn probs_wide<F: SimdF32>(exp: ExpMode, x: F) -> F {
    match exp {
        ExpMode::Fast => exp_fast_wide(x.max(F::splat(-80.0))),
        // Non-default modes (test alignment) evaluated per lane.
        other => {
            debug_assert!(F::LANES <= MAX_LANES);
            let mut buf = [0.0f32; MAX_LANES];
            x.store(&mut buf);
            for v in buf.iter_mut().take(F::LANES) {
                *v = other.eval(*v);
            }
            F::load(&buf)
        }
    }
}

impl<U: SimdU32> A3VecRng<U> {
    pub fn new(model: &QmcModel, s0: &[f32], seed: u32, exp: ExpMode) -> Self {
        assert_eq!(s0.len(), model.n_spins());
        let im = InterlacedModel::build_w(model, U::LANES);
        let s = im.it.to_interlaced(s0);
        let (hs0, ht0) = model.effective_fields(s0);
        let hs = im.it.to_interlaced(&hs0);
        let ht = im.it.to_interlaced(&ht0);
        // The paper's W interlaced generators "with different seeds".
        let rng = Mt19937Simd::from_base_seed(seed);
        Self { model: model.clone(), im, s, hs, ht, rng, exp }
    }

    /// Scalar flip of lane `lane` of group `g` — the A.2-style update
    /// loop over the shared group-edge table.
    #[inline]
    fn flip_scalar(&mut self, g: usize, lane: usize) {
        let w = U::LANES;
        let i = w * g + lane;
        let two_s_mul = 2.0 * self.s[i];
        self.s[i] = -self.s[i];
        let (lo, hi) = (self.im.qoffsets[g] as usize, self.im.qoffsets[g + 1] as usize);
        for e in lo..hi {
            let t = self.im.qedge_target[e] as usize + lane;
            self.hs[t] -= two_s_mul * self.im.qedge_j[e];
        }
        let up = match self.im.up_base(g) {
            Some(b) => b + lane,
            None => self.im.up_wrap_base(g) + (lane + 1) % w,
        };
        let down = match self.im.down_base(g) {
            Some(b) => b + lane,
            None => self.im.down_wrap_base(g) + (lane + w - 1) % w,
        };
        self.ht[up] -= two_s_mul * self.im.jtau;
        self.ht[down] -= two_s_mul * self.im.jtau;
    }

    #[inline(always)]
    fn sweep_once(&mut self, beta: f32, stats: &mut SweepStats) {
        let w = U::LANES;
        let n_groups = self.im.n_groups();
        let neg_beta = <U::F as SimdF32>::splat(-beta);
        let two = <U::F as SimdF32>::splat(2.0);
        for g in 0..n_groups {
            let u = self.rng.next_vec_f32();
            let sv = <U::F as SimdF32>::load(&self.s[w * g..]);
            let hsv = <U::F as SimdF32>::load(&self.hs[w * g..]);
            let htv = <U::F as SimdF32>::load(&self.ht[w * g..]);
            let de = two * sv * (hsv + htv);
            let p = probs_wide(self.exp, neg_beta * de);
            let mask = u.lt(p);
            let mm = mask.movemask();
            stats.attempts += w as u64;
            stats.groups += 1;
            if mm != 0 {
                stats.groups_with_flip += 1;
                stats.flips += mm.count_ones() as u64;
                for lane in 0..w {
                    if mm & (1 << lane) != 0 {
                        self.flip_scalar(g, lane);
                    }
                }
            }
        }
    }
}

impl<U: SimdU32> Sweeper for A3VecRng<U> {
    fn kind(&self) -> SweepKind {
        SweepKind::a3_for_width(U::LANES)
    }

    fn width(&self) -> usize {
        U::LANES
    }

    fn run(&mut self, n_sweeps: usize, beta: f32) -> SweepStats {
        let mut stats = SweepStats::default();
        U::with_features(|| {
            for _ in 0..n_sweeps {
                self.sweep_once(beta, &mut stats);
            }
        });
        stats
    }

    fn energy(&mut self) -> f64 {
        self.model.total_energy(&self.im.it.to_original(&self.s))
    }

    fn state(&mut self) -> Vec<f32> {
        self.im.it.to_original(&self.s)
    }

    fn set_state(&mut self, s: &[f32]) {
        self.s = self.im.it.to_interlaced(s);
        let (hs0, ht0) = self.model.effective_fields(s);
        self.hs = self.im.it.to_interlaced(&hs0);
        self.ht = self.im.it.to_interlaced(&ht0);
    }

    fn validate(&mut self) -> f64 {
        let orig = self.im.it.to_original(&self.s);
        let (hs0, ht0) = self.model.effective_fields(&orig);
        let hs = self.im.it.to_interlaced(&hs0);
        let ht = self.im.it.to_interlaced(&ht0);
        let mut worst = 0.0f64;
        for i in 0..self.s.len() {
            worst = worst
                .max((hs[i] - self.hs[i]).abs() as f64)
                .max((ht[i] - self.ht[i]).abs() as f64);
        }
        worst
    }

    fn rng_state(&self) -> Option<Vec<u32>> {
        Some(self.rng.state_words())
    }

    fn set_rng_state(&mut self, words: &[u32]) -> bool {
        self.rng.restore_words(words)
    }
}
