//! A.3 — vectorized MT19937 and vectorized flip decisions (paper §3).
//!
//! Spins are processed in the 4-way interlaced order, one *quadruplet*
//! per step: four uniforms arrive as one SSE register from the interlaced
//! generator, four energy deltas and four flip probabilities are computed
//! with 4-wide ops, and the accept comparison produces a lane mask
//! (Figure 10).  The neighbour updates, however, are still the scalar
//! Figure-6 loop per flipped lane — that is precisely what A.4 adds.

use crate::expapprox::simd::exp_fast_x4;
use crate::ising::QmcModel;
use crate::rng::Mt19937x4;
use crate::simd::F32x4;

use super::interlaced::InterlacedModel;
use super::{ExpMode, SweepKind, SweepStats, Sweeper};

pub struct A3VecRng {
    model: QmcModel,
    im: InterlacedModel,
    /// Spins in interlaced order.
    s: Vec<f32>,
    /// Effective fields in interlaced order.
    hs: Vec<f32>,
    ht: Vec<f32>,
    rng: Mt19937x4,
    exp: ExpMode,
}

/// Compute four flip probabilities for `x = -beta*dE` lanes.
#[inline(always)]
pub(super) fn probs_x4(exp: ExpMode, x: F32x4) -> F32x4 {
    match exp {
        ExpMode::Fast => exp_fast_x4(x.max(F32x4::splat(-80.0))),
        // Non-default modes (test alignment) evaluated per lane.
        other => {
            let a = x.to_array();
            F32x4::from([other.eval(a[0]), other.eval(a[1]), other.eval(a[2]), other.eval(a[3])])
        }
    }
}

impl A3VecRng {
    pub fn new(model: &QmcModel, s0: &[f32], seed: u32, exp: ExpMode) -> Self {
        assert_eq!(s0.len(), model.n_spins());
        let im = InterlacedModel::build(model);
        let s = im.it.to_interlaced(s0);
        let (hs0, ht0) = model.effective_fields(s0);
        let hs = im.it.to_interlaced(&hs0);
        let ht = im.it.to_interlaced(&ht0);
        // The paper's 4 interlaced generators "with different seeds".
        let rng = Mt19937x4::new([seed, seed.wrapping_add(1), seed.wrapping_add(2), seed.wrapping_add(3)]);
        Self { model: model.clone(), im, s, hs, ht, rng, exp }
    }

    /// Scalar flip of lane `lane` of quadruplet `q` — the A.2-style
    /// update loop over the shared quad-edge table.
    #[inline]
    fn flip_scalar(&mut self, q: usize, lane: usize) {
        let i = 4 * q + lane;
        let two_s_mul = 2.0 * self.s[i];
        self.s[i] = -self.s[i];
        let (lo, hi) = (self.im.qoffsets[q] as usize, self.im.qoffsets[q + 1] as usize);
        for e in lo..hi {
            let t = self.im.qedge_target[e] as usize + lane;
            self.hs[t] -= two_s_mul * self.im.qedge_j[e];
        }
        let up = match self.im.up_quad(q) {
            Some(b) => b + lane,
            None => self.im.up_wrap_quad(q) + (lane + 1) % 4,
        };
        let down = match self.im.down_quad(q) {
            Some(b) => b + lane,
            None => self.im.down_wrap_quad(q) + (lane + 3) % 4,
        };
        self.ht[up] -= two_s_mul * self.im.jtau;
        self.ht[down] -= two_s_mul * self.im.jtau;
    }

    fn sweep_once(&mut self, beta: f32, stats: &mut SweepStats) {
        let n_quads = self.im.n_quads();
        let neg_beta = F32x4::splat(-beta);
        let two = F32x4::splat(2.0);
        for q in 0..n_quads {
            let u4 = self.rng.next4_f32();
            let s4 = F32x4::load(&self.s[4 * q..]);
            let hs4 = F32x4::load(&self.hs[4 * q..]);
            let ht4 = F32x4::load(&self.ht[4 * q..]);
            let de4 = two * s4 * (hs4 + ht4);
            let p4 = probs_x4(self.exp, neg_beta * de4);
            let mask = u4.lt(p4);
            let mm = mask.movemask();
            stats.attempts += 4;
            stats.groups += 1;
            if mm != 0 {
                stats.groups_with_flip += 1;
                stats.flips += mm.count_ones() as u64;
                for lane in 0..4 {
                    if mm & (1 << lane) != 0 {
                        self.flip_scalar(q, lane);
                    }
                }
            }
        }
    }
}

impl Sweeper for A3VecRng {
    fn kind(&self) -> SweepKind {
        SweepKind::A3VecRng
    }

    fn run(&mut self, n_sweeps: usize, beta: f32) -> SweepStats {
        let mut stats = SweepStats::default();
        for _ in 0..n_sweeps {
            self.sweep_once(beta, &mut stats);
        }
        stats
    }

    fn energy(&mut self) -> f64 {
        self.model.total_energy(&self.im.it.to_original(&self.s))
    }

    fn state(&mut self) -> Vec<f32> {
        self.im.it.to_original(&self.s)
    }

    fn set_state(&mut self, s: &[f32]) {
        self.s = self.im.it.to_interlaced(s);
        let (hs0, ht0) = self.model.effective_fields(s);
        self.hs = self.im.it.to_interlaced(&hs0);
        self.ht = self.im.it.to_interlaced(&ht0);
    }

    fn validate(&mut self) -> f64 {
        let orig = self.im.it.to_original(&self.s);
        let (hs0, ht0) = self.model.effective_fields(&orig);
        let hs = self.im.it.to_interlaced(&hs0);
        let ht = self.im.it.to_interlaced(&ht0);
        let mut worst = 0.0f64;
        for i in 0..self.s.len() {
            worst = worst
                .max((hs[i] - self.hs[i]).abs() as f64)
                .max((ht[i] - self.ht[i]).abs() as f64);
        }
        worst
    }
}
