//! A.2 — the paper's §2 *basic optimizations*: branch elimination, the
//! simplified Figure-5/6 data structure (flat per-spin edge arrays with
//! the two tau edges last), result caching, and the fast exponential
//! approximation.
//!
//! The inner update loop is the paper's Figure 6 verbatim: one line per
//! space edge, then the two tau edges unrolled, no `isATauEdge` flag, no
//! endpoint branch, and `2 * S_mul` hoisted out of the loop.

use crate::ising::layout::CsrLayout;
use crate::ising::QmcModel;
use crate::rng::Mt19937;

use super::{ExpMode, SweepKind, SweepStats, Sweeper};

pub struct A2Basic {
    model: QmcModel,
    lay: CsrLayout,
    s: Vec<f32>,
    h_eff_space: Vec<f32>,
    h_eff_tau: Vec<f32>,
    rng: Mt19937,
    exp: ExpMode,
}

impl A2Basic {
    pub fn new(model: &QmcModel, s0: &[f32], seed: u32, exp: ExpMode) -> Self {
        assert_eq!(s0.len(), model.n_spins());
        let lay = CsrLayout::build(model);
        let (h_eff_space, h_eff_tau) = model.effective_fields(s0);
        Self {
            model: model.clone(),
            lay,
            s: s0.to_vec(),
            h_eff_space,
            h_eff_tau,
            rng: Mt19937::new(seed),
            exp,
        }
    }

    fn sweep_once(&mut self, beta: f32, stats: &mut SweepStats) {
        let n_spins = self.s.len();
        let neg_beta = -beta; // result caching: hoisted once per sweep
        for i in 0..n_spins {
            let u = self.rng.next_f32();
            let de = 2.0 * self.s[i] * (self.h_eff_space[i] + self.h_eff_tau[i]);
            let p = self.exp.eval(neg_beta * de);
            stats.attempts += 1;
            stats.groups += 1;
            if u < p {
                stats.flips += 1;
                stats.groups_with_flip += 1;
                // §2.3 result caching: S_mul never read without doubling.
                let two_s_mul = 2.0 * self.s[i];
                self.s[i] = -self.s[i];
                // Figure 6: flat edges, tau pair last, branch-free body.
                let (lo, hi) = (self.lay.offsets[i] as usize, self.lay.offsets[i + 1] as usize);
                let targets = &self.lay.edge_target[lo..hi];
                let js = &self.lay.edge_j[lo..hi];
                let k = targets.len();
                for e in 0..k - 2 {
                    self.h_eff_space[targets[e] as usize] -= two_s_mul * js[e];
                }
                self.h_eff_tau[targets[k - 2] as usize] -= two_s_mul * js[k - 2];
                self.h_eff_tau[targets[k - 1] as usize] -= two_s_mul * js[k - 1];
            }
        }
    }
}

impl Sweeper for A2Basic {
    fn kind(&self) -> SweepKind {
        SweepKind::A2Basic
    }

    fn run(&mut self, n_sweeps: usize, beta: f32) -> SweepStats {
        let mut stats = SweepStats::default();
        for _ in 0..n_sweeps {
            self.sweep_once(beta, &mut stats);
        }
        stats
    }

    fn energy(&mut self) -> f64 {
        self.model.total_energy(&self.s)
    }

    fn state(&mut self) -> Vec<f32> {
        self.s.clone()
    }

    fn set_state(&mut self, s: &[f32]) {
        assert_eq!(s.len(), self.s.len());
        self.s.copy_from_slice(s);
        let (hs, ht) = self.model.effective_fields(s);
        self.h_eff_space = hs;
        self.h_eff_tau = ht;
    }

    fn validate(&mut self) -> f64 {
        let (hs, ht) = self.model.effective_fields(&self.s);
        let mut worst = 0.0f64;
        for i in 0..self.s.len() {
            worst = worst
                .max((hs[i] - self.h_eff_space[i]).abs() as f64)
                .max((ht[i] - self.h_eff_tau[i]).abs() as f64);
        }
        worst
    }

    fn rng_state(&self) -> Option<Vec<u32>> {
        Some(self.rng.state_words())
    }

    fn set_rng_state(&mut self, words: &[u32]) -> bool {
        self.rng.restore_words(words)
    }
}
