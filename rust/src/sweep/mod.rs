//! The Metropolis sweep optimization ladder — the paper's Table 1,
//! extended along the vector-width axis.
//!
//! Every rung implements [`Sweeper`] over the same [`QmcModel`], so the
//! benchmark harness can time them interchangeably and the tests can
//! check trajectory/statistical equivalence:
//!
//! | Rung   | Module | Lanes | Paper ingredients |
//! |--------|--------|-------|-------------------|
//! | A.1    | [`a1_original`] | 1 | Fig-2 branchy loop, Fig-4 nested tables, library `exp` |
//! | A.2    | [`a2_basic`]    | 1 | Fig-3/6 branch-free flat loop, tau-last edges, result caching, fast `exp` (§2) |
//! | A.3    | [`a3_vecrng`]   | 4 | + SSE-interlaced MT19937 and vector flip decisions (§3) |
//! | A.4    | [`a4_full`]     | 4 | + vectorized neighbour updates via 4-way layer interlacing (§3.1) |
//! | A.3w8  | [`a3_vecrng`]   | 8 | A.3 on the AVX2 octet substrate (portable fallback without AVX2) |
//! | A.4w8  | [`a4_full`]     | 8 | A.4 on the AVX2 octet substrate (portable fallback without AVX2) |
//! | C.1    | [`c1_replica_batch`] | 4 | lane-per-replica batch: 4 tempering replicas in lockstep, per-lane β (§3.2's coalescing applied across the ensemble) |
//! | C.1w8  | [`c1_replica_batch`] | 8 | the same batch on the AVX2 octet substrate |
//! | M.1    | [`m1_multispin`] | 64 | multi-spin coding: 64 spins bit-packed per word, XOR-parity neighbour sums, per-bin integer acceptance thresholds |
//! | B.1    | [`crate::device`] | 32 | software device, naive gathered layout (§3.2) |
//! | B.2    | [`crate::device`] | 32 | software device, coalesced layout — "the only difference" (§3.2) |
//!
//! The A-rungs vectorize *within* one model; the C-rungs vectorize
//! *across* the tempering ensemble (one lane = one replica, so any layer
//! count ≥ 2 works — including the shallow models the A-rungs reject).
//! A C-rung sweeps a whole lane-batch and therefore implements the
//! batch-level [`c1_replica_batch::BatchSweeper`] instead of [`Sweeper`];
//! build one with [`c1_replica_batch::make_batch_sweeper`] or run a whole
//! ladder through `tempering::BatchedPtEnsemble`.
//!
//! The A.3/A.4 sweepers are generic over the [`crate::simd::SimdU32`]
//! backend.  Construction goes through the Engine API v1: a
//! [`crate::engine::SamplerSpec`] (rung × width × backend) resolved by
//! [`crate::engine::EngineBuilder`] into a capability-negotiated
//! [`crate::engine::Plan`].  [`SweepKind`] remains as the legacy
//! width-baked surface — every variant lowers onto the equivalent spec
//! (see [`SweepKind::spec`]) and [`try_make_sweeper`] is a thin shim over
//! the builder, so all old spellings keep working.
//!
//! The a/b compiler-optimization split of the paper (A.1a vs A.1b etc.) is
//! not a code difference — the harness measures the same rungs from a
//! binary built with `--profile opt0`.

pub mod a1_original;
pub mod ablation;
pub mod a2_basic;
pub mod a3_vecrng;
pub mod a4_full;
pub mod accel;
pub mod c1_replica_batch;
pub mod interlaced;
pub mod m1_multispin;

use crate::ising::QmcModel;

/// Which exponential the flip probability uses.  The paper's defaults:
/// A.1 the library `exp`; A.2–A.4 and B.x the fast approximation ("this
/// faster approximation was used in the performance tests for all
/// implementations with these basic optimizations").  Tests override the
/// mode to get bit-identical trajectories across rungs.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum ExpMode {
    Exact,
    Fast,
    Accurate,
}

impl ExpMode {
    /// Scalar flip probability for `x = -beta * dE`.
    #[inline(always)]
    pub fn eval(self, x: f32) -> f32 {
        match self {
            ExpMode::Exact => x.exp(),
            ExpMode::Fast => crate::expapprox::exp_fast(x.max(-80.0)),
            ExpMode::Accurate => crate::expapprox::exp_accurate(x),
        }
    }
}

/// The implementation rungs of the paper's Table 1, plus the width-8
/// variants of the vectorized CPU rungs.
#[derive(Copy, Clone, Debug, PartialEq, Eq, Hash)]
pub enum SweepKind {
    /// A.1 — original scalar implementation.
    A1Original,
    /// A.2 — basic optimizations (§2).
    A2Basic,
    /// A.3 — vectorized MT19937 + flip decisions (§3), 4 lanes (SSE2).
    A3VecRng,
    /// A.4 — fully vectorized, incl. neighbour updates (§3.1), 4 lanes.
    A4Full,
    /// A.3 at 8 lanes (AVX2 when available, portable otherwise).
    A3VecRngW8,
    /// A.4 at 8 lanes (AVX2 when available, portable otherwise).
    A4FullW8,
    /// C.1 — lane-per-replica batch of 4 tempering replicas (SSE2).
    C1ReplicaBatch,
    /// C.1 at 8 lanes (AVX2 when available, portable otherwise).
    C1ReplicaBatchW8,
    /// M.1 — multi-spin coding: 64 spins per word (±J workloads only).
    M1MultiSpin,
    /// B.1 — accelerator, naive layout.
    B1Accel,
    /// B.2 — accelerator, coalesced layout (§3.2).
    B2Accel,
}

impl std::str::FromStr for SweepKind {
    type Err = crate::Error;

    /// Parse CLI spellings: `a1-original`/`a1`/`A.1`, …, plus explicit
    /// width suffixes `a3-vec-rng-w8`/`a4-full-w8` (and `-w4` aliases for
    /// the paper-width rungs).
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "a1-original" | "a1" | "a.1" => Ok(SweepKind::A1Original),
            "a2-basic" | "a2" | "a.2" => Ok(SweepKind::A2Basic),
            "a3-vec-rng" | "a3-vecrng" | "a3" | "a.3" | "a3-vec-rng-w4" | "a3-w4" => {
                Ok(SweepKind::A3VecRng)
            }
            "a4-full" | "a4" | "a.4" | "a4-full-w4" | "a4-w4" => Ok(SweepKind::A4Full),
            "a3-vec-rng-w8" | "a3-vecrng-w8" | "a3-w8" | "a.3w8" => Ok(SweepKind::A3VecRngW8),
            "a4-full-w8" | "a4-w8" | "a.4w8" => Ok(SweepKind::A4FullW8),
            "c1-replica-batch" | "c1" | "c.1" | "c1-replica-batch-w4" | "c1-w4" => {
                Ok(SweepKind::C1ReplicaBatch)
            }
            "c1-replica-batch-w8" | "c1-w8" | "c.1w8" => Ok(SweepKind::C1ReplicaBatchW8),
            "m1-multispin" | "m1" | "m.1" => Ok(SweepKind::M1MultiSpin),
            "b1-accel" | "b1" | "b.1" => Ok(SweepKind::B1Accel),
            "b2-accel" | "b2" | "b.2" => Ok(SweepKind::B2Accel),
            other => anyhow::bail!(
                "unknown rung {other:?} (expected a1-original, a2-basic, a3-vec-rng, a4-full, \
                 a3-vec-rng-w8, a4-full-w8, c1-replica-batch, c1-replica-batch-w8, m1-multispin, \
                 b1-accel, b2-accel)"
            ),
        }
    }
}

impl SweepKind {
    /// Lower this legacy width-baked variant onto the orthogonal
    /// [`crate::engine::SamplerSpec`] it always meant.
    pub fn spec(self) -> crate::engine::SamplerSpec {
        self.into()
    }

    /// The canonical CLI spelling of this variant (the one `repro plan`
    /// reports as `legacy_kind`).
    pub fn cli_spelling(self) -> &'static str {
        match self {
            SweepKind::A1Original => "a1-original",
            SweepKind::A2Basic => "a2-basic",
            SweepKind::A3VecRng => "a3-vec-rng",
            SweepKind::A4Full => "a4-full",
            SweepKind::A3VecRngW8 => "a3-vec-rng-w8",
            SweepKind::A4FullW8 => "a4-full-w8",
            SweepKind::C1ReplicaBatch => "c1-replica-batch",
            SweepKind::C1ReplicaBatchW8 => "c1-replica-batch-w8",
            SweepKind::M1MultiSpin => "m1-multispin",
            SweepKind::B1Accel => "b1-accel",
            SweepKind::B2Accel => "b2-accel",
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            SweepKind::A1Original => "A.1",
            SweepKind::A2Basic => "A.2",
            SweepKind::A3VecRng => "A.3",
            SweepKind::A4Full => "A.4",
            SweepKind::A3VecRngW8 => "A.3w8",
            SweepKind::A4FullW8 => "A.4w8",
            SweepKind::C1ReplicaBatch => "C.1",
            SweepKind::C1ReplicaBatchW8 => "C.1w8",
            SweepKind::M1MultiSpin => "M.1",
            SweepKind::B1Accel => "B.1",
            SweepKind::B2Accel => "B.2",
        }
    }

    /// Paper-default exponential mode of this rung.
    pub fn default_exp(self) -> ExpMode {
        match self {
            SweepKind::A1Original => ExpMode::Exact,
            _ => ExpMode::Fast,
        }
    }

    /// Width of the group that must be decided together — 1 for scalar
    /// rungs, the lane count for the SIMD rungs, the interlace width for
    /// the accelerator (Fig 14's "1 spin out of W flips" analysis).
    pub fn group_width(self) -> usize {
        match self {
            SweepKind::A1Original | SweepKind::A2Basic => 1,
            SweepKind::A3VecRng | SweepKind::A4Full | SweepKind::C1ReplicaBatch => 4,
            SweepKind::A3VecRngW8 | SweepKind::A4FullW8 | SweepKind::C1ReplicaBatchW8 => 8,
            SweepKind::M1MultiSpin => 64,
            SweepKind::B1Accel | SweepKind::B2Accel => 32,
        }
    }

    /// Whether this rung sweeps a lane-batch of replicas (one lane = one
    /// tempering replica) rather than a single model.
    pub fn is_replica_batch(self) -> bool {
        matches!(self, SweepKind::C1ReplicaBatch | SweepKind::C1ReplicaBatchW8)
    }

    /// The C.1 rung at lane width `w` (4 or 8).
    pub fn c1_for_width(w: usize) -> SweepKind {
        if w == 8 {
            SweepKind::C1ReplicaBatchW8
        } else {
            SweepKind::C1ReplicaBatch
        }
    }

    /// The widest C.1 rung this host has a hand-written backend for.
    pub fn preferred_replica_batch() -> SweepKind {
        SweepKind::c1_for_width(crate::simd::widest_supported_width())
    }

    /// The A.3 rung at lane width `w` (4 or 8).
    pub fn a3_for_width(w: usize) -> SweepKind {
        if w == 8 {
            SweepKind::A3VecRngW8
        } else {
            SweepKind::A3VecRng
        }
    }

    /// The A.4 rung at lane width `w` (4 or 8).
    pub fn a4_for_width(w: usize) -> SweepKind {
        if w == 8 {
            SweepKind::A4FullW8
        } else {
            SweepKind::A4Full
        }
    }

    /// The fastest CPU rung at the widest lane count this host has a
    /// hand-written backend for — A.4w8 on AVX2 machines, A.4 otherwise.
    pub fn preferred_cpu() -> SweepKind {
        SweepKind::a4_for_width(crate::simd::widest_supported_width())
    }

    /// [`SweepKind::preferred_cpu`] constrained by the model geometry: the
    /// widest A.4 rung whose lane count the layer count supports.  The
    /// CLI's default `--kind`.
    pub fn preferred_cpu_for_layers(n_layers: usize) -> SweepKind {
        let wide = SweepKind::preferred_cpu();
        if wide.supports_layers(n_layers) {
            wide
        } else {
            SweepKind::A4Full
        }
    }

    /// Whether a model with `n_layers` QMC layers can run on this rung:
    /// the SIMD A-rungs interlace the layers into `group_width()` sections
    /// of at least 2 layers each.  The replica-batch C-rungs vectorize
    /// across the ensemble instead and accept any layer count ≥ 2.  (The
    /// accelerator rungs have their own geometry checks against the
    /// compiled artifacts.)
    pub fn supports_layers(self, n_layers: usize) -> bool {
        match self {
            SweepKind::A3VecRng
            | SweepKind::A4Full
            | SweepKind::A3VecRngW8
            | SweepKind::A4FullW8 => {
                crate::engine::builder::interlace_ok(n_layers, self.group_width())
            }
            SweepKind::C1ReplicaBatch | SweepKind::C1ReplicaBatchW8 => n_layers >= 2,
            // The multi-spin checkerboard phases need an even layer count
            // (the (layer + colour) parity classes must close under the
            // tau wrap).
            SweepKind::M1MultiSpin => n_layers >= 2 && n_layers % 2 == 0,
            // The naive device kernel gathers per lane: any well-formed
            // model runs.
            SweepKind::B1Accel => n_layers >= 2,
            // B.2's pair-packed coalesced streams need the tau ring to
            // close over the lane pairs — same parity argument as M.1.
            SweepKind::B2Accel => n_layers >= 2 && n_layers % 2 == 0,
            _ => true,
        }
    }

    /// The paper's four CPU rungs (widths 1 and 4).
    pub fn all_cpu() -> [SweepKind; 4] {
        [SweepKind::A1Original, SweepKind::A2Basic, SweepKind::A3VecRng, SweepKind::A4Full]
    }

    /// All six CPU rungs including the width-8 variants.  The W8 rungs
    /// need `n_layers` divisible by 8 with `n_layers/8 >= 2`.
    pub fn all_cpu_wide() -> [SweepKind; 6] {
        [
            SweepKind::A1Original,
            SweepKind::A2Basic,
            SweepKind::A3VecRng,
            SweepKind::A4Full,
            SweepKind::A3VecRngW8,
            SweepKind::A4FullW8,
        ]
    }
}

/// Counters accumulated over [`Sweeper::run`] calls.
#[derive(Copy, Clone, Debug, Default)]
pub struct SweepStats {
    /// Flip attempts (= spins visited).
    pub attempts: u64,
    /// Accepted flips.
    pub flips: u64,
    /// Decision groups processed (quadruplets/octets for the SIMD rungs).
    pub groups: u64,
    /// Groups in which at least one spin flipped — the paper's Fig-14
    /// "must wait for a flip" event.
    pub groups_with_flip: u64,
}

impl SweepStats {
    pub fn merge(&mut self, o: &SweepStats) {
        self.attempts += o.attempts;
        self.flips += o.flips;
        self.groups += o.groups;
        self.groups_with_flip += o.groups_with_flip;
    }

    /// Observed per-spin flip probability.
    pub fn flip_prob(&self) -> f64 {
        if self.attempts == 0 {
            0.0
        } else {
            self.flips as f64 / self.attempts as f64
        }
    }

    /// Observed probability that a decision group contains a flip.
    pub fn wait_prob(&self) -> f64 {
        if self.groups == 0 {
            0.0
        } else {
            self.groups_with_flip as f64 / self.groups as f64
        }
    }
}

/// A Metropolis sweep engine over one QMC Ising model.
pub trait Sweeper {
    fn kind(&self) -> SweepKind;

    /// Effective lane count.  The default reads the legacy kind tag;
    /// width-generic sweepers override it with the true `W` (the kind
    /// tag cannot spell widths beyond 8).
    fn width(&self) -> usize {
        self.kind().group_width()
    }

    /// Smallest number of sweeps a single `run` call can execute (1 for
    /// CPU rungs; `sweeps_per_call` for accelerator artifacts).
    fn granularity(&self) -> usize {
        1
    }

    /// Execute `n_sweeps` Metropolis sweeps at inverse temperature `beta`;
    /// `n_sweeps` must be a multiple of [`Self::granularity`].
    fn run(&mut self, n_sweeps: usize, beta: f32) -> SweepStats;

    /// Current total energy.
    fn energy(&mut self) -> f64;

    /// Current state in original (layer-major) order.
    fn state(&mut self) -> Vec<f32>;

    /// Replace the state (original order) — used by parallel tempering
    /// swaps and by the equivalence tests.
    fn set_state(&mut self, s: &[f32]);

    /// Maximum absolute inconsistency between the incrementally-maintained
    /// effective fields and a from-scratch recomputation (0 when exact).
    fn validate(&mut self) -> f64;

    /// Serialized RNG state for bit-exact checkpoint resume, or `None`
    /// when the rung cannot serialize its generator (accelerator
    /// artifacts keep theirs on device).
    fn rng_state(&self) -> Option<Vec<u32>> {
        None
    }

    /// Restore a state captured by [`Self::rng_state`]; `false` when
    /// unsupported or the payload does not match this rung's generator.
    fn set_rng_state(&mut self, _words: &[u32]) -> bool {
        false
    }

    /// Device execution counters — `Some` only for the accelerator rungs
    /// running on [`crate::device::DeviceSweeper`] (coalesced/strided
    /// transactions, shared-tile traffic, divergent replays).
    fn device_stats(&self) -> Option<crate::device::DeviceStats> {
        None
    }
}

/// Fallible construction with the rung's paper-default exponential mode.
///
/// A legacy-surface shim: lowers `kind` onto its
/// [`crate::engine::SamplerSpec`] and resolves it through
/// [`crate::engine::EngineBuilder`] — the crate's single dispatch point.
/// `seed` seeds the rung's MT19937 state (scalar or interlaced).  The
/// accelerator rungs build onto the software
/// [`crate::device::DeviceSweeper`]; geometry mismatches (SIMD lane
/// widths that do not divide the layer count, odd-depth B.2) error with
/// a structured [`crate::engine::UnsupportedGeometry`].
pub fn try_make_sweeper(
    kind: SweepKind,
    model: &QmcModel,
    s0: &[f32],
    seed: u32,
) -> crate::Result<Box<dyn Sweeper + Send>> {
    try_make_sweeper_with_exp(kind, model, s0, seed, kind.default_exp())
}

/// Fallible construction with an explicit exponential mode (tests use
/// this to align trajectories across rungs).  Shim over
/// [`crate::engine::EngineBuilder`].
pub fn try_make_sweeper_with_exp(
    kind: SweepKind,
    model: &QmcModel,
    s0: &[f32],
    seed: u32,
    exp: ExpMode,
) -> crate::Result<Box<dyn Sweeper + Send>> {
    Ok(crate::engine::EngineBuilder::new(kind.spec())
        .exp(exp)
        .build(model, s0, seed)?
        .into_sweeper())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ising::builder::torus_workload;
    use std::str::FromStr;

    #[test]
    fn accel_rungs_build_on_the_software_device() {
        let wl = torus_workload(4, 4, 8, 1, 0.3);
        for kind in [SweepKind::B1Accel, SweepKind::B2Accel] {
            let mut sw = try_make_sweeper(kind, &wl.model, &wl.s0, 1)
                .unwrap_or_else(|e| panic!("{kind:?} should build on the device sim: {e:#}"));
            assert_eq!(sw.kind(), kind);
            assert_eq!(sw.width(), 32);
            let stats = sw.run(2, 0.8);
            assert_eq!(stats.attempts, 2 * wl.model.n_spins() as u64);
            let dev = sw.device_stats().expect("device rungs expose device stats");
            assert!(dev.warps > 0);
            assert!(dev.transactions() > 0);
        }
        // Odd depth: B.1 runs, B.2 rejects with the structured geometry
        // error naming B.1 as the nearest runnable accel config.
        let wl = torus_workload(4, 4, 9, 1, 0.3);
        assert!(try_make_sweeper(SweepKind::B1Accel, &wl.model, &wl.s0, 1).is_ok());
        let err = try_make_sweeper(SweepKind::B2Accel, &wl.model, &wl.s0, 1);
        let msg = format!("{:#}", err.err().unwrap());
        assert!(msg.contains("b1"), "should name the accel alternative: {msg}");
    }

    #[test]
    fn w8_rungs_reject_incompatible_layer_counts() {
        // 12 % 8 != 0, and 8/8 = 1 < 2 sections.
        for layers in [12usize, 8] {
            let wl = torus_workload(4, 4, layers, 1, 0.3);
            let err = try_make_sweeper(SweepKind::A4FullW8, &wl.model, &wl.s0, 1);
            assert!(err.is_err(), "layers={layers} should be rejected for W8");
            // The rejection must name the working alternative: the C-rungs
            // accept any layers >= 2.
            let msg = format!("{:#}", err.err().unwrap());
            assert!(msg.contains("c1-replica-batch"), "message should point at the C-rungs: {msg}");
        }
        let wl = torus_workload(4, 4, 16, 1, 0.3);
        assert!(try_make_sweeper(SweepKind::A4FullW8, &wl.model, &wl.s0, 1).is_ok());
    }

    #[test]
    fn width_spellings_parse() {
        assert_eq!(SweepKind::from_str("a4-full-w8").unwrap(), SweepKind::A4FullW8);
        assert_eq!(SweepKind::from_str("a3-w8").unwrap(), SweepKind::A3VecRngW8);
        assert_eq!(SweepKind::from_str("a4-full-w4").unwrap(), SweepKind::A4Full);
        assert_eq!(SweepKind::from_str("A.4w8").unwrap(), SweepKind::A4FullW8);
        assert!(SweepKind::from_str("a4-full-w16").is_err());
    }

    #[test]
    fn group_widths_follow_lanes() {
        assert_eq!(SweepKind::A4Full.group_width(), 4);
        assert_eq!(SweepKind::A4FullW8.group_width(), 8);
        assert_eq!(SweepKind::A3VecRngW8.group_width(), 8);
        assert_eq!(SweepKind::preferred_cpu().group_width(), crate::simd::widest_supported_width());
    }

    #[test]
    fn layer_support_predicate_matches_interlacing_rules() {
        assert!(SweepKind::A4Full.supports_layers(8));
        assert!(!SweepKind::A4Full.supports_layers(6)); // 6 % 4 != 0
        assert!(!SweepKind::A4FullW8.supports_layers(8)); // one layer/section
        assert!(!SweepKind::A4FullW8.supports_layers(12)); // 12 % 8 != 0
        assert!(SweepKind::A4FullW8.supports_layers(16));
        assert!(SweepKind::A1Original.supports_layers(6)); // scalar: anything
        // The geometry-aware default never picks a rung the layers reject,
        // regardless of host features.
        assert_eq!(SweepKind::preferred_cpu_for_layers(12), SweepKind::A4Full);
        let k16 = SweepKind::preferred_cpu_for_layers(16);
        assert!(k16 == SweepKind::A4Full || k16 == SweepKind::A4FullW8);
        assert!(k16.supports_layers(16));
    }

    #[test]
    fn c1_spellings_and_widths() {
        assert_eq!(SweepKind::from_str("c1-replica-batch").unwrap(), SweepKind::C1ReplicaBatch);
        assert_eq!(SweepKind::from_str("c1").unwrap(), SweepKind::C1ReplicaBatch);
        assert_eq!(SweepKind::from_str("C.1").unwrap(), SweepKind::C1ReplicaBatch);
        assert_eq!(
            SweepKind::from_str("c1-replica-batch-w8").unwrap(),
            SweepKind::C1ReplicaBatchW8
        );
        assert_eq!(SweepKind::from_str("c1-w8").unwrap(), SweepKind::C1ReplicaBatchW8);
        assert_eq!(SweepKind::from_str("C.1w8").unwrap(), SweepKind::C1ReplicaBatchW8);
        assert!(SweepKind::from_str("c1-w16").is_err());
        assert_eq!(SweepKind::C1ReplicaBatch.group_width(), 4);
        assert_eq!(SweepKind::C1ReplicaBatchW8.group_width(), 8);
        assert!(SweepKind::C1ReplicaBatch.is_replica_batch());
        assert!(!SweepKind::A4FullW8.is_replica_batch());
        // C-rungs vectorize across replicas: any layer count >= 2 is fine,
        // including shallow models the A-rungs reject — but never fewer
        // (a 1-layer model has degenerate self-tau edges).
        assert!(SweepKind::C1ReplicaBatch.supports_layers(2));
        assert!(SweepKind::C1ReplicaBatchW8.supports_layers(2));
        assert!(!SweepKind::C1ReplicaBatch.supports_layers(1));
        assert!(!SweepKind::C1ReplicaBatchW8.supports_layers(1));
        assert_eq!(
            SweepKind::preferred_replica_batch().group_width(),
            crate::simd::widest_supported_width()
        );
    }

    #[test]
    fn c1_rungs_error_from_single_model_factory() {
        let wl = torus_workload(4, 4, 8, 1, 0.3);
        for kind in [SweepKind::C1ReplicaBatch, SweepKind::C1ReplicaBatchW8] {
            let err = try_make_sweeper(kind, &wl.model, &wl.s0, 1);
            assert!(err.is_err(), "{kind:?} should not build from one model");
            let msg = format!("{:#}", err.err().unwrap());
            assert!(msg.contains("make_batch_sweeper"), "unhelpful message: {msg}");
        }
    }

    #[test]
    fn sweeper_kind_reports_width_variant() {
        let wl = torus_workload(4, 4, 16, 1, 0.3);
        let mut w4 = try_make_sweeper(SweepKind::A4Full, &wl.model, &wl.s0, 1).unwrap();
        let mut w8 = try_make_sweeper(SweepKind::A4FullW8, &wl.model, &wl.s0, 1).unwrap();
        assert_eq!(w4.kind(), SweepKind::A4Full);
        assert_eq!(w8.kind(), SweepKind::A4FullW8);
        assert_eq!(w4.width(), 4);
        assert_eq!(w8.width(), 8);
        // Both must actually sweep.
        assert!(w4.run(2, 0.8).attempts > 0);
        assert!(w8.run(2, 0.8).attempts > 0);
    }

    #[test]
    fn kinds_have_canonical_spellings_that_reparse() {
        for kind in [
            SweepKind::A1Original,
            SweepKind::A2Basic,
            SweepKind::A3VecRng,
            SweepKind::A4Full,
            SweepKind::A3VecRngW8,
            SweepKind::A4FullW8,
            SweepKind::C1ReplicaBatch,
            SweepKind::C1ReplicaBatchW8,
            SweepKind::M1MultiSpin,
            SweepKind::B1Accel,
            SweepKind::B2Accel,
        ] {
            assert_eq!(SweepKind::from_str(kind.cli_spelling()).unwrap(), kind);
        }
    }

    #[test]
    fn m1_kind_surface_is_consistent() {
        assert_eq!(SweepKind::from_str("m1").unwrap(), SweepKind::M1MultiSpin);
        assert_eq!(SweepKind::from_str("M.1").unwrap(), SweepKind::M1MultiSpin);
        assert_eq!(SweepKind::M1MultiSpin.label(), "M.1");
        assert_eq!(SweepKind::M1MultiSpin.group_width(), 64);
        assert!(!SweepKind::M1MultiSpin.is_replica_batch());
        // Even layer counts only (checkerboard parity), any depth >= 2.
        assert!(SweepKind::M1MultiSpin.supports_layers(2));
        assert!(SweepKind::M1MultiSpin.supports_layers(256));
        assert!(!SweepKind::M1MultiSpin.supports_layers(9));
        assert!(!SweepKind::M1MultiSpin.supports_layers(1));
    }
}
