//! The Metropolis sweep optimization ladder — the paper's Table 1.
//!
//! Every rung implements [`Sweeper`] over the same [`QmcModel`], so the
//! benchmark harness can time them interchangeably and the tests can
//! check trajectory/statistical equivalence:
//!
//! | Rung | Module | Paper ingredients |
//! |------|--------|-------------------|
//! | A.1  | [`a1_original`] | Fig-2 branchy loop, Fig-4 nested tables, library `exp` |
//! | A.2  | [`a2_basic`]    | Fig-3/6 branch-free flat loop, tau-last edges, result caching, fast `exp` (§2) |
//! | A.3  | [`a3_vecrng`]   | + SSE-interlaced MT19937 and vector flip decisions (§3) |
//! | A.4  | [`a4_full`]     | + vectorized neighbour updates via 4-way layer interlacing (§3.1) |
//! | B.1  | [`accel`]       | accelerator, naive gathered layout |
//! | B.2  | [`accel`]       | accelerator, coalesced interlaced layout (§3.2) |
//!
//! The a/b compiler-optimization split of the paper (A.1a vs A.1b etc.) is
//! not a code difference — the harness measures the same rungs from a
//! binary built with `--profile opt0`.

pub mod a1_original;
pub mod ablation;
pub mod a2_basic;
pub mod a3_vecrng;
pub mod a4_full;
pub mod accel;
pub mod interlaced;

use crate::ising::QmcModel;

/// Which exponential the flip probability uses.  The paper's defaults:
/// A.1 the library `exp`; A.2–A.4 and B.x the fast approximation ("this
/// faster approximation was used in the performance tests for all
/// implementations with these basic optimizations").  Tests override the
/// mode to get bit-identical trajectories across rungs.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum ExpMode {
    Exact,
    Fast,
    Accurate,
}

impl ExpMode {
    /// Scalar flip probability for `x = -beta * dE`.
    #[inline(always)]
    pub fn eval(self, x: f32) -> f32 {
        match self {
            ExpMode::Exact => x.exp(),
            ExpMode::Fast => crate::expapprox::exp_fast(x.max(-80.0)),
            ExpMode::Accurate => crate::expapprox::exp_accurate(x),
        }
    }
}

/// The implementation rungs of the paper's Table 1.
#[derive(Copy, Clone, Debug, PartialEq, Eq, Hash)]
pub enum SweepKind {
    /// A.1 — original scalar implementation.
    A1Original,
    /// A.2 — basic optimizations (§2).
    A2Basic,
    /// A.3 — vectorized MT19937 + flip decisions (§3).
    A3VecRng,
    /// A.4 — fully vectorized, incl. neighbour updates (§3.1).
    A4Full,
    /// B.1 — accelerator, naive layout.
    B1Accel,
    /// B.2 — accelerator, coalesced layout (§3.2).
    B2Accel,
}

impl std::str::FromStr for SweepKind {
    type Err = crate::Error;

    /// Parse CLI spellings: `a1-original`/`a1`/`A.1`, …
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "a1-original" | "a1" | "a.1" => Ok(SweepKind::A1Original),
            "a2-basic" | "a2" | "a.2" => Ok(SweepKind::A2Basic),
            "a3-vec-rng" | "a3-vecrng" | "a3" | "a.3" => Ok(SweepKind::A3VecRng),
            "a4-full" | "a4" | "a.4" => Ok(SweepKind::A4Full),
            "b1-accel" | "b1" | "b.1" => Ok(SweepKind::B1Accel),
            "b2-accel" | "b2" | "b.2" => Ok(SweepKind::B2Accel),
            other => anyhow::bail!(
                "unknown rung {other:?} (expected a1-original, a2-basic, a3-vec-rng, a4-full, b1-accel, b2-accel)"
            ),
        }
    }
}

impl SweepKind {
    pub fn label(self) -> &'static str {
        match self {
            SweepKind::A1Original => "A.1",
            SweepKind::A2Basic => "A.2",
            SweepKind::A3VecRng => "A.3",
            SweepKind::A4Full => "A.4",
            SweepKind::B1Accel => "B.1",
            SweepKind::B2Accel => "B.2",
        }
    }

    /// Paper-default exponential mode of this rung.
    pub fn default_exp(self) -> ExpMode {
        match self {
            SweepKind::A1Original => ExpMode::Exact,
            _ => ExpMode::Fast,
        }
    }

    /// Width of the group that must be decided together — 1 for scalar
    /// rungs, 4 for the SSE rungs, the interlace width for the
    /// accelerator (Fig 14's "1 spin out of W flips" analysis).
    pub fn group_width(self) -> usize {
        match self {
            SweepKind::A1Original | SweepKind::A2Basic => 1,
            SweepKind::A3VecRng | SweepKind::A4Full => 4,
            SweepKind::B1Accel | SweepKind::B2Accel => 32,
        }
    }

    pub fn all_cpu() -> [SweepKind; 4] {
        [SweepKind::A1Original, SweepKind::A2Basic, SweepKind::A3VecRng, SweepKind::A4Full]
    }
}

/// Counters accumulated over [`Sweeper::run`] calls.
#[derive(Copy, Clone, Debug, Default)]
pub struct SweepStats {
    /// Flip attempts (= spins visited).
    pub attempts: u64,
    /// Accepted flips.
    pub flips: u64,
    /// Decision groups processed (quadruplets for the SSE rungs).
    pub groups: u64,
    /// Groups in which at least one spin flipped — the paper's Fig-14
    /// "must wait for a flip" event.
    pub groups_with_flip: u64,
}

impl SweepStats {
    pub fn merge(&mut self, o: &SweepStats) {
        self.attempts += o.attempts;
        self.flips += o.flips;
        self.groups += o.groups;
        self.groups_with_flip += o.groups_with_flip;
    }

    /// Observed per-spin flip probability.
    pub fn flip_prob(&self) -> f64 {
        if self.attempts == 0 {
            0.0
        } else {
            self.flips as f64 / self.attempts as f64
        }
    }

    /// Observed probability that a decision group contains a flip.
    pub fn wait_prob(&self) -> f64 {
        if self.groups == 0 {
            0.0
        } else {
            self.groups_with_flip as f64 / self.groups as f64
        }
    }
}

/// A Metropolis sweep engine over one QMC Ising model.
pub trait Sweeper {
    fn kind(&self) -> SweepKind;

    /// Smallest number of sweeps a single `run` call can execute (1 for
    /// CPU rungs; `sweeps_per_call` for accelerator artifacts).
    fn granularity(&self) -> usize {
        1
    }

    /// Execute `n_sweeps` Metropolis sweeps at inverse temperature `beta`;
    /// `n_sweeps` must be a multiple of [`Self::granularity`].
    fn run(&mut self, n_sweeps: usize, beta: f32) -> SweepStats;

    /// Current total energy.
    fn energy(&mut self) -> f64;

    /// Current state in original (layer-major) order.
    fn state(&mut self) -> Vec<f32>;

    /// Replace the state (original order) — used by parallel tempering
    /// swaps and by the equivalence tests.
    fn set_state(&mut self, s: &[f32]);

    /// Maximum absolute inconsistency between the incrementally-maintained
    /// effective fields and a from-scratch recomputation (0 when exact).
    fn validate(&mut self) -> f64;
}

/// Construct a sweeper with the rung's paper-default exponential mode.
///
/// `seed` seeds the rung's MT19937 state (scalar or interlaced).  For the
/// accelerator rungs use [`accel::AccelSweeper::new`] directly (they need
/// a [`crate::runtime::Runtime`] and artifacts on disk).
pub fn make_sweeper(kind: SweepKind, model: &QmcModel, s0: &[f32], seed: u32) -> Box<dyn Sweeper + Send> {
    make_sweeper_with_exp(kind, model, s0, seed, kind.default_exp())
}

/// [`make_sweeper`] with an explicit exponential mode (tests use this to
/// align trajectories across rungs).
pub fn make_sweeper_with_exp(
    kind: SweepKind,
    model: &QmcModel,
    s0: &[f32],
    seed: u32,
    exp: ExpMode,
) -> Box<dyn Sweeper + Send> {
    match kind {
        SweepKind::A1Original => Box::new(a1_original::A1Original::new(model, s0, seed, exp)),
        SweepKind::A2Basic => Box::new(a2_basic::A2Basic::new(model, s0, seed, exp)),
        SweepKind::A3VecRng => Box::new(a3_vecrng::A3VecRng::new(model, s0, seed, exp)),
        SweepKind::A4Full => Box::new(a4_full::A4Full::new(model, s0, seed, exp)),
        SweepKind::B1Accel | SweepKind::B2Accel => {
            panic!("accelerator rungs need a Runtime; use accel::AccelSweeper::new")
        }
    }
}
