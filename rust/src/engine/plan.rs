//! The negotiated [`Plan`]: what a [`crate::engine::SamplerSpec`]
//! resolved to on this host for this geometry, including the
//! machine-readable fallback chain — the construction-time analogue of
//! the paper's "fraction of vector width utilized" reporting.

use crate::sweep::{ExpMode, SweepKind};
use crate::util::json::{self, Value};

use super::{BackendPref, Rung, SamplerSpec};

/// A concrete instruction-set backend (post-negotiation — unlike
/// [`BackendPref`] there is no `Auto` here).
#[derive(Copy, Clone, Debug, PartialEq, Eq, Hash)]
pub enum Backend {
    /// Plain scalar code (the A.1/A.2 rungs).
    Scalar,
    /// 4-lane SSE2 intrinsics (x86_64 baseline).
    Sse2,
    /// 8-lane AVX2 intrinsics (runtime-detected).
    Avx2,
    /// 16-lane AVX-512F intrinsics (runtime-detected; needs a Rust ≥ 1.89
    /// toolchain — the build script probes for the stabilized intrinsics).
    Avx512,
    /// Const-generic portable lanes (any width, any architecture).
    Portable,
    /// The software device (the B-rungs): 32-lane warps over the host
    /// vector units with counted coalesced/strided memory transactions
    /// (see [`crate::device`]); real XLA artifacts can instead run
    /// through PJRT via `sweep::accel::AccelSweeper`.
    Accel,
}

impl Backend {
    pub fn as_str(self) -> &'static str {
        match self {
            Backend::Scalar => "scalar",
            Backend::Sse2 => "sse2",
            Backend::Avx2 => "avx2",
            Backend::Avx512 => "avx512",
            Backend::Portable => "portable",
            Backend::Accel => "accel",
        }
    }

    /// Whether `pref` is satisfied by this concrete backend.
    pub fn satisfies(self, pref: BackendPref) -> bool {
        match pref {
            BackendPref::Auto => true,
            BackendPref::Sse2 => self == Backend::Sse2,
            BackendPref::Avx2 => self == Backend::Avx2,
            BackendPref::Avx512 => self == Backend::Avx512,
            BackendPref::Portable => self == Backend::Portable,
            BackendPref::Accel => self == Backend::Accel,
        }
    }
}

impl std::fmt::Display for Backend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl std::str::FromStr for Backend {
    type Err = crate::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "scalar" => Ok(Backend::Scalar),
            "sse2" => Ok(Backend::Sse2),
            "avx2" => Ok(Backend::Avx2),
            "avx512" => Ok(Backend::Avx512),
            "portable" => Ok(Backend::Portable),
            "accel" => Ok(Backend::Accel),
            other => anyhow::bail!(
                "unknown backend {other:?} (expected scalar, sse2, avx2, avx512, portable or \
                 accel)"
            ),
        }
    }
}

/// How lanes map onto work — the memory-layout half of the negotiation.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum GroupLayout {
    /// One spin at a time; no lane structure.
    Scalar,
    /// The A.3/A.4 within-model layout: the layer stack is interlaced
    /// into `sections` sections (one per lane) of `layers_per_section`
    /// layers each (`None` when no geometry was supplied).
    LayerInterlace { sections: usize, layers_per_section: Option<usize> },
    /// The C.1 across-ensemble layout: one tempering replica per lane.
    ReplicaLanes { lanes: usize },
    /// The accelerator's §3.2 coalesced spin interlacing.
    AccelInterlace { width: usize },
    /// The M.1 multi-spin layout: every vertex's layer stack packed
    /// `bits` spins per machine word (bit b of word j = layer 64j+b).
    BitPlanes { bits: usize },
}

impl GroupLayout {
    pub fn to_value(&self) -> Value {
        match *self {
            GroupLayout::Scalar => json::obj(vec![("kind", json::str_v("scalar"))]),
            GroupLayout::LayerInterlace { sections, layers_per_section } => {
                let mut pairs = vec![
                    ("kind", json::str_v("layer-interlace")),
                    ("sections", json::num(sections as f64)),
                ];
                if let Some(l) = layers_per_section {
                    pairs.push(("layers_per_section", json::num(l as f64)));
                }
                json::obj(pairs)
            }
            GroupLayout::ReplicaLanes { lanes } => json::obj(vec![
                ("kind", json::str_v("replica-lanes")),
                ("lanes", json::num(lanes as f64)),
            ]),
            GroupLayout::AccelInterlace { width } => json::obj(vec![
                ("kind", json::str_v("accel-interlace")),
                ("width", json::num(width as f64)),
            ]),
            GroupLayout::BitPlanes { bits } => json::obj(vec![
                ("kind", json::str_v("bit-planes")),
                ("bits", json::num(bits as f64)),
            ]),
        }
    }
}

/// One candidate the negotiation considered and turned down, with a
/// machine-readable `code` and a human-readable `reason`.
#[derive(Clone, Debug)]
pub struct Rejection {
    pub rung: Rung,
    pub width: usize,
    /// Stable reason codes: `layer-interlace`, `no-avx2`, `no-avx512`,
    /// `no-intrinsics`, `width-unavailable`, `backend-mismatch`,
    /// `forced-portable`.
    pub code: &'static str,
    pub reason: String,
}

impl Rejection {
    pub fn to_value(&self) -> Value {
        json::obj(vec![
            ("rung", json::str_v(self.rung.as_str())),
            ("width", json::num(self.width as f64)),
            ("code", json::str_v(self.code)),
            ("reason", json::str_v(&self.reason)),
        ])
    }
}

/// The `(rung, backend, width)` triple a plan resolved to — `Copy`, so
/// executors can carry it around and instantiate sweepers from it (see
/// [`crate::engine::builder::instantiate`]).
#[derive(Copy, Clone, Debug, PartialEq, Eq, Hash)]
pub struct Resolved {
    pub rung: Rung,
    pub backend: Backend,
    pub width: usize,
}

impl Resolved {
    /// Paper-style label with the width spelled out away from the paper
    /// defaults: `A.4` (4 lanes), `A.4w8`, `A.4w16`, `C.1w8`, `B.2`.
    pub fn label(&self) -> String {
        let base = self.rung.label();
        match (self.rung, self.width) {
            (Rung::A1 | Rung::A2 | Rung::B1 | Rung::B2 | Rung::M1, _) => base.to_string(),
            (_, 4) => base.to_string(),
            (_, w) => format!("{base}w{w}"),
        }
    }

    /// The legacy enum variant this resolution corresponds to, when one
    /// exists (widths beyond 8 have no `SweepKind` spelling).
    pub fn legacy_kind(&self) -> Option<SweepKind> {
        match (self.rung, self.width) {
            (Rung::A1, 1) => Some(SweepKind::A1Original),
            (Rung::A2, 1) => Some(SweepKind::A2Basic),
            (Rung::A3, 4) => Some(SweepKind::A3VecRng),
            (Rung::A3, 8) => Some(SweepKind::A3VecRngW8),
            (Rung::A4, 4) => Some(SweepKind::A4Full),
            (Rung::A4, 8) => Some(SweepKind::A4FullW8),
            (Rung::C1, 4) => Some(SweepKind::C1ReplicaBatch),
            (Rung::C1, 8) => Some(SweepKind::C1ReplicaBatchW8),
            (Rung::M1, 64) => Some(SweepKind::M1MultiSpin),
            (Rung::B1, _) => Some(SweepKind::B1Accel),
            (Rung::B2, _) => Some(SweepKind::B2Accel),
            _ => None,
        }
    }
}

impl Resolved {
    /// JSON form (`{"rung":"c1","width":8,"backend":"avx2"}`) — the
    /// per-group plan record of Checkpoint schema v2 and the `plans`
    /// echo of a `RunReport`.
    pub fn to_value(&self) -> Value {
        json::obj(vec![
            ("rung", json::str_v(self.rung.as_str())),
            ("width", json::num(self.width as f64)),
            ("backend", json::str_v(self.backend.as_str())),
        ])
    }

    /// Parse the JSON form back.
    pub fn from_value(v: &Value) -> crate::Result<Resolved> {
        Ok(Resolved {
            rung: v.get("rung")?.as_str()?.parse()?,
            width: v.get("width")?.as_usize()?,
            backend: v.get("backend")?.as_str()?.parse()?,
        })
    }
}

/// One group of a (possibly heterogeneous) batched run: which resolved
/// `(rung, backend, width)` triple sweeps it and how many *active*
/// replicas it carries (lanes beyond `replicas` are padding).  A ladder
/// scheduled as `[{C.1w8, 8}, {C.1, 2}]` runs an AVX2 octet group next
/// to a 2-active-lane SSE2 quadruplet tail — the heterogeneous layout
/// Checkpoint schema v2 serializes and `RunReport` echoes.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct GroupPlan {
    pub resolved: Resolved,
    /// Active (non-padded) replicas in this group (`1..=resolved.width`).
    pub replicas: usize,
}

impl GroupPlan {
    pub fn new(resolved: Resolved, replicas: usize) -> Self {
        Self { resolved, replicas }
    }

    /// JSON form: the resolved triple plus the active replica count.
    pub fn to_value(&self) -> Value {
        let mut v = self.resolved.to_value();
        if let Value::Obj(m) = &mut v {
            m.insert("replicas".to_string(), json::num(self.replicas as f64));
        }
        v
    }

    pub fn from_value(v: &Value) -> crate::Result<GroupPlan> {
        Ok(GroupPlan {
            resolved: Resolved::from_value(v)?,
            replicas: v.get("replicas")?.as_usize()?,
        })
    }

    /// Parse an optional `plans` JSON array — the one parser shared by
    /// checkpoints and run reports; an absent field means no plans.
    pub fn vec_from_opt(v: Option<&Value>) -> crate::Result<Vec<GroupPlan>> {
        match v {
            Some(arr) => arr.as_arr()?.iter().map(GroupPlan::from_value).collect(),
            None => Ok(Vec::new()),
        }
    }

    /// Whether a serialized RNG payload captured under `other` can be
    /// restored into a group planned as `self`: the rung, lane width and
    /// active-replica layout must match.  The *backend* may differ — the
    /// interlaced generator serializes identically for every backend of
    /// one width, which is what makes resume portable across hosts
    /// (checkpoint on AVX2, resume on portable lanes).
    pub fn layout_matches(&self, other: &GroupPlan) -> bool {
        self.resolved.rung == other.resolved.rung
            && self.resolved.width == other.resolved.width
            && self.replicas == other.replicas
    }
}

/// Joined label of a group sequence: the single label when every group
/// resolves alike (`C.1w8`), otherwise the distinct labels in group
/// order (`C.1w8+C.1`).
pub fn groups_label(groups: &[GroupPlan]) -> String {
    let mut labels: Vec<String> = Vec::new();
    for g in groups {
        let l = g.resolved.label();
        if !labels.contains(&l) {
            labels.push(l);
        }
    }
    if labels.is_empty() {
        "?".to_string()
    } else {
        labels.join("+")
    }
}

/// The outcome of capability negotiation: everything a caller (or a
/// service client) needs to know about what will actually run.
#[derive(Clone, Debug)]
pub struct Plan {
    /// The spec as requested.
    pub spec: SamplerSpec,
    /// The rung (same as `spec.rung` — there is no rung auto-selection).
    pub rung: Rung,
    /// The backend the negotiation chose.
    pub backend: Backend,
    /// The effective lane count.
    pub width: usize,
    /// How lanes map onto work.
    pub layout: GroupLayout,
    /// The model geometry the plan was resolved against, when supplied.
    pub layers: Option<usize>,
    /// Exponential mode the engine will use.
    pub exp: ExpMode,
    /// Every candidate considered and rejected, in evaluation order —
    /// the fallback chain with machine-readable reasons.
    pub rejected: Vec<Rejection>,
    /// Free-form negotiation notes (e.g. the portable-force override).
    pub notes: Vec<String>,
}

impl Plan {
    /// The `Copy` triple for instantiation.
    pub fn resolved(&self) -> Resolved {
        Resolved { rung: self.rung, backend: self.backend, width: self.width }
    }

    /// Paper-style label (see [`Resolved::label`]).
    pub fn label(&self) -> String {
        self.resolved().label()
    }

    /// The legacy [`SweepKind`] this plan corresponds to, when one exists.
    pub fn legacy_kind(&self) -> Option<SweepKind> {
        self.resolved().legacy_kind()
    }

    /// Serialize the plan (the `repro plan` output and the service's
    /// per-result echo).
    pub fn to_json(&self) -> String {
        let mut pairs = vec![
            ("protocol_version", json::num(super::PROTOCOL_VERSION as f64)),
            ("spec", self.spec.to_value()),
            ("rung", json::str_v(self.rung.as_str())),
            ("label", json::str_v(&self.label())),
            ("backend", json::str_v(self.backend.as_str())),
            ("width", json::num(self.width as f64)),
            ("exp", json::str_v(exp_as_str(self.exp))),
            ("layout", self.layout.to_value()),
        ];
        if let Some(layers) = self.layers {
            pairs.push(("layers", json::num(layers as f64)));
        }
        if let Some(kind) = self.legacy_kind() {
            pairs.push(("legacy_kind", json::str_v(kind.cli_spelling())));
        }
        pairs.push(("rejected", Value::Arr(self.rejected.iter().map(|r| r.to_value()).collect())));
        if !self.notes.is_empty() {
            pairs.push(("notes", Value::Arr(self.notes.iter().map(|n| json::str_v(n)).collect())));
        }
        json::obj(pairs).to_string()
    }
}

pub(crate) fn exp_as_str(exp: ExpMode) -> &'static str {
    match exp {
        ExpMode::Exact => "exact",
        ExpMode::Fast => "fast",
        ExpMode::Accurate => "accurate",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_follow_width() {
        let r = |rung, width| Resolved { rung, backend: Backend::Portable, width };
        assert_eq!(r(Rung::A4, 4).label(), "A.4");
        assert_eq!(r(Rung::A4, 8).label(), "A.4w8");
        assert_eq!(r(Rung::A4, 16).label(), "A.4w16");
        assert_eq!(r(Rung::C1, 4).label(), "C.1");
        assert_eq!(r(Rung::C1, 8).label(), "C.1w8");
        assert_eq!(r(Rung::A2, 1).label(), "A.2");
        assert_eq!(r(Rung::M1, 64).label(), "M.1");
        assert_eq!(r(Rung::B2, 32).label(), "B.2");
    }

    #[test]
    fn legacy_kind_round_trips_for_representable_widths() {
        let r = |rung, width| Resolved { rung, backend: Backend::Portable, width };
        assert_eq!(r(Rung::A3, 8).legacy_kind(), Some(SweepKind::A3VecRngW8));
        assert_eq!(r(Rung::C1, 4).legacy_kind(), Some(SweepKind::C1ReplicaBatch));
        assert_eq!(r(Rung::A4, 16).legacy_kind(), None);
    }

    #[test]
    fn group_plans_roundtrip_and_label_joins() {
        use std::str::FromStr;
        let r = |rung, backend, width| Resolved { rung, backend, width };
        let g8 = GroupPlan::new(r(Rung::C1, Backend::Avx2, 8), 8);
        let g4 = GroupPlan::new(r(Rung::C1, Backend::Sse2, 4), 2);
        // JSON round-trip.
        for g in [g8, g4] {
            let v = Value::parse(&g.to_value().to_string()).unwrap();
            assert_eq!(GroupPlan::from_value(&v).unwrap(), g);
        }
        // Label joining: homogeneous collapses, heterogeneous lists.
        assert_eq!(groups_label(&[g8, GroupPlan::new(r(Rung::C1, Backend::Avx2, 8), 3)]), "C.1w8");
        assert_eq!(groups_label(&[g8, g4]), "C.1w8+C.1");
        assert_eq!(groups_label(&[]), "?");
        // Layout matching ignores the backend (resume portability) but
        // not the width or the active replica count.
        let g8_portable = GroupPlan::new(r(Rung::C1, Backend::Portable, 8), 8);
        assert!(g8.layout_matches(&g8_portable));
        assert!(!g8.layout_matches(&g4));
        assert!(!g8.layout_matches(&GroupPlan::new(r(Rung::C1, Backend::Avx2, 8), 7)));
        // Backend parses back from its JSON spelling.
        assert_eq!(Backend::from_str("portable").unwrap(), Backend::Portable);
        assert_eq!(Backend::from_str("scalar").unwrap(), Backend::Scalar);
        assert!(Backend::from_str("neon").is_err());
    }

    #[test]
    fn plan_json_names_backend_width_and_rejections() {
        let plan = Plan {
            spec: SamplerSpec::rung(Rung::C1),
            rung: Rung::C1,
            backend: Backend::Avx2,
            width: 8,
            layout: GroupLayout::ReplicaLanes { lanes: 8 },
            layers: Some(2),
            exp: ExpMode::Fast,
            rejected: vec![Rejection {
                rung: Rung::A4,
                width: 8,
                code: "layer-interlace",
                reason: "layers=2 is not divisible into 8 sections".into(),
            }],
            notes: vec![],
        };
        let v = Value::parse(&plan.to_json()).unwrap();
        assert_eq!(v.get("backend").unwrap().as_str().unwrap(), "avx2");
        assert_eq!(v.get("width").unwrap().as_usize().unwrap(), 8);
        assert_eq!(v.get("protocol_version").unwrap().as_usize().unwrap(), 1);
        assert_eq!(v.get("legacy_kind").unwrap().as_str().unwrap(), "c1-replica-batch-w8");
        let rejected = v.get("rejected").unwrap().as_arr().unwrap();
        assert_eq!(rejected.len(), 1);
        assert_eq!(rejected[0].get("rung").unwrap().as_str().unwrap(), "a4");
        assert_eq!(rejected[0].get("code").unwrap().as_str().unwrap(), "layer-interlace");
    }
}
