//! Structured negotiation errors.
//!
//! Geometry failures are *data*, not prose: callers (the CLI, the
//! service's admission path, tests) downcast to
//! [`UnsupportedGeometry`] and read the rejected `(rung, width)` plus
//! the machine-chosen `alternatives` instead of parsing a message.

use super::{Rung, SamplerSpec};

/// A spec's rung×width cannot run on the given model geometry — e.g. an
/// A-rung whose lane count does not divide the layer count.  Carries
/// ready-to-use alternative specs, best first.
#[derive(Clone, Debug)]
pub struct UnsupportedGeometry {
    /// The rung that was rejected.
    pub rung: Rung,
    /// The lane width that failed (the requested width, or the widest
    /// candidate when the width was `Auto`).
    pub width: usize,
    /// The model's layer count the rung was checked against.
    pub layers: usize,
    /// Specs that *do* support this geometry, best first (used by
    /// `repro run` to print a suggestion).
    pub alternatives: Vec<SamplerSpec>,
}

impl std::fmt::Display for UnsupportedGeometry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.rung.is_replica_batch() {
            write!(
                f,
                "rung {} needs at least 2 layers (got {}): a 1-layer model has degenerate \
                 self-tau edges",
                self.rung.label(),
                self.layers
            )?;
        } else {
            write!(
                f,
                "rung {} at width {} needs n_layers divisible by {} with at least 2 layers per \
                 section (got {})",
                self.rung.label(),
                self.width,
                self.width,
                self.layers
            )?;
        }
        if !self.alternatives.is_empty() {
            write!(f, "; alternatives:")?;
            for (i, alt) in self.alternatives.iter().enumerate() {
                let sep = if i == 0 { " " } else { "; " };
                write!(f, "{sep}{}", describe(alt))?;
            }
        }
        Ok(())
    }
}

impl std::error::Error for UnsupportedGeometry {}

/// A checkpoint cannot be resumed bit-exactly because its rung does not
/// serialize its generator (the accelerator rungs keep the RNG on
/// device, so the checkpoint carries states only).  Structured like the
/// geometry rejections: callers downcast and read the recovery
/// procedure as data instead of a doc comment.
#[derive(Clone, Debug)]
pub struct NonResumableRng {
    /// Label of the rung the checkpoint was captured on (e.g. `B.2`).
    pub label: String,
    /// Checkpoint epoch — the seed offset the fresh-seed resume must
    /// apply so the continued segment draws a disjoint uniform stream.
    pub epoch: u64,
    /// Sweeps completed at capture time.
    pub sweeps_done: usize,
}

impl std::fmt::Display for NonResumableRng {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "checkpoint was captured on rung {} which cannot serialize its generator \
             (accelerator RNG state lives on device), so a bit-exact resume is impossible; \
             rebuild the ensemble with FRESH sweeper seeds for the resumed segment — offset \
             the base seed by the checkpoint epoch ({}) — and restore the spin states only \
             (Checkpoint::restore_states_only).  Reusing the original seeds would replay the \
             {} sweeps of uniforms the recorded segment already consumed and correlate the \
             continuation with it",
            self.label, self.epoch, self.sweeps_done
        )
    }
}

impl std::error::Error for NonResumableRng {}

/// Human-readable one-liner for an alternative spec, leading with the
/// legacy spelling where one exists so old error-message greps keep
/// working.
fn describe(spec: &SamplerSpec) -> String {
    match spec.rung {
        Rung::C1 => format!(
            "c1-replica-batch ({}) — vectorizes across the tempering ensemble instead, \
             accepts any layers >= 2",
            spec.cli()
        ),
        Rung::A2 => format!("a2-basic ({}) — scalar, any geometry", spec.cli()),
        _ => spec.cli(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::Width;

    #[test]
    fn display_names_geometry_and_alternatives() {
        let e = UnsupportedGeometry {
            rung: Rung::A4,
            width: 8,
            layers: 12,
            alternatives: vec![
                SamplerSpec::rung(Rung::A4).w(4),
                SamplerSpec::rung(Rung::C1),
                SamplerSpec::rung(Rung::A2),
            ],
        };
        let msg = e.to_string();
        assert!(msg.contains("needs n_layers divisible by 8"), "{msg}");
        assert!(msg.contains("got 12"), "{msg}");
        assert!(msg.contains("c1-replica-batch"), "{msg}");
        assert!(msg.contains("--rung a4 --width 4"), "{msg}");
        assert_eq!(e.alternatives[1].width, Width::Auto);
    }

    #[test]
    fn downcasts_through_anyhow() {
        let e = UnsupportedGeometry {
            rung: Rung::A3,
            width: 8,
            layers: 8,
            alternatives: vec![SamplerSpec::rung(Rung::C1)],
        };
        let any: crate::Error = e.into();
        let back = any.downcast_ref::<UnsupportedGeometry>().expect("downcast");
        assert_eq!(back.rung, Rung::A3);
        assert_eq!(back.width, 8);
        assert_eq!(back.layers, 8);
    }
}
