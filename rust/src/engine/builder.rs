//! [`EngineBuilder`]: resolve a [`SamplerSpec`] against host capabilities
//! and model geometry into a [`Plan`], then instantiate the sweeper.
//!
//! This is the crate's **single dispatch point**: the legacy
//! `sweep::try_make_sweeper*` constructors, `make_batch_sweeper`, the
//! coordinator, the CLI and the sampling service all build through here,
//! so the `VECTORISING_FORCE_PORTABLE` override, AVX2 detection and the
//! layer-interlacing geometry rule each live in exactly one place.

use crate::device::DeviceSweeper;
use crate::ising::QmcModel;
use crate::sweep::c1_replica_batch::{BatchSweeper, C1ReplicaBatch};
use crate::sweep::{
    a1_original, a2_basic, a3_vecrng, a4_full, m1_multispin, ExpMode, SweepKind, Sweeper,
};
use crate::Result;

use super::error::UnsupportedGeometry;
use super::plan::{Backend, GroupLayout, Plan, Rejection, Resolved};
use super::{BackendPref, Rung, SamplerSpec, Width};

/// A negotiated single-model engine: the [`Plan`] plus the sweeper it
/// instantiated.  Derefs to the sweeper, so `engine.run(n, beta)` works
/// directly.
pub struct Engine {
    pub plan: Plan,
    sweeper: Box<dyn Sweeper + Send>,
}

impl Engine {
    pub fn into_sweeper(self) -> Box<dyn Sweeper + Send> {
        self.sweeper
    }

    pub fn into_parts(self) -> (Plan, Box<dyn Sweeper + Send>) {
        (self.plan, self.sweeper)
    }
}

impl std::ops::Deref for Engine {
    type Target = Box<dyn Sweeper + Send>;

    fn deref(&self) -> &Self::Target {
        &self.sweeper
    }
}

impl std::ops::DerefMut for Engine {
    fn deref_mut(&mut self) -> &mut Self::Target {
        &mut self.sweeper
    }
}

/// A negotiated lane-batch engine (the C-rung): the [`Plan`] plus the
/// batch sweeper.  Derefs to the batch sweeper.
pub struct BatchEngine {
    pub plan: Plan,
    sweeper: Box<dyn BatchSweeper + Send>,
}

impl BatchEngine {
    pub fn into_sweeper(self) -> Box<dyn BatchSweeper + Send> {
        self.sweeper
    }

    pub fn into_parts(self) -> (Plan, Box<dyn BatchSweeper + Send>) {
        (self.plan, self.sweeper)
    }
}

impl std::ops::Deref for BatchEngine {
    type Target = Box<dyn BatchSweeper + Send>;

    fn deref(&self) -> &Self::Target {
        &self.sweeper
    }
}

impl std::ops::DerefMut for BatchEngine {
    fn deref_mut(&mut self) -> &mut Self::Target {
        &mut self.sweeper
    }
}

/// Resolves specs into plans and plans into sweepers.
#[derive(Copy, Clone, Debug)]
pub struct EngineBuilder {
    spec: SamplerSpec,
    layers: Option<usize>,
    exp: Option<ExpMode>,
}

impl EngineBuilder {
    pub fn new(spec: impl Into<SamplerSpec>) -> Self {
        Self { spec: spec.into(), layers: None, exp: None }
    }

    /// Supply the model geometry (layer count) so the plan can apply the
    /// interlacing rules.  [`Self::build`] takes it from the model
    /// automatically; call this when you only want a [`Plan`].
    pub fn layers(mut self, n_layers: usize) -> Self {
        self.layers = Some(n_layers);
        self
    }

    /// Override the exponential mode (default: the rung's paper default —
    /// exact for A.1, fast elsewhere).
    pub fn exp(mut self, exp: ExpMode) -> Self {
        self.exp = Some(exp);
        self
    }

    /// Negotiate the spec against host capabilities (and the layer count
    /// when one was supplied) without building anything.
    pub fn plan(&self) -> Result<Plan> {
        resolve(self.spec, self.layers, self.exp)
    }

    /// Negotiate against `model`'s geometry and instantiate a
    /// single-model sweeper (the A-rungs; C/B rungs explain where to go
    /// instead).
    pub fn build(&self, model: &QmcModel, s0: &[f32], seed: u32) -> Result<Engine> {
        let plan = resolve(self.spec, Some(model.n_layers), self.exp)?;
        let sweeper = instantiate(plan.resolved(), model, s0, seed, plan.exp)?;
        Ok(Engine { plan, sweeper })
    }

    /// Negotiate and instantiate a C-rung lane-batch over `models[k]`
    /// starting from `states[k]`, lane `k` seeded with `seeds[k]`.
    pub fn build_batch(
        &self,
        models: &[QmcModel],
        states: &[Vec<f32>],
        seeds: &[u32],
    ) -> Result<BatchEngine> {
        anyhow::ensure!(!models.is_empty(), "cannot build a lane-batch over zero models");
        let mut this = *self;
        this.layers = Some(models[0].n_layers);
        let plan = this.plan()?;
        let sweeper = instantiate_batch(plan.resolved(), models, states, seeds, plan.exp)?;
        Ok(BatchEngine { plan, sweeper })
    }
}

/// The A-rung interlacing rule: `w` sections of at least 2 layers each.
/// The single source of the geometry predicate —
/// `SweepKind::supports_layers` delegates here.
pub(crate) fn interlace_ok(layers: usize, w: usize) -> bool {
    layers % w == 0 && layers / w >= 2
}

/// Widths with a monomorphized vector backend (4 and 8 have SSE2/AVX2
/// intrinsic implementations; 16 runs on AVX-512F where the host and
/// toolchain support it, portable lanes otherwise — either way it is
/// compiled in, which is what makes `--width 16` work without any new
/// enum variant).
pub(crate) const MONO_WIDTHS: [usize; 3] = [4, 8, 16];

/// Candidate lane widths for a vector rung, preference order.
fn candidate_widths(width: Width, pref: BackendPref) -> Vec<usize> {
    match width {
        Width::W(n) => vec![n],
        Width::Auto => match pref {
            BackendPref::Avx512 => vec![16],
            BackendPref::Avx2 => vec![8],
            // Parity with the legacy dispatch: auto width under an
            // explicit SSE2/portable preference is the paper's 4 lanes.
            BackendPref::Sse2 | BackendPref::Portable => vec![4],
            _ => {
                let mut widths = Vec::new();
                if crate::simd::avx512_available() {
                    widths.push(16);
                }
                if crate::simd::widest_supported_width() == 8 {
                    widths.push(8);
                }
                widths.push(4);
                widths
            }
        },
    }
}

/// Resolve the backend for one `(rung, width)` candidate.  `Ok` may carry
/// a fallback [`Rejection`] documenting a downgraded first choice (e.g.
/// AVX2 missing at width 8).
fn resolve_backend(
    rung: Rung,
    pref: BackendPref,
    w: usize,
) -> std::result::Result<(Backend, Option<Rejection>), Rejection> {
    let rej = |code: &'static str, reason: String| Rejection { rung, width: w, code, reason };
    let on_x86 = cfg!(target_arch = "x86_64");
    match pref {
        BackendPref::Auto => {
            if on_x86 && w == 4 {
                return Ok((Backend::Sse2, None));
            }
            if on_x86 && w == 8 {
                if crate::simd::avx2_available() {
                    return Ok((Backend::Avx2, None));
                }
                return Ok((
                    Backend::Portable,
                    Some(rej(
                        "no-avx2",
                        "host does not report AVX2; falling back to portable 8-lane code".into(),
                    )),
                ));
            }
            if on_x86 && w == 16 {
                if crate::simd::avx512_available() {
                    return Ok((Backend::Avx512, None));
                }
                return Ok((
                    Backend::Portable,
                    Some(rej(
                        "no-avx512",
                        "host/toolchain does not support AVX-512F; falling back to portable \
                         16-lane code"
                            .into(),
                    )),
                ));
            }
            Ok((
                Backend::Portable,
                Some(rej(
                    "no-intrinsics",
                    format!("no hand-written intrinsic backend at width {w}; portable lanes"),
                )),
            ))
        }
        BackendPref::Sse2 => {
            if !on_x86 {
                Err(rej("backend-mismatch", "sse2 requires an x86_64 host".into()))
            } else if w == 4 {
                Ok((Backend::Sse2, None))
            } else {
                Err(rej(
                    "backend-mismatch",
                    format!("the sse2 backend is 4-lane (requested width {w})"),
                ))
            }
        }
        BackendPref::Avx2 => {
            if w != 8 {
                return Err(rej(
                    "backend-mismatch",
                    format!("the avx2 backend is 8-lane (requested width {w})"),
                ));
            }
            if crate::simd::avx2_available() {
                Ok((Backend::Avx2, None))
            } else {
                Err(rej("no-avx2", "host does not report AVX2".into()))
            }
        }
        BackendPref::Avx512 => {
            if w != 16 {
                return Err(rej(
                    "backend-mismatch",
                    format!("the avx512 backend is 16-lane (requested width {w})"),
                ));
            }
            if crate::simd::avx512_available() {
                Ok((Backend::Avx512, None))
            } else {
                Err(rej(
                    "no-avx512",
                    "host does not report AVX-512F (or the toolchain predates the stabilized \
                     _mm512_ intrinsics, Rust 1.89)"
                        .into(),
                ))
            }
        }
        BackendPref::Portable => Ok((Backend::Portable, None)),
        BackendPref::Accel => Err(rej(
            "backend-mismatch",
            "the accel backend serves only the accelerator rungs (b1/b2)".into(),
        )),
    }
}

/// Alternatives for a geometry rejection, best first.
fn geometry_alternatives(layers: usize) -> Vec<SamplerSpec> {
    let mut alts = Vec::new();
    for w in [16usize, 8, 4] {
        let host_ok = match w {
            16 => crate::simd::avx512_available(),
            8 => crate::simd::widest_supported_width() >= 8,
            _ => true,
        };
        if host_ok && interlace_ok(layers, w) {
            alts.push(SamplerSpec::rung(Rung::A4).w(w));
        }
    }
    if layers >= 2 {
        alts.push(SamplerSpec::rung(Rung::C1));
    }
    alts.push(SamplerSpec::rung(Rung::A2));
    alts
}

/// Capability negotiation: spec × host × geometry → [`Plan`].
fn resolve(spec: SamplerSpec, layers: Option<usize>, exp: Option<ExpMode>) -> Result<Plan> {
    let mut notes: Vec<String> = Vec::new();
    let mut rejected: Vec<Rejection> = Vec::new();

    // The env override and the API preference share one path: force the
    // portable preference here, and nowhere else in the crate.
    let mut pref = spec.backend;
    if crate::simd::force_portable() && !spec.rung.is_accel() && pref != BackendPref::Portable {
        notes.push(format!(
            "VECTORISING_FORCE_PORTABLE is set: backend preference {pref} overridden to portable"
        ));
        pref = BackendPref::Portable;
    }

    let exp = exp.unwrap_or(match spec.rung {
        Rung::A1 => ExpMode::Exact,
        _ => ExpMode::Fast,
    });

    let done = |backend, width, layout, rejected, notes| {
        Ok(Plan { spec, rung: spec.rung, backend, width, layout, layers, exp, rejected, notes })
    };

    match spec.rung {
        Rung::A1 | Rung::A2 => {
            if let Width::W(n) = spec.width {
                anyhow::ensure!(
                    n == 1,
                    "scalar rung {} sweeps one spin at a time (requested width {n}); the vector \
                     rungs are a3/a4 (within one model) and c1 (across the ensemble)",
                    spec.rung.label()
                );
            }
            match pref {
                BackendPref::Auto => {}
                BackendPref::Portable => {
                    notes.push("scalar rung: the portable preference is a no-op".into())
                }
                other => anyhow::bail!(
                    "scalar rung {} has no {other} backend (only auto/portable make sense)",
                    spec.rung.label()
                ),
            }
            done(Backend::Scalar, 1, GroupLayout::Scalar, rejected, notes)
        }
        Rung::A3 | Rung::A4 | Rung::C1 => {
            let is_batch = spec.rung.is_replica_batch();
            if is_batch {
                if let Some(l) = layers {
                    if l < 2 {
                        return Err(UnsupportedGeometry {
                            rung: spec.rung,
                            width: 0,
                            layers: l,
                            alternatives: vec![SamplerSpec::rung(Rung::A2)],
                        }
                        .into());
                    }
                    // Record why within-model interlacing was (or was not)
                    // an option — the motivating context for choosing the
                    // replica-batch rung at this geometry.
                    for a_rung in [Rung::A3, Rung::A4] {
                        for &w in &candidate_widths(spec.width, pref) {
                            if MONO_WIDTHS.contains(&w) && !interlace_ok(l, w) {
                                rejected.push(Rejection {
                                    rung: a_rung,
                                    width: w,
                                    code: "layer-interlace",
                                    reason: format!(
                                        "within-model interlacing needs layers divisible by {w} \
                                         with >= 2 layers per section; layers={l} fails, so the \
                                         A-rungs cannot vectorize this model"
                                    ),
                                });
                            }
                        }
                    }
                }
            }
            let widths = candidate_widths(spec.width, pref);
            let mut geometry_failure: Option<usize> = None;
            for &w in &widths {
                if !MONO_WIDTHS.contains(&w) {
                    rejected.push(Rejection {
                        rung: spec.rung,
                        width: w,
                        code: "width-unavailable",
                        reason: format!(
                            "no monomorphized vector backend at width {w} (available: 4, 8, 16)"
                        ),
                    });
                    continue;
                }
                if !is_batch {
                    if let Some(l) = layers {
                        if !interlace_ok(l, w) {
                            geometry_failure.get_or_insert(w);
                            rejected.push(Rejection {
                                rung: spec.rung,
                                width: w,
                                code: "layer-interlace",
                                reason: format!(
                                    "needs n_layers divisible by {w} with at least 2 layers per \
                                     section (got {l})"
                                ),
                            });
                            continue;
                        }
                    }
                }
                match resolve_backend(spec.rung, pref, w) {
                    Ok((backend, fallback)) => {
                        if let Some(r) = fallback {
                            rejected.push(r);
                        }
                        let layout = if is_batch {
                            GroupLayout::ReplicaLanes { lanes: w }
                        } else {
                            GroupLayout::LayerInterlace {
                                sections: w,
                                layers_per_section: layers.map(|l| l / w),
                            }
                        };
                        return done(backend, w, layout, rejected, notes);
                    }
                    Err(r) => rejected.push(r),
                }
            }
            // Every candidate rejected.  A geometry failure gets the
            // structured error (with alternatives); otherwise summarize
            // the backend rejections.
            if let (Some(w), Some(l)) = (geometry_failure, layers) {
                let alternatives = geometry_alternatives(l)
                    .into_iter()
                    .filter(|alt| !(alt.rung == spec.rung && alt.width == Width::W(w)))
                    .collect();
                return Err(UnsupportedGeometry {
                    rung: spec.rung,
                    width: w,
                    layers: l,
                    alternatives,
                }
                .into());
            }
            let reasons: Vec<String> = rejected
                .iter()
                .map(|r| format!("{} at width {}: {} [{}]", r.rung, r.width, r.reason, r.code))
                .collect();
            anyhow::bail!(
                "no backend satisfies {} (rung {}): {}",
                spec.cli(),
                spec.rung.label(),
                reasons.join("; ")
            )
        }
        Rung::M1 => {
            if let Width::W(n) = spec.width {
                anyhow::ensure!(
                    n == 64,
                    "the multi-spin rung packs 64 spins per machine word; its width axis is \
                     fixed at 64 bits (requested width {n}) — use `--width auto` or `--width 64`"
                );
            }
            anyhow::ensure!(
                matches!(pref, BackendPref::Auto | BackendPref::Portable),
                "rung M.1 sweeps bit-packed words on the scalar ALU (the internal RNG lanes are \
                 negotiated separately and stream-identically); backend {pref} does not apply"
            );
            if pref == BackendPref::Portable {
                notes.push(
                    "m1: the portable preference only affects the internal RNG lanes; the \
                     uniform stream (and hence every flip) is bit-identical either way"
                        .into(),
                );
            }
            if let Some(l) = layers {
                if l < 2 || l % 2 != 0 {
                    return Err(UnsupportedGeometry {
                        rung: spec.rung,
                        width: 64,
                        layers: l,
                        alternatives: geometry_alternatives(l),
                    }
                    .into());
                }
            }
            notes.push(
                "m1 requires ±1 couplings and zero on-site fields (build the workload with \
                 ising::builder::pm_torus_workload); checked when the sweeper is instantiated"
                    .into(),
            );
            done(Backend::Scalar, 64, GroupLayout::BitPlanes { bits: 64 }, rejected, notes)
        }
        Rung::B1 | Rung::B2 => {
            if let Width::W(n) = spec.width {
                anyhow::ensure!(
                    n == 32,
                    "the accelerator rungs run 32-thread warps (requested width {n})"
                );
            }
            anyhow::ensure!(
                matches!(pref, BackendPref::Auto | BackendPref::Accel),
                "rung {} runs on the accelerator; backend {pref} does not apply",
                spec.rung.label()
            );
            if let Some(l) = layers {
                // B.2's pair-packed coalesced streams need the tau ring to
                // close over the lane pairs — an even layer count, the same
                // parity argument as M.1's checkerboard.  B.1 gathers
                // per-lane and only needs a well-formed model (>= 2 layers).
                let b2_parity_ok = spec.rung != Rung::B2 || l % 2 == 0;
                if l < 2 || !b2_parity_ok {
                    // Best alternative first: the nearest *runnable accel*
                    // config, then the usual CPU ladder.
                    let mut alternatives = Vec::new();
                    if spec.rung == Rung::B2 && l >= 2 {
                        alternatives.push(SamplerSpec::rung(Rung::B1).on(BackendPref::Accel));
                    }
                    alternatives.extend(geometry_alternatives(l));
                    return Err(UnsupportedGeometry {
                        rung: spec.rung,
                        width: 32,
                        layers: l,
                        alternatives,
                    }
                    .into());
                }
            }
            notes.push(
                "accelerator plans execute on the in-process software device: 256-thread blocks \
                 of 32-lane warps mapped onto the host vector units, coalesced vs strided \
                 global-memory transactions counted (device::DeviceSweeper); supply a PJRT \
                 Runtime to sweep::accel::AccelSweeper to run real compiled artifacts instead"
                    .into(),
            );
            done(Backend::Accel, 32, GroupLayout::AccelInterlace { width: 32 }, rejected, notes)
        }
    }
}

/// Instantiate a single-model sweeper from a resolved plan triple.  This
/// is the one match in the crate that maps `(rung, backend, width)` onto
/// concrete monomorphizations.
pub fn instantiate(
    r: Resolved,
    model: &QmcModel,
    s0: &[f32],
    seed: u32,
    exp: ExpMode,
) -> Result<Box<dyn Sweeper + Send>> {
    use crate::simd::portable::U32xN;
    match r.rung {
        Rung::A1 => return Ok(Box::new(a1_original::A1Original::new(model, s0, seed, exp))),
        Rung::A2 => return Ok(Box::new(a2_basic::A2Basic::new(model, s0, seed, exp))),
        Rung::C1 => anyhow::bail!(
            "replica-batch rung C.1 sweeps a lane-batch of replicas, not one model; use \
             EngineBuilder::build_batch / sweep::c1_replica_batch::make_batch_sweeper (or \
             tempering::BatchedPtEnsemble)"
        ),
        Rung::B1 | Rung::B2 => {
            let kind =
                if r.rung == Rung::B1 { SweepKind::B1Accel } else { SweepKind::B2Accel };
            // Micro-backend selection: the warp's 32 lanes tile over the
            // widest vector unit the host offers.  The choice never affects
            // trajectories (the wide fast-exp is lane-exact to scalar),
            // only throughput — so the plan's backend stays `Accel`.
            #[cfg(all(target_arch = "x86_64", has_avx512_intrinsics))]
            if crate::simd::avx512_available() {
                return Ok(Box::new(DeviceSweeper::<crate::simd::avx512::U32x16>::new(
                    kind, model, s0, seed, exp,
                )?));
            }
            #[cfg(target_arch = "x86_64")]
            if crate::simd::avx2_available() {
                return Ok(Box::new(DeviceSweeper::<crate::simd::avx2::U32x8>::new(
                    kind, model, s0, seed, exp,
                )?));
            }
            if crate::simd::force_portable() {
                return Ok(Box::new(DeviceSweeper::<U32xN<4>>::new(kind, model, s0, seed, exp)?));
            }
            return Ok(Box::new(DeviceSweeper::<crate::simd::U32x4>::new(
                kind, model, s0, seed, exp,
            )?));
        }
        Rung::M1 => {
            // The word sweep is scalar ALU work; only the internal uniform
            // generator is lane-parallel.  Pick the fastest 8-lane RNG
            // backend — the streams are bit-identical, so the choice never
            // changes a flip decision (or a checkpoint payload).
            #[cfg(target_arch = "x86_64")]
            if crate::simd::avx2_available() {
                return Ok(Box::new(m1_multispin::M1MultiSpin::<crate::simd::avx2::U32x8>::new(
                    model, s0, seed, exp,
                )?));
            }
            return Ok(Box::new(m1_multispin::M1MultiSpin::<U32xN<8>>::new(
                model, s0, seed, exp,
            )?));
        }
        Rung::A3 | Rung::A4 => {}
    }
    let a3 = r.rung == Rung::A3;
    // `crate::simd::U32x4` is the SSE2 type on x86_64 and the portable
    // quadruplet elsewhere; negotiation only ever yields `Sse2` on x86_64.
    Ok(match (r.backend, r.width) {
        (Backend::Sse2, 4) => {
            if a3 {
                Box::new(a3_vecrng::A3VecRng::<crate::simd::U32x4>::new(model, s0, seed, exp))
            } else {
                Box::new(a4_full::A4Full::<crate::simd::U32x4>::new(model, s0, seed, exp))
            }
        }
        #[cfg(target_arch = "x86_64")]
        (Backend::Avx2, 8) => {
            if a3 {
                Box::new(a3_vecrng::A3VecRng::<crate::simd::avx2::U32x8>::new(
                    model, s0, seed, exp,
                ))
            } else {
                Box::new(a4_full::A4Full::<crate::simd::avx2::U32x8>::new(model, s0, seed, exp))
            }
        }
        #[cfg(all(target_arch = "x86_64", has_avx512_intrinsics))]
        (Backend::Avx512, 16) => {
            if a3 {
                Box::new(a3_vecrng::A3VecRng::<crate::simd::avx512::U32x16>::new(
                    model, s0, seed, exp,
                ))
            } else {
                Box::new(a4_full::A4Full::<crate::simd::avx512::U32x16>::new(model, s0, seed, exp))
            }
        }
        (Backend::Portable, 4) => {
            if a3 {
                Box::new(a3_vecrng::A3VecRng::<U32xN<4>>::new(model, s0, seed, exp))
            } else {
                Box::new(a4_full::A4Full::<U32xN<4>>::new(model, s0, seed, exp))
            }
        }
        (Backend::Portable, 8) => {
            if a3 {
                Box::new(a3_vecrng::A3VecRng::<U32xN<8>>::new(model, s0, seed, exp))
            } else {
                Box::new(a4_full::A4Full::<U32xN<8>>::new(model, s0, seed, exp))
            }
        }
        (Backend::Portable, 16) => {
            if a3 {
                Box::new(a3_vecrng::A3VecRng::<U32xN<16>>::new(model, s0, seed, exp))
            } else {
                Box::new(a4_full::A4Full::<U32xN<16>>::new(model, s0, seed, exp))
            }
        }
        (backend, width) => anyhow::bail!(
            "no {} implementation for backend {backend} at width {width} on this host",
            r.rung.label()
        ),
    })
}

/// Instantiate a C-rung lane-batch from a resolved plan triple.
pub fn instantiate_batch(
    r: Resolved,
    models: &[QmcModel],
    states: &[Vec<f32>],
    seeds: &[u32],
    exp: ExpMode,
) -> Result<Box<dyn BatchSweeper + Send>> {
    use crate::simd::portable::U32xN;
    anyhow::ensure!(
        r.rung.is_replica_batch(),
        "{} is not a replica-batch rung (only c1 sweeps lane-batches)",
        r.rung.label()
    );
    Ok(match (r.backend, r.width) {
        (Backend::Sse2, 4) => {
            Box::new(C1ReplicaBatch::<crate::simd::U32x4>::new(models, states, seeds, exp)?)
        }
        #[cfg(target_arch = "x86_64")]
        (Backend::Avx2, 8) => {
            Box::new(C1ReplicaBatch::<crate::simd::avx2::U32x8>::new(models, states, seeds, exp)?)
        }
        #[cfg(all(target_arch = "x86_64", has_avx512_intrinsics))]
        (Backend::Avx512, 16) => Box::new(C1ReplicaBatch::<crate::simd::avx512::U32x16>::new(
            models, states, seeds, exp,
        )?),
        (Backend::Portable, 4) => {
            Box::new(C1ReplicaBatch::<U32xN<4>>::new(models, states, seeds, exp)?)
        }
        (Backend::Portable, 8) => {
            Box::new(C1ReplicaBatch::<U32xN<8>>::new(models, states, seeds, exp)?)
        }
        (Backend::Portable, 16) => {
            Box::new(C1ReplicaBatch::<U32xN<16>>::new(models, states, seeds, exp)?)
        }
        (backend, width) => anyhow::bail!(
            "no C.1 implementation for backend {backend} at width {width} on this host"
        ),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ising::builder::torus_workload;

    #[test]
    fn auto_spec_resolves_to_host_widest() {
        let plan = EngineBuilder::new(SamplerSpec::rung(Rung::A4)).layers(32).plan().unwrap();
        let expect = if crate::simd::avx512_available() {
            16
        } else {
            crate::simd::widest_supported_width()
        };
        assert_eq!(plan.width, expect);
        assert!(matches!(plan.layout, GroupLayout::LayerInterlace { .. }));
        assert_eq!(plan.rung, Rung::A4);
    }

    #[test]
    fn auto_width_narrows_on_geometry() {
        // layers=12: width 8 impossible (12 % 8 != 0), width 4 fine.
        let plan = EngineBuilder::new(SamplerSpec::rung(Rung::A4)).layers(12).plan().unwrap();
        assert_eq!(plan.width, 4);
        if crate::simd::widest_supported_width() == 8 {
            assert!(
                plan.rejected.iter().any(|r| r.width == 8 && r.code == "layer-interlace"),
                "the w8 candidate must be recorded as rejected: {:?}",
                plan.rejected
            );
        }
    }

    #[test]
    fn explicit_width_failure_is_structured() {
        let err =
            EngineBuilder::new(SamplerSpec::rung(Rung::A4).w(8)).layers(12).plan().err().unwrap();
        let ug = err.downcast_ref::<UnsupportedGeometry>().expect("UnsupportedGeometry");
        assert_eq!(ug.width, 8);
        assert_eq!(ug.layers, 12);
        assert!(ug.alternatives.iter().any(|a| a.rung == Rung::C1));
        assert!(ug.alternatives.iter().any(|a| a.rung == Rung::A4 && a.width == Width::W(4)));
    }

    #[test]
    fn c1_plan_records_why_a_rungs_were_rejected() {
        // The acceptance scenario: shallow model, C-rung chosen, and the
        // plan explains that A-rung interlacing is impossible at layers=2.
        let plan = EngineBuilder::new(SamplerSpec::rung(Rung::C1)).layers(2).plan().unwrap();
        assert!(plan.width == 4 || plan.width == 8 || plan.width == 16);
        assert!(matches!(plan.layout, GroupLayout::ReplicaLanes { .. }));
        assert!(
            plan.rejected
                .iter()
                .any(|r| matches!(r.rung, Rung::A3 | Rung::A4) && r.code == "layer-interlace"),
            "plan must name the A-rung rejections: {:?}",
            plan.rejected
        );
    }

    #[test]
    fn scalar_rungs_reject_vector_widths() {
        assert!(EngineBuilder::new(SamplerSpec::rung(Rung::A2).w(4)).plan().is_err());
        let plan = EngineBuilder::new(SamplerSpec::rung(Rung::A1)).plan().unwrap();
        assert_eq!(plan.width, 1);
        assert_eq!(plan.backend, Backend::Scalar);
        assert_eq!(plan.exp, ExpMode::Exact, "A.1 defaults to the library exp");
    }

    #[test]
    fn portable_width_16_is_free() {
        // Pin the portable backend: with backend auto, a host with
        // AVX-512F resolves w16 onto the intrinsic backend instead.
        let spec = SamplerSpec::rung(Rung::A4).w(16).on(BackendPref::Portable);
        let plan = EngineBuilder::new(spec).layers(32).plan().unwrap();
        assert_eq!(plan.width, 16);
        assert_eq!(plan.backend, Backend::Portable);
        assert_eq!(plan.label(), "A.4w16");
        assert_eq!(plan.legacy_kind(), None);
        let wl = torus_workload(4, 4, 32, 1, 0.3);
        let mut engine = EngineBuilder::new(spec).build(&wl.model, &wl.s0, 7).unwrap();
        let stats = engine.run(3, 0.8);
        assert!(stats.attempts > 0);
        assert!(engine.validate() < 1e-3);
    }

    #[test]
    fn accel_rungs_plan_and_build_on_the_device_sim() {
        let plan = EngineBuilder::new(SamplerSpec::rung(Rung::B2)).plan().unwrap();
        assert_eq!(plan.backend, Backend::Accel);
        assert_eq!(plan.width, 32);
        let wl = torus_workload(4, 4, 8, 1, 0.3);
        for rung in [Rung::B1, Rung::B2] {
            let mut engine =
                EngineBuilder::new(SamplerSpec::rung(rung)).build(&wl.model, &wl.s0, 1).unwrap();
            assert_eq!(engine.plan.backend, Backend::Accel);
            let stats = engine.run(3, 0.8);
            assert!(stats.attempts > 0, "{} must sweep", rung.label());
            assert!(engine.validate() < 1e-3);
        }
    }

    #[test]
    fn b2_odd_layers_name_the_nearest_runnable_accel_config() {
        let err = EngineBuilder::new(SamplerSpec::rung(Rung::B2)).layers(9).plan().err().unwrap();
        let ug = err.downcast_ref::<UnsupportedGeometry>().expect("UnsupportedGeometry");
        assert_eq!(ug.width, 32);
        assert_eq!(ug.layers, 9);
        let first = ug.alternatives.first().expect("must offer alternatives");
        assert_eq!(first.rung, Rung::B1, "nearest accel config first: {:?}", ug.alternatives);
        assert_eq!(first.backend, BackendPref::Accel);
        // ... and that alternative really resolves at this geometry.
        assert!(EngineBuilder::new(*first).layers(9).plan().is_ok());
        // Degenerate depths fall back to the CPU ladder only.
        let err = EngineBuilder::new(SamplerSpec::rung(Rung::B1)).layers(1).plan().err().unwrap();
        let ug = err.downcast_ref::<UnsupportedGeometry>().expect("UnsupportedGeometry");
        assert!(ug.alternatives.iter().all(|a| a.rung != Rung::B1 && a.rung != Rung::B2));
    }

    #[test]
    fn batch_builder_builds_c1() {
        let w = 4usize;
        let wls: Vec<_> = (0..w).map(|i| torus_workload(4, 4, 2, 1 + i as u64, 0.3)).collect();
        let models: Vec<_> = wls.iter().map(|wl| wl.model.clone()).collect();
        let states: Vec<_> = wls.iter().map(|wl| wl.s0.clone()).collect();
        let seeds: Vec<u32> = (0..w as u32).map(|i| 100 + i).collect();
        let mut batch = EngineBuilder::new(SamplerSpec::rung(Rung::C1).w(4))
            .build_batch(&models, &states, &seeds)
            .unwrap();
        assert_eq!(batch.plan.width, 4);
        let stats = batch.run(2, &[0.5, 0.6, 0.7, 0.8]);
        assert_eq!(stats.len(), 4);
        assert!(stats[0].attempts > 0);
    }

    #[test]
    fn avx2_pin_errors_cleanly_at_wrong_width() {
        let err = EngineBuilder::new(SamplerSpec::rung(Rung::A4).w(4).on(BackendPref::Avx2))
            .layers(32)
            .plan()
            .err()
            .unwrap();
        assert!(format!("{err:#}").contains("8-lane"));
    }

    #[test]
    fn avx512_pin_errors_cleanly_at_wrong_width() {
        let err = EngineBuilder::new(SamplerSpec::rung(Rung::A4).w(8).on(BackendPref::Avx512))
            .layers(32)
            .plan()
            .err()
            .unwrap();
        assert!(format!("{err:#}").contains("16-lane"));
    }

    #[test]
    fn width_16_resolves_avx512_or_portable_with_reason() {
        let plan = EngineBuilder::new(SamplerSpec::rung(Rung::A4).w(16)).layers(32).plan().unwrap();
        assert_eq!(plan.width, 16);
        if crate::simd::avx512_available() {
            assert_eq!(plan.backend, Backend::Avx512);
        } else {
            assert_eq!(plan.backend, Backend::Portable);
            assert!(
                plan.rejected.iter().any(|r| r.code == "no-avx512"),
                "the avx512 downgrade must be recorded: {:?}",
                plan.rejected
            );
        }
    }

    #[test]
    fn m1_plan_is_bit_planes_width_64() {
        let plan = EngineBuilder::new(SamplerSpec::rung(Rung::M1)).layers(256).plan().unwrap();
        assert_eq!(plan.width, 64);
        assert_eq!(plan.backend, Backend::Scalar);
        assert_eq!(plan.layout, GroupLayout::BitPlanes { bits: 64 });
        assert_eq!(plan.label(), "M.1");
        assert_eq!(plan.legacy_kind(), Some(crate::sweep::SweepKind::M1MultiSpin));
        // Spelled-out width 64 is the same plan; any other width is an error.
        assert!(EngineBuilder::new(SamplerSpec::rung(Rung::M1).w(64)).layers(256).plan().is_ok());
        assert!(EngineBuilder::new(SamplerSpec::rung(Rung::M1).w(8)).layers(256).plan().is_err());
    }

    #[test]
    fn m1_rejects_odd_layer_counts() {
        let err = EngineBuilder::new(SamplerSpec::rung(Rung::M1)).layers(9).plan().err().unwrap();
        let ug = err.downcast_ref::<UnsupportedGeometry>().expect("UnsupportedGeometry");
        assert_eq!(ug.layers, 9);
        // Even (checkerboard-compatible) layer counts plan fine, even when
        // they are not divisible by the word size.
        assert!(EngineBuilder::new(SamplerSpec::rung(Rung::M1)).layers(10).plan().is_ok());
    }

    #[test]
    fn m1_requires_pm_couplings_at_build_time() {
        use crate::ising::builder::{pm_torus_workload, torus_workload};
        let wl = torus_workload(4, 4, 8, 1, 0.3);
        let err = EngineBuilder::new(SamplerSpec::rung(Rung::M1)).build(&wl.model, &wl.s0, 5);
        assert!(format!("{:#}", err.err().unwrap()).contains("pm_torus_workload"));
        let wl = pm_torus_workload(4, 4, 8, 1, 0.5);
        let mut engine =
            EngineBuilder::new(SamplerSpec::rung(Rung::M1)).build(&wl.model, &wl.s0, 5).unwrap();
        let stats = engine.run(3, 0.7);
        assert!(stats.attempts > 0);
    }
}
