//! Engine API v1 — the orthogonal construction surface.
//!
//! The paper's lesson is that vector width and memory layout are tunable
//! axes of *one* algorithm, not separate algorithms.  The legacy
//! [`crate::sweep::SweepKind`] surface baked the width into enum variants
//! (`A3VecRngW8`, `C1ReplicaBatchW8`), so every new width or backend
//! multiplied the enum and every match arm downstream.  This module
//! replaces that with three orthogonal axes:
//!
//! * [`Rung`] — *which algorithm* (the paper's ladder: A.1/A.2/A.3/A.4,
//!   the replica-batch C.1, the accelerator B.1/B.2);
//! * [`Width`] — *how many lanes* (`Auto` or an explicit lane count);
//! * [`BackendPref`] — *which instruction set* (`Auto`, or pin SSE2 /
//!   AVX2 / the const-generic portable lanes / the accelerator).
//!
//! A [`SamplerSpec`] combines the three; an [`EngineBuilder`] resolves it
//! against host capabilities (`is_x86_feature_detected!`, the
//! `VECTORISING_FORCE_PORTABLE` override) and model geometry (the layer
//! count) into an explicit [`Plan`]: the chosen backend, the effective
//! width, the lane→work layout, and a machine-readable fallback chain of
//! every candidate that was considered and rejected ("a4 at width 8
//! rejected: layers=12 not divisible by 8").  The Plan is what `repro
//! plan` prints as JSON and what the sampling service echoes back with
//! every result.
//!
//! Express intent; let the dispatch layer negotiate the instruction set.
//! The legacy `SweepKind` spellings all lower onto specs (see
//! [`SamplerSpec::from`]), and `sweep::try_make_sweeper` is now a thin
//! shim over this module — one dispatch point for the whole crate.
//!
//! ```no_run
//! use vectorising::engine::{EngineBuilder, Rung, SamplerSpec};
//! use vectorising::ising::builder::torus_workload;
//! use vectorising::sweep::Sweeper;
//!
//! let wl = torus_workload(8, 8, 32, 1, 0.3);
//! let spec = SamplerSpec::rung(Rung::A4); // width auto, backend auto
//! let mut engine = EngineBuilder::new(spec).build(&wl.model, &wl.s0, 5489).unwrap();
//! println!("negotiated: {}", engine.plan.label());
//! engine.run(100, 0.5);
//! ```

pub mod builder;
pub mod error;
pub mod plan;

pub use builder::{BatchEngine, Engine, EngineBuilder};
pub use error::{NonResumableRng, UnsupportedGeometry};
pub use plan::{groups_label, Backend, GroupLayout, GroupPlan, Plan, Rejection, Resolved};

use crate::sweep::SweepKind;
use crate::util::json::{self, Value};

/// Version of the v1 surface: stamped on every negotiated [`Plan`] and
/// every sampling-service response line (the service re-exports it as
/// `service::job::PROTOCOL_VERSION`).  Version-0 artifacts (no version
/// field) remain accepted everywhere.
pub const PROTOCOL_VERSION: usize = 1;

/// Which algorithm family of the paper's ladder — the rung axis, with the
/// width and backend factored out into [`Width`] and [`BackendPref`].
#[derive(Copy, Clone, Debug, PartialEq, Eq, Hash)]
pub enum Rung {
    /// A.1 — original scalar implementation (branchy loop, library exp).
    A1,
    /// A.2 — basic optimizations (§2): branch-free, flat edges, fast exp.
    A2,
    /// A.3 — vectorized MT19937 + flip decisions (§3).
    A3,
    /// A.4 — fully vectorized, incl. neighbour updates (§3.1).
    A4,
    /// C.1 — replica-batched: one SIMD lane per tempering replica.
    C1,
    /// M.1 — multi-spin coding: 64 spins bit-packed per machine word,
    /// XOR-parity neighbour sums, acceptance via per-bin integer
    /// thresholds (Weigel & Yavors'kii's trick on top of the A-ladder).
    M1,
    /// B.1 — accelerator, naive gathered layout.
    B1,
    /// B.2 — accelerator, coalesced interlaced layout (§3.2).
    B2,
}

impl Rung {
    /// Canonical CLI spelling (`--rung a4`).
    pub fn as_str(self) -> &'static str {
        match self {
            Rung::A1 => "a1",
            Rung::A2 => "a2",
            Rung::A3 => "a3",
            Rung::A4 => "a4",
            Rung::C1 => "c1",
            Rung::M1 => "m1",
            Rung::B1 => "b1",
            Rung::B2 => "b2",
        }
    }

    /// Paper-style label (`A.4`).
    pub fn label(self) -> &'static str {
        match self {
            Rung::A1 => "A.1",
            Rung::A2 => "A.2",
            Rung::A3 => "A.3",
            Rung::A4 => "A.4",
            Rung::C1 => "C.1",
            Rung::M1 => "M.1",
            Rung::B1 => "B.1",
            Rung::B2 => "B.2",
        }
    }

    /// Scalar rungs sweep one spin at a time (width is always 1).
    pub fn is_scalar(self) -> bool {
        matches!(self, Rung::A1 | Rung::A2)
    }

    /// The within-model vector rungs (lanes interlace the layers).
    pub fn is_vector_cpu(self) -> bool {
        matches!(self, Rung::A3 | Rung::A4)
    }

    /// The across-ensemble vector rung (one lane per replica).
    pub fn is_replica_batch(self) -> bool {
        matches!(self, Rung::C1)
    }

    /// The bit-packed multi-spin rung (64 spins per word; "width" counts
    /// spin bits per word, not f32/u32 SIMD lanes).
    pub fn is_multispin(self) -> bool {
        matches!(self, Rung::M1)
    }

    /// The accelerator rungs (the software device of [`crate::device`];
    /// compiled XLA artifacts via PJRT when a runtime is supplied).
    pub fn is_accel(self) -> bool {
        matches!(self, Rung::B1 | Rung::B2)
    }

    /// A spec for this rung with both other axes on `Auto`.
    pub fn spec(self) -> SamplerSpec {
        SamplerSpec::rung(self)
    }
}

impl std::fmt::Display for Rung {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl std::str::FromStr for Rung {
    type Err = crate::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "a1" | "a.1" | "a1-original" => Ok(Rung::A1),
            "a2" | "a.2" | "a2-basic" => Ok(Rung::A2),
            "a3" | "a.3" | "a3-vec-rng" | "a3-vecrng" => Ok(Rung::A3),
            "a4" | "a.4" | "a4-full" => Ok(Rung::A4),
            "c1" | "c.1" | "c1-replica-batch" => Ok(Rung::C1),
            "m1" | "m.1" | "m1-multispin" => Ok(Rung::M1),
            "b1" | "b.1" | "b1-accel" => Ok(Rung::B1),
            "b2" | "b.2" | "b2-accel" => Ok(Rung::B2),
            other => anyhow::bail!(
                "unknown rung {other:?} (expected a1, a2, a3, a4, c1, m1, b1 or b2; width goes in \
                 --width, not the rung name — use `--rung a4 --width 8`, not `a4-full-w8`)"
            ),
        }
    }
}

/// The lane-count axis of a [`SamplerSpec`].
#[derive(Copy, Clone, Debug, PartialEq, Eq, Hash)]
pub enum Width {
    /// Negotiate: the widest lane count the host, backend preference and
    /// model geometry jointly support.
    Auto,
    /// Exactly this many lanes (1 for the scalar rungs; 4/8/16 have
    /// monomorphized vector backends).
    W(usize),
}

impl std::fmt::Display for Width {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Width::Auto => f.write_str("auto"),
            Width::W(n) => write!(f, "{n}"),
        }
    }
}

impl std::str::FromStr for Width {
    type Err = crate::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        if s.eq_ignore_ascii_case("auto") {
            return Ok(Width::Auto);
        }
        let n: usize = s
            .parse()
            .map_err(|e| anyhow::anyhow!("width {s:?}: {e} (expected `auto` or a lane count)"))?;
        anyhow::ensure!(n >= 1, "width must be >= 1 (got {n})");
        Ok(Width::W(n))
    }
}

/// The instruction-set axis of a [`SamplerSpec`].
#[derive(Copy, Clone, Debug, PartialEq, Eq, Hash)]
pub enum BackendPref {
    /// Negotiate: the fastest backend the host supports at the effective
    /// width (AVX2 at 8, SSE2 at 4, portable lanes otherwise).
    Auto,
    /// Pin the 4-lane SSE2 backend (x86_64 baseline).
    Sse2,
    /// Pin the 8-lane AVX2 backend (requires host detection).
    Avx2,
    /// Pin the 16-lane AVX-512F backend (requires host detection *and* a
    /// toolchain with the stabilized `_mm512_*` intrinsics, Rust ≥ 1.89).
    Avx512,
    /// Pin the const-generic portable lanes (any width, any arch — also
    /// what `VECTORISING_FORCE_PORTABLE=1` forces for every CPU rung).
    Portable,
    /// The accelerator path (B-rungs only): the in-process software
    /// device with counted memory transactions.
    Accel,
}

impl BackendPref {
    pub fn as_str(self) -> &'static str {
        match self {
            BackendPref::Auto => "auto",
            BackendPref::Sse2 => "sse2",
            BackendPref::Avx2 => "avx2",
            BackendPref::Avx512 => "avx512",
            BackendPref::Portable => "portable",
            BackendPref::Accel => "accel",
        }
    }
}

impl std::fmt::Display for BackendPref {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl std::str::FromStr for BackendPref {
    type Err = crate::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Ok(BackendPref::Auto),
            "sse2" | "sse" => Ok(BackendPref::Sse2),
            "avx2" | "avx" => Ok(BackendPref::Avx2),
            "avx512" | "avx512f" | "avx-512" => Ok(BackendPref::Avx512),
            "portable" => Ok(BackendPref::Portable),
            "accel" => Ok(BackendPref::Accel),
            other => anyhow::bail!(
                "unknown backend {other:?} (expected auto, sse2, avx2, avx512, portable or accel)"
            ),
        }
    }
}

/// What to build: rung × width × backend, each axis independent.  The
/// construction surface of the crate — resolve one against a host and a
/// model with [`EngineBuilder`].
#[derive(Copy, Clone, Debug, PartialEq, Eq, Hash)]
pub struct SamplerSpec {
    pub rung: Rung,
    pub width: Width,
    pub backend: BackendPref,
}

impl SamplerSpec {
    /// A spec with width and backend on `Auto`.
    pub fn rung(rung: Rung) -> Self {
        Self { rung, width: Width::Auto, backend: BackendPref::Auto }
    }

    /// Pin the lane count.
    pub fn w(mut self, lanes: usize) -> Self {
        self.width = Width::W(lanes);
        self
    }

    /// Pin the backend.
    pub fn on(mut self, backend: BackendPref) -> Self {
        self.backend = backend;
        self
    }

    /// The CLI spelling of this spec (`--rung a4 --width 8 --backend avx2`;
    /// `auto` axes are included for width, omitted for backend).
    pub fn cli(&self) -> String {
        let mut s = format!("--rung {} --width {}", self.rung, self.width);
        if self.backend != BackendPref::Auto {
            s.push_str(&format!(" --backend {}", self.backend));
        }
        s
    }

    /// JSON form (`{"rung":"a4","width":"auto","backend":"auto"}`).
    pub fn to_value(&self) -> Value {
        let width = match self.width {
            Width::Auto => json::str_v("auto"),
            Width::W(n) => json::num(n as f64),
        };
        json::obj(vec![
            ("rung", json::str_v(self.rung.as_str())),
            ("width", width),
            ("backend", json::str_v(self.backend.as_str())),
        ])
    }

    /// Parse the JSON form back (`width` may be the string `"auto"` or a
    /// number; `width`/`backend` default to auto when absent).
    pub fn from_value(v: &Value) -> crate::Result<SamplerSpec> {
        let rung: Rung = v.get("rung")?.as_str()?.parse()?;
        let width = match v.opt("width") {
            None => Width::Auto,
            Some(Value::Str(s)) => s.parse()?,
            Some(n) => Width::W(n.as_usize().map_err(|e| anyhow::anyhow!("sampler width: {e}"))?),
        };
        let backend = match v.opt("backend") {
            None => BackendPref::Auto,
            Some(b) => b.as_str()?.parse()?,
        };
        Ok(SamplerSpec { rung, width, backend })
    }
}

impl std::fmt::Display for SamplerSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}/w{}/{}", self.rung, self.width, self.backend)
    }
}

/// Lower a legacy width-baked [`SweepKind`] onto the orthogonal spec it
/// always meant — the back-compat story of the v1 API: every old
/// spelling keeps working by lowering through this.
impl From<SweepKind> for SamplerSpec {
    fn from(kind: SweepKind) -> SamplerSpec {
        let (rung, width) = match kind {
            SweepKind::A1Original => (Rung::A1, Width::W(1)),
            SweepKind::A2Basic => (Rung::A2, Width::W(1)),
            SweepKind::A3VecRng => (Rung::A3, Width::W(4)),
            SweepKind::A4Full => (Rung::A4, Width::W(4)),
            SweepKind::A3VecRngW8 => (Rung::A3, Width::W(8)),
            SweepKind::A4FullW8 => (Rung::A4, Width::W(8)),
            SweepKind::C1ReplicaBatch => (Rung::C1, Width::W(4)),
            SweepKind::C1ReplicaBatchW8 => (Rung::C1, Width::W(8)),
            SweepKind::M1MultiSpin => (Rung::M1, Width::W(64)),
            SweepKind::B1Accel => (Rung::B1, Width::W(32)),
            SweepKind::B2Accel => (Rung::B2, Width::W(32)),
        };
        let backend = if kind == SweepKind::B1Accel || kind == SweepKind::B2Accel {
            BackendPref::Accel
        } else {
            BackendPref::Auto
        };
        SamplerSpec { rung, width, backend }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::str::FromStr;

    #[test]
    fn rung_spellings_parse() {
        for (s, r) in [
            ("a1", Rung::A1),
            ("A.2", Rung::A2),
            ("a3-vec-rng", Rung::A3),
            ("a4-full", Rung::A4),
            ("c1-replica-batch", Rung::C1),
            ("m1", Rung::M1),
            ("M.1", Rung::M1),
            ("m1-multispin", Rung::M1),
            ("b1", Rung::B1),
            ("B.2", Rung::B2),
        ] {
            assert_eq!(Rung::from_str(s).unwrap(), r, "{s}");
        }
        // Width-suffixed legacy spellings are SweepKind spellings, not rungs.
        assert!(Rung::from_str("a4-full-w8").is_err());
    }

    #[test]
    fn width_and_backend_parse() {
        assert_eq!(Width::from_str("auto").unwrap(), Width::Auto);
        assert_eq!(Width::from_str("8").unwrap(), Width::W(8));
        assert!(Width::from_str("0").is_err());
        assert!(Width::from_str("four").is_err());
        assert_eq!(BackendPref::from_str("avx2").unwrap(), BackendPref::Avx2);
        assert_eq!(BackendPref::from_str("avx512").unwrap(), BackendPref::Avx512);
        assert_eq!(BackendPref::from_str("sse").unwrap(), BackendPref::Sse2);
        assert!(BackendPref::from_str("neon").is_err());
    }

    #[test]
    fn legacy_kinds_lower_to_specs() {
        let s: SamplerSpec = SweepKind::A4FullW8.into();
        assert_eq!(s, SamplerSpec::rung(Rung::A4).w(8));
        let s: SamplerSpec = SweepKind::C1ReplicaBatch.into();
        assert_eq!(s, SamplerSpec::rung(Rung::C1).w(4));
        let s: SamplerSpec = SweepKind::B2Accel.into();
        assert_eq!(s, SamplerSpec::rung(Rung::B2).w(32).on(BackendPref::Accel));
        let s: SamplerSpec = SweepKind::A1Original.into();
        assert_eq!(s, SamplerSpec::rung(Rung::A1).w(1));
    }

    #[test]
    fn spec_json_roundtrips() {
        for spec in [
            SamplerSpec::rung(Rung::A4),
            SamplerSpec::rung(Rung::C1).w(8).on(BackendPref::Avx2),
            SamplerSpec::rung(Rung::A3).w(16).on(BackendPref::Portable),
        ] {
            let v = spec.to_value();
            let parsed = SamplerSpec::from_value(&Value::parse(&v.to_string()).unwrap()).unwrap();
            assert_eq!(parsed, spec);
        }
    }

    #[test]
    fn cli_spelling_is_flag_shaped() {
        assert_eq!(SamplerSpec::rung(Rung::C1).cli(), "--rung c1 --width auto");
        assert_eq!(
            SamplerSpec::rung(Rung::A4).w(8).on(BackendPref::Avx2).cli(),
            "--rung a4 --width 8 --backend avx2"
        );
    }
}
