//! The dynamic lane-batching scheduler core: shape-bucketed admission
//! queues that pack up to `W` compatible jobs into one C-rung lane-batch.
//!
//! This is the service-level version of the paper's central lesson —
//! throughput comes from keeping every SIMD lane busy with homogeneous
//! work.  Queued jobs are bucketed by [`ShapeKey`] (identical model
//! shape ⇒ identical CSR topology ⇒ batchable into one
//! [`crate::ising::ReplicaBatchModel`]); a bucket dispatches
//!
//! * immediately once it holds `W` jobs (a full batch, lane fill 1), or
//! * when its **oldest** job has waited past the flush deadline, so
//!   latency is bounded: ≥ 2 stragglers go out as a padded batch, a lone
//!   job falls back to a scalar A-rung dispatch.
//!
//! Jobs that pin the scalar (`a2`), multi-spin (`m1`) or accel
//! (`b1`/`b2`) sampler bypass the shape buckets and dispatch as singles
//! on the next poll — m1's 64 lanes are the job's own layer bits, and
//! the accel rungs' 32-thread warps are spins of the job's own model,
//! so cross-job packing would add nothing.
//!
//! FIFO order is preserved within a bucket (each bucket is a `VecDeque`
//! popped from the front), and a batch never mixes shapes by
//! construction — the property tests in `tests/service_batcher.rs` pin
//! both invariants down.
//!
//! Time is always passed in (`push(_, _, now)` / `poll(now)`), so the
//! deadline machinery is testable without sleeping.

use std::collections::{BTreeMap, VecDeque};
use std::sync::mpsc::Sender;
use std::time::{Duration, Instant};

use crate::obs::Timeline;

use super::job::{JobSpec, ShapeKey};

/// An admitted job waiting for lane-mates.
pub struct PendingJob {
    pub spec: JobSpec,
    /// Channel the serialized result line goes back through (`None` in
    /// benches/tests that consume results directly).
    pub reply: Option<Sender<String>>,
    /// Admission time — the flush deadline counts from here.
    pub enqueued: Instant,
    /// Admission sequence number (FIFO evidence).
    pub seq: u64,
    /// Lifecycle stage stamps (admit → enqueue set here; seal, dispatch
    /// and the sweep pair stamped as the job moves downstream).
    pub timeline: Timeline,
}

/// The shape of work inside a [`Dispatch`].
pub enum DispatchWork {
    /// `1..=W` shape-compatible jobs packed into one lane-batch (padded
    /// up to `W` discarded lanes at execution time when fewer than `W`).
    Batch(Vec<PendingJob>),
    /// A job with no compatible peers — served by a scalar A.2 sweeper
    /// (or the m1 path when the job pins it).
    Single(PendingJob),
}

/// A unit of work the scheduler hands to the sweep pool, annotated with
/// *why* it left the queue — a full-width batch and a pinned single
/// dispatch by design, while a deadline flush means the batcher gave up
/// waiting for lane-mates.  The distinction feeds the `deadline_flushes`
/// metric, the control signal for w8 → w4 bucket retargeting.
pub struct Dispatch {
    pub work: DispatchWork,
    /// True only when the flush deadline (not width or a sampler pin)
    /// forced this dispatch out of the queue.
    pub deadline_forced: bool,
}

impl Dispatch {
    pub fn batch(jobs: Vec<PendingJob>, deadline_forced: bool) -> Self {
        Self { work: DispatchWork::Batch(jobs), deadline_forced }
    }

    pub fn single(job: PendingJob, deadline_forced: bool) -> Self {
        Self { work: DispatchWork::Single(job), deadline_forced }
    }

    /// Active (non-padded) lanes this dispatch occupies.
    pub fn occupancy(&self) -> usize {
        match &self.work {
            DispatchWork::Batch(jobs) => jobs.len(),
            DispatchWork::Single(_) => 1,
        }
    }

    pub fn is_batch(&self) -> bool {
        matches!(self.work, DispatchWork::Batch(_))
    }

    pub fn into_jobs(self) -> Vec<PendingJob> {
        match self.work {
            DispatchWork::Batch(jobs) => jobs,
            DispatchWork::Single(job) => vec![job],
        }
    }

    /// Shape-bucket label of the jobs inside (`WxHxL`) — uniform within
    /// a batch by construction.
    pub fn shape_label(&self) -> String {
        match &self.work {
            DispatchWork::Batch(jobs) => jobs[0].spec.shape().to_string(),
            DispatchWork::Single(job) => job.spec.shape().to_string(),
        }
    }

    fn jobs_mut(&mut self) -> &mut [PendingJob] {
        match &mut self.work {
            DispatchWork::Batch(jobs) => jobs,
            DispatchWork::Single(job) => std::slice::from_mut(job),
        }
    }

    /// Stamp every member's batch-seal time (the batcher committed this
    /// dispatch).
    pub fn stamp_sealed(&mut self, t: Instant) {
        for job in self.jobs_mut() {
            job.timeline.seal = Some(t);
        }
    }

    /// Stamp every member's pool-pickup time (a worker started the
    /// dispatch).
    pub fn stamp_dispatched(&mut self, t: Instant) {
        for job in self.jobs_mut() {
            job.timeline.dispatch = Some(t);
        }
    }
}

/// One queue bucket's live state (shape label, waiting jobs, oldest-job
/// wait, lane width) — snapshotted per scheduler round for the stats
/// `buckets` array.
#[derive(Clone, Debug)]
pub struct BucketStat {
    /// Shape label (`WxHxL`) for lane buckets, or the singles-lane class
    /// (`a2-singles`, `m1-singles`, `accel-singles`).
    pub shape: String,
    /// Jobs waiting in this bucket right now.
    pub depth: usize,
    /// How long the oldest waiting job has been queued (µs; 0 if empty).
    pub oldest_age_us: u64,
    /// Lane width this bucket dispatches at.
    pub lanes: usize,
}

/// Shape-bucketed job queue with deadline-bounded lane packing.
pub struct Batcher {
    width: usize,
    deadline: Duration,
    buckets: BTreeMap<ShapeKey, VecDeque<PendingJob>>,
    /// Jobs whose sampler pins the scalar path (`rung: a2`): they skip
    /// lane-packing and dispatch as singles on the next poll.
    scalar_lane: VecDeque<PendingJob>,
    /// Jobs whose sampler pins the multi-spin path (`rung: m1`): also
    /// singles — their 64 lanes are the job's own layer bits, so there
    /// is nothing to pack across jobs.
    multispin_lane: VecDeque<PendingJob>,
    /// Jobs whose sampler pins an accel rung (`rung: b1`/`b2`): also
    /// singles — the software device's 32-thread warps sweep spins of
    /// the job's own model.
    accel_lane: VecDeque<PendingJob>,
    next_seq: u64,
    queued: usize,
}

impl Batcher {
    /// `width` lanes per batch (the C-rung `W`), `deadline` the maximum
    /// time a job may wait for lane-mates before its bucket flushes.
    pub fn new(width: usize, deadline: Duration) -> Self {
        assert!(width >= 2, "lane-batching needs at least 2 lanes");
        Self {
            width,
            deadline,
            buckets: BTreeMap::new(),
            scalar_lane: VecDeque::new(),
            multispin_lane: VecDeque::new(),
            accel_lane: VecDeque::new(),
            next_seq: 0,
            queued: 0,
        }
    }

    pub fn width(&self) -> usize {
        self.width
    }

    /// Jobs currently waiting for dispatch.
    pub fn queued(&self) -> usize {
        self.queued
    }

    /// Admit a job; returns its sequence number.  Jobs that pin the
    /// scalar sampler bypass the shape buckets entirely.
    pub fn push(&mut self, spec: JobSpec, reply: Option<Sender<String>>, now: Instant) -> u64 {
        self.push_timed(spec, reply, now, now)
    }

    /// Like [`Self::push`], with a distinct admission-gate stamp for the
    /// job's timeline (`admit` ≤ `now`): the engine passes the instant
    /// the connection thread reserved the job's slot, so `admit_us`
    /// measures the channel hand-off to the scheduler.
    pub fn push_timed(
        &mut self,
        spec: JobSpec,
        reply: Option<Sender<String>>,
        admit: Instant,
        now: Instant,
    ) -> u64 {
        let seq = self.next_seq;
        self.next_seq += 1;
        let job =
            PendingJob { spec, reply, enqueued: now, seq, timeline: Timeline::new(admit, now) };
        if job.spec.wants_scalar() {
            self.scalar_lane.push_back(job);
        } else if job.spec.wants_multispin() {
            self.multispin_lane.push_back(job);
        } else if job.spec.wants_accel() {
            self.accel_lane.push_back(job);
        } else {
            self.buckets.entry(job.spec.shape()).or_default().push_back(job);
        }
        self.queued += 1;
        seq
    }

    /// Remove and return every dispatch ready at `now`: full batches
    /// always; a non-empty bucket whose oldest job has waited at least
    /// the deadline flushes what it has.
    pub fn poll(&mut self, now: Instant) -> Vec<Dispatch> {
        let deadline = self.deadline;
        self.collect_ready(now, |oldest| now.saturating_duration_since(oldest) >= deadline)
    }

    /// Flush everything regardless of deadline (drain on shutdown).
    pub fn drain(&mut self) -> Vec<Dispatch> {
        self.collect_ready(Instant::now(), |_| true)
    }

    /// Per-bucket queue state at `now` — the observable behind the
    /// stats `buckets` array (per-shape backpressure, the signal a
    /// shard router needs beyond the global `queue_depth`).  The pinned
    /// singles lanes report under their rung-class labels at their
    /// fixed widths; shape buckets report at the batch width.
    pub fn bucket_stats(&self, now: Instant) -> Vec<BucketStat> {
        let age = |q: &VecDeque<PendingJob>| {
            q.front()
                .map(|job| now.saturating_duration_since(job.enqueued).as_micros() as u64)
                .unwrap_or(0)
        };
        let mut out = Vec::new();
        for (shape, q) in &self.buckets {
            out.push(BucketStat {
                shape: shape.to_string(),
                depth: q.len(),
                oldest_age_us: age(q),
                lanes: self.width,
            });
        }
        let singles: [(&str, &VecDeque<PendingJob>, usize); 3] = [
            ("a2-singles", &self.scalar_lane, 1),
            ("m1-singles", &self.multispin_lane, 64),
            ("accel-singles", &self.accel_lane, 32),
        ];
        for (label, q, lanes) in singles {
            if !q.is_empty() {
                out.push(BucketStat {
                    shape: label.to_string(),
                    depth: q.len(),
                    oldest_age_us: age(q),
                    lanes,
                });
            }
        }
        out
    }

    /// Earliest pending flush deadline — the scheduler's sleep bound.  A
    /// queued scalar- or multispin-pinned job is due immediately (its
    /// admission time).
    pub fn next_deadline(&self) -> Option<Instant> {
        let single = [self.scalar_lane.front(), self.multispin_lane.front(), self.accel_lane.front()]
            .into_iter()
            .flatten()
            .map(|job| job.enqueued)
            .min();
        let bucket = self
            .buckets
            .values()
            .filter_map(|q| q.front().map(|job| job.enqueued + self.deadline))
            .min();
        match (single, bucket) {
            (Some(s), Some(b)) => Some(s.min(b)),
            (s, b) => s.or(b),
        }
    }

    fn collect_ready<F: Fn(Instant) -> bool>(&mut self, now: Instant, flush: F) -> Vec<Dispatch> {
        let width = self.width;
        let mut out = Vec::new();
        // Scalar-, multispin- and accel-pinned jobs dispatch
        // immediately, ahead of any deadline — all are singles by
        // construction, not deadline flushes.
        out.extend(self.scalar_lane.drain(..).map(|job| Dispatch::single(job, false)));
        out.extend(self.multispin_lane.drain(..).map(|job| Dispatch::single(job, false)));
        out.extend(self.accel_lane.drain(..).map(|job| Dispatch::single(job, false)));
        for queue in self.buckets.values_mut() {
            while queue.len() >= width {
                out.push(Dispatch::batch(queue.drain(..width).collect(), false));
            }
            if !queue.is_empty() && flush(queue.front().unwrap().enqueued) {
                // A lone job falls back to the scalar path — unless its
                // sampler pins the C-rung, in which case it dispatches as
                // a padded one-lane batch (the pin is a contract, not a
                // hint).  Either way the deadline, not width, forced it.
                if queue.len() == 1 && !queue.front().unwrap().spec.pins_batch() {
                    out.push(Dispatch::single(queue.pop_front().unwrap(), true));
                } else {
                    out.push(Dispatch::batch(queue.drain(..).collect(), true));
                }
            }
        }
        self.buckets.retain(|_, queue| !queue.is_empty());
        for dispatch in &mut out {
            dispatch.stamp_sealed(now);
            // Saturating: `queued` is also surfaced as the queue-depth
            // gauge, where a transient accounting bug must never wrap
            // to u64::MAX-ish depths.
            self.queued = self.queued.saturating_sub(dispatch.occupancy());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(id: &str, width: usize, layers: usize) -> JobSpec {
        JobSpec {
            id: id.to_string(),
            width,
            height: 4,
            layers,
            model_seed: 1,
            jtau: 0.3,
            sweeps: 10,
            beta: 0.8,
            seed: 1,
            trace_every: 0,
            want_state: false,
            want_timing: false,
            sampler: None,
        }
    }

    #[test]
    fn dispatched_jobs_carry_sealed_timelines() {
        let mut b = Batcher::new(4, Duration::from_secs(3600));
        let admit = Instant::now();
        let now = admit + Duration::from_micros(50);
        for i in 0..4 {
            b.push_timed(spec(&format!("j{i}"), 4, 8), None, admit, now);
        }
        let seal_at = now + Duration::from_millis(2);
        let ds = b.poll(seal_at);
        assert_eq!(ds.len(), 1);
        for job in ds.into_iter().next().unwrap().into_jobs() {
            assert_eq!(job.timeline.admit, admit);
            assert_eq!(job.timeline.enqueue, now);
            assert_eq!(job.timeline.seal, Some(seal_at));
            assert!(job.timeline.dispatch.is_none(), "pool pickup not stamped yet");
        }
    }

    #[test]
    fn full_buckets_dispatch_immediately() {
        let mut b = Batcher::new(4, Duration::from_secs(3600));
        let now = Instant::now();
        for i in 0..9 {
            b.push(spec(&format!("j{i}"), 4, 8), None, now);
        }
        let ds = b.poll(now);
        assert_eq!(ds.len(), 2, "two full batches, one straggler stays");
        assert!(ds.iter().all(|d| d.occupancy() == 4 && d.is_batch()));
        assert!(ds.iter().all(|d| !d.deadline_forced), "full batches are not deadline flushes");
        assert_eq!(b.queued(), 1);
        assert!(b.next_deadline().is_some());
    }

    #[test]
    fn scalar_pinned_jobs_bypass_lane_packing() {
        use crate::engine::{Rung, SamplerSpec};
        let mut b = Batcher::new(4, Duration::from_secs(3600));
        let now = Instant::now();
        // 3 batchable jobs of one shape + 1 scalar-pinned job of the SAME
        // shape: the pinned job must dispatch as a single immediately,
        // never counting toward the bucket.
        for i in 0..3 {
            b.push(spec(&format!("j{i}"), 4, 8), None, now);
        }
        let mut pinned = spec("scalar", 4, 8);
        pinned.sampler = Some(SamplerSpec::rung(Rung::A2));
        b.push(pinned, None, now);
        assert!(b.next_deadline().unwrap() <= now, "pinned job is due immediately");
        let ds = b.poll(now);
        assert_eq!(ds.len(), 1, "only the pinned single is ready: {}", ds.len());
        assert!(!ds[0].is_batch());
        assert!(!ds[0].deadline_forced, "a pinned single dispatches by design, not deadline");
        assert_eq!(b.queued(), 3, "the bucket still waits for a 4th lane-mate");
    }

    #[test]
    fn multispin_pinned_jobs_dispatch_as_singles_immediately() {
        use crate::engine::{Rung, SamplerSpec};
        let mut b = Batcher::new(4, Duration::from_secs(3600));
        let now = Instant::now();
        // 3 batchable jobs of one shape + 1 m1-pinned job of the SAME
        // shape: the pinned job never counts toward the bucket.
        for i in 0..3 {
            b.push(spec(&format!("j{i}"), 4, 8), None, now);
        }
        let mut pinned = spec("multispin", 4, 8);
        pinned.sampler = Some(SamplerSpec::rung(Rung::M1));
        b.push(pinned, None, now);
        assert!(b.next_deadline().unwrap() <= now, "pinned job is due immediately");
        let ds = b.poll(now);
        assert_eq!(ds.len(), 1, "only the m1 single is ready");
        assert!(!ds[0].is_batch());
        assert!(!ds[0].deadline_forced, "an m1 single dispatches by design, not deadline");
        assert_eq!(b.queued(), 3, "the bucket still waits for a 4th lane-mate");
    }

    #[test]
    fn accel_pinned_jobs_dispatch_as_singles_immediately() {
        use crate::engine::{Rung, SamplerSpec};
        let mut b = Batcher::new(4, Duration::from_secs(3600));
        let now = Instant::now();
        // 3 batchable jobs of one shape + 1 b2-pinned job of the SAME
        // shape: the pinned job never counts toward the bucket.
        for i in 0..3 {
            b.push(spec(&format!("j{i}"), 4, 8), None, now);
        }
        let mut pinned = spec("accel", 4, 8);
        pinned.sampler = Some(SamplerSpec::rung(Rung::B2));
        b.push(pinned, None, now);
        assert!(b.next_deadline().unwrap() <= now, "pinned job is due immediately");
        let ds = b.poll(now);
        assert_eq!(ds.len(), 1, "only the accel single is ready");
        assert!(!ds[0].is_batch());
        assert!(!ds[0].deadline_forced, "an accel single dispatches by design, not deadline");
        assert_eq!(b.queued(), 3, "the bucket still waits for a 4th lane-mate");
    }

    #[test]
    fn lone_c1_pinned_job_flushes_as_padded_batch_not_scalar() {
        use crate::engine::{Rung, SamplerSpec};
        let mut b = Batcher::new(4, Duration::from_millis(10));
        let now = Instant::now();
        let mut pinned = spec("pin", 4, 8);
        pinned.sampler = Some(SamplerSpec::rung(Rung::C1));
        b.push(pinned, None, now);
        let ds = b.poll(now + Duration::from_millis(20));
        assert_eq!(ds.len(), 1);
        assert!(ds[0].is_batch(), "a c1 pin must never degrade to the scalar path");
        assert_eq!(ds[0].occupancy(), 1, "one real lane, padding added at execution");
        assert!(ds[0].deadline_forced, "the deadline, not width, flushed this batch");
    }

    #[test]
    fn bucket_stats_report_depth_age_and_width() {
        use crate::engine::{Rung, SamplerSpec};
        let mut b = Batcher::new(4, Duration::from_secs(3600));
        let t0 = Instant::now();
        b.push(spec("a", 4, 8), None, t0);
        b.push(spec("b", 4, 8), None, t0 + Duration::from_millis(5));
        b.push(spec("c", 4, 2), None, t0 + Duration::from_millis(5));
        let mut pinned = spec("m", 4, 8);
        pinned.sampler = Some(SamplerSpec::rung(Rung::M1));
        b.push(pinned, None, t0 + Duration::from_millis(5));
        let stats = b.bucket_stats(t0 + Duration::from_millis(10));
        let by_shape: std::collections::BTreeMap<_, _> =
            stats.iter().map(|s| (s.shape.clone(), s)).collect();
        let deep = by_shape["4x4x8"];
        assert_eq!(deep.depth, 2);
        assert_eq!(deep.lanes, 4);
        assert!(deep.oldest_age_us >= 10_000, "age counts from the oldest job: {deep:?}");
        assert_eq!(by_shape["4x4x2"].depth, 1);
        let m1 = by_shape["m1-singles"];
        assert_eq!((m1.depth, m1.lanes), (1, 64));
        assert!(!by_shape.contains_key("a2-singles"), "empty singles lanes are omitted");
    }

    #[test]
    fn drain_flushes_everything() {
        let mut b = Batcher::new(4, Duration::from_secs(3600));
        let now = Instant::now();
        b.push(spec("a", 4, 8), None, now);
        b.push(spec("b", 4, 2), None, now);
        b.push(spec("c", 4, 2), None, now);
        let ds = b.drain();
        assert_eq!(ds.len(), 2);
        assert_eq!(b.queued(), 0);
        assert!(b.next_deadline().is_none());
        let occ: usize = ds.iter().map(|d| d.occupancy()).sum();
        assert_eq!(occ, 3);
    }
}
