//! Service-level counters — the serving analogue of the paper's
//! "fraction of vector width utilized".
//!
//! All counters are atomics: connection threads and pool workers update
//! them concurrently, `{"op":"stats"}` snapshots them lock-free.  The
//! headline figure is the **lane-fill ratio**: of all SIMD lanes the
//! service dispatched in batches, the fraction that carried a real job
//! (the rest were deadline-flush padding).

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::json;

/// Cumulative counters of one running service.
#[derive(Default)]
pub struct ServiceMetrics {
    /// Jobs admitted into the batcher.
    pub jobs_submitted: AtomicU64,
    /// Jobs answered with an `"ok"` result.
    pub jobs_completed: AtomicU64,
    /// Jobs answered with an `"error"` result after dispatch.
    pub jobs_failed: AtomicU64,
    /// Request lines rejected at admission (parse/validation).
    pub jobs_rejected: AtomicU64,
    /// Lane-batch dispatches (full or padded).
    pub batches_dispatched: AtomicU64,
    /// Scalar-fallback dispatches (lone jobs).
    pub singles_dispatched: AtomicU64,
    /// Dispatches forced by the flush deadline (padded batches + singles).
    pub deadline_flushes: AtomicU64,
    /// Lanes that carried a real job, summed over batch dispatches.
    pub lanes_occupied: AtomicU64,
    /// Padding lanes, summed over batch dispatches.
    pub lanes_padded: AtomicU64,
    /// Jobs waiting in the batcher right now.
    pub queue_depth: AtomicU64,
    /// High-water mark of `queue_depth`.
    pub max_queue_depth: AtomicU64,
}

impl ServiceMetrics {
    /// Account one dispatch of `occupancy` jobs at lane width `width`.
    pub fn record_dispatch(&self, occupancy: usize, width: usize, is_batch: bool) {
        if is_batch {
            self.batches_dispatched.fetch_add(1, Ordering::Relaxed);
            self.lanes_occupied.fetch_add(occupancy as u64, Ordering::Relaxed);
            self.lanes_padded.fetch_add((width - occupancy) as u64, Ordering::Relaxed);
            if occupancy < width {
                self.deadline_flushes.fetch_add(1, Ordering::Relaxed);
            }
        } else {
            self.singles_dispatched.fetch_add(1, Ordering::Relaxed);
            self.deadline_flushes.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Update the live queue depth (and its high-water mark).
    pub fn set_queue_depth(&self, depth: usize) {
        self.queue_depth.store(depth as u64, Ordering::Relaxed);
        self.max_queue_depth.fetch_max(depth as u64, Ordering::Relaxed);
    }

    /// Fraction of dispatched batch lanes that carried a real job
    /// (1.0 before any batch has been dispatched).
    pub fn lane_fill_ratio(&self) -> f64 {
        let occupied = self.lanes_occupied.load(Ordering::Relaxed) as f64;
        let padded = self.lanes_padded.load(Ordering::Relaxed) as f64;
        if occupied + padded == 0.0 {
            1.0
        } else {
            occupied / (occupied + padded)
        }
    }

    /// Snapshot as a `{"op":"stats", ...}` line.
    pub fn snapshot_json(&self) -> String {
        let get = |a: &AtomicU64| json::num(a.load(Ordering::Relaxed) as f64);
        json::obj(vec![
            ("protocol_version", json::num(super::job::PROTOCOL_VERSION as f64)),
            ("op", json::str_v("stats")),
            ("jobs_submitted", get(&self.jobs_submitted)),
            ("jobs_completed", get(&self.jobs_completed)),
            ("jobs_failed", get(&self.jobs_failed)),
            ("jobs_rejected", get(&self.jobs_rejected)),
            ("batches_dispatched", get(&self.batches_dispatched)),
            ("singles_dispatched", get(&self.singles_dispatched)),
            ("deadline_flushes", get(&self.deadline_flushes)),
            ("lanes_occupied", get(&self.lanes_occupied)),
            ("lanes_padded", get(&self.lanes_padded)),
            ("lane_fill_ratio", json::num(self.lane_fill_ratio())),
            ("queue_depth", get(&self.queue_depth)),
            ("max_queue_depth", get(&self.max_queue_depth)),
        ])
        .to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Value;

    #[test]
    fn lane_fill_tracks_dispatches() {
        let m = ServiceMetrics::default();
        assert_eq!(m.lane_fill_ratio(), 1.0, "vacuously full before any batch");
        m.record_dispatch(4, 4, true); // full batch
        assert_eq!(m.lane_fill_ratio(), 1.0);
        m.record_dispatch(2, 4, true); // padded flush
        assert!((m.lane_fill_ratio() - 0.75).abs() < 1e-12);
        m.record_dispatch(1, 4, false); // scalar fallback: no lanes counted
        assert!((m.lane_fill_ratio() - 0.75).abs() < 1e-12);
        assert_eq!(m.deadline_flushes.load(Ordering::Relaxed), 2);
        assert_eq!(m.singles_dispatched.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn snapshot_is_parseable_json() {
        let m = ServiceMetrics::default();
        m.record_dispatch(4, 4, true);
        m.set_queue_depth(7);
        m.set_queue_depth(3);
        let v = Value::parse(&m.snapshot_json()).unwrap();
        assert_eq!(v.get("op").unwrap().as_str().unwrap(), "stats");
        assert_eq!(v.get("queue_depth").unwrap().as_usize().unwrap(), 3);
        assert_eq!(v.get("max_queue_depth").unwrap().as_usize().unwrap(), 7);
        assert_eq!(v.get("lane_fill_ratio").unwrap().as_f64().unwrap(), 1.0);
    }
}
