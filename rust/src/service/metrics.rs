//! Service-level counters — the serving analogue of the paper's
//! "fraction of vector width utilized".
//!
//! All counters are atomics: connection threads and pool workers update
//! them concurrently, `{"op":"stats"}` snapshots them lock-free.  The
//! headline figure is the **lane-fill ratio**: of all SIMD lanes the
//! service dispatched in batches, the fraction that carried a real job
//! (the rest were deadline-flush padding).

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::json;

/// Cumulative counters of one running service.
#[derive(Default)]
pub struct ServiceMetrics {
    /// Jobs admitted into the batcher.
    pub jobs_submitted: AtomicU64,
    /// Jobs answered with an `"ok"` result.
    pub jobs_completed: AtomicU64,
    /// Jobs answered with an `"error"` result after dispatch.
    pub jobs_failed: AtomicU64,
    /// Request lines rejected at admission (parse/validation).
    pub jobs_rejected: AtomicU64,
    /// Lane-batch dispatches (full or padded).
    pub batches_dispatched: AtomicU64,
    /// Scalar-fallback dispatches (lone jobs).
    pub singles_dispatched: AtomicU64,
    /// Dispatches forced by the flush deadline (padded batches + singles).
    pub deadline_flushes: AtomicU64,
    /// Lanes that carried a real job, summed over batch dispatches.
    pub lanes_occupied: AtomicU64,
    /// Padding lanes, summed over batch dispatches.
    pub lanes_padded: AtomicU64,
    /// Jobs waiting in the batcher right now.
    pub queue_depth: AtomicU64,
    /// High-water mark of `queue_depth`.
    pub max_queue_depth: AtomicU64,
    /// `{"op":"run"}` jobs executed to completion (ok or error).
    pub runs_executed: AtomicU64,
    /// Jobs refused at admission because the queue cap was hit.
    pub jobs_overloaded: AtomicU64,
    /// Jobs admitted but not yet answered (queued + executing) — the
    /// gauge the admission cap compares against.
    pub jobs_in_system: AtomicU64,
    /// Dispatch rounds handed to the pool and not yet completed.
    pub dispatches_in_flight: AtomicU64,
}

impl ServiceMetrics {
    /// Account one dispatch of `occupancy` jobs at lane width `width`.
    /// `deadline_forced` is the batcher's verdict on *why* the dispatch
    /// left the queue — a2-/m1-pinned singles and full-width batches
    /// dispatch by design and must not count as deadline flushes.
    pub fn record_dispatch(
        &self,
        occupancy: usize,
        width: usize,
        is_batch: bool,
        deadline_forced: bool,
    ) {
        if is_batch {
            self.batches_dispatched.fetch_add(1, Ordering::Relaxed);
            self.lanes_occupied.fetch_add(occupancy as u64, Ordering::Relaxed);
            self.lanes_padded.fetch_add((width - occupancy) as u64, Ordering::Relaxed);
        } else {
            self.singles_dispatched.fetch_add(1, Ordering::Relaxed);
        }
        if deadline_forced {
            self.deadline_flushes.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Update the live queue depth (and its high-water mark).
    pub fn set_queue_depth(&self, depth: usize) {
        self.queue_depth.store(depth as u64, Ordering::Relaxed);
        self.max_queue_depth.fetch_max(depth as u64, Ordering::Relaxed);
    }

    /// Fraction of dispatched batch lanes that carried a real job
    /// (1.0 before any batch has been dispatched).
    pub fn lane_fill_ratio(&self) -> f64 {
        let occupied = self.lanes_occupied.load(Ordering::Relaxed) as f64;
        let padded = self.lanes_padded.load(Ordering::Relaxed) as f64;
        if occupied + padded == 0.0 {
            1.0
        } else {
            occupied / (occupied + padded)
        }
    }

    /// Snapshot as a `{"op":"stats", ...}` line.
    pub fn snapshot_json(&self) -> String {
        let get = |a: &AtomicU64| json::num(a.load(Ordering::Relaxed) as f64);
        json::obj(vec![
            ("protocol_version", json::num(super::job::PROTOCOL_VERSION as f64)),
            ("op", json::str_v("stats")),
            ("jobs_submitted", get(&self.jobs_submitted)),
            ("jobs_completed", get(&self.jobs_completed)),
            ("jobs_failed", get(&self.jobs_failed)),
            ("jobs_rejected", get(&self.jobs_rejected)),
            ("batches_dispatched", get(&self.batches_dispatched)),
            ("singles_dispatched", get(&self.singles_dispatched)),
            ("deadline_flushes", get(&self.deadline_flushes)),
            ("lanes_occupied", get(&self.lanes_occupied)),
            ("lanes_padded", get(&self.lanes_padded)),
            ("lane_fill_ratio", json::num(self.lane_fill_ratio())),
            ("queue_depth", get(&self.queue_depth)),
            ("max_queue_depth", get(&self.max_queue_depth)),
            // Appended fields (protocol back-compat: readers of the
            // original stats line ignore unknown trailing keys).
            ("runs_executed", get(&self.runs_executed)),
            ("jobs_overloaded", get(&self.jobs_overloaded)),
            ("jobs_in_system", get(&self.jobs_in_system)),
            ("dispatches_in_flight", get(&self.dispatches_in_flight)),
        ])
        .to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Value;

    #[test]
    fn lane_fill_tracks_dispatches() {
        let m = ServiceMetrics::default();
        assert_eq!(m.lane_fill_ratio(), 1.0, "vacuously full before any batch");
        m.record_dispatch(4, 4, true, false); // full batch
        assert_eq!(m.lane_fill_ratio(), 1.0);
        m.record_dispatch(2, 4, true, true); // padded deadline flush
        assert!((m.lane_fill_ratio() - 0.75).abs() < 1e-12);
        m.record_dispatch(1, 4, false, true); // lone-job fallback: no lanes counted
        assert!((m.lane_fill_ratio() - 0.75).abs() < 1e-12);
        assert_eq!(m.deadline_flushes.load(Ordering::Relaxed), 2);
        assert_eq!(m.singles_dispatched.load(Ordering::Relaxed), 1);
    }

    /// Regression: an a2-/m1-pinned single dispatches immediately by
    /// design — it must not inflate `deadline_flushes`, the control
    /// signal for w8 → w4 bucket retargeting.
    #[test]
    fn pinned_singles_are_not_deadline_flushes() {
        let m = ServiceMetrics::default();
        m.record_dispatch(1, 4, false, false); // pinned single
        m.record_dispatch(1, 4, true, true); // c1-pinned lone-job flush
        assert_eq!(m.singles_dispatched.load(Ordering::Relaxed), 1);
        assert_eq!(m.deadline_flushes.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn snapshot_is_parseable_json() {
        let m = ServiceMetrics::default();
        m.record_dispatch(4, 4, true, false);
        m.set_queue_depth(7);
        m.set_queue_depth(3);
        m.runs_executed.fetch_add(2, Ordering::Relaxed);
        m.jobs_overloaded.fetch_add(1, Ordering::Relaxed);
        let v = Value::parse(&m.snapshot_json()).unwrap();
        assert_eq!(v.get("op").unwrap().as_str().unwrap(), "stats");
        assert_eq!(v.get("queue_depth").unwrap().as_usize().unwrap(), 3);
        assert_eq!(v.get("max_queue_depth").unwrap().as_usize().unwrap(), 7);
        assert_eq!(v.get("lane_fill_ratio").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(v.get("runs_executed").unwrap().as_usize().unwrap(), 2);
        assert_eq!(v.get("jobs_overloaded").unwrap().as_usize().unwrap(), 1);
        assert_eq!(v.get("jobs_in_system").unwrap().as_usize().unwrap(), 0);
        assert_eq!(v.get("dispatches_in_flight").unwrap().as_usize().unwrap(), 0);
    }
}
