//! Service-level counters — the serving analogue of the paper's
//! "fraction of vector width utilized".
//!
//! All counters are atomics: connection threads and pool workers update
//! them concurrently, `{"op":"stats"}` snapshots them lock-free.  The
//! headline figure is the **lane-fill ratio**: of all SIMD lanes the
//! service dispatched in batches, the fraction that carried a real job
//! (the rest were deadline-flush padding).
//!
//! Beyond the lifetime counters, [`ServiceMetrics`] owns one
//! [`Obs`] instance: latency/lane-fill histograms, the recent-trace
//! ring, and windowed rates.  Three wire surfaces read it:
//!
//! * `{"op":"stats"}` — counters plus p50/p90/p99 latency summaries,
//! * `{"op":"metrics"}` — Prometheus text exposition,
//! * `{"op":"trace"}` — the last N completed-job stage timings.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::harness::bench::{self, HostCaps};
use crate::obs::prometheus::PromWriter;
use crate::obs::{phase, HistogramSnapshot, Obs, RateWindow};
use crate::util::json::{self, Value};

use super::batcher::BucketStat;

/// Rungs a service instance can execute, in ladder order (the CLI
/// spellings a `{"op":"hello"}` reply advertises): the scalar A.2
/// reference, the lane-batched C.1 family, bit-packed multi-spin M.1
/// and the software-device B-rungs.
pub const SERVED_RUNGS: [&str; 5] = ["a2", "c1", "m1", "b1", "b2"];

/// Serving backends, in metric-label order: the scalar A.2 reference,
/// the lane-batched SIMD C-rungs, the bit-packed multi-spin path and
/// the software-device accel rungs.
pub const BACKEND_LABELS: [&str; 4] = ["scalar", "simd", "multispin", "accel"];

/// Index into the per-backend counter arrays for a result's rung label
/// (`"A.2"`, `"C.1w8"`, `"M.1"`, `"B.2"`, ...).  Unknown labels count
/// as scalar, the fallback path.
pub fn backend_index(kind: &str) -> usize {
    if kind.starts_with("C.") {
        1
    } else if kind.starts_with("M.") {
        2
    } else if kind.starts_with("B.") {
        3
    } else {
        0
    }
}

/// Cumulative counters of one running service.
#[derive(Default)]
pub struct ServiceMetrics {
    /// Jobs admitted into the batcher.
    pub jobs_submitted: AtomicU64,
    /// Jobs answered with an `"ok"` result.
    pub jobs_completed: AtomicU64,
    /// Jobs answered with an `"error"` result after dispatch.
    pub jobs_failed: AtomicU64,
    /// Request lines rejected at admission (parse/validation).
    pub jobs_rejected: AtomicU64,
    /// Lane-batch dispatches (full or padded).
    pub batches_dispatched: AtomicU64,
    /// Scalar-fallback dispatches (lone jobs).
    pub singles_dispatched: AtomicU64,
    /// Dispatches forced by the flush deadline (padded batches + singles).
    pub deadline_flushes: AtomicU64,
    /// Lanes that carried a real job, summed over batch dispatches.
    pub lanes_occupied: AtomicU64,
    /// Padding lanes, summed over batch dispatches.
    pub lanes_padded: AtomicU64,
    /// Jobs waiting in the batcher right now.
    pub queue_depth: AtomicU64,
    /// High-water mark of `queue_depth`.
    pub max_queue_depth: AtomicU64,
    /// `{"op":"run"}` jobs executed to completion (ok or error).
    pub runs_executed: AtomicU64,
    /// Jobs refused at admission because the queue cap was hit.
    pub jobs_overloaded: AtomicU64,
    /// Jobs admitted but not yet answered (queued + executing) — the
    /// gauge the admission cap compares against.
    pub jobs_in_system: AtomicU64,
    /// Dispatch rounds handed to the pool and not yet completed.
    pub dispatches_in_flight: AtomicU64,
    /// Jobs answered ok, by serving backend (index: [`BACKEND_LABELS`]).
    pub jobs_completed_backend: [AtomicU64; 4],
    /// Spin updates attempted by completed jobs, by serving backend.
    pub spins_backend: [AtomicU64; 4],
    /// Per-shape queue buckets, published by the scheduler each round
    /// (stats-only read path; one short lock per round / per scrape).
    pub bucket_stats: Mutex<Vec<BucketStat>>,
    /// Histograms, traces and rates for this instance.
    pub obs: Obs,
}

/// One coherent read of every counter.  `snapshot_json` and
/// `prometheus_text` load each atomic exactly once through this struct,
/// so derived figures (lane-fill ratio) and their inputs (occupied /
/// padded) always agree within one emission — reading the atomics twice
/// can tear against a concurrent dispatch.
#[derive(Clone, Copy, Debug, Default)]
pub struct StatsSnapshot {
    pub jobs_submitted: u64,
    pub jobs_completed: u64,
    pub jobs_failed: u64,
    pub jobs_rejected: u64,
    pub batches_dispatched: u64,
    pub singles_dispatched: u64,
    pub deadline_flushes: u64,
    pub lanes_occupied: u64,
    pub lanes_padded: u64,
    pub queue_depth: u64,
    pub max_queue_depth: u64,
    pub runs_executed: u64,
    pub jobs_overloaded: u64,
    pub jobs_in_system: u64,
    pub dispatches_in_flight: u64,
}

impl StatsSnapshot {
    /// Fraction of dispatched batch lanes that carried a real job
    /// (1.0 before any batch has been dispatched).
    pub fn lane_fill_ratio(&self) -> f64 {
        let occupied = self.lanes_occupied as f64;
        let padded = self.lanes_padded as f64;
        if occupied + padded == 0.0 {
            1.0
        } else {
            occupied / (occupied + padded)
        }
    }
}

impl ServiceMetrics {
    /// Account one dispatch of `occupancy` jobs at lane width `width`.
    /// `deadline_forced` is the batcher's verdict on *why* the dispatch
    /// left the queue — a2-/m1-pinned singles and full-width batches
    /// dispatch by design and must not count as deadline flushes.
    pub fn record_dispatch(
        &self,
        occupancy: usize,
        width: usize,
        is_batch: bool,
        deadline_forced: bool,
    ) {
        if is_batch {
            self.batches_dispatched.fetch_add(1, Ordering::Relaxed);
            self.lanes_occupied.fetch_add(occupancy as u64, Ordering::Relaxed);
            self.lanes_padded.fetch_add((width - occupancy) as u64, Ordering::Relaxed);
        } else {
            self.singles_dispatched.fetch_add(1, Ordering::Relaxed);
        }
        if deadline_forced {
            self.deadline_flushes.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Account one completed job against its serving backend (`kind` is
    /// the result's rung label, e.g. `"C.1w8"` or `"B.2"`).
    pub fn record_backend(&self, kind: &str, spins: u64) {
        let i = backend_index(kind);
        self.jobs_completed_backend[i].fetch_add(1, Ordering::Relaxed);
        self.spins_backend[i].fetch_add(spins, Ordering::Relaxed);
    }

    /// Update the live queue depth (and its high-water mark).
    pub fn set_queue_depth(&self, depth: usize) {
        self.queue_depth.store(depth as u64, Ordering::Relaxed);
        self.max_queue_depth.fetch_max(depth as u64, Ordering::Relaxed);
    }

    /// Publish the scheduler's per-bucket queue snapshot (overwrites the
    /// previous round's).
    pub fn set_bucket_stats(&self, stats: Vec<BucketStat>) {
        match self.bucket_stats.lock() {
            Ok(mut g) => *g = stats,
            Err(poisoned) => *poisoned.into_inner() = stats,
        }
    }

    /// Decrement the in-system gauge without risking u64 wrap: a settle
    /// racing a concurrent reset (or a bookkeeping bug) must saturate at
    /// zero, not jump to 2^64-1 and wedge admission forever.
    pub fn dec_jobs_in_system(&self, n: u64) {
        let mut cur = self.jobs_in_system.load(Ordering::Acquire);
        loop {
            let next = cur.saturating_sub(n);
            match self.jobs_in_system.compare_exchange_weak(
                cur,
                next,
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Load every counter once.
    pub fn snapshot(&self) -> StatsSnapshot {
        let get = |a: &AtomicU64| a.load(Ordering::Relaxed);
        StatsSnapshot {
            jobs_submitted: get(&self.jobs_submitted),
            jobs_completed: get(&self.jobs_completed),
            jobs_failed: get(&self.jobs_failed),
            jobs_rejected: get(&self.jobs_rejected),
            batches_dispatched: get(&self.batches_dispatched),
            singles_dispatched: get(&self.singles_dispatched),
            deadline_flushes: get(&self.deadline_flushes),
            lanes_occupied: get(&self.lanes_occupied),
            lanes_padded: get(&self.lanes_padded),
            queue_depth: get(&self.queue_depth),
            max_queue_depth: get(&self.max_queue_depth),
            runs_executed: get(&self.runs_executed),
            jobs_overloaded: get(&self.jobs_overloaded),
            jobs_in_system: get(&self.jobs_in_system),
            dispatches_in_flight: get(&self.dispatches_in_flight),
        }
    }

    /// Fraction of dispatched batch lanes that carried a real job
    /// (1.0 before any batch has been dispatched).
    pub fn lane_fill_ratio(&self) -> f64 {
        self.snapshot().lane_fill_ratio()
    }

    /// Snapshot as a `{"op":"stats", ...}` line.  Every field of the
    /// original line is preserved; new keys are appended only.
    pub fn snapshot_json(&self) -> String {
        let snap = self.snapshot();
        let num = |v: u64| json::num(v as f64);
        let mut fields = vec![
            ("protocol_version", json::num(super::job::PROTOCOL_VERSION as f64)),
            ("op", json::str_v("stats")),
            ("jobs_submitted", num(snap.jobs_submitted)),
            ("jobs_completed", num(snap.jobs_completed)),
            ("jobs_failed", num(snap.jobs_failed)),
            ("jobs_rejected", num(snap.jobs_rejected)),
            ("batches_dispatched", num(snap.batches_dispatched)),
            ("singles_dispatched", num(snap.singles_dispatched)),
            ("deadline_flushes", num(snap.deadline_flushes)),
            ("lanes_occupied", num(snap.lanes_occupied)),
            ("lanes_padded", num(snap.lanes_padded)),
            ("lane_fill_ratio", json::num(snap.lane_fill_ratio())),
            ("queue_depth", num(snap.queue_depth)),
            ("max_queue_depth", num(snap.max_queue_depth)),
            // Appended fields (protocol back-compat: readers of the
            // original stats line ignore unknown trailing keys).
            ("runs_executed", num(snap.runs_executed)),
            ("jobs_overloaded", num(snap.jobs_overloaded)),
            ("jobs_in_system", num(snap.jobs_in_system)),
            ("dispatches_in_flight", num(snap.dispatches_in_flight)),
            ("uptime_ms", num(self.obs.uptime_ms())),
            ("started_at_ms", num(self.obs.started_at_ms())),
            ("spins_attempted", num(self.obs.spins_attempted.load(Ordering::Relaxed))),
        ];
        if let Some(c) = self.obs.config() {
            fields.push((
                "config",
                json::obj(vec![
                    ("lanes", json::num(c.lanes as f64)),
                    ("flush_ms", json::num(c.flush_ms as f64)),
                    ("max_queue", json::num(c.max_queue as f64)),
                    ("threads", json::num(c.threads as f64)),
                    ("backend", json::str_v(&c.backend)),
                ]),
            ));
        }
        // Per-shape queue buckets: the signal a shard router needs
        // beyond the global queue_depth (which bucket is backed up, how
        // stale its head is, at what lane width it drains).
        let buckets = match self.bucket_stats.lock() {
            Ok(g) => g.clone(),
            Err(poisoned) => poisoned.into_inner().clone(),
        };
        fields.push((
            "buckets",
            Value::Arr(
                buckets
                    .iter()
                    .map(|b| {
                        json::obj(vec![
                            ("shape", json::str_v(&b.shape)),
                            ("depth", json::num(b.depth as f64)),
                            ("oldest_age_us", json::num(b.oldest_age_us as f64)),
                            ("lanes", json::num(b.lanes as f64)),
                        ])
                    })
                    .collect(),
            ),
        ));
        // Full sparse histograms alongside the p50/p90/p99 summaries:
        // a router merges these bucketwise (obs::HistogramSnapshot wire
        // form), so cluster percentiles are exact, not summary-of-summaries.
        fields.push((
            "latency_hist",
            json::obj(vec![
                ("queue_wait", self.obs.queue_wait_us.snapshot().to_value()),
                ("exec", self.obs.exec_us.snapshot().to_value()),
                ("e2e", self.obs.e2e_us.snapshot().to_value()),
                ("pool_task", self.obs.pool_task_us.snapshot().to_value()),
            ]),
        ));
        fields.push((
            "latency_us",
            json::obj(vec![
                ("queue_wait", latency_summary(&self.obs.queue_wait_us.snapshot())),
                ("exec", latency_summary(&self.obs.exec_us.snapshot())),
                ("e2e", latency_summary(&self.obs.e2e_us.snapshot())),
                ("pool_task", latency_summary(&self.obs.pool_task_us.snapshot())),
            ]),
        ));
        let now = Instant::now();
        fields.push((
            "rate",
            json::obj(vec![
                ("window_secs", json::num(RateWindow::WINDOW_SECS as f64)),
                (
                    "jobs_per_sec",
                    json::num(self.obs.jobs_rate.per_sec(RateWindow::WINDOW_SECS, now)),
                ),
                (
                    "spins_per_sec",
                    json::num(self.obs.spins_rate.per_sec(RateWindow::WINDOW_SECS, now)),
                ),
            ]),
        ));
        json::obj(fields).to_string()
    }

    /// `{"op":"trace"}` reply: the last `last` completed-job traces,
    /// oldest first.
    pub fn trace_line(&self, last: usize) -> String {
        let traces = self.obs.traces.recent(last);
        json::obj(vec![
            ("protocol_version", json::num(super::job::PROTOCOL_VERSION as f64)),
            ("op", json::str_v("trace")),
            ("traces_recorded", json::num(self.obs.traces.pushed() as f64)),
            ("count", json::num(traces.len() as f64)),
            ("traces", Value::Arr(traces.iter().map(|t| t.to_value()).collect())),
        ])
        .to_string()
    }

    /// `{"op":"hello"}` reply: the capability handshake.  Everything a
    /// client (or a shard router doing capability-aware placement)
    /// needs before submitting: protocol version, the host's CPU
    /// capability fingerprint, the rungs this service can execute, and
    /// the resolved serving config (backend, lane width, queue cap).
    pub fn hello_line(&self) -> String {
        let mut fields = vec![
            ("protocol_version", json::num(super::job::PROTOCOL_VERSION as f64)),
            ("op", json::str_v("hello")),
            ("host", json::str_v(&HostCaps::detect().fingerprint())),
            (
                "rungs",
                Value::Arr(SERVED_RUNGS.iter().map(|r| json::str_v(r)).collect()),
            ),
            ("started_at_ms", json::num(self.obs.started_at_ms() as f64)),
        ];
        if let Some(c) = self.obs.config() {
            fields.push(("backend", json::str_v(&c.backend)));
            fields.push(("lanes", json::num(c.lanes as f64)));
            fields.push(("max_queue", json::num(c.max_queue as f64)));
            fields.push(("flush_ms", json::num(c.flush_ms as f64)));
            fields.push(("threads", json::num(c.threads as f64)));
        }
        json::obj(fields).to_string()
    }

    /// `{"op":"metrics"}` reply: Prometheus text riding in a JSON line
    /// (the wire stays line-oriented; scrapers unwrap `"text"`).
    pub fn metrics_line(&self) -> String {
        json::obj(vec![
            ("protocol_version", json::num(super::job::PROTOCOL_VERSION as f64)),
            ("op", json::str_v("metrics")),
            ("content_type", json::str_v("text/plain; version=0.0.4")),
            ("text", json::str_v(&self.prometheus_text())),
        ])
        .to_string()
    }

    /// Prometheus text exposition of everything this instance measures.
    /// Every sample carries `host` (CPU capability fingerprint) and
    /// `sha` labels, so scrapes from a fleet of heterogeneous boxes stay
    /// attributable — the cross-host story of `harness::bench`.
    pub fn prometheus_text(&self) -> String {
        let snap = self.snapshot();
        let (host, sha) = build_labels();
        let mut w = PromWriter::new(&[("host", host), ("sha", sha)]);
        let counters: &[(&str, &str, u64)] = &[
            ("repro_jobs_submitted_total", "Jobs admitted into the batcher.", snap.jobs_submitted),
            ("repro_jobs_completed_total", "Jobs answered ok.", snap.jobs_completed),
            ("repro_jobs_failed_total", "Jobs answered with an error.", snap.jobs_failed),
            ("repro_jobs_rejected_total", "Lines rejected at admission.", snap.jobs_rejected),
            ("repro_jobs_overloaded_total", "Jobs refused at the queue cap.", snap.jobs_overloaded),
            ("repro_batches_dispatched_total", "Lane-batch dispatches.", snap.batches_dispatched),
            ("repro_singles_dispatched_total", "Scalar dispatches.", snap.singles_dispatched),
            ("repro_deadline_flushes_total", "Deadline-forced dispatches.", snap.deadline_flushes),
            ("repro_lanes_occupied_total", "Batch lanes with a real job.", snap.lanes_occupied),
            ("repro_lanes_padded_total", "Batch lanes dispatched as padding.", snap.lanes_padded),
            ("repro_runs_executed_total", "Spec-carrying run jobs executed.", snap.runs_executed),
            (
                "repro_spins_attempted_total",
                "Spin updates attempted by completed jobs.",
                self.obs.spins_attempted.load(Ordering::Relaxed),
            ),
        ];
        for &(name, help, value) in counters {
            w.counter(name, help, value);
        }
        let now = Instant::now();
        let gauges: &[(&str, &str, f64)] = &[
            ("repro_queue_depth", "Jobs waiting in the batcher.", snap.queue_depth as f64),
            (
                "repro_max_queue_depth",
                "High-water mark of the queue depth.",
                snap.max_queue_depth as f64,
            ),
            (
                "repro_jobs_in_system",
                "Jobs admitted but not yet answered.",
                snap.jobs_in_system as f64,
            ),
            (
                "repro_dispatches_in_flight",
                "Dispatch rounds executing on the pool.",
                snap.dispatches_in_flight as f64,
            ),
            (
                "repro_lane_fill_ratio",
                "Occupied fraction of dispatched batch lanes.",
                snap.lane_fill_ratio(),
            ),
            ("repro_uptime_seconds", "Seconds since serve start.", self.obs.uptime_ms() as f64 / 1e3),
            (
                "repro_jobs_per_sec",
                "Completed jobs per second (10 s window).",
                self.obs.jobs_rate.per_sec(RateWindow::WINDOW_SECS, now),
            ),
            (
                "repro_spins_per_sec",
                "Attempted spin updates per second (10 s window).",
                self.obs.spins_rate.per_sec(RateWindow::WINDOW_SECS, now),
            ),
        ];
        for &(name, help, value) in gauges {
            w.gauge(name, help, value);
        }
        w.histogram_seconds(
            "repro_queue_wait_seconds",
            "Enqueue to batch-seal wait.",
            &self.obs.queue_wait_us.snapshot(),
        );
        w.histogram_seconds(
            "repro_exec_seconds",
            "Sweep execution time.",
            &self.obs.exec_us.snapshot(),
        );
        w.histogram_seconds(
            "repro_e2e_seconds",
            "Admission to reply latency.",
            &self.obs.e2e_us.snapshot(),
        );
        w.histogram_seconds(
            "repro_pool_task_seconds",
            "Sweep-pool task wall time.",
            &self.obs.pool_task_us.snapshot(),
        );
        // Per-shape lane-occupancy distribution.  Label values must
        // outlive the borrow rows, so render them first.
        let fills = self.obs.fill.snapshot();
        let mut rows: Vec<(String, String, u64)> = Vec::new();
        for (shape, f) in &fills {
            for (k, &c) in f.counts.iter().enumerate() {
                if c > 0 {
                    rows.push((shape.clone(), k.to_string(), c));
                }
            }
        }
        if !rows.is_empty() {
            let samples: Vec<(Vec<(&str, &str)>, u64)> = rows
                .iter()
                .map(|(s, k, c)| (vec![("shape", s.as_str()), ("occupancy", k.as_str())], *c))
                .collect();
            w.counter_family(
                "repro_lane_occupancy_total",
                "Batch dispatches by shape and occupied-lane count.",
                &samples,
            );
        }
        // Per-backend completion counters: which serving lane (scalar
        // A.2, SIMD C-rungs, bit-packed m1, software-device accel) did
        // the work.
        let jobs_rows: Vec<(Vec<(&str, &str)>, u64)> = BACKEND_LABELS
            .iter()
            .enumerate()
            .map(|(i, &b)| {
                (vec![("backend", b)], self.jobs_completed_backend[i].load(Ordering::Relaxed))
            })
            .collect();
        w.counter_family(
            "repro_jobs_completed_by_backend_total",
            "Jobs answered ok, by serving backend.",
            &jobs_rows,
        );
        let spin_rows: Vec<(Vec<(&str, &str)>, u64)> = BACKEND_LABELS
            .iter()
            .enumerate()
            .map(|(i, &b)| (vec![("backend", b)], self.spins_backend[i].load(Ordering::Relaxed)))
            .collect();
        w.counter_family(
            "repro_spins_attempted_by_backend_total",
            "Spin updates attempted by completed jobs, by serving backend.",
            &spin_rows,
        );
        // The software device's process-global memory-access model:
        // coalesced vs strided transactions (the paper's B.1-vs-B.2
        // axis) plus in-warp divergent replays.
        let (coalesced, strided, replays) = crate::device::global_totals();
        w.counter_family(
            "repro_device_transactions_total",
            "Software-device global-memory transactions by access kind.",
            &[(vec![("kind", "coalesced")], coalesced), (vec![("kind", "strided")], strided)],
        );
        w.counter(
            "repro_device_divergent_replays_total",
            "Software-device in-warp conflict replays.",
            replays,
        );
        if let Some(t) = phase::snapshot() {
            w.counter_family(
                "repro_phase_ns_total",
                "Kernel time by sweep phase (phase-timers build only).",
                &[
                    (vec![("phase", "rng")], t.rng_ns),
                    (vec![("phase", "update")], t.update_ns),
                    (vec![("phase", "reduce")], t.reduce_ns),
                ],
            );
        }
        w.gauge("repro_build_info", "Always 1; build metadata rides on the labels.", 1.0);
        w.finish()
    }
}

/// `{count, mean_us, p50_us, p90_us, p99_us}` for one histogram (also
/// used by the router to summarize cluster-merged snapshots).
pub(crate) fn latency_summary(snap: &HistogramSnapshot) -> Value {
    let (p50, p90, p99) = snap.percentiles_us();
    json::obj(vec![
        ("count", json::num(snap.count() as f64)),
        ("mean_us", json::num(snap.mean_us())),
        ("p50_us", json::num(p50)),
        ("p90_us", json::num(p90)),
        ("p99_us", json::num(p99)),
    ])
}

/// Host fingerprint + git sha, detected once per process: `git_sha()`
/// shells out, which must not happen on every scrape.  The router's
/// aggregated exposition reuses these for its own sample families.
pub(crate) fn build_labels() -> (&'static str, &'static str) {
    static LABELS: OnceLock<(String, String)> = OnceLock::new();
    let (host, sha) = LABELS.get_or_init(|| (HostCaps::detect().fingerprint(), bench::git_sha()));
    (host.as_str(), sha.as_str())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{ConfigEcho, StageTiming};

    #[test]
    fn lane_fill_tracks_dispatches() {
        let m = ServiceMetrics::default();
        assert_eq!(m.lane_fill_ratio(), 1.0, "vacuously full before any batch");
        m.record_dispatch(4, 4, true, false); // full batch
        assert_eq!(m.lane_fill_ratio(), 1.0);
        m.record_dispatch(2, 4, true, true); // padded deadline flush
        assert!((m.lane_fill_ratio() - 0.75).abs() < 1e-12);
        m.record_dispatch(1, 4, false, true); // lone-job fallback: no lanes counted
        assert!((m.lane_fill_ratio() - 0.75).abs() < 1e-12);
        assert_eq!(m.deadline_flushes.load(Ordering::Relaxed), 2);
        assert_eq!(m.singles_dispatched.load(Ordering::Relaxed), 1);
    }

    /// Regression: an a2-/m1-pinned single dispatches immediately by
    /// design — it must not inflate `deadline_flushes`, the control
    /// signal for w8 → w4 bucket retargeting.
    #[test]
    fn pinned_singles_are_not_deadline_flushes() {
        let m = ServiceMetrics::default();
        m.record_dispatch(1, 4, false, false); // pinned single
        m.record_dispatch(1, 4, true, true); // c1-pinned lone-job flush
        assert_eq!(m.singles_dispatched.load(Ordering::Relaxed), 1);
        assert_eq!(m.deadline_flushes.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn snapshot_is_parseable_json() {
        let m = ServiceMetrics::default();
        m.record_dispatch(4, 4, true, false);
        m.set_queue_depth(7);
        m.set_queue_depth(3);
        m.runs_executed.fetch_add(2, Ordering::Relaxed);
        m.jobs_overloaded.fetch_add(1, Ordering::Relaxed);
        let v = Value::parse(&m.snapshot_json()).unwrap();
        assert_eq!(v.get("op").unwrap().as_str().unwrap(), "stats");
        assert_eq!(v.get("queue_depth").unwrap().as_usize().unwrap(), 3);
        assert_eq!(v.get("max_queue_depth").unwrap().as_usize().unwrap(), 7);
        assert_eq!(v.get("lane_fill_ratio").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(v.get("runs_executed").unwrap().as_usize().unwrap(), 2);
        assert_eq!(v.get("jobs_overloaded").unwrap().as_usize().unwrap(), 1);
        assert_eq!(v.get("jobs_in_system").unwrap().as_usize().unwrap(), 0);
        assert_eq!(v.get("dispatches_in_flight").unwrap().as_usize().unwrap(), 0);
    }

    #[test]
    fn stats_carries_latency_rate_and_config_echo() {
        let m = ServiceMetrics::default();
        m.obs.set_config(ConfigEcho {
            lanes: 8,
            flush_ms: 25,
            max_queue: 1024,
            threads: 2,
            backend: "avx2".into(),
        });
        let timing =
            StageTiming { queue_us: 200, sweep_us: 3000, e2e_us: 3500, ..StageTiming::default() };
        m.obs.record_completed(&timing, 640);
        m.obs.record_completed(&timing, 640);
        let v = Value::parse(&m.snapshot_json()).unwrap();
        let cfg = v.get("config").unwrap();
        assert_eq!(cfg.get("lanes").unwrap().as_usize().unwrap(), 8);
        assert_eq!(cfg.get("flush_ms").unwrap().as_usize().unwrap(), 25);
        assert_eq!(cfg.get("max_queue").unwrap().as_usize().unwrap(), 1024);
        let e2e = v.get("latency_us").unwrap().get("e2e").unwrap();
        assert_eq!(e2e.get("count").unwrap().as_usize().unwrap(), 2);
        let p50 = e2e.get("p50_us").unwrap().as_f64().unwrap();
        let p99 = e2e.get("p99_us").unwrap().as_f64().unwrap();
        assert!(p50 > 0.0 && p50 <= p99, "p50 {p50} must not exceed p99 {p99}");
        assert_eq!(v.get("spins_attempted").unwrap().as_usize().unwrap(), 1280);
        assert_eq!(v.get("rate").unwrap().get("window_secs").unwrap().as_usize().unwrap(), 10);
        assert!(v.get("uptime_ms").unwrap().as_f64().unwrap() < 60_000.0);
        assert_eq!(cfg.get("backend").unwrap().as_str().unwrap(), "avx2");
        // The mergeable histogram rides along: its bucket counts sum to
        // the summary's count.
        let hist = v.get("latency_hist").unwrap().get("e2e").unwrap();
        let snap = crate::obs::HistogramSnapshot::from_value(hist).unwrap();
        assert_eq!(snap.count(), 2);
    }

    #[test]
    fn stats_carries_per_bucket_queue_state() {
        let m = ServiceMetrics::default();
        m.set_bucket_stats(vec![
            BucketStat { shape: "4x4x8".into(), depth: 3, oldest_age_us: 12_000, lanes: 8 },
            BucketStat { shape: "m1-singles".into(), depth: 1, oldest_age_us: 5, lanes: 64 },
        ]);
        let v = Value::parse(&m.snapshot_json()).unwrap();
        let buckets = v.get("buckets").unwrap().as_arr().unwrap();
        assert_eq!(buckets.len(), 2);
        assert_eq!(buckets[0].get("shape").unwrap().as_str().unwrap(), "4x4x8");
        assert_eq!(buckets[0].get("depth").unwrap().as_usize().unwrap(), 3);
        assert_eq!(buckets[0].get("oldest_age_us").unwrap().as_usize().unwrap(), 12_000);
        assert_eq!(buckets[1].get("lanes").unwrap().as_usize().unwrap(), 64);
        // Overwritten next round, not accumulated.
        m.set_bucket_stats(vec![]);
        let v = Value::parse(&m.snapshot_json()).unwrap();
        assert!(v.get("buckets").unwrap().as_arr().unwrap().is_empty());
    }

    #[test]
    fn hello_line_advertises_capabilities_and_config() {
        let m = ServiceMetrics::default();
        m.obs.set_config(ConfigEcho {
            lanes: 8,
            flush_ms: 25,
            max_queue: 1024,
            threads: 2,
            backend: "avx2".into(),
        });
        let v = Value::parse(&m.hello_line()).unwrap();
        assert_eq!(v.get("op").unwrap().as_str().unwrap(), "hello");
        assert_eq!(v.get("protocol_version").unwrap().as_usize().unwrap(), 1);
        assert!(!v.get("host").unwrap().as_str().unwrap().is_empty());
        let rungs: Vec<&str> =
            v.get("rungs").unwrap().as_arr().unwrap().iter().map(|r| r.as_str().unwrap()).collect();
        assert_eq!(rungs, SERVED_RUNGS);
        assert_eq!(v.get("backend").unwrap().as_str().unwrap(), "avx2");
        assert_eq!(v.get("lanes").unwrap().as_usize().unwrap(), 8);
        assert_eq!(v.get("max_queue").unwrap().as_usize().unwrap(), 1024);
        assert_eq!(v.get("flush_ms").unwrap().as_usize().unwrap(), 25);
    }

    /// S1 regression: the in-system gauge must saturate at zero, never
    /// wrap to 2^64-1 (which would wedge admission forever).
    #[test]
    fn dec_jobs_in_system_saturates_at_zero() {
        let m = ServiceMetrics::default();
        m.jobs_in_system.store(3, Ordering::Relaxed);
        m.dec_jobs_in_system(2);
        assert_eq!(m.jobs_in_system.load(Ordering::Relaxed), 1);
        m.dec_jobs_in_system(5);
        assert_eq!(m.jobs_in_system.load(Ordering::Relaxed), 0);
        m.dec_jobs_in_system(1);
        assert_eq!(m.jobs_in_system.load(Ordering::Relaxed), 0, "saturating, not wrapping");
    }

    #[test]
    fn metrics_line_wraps_valid_prometheus_text() {
        let m = ServiceMetrics::default();
        m.record_dispatch(3, 4, true, true);
        m.obs.fill.record("4x4x8", 3, 4);
        let timing =
            StageTiming { queue_us: 50, sweep_us: 900, e2e_us: 1000, ..StageTiming::default() };
        m.obs.record_completed(&timing, 160);
        m.record_backend("B.2", 640);
        let v = Value::parse(&m.metrics_line()).unwrap();
        assert_eq!(v.get("op").unwrap().as_str().unwrap(), "metrics");
        assert!(v
            .get("content_type")
            .unwrap()
            .as_str()
            .unwrap()
            .starts_with("text/plain"));
        let text = v.get("text").unwrap().as_str().unwrap();
        assert!(text.contains("# TYPE repro_e2e_seconds histogram"));
        assert!(text.contains("repro_e2e_seconds_count"));
        assert!(text.contains(r#"repro_lane_occupancy_total"#));
        assert!(text.contains(r#"shape="4x4x8""#));
        assert!(text.contains("repro_lane_fill_ratio"));
        assert!(text.contains("repro_build_info"));
        assert!(text.contains("# TYPE repro_jobs_completed_by_backend_total counter"));
        assert!(text.contains(r#"backend="accel""#));
        assert!(text.contains("repro_spins_attempted_by_backend_total"));
        assert!(text.contains(r#"repro_device_transactions_total"#));
        assert!(text.contains(r#"kind="coalesced""#));
        assert!(text.contains("repro_device_divergent_replays_total"));
        // Every sample line carries the common labels.
        for line in text.lines().filter(|l| !l.starts_with('#') && !l.is_empty()) {
            assert!(line.contains("host=\""), "missing host label: {line}");
            assert!(line.contains("sha=\""), "missing sha label: {line}");
        }
    }

    #[test]
    fn backend_counters_bucket_by_rung_kind_label() {
        assert_eq!(backend_index("A.2"), 0);
        assert_eq!(backend_index("C.1 w8"), 1);
        assert_eq!(backend_index("M.1"), 2);
        assert_eq!(backend_index("B.1"), 3);
        assert_eq!(backend_index("B.2"), 3);
        let m = ServiceMetrics::default();
        m.record_backend("B.2", 128);
        m.record_backend("B.1", 64);
        m.record_backend("A.2", 10);
        assert_eq!(m.jobs_completed_backend[3].load(Ordering::Relaxed), 2);
        assert_eq!(m.spins_backend[3].load(Ordering::Relaxed), 192);
        assert_eq!(m.jobs_completed_backend[0].load(Ordering::Relaxed), 1);
        assert_eq!(m.spins_backend[0].load(Ordering::Relaxed), 10);
    }

    #[test]
    fn trace_line_reports_recent_jobs_oldest_first() {
        use crate::obs::JobTrace;
        let m = ServiceMetrics::default();
        for i in 0..5u64 {
            m.obs.traces.push(JobTrace {
                seq: 0,
                id: format!("j{i}"),
                shape: "4x4x8".to_string(),
                kind: "result".to_string(),
                ok: true,
                timing: StageTiming { e2e_us: 100 + i, ..StageTiming::default() },
            });
        }
        let v = Value::parse(&m.trace_line(3)).unwrap();
        assert_eq!(v.get("op").unwrap().as_str().unwrap(), "trace");
        assert_eq!(v.get("count").unwrap().as_usize().unwrap(), 3);
        assert_eq!(v.get("traces_recorded").unwrap().as_usize().unwrap(), 5);
        let traces = match v.get("traces").unwrap() {
            Value::Arr(ts) => ts,
            other => panic!("traces must be an array, got {other:?}"),
        };
        assert_eq!(traces[0].get("id").unwrap().as_str().unwrap(), "j2");
        assert_eq!(traces[2].get("id").unwrap().as_str().unwrap(), "j4");
    }
}
