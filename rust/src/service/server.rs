//! Wire frontends of the sampling service: a TCP JSON-lines server, a
//! stdin/stdout mode, and the client used by `repro submit`.
//!
//! Protocol (per connection): the client writes request lines (jobs or
//! control ops), the server streams back one result line per job as its
//! lane-batch completes (order not guaranteed — correlate by `id`), plus
//! immediate replies for control ops.  When the client half-closes its
//! write side, the server finishes answering that connection's jobs and
//! then closes — so "read until EOF" collects exactly the results.
//!
//! Admission is bounded: when the engine's queue cap is hit, the job's
//! result line is an immediate structured rejection
//! (`{"error":"overloaded","retry_after_ms":...}`) — clients back off
//! and retry rather than queue unboundedly.
//!
//! `{"op":"shutdown"}` stops accepting, waits for open connections,
//! drains the queue and returns from [`serve_tcp`].

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use crate::util::json::Value;
use crate::Result;

use super::engine::{self, SubmitPayload, SubmitRejected, Submitter};
use super::job::{parse_request, JobResult, Request};
use super::metrics::ServiceMetrics;
use super::ServiceConfig;

/// Serve sampling jobs on `listener` until a shutdown request.
pub fn serve_tcp(listener: TcpListener, cfg: &ServiceConfig) -> Result<()> {
    listener.set_nonblocking(true)?;
    let engine = engine::start(cfg)?;
    let shutdown = Arc::new(AtomicBool::new(false));
    let emitter = spawn_metrics_emitter(
        Arc::clone(&engine.metrics),
        cfg.metrics_every_secs,
        Arc::clone(&shutdown),
    );
    let mut connections: Vec<thread::JoinHandle<()>> = Vec::new();
    let mut accept_error: Option<std::io::Error> = None;
    while !shutdown.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                // Reap before tracking the new handle, so sustained
                // connection arrival (which may never hit the idle
                // branch below) cannot grow the ledger without bound.
                connections.retain(|conn| !conn.is_finished());
                let submitter = engine.submitter();
                let metrics = Arc::clone(&engine.metrics);
                let flag = Arc::clone(&shutdown);
                connections.push(thread::spawn(move || {
                    let _ = handle_conn(stream, submitter, metrics, flag);
                }));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                // Reap finished connection threads so a long-lived server
                // does not accumulate handles without bound.
                connections.retain(|conn| !conn.is_finished());
                thread::sleep(Duration::from_millis(5));
            }
            Err(e) => {
                // Flag the connections down too, or their submitter
                // clones would keep the engine from draining.
                shutdown.store(true, Ordering::SeqCst);
                accept_error = Some(e);
            }
        }
    }
    // Stop accepting; open connections poll the shutdown flag and wind
    // down, then the engine drains whatever is still queued.
    for conn in connections {
        let _ = conn.join();
    }
    engine.shutdown();
    if let Some(emitter) = emitter {
        let _ = emitter.join();
    }
    match accept_error {
        Some(e) => Err(e.into()),
        None => Ok(()),
    }
}

/// Periodically write a Prometheus text snapshot to **stderr** (stdout
/// carries protocol lines in stdin mode) until `stop` flips.  Polls the
/// flag in short steps so shutdown never waits out a full period.
fn spawn_metrics_emitter(
    metrics: Arc<ServiceMetrics>,
    every_secs: u64,
    stop: Arc<AtomicBool>,
) -> Option<thread::JoinHandle<()>> {
    if every_secs == 0 {
        return None;
    }
    Some(thread::spawn(move || {
        let period = Duration::from_secs(every_secs);
        let step = Duration::from_millis(100);
        let mut next = std::time::Instant::now() + period;
        while !stop.load(Ordering::SeqCst) {
            thread::sleep(step.min(period));
            if std::time::Instant::now() >= next {
                let mut err = std::io::stderr().lock();
                let _ = err.write_all(metrics.prometheus_text().as_bytes());
                let _ = err.flush();
                next += period;
            }
        }
    }))
}

/// Serve from stdin, streaming result lines to stdout; returns at EOF
/// (or a shutdown op) once the queue has drained.
pub fn serve_stdin(cfg: &ServiceConfig) -> Result<()> {
    let engine = engine::start(cfg)?;
    let (line_tx, line_rx) = channel::<String>();
    let writer = thread::spawn(move || {
        let stdout = std::io::stdout();
        for line in line_rx {
            let mut out = stdout.lock();
            let _ = out.write_all(line.as_bytes());
            let _ = out.write_all(b"\n");
            let _ = out.flush();
        }
    });
    let submitter = engine.submitter();
    let shutdown = Arc::new(AtomicBool::new(false));
    let emitter = spawn_metrics_emitter(
        Arc::clone(&engine.metrics),
        cfg.metrics_every_secs,
        Arc::clone(&shutdown),
    );
    let stdin = std::io::stdin();
    for line in stdin.lock().lines() {
        let Ok(line) = line else { break };
        let line = line.trim();
        if !line.is_empty() {
            handle_line(line, &submitter, &line_tx, &engine.metrics, &shutdown);
        }
        if shutdown.load(Ordering::SeqCst) {
            break;
        }
    }
    drop(line_tx);
    drop(submitter);
    shutdown.store(true, Ordering::SeqCst); // stop the emitter at EOF too
    engine.shutdown(); // drains queued jobs; their reply clones then drop
    if let Some(emitter) = emitter {
        let _ = emitter.join();
    }
    let _ = writer.join();
    Ok(())
}

fn handle_conn(
    stream: TcpStream,
    submitter: Submitter,
    metrics: Arc<ServiceMetrics>,
    shutdown: Arc<AtomicBool>,
) -> Result<()> {
    // Short read timeouts let the reader poll the shutdown flag.
    stream.set_read_timeout(Some(Duration::from_millis(100)))?;
    let write_half = stream.try_clone()?;
    let (line_tx, line_rx) = channel::<String>();
    let writer = thread::spawn(move || {
        let mut out = BufWriter::new(write_half);
        for line in line_rx {
            if out.write_all(line.as_bytes()).is_err()
                || out.write_all(b"\n").is_err()
                || out.flush().is_err()
            {
                break;
            }
        }
        if let Ok(inner) = out.into_inner() {
            let _ = inner.shutdown(Shutdown::Write);
        }
    });
    let mut reader = BufReader::new(stream);
    let mut buf = String::new();
    loop {
        match reader.read_line(&mut buf) {
            Ok(0) => break, // client half-closed: no more requests
            Ok(_) => {
                let line = buf.trim();
                if !line.is_empty() {
                    handle_line(line, &submitter, &line_tx, &metrics, &shutdown);
                }
                buf.clear();
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                if shutdown.load(Ordering::SeqCst) {
                    break;
                }
            }
            Err(_) => break,
        }
    }
    // The writer exits once the engine has answered every job this
    // connection submitted (each pending job holds a sender clone).
    drop(line_tx);
    drop(submitter);
    let _ = writer.join();
    Ok(())
}

fn handle_line(
    line: &str,
    submitter: &Submitter,
    line_tx: &Sender<String>,
    metrics: &ServiceMetrics,
    shutdown: &AtomicBool,
) {
    match parse_request(line) {
        Ok(Request::Job(spec)) => {
            submit(submitter, SubmitPayload::Job(spec), line_tx);
        }
        Ok(Request::Run(job)) => {
            // A checkpointable full run: admitted like any other job and
            // executed on the engine's sweep pool (admission has already
            // capped its work), so this reader loop stays responsive —
            // an interleaved {"op":"stats"} answers while the run sweeps.
            submit(submitter, SubmitPayload::Run(job), line_tx);
        }
        Ok(Request::Hello) => {
            let _ = line_tx.send(metrics.hello_line());
        }
        Ok(Request::Stats) => {
            let _ = line_tx.send(metrics.snapshot_json());
        }
        Ok(Request::Metrics) => {
            let _ = line_tx.send(metrics.metrics_line());
        }
        Ok(Request::Trace { last }) => {
            let _ = line_tx.send(metrics.trace_line(last));
        }
        Ok(Request::Shutdown) => {
            shutdown.store(true, Ordering::SeqCst);
            let ack = crate::util::json::obj(vec![
                ("ok", Value::Bool(true)),
                ("op", crate::util::json::str_v("shutdown")),
                (
                    "protocol_version",
                    crate::util::json::num(super::job::PROTOCOL_VERSION as f64),
                ),
            ]);
            let _ = line_tx.send(ack.to_string());
        }
        Err(e) => {
            metrics.jobs_rejected.fetch_add(1, Ordering::Relaxed);
            // Echo the id back when the line was at least valid JSON.
            let id = Value::parse(line)
                .ok()
                .and_then(|v| v.opt("id").and_then(|x| x.as_str().ok().map(String::from)))
                .unwrap_or_default();
            let _ = line_tx.send(JobResult::error_line(&id, &format!("{e:#}")));
        }
    }
}

/// Submit one payload through the bounded admission gate, answering a
/// refusal with the structured rejection line right away.
fn submit(submitter: &Submitter, payload: SubmitPayload, line_tx: &Sender<String>) {
    let id = payload.id().to_string();
    match submitter.submit(payload, line_tx.clone()) {
        Ok(()) => {}
        Err(SubmitRejected::Overloaded { retry_after_ms }) => {
            let _ = line_tx.send(JobResult::overloaded_line(&id, retry_after_ms));
        }
        Err(SubmitRejected::ShuttingDown) => {
            let _ = line_tx.send(JobResult::error_line(&id, "service shutting down"));
        }
    }
}

/// `repro submit`: send request lines to a serving `repro serve
/// --listen`, then stream every response line to `out` until the server
/// closes the connection.  Returns the number of response lines.
pub fn submit_lines<I: IntoIterator<Item = String>>(
    addr: &str,
    lines: I,
    out: &mut dyn Write,
) -> Result<usize> {
    let stream = TcpStream::connect(addr)?;
    {
        let mut w = BufWriter::new(stream.try_clone()?);
        for line in lines {
            let line = line.trim().to_string();
            if line.is_empty() {
                continue;
            }
            w.write_all(line.as_bytes())?;
            w.write_all(b"\n")?;
        }
        w.flush()?;
    }
    stream.shutdown(Shutdown::Write)?;
    let mut n = 0usize;
    for line in BufReader::new(stream).lines() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        writeln!(out, "{line}")?;
        n += 1;
    }
    Ok(n)
}
