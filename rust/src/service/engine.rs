//! The service engine: one scheduler thread driving admission →
//! lane-batching → sweep-pool execution → per-job result lines.
//!
//! Submissions arrive on an mpsc channel (one sender clone per
//! connection).  The scheduler sleeps until either a new submission or
//! the earliest flush deadline, packs what is ready through the
//! [`Batcher`], and executes the resulting dispatches on one persistent
//! [`SweepPool`] — one pool task per dispatch, so independent batches of
//! different shapes sweep in parallel while each batch keeps its lanes
//! in lockstep.  Result lines stream back through each job's reply
//! channel as its dispatch completes.
//!
//! Shutdown is by hang-up: dropping the [`EngineHandle`] (or calling
//! [`EngineHandle::shutdown`]) closes the submission channel; the
//! scheduler drains every queued job, answers it, and exits.
//!
//! Dispatch rounds are synchronous: the scheduler blocks in
//! `SweepPool::run_batch` until the round's dispatches finish, and
//! submissions arriving meanwhile wait in the channel.  The admission
//! work cap (`JobSpec::validate`) bounds how long one round can take,
//! so the flush deadline is a *time-to-dispatch* bound plus at most one
//! round of execution — a fully asynchronous dispatcher is future work
//! (see DESIGN.md).

use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::SweepPool;
use crate::Result;

use super::batcher::{Batcher, Dispatch};
use super::executor::Executor;
use super::job::{JobResult, JobSpec};
use super::metrics::ServiceMetrics;
use super::ServiceConfig;

/// A job plus the channel its serialized result line goes back through.
pub struct Submission {
    pub spec: JobSpec,
    pub reply: Sender<String>,
}

/// Handle to a running engine: submit jobs, read metrics, shut down.
pub struct EngineHandle {
    tx: Option<Sender<Submission>>,
    pub metrics: Arc<ServiceMetrics>,
    join: Option<JoinHandle<()>>,
}

impl EngineHandle {
    /// A cloneable submission channel (one per connection).
    pub fn submitter(&self) -> Sender<Submission> {
        self.tx.as_ref().expect("engine running").clone()
    }

    /// Close admission, drain every queued job, stop the scheduler.
    pub fn shutdown(mut self) {
        self.close_and_join();
    }

    fn close_and_join(&mut self) {
        self.tx.take();
        if let Some(join) = self.join.take() {
            let _ = join.join();
        }
    }
}

impl Drop for EngineHandle {
    fn drop(&mut self) {
        self.close_and_join();
    }
}

/// Start the scheduler thread for `cfg`.
pub fn start(cfg: &ServiceConfig) -> Result<EngineHandle> {
    let executor = Executor::with_backend(cfg.lanes, cfg.backend, cfg.exp)?;
    let metrics = Arc::new(ServiceMetrics::default());
    let metrics_for_thread = Arc::clone(&metrics);
    let (tx, rx) = channel::<Submission>();
    let threads = cfg.threads;
    let flush = Duration::from_millis(cfg.flush_ms.max(1));
    let join = std::thread::spawn(move || {
        scheduler_loop(rx, executor, threads, flush, metrics_for_thread);
    });
    Ok(EngineHandle { tx: Some(tx), metrics, join: Some(join) })
}

fn scheduler_loop(
    rx: Receiver<Submission>,
    executor: Executor,
    threads: usize,
    flush: Duration,
    metrics: Arc<ServiceMetrics>,
) {
    let pool = SweepPool::new(threads);
    let mut batcher = Batcher::new(executor.width, flush);
    loop {
        // Sleep until the next admission or the earliest flush deadline.
        let msg = match batcher.next_deadline() {
            None => rx.recv().map_err(|_| RecvTimeoutError::Disconnected),
            Some(deadline) => {
                let now = Instant::now();
                if deadline <= now {
                    Err(RecvTimeoutError::Timeout)
                } else {
                    rx.recv_timeout(deadline - now)
                }
            }
        };
        let disconnected = match msg {
            Ok(sub) => {
                admit(&mut batcher, sub, &executor, &metrics);
                while let Ok(sub) = rx.try_recv() {
                    admit(&mut batcher, sub, &executor, &metrics);
                }
                false
            }
            Err(RecvTimeoutError::Timeout) => false,
            Err(RecvTimeoutError::Disconnected) => true,
        };
        let dispatches =
            if disconnected { batcher.drain() } else { batcher.poll(Instant::now()) };
        metrics.set_queue_depth(batcher.queued());
        execute(&pool, executor, dispatches, &metrics);
        if disconnected {
            break;
        }
    }
}

fn admit(batcher: &mut Batcher, sub: Submission, executor: &Executor, metrics: &ServiceMetrics) {
    // Line-level validation already ran in the connection thread; here
    // the job's sampler (if any) is checked against the serving plan.
    if let Err(e) = executor.admits(&sub.spec) {
        metrics.jobs_rejected.fetch_add(1, Ordering::Relaxed);
        let _ = sub.reply.send(JobResult::error_line(&sub.spec.id, &format!("{e:#}")));
        return;
    }
    metrics.jobs_submitted.fetch_add(1, Ordering::Relaxed);
    batcher.push(sub.spec, Some(sub.reply), Instant::now());
    metrics.set_queue_depth(batcher.queued());
}

/// One pool task per dispatch; each job's result line streams back to
/// its connection as soon as its dispatch completes.
fn execute(
    pool: &SweepPool,
    executor: Executor,
    dispatches: Vec<Dispatch>,
    metrics: &Arc<ServiceMetrics>,
) {
    if dispatches.is_empty() {
        return;
    }
    let width = executor.width;
    let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = dispatches
        .into_iter()
        .map(|dispatch| {
            let metrics = Arc::clone(metrics);
            Box::new(move || {
                metrics.record_dispatch(dispatch.occupancy(), width, dispatch.is_batch());
                for (job, outcome) in executor.run_dispatch(dispatch) {
                    let line = match outcome {
                        Ok(result) => {
                            metrics.jobs_completed.fetch_add(1, Ordering::Relaxed);
                            result.to_line()
                        }
                        Err(e) => {
                            metrics.jobs_failed.fetch_add(1, Ordering::Relaxed);
                            JobResult::error_line(&job.spec.id, &format!("{e:#}"))
                        }
                    };
                    if let Some(reply) = &job.reply {
                        // A gone connection just discards its results.
                        let _ = reply.send(line);
                    }
                }
            }) as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    pool.run_batch(tasks);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sweep::ExpMode;

    fn spec(id: &str, layers: usize, seed: u32) -> JobSpec {
        JobSpec {
            id: id.to_string(),
            width: 4,
            height: 4,
            layers,
            model_seed: 1,
            jtau: 0.3,
            sweeps: 12,
            beta: 0.8,
            seed,
            trace_every: 0,
            want_state: true,
            sampler: None,
        }
    }

    /// Submissions flow through batching + pool execution back to the
    /// reply channel, one result line per job, drained on shutdown.
    #[test]
    fn engine_answers_every_submission() {
        // A generous flush deadline so slow CI cannot split the 4-job
        // bucket into a padded flush before all four have been admitted.
        let cfg = ServiceConfig {
            lanes: 4,
            threads: 2,
            flush_ms: 200,
            exp: ExpMode::Fast,
            ..ServiceConfig::default()
        };
        let engine = start(&cfg).unwrap();
        let submitter = engine.submitter();
        let (reply_tx, reply_rx) = channel::<String>();
        // 4 batchable jobs + 1 lone shallow job (deadline flush -> A.2).
        for i in 0..4 {
            let sub =
                Submission { spec: spec(&format!("b{i}"), 8, 40 + i), reply: reply_tx.clone() };
            submitter.send(sub).unwrap();
        }
        submitter
            .send(Submission { spec: spec("lone", 2, 99), reply: reply_tx.clone() })
            .unwrap();
        drop(reply_tx);
        drop(submitter);
        let metrics = Arc::clone(&engine.metrics);
        engine.shutdown(); // drains the queue before returning

        let mut lines: Vec<String> = reply_rx.iter().collect();
        lines.sort();
        assert_eq!(lines.len(), 5, "one result line per job: {lines:?}");
        let mut kinds = Vec::new();
        for line in &lines {
            let r = JobResult::from_line(line).unwrap();
            kinds.push(r.kind.clone());
            assert!(r.state.is_some());
        }
        assert!(kinds.iter().any(|k| k == "A.2"), "lone job fell back to scalar: {kinds:?}");
        assert!(kinds.iter().any(|k| k.starts_with("C.1")), "batch served by a C-rung");
        assert_eq!(metrics.jobs_completed.load(Ordering::Relaxed), 5);
        assert_eq!(metrics.jobs_submitted.load(Ordering::Relaxed), 5);
        assert_eq!(metrics.lane_fill_ratio(), 1.0, "the 4-job bucket filled its batch");
    }
}
