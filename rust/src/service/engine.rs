//! The service engine: one scheduler thread driving admission →
//! lane-batching → sweep-pool execution → per-job result lines.
//!
//! Submissions arrive on an mpsc channel (one [`Submitter`] clone per
//! connection), gated by a **bounded admission** check: each connection
//! thread reserves a slot against the configured queue cap *before*
//! sending, so overload is answered right there with a structured
//! `{"error":"overloaded","retry_after_ms":...}` rejection instead of
//! queueing unboundedly.  The scheduler sleeps until either a new
//! submission or the earliest flush deadline, packs what is ready
//! through the [`Batcher`], and hands each resulting dispatch to a
//! persistent [`SweepPool`] as a **fire-and-forget task**: the scheduler
//! never blocks on execution, so admission, deadline polling and
//! metrics stay live while batches sweep.  `{"op":"run"}` jobs take the
//! same path — the scheduler spawns them straight onto the pool, so a
//! work-capped full run no longer stalls its connection's reader loop.
//! Result lines stream back through each job's reply channel as its
//! dispatch completes.
//!
//! Every spawned task carries a drop-signalling completion guard wired
//! to the scheduler's completion channel.  Shutdown is by hang-up:
//! dropping the [`EngineHandle`] (or calling [`EngineHandle::shutdown`])
//! closes the submission channel; the scheduler drains every queued job
//! into final dispatches, then blocks on the completion channel until
//! every in-flight task has settled — so shutdown answers every
//! admitted job, panics included (the guard signals on drop).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::SweepPool;
use crate::obs::{ConfigEcho, JobTrace, Timeline};
use crate::Result;

use super::batcher::{Batcher, Dispatch};
use super::executor::Executor;
use super::job::{JobResult, JobSpec, RunJob};
use super::metrics::ServiceMetrics;
use super::ServiceConfig;

/// What a connection submits: a batchable sweep job or a checkpointable
/// full run.  Both flow through the same admission gate and the same
/// sweep pool.
pub enum SubmitPayload {
    Job(JobSpec),
    Run(Box<RunJob>),
}

impl SubmitPayload {
    /// The client-assigned id (for error correlation).
    pub fn id(&self) -> &str {
        match self {
            SubmitPayload::Job(spec) => &spec.id,
            SubmitPayload::Run(job) => &job.id,
        }
    }
}

/// A payload plus the channel its serialized result line goes back
/// through.
pub struct Submission {
    pub payload: SubmitPayload,
    pub reply: Sender<String>,
    /// When the connection thread passed the admission gate — the
    /// origin of the job's lifecycle timeline.
    pub admit: Instant,
}

/// Why a submission was refused at the admission gate.
#[derive(Debug, PartialEq, Eq)]
pub enum SubmitRejected {
    /// The queue cap is hit; retry after the hinted backoff.
    Overloaded { retry_after_ms: u64 },
    /// The engine is shutting down; no more work is accepted.
    ShuttingDown,
}

/// The bounded admission gate, shared by every [`Submitter`] clone.
///
/// The capacity check runs on the submitting connection's thread via a
/// compare-exchange loop on the `jobs_in_system` gauge (admitted and
/// not yet answered), so the cap is exact: an admitted job is never
/// dropped, and an over-cap job is refused before it touches the
/// scheduler.
struct Admission {
    /// Maximum jobs in the system (queued + executing); 0 = unbounded.
    max_queue: usize,
    /// Flush deadline in ms — the base unit of the retry hint.
    flush_ms: u64,
    /// Lane width — jobs the service retires per dispatch round.
    lanes: usize,
    metrics: Arc<ServiceMetrics>,
}

impl Admission {
    /// Reserve one in-system slot, or refuse with a retry hint.
    fn try_admit(&self) -> std::result::Result<(), SubmitRejected> {
        if self.max_queue == 0 {
            self.metrics.jobs_in_system.fetch_add(1, Ordering::AcqRel);
            return Ok(());
        }
        let gauge = &self.metrics.jobs_in_system;
        let mut depth = gauge.load(Ordering::Acquire);
        loop {
            if depth >= self.max_queue as u64 {
                self.metrics.jobs_overloaded.fetch_add(1, Ordering::Relaxed);
                return Err(SubmitRejected::Overloaded {
                    retry_after_ms: self.retry_after_ms(depth),
                });
            }
            match gauge.compare_exchange_weak(
                depth,
                depth + 1,
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => return Ok(()),
                Err(cur) => depth = cur,
            }
        }
    }

    /// Release one in-system slot (job answered, or admission raced a
    /// shutdown).  Saturating: the gauge must never wrap below zero.
    fn settle(&self) {
        self.metrics.dec_jobs_in_system(1);
    }

    /// Backoff hint: one flush deadline per expected dispatch round the
    /// backlog needs to clear (`depth / lanes`, rounded up to ≥ 1),
    /// capped at a minute so a deep queue cannot hint forever.
    fn retry_after_ms(&self, depth: u64) -> u64 {
        let rounds = 1 + depth / self.lanes.max(1) as u64;
        (self.flush_ms.max(1)).saturating_mul(rounds).min(60_000)
    }
}

/// A cloneable submission endpoint (one per connection): the admission
/// gate plus the scheduler channel behind it.
#[derive(Clone)]
pub struct Submitter {
    tx: Sender<Submission>,
    admission: Arc<Admission>,
}

impl Submitter {
    /// Admit and enqueue one payload, or refuse with a structured
    /// reason.  On success the result line (ok or error) will arrive on
    /// `reply` exactly once.
    pub fn submit(
        &self,
        payload: SubmitPayload,
        reply: Sender<String>,
    ) -> std::result::Result<(), SubmitRejected> {
        let admit = Instant::now();
        self.admission.try_admit()?;
        if self.tx.send(Submission { payload, reply, admit }).is_err() {
            self.admission.settle();
            return Err(SubmitRejected::ShuttingDown);
        }
        Ok(())
    }
}

/// Handle to a running engine: submit jobs, read metrics, shut down.
pub struct EngineHandle {
    submitter: Option<Submitter>,
    pub metrics: Arc<ServiceMetrics>,
    join: Option<JoinHandle<()>>,
}

impl EngineHandle {
    /// A cloneable submission endpoint (one per connection).
    pub fn submitter(&self) -> Submitter {
        self.submitter.as_ref().expect("engine running").clone()
    }

    /// Close admission, drain every in-flight job, stop the scheduler.
    pub fn shutdown(mut self) {
        self.close_and_join();
    }

    fn close_and_join(&mut self) {
        self.submitter.take();
        if let Some(join) = self.join.take() {
            let _ = join.join();
        }
    }
}

impl Drop for EngineHandle {
    fn drop(&mut self) {
        self.close_and_join();
    }
}

/// Start the scheduler thread for `cfg`.
pub fn start(cfg: &ServiceConfig) -> Result<EngineHandle> {
    let executor = Executor::with_backend(cfg.lanes, cfg.backend, cfg.exp)?;
    let metrics = Arc::new(ServiceMetrics::default());
    metrics.obs.set_config(ConfigEcho {
        lanes: executor.width,
        flush_ms: cfg.flush_ms,
        max_queue: cfg.max_queue,
        threads: cfg.threads,
        backend: executor.resolved.backend.as_str().to_string(),
    });
    let metrics_for_thread = Arc::clone(&metrics);
    let (tx, rx) = channel::<Submission>();
    let threads = cfg.threads;
    let flush = Duration::from_millis(cfg.flush_ms.max(1));
    let join = std::thread::spawn(move || {
        scheduler_loop(rx, executor, threads, flush, metrics_for_thread);
    });
    let admission = Arc::new(Admission {
        max_queue: cfg.max_queue,
        flush_ms: cfg.flush_ms,
        lanes: cfg.lanes,
        metrics: Arc::clone(&metrics),
    });
    let submitter = Submitter { tx, admission };
    Ok(EngineHandle { submitter: Some(submitter), metrics, join: Some(join) })
}

/// Signals dispatch completion to the scheduler on drop — so the signal
/// survives a panicking task and shutdown can await every in-flight
/// dispatch by draining the channel to hang-up.
struct CompletionSignal {
    done: Sender<()>,
    metrics: Arc<ServiceMetrics>,
}

impl Drop for CompletionSignal {
    fn drop(&mut self) {
        self.metrics.dispatches_in_flight.fetch_sub(1, Ordering::Relaxed);
        let _ = self.done.send(());
    }
}

fn scheduler_loop(
    rx: Receiver<Submission>,
    executor: Executor,
    threads: usize,
    flush: Duration,
    metrics: Arc<ServiceMetrics>,
) {
    // Always-threaded, even for one worker: dispatches must run off the
    // scheduler thread so admission and deadline polling stay live.
    let pool = SweepPool::new_threaded(threads);
    pool.set_task_hist(Arc::clone(&metrics.obs.pool_task_us));
    let (done_tx, done_rx) = channel::<()>();
    let mut batcher = Batcher::new(executor.width, flush);
    loop {
        // Keep the completion buffer drained (the gauge lives in
        // metrics; the channel exists for the shutdown barrier below).
        while done_rx.try_recv().is_ok() {}
        // Sleep until the next admission or the earliest flush deadline.
        let msg = match batcher.next_deadline() {
            None => rx.recv().map_err(|_| RecvTimeoutError::Disconnected),
            Some(deadline) => {
                let now = Instant::now();
                if deadline <= now {
                    Err(RecvTimeoutError::Timeout)
                } else {
                    rx.recv_timeout(deadline - now)
                }
            }
        };
        let disconnected = match msg {
            Ok(sub) => {
                admit(&mut batcher, sub, &pool, &executor, &metrics, &done_tx);
                while let Ok(sub) = rx.try_recv() {
                    admit(&mut batcher, sub, &pool, &executor, &metrics, &done_tx);
                }
                false
            }
            Err(RecvTimeoutError::Timeout) => false,
            Err(RecvTimeoutError::Disconnected) => true,
        };
        let dispatches = if disconnected { batcher.drain() } else { batcher.poll(Instant::now()) };
        metrics.set_queue_depth(batcher.queued());
        metrics.set_bucket_stats(batcher.bucket_stats(Instant::now()));
        for dispatch in dispatches {
            spawn_dispatch(&pool, executor, dispatch, &metrics, &done_tx);
        }
        if disconnected {
            break;
        }
    }
    // Drain-on-shutdown barrier: every spawned task holds a completion
    // sender clone; once ours is gone, channel hang-up means every
    // in-flight dispatch (including run jobs) has settled and answered.
    drop(done_tx);
    while done_rx.recv().is_ok() {}
}

fn admit(
    batcher: &mut Batcher,
    sub: Submission,
    pool: &SweepPool,
    executor: &Executor,
    metrics: &Arc<ServiceMetrics>,
    done: &Sender<()>,
) {
    match sub.payload {
        SubmitPayload::Job(spec) => {
            // Line-level validation already ran in the connection
            // thread; here the job's sampler (if any) is checked against
            // the serving plan.
            if let Err(e) = executor.admits(&spec) {
                metrics.jobs_rejected.fetch_add(1, Ordering::Relaxed);
                metrics.dec_jobs_in_system(1);
                let _ = sub.reply.send(JobResult::error_line(&spec.id, &format!("{e:#}")));
                return;
            }
            metrics.jobs_submitted.fetch_add(1, Ordering::Relaxed);
            batcher.push_timed(spec, Some(sub.reply), sub.admit, Instant::now());
            metrics.set_queue_depth(batcher.queued());
        }
        SubmitPayload::Run(job) => {
            // A checkpointable full run: spawned straight onto the pool
            // (admission has already capped its work), so it neither
            // stalls the scheduler nor its connection's reader loop.
            metrics.jobs_submitted.fetch_add(1, Ordering::Relaxed);
            spawn_run(pool, *job, sub.reply, sub.admit, metrics, done);
        }
    }
}

/// Fire-and-forget one dispatch onto the pool; each job's result line
/// streams back to its connection as soon as the dispatch completes.
fn spawn_dispatch(
    pool: &SweepPool,
    executor: Executor,
    mut dispatch: Dispatch,
    metrics: &Arc<ServiceMetrics>,
    done: &Sender<()>,
) {
    let metrics = Arc::clone(metrics);
    metrics.dispatches_in_flight.fetch_add(1, Ordering::Relaxed);
    let signal = CompletionSignal { done: done.clone(), metrics: Arc::clone(&metrics) };
    let width = executor.width;
    pool.spawn(Box::new(move || {
        let _signal = signal;
        dispatch.stamp_dispatched(Instant::now());
        let total = dispatch.occupancy();
        metrics.record_dispatch(total, width, dispatch.is_batch(), dispatch.deadline_forced);
        if dispatch.is_batch() {
            metrics.obs.fill.record(&dispatch.shape_label(), total, width);
        }
        let settled = std::cell::Cell::new(0u64);
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            for (job, outcome) in executor.run_dispatch(dispatch) {
                // Stamp reply *before* serialization: the stage sum must
                // stay ≤ the e2e the client measures from its own clock.
                let timing = job.timeline.stages(Instant::now());
                let shape = job.spec.shape().to_string();
                let line = match outcome {
                    Ok(mut result) => {
                        metrics.jobs_completed.fetch_add(1, Ordering::Relaxed);
                        metrics.obs.record_completed(&timing, result.stats.attempts);
                        metrics.record_backend(&result.kind, result.stats.attempts);
                        metrics.obs.traces.push(JobTrace {
                            seq: 0,
                            id: result.id.clone(),
                            shape,
                            kind: result.kind.clone(),
                            ok: true,
                            timing,
                        });
                        if job.spec.want_timing {
                            result.timing = Some(timing);
                        }
                        result.to_line()
                    }
                    Err(e) => {
                        metrics.jobs_failed.fetch_add(1, Ordering::Relaxed);
                        metrics.obs.traces.push(JobTrace {
                            seq: 0,
                            id: job.spec.id.clone(),
                            shape,
                            kind: "error".to_string(),
                            ok: false,
                            timing,
                        });
                        JobResult::error_line(&job.spec.id, &format!("{e:#}"))
                    }
                };
                if let Some(reply) = &job.reply {
                    // A gone connection just discards its results.
                    let _ = reply.send(line);
                }
                metrics.dec_jobs_in_system(1);
                settled.set(settled.get() + 1);
            }
        }));
        if outcome.is_err() {
            // A panicking dispatch dropped its jobs' reply senders
            // during unwind; settle their slots so admission capacity
            // is never leaked.
            let lost = total as u64 - settled.get();
            metrics.jobs_failed.fetch_add(lost, Ordering::Relaxed);
            metrics.dec_jobs_in_system(lost);
        }
    }));
}

/// Fire-and-forget one `{"op":"run"}` job onto the pool.
fn spawn_run(
    pool: &SweepPool,
    job: RunJob,
    reply: Sender<String>,
    admit: Instant,
    metrics: &Arc<ServiceMetrics>,
    done: &Sender<()>,
) {
    let metrics = Arc::clone(metrics);
    metrics.dispatches_in_flight.fetch_add(1, Ordering::Relaxed);
    let signal = CompletionSignal { done: done.clone(), metrics: Arc::clone(&metrics) };
    pool.spawn(Box::new(move || {
        let _signal = signal;
        let id = job.id.clone();
        let spins = job.spec.config.total_updates();
        let kind = job.spec.sampler.rung.label();
        // A run bypasses the batcher: it "seals" at admission and both
        // dispatch and sweep begin when the pool picks it up.
        let mut timeline = Timeline::new(admit, admit);
        timeline.seal = Some(admit);
        let picked_up = Instant::now();
        timeline.dispatch = Some(picked_up);
        timeline.sweep_start = Some(picked_up);
        let outcome = catch_unwind(AssertUnwindSafe(|| execute_run_job(job)));
        timeline.sweep_end = Some(Instant::now());
        let (line, ok) = outcome
            .unwrap_or_else(|_| (JobResult::error_line(&id, "run job panicked"), false));
        let timing = timeline.stages(Instant::now());
        metrics.runs_executed.fetch_add(1, Ordering::Relaxed);
        if ok {
            metrics.jobs_completed.fetch_add(1, Ordering::Relaxed);
            metrics.obs.record_completed(&timing, spins);
            metrics.record_backend(kind, spins);
        } else {
            metrics.jobs_failed.fetch_add(1, Ordering::Relaxed);
        }
        metrics.obs.traces.push(JobTrace {
            seq: 0,
            id,
            shape: "run".to_string(),
            kind: "run".to_string(),
            ok,
            timing,
        });
        let _ = reply.send(line);
        metrics.dec_jobs_in_system(1);
    }));
}

/// Execute one checkpointable run job through the coordinator and
/// serialize its outcome (one result line either way; the bool reports
/// success for the completion counters).
fn execute_run_job(job: RunJob) -> (String, bool) {
    use crate::coordinator::{self, RunOptions};
    let id = job.id.clone();
    let opts = RunOptions { resume: job.checkpoint, ..RunOptions::default() };
    let outcome = if job.want_checkpoint {
        coordinator::run_spec_capturing(&job.spec, &opts).map(|(rep, ck)| (rep, Some(ck)))
    } else {
        coordinator::run_spec_with(&job.spec, &opts).map(|rep| (rep, None))
    };
    match outcome {
        Ok((report, ck)) => (RunJob::result_line(&id, &report, ck.as_ref()), true),
        Err(e) => (JobResult::error_line(&id, &format!("{e:#}")), false),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sweep::ExpMode;

    fn spec(id: &str, layers: usize, seed: u32) -> JobSpec {
        JobSpec {
            id: id.to_string(),
            width: 4,
            height: 4,
            layers,
            model_seed: 1,
            jtau: 0.3,
            sweeps: 12,
            beta: 0.8,
            seed,
            trace_every: 0,
            want_state: true,
            want_timing: false,
            sampler: None,
        }
    }

    /// Submissions flow through batching + pool execution back to the
    /// reply channel, one result line per job, drained on shutdown —
    /// with a `{"op":"run"}` job riding the same pool.
    #[test]
    fn engine_answers_every_submission() {
        use crate::coordinator::{RunConfig, RunSpec};
        // A generous flush deadline so slow CI cannot split the 4-job
        // bucket into a padded flush before all four have been admitted.
        let cfg = ServiceConfig {
            lanes: 4,
            threads: 2,
            flush_ms: 200,
            exp: ExpMode::Fast,
            ..ServiceConfig::default()
        };
        let engine = start(&cfg).unwrap();
        let submitter = engine.submitter();
        let (reply_tx, reply_rx) = channel::<String>();
        // 4 batchable jobs + 1 lone shallow job (deadline flush -> A.2).
        for i in 0..4 {
            submitter
                .submit(SubmitPayload::Job(spec(&format!("b{i}"), 8, 40 + i)), reply_tx.clone())
                .unwrap();
        }
        submitter
            .submit(SubmitPayload::Job(spec("lone", 2, 99)), reply_tx.clone())
            .unwrap();
        // One pool-executed run job (small: 2 models, 20 sweeps, A.2).
        let run_spec = RunSpec::new(
            RunConfig {
                width: 4,
                height: 4,
                layers: 8,
                n_models: 2,
                sweeps: 20,
                ..RunConfig::default()
            },
            crate::engine::SamplerSpec::rung(crate::engine::Rung::A2),
        );
        let run = RunJob {
            id: "run0".to_string(),
            spec: run_spec,
            checkpoint: None,
            want_checkpoint: false,
        };
        submitter.submit(SubmitPayload::Run(Box::new(run)), reply_tx.clone()).unwrap();
        drop(reply_tx);
        drop(submitter);
        let metrics = Arc::clone(&engine.metrics);
        engine.shutdown(); // drains in-flight work before returning

        let mut lines: Vec<String> = reply_rx.iter().collect();
        lines.sort();
        assert_eq!(lines.len(), 6, "one result line per job: {lines:?}");
        let mut kinds = Vec::new();
        let mut run_lines = 0;
        for line in &lines {
            // The run result is the one line carrying a run_report.
            if line.contains("\"run_report\"") {
                assert!(line.contains("\"status\":\"ok\""), "run job succeeded: {line}");
                run_lines += 1;
                continue;
            }
            let r = JobResult::from_line(line).unwrap();
            kinds.push(r.kind.clone());
            assert!(r.state.is_some());
        }
        assert_eq!(run_lines, 1, "exactly one run result: {lines:?}");
        assert!(kinds.iter().any(|k| k == "A.2"), "lone job fell back to scalar: {kinds:?}");
        assert!(kinds.iter().any(|k| k.starts_with("C.1")), "batch served by a C-rung");
        assert_eq!(metrics.jobs_completed.load(Ordering::Relaxed), 6);
        assert_eq!(metrics.jobs_submitted.load(Ordering::Relaxed), 6);
        assert_eq!(metrics.runs_executed.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.jobs_in_system.load(Ordering::Relaxed), 0, "every slot settled");
        assert_eq!(metrics.dispatches_in_flight.load(Ordering::Relaxed), 0);
        assert_eq!(metrics.lane_fill_ratio(), 1.0, "the 4-job bucket filled its batch");
        // Observability rode along: one e2e sample per completed job
        // (the invariant the CI metrics leg asserts), one trace each.
        assert_eq!(metrics.obs.e2e_us.snapshot().count(), 6);
        assert_eq!(metrics.obs.queue_wait_us.snapshot().count(), 6);
        assert_eq!(metrics.obs.traces.pushed(), 6);
        let traces = metrics.obs.traces.recent(16);
        assert!(traces.iter().all(|t| t.ok));
        assert!(traces.iter().any(|t| t.kind == "run"));
        for t in &traces {
            assert!(
                t.timing.stage_sum_us() <= t.timing.e2e_us,
                "consecutive stages cannot exceed e2e: {:?}",
                t.timing
            );
        }
        let fills = metrics.obs.fill.snapshot();
        assert_eq!(fills["4x4x8"].counts[4], 1, "the full batch recorded occupancy 4");
        assert!(metrics.obs.pool_task_us.snapshot().count() >= 2, "pool tasks were timed");
    }

    /// Bounded admission: over-cap submissions are refused with a
    /// retry hint derived from queue depth and the flush deadline, no
    /// admitted job is ever dropped, and shutdown drains the backlog.
    #[test]
    fn over_cap_submissions_are_refused_with_retry_hint() {
        let cfg = ServiceConfig {
            lanes: 4,
            threads: 1,
            flush_ms: 5_000, // far beyond the test: nothing dispatches
            max_queue: 2,
            exp: ExpMode::Fast,
            ..ServiceConfig::default()
        };
        let engine = start(&cfg).unwrap();
        let submitter = engine.submitter();
        let (reply_tx, reply_rx) = channel::<String>();
        // Two same-shape jobs fill the cap (the 4-lane bucket holds them
        // until the distant flush deadline).
        submitter.submit(SubmitPayload::Job(spec("a", 8, 1)), reply_tx.clone()).unwrap();
        submitter.submit(SubmitPayload::Job(spec("b", 8, 2)), reply_tx.clone()).unwrap();
        // The third must be refused — deterministically, because nothing
        // can leave the queue before the 5 s flush.
        let refused = submitter.submit(SubmitPayload::Job(spec("c", 8, 3)), reply_tx.clone());
        match refused {
            Err(SubmitRejected::Overloaded { retry_after_ms }) => {
                assert!(
                    retry_after_ms >= 5_000,
                    "hint covers at least one flush deadline: {retry_after_ms}"
                );
                assert!(retry_after_ms <= 60_000, "hint is capped: {retry_after_ms}");
            }
            other => panic!("expected Overloaded, got {other:?}"),
        }
        let metrics = Arc::clone(&engine.metrics);
        assert_eq!(metrics.jobs_overloaded.load(Ordering::Relaxed), 1);
        drop(reply_tx);
        drop(submitter);
        engine.shutdown(); // drain answers both admitted jobs
        let lines: Vec<String> = reply_rx.iter().collect();
        assert_eq!(lines.len(), 2, "both admitted jobs answered: {lines:?}");
        for line in &lines {
            // from_line rejects any non-"ok" status line.
            JobResult::from_line(line).unwrap();
        }
        assert_eq!(metrics.jobs_in_system.load(Ordering::Relaxed), 0);
    }
}
