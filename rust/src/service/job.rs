//! Wire format of the sampling service: job requests, control ops and
//! per-job results, all JSON-lines over the dependency-free
//! [`crate::util::json`] subset.
//!
//! **Protocol version 1.** Requests may carry `"protocol_version": 1`
//! and a `"sampler"` spec ([`crate::engine::SamplerSpec`], the typed
//! request envelope); responses always carry `"protocol_version": 1`
//! plus a `"plan"` object echoing the *resolved* sampler
//! (rung/width/backend — the serving analogue of reporting the fraction
//! of vector width utilized).  The v0 line format (no version field, no
//! sampler) remains accepted unchanged, and every v0 response field
//! (`kind`, `lanes`, `occupancy`, ...) is still emitted.
//!
//! A request line is either a job object (every field optional except
//! `id`) or a control op:
//!
//! ```text
//! {"id":"j1","width":4,"height":4,"layers":8,"model_seed":3,"jtau":0.3,
//!  "sweeps":100,"beta":0.8,"seed":42,"trace_every":0,"want_state":true}
//! {"protocol_version":1,"op":"submit",
//!  "job":{"id":"j2","layers":2,"sampler":{"rung":"c1","width":"auto","backend":"auto"}}}
//! {"op":"stats"}
//! {"op":"shutdown"}
//! ```
//!
//! Each job yields exactly one result line (`status` `"ok"` or
//! `"error"`), streamed back as soon as its lane-batch completes.  The
//! served trajectory is **bit-exact** to the scalar A.2 run of the same
//! job (`repro job-run`), whichever lane of whichever batch it landed on
//! — that is the C-rung correctness contract (see `tests/replica_batch.rs`).
//!
//! Jobs may instead pin `rung: m1` (the bit-packed multi-spin path):
//! they dispatch as singles outside the lane buckets, sweep the
//! **±1-coupling** workload family
//! ([`crate::ising::builder::pm_torus_workload`] for the same
//! width/height/layers/model_seed/jtau — a different model than the
//! Gaussian-free torus the other rungs build), and their trajectory is
//! **not** bit-exact to A.2: the multi-spin sweep visits spins in
//! bit-packed checkerboard order, so it is a different (equally valid)
//! Markov chain.  The A.2 oracle contract applies to the C-rung path
//! only.
//!
//! Jobs may also pin `rung: b1` or `rung: b2` (the accel lane): they
//! dispatch as singles on the in-process software device
//! ([`crate::device`], 32-thread warps with counted coalesced/strided
//! memory transactions).  The device sweeps in the scalar visit order,
//! so accel-lane results **are** bit-exact to the A.2 oracle
//! (`repro job-run`) — the same contract as the C-rungs.

use crate::coordinator::{Checkpoint, RunReport, RunSpec};
use crate::engine::{Resolved, Rung, SamplerSpec, Width};
use crate::ising::builder::{pm_torus_workload, torus_workload, Workload};
use crate::obs::StageTiming;
use crate::sweep::SweepStats;
use crate::util::json::{self, Value};
use crate::Result;

/// The service wire-protocol version this build speaks (the Engine API
/// version).  Version-0 lines (no `protocol_version` field) are accepted
/// for back-compat; responses are always stamped with the current
/// version.
pub use crate::engine::PROTOCOL_VERSION;

/// Shape-bucket key of the lane-batching scheduler: jobs with equal keys
/// build identically-shaped models — same torus dims and layer count,
/// hence the same CSR edge topology — so they can share one lane-batch
/// regardless of couplings (`model_seed`, `jtau`), β, sweeps or RNG seed.
#[derive(Copy, Clone, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ShapeKey {
    pub width: usize,
    pub height: usize,
    pub layers: usize,
}

impl std::fmt::Display for ShapeKey {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}x{}x{}", self.width, self.height, self.layers)
    }
}

/// A validated sampling job: sweep a torus QMC workload for `sweeps`
/// Metropolis sweeps at inverse temperature `beta`, RNG stream `seed`.
#[derive(Clone, Debug)]
pub struct JobSpec {
    pub id: String,
    pub width: usize,
    pub height: usize,
    pub layers: usize,
    /// Workload seed (couplings, fields, initial state).
    pub model_seed: u64,
    /// Inter-layer coupling.
    pub jtau: f32,
    pub sweeps: usize,
    pub beta: f32,
    /// MT19937 stream seed — the scalar A.2 reference uses the same one.
    pub seed: u32,
    /// Record the energy every this many sweeps (0 = no trace).
    pub trace_every: usize,
    /// Return the final spin state in the result.
    pub want_state: bool,
    /// Echo per-stage lifecycle durations (`"timing"`, µs) in the
    /// result line.  The stages are always *measured* (they feed the
    /// service latency histograms); this flag only controls the wire
    /// echo.
    pub want_timing: bool,
    /// v1: requested sampler spec.  `None` (v0 lines) means "whatever
    /// the service deems best" — the lane-batched C-rung with scalar
    /// fallback.  `rung: a2` forces the scalar reference path; `rung:
    /// c1` may pin width/backend, checked against the service's executor
    /// at admission.
    pub sampler: Option<SamplerSpec>,
}

impl JobSpec {
    pub fn shape(&self) -> ShapeKey {
        ShapeKey { width: self.width, height: self.height, layers: self.layers }
    }

    /// Build the job's workload (deterministic in `model_seed`).  An
    /// m1-pinned job builds the ±1-coupling family the bit-packed sweep
    /// needs; everything else builds the Gaussian-free torus.
    pub fn workload(&self) -> Workload {
        if self.wants_multispin() {
            let (w, h, l) = (self.width, self.height, self.layers);
            return pm_torus_workload(w, h, l, self.model_seed, self.jtau);
        }
        torus_workload(self.width, self.height, self.layers, self.model_seed, self.jtau)
    }

    /// Parse a job object (not a control op), applying defaults, then
    /// validate.
    pub fn from_value(v: &Value) -> Result<JobSpec> {
        let us = |key: &str, default: usize| -> Result<usize> {
            match v.opt(key) {
                None => Ok(default),
                Some(x) => x.as_usize().map_err(|e| anyhow::anyhow!("field {key:?}: {e}")),
            }
        };
        let fl = |key: &str, default: f64| -> Result<f64> {
            match v.opt(key) {
                None => Ok(default),
                Some(x) => x.as_f64().map_err(|e| anyhow::anyhow!("field {key:?}: {e}")),
            }
        };
        let seed = us("seed", 1)?;
        anyhow::ensure!(
            seed <= u32::MAX as usize,
            "seed must fit in u32 (got {seed}) — a truncated seed would silently alias \
             another stream"
        );
        let spec = JobSpec {
            id: v.get("id")?.as_str()?.to_string(),
            width: us("width", 8)?,
            height: us("height", 8)?,
            layers: us("layers", 8)?,
            model_seed: us("model_seed", 1)? as u64,
            jtau: fl("jtau", 0.3)? as f32,
            sweeps: us("sweeps", 100)?,
            beta: fl("beta", 1.0)? as f32,
            seed: seed as u32,
            trace_every: us("trace_every", 0)?,
            want_state: v.opt("want_state").map(|x| x.as_bool()).transpose()?.unwrap_or(false),
            want_timing: v.opt("want_timing").map(|x| x.as_bool()).transpose()?.unwrap_or(false),
            sampler: match v.opt("sampler") {
                Some(sv) => {
                    Some(SamplerSpec::from_value(sv).map_err(|e| anyhow::anyhow!("sampler: {e}"))?)
                }
                None => None,
            },
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Whether the job's sampler pins the scalar reference path (rung
    /// `a2`) — such jobs skip lane-batching entirely.
    pub fn wants_scalar(&self) -> bool {
        matches!(self.sampler, Some(s) if s.rung == Rung::A2)
    }

    /// Whether the job's sampler pins the lane-batched C-rung — such
    /// jobs may never fall back to the scalar path, even when flushed
    /// alone (they go out as a padded one-lane batch instead).
    pub fn pins_batch(&self) -> bool {
        matches!(self.sampler, Some(s) if s.rung == Rung::C1)
    }

    /// Whether the job's sampler pins the bit-packed multi-spin rung
    /// (`m1`) — such jobs bypass lane-packing and dispatch as singles
    /// on the multi-spin path (64 layer bit-lanes inside one job).
    pub fn wants_multispin(&self) -> bool {
        matches!(self.sampler, Some(s) if s.rung == Rung::M1)
    }

    /// Whether the job's sampler pins an accelerator rung (`b1`/`b2`) —
    /// such jobs bypass lane-packing and dispatch as singles on the
    /// software device (32-thread warps inside one job).
    pub fn wants_accel(&self) -> bool {
        matches!(self.sampler, Some(s) if s.rung.is_accel())
    }

    /// Admission checks: the same geometry rules the C-rungs need
    /// (even torus dims, `layers >= 2`) plus service abuse bounds.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            !self.id.is_empty() && self.id.len() <= 128,
            "id must be 1..=128 characters"
        );
        anyhow::ensure!(
            self.width >= 2 && self.height >= 2 && self.width % 2 == 0 && self.height % 2 == 0,
            "torus dims must be even and >= 2 (got {}x{})",
            self.width,
            self.height
        );
        anyhow::ensure!(
            self.layers >= 2 && self.layers <= 1024,
            "layers must be in 2..=1024 (got {})",
            self.layers
        );
        let n_spins = self.width * self.height * self.layers;
        anyhow::ensure!(
            n_spins <= 1 << 21,
            "model too large: {} spins (limit {})",
            n_spins,
            1usize << 21
        );
        anyhow::ensure!(
            self.sweeps >= 1 && self.sweeps <= 1_000_000,
            "sweeps must be in 1..=1000000 (got {})",
            self.sweeps
        );
        // Cap the total work of one job so a single dispatch can never
        // stall the scheduler (and its lane-mates) for long.
        let updates = n_spins as u64 * self.sweeps as u64;
        anyhow::ensure!(
            updates <= 1 << 31,
            "job too heavy: {} spin-updates (limit {})",
            updates,
            1u64 << 31
        );
        if self.trace_every > 0 {
            anyhow::ensure!(
                self.sweeps / self.trace_every <= 10_000,
                "energy trace too long: {} points (limit 10000) — raise trace_every",
                self.sweeps / self.trace_every
            );
        }
        anyhow::ensure!(
            self.beta.is_finite() && self.beta > 0.0,
            "beta must be finite and positive (got {})",
            self.beta
        );
        anyhow::ensure!(self.jtau.is_finite(), "jtau must be finite");
        if let Some(s) = self.sampler {
            anyhow::ensure!(
                matches!(s.rung, Rung::C1 | Rung::A2 | Rung::M1 | Rung::B1 | Rung::B2),
                "sampler rung {} is not servable: the service lane-batches through c1, runs m1 \
                 and b1/b2 as singles (bit-packed / software device), and falls back to the \
                 scalar a2 reference",
                s.rung
            );
            if s.rung == Rung::A2 {
                anyhow::ensure!(
                    matches!(s.width, Width::Auto | Width::W(1)),
                    "the scalar a2 path has width 1 (sampler requested {})",
                    s.width
                );
            }
            if s.rung == Rung::M1 {
                anyhow::ensure!(
                    matches!(s.width, Width::Auto | Width::W(64)),
                    "the m1 multi-spin path packs 64 layers per word — its width is fixed at 64 \
                     (sampler requested {})",
                    s.width
                );
                anyhow::ensure!(
                    self.layers % 2 == 0,
                    "m1 needs an even layer count for its checkerboard phases (got {})",
                    self.layers
                );
            }
            if s.rung.is_accel() {
                anyhow::ensure!(
                    matches!(s.width, Width::Auto | Width::W(32)),
                    "the accel rungs run 32-thread warps — their width is fixed at 32 \
                     (sampler requested {})",
                    s.width
                );
                if s.rung == Rung::B2 {
                    anyhow::ensure!(
                        self.layers % 2 == 0,
                        "b2's coalesced layout pair-packs the tau ring — it needs an even \
                         layer count (got {}); b1 takes any layers >= 2",
                        self.layers
                    );
                }
            }
        }
        Ok(())
    }

    /// Serialize back to a request line (clients, benches, tests).
    pub fn to_line(&self) -> String {
        let mut pairs = vec![
            ("id", json::str_v(&self.id)),
            ("width", json::num(self.width as f64)),
            ("height", json::num(self.height as f64)),
            ("layers", json::num(self.layers as f64)),
            ("model_seed", json::num(self.model_seed as f64)),
            ("jtau", json::num(self.jtau as f64)),
            ("sweeps", json::num(self.sweeps as f64)),
            ("beta", json::num(self.beta as f64)),
            ("seed", json::num(self.seed as f64)),
            ("trace_every", json::num(self.trace_every as f64)),
            ("want_state", Value::Bool(self.want_state)),
        ];
        if self.want_timing {
            pairs.push(("want_timing", Value::Bool(true)));
        }
        if let Some(s) = self.sampler {
            pairs.push(("protocol_version", json::num(PROTOCOL_VERSION as f64)));
            pairs.push(("sampler", s.to_value()));
        }
        json::obj(pairs).to_string()
    }
}

/// A checkpointable full-run job (`{"op":"run", ...}`): a complete
/// [`RunSpec`] executed server-side through the coordinator, optionally
/// resuming from an inline schema-v2 [`Checkpoint`] and optionally
/// returning the final checkpoint inline — so a client can drive a long
/// tempering run through the service in resumable segments without the
/// server keeping any state between requests.
///
/// ```text
/// {"op":"run","id":"r1","run_spec":{"version":1,"config":{...},
///  "sampler":{"rung":"c1","width":"auto"}},"want_checkpoint":true}
/// {"op":"run","id":"r2","run_spec":{...},"checkpoint":{...},"want_checkpoint":true}
/// ```
///
/// Run jobs are admitted like any other job and execute as
/// fire-and-forget tasks on the engine's sweep pool (they are whole
/// parallel-tempering runs, not lane-batchable sweep requests), so a
/// long run never stalls its connection's reader loop; the same
/// per-request work cap as plain jobs applies.
#[derive(Clone, Debug)]
pub struct RunJob {
    pub id: String,
    pub spec: RunSpec,
    /// Inline checkpoint to resume from (its workload must match the
    /// spec's — checked by the coordinator).
    pub checkpoint: Option<Checkpoint>,
    /// Return the final checkpoint inline in the result line.
    pub want_checkpoint: bool,
}

impl RunJob {
    /// Hard cap on one run job's total spin-updates (the same bound as
    /// a plain job, so a run request can never stall a connection for
    /// unbounded time).
    pub const MAX_UPDATES: u64 = 1 << 31;

    pub fn from_value(v: &Value) -> Result<RunJob> {
        let job = RunJob {
            id: v.get("id")?.as_str()?.to_string(),
            spec: RunSpec::from_value(v.get("run_spec")?)
                .map_err(|e| anyhow::anyhow!("run_spec: {e}"))?,
            checkpoint: match v.opt("checkpoint") {
                Some(cv) => Some(
                    Checkpoint::from_value(cv).map_err(|e| anyhow::anyhow!("checkpoint: {e}"))?,
                ),
                None => None,
            },
            want_checkpoint: v
                .opt("want_checkpoint")
                .map(|x| x.as_bool())
                .transpose()?
                .unwrap_or(false),
        };
        job.validate()?;
        Ok(job)
    }

    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            !self.id.is_empty() && self.id.len() <= 128,
            "id must be 1..=128 characters"
        );
        self.spec.validate()?;
        anyhow::ensure!(
            self.spec.config.total_updates() <= Self::MAX_UPDATES,
            "run too heavy: {} spin-updates (limit {})",
            self.spec.config.total_updates(),
            Self::MAX_UPDATES
        );
        anyhow::ensure!(
            self.spec.config.threads <= 8,
            "run jobs are capped at 8 worker threads (got {})",
            self.spec.config.threads
        );
        Ok(())
    }

    /// Serialize back to a request line (clients, tests).
    pub fn to_line(&self) -> String {
        let mut pairs = vec![
            ("protocol_version", json::num(PROTOCOL_VERSION as f64)),
            ("op", json::str_v("run")),
            ("id", json::str_v(&self.id)),
            ("run_spec", self.spec.to_value()),
        ];
        if let Some(ck) = &self.checkpoint {
            pairs.push(("checkpoint", ck.to_value()));
        }
        if self.want_checkpoint {
            pairs.push(("want_checkpoint", Value::Bool(true)));
        }
        json::obj(pairs).to_string()
    }

    /// The result line of a completed run job: the full [`RunReport`]
    /// (with its per-group `plans` echo) plus, when requested, the
    /// final schema-v2 checkpoint inline.
    pub fn result_line(id: &str, report: &RunReport, ck: Option<&Checkpoint>) -> String {
        let mut pairs = vec![
            ("protocol_version", json::num(PROTOCOL_VERSION as f64)),
            ("id", json::str_v(id)),
            ("status", json::str_v("ok")),
            ("op", json::str_v("run")),
            ("run_report", report.to_value()),
        ];
        if let Some(ck) = ck {
            pairs.push(("checkpoint", ck.to_value()));
        }
        json::obj(pairs).to_string()
    }
}

/// A parsed request line.
pub enum Request {
    Job(JobSpec),
    /// A checkpointable full-run job (executed on the sweep pool).
    Run(Box<RunJob>),
    /// Capability handshake: protocol version, host fingerprint,
    /// servable rungs, resolved backend and queue config — what a
    /// router (or any client) needs for capability-aware placement.
    Hello,
    Stats,
    /// Prometheus text exposition of the service metrics.
    Metrics,
    /// The most recent `last` completed-job traces from the trace ring.
    Trace { last: usize },
    Shutdown,
}

/// Traces returned by `{"op":"trace"}` when `last` is omitted.
pub const DEFAULT_TRACE_LAST: usize = 32;

/// Parse one request line: a control op (`{"op": ...}`) or a job object,
/// in the v1 envelope (`"protocol_version": 1`) or the bare v0 format.
pub fn parse_request(line: &str) -> Result<Request> {
    let v = Value::parse(line)?;
    if let Some(pv) = v.opt("protocol_version") {
        let pv = pv.as_usize().map_err(|e| anyhow::anyhow!("protocol_version: {e}"))?;
        anyhow::ensure!(
            pv == PROTOCOL_VERSION,
            "unsupported protocol_version {pv}: this server speaks version {PROTOCOL_VERSION} \
             (omit the field for the unversioned v0 line format)"
        );
    }
    if let Some(op) = v.opt("op") {
        return match op.as_str()? {
            "hello" => Ok(Request::Hello),
            "stats" => Ok(Request::Stats),
            "metrics" => Ok(Request::Metrics),
            "trace" => {
                let last = match v.opt("last") {
                    None => DEFAULT_TRACE_LAST,
                    Some(x) => x.as_usize().map_err(|e| anyhow::anyhow!("field \"last\": {e}"))?,
                };
                anyhow::ensure!(last >= 1, "trace op needs last >= 1 (got {last})");
                Ok(Request::Trace { last })
            }
            "shutdown" => Ok(Request::Shutdown),
            "submit" => Ok(Request::Job(JobSpec::from_value(v.get("job")?)?)),
            "run" => Ok(Request::Run(Box::new(RunJob::from_value(&v)?))),
            other => {
                anyhow::bail!(
                    "unknown op {other:?} (expected hello, stats, metrics, trace, shutdown, \
                     submit or run)"
                )
            }
        };
    }
    Ok(Request::Job(JobSpec::from_value(&v)?))
}

/// The resolved plan a result line echoes back (v1): which rung, at what
/// width, on which backend the job actually ran.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PlanEcho {
    pub rung: String,
    pub width: usize,
    pub backend: String,
}

impl PlanEcho {
    /// The scalar A.2 reference path.
    pub fn scalar() -> Self {
        Self { rung: "a2".into(), width: 1, backend: "scalar".into() }
    }

    pub fn of(r: Resolved) -> Self {
        Self {
            rung: r.rung.as_str().to_string(),
            width: r.width,
            backend: r.backend.as_str().to_string(),
        }
    }

    fn to_value(&self) -> Value {
        json::obj(vec![
            ("rung", json::str_v(&self.rung)),
            ("width", json::num(self.width as f64)),
            ("backend", json::str_v(&self.backend)),
        ])
    }

    fn from_value(v: &Value) -> Result<Self> {
        Ok(Self {
            rung: v.get("rung")?.as_str()?.to_string(),
            width: v.get("width")?.as_usize()?,
            backend: v.get("backend")?.as_str()?.to_string(),
        })
    }
}

/// The outcome of one served job.
#[derive(Clone, Debug)]
pub struct JobResult {
    pub id: String,
    /// Final total energy after `sweeps` sweeps.
    pub energy: f64,
    /// Flip statistics accumulated over exactly the job's own sweeps.
    pub stats: SweepStats,
    /// Rung that served the job: a C-rung label for lane-batched jobs,
    /// "A.2" for the scalar fallback (the v0 field; v1 clients read
    /// `plan` instead).
    pub kind: String,
    /// Vector width of the serving batch (1 for the scalar fallback).
    pub lanes: usize,
    /// Active (non-padded) lanes in the serving batch.
    pub occupancy: usize,
    /// Energies recorded every `trace_every` sweeps (empty when 0).
    pub energy_trace: Vec<f64>,
    /// Final spin state (original layer-major order) when requested.
    pub state: Option<Vec<f32>>,
    /// v1: the resolved plan that served the job (`None` only when
    /// parsed back from a v0 line).
    pub plan: Option<PlanEcho>,
    /// Per-stage lifecycle durations (µs), echoed when the job asked
    /// with `"want_timing": true`.  The stage sum is ≤ the end-to-end
    /// latency by construction (consecutive intervals, floor-rounded).
    pub timing: Option<StageTiming>,
}

impl JobResult {
    /// Serialize as a result line (always stamped with the current
    /// protocol version; every v0 field is still present).
    pub fn to_line(&self) -> String {
        let mut pairs = vec![
            ("protocol_version", json::num(PROTOCOL_VERSION as f64)),
            ("id", json::str_v(&self.id)),
            ("status", json::str_v("ok")),
            ("kind", json::str_v(&self.kind)),
            ("lanes", json::num(self.lanes as f64)),
            ("occupancy", json::num(self.occupancy as f64)),
            ("energy", json::num(self.energy)),
            ("flips", json::num(self.stats.flips as f64)),
            ("attempts", json::num(self.stats.attempts as f64)),
            ("flip_prob", json::num(self.stats.flip_prob())),
        ];
        if let Some(plan) = &self.plan {
            pairs.push(("plan", plan.to_value()));
        }
        if let Some(timing) = &self.timing {
            pairs.push(("timing", timing.to_value()));
        }
        if !self.energy_trace.is_empty() {
            pairs.push(("energy_trace", json::arr_f64(&self.energy_trace)));
        }
        if let Some(state) = &self.state {
            let arr = Value::Arr(state.iter().map(|&x| Value::Num(x as f64)).collect());
            pairs.push(("state", arr));
        }
        json::obj(pairs).to_string()
    }

    /// An error result line for a job that could not be served.
    pub fn error_line(id: &str, msg: &str) -> String {
        json::obj(vec![
            ("protocol_version", json::num(PROTOCOL_VERSION as f64)),
            ("id", json::str_v(id)),
            ("status", json::str_v("error")),
            ("error", json::str_v(msg)),
        ])
        .to_string()
    }

    /// The structured backpressure rejection: the admission queue is at
    /// its cap, retry after the hinted backoff (derived from queue
    /// depth and the flush deadline).
    pub fn overloaded_line(id: &str, retry_after_ms: u64) -> String {
        json::obj(vec![
            ("protocol_version", json::num(PROTOCOL_VERSION as f64)),
            ("id", json::str_v(id)),
            ("status", json::str_v("error")),
            ("error", json::str_v("overloaded")),
            ("retry_after_ms", json::num(retry_after_ms as f64)),
        ])
        .to_string()
    }

    /// Parse a result line back (clients and tests); errors on
    /// `status != "ok"` lines.  Accepts v0 lines (no version, no plan).
    pub fn from_line(line: &str) -> Result<JobResult> {
        let v = Value::parse(line)?;
        let status = v.get("status")?.as_str()?;
        anyhow::ensure!(status == "ok", "result status {status:?}: {line}");
        Ok(JobResult {
            id: v.get("id")?.as_str()?.to_string(),
            energy: v.get("energy")?.as_f64()?,
            stats: SweepStats {
                attempts: v.get("attempts")?.as_f64()? as u64,
                flips: v.get("flips")?.as_f64()? as u64,
                groups: 0,
                groups_with_flip: 0,
            },
            kind: v.get("kind")?.as_str()?.to_string(),
            lanes: v.get("lanes")?.as_usize()?,
            occupancy: v.get("occupancy")?.as_usize()?,
            energy_trace: match v.opt("energy_trace") {
                Some(t) => t.as_arr()?.iter().map(|x| x.as_f64()).collect::<Result<_>>()?,
                None => Vec::new(),
            },
            state: match v.opt("state") {
                Some(t) => Some(
                    t.as_arr()?
                        .iter()
                        .map(|x| x.as_f64().map(|f| f as f32))
                        .collect::<Result<_>>()?,
                ),
                None => None,
            },
            plan: match v.opt("plan") {
                Some(p) => Some(PlanEcho::from_value(p)?),
                None => None,
            },
            timing: match v.opt("timing") {
                Some(t) => Some(StageTiming::from_value(t)?),
                None => None,
            },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base_line() -> String {
        r#"{"id":"j1","width":4,"height":4,"layers":8,"sweeps":50,"beta":0.8,"seed":7}"#
            .to_string()
    }

    #[test]
    fn job_lines_parse_with_defaults() {
        let Request::Job(spec) = parse_request(&base_line()).unwrap() else {
            panic!("expected a job");
        };
        assert_eq!(spec.id, "j1");
        assert_eq!(spec.shape(), ShapeKey { width: 4, height: 4, layers: 8 });
        assert_eq!(spec.model_seed, 1); // default
        assert_eq!(spec.trace_every, 0);
        assert!(!spec.want_state);
        // round-trips through to_line
        let Request::Job(again) = parse_request(&spec.to_line()).unwrap() else {
            panic!("expected a job");
        };
        assert_eq!(again.id, spec.id);
        assert_eq!(again.seed, spec.seed);
        assert_eq!(again.beta, spec.beta);
    }

    #[test]
    fn control_ops_parse() {
        assert!(matches!(parse_request(r#"{"op":"stats"}"#).unwrap(), Request::Stats));
        assert!(matches!(parse_request(r#"{"op":"shutdown"}"#).unwrap(), Request::Shutdown));
        assert!(matches!(parse_request(r#"{"op":"metrics"}"#).unwrap(), Request::Metrics));
        let line = format!(r#"{{"op":"submit","job":{}}}"#, base_line());
        assert!(matches!(parse_request(&line).unwrap(), Request::Job(_)));
        assert!(parse_request(r#"{"op":"nope"}"#).is_err());
        assert!(parse_request("not json").is_err());
    }

    #[test]
    fn trace_op_parses_with_default_and_explicit_depth() {
        match parse_request(r#"{"op":"trace"}"#).unwrap() {
            Request::Trace { last } => assert_eq!(last, DEFAULT_TRACE_LAST),
            _ => panic!("expected trace"),
        }
        match parse_request(r#"{"op":"trace","last":5}"#).unwrap() {
            Request::Trace { last } => assert_eq!(last, 5),
            _ => panic!("expected trace"),
        }
        assert!(parse_request(r#"{"op":"trace","last":0}"#).is_err());
    }

    #[test]
    fn want_timing_parses_and_roundtrips() {
        let Request::Job(spec) = parse_request(&base_line()).unwrap() else { panic!("job") };
        assert!(!spec.want_timing, "timing echo is opt-in");
        let line = r#"{"id":"t1","layers":8,"want_timing":true}"#;
        let Request::Job(spec) = parse_request(line).unwrap() else { panic!("job") };
        assert!(spec.want_timing);
        let Request::Job(again) = parse_request(&spec.to_line()).unwrap() else { panic!("job") };
        assert!(again.want_timing, "to_line carries the flag");
    }

    #[test]
    fn validation_rejects_bad_jobs() {
        let cases = [
            r#"{"width":4}"#,                              // missing id
            r#"{"id":"x","width":5}"#,                     // odd torus dim
            r#"{"id":"x","layers":1}"#,                    // layers < 2
            r#"{"id":"x","sweeps":0}"#,                    // no sweeps
            r#"{"id":"x","beta":-1.0}"#,                   // bad beta
            r#"{"id":"x","width":64,"height":64,"layers":1024}"#, // too big
            r#"{"id":"x","seed":4294967296}"#,             // seed > u32::MAX (would alias)
            r#"{"id":"x","width":32,"height":32,"layers":64,"sweeps":100000}"#, // too heavy
            r#"{"id":"x","sweeps":100000,"trace_every":1}"#, // trace too long
        ];
        for line in cases {
            assert!(parse_request(line).is_err(), "should reject {line}");
        }
    }

    #[test]
    fn results_roundtrip_with_state_and_trace() {
        let r = JobResult {
            id: "j9".into(),
            energy: -12.5,
            stats: SweepStats { attempts: 100, flips: 7, groups: 100, groups_with_flip: 7 },
            kind: "C.1".into(),
            lanes: 4,
            occupancy: 3,
            energy_trace: vec![-10.0, -11.25],
            state: Some(vec![1.0, -1.0, -1.0, 1.0]),
            plan: Some(PlanEcho { rung: "c1".into(), width: 4, backend: "sse2".into() }),
            timing: Some(StageTiming {
                admit_us: 2,
                queue_us: 1400,
                dispatch_us: 12,
                setup_us: 90,
                sweep_us: 5100,
                reply_us: 8,
                e2e_us: 6615,
            }),
        };
        let line = r.to_line();
        let back = JobResult::from_line(&line).unwrap();
        assert_eq!(back.id, "j9");
        assert_eq!(back.energy.to_bits(), r.energy.to_bits());
        assert_eq!(back.stats.flips, 7);
        assert_eq!(back.occupancy, 3);
        assert_eq!(back.energy_trace, r.energy_trace);
        assert_eq!(back.state, r.state);
        assert_eq!(back.plan, r.plan, "v1 results echo the resolved plan");
        assert_eq!(back.timing, r.timing, "timing echoes through the wire");
        let timing = back.timing.unwrap();
        assert!(timing.stage_sum_us() <= timing.e2e_us);
        // The response envelope is versioned.
        let v = Value::parse(&line).unwrap();
        assert_eq!(v.get("protocol_version").unwrap().as_usize().unwrap(), PROTOCOL_VERSION);
        let err_line = JobResult::error_line("j9", "boom");
        assert!(JobResult::from_line(&err_line).is_err());
        let ev = Value::parse(&err_line).unwrap();
        assert_eq!(ev.get("protocol_version").unwrap().as_usize().unwrap(), PROTOCOL_VERSION);
    }

    #[test]
    fn v0_result_lines_still_parse() {
        // A pre-v1 response: no protocol_version, no plan.
        let line = r#"{"id":"old","status":"ok","kind":"A.2","lanes":1,"occupancy":1,
                       "energy":-3.5,"flips":2,"attempts":10,"flip_prob":0.2}"#
            .replace('\n', "");
        let r = JobResult::from_line(&line).unwrap();
        assert_eq!(r.kind, "A.2");
        assert_eq!(r.plan, None);
    }

    #[test]
    fn v1_envelopes_parse_and_bad_versions_error() {
        // v1 job with a sampler spec.
        let line = r#"{"protocol_version":1,"id":"j1","width":4,"height":4,"layers":2,
                       "sweeps":10,"beta":0.8,"sampler":{"rung":"c1","width":"auto"}}"#
            .replace('\n', "");
        let Request::Job(spec) = parse_request(&line).unwrap() else { panic!("expected job") };
        let sampler = spec.sampler.expect("sampler");
        assert_eq!(sampler.rung, Rung::C1);
        assert_eq!(sampler.width, Width::Auto);
        assert!(!spec.wants_scalar());
        // round-trips through to_line (which stamps the version).
        let Request::Job(again) = parse_request(&spec.to_line()).unwrap() else {
            panic!("expected job")
        };
        assert_eq!(again.sampler, spec.sampler);
        // v1 envelope around a control op.
        assert!(matches!(
            parse_request(r#"{"protocol_version":1,"op":"stats"}"#).unwrap(),
            Request::Stats
        ));
        // Unknown versions are refused loudly, not mis-parsed.
        let err = parse_request(r#"{"protocol_version":2,"op":"stats"}"#).err().unwrap();
        assert!(format!("{err:#}").contains("unsupported protocol_version"));
    }

    #[test]
    fn run_jobs_parse_validate_and_roundtrip() {
        use crate::coordinator::RunConfig;
        let rs = RunSpec::new(
            RunConfig { n_models: 3, sweeps: 20, sweeps_per_round: 10, ..RunConfig::default() },
            SamplerSpec::rung(Rung::C1),
        );
        let job = RunJob { id: "r1".into(), spec: rs.clone(), checkpoint: None, want_checkpoint: true };
        let line = job.to_line();
        let Request::Run(parsed) = parse_request(&line).unwrap() else { panic!("expected run") };
        assert_eq!(parsed.id, "r1");
        assert!(parsed.want_checkpoint);
        assert_eq!(parsed.spec.sampler.rung, Rung::C1);
        assert_eq!(parsed.spec.config.n_models, 3);
        assert!(parsed.checkpoint.is_none());
        // Accelerator rungs are servable as run jobs: the software
        // device keeps its RNG on the host, so they checkpoint like any
        // other rung.
        let accel = RunJob {
            id: "r2".into(),
            spec: RunSpec::new(rs.config.clone(), crate::sweep::SweepKind::B2Accel),
            checkpoint: None,
            want_checkpoint: false,
        };
        let Request::Run(accel_parsed) = parse_request(&accel.to_line()).unwrap() else {
            panic!("expected run")
        };
        assert!(accel_parsed.spec.sampler.rung.is_accel());
        // The per-request work cap applies.
        let heavy = RunJob {
            id: "r3".into(),
            spec: RunSpec::new(
                RunConfig {
                    width: 32,
                    height: 32,
                    layers: 64,
                    n_models: 40,
                    sweeps: 100_000,
                    sweeps_per_round: 100,
                    ..RunConfig::default()
                },
                SamplerSpec::rung(Rung::C1),
            ),
            checkpoint: None,
            want_checkpoint: false,
        };
        let err = parse_request(&heavy.to_line()).err().unwrap();
        assert!(format!("{err:#}").contains("too heavy"));
    }

    #[test]
    fn multispin_sampler_routes_and_validates() {
        let line = r#"{"id":"m1","width":4,"height":4,"layers":8,"sampler":{"rung":"m1"}}"#;
        let Request::Job(spec) = parse_request(line).unwrap() else { panic!("expected job") };
        assert!(spec.wants_multispin());
        assert!(!spec.wants_scalar() && !spec.pins_batch());
        // The m1 workload is the ±1-coupling family: every space coupling
        // is exactly +1 or -1 (the generic torus draws Gaussians).
        let wl = spec.workload();
        assert!(wl.model.base.edges.iter().all(|&(_, _, j)| j == 1.0 || j == -1.0));
        // Width is fixed at 64 bit-lanes; layer counts must be even.
        assert!(parse_request(r#"{"id":"m2","sampler":{"rung":"m1","width":64}}"#).is_ok());
        assert!(parse_request(r#"{"id":"m3","sampler":{"rung":"m1","width":8}}"#).is_err());
        let odd = r#"{"id":"m4","layers":9,"sampler":{"rung":"m1"}}"#;
        assert!(parse_request(odd).is_err());
    }

    #[test]
    fn scalar_sampler_routes_and_bad_samplers_reject() {
        let line = r#"{"id":"s1","layers":8,"sampler":{"rung":"a2"}}"#;
        let Request::Job(spec) = parse_request(line).unwrap() else { panic!("expected job") };
        assert!(spec.wants_scalar());
        // a2 at a vector width is contradictory.
        assert!(parse_request(r#"{"id":"s2","sampler":{"rung":"a2","width":4}}"#).is_err());
        // The service does not serve the within-model A vector rungs.
        assert!(parse_request(r#"{"id":"s4","sampler":{"rung":"a4"}}"#).is_err());
        assert!(parse_request(r#"{"id":"s5","sampler":{"rung":"nope"}}"#).is_err());
    }

    #[test]
    fn accel_sampler_routes_and_validates() {
        let line = r#"{"id":"b1","width":4,"height":4,"layers":8,"sampler":{"rung":"b2"}}"#;
        let Request::Job(spec) = parse_request(line).unwrap() else { panic!("expected job") };
        assert!(spec.wants_accel());
        assert!(!spec.wants_scalar() && !spec.pins_batch() && !spec.wants_multispin());
        // Width is fixed at the 32-thread warp.
        assert!(parse_request(r#"{"id":"b2","sampler":{"rung":"b1","width":32}}"#).is_ok());
        assert!(parse_request(r#"{"id":"b3","sampler":{"rung":"b1","width":8}}"#).is_err());
        // b2 needs an even depth; b1 takes any layers >= 2.
        assert!(parse_request(r#"{"id":"b4","layers":9,"sampler":{"rung":"b2"}}"#).is_err());
        assert!(parse_request(r#"{"id":"b5","layers":9,"sampler":{"rung":"b1"}}"#).is_ok());
    }
}
