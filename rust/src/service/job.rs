//! Wire format of the sampling service: job requests, control ops and
//! per-job results, all JSON-lines over the dependency-free
//! [`crate::util::json`] subset.
//!
//! A request line is either a job object (every field optional except
//! `id`) or a control op:
//!
//! ```text
//! {"id":"j1","width":4,"height":4,"layers":8,"model_seed":3,"jtau":0.3,
//!  "sweeps":100,"beta":0.8,"seed":42,"trace_every":0,"want_state":true}
//! {"op":"stats"}
//! {"op":"shutdown"}
//! {"op":"submit","job":{...}}        # explicit-op spelling of a job line
//! ```
//!
//! Each job yields exactly one result line (`status` `"ok"` or
//! `"error"`), streamed back as soon as its lane-batch completes.  The
//! served trajectory is **bit-exact** to the scalar A.2 run of the same
//! job (`repro job-run`), whichever lane of whichever batch it landed on
//! — that is the C-rung correctness contract (see `tests/replica_batch.rs`).

use crate::ising::builder::{torus_workload, Workload};
use crate::sweep::SweepStats;
use crate::util::json::{self, Value};
use crate::Result;

/// Shape-bucket key of the lane-batching scheduler: jobs with equal keys
/// build identically-shaped models — same torus dims and layer count,
/// hence the same CSR edge topology — so they can share one lane-batch
/// regardless of couplings (`model_seed`, `jtau`), β, sweeps or RNG seed.
#[derive(Copy, Clone, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ShapeKey {
    pub width: usize,
    pub height: usize,
    pub layers: usize,
}

impl std::fmt::Display for ShapeKey {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}x{}x{}", self.width, self.height, self.layers)
    }
}

/// A validated sampling job: sweep a torus QMC workload for `sweeps`
/// Metropolis sweeps at inverse temperature `beta`, RNG stream `seed`.
#[derive(Clone, Debug)]
pub struct JobSpec {
    pub id: String,
    pub width: usize,
    pub height: usize,
    pub layers: usize,
    /// Workload seed (couplings, fields, initial state).
    pub model_seed: u64,
    /// Inter-layer coupling.
    pub jtau: f32,
    pub sweeps: usize,
    pub beta: f32,
    /// MT19937 stream seed — the scalar A.2 reference uses the same one.
    pub seed: u32,
    /// Record the energy every this many sweeps (0 = no trace).
    pub trace_every: usize,
    /// Return the final spin state in the result.
    pub want_state: bool,
}

impl JobSpec {
    pub fn shape(&self) -> ShapeKey {
        ShapeKey { width: self.width, height: self.height, layers: self.layers }
    }

    /// Build the job's workload (deterministic in `model_seed`).
    pub fn workload(&self) -> Workload {
        torus_workload(self.width, self.height, self.layers, self.model_seed, self.jtau)
    }

    /// Parse a job object (not a control op), applying defaults, then
    /// validate.
    pub fn from_value(v: &Value) -> Result<JobSpec> {
        let us = |key: &str, default: usize| -> Result<usize> {
            match v.opt(key) {
                None => Ok(default),
                Some(x) => x.as_usize().map_err(|e| anyhow::anyhow!("field {key:?}: {e}")),
            }
        };
        let fl = |key: &str, default: f64| -> Result<f64> {
            match v.opt(key) {
                None => Ok(default),
                Some(x) => x.as_f64().map_err(|e| anyhow::anyhow!("field {key:?}: {e}")),
            }
        };
        let seed = us("seed", 1)?;
        anyhow::ensure!(
            seed <= u32::MAX as usize,
            "seed must fit in u32 (got {seed}) — a truncated seed would silently alias \
             another stream"
        );
        let spec = JobSpec {
            id: v.get("id")?.as_str()?.to_string(),
            width: us("width", 8)?,
            height: us("height", 8)?,
            layers: us("layers", 8)?,
            model_seed: us("model_seed", 1)? as u64,
            jtau: fl("jtau", 0.3)? as f32,
            sweeps: us("sweeps", 100)?,
            beta: fl("beta", 1.0)? as f32,
            seed: seed as u32,
            trace_every: us("trace_every", 0)?,
            want_state: v.opt("want_state").map(|x| x.as_bool()).transpose()?.unwrap_or(false),
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Admission checks: the same geometry rules the C-rungs need
    /// (even torus dims, `layers >= 2`) plus service abuse bounds.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            !self.id.is_empty() && self.id.len() <= 128,
            "id must be 1..=128 characters"
        );
        anyhow::ensure!(
            self.width >= 2 && self.height >= 2 && self.width % 2 == 0 && self.height % 2 == 0,
            "torus dims must be even and >= 2 (got {}x{})",
            self.width,
            self.height
        );
        anyhow::ensure!(
            self.layers >= 2 && self.layers <= 1024,
            "layers must be in 2..=1024 (got {})",
            self.layers
        );
        let n_spins = self.width * self.height * self.layers;
        anyhow::ensure!(
            n_spins <= 1 << 21,
            "model too large: {} spins (limit {})",
            n_spins,
            1usize << 21
        );
        anyhow::ensure!(
            self.sweeps >= 1 && self.sweeps <= 1_000_000,
            "sweeps must be in 1..=1000000 (got {})",
            self.sweeps
        );
        // Cap the total work of one job so a single dispatch can never
        // stall the scheduler (and its lane-mates) for long.
        let updates = n_spins as u64 * self.sweeps as u64;
        anyhow::ensure!(
            updates <= 1 << 31,
            "job too heavy: {} spin-updates (limit {})",
            updates,
            1u64 << 31
        );
        if self.trace_every > 0 {
            anyhow::ensure!(
                self.sweeps / self.trace_every <= 10_000,
                "energy trace too long: {} points (limit 10000) — raise trace_every",
                self.sweeps / self.trace_every
            );
        }
        anyhow::ensure!(
            self.beta.is_finite() && self.beta > 0.0,
            "beta must be finite and positive (got {})",
            self.beta
        );
        anyhow::ensure!(self.jtau.is_finite(), "jtau must be finite");
        Ok(())
    }

    /// Serialize back to a request line (clients, benches, tests).
    pub fn to_line(&self) -> String {
        json::obj(vec![
            ("id", json::str_v(&self.id)),
            ("width", json::num(self.width as f64)),
            ("height", json::num(self.height as f64)),
            ("layers", json::num(self.layers as f64)),
            ("model_seed", json::num(self.model_seed as f64)),
            ("jtau", json::num(self.jtau as f64)),
            ("sweeps", json::num(self.sweeps as f64)),
            ("beta", json::num(self.beta as f64)),
            ("seed", json::num(self.seed as f64)),
            ("trace_every", json::num(self.trace_every as f64)),
            ("want_state", Value::Bool(self.want_state)),
        ])
        .to_string()
    }
}

/// A parsed request line.
pub enum Request {
    Job(JobSpec),
    Stats,
    Shutdown,
}

/// Parse one request line: a control op (`{"op": ...}`) or a job object.
pub fn parse_request(line: &str) -> Result<Request> {
    let v = Value::parse(line)?;
    if let Some(op) = v.opt("op") {
        return match op.as_str()? {
            "stats" => Ok(Request::Stats),
            "shutdown" => Ok(Request::Shutdown),
            "submit" => Ok(Request::Job(JobSpec::from_value(v.get("job")?)?)),
            other => anyhow::bail!("unknown op {other:?} (expected stats, shutdown or submit)"),
        };
    }
    Ok(Request::Job(JobSpec::from_value(&v)?))
}

/// The outcome of one served job.
#[derive(Clone, Debug)]
pub struct JobResult {
    pub id: String,
    /// Final total energy after `sweeps` sweeps.
    pub energy: f64,
    /// Flip statistics accumulated over exactly the job's own sweeps.
    pub stats: SweepStats,
    /// Rung that served the job: a C-rung label for lane-batched jobs,
    /// "A.2" for the scalar fallback.
    pub kind: String,
    /// Vector width of the serving batch (1 for the scalar fallback).
    pub lanes: usize,
    /// Active (non-padded) lanes in the serving batch.
    pub occupancy: usize,
    /// Energies recorded every `trace_every` sweeps (empty when 0).
    pub energy_trace: Vec<f64>,
    /// Final spin state (original layer-major order) when requested.
    pub state: Option<Vec<f32>>,
}

impl JobResult {
    /// Serialize as a result line.
    pub fn to_line(&self) -> String {
        let mut pairs = vec![
            ("id", json::str_v(&self.id)),
            ("status", json::str_v("ok")),
            ("kind", json::str_v(&self.kind)),
            ("lanes", json::num(self.lanes as f64)),
            ("occupancy", json::num(self.occupancy as f64)),
            ("energy", json::num(self.energy)),
            ("flips", json::num(self.stats.flips as f64)),
            ("attempts", json::num(self.stats.attempts as f64)),
            ("flip_prob", json::num(self.stats.flip_prob())),
        ];
        if !self.energy_trace.is_empty() {
            pairs.push(("energy_trace", json::arr_f64(&self.energy_trace)));
        }
        if let Some(state) = &self.state {
            let arr = Value::Arr(state.iter().map(|&x| Value::Num(x as f64)).collect());
            pairs.push(("state", arr));
        }
        json::obj(pairs).to_string()
    }

    /// An error result line for a job that could not be served.
    pub fn error_line(id: &str, msg: &str) -> String {
        json::obj(vec![
            ("id", json::str_v(id)),
            ("status", json::str_v("error")),
            ("error", json::str_v(msg)),
        ])
        .to_string()
    }

    /// Parse a result line back (clients and tests); errors on
    /// `status != "ok"` lines.
    pub fn from_line(line: &str) -> Result<JobResult> {
        let v = Value::parse(line)?;
        let status = v.get("status")?.as_str()?;
        anyhow::ensure!(status == "ok", "result status {status:?}: {line}");
        Ok(JobResult {
            id: v.get("id")?.as_str()?.to_string(),
            energy: v.get("energy")?.as_f64()?,
            stats: SweepStats {
                attempts: v.get("attempts")?.as_f64()? as u64,
                flips: v.get("flips")?.as_f64()? as u64,
                groups: 0,
                groups_with_flip: 0,
            },
            kind: v.get("kind")?.as_str()?.to_string(),
            lanes: v.get("lanes")?.as_usize()?,
            occupancy: v.get("occupancy")?.as_usize()?,
            energy_trace: match v.opt("energy_trace") {
                Some(t) => t.as_arr()?.iter().map(|x| x.as_f64()).collect::<Result<_>>()?,
                None => Vec::new(),
            },
            state: match v.opt("state") {
                Some(t) => Some(
                    t.as_arr()?
                        .iter()
                        .map(|x| x.as_f64().map(|f| f as f32))
                        .collect::<Result<_>>()?,
                ),
                None => None,
            },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base_line() -> String {
        r#"{"id":"j1","width":4,"height":4,"layers":8,"sweeps":50,"beta":0.8,"seed":7}"#
            .to_string()
    }

    #[test]
    fn job_lines_parse_with_defaults() {
        let Request::Job(spec) = parse_request(&base_line()).unwrap() else {
            panic!("expected a job");
        };
        assert_eq!(spec.id, "j1");
        assert_eq!(spec.shape(), ShapeKey { width: 4, height: 4, layers: 8 });
        assert_eq!(spec.model_seed, 1); // default
        assert_eq!(spec.trace_every, 0);
        assert!(!spec.want_state);
        // round-trips through to_line
        let Request::Job(again) = parse_request(&spec.to_line()).unwrap() else {
            panic!("expected a job");
        };
        assert_eq!(again.id, spec.id);
        assert_eq!(again.seed, spec.seed);
        assert_eq!(again.beta, spec.beta);
    }

    #[test]
    fn control_ops_parse() {
        assert!(matches!(parse_request(r#"{"op":"stats"}"#).unwrap(), Request::Stats));
        assert!(matches!(parse_request(r#"{"op":"shutdown"}"#).unwrap(), Request::Shutdown));
        let line = format!(r#"{{"op":"submit","job":{}}}"#, base_line());
        assert!(matches!(parse_request(&line).unwrap(), Request::Job(_)));
        assert!(parse_request(r#"{"op":"nope"}"#).is_err());
        assert!(parse_request("not json").is_err());
    }

    #[test]
    fn validation_rejects_bad_jobs() {
        let cases = [
            r#"{"width":4}"#,                              // missing id
            r#"{"id":"x","width":5}"#,                     // odd torus dim
            r#"{"id":"x","layers":1}"#,                    // layers < 2
            r#"{"id":"x","sweeps":0}"#,                    // no sweeps
            r#"{"id":"x","beta":-1.0}"#,                   // bad beta
            r#"{"id":"x","width":64,"height":64,"layers":1024}"#, // too big
            r#"{"id":"x","seed":4294967296}"#,             // seed > u32::MAX (would alias)
            r#"{"id":"x","width":32,"height":32,"layers":64,"sweeps":100000}"#, // too heavy
            r#"{"id":"x","sweeps":100000,"trace_every":1}"#, // trace too long
        ];
        for line in cases {
            assert!(parse_request(line).is_err(), "should reject {line}");
        }
    }

    #[test]
    fn results_roundtrip_with_state_and_trace() {
        let r = JobResult {
            id: "j9".into(),
            energy: -12.5,
            stats: SweepStats { attempts: 100, flips: 7, groups: 100, groups_with_flip: 7 },
            kind: "C.1".into(),
            lanes: 4,
            occupancy: 3,
            energy_trace: vec![-10.0, -11.25],
            state: Some(vec![1.0, -1.0, -1.0, 1.0]),
        };
        let back = JobResult::from_line(&r.to_line()).unwrap();
        assert_eq!(back.id, "j9");
        assert_eq!(back.energy.to_bits(), r.energy.to_bits());
        assert_eq!(back.stats.flips, 7);
        assert_eq!(back.occupancy, 3);
        assert_eq!(back.energy_trace, r.energy_trace);
        assert_eq!(back.state, r.state);
        assert!(JobResult::from_line(&JobResult::error_line("j9", "boom")).is_err());
    }
}
