//! Dispatch execution: turn a [`Dispatch`] into per-job results.
//!
//! A [`DispatchWork::Batch`] builds one C-rung lane-batch (padded to `W`
//! with discarded clone lanes, exactly like the tempering ensemble pads
//! its tail batch) and sweeps all lanes in lockstep; a
//! [`DispatchWork::Single`] runs the scalar A.2 sweeper.  Either way every
//! job's trajectory is **bit-exact** to the standalone scalar A.2 run of
//! the same job — [`Executor::run_single`] *is* that reference run, and
//! the C-rung differential suite guarantees each lane reproduces it.
//!
//! Jobs in one batch may ask for different sweep counts: the batch
//! executes in chunks between the union of all lanes' capture points, and
//! each lane's result (energy, state, stats, trace) is captured exactly
//! at its own sweep count.  Lanes past their target keep sweeping as
//! padding until the longest job finishes — lanes never interact, so
//! that is purely discarded work, never a perturbation.

use std::collections::BTreeSet;

use crate::engine::{self, Backend, BackendPref, EngineBuilder, Resolved, Rung, SamplerSpec, Width};
use crate::ising::QmcModel;
use crate::sweep::{ExpMode, SweepStats};
use crate::Result;

use super::batcher::{Dispatch, DispatchWork, PendingJob};
use super::job::{JobResult, JobSpec, PlanEcho};

/// Executes dispatches on the current thread (the engine runs one
/// executor call per sweep-pool task).  `Copy`, so pool tasks can take
/// it by value.
#[derive(Copy, Clone)]
pub struct Executor {
    /// The negotiated serving C-rung (rung, backend, width) — echoed as
    /// the `plan` of every lane-batched result.
    pub resolved: Resolved,
    /// Lane width `W` (== `resolved.width`).
    pub width: usize,
    /// Exponential mode — `Fast` by default; the wide fast exp is
    /// lane-exact to the scalar one, so serving stays bit-exact either way.
    pub exp: ExpMode,
}

impl Executor {
    pub fn new(lanes: usize, exp: ExpMode) -> Result<Self> {
        Self::with_backend(lanes, BackendPref::Auto, exp)
    }

    /// Negotiate the serving C-rung once at startup: `lanes` ∈ {4, 8, 16}
    /// and a backend preference, resolved through the engine (AVX2/SSE2
    /// when available, portable lanes otherwise or when forced).
    pub fn with_backend(lanes: usize, backend: BackendPref, exp: ExpMode) -> Result<Self> {
        anyhow::ensure!(
            matches!(lanes, 4 | 8 | 16),
            "lane width must be 4, 8 or 16 (got {lanes})"
        );
        let plan = EngineBuilder::new(SamplerSpec::rung(Rung::C1).w(lanes).on(backend))
            .exp(exp)
            .plan()?;
        Ok(Self { resolved: plan.resolved(), width: plan.width, exp })
    }

    /// Admission check for a job's requested sampler against this
    /// executor: `a2` always passes (scalar path); `m1` passes when its
    /// backend axis is compatible (the bit-packed sweep is scalar ALU
    /// work); `c1` must be compatible with the negotiated serving width
    /// and backend.
    pub fn admits(&self, spec: &JobSpec) -> Result<()> {
        let Some(s) = spec.sampler else { return Ok(()) };
        match s.rung {
            Rung::A2 => Ok(()),
            Rung::M1 => {
                anyhow::ensure!(
                    matches!(s.backend, BackendPref::Auto | BackendPref::Portable),
                    "the m1 path sweeps bit-packed words on the scalar ALU (job requested \
                     backend {})",
                    s.backend
                );
                Ok(())
            }
            Rung::B1 | Rung::B2 => {
                anyhow::ensure!(
                    matches!(s.backend, BackendPref::Auto | BackendPref::Accel),
                    "the accel rungs run on the software device (job requested backend {})",
                    s.backend
                );
                Ok(())
            }
            Rung::C1 => {
                if let Width::W(w) = s.width {
                    anyhow::ensure!(
                        w == self.width,
                        "this service lane-batches at width {} (job requested {w}); resubmit \
                         with width auto or {}",
                        self.width,
                        self.width
                    );
                }
                anyhow::ensure!(
                    self.resolved.backend.satisfies(s.backend),
                    "this service serves backend {} (job requested {})",
                    self.resolved.backend,
                    s.backend
                );
                Ok(())
            }
            other => anyhow::bail!("sampler rung {other} is not servable"),
        }
    }

    /// Run one dispatch to completion, returning each job with its
    /// outcome (jobs are handed back so the caller can route replies).
    pub fn run_dispatch(&self, dispatch: Dispatch) -> Vec<(PendingJob, Result<JobResult>)> {
        match dispatch.work {
            DispatchWork::Single(mut job) => {
                job.timeline.sweep_start = Some(std::time::Instant::now());
                let outcome = self.run_single(&job.spec);
                job.timeline.sweep_end = Some(std::time::Instant::now());
                vec![(job, outcome)]
            }
            DispatchWork::Batch(jobs) => self.run_batch(jobs),
        }
    }

    /// The resolved plan of the scalar A.2 reference path.
    pub const SCALAR: Resolved = Resolved { rung: Rung::A2, backend: Backend::Scalar, width: 1 };

    /// The resolved plan of the bit-packed multi-spin path (64 layer
    /// bit-lanes inside one job; the word sweep is scalar ALU work).
    pub const MULTISPIN: Resolved =
        Resolved { rung: Rung::M1, backend: Backend::Scalar, width: 64 };

    /// The single-job path: the scalar A.2 reference for plain jobs
    /// (exactly the run a standalone invocation would execute — also the
    /// bit-exactness oracle for C-rung served results, `repro job-run`),
    /// the bit-packed m1 sweep for m1-pinned jobs (a different Markov
    /// chain on the ±1 workload family — not A.2-bit-exact by design),
    /// or the software device for accel-pinned jobs (same visit order as
    /// A.2, so bit-exact to the oracle).  All instantiate through the
    /// engine's single dispatch point, like the lane-batched path.
    pub fn run_single(&self, spec: &JobSpec) -> Result<JobResult> {
        let resolved = if spec.wants_multispin() {
            Self::MULTISPIN
        } else if spec.wants_accel() {
            Resolved {
                rung: spec.sampler.expect("accel jobs pin a sampler").rung,
                backend: Backend::Accel,
                width: 32,
            }
        } else {
            Self::SCALAR
        };
        let wl = spec.workload();
        let mut sweeper =
            engine::builder::instantiate(resolved, &wl.model, &wl.s0, spec.seed, self.exp)?;
        let mut stats = SweepStats::default();
        let mut trace = Vec::new();
        let mut done = 0usize;
        for p in capture_points(spec) {
            stats.merge(&sweeper.run(p - done, spec.beta));
            done = p;
            if traces_at(spec, p) {
                trace.push(sweeper.energy());
            }
        }
        Ok(JobResult {
            id: spec.id.clone(),
            energy: sweeper.energy(),
            stats,
            kind: resolved.label(),
            lanes: resolved.width,
            // For m1 the "lanes" are layer bits: with fewer than 64
            // layers the top bits of each word are padding.  For the
            // accel rungs they are warp threads, filled by spins.
            occupancy: if spec.wants_accel() {
                (spec.width * spec.height * spec.layers).min(resolved.width)
            } else {
                spec.layers.min(resolved.width).max(1)
            },
            energy_trace: trace,
            state: if spec.want_state { Some(sweeper.state()) } else { None },
            plan: Some(PlanEcho::of(resolved)),
            // Stage durations are folded in by the engine at reply time
            // (the executor only stamps the sweep window).
            timing: None,
        })
    }

    fn run_batch(&self, mut jobs: Vec<PendingJob>) -> Vec<(PendingJob, Result<JobResult>)> {
        match self.try_run_batch(&mut jobs) {
            Ok(results) => jobs.into_iter().zip(results.into_iter().map(Ok)).collect(),
            Err(e) => {
                // Whole-batch construction failure (cannot happen for
                // shape-bucketed jobs): fail every member with the cause.
                let msg = format!("{e:#}");
                jobs.into_iter().map(|job| (job, Err(anyhow::anyhow!("{}", msg)))).collect()
            }
        }
    }

    fn try_run_batch(&self, jobs: &mut [PendingJob]) -> Result<Vec<JobResult>> {
        let w = self.width;
        let n = jobs.len();
        // n == 1 happens only for sampler-pinned C-rung jobs flushed
        // alone: they run as a fully padded batch rather than silently
        // degrading to the scalar path they explicitly opted out of.
        anyhow::ensure!(n >= 1 && n <= w, "a batch dispatch packs 1..=W jobs (got {n})");

        let workloads: Vec<_> = jobs.iter().map(|job| job.spec.workload()).collect();
        let mut models: Vec<QmcModel> = workloads.iter().map(|wl| wl.model.clone()).collect();
        let mut states: Vec<Vec<f32>> = workloads.iter().map(|wl| wl.s0.clone()).collect();
        let mut seeds: Vec<u32> = jobs.iter().map(|job| job.spec.seed).collect();
        let mut betas: Vec<f32> = jobs.iter().map(|job| job.spec.beta).collect();
        for k in n..w {
            // Padding: clone the last job's replica with an off-stream
            // seed, as the tempering tail batch does — the padded chain is
            // discarded and lanes never interact.
            models.push(models[n - 1].clone());
            states.push(states[n - 1].clone());
            seeds.push(seeds[n - 1] ^ 0x8000_0000 ^ (k as u32));
            betas.push(betas[n - 1]);
        }
        let mut batch = engine::builder::instantiate_batch(
            self.resolved,
            &models,
            &states,
            &seeds,
            self.exp,
        )?;
        // Sweeping starts now: everything above (workload builds, lane
        // interleave, sweeper construction) is the `setup_us` stage.
        let sweep_start = std::time::Instant::now();
        for job in jobs.iter_mut() {
            job.timeline.sweep_start = Some(sweep_start);
        }

        let mut points = BTreeSet::new();
        for job in jobs.iter() {
            points.extend(capture_points(&job.spec));
        }
        let mut stats = vec![SweepStats::default(); n];
        let mut traces: Vec<Vec<f64>> = vec![Vec::new(); n];
        let mut results: Vec<Option<JobResult>> = (0..n).map(|_| None).collect();
        let mut done = 0usize;
        for p in points {
            let per_lane = batch.run(p - done, &betas);
            done = p;
            for (k, job) in jobs.iter().enumerate() {
                let spec = &job.spec;
                if p <= spec.sweeps {
                    stats[k].merge(&per_lane[k]);
                }
                if traces_at(spec, p) {
                    traces[k].push(batch.energy_of(k));
                }
                if p == spec.sweeps {
                    results[k] = Some(JobResult {
                        id: spec.id.clone(),
                        energy: batch.energy_of(k),
                        stats: stats[k],
                        kind: self.resolved.label(),
                        lanes: w,
                        occupancy: n,
                        energy_trace: std::mem::take(&mut traces[k]),
                        state: if spec.want_state { Some(batch.state_of(k)) } else { None },
                        plan: Some(PlanEcho::of(self.resolved)),
                        timing: None,
                    });
                }
            }
        }
        let sweep_end = std::time::Instant::now();
        for job in jobs.iter_mut() {
            job.timeline.sweep_end = Some(sweep_end);
        }
        Ok(results
            .into_iter()
            .map(|r| r.expect("every lane's sweep count is a capture point"))
            .collect())
    }
}

/// Sorted sweep counts at which the batch must pause: every lane's final
/// sweep count plus its energy-trace points.
fn capture_points(spec: &JobSpec) -> Vec<usize> {
    let mut points = BTreeSet::new();
    points.insert(spec.sweeps);
    if spec.trace_every > 0 {
        let mut t = spec.trace_every;
        while t < spec.sweeps {
            points.insert(t);
            t += spec.trace_every;
        }
    }
    points.into_iter().collect()
}

/// Whether sweep count `p` is an energy-trace point of `spec`.
fn traces_at(spec: &JobSpec, p: usize) -> bool {
    spec.trace_every > 0 && p <= spec.sweeps && p % spec.trace_every == 0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn m1_pinned_jobs_run_the_multispin_path() {
        let spec = JobSpec {
            id: "m".into(),
            width: 4,
            height: 4,
            layers: 8,
            model_seed: 3,
            jtau: 0.5,
            sweeps: 5,
            beta: 0.7,
            seed: 11,
            trace_every: 0,
            want_state: true,
            want_timing: false,
            sampler: Some(SamplerSpec::rung(Rung::M1)),
        };
        let exec = Executor::new(4, ExpMode::Fast).unwrap();
        exec.admits(&spec).unwrap();
        let r = exec.run_single(&spec).unwrap();
        assert_eq!(r.kind, "M.1");
        assert_eq!(r.lanes, 64);
        assert_eq!(r.occupancy, 8, "8 layer bit-lanes carry spins");
        assert_eq!(r.stats.attempts, 5 * 4 * 4 * 8, "every spin attempted once per sweep");
        assert!(r.stats.flips > 0);
        assert_eq!(r.plan.as_ref().unwrap().rung, "m1");
        let state = r.state.as_ref().unwrap();
        assert_eq!(state.len(), 4 * 4 * 8);
        assert!(state.iter().all(|&s| s == 1.0 || s == -1.0));
        assert_eq!(r.energy.to_bits(), spec.workload().model.total_energy(state).to_bits());
        // The bit-packed sweep is scalar ALU work: a pinned SIMD backend
        // is refused at admission.
        let mut pinned = spec.clone();
        pinned.sampler = Some(SamplerSpec::rung(Rung::M1).on(BackendPref::Avx2));
        assert!(exec.admits(&pinned).is_err());
    }

    #[test]
    fn accel_pinned_jobs_run_the_device_path() {
        let spec = JobSpec {
            id: "b".into(),
            width: 4,
            height: 4,
            layers: 8,
            model_seed: 3,
            jtau: 0.5,
            sweeps: 5,
            beta: 0.7,
            seed: 11,
            trace_every: 0,
            want_state: true,
            want_timing: false,
            sampler: Some(SamplerSpec::rung(Rung::B2)),
        };
        let exec = Executor::new(4, ExpMode::Fast).unwrap();
        exec.admits(&spec).unwrap();
        let r = exec.run_single(&spec).unwrap();
        assert_eq!(r.kind, "B.2");
        assert_eq!(r.lanes, 32);
        assert_eq!(r.occupancy, 32, "128 spins fill every warp thread");
        assert_eq!(r.stats.attempts, 5 * 4 * 4 * 8, "every spin attempted once per sweep");
        assert!(r.stats.flips > 0);
        assert_eq!(r.plan.as_ref().unwrap().rung, "b2");
        assert_eq!(r.plan.as_ref().unwrap().backend, "accel");
        let state = r.state.as_ref().unwrap();
        assert_eq!(state.len(), 4 * 4 * 8);
        // The device sweeps in the scalar visit order: bit-exact to the
        // A.2 oracle run of the same job.
        let mut plain = spec.clone();
        plain.sampler = None;
        let oracle = exec.run_single(&plain).unwrap();
        assert_eq!(r.energy.to_bits(), oracle.energy.to_bits());
        assert_eq!(r.stats.flips, oracle.stats.flips);
        assert_eq!(r.state, oracle.state);
        // A pinned SIMD backend is refused at admission — the device
        // picks its own micro-backend.
        let mut pinned = spec.clone();
        pinned.sampler = Some(SamplerSpec::rung(Rung::B1).on(BackendPref::Avx2));
        assert!(exec.admits(&pinned).is_err());
    }

    #[test]
    fn capture_points_cover_trace_and_final() {
        let mut spec = JobSpec {
            id: "t".into(),
            width: 4,
            height: 4,
            layers: 8,
            model_seed: 1,
            jtau: 0.3,
            sweeps: 10,
            beta: 0.8,
            seed: 1,
            trace_every: 4,
            want_state: false,
            want_timing: false,
            sampler: None,
        };
        assert_eq!(capture_points(&spec), vec![4, 8, 10]);
        assert!(traces_at(&spec, 4) && traces_at(&spec, 8));
        assert!(!traces_at(&spec, 10));
        spec.trace_every = 5;
        assert_eq!(capture_points(&spec), vec![5, 10]);
        assert!(traces_at(&spec, 10), "final sweep that lands on the grid is traced");
        spec.trace_every = 0;
        assert_eq!(capture_points(&spec), vec![10]);
        assert!(!traces_at(&spec, 10));
    }
}
