//! The sampling service: a long-running job queue plus a **dynamic
//! lane-batching scheduler** that serves sweep requests through the
//! C-rungs — the vector width itself as the unit of multi-tenancy.
//!
//! The paper's throughput lesson is that every SIMD lane must carry
//! homogeneous work.  The C-rungs (PR 2) built that substrate for one
//! pre-configured tempering ladder per process; this subsystem turns it
//! into a server: independent sampling jobs from many clients are
//! validated, bucketed by model *shape* (torus dims × layers ⇒ identical
//! CSR topology), and packed `W` at a time into one
//! [`crate::ising::replica_batch::ReplicaBatchModel`] +
//! [`crate::sweep::c1_replica_batch::C1ReplicaBatch`] lane-batch — the
//! same batching-across-independent-simulations trick GPU Monte Carlo
//! codes use to saturate wide devices, applied to CPU vector units.
//!
//! ```text
//! clients ──JSON-lines──▶ admission ─▶ shape buckets ─▶ lane batches ─▶ SweepPool
//!    ▲                   (validate)    (FIFO per shape,  (W jobs per      (persistent
//!    └──── result lines ◀── engine ◀── deadline flush)    C-rung batch)    workers)
//! ```
//!
//! * Full buckets dispatch immediately (lane fill 1); stragglers flush
//!   on a deadline — ≥ 2 as a padded batch, a lone job on a scalar A.2
//!   sweeper — so time-to-dispatch is bounded and every shape is
//!   servable (admission caps per-job work, bounding the rounds too).
//! * Dispatch rounds are fire-and-forget pool tasks: the scheduler
//!   never blocks on execution, and admission is **bounded**
//!   (`--max-queue`) — over-cap submissions get a structured
//!   `{"error":"overloaded","retry_after_ms":...}` rejection instead of
//!   unbounded queueing.
//! * Results stream back per job as batches complete, **bit-exact** to a
//!   standalone scalar A.2 run with the same seed (the C-rung
//!   differential contract).
//! * [`metrics::ServiceMetrics`] exposes queue depth, batch occupancy
//!   and the lane-fill ratio — the service-level analogue of the paper's
//!   "fraction of vector width utilized" — plus the [`crate::obs`]
//!   surface: `{"op":"stats"}` latency percentiles, `{"op":"trace"}`
//!   per-job stage timings, and `{"op":"metrics"}` Prometheus text
//!   (also emitted periodically with `--metrics-every N`).
//!
//! Frontends: `repro serve --listen HOST:PORT` (TCP JSON-lines) or
//! `repro serve` (stdin/stdout); `repro submit` is the client and
//! `repro job-run` the scalar bit-exactness oracle.

pub mod batcher;
pub mod engine;
pub mod executor;
pub mod job;
pub mod metrics;
pub mod server;

use crate::engine::BackendPref;
use crate::sweep::ExpMode;

/// Configuration of one service instance.
#[derive(Copy, Clone, Debug)]
pub struct ServiceConfig {
    /// SIMD lanes per batch: 4, 8 or 16 (default: the widest backend
    /// this host has hand-written code for; 16 runs on the portable
    /// lanes).
    pub lanes: usize,
    /// Backend preference for the serving C-rung (default `Auto`;
    /// resolved through the engine's capability negotiation and echoed
    /// in every result's `plan`).
    pub backend: BackendPref,
    /// Sweep-pool worker threads (1 = dispatches run inline on the
    /// scheduler thread).
    pub threads: usize,
    /// Flush deadline in milliseconds: a shape bucket older than this
    /// dispatches even when not full, bounding job latency.
    pub flush_ms: u64,
    /// Exponential mode (`Fast` by default — bit-exact to the scalar
    /// A.2 reference either way).
    pub exp: ExpMode,
    /// Admission cap: maximum jobs in the system (queued + executing)
    /// before new submissions are refused with a structured
    /// `{"error":"overloaded","retry_after_ms":...}` line (0 =
    /// unbounded).
    pub max_queue: usize,
    /// Emit a Prometheus text snapshot to stderr every N seconds
    /// (`--metrics-every N`; 0 = off).  Stderr, not stdout: the stdout
    /// stream carries protocol lines in stdin mode.
    pub metrics_every_secs: u64,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            lanes: crate::simd::widest_supported_width(),
            backend: BackendPref::Auto,
            threads: 1,
            flush_ms: 25,
            exp: ExpMode::Fast,
            max_queue: 1024,
            metrics_every_secs: 0,
        }
    }
}
