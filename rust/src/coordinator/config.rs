//! Run configurations for the coordinator and the benchmark harness:
//! the workload geometry ([`RunConfig`]) and the versioned, spec-carrying
//! run description ([`RunSpec`]) the Run API v1 surface is built on.

use crate::engine::{EngineBuilder, Plan, SamplerSpec};
use crate::sweep::SweepKind;
use crate::util::json::{self, Value};
use crate::Result;

/// A complete simulation/benchmark configuration.
///
/// The defaults are the scaled workload (runs in seconds on one core);
/// [`RunConfig::paper`] is the paper's §4 geometry: 115 models of
/// 96 × 256 = 24,576 spins, 30,000 sweeps.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Base-graph torus width (spins per layer = width × height).
    pub width: usize,
    /// Base-graph torus height.
    pub height: usize,
    /// QMC layers (multiple of 4, ≥ 8).
    pub layers: usize,
    /// Tempering replicas ("Ising models" in the paper's §4).
    pub n_models: usize,
    /// Total Metropolis sweeps per replica.
    pub sweeps: usize,
    /// Sweeps between replica-exchange attempts.
    pub sweeps_per_round: usize,
    /// Worker threads for the sweep phase.
    pub threads: usize,
    /// Coldest inverse temperature (ladder top).
    pub beta_cold: f32,
    /// Hottest inverse temperature (ladder bottom).
    pub beta_hot: f32,
    /// Inter-layer coupling.
    pub jtau: f32,
    /// Workload seed.
    pub seed: u64,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            width: 8,
            height: 8,
            layers: 32,
            n_models: 8,
            sweeps: 200,
            sweeps_per_round: 10,
            threads: 1,
            beta_cold: 3.0,
            beta_hot: 0.5,
            jtau: 0.3,
            seed: 1,
        }
    }
}

impl RunConfig {
    /// The paper's §4 benchmark scale.
    pub fn paper() -> Self {
        Self {
            width: 12,
            height: 8,
            layers: 256,
            n_models: 115,
            sweeps: 30_000,
            sweeps_per_round: 100,
            ..Self::default()
        }
    }

    pub fn n_base(&self) -> usize {
        self.width * self.height
    }

    pub fn n_spins_per_model(&self) -> usize {
        self.n_base() * self.layers
    }

    /// Total spins across the ensemble (paper: 2,826,240 at full scale).
    pub fn total_spins(&self) -> usize {
        self.n_spins_per_model() * self.n_models
    }

    /// Total single-spin Metropolis updates the run performs.
    pub fn total_updates(&self) -> u64 {
        self.total_spins() as u64 * self.sweeps as u64
    }

    pub fn validate(&self) -> crate::Result<()> {
        if self.layers % 4 != 0 || self.layers < 8 {
            anyhow::bail!("layers must be a multiple of 4 and >= 8 (got {})", self.layers);
        }
        self.validate_common()
    }

    /// Rung-aware validation: the replica-batch (C) rungs vectorize
    /// across the ensemble instead of across layers, so they accept any
    /// layer count ≥ 2 — including the shallow models the A-ladder
    /// geometry rule exists for.  Every other rung keeps [`Self::validate`].
    pub fn validate_for(&self, kind: SweepKind) -> crate::Result<()> {
        self.validate_for_spec(&kind.spec())
    }

    /// [`Self::validate_for`] on the orthogonal spec surface.
    pub fn validate_for_spec(&self, spec: &crate::engine::SamplerSpec) -> crate::Result<()> {
        if spec.rung.is_replica_batch() {
            if self.layers < 2 {
                anyhow::bail!("layers must be >= 2 (got {})", self.layers);
            }
            return self.validate_common();
        }
        if spec.rung.is_multispin() {
            // The m1 checkerboard phases need an even layer count; the
            // A-ladder's multiple-of-4 interlacing rule does not apply.
            if self.layers < 2 || self.layers % 2 != 0 {
                anyhow::bail!("m1 needs an even layer count >= 2 (got {})", self.layers);
            }
            return self.validate_common();
        }
        if spec.rung.is_accel() {
            // The software device sweeps in flat A.2 order, so the
            // A-ladder's multiple-of-4 interlacing rule does not apply.
            // b2's coalesced layout pair-packs the tau ring, so it also
            // needs an even layer count (same parity argument as m1).
            if self.layers < 2 {
                anyhow::bail!("the accel rungs need layers >= 2 (got {})", self.layers);
            }
            if spec.rung == crate::engine::Rung::B2 && self.layers % 2 != 0 {
                anyhow::bail!("b2 needs an even layer count >= 2 (got {})", self.layers);
            }
            return self.validate_common();
        }
        self.validate()
    }

    /// JSON form (the `config` object of run specs and checkpoints).
    pub fn to_value(&self) -> Value {
        json::obj(vec![
            ("width", json::num(self.width as f64)),
            ("height", json::num(self.height as f64)),
            ("layers", json::num(self.layers as f64)),
            ("n_models", json::num(self.n_models as f64)),
            ("sweeps", json::num(self.sweeps as f64)),
            ("sweeps_per_round", json::num(self.sweeps_per_round as f64)),
            ("threads", json::num(self.threads as f64)),
            ("beta_cold", json::num(self.beta_cold as f64)),
            ("beta_hot", json::num(self.beta_hot as f64)),
            ("jtau", json::num(self.jtau as f64)),
            ("seed", json::num(self.seed as f64)),
        ])
    }

    /// Parse the JSON form back.
    pub fn from_value(v: &Value) -> Result<RunConfig> {
        Ok(RunConfig {
            width: v.get("width")?.as_usize()?,
            height: v.get("height")?.as_usize()?,
            layers: v.get("layers")?.as_usize()?,
            n_models: v.get("n_models")?.as_usize()?,
            sweeps: v.get("sweeps")?.as_usize()?,
            sweeps_per_round: v.get("sweeps_per_round")?.as_usize()?,
            threads: v.get("threads")?.as_usize()?,
            beta_cold: v.get("beta_cold")?.as_f64()? as f32,
            beta_hot: v.get("beta_hot")?.as_f64()? as f32,
            jtau: v.get("jtau")?.as_f64()? as f32,
            seed: v.get("seed")?.as_f64()? as u64,
        })
    }

    fn validate_common(&self) -> crate::Result<()> {
        if self.width % 2 != 0 || self.height % 2 != 0 {
            anyhow::bail!("torus dims must be even (got {}x{})", self.width, self.height);
        }
        if self.sweeps % self.sweeps_per_round != 0 {
            anyhow::bail!(
                "sweeps ({}) must be a multiple of sweeps_per_round ({})",
                self.sweeps,
                self.sweeps_per_round
            );
        }
        if self.n_models == 0 || self.threads == 0 {
            anyhow::bail!("n_models and threads must be positive");
        }
        if !(self.beta_cold > self.beta_hot && self.beta_hot > 0.0) {
            anyhow::bail!("need beta_cold > beta_hot > 0");
        }
        Ok(())
    }
}

/// Version of the Run API surface: stamped on every serialized
/// [`RunSpec`] and on every schema-v2 checkpoint.
pub const RUN_SPEC_VERSION: usize = 1;

/// A complete, versioned description of a run: the workload geometry +
/// ladder ([`RunConfig`]) and the sampler to run it with
/// ([`SamplerSpec`]).  This is the Run API v1 surface — the coordinator
/// entry points, the checkpoint format and the service's run jobs all
/// speak `RunSpec`, replacing the old `(RunConfig, SweepKind)` pairing
/// that welded runs to the width-baked legacy enum.
///
/// Serializes as
/// `{"version":1,"config":{...},"sampler":{"rung":"c1","width":16,...}}`
/// and round-trips losslessly, so a run description can travel through
/// files, checkpoints and the service wire format.
#[derive(Clone, Debug)]
pub struct RunSpec {
    pub config: RunConfig,
    pub sampler: SamplerSpec,
}

impl RunSpec {
    /// Pair a workload with anything that lowers onto a sampler spec (a
    /// spec, or a legacy [`SweepKind`] via its `From` lowering).
    pub fn new(config: RunConfig, sampler: impl Into<SamplerSpec>) -> Self {
        Self { config, sampler: sampler.into() }
    }

    /// Rung-aware validation of the workload under this sampler.
    pub fn validate(&self) -> Result<()> {
        self.config.validate_for_spec(&self.sampler)
    }

    /// Negotiate the sampler against host capabilities and the workload
    /// geometry (the same [`Plan`] `repro plan` prints).
    pub fn plan(&self) -> Result<Plan> {
        EngineBuilder::new(self.sampler).layers(self.config.layers).plan()
    }

    pub fn to_value(&self) -> Value {
        json::obj(vec![
            ("version", json::num(RUN_SPEC_VERSION as f64)),
            ("config", self.config.to_value()),
            ("sampler", self.sampler.to_value()),
        ])
    }

    pub fn to_json(&self) -> String {
        self.to_value().to_string()
    }

    /// Parse a serialized run spec.  A missing `version` field is
    /// treated as version 1; future versions are refused loudly.
    pub fn from_value(v: &Value) -> Result<RunSpec> {
        if let Some(ver) = v.opt("version") {
            let ver = ver.as_usize()?;
            anyhow::ensure!(
                ver <= RUN_SPEC_VERSION,
                "run spec version {ver} is newer than this build speaks ({RUN_SPEC_VERSION})"
            );
        }
        Ok(RunSpec {
            config: RunConfig::from_value(v.get("config")?)?,
            sampler: SamplerSpec::from_value(v.get("sampler")?)?,
        })
    }

    pub fn from_json(text: &str) -> Result<RunSpec> {
        Self::from_value(&Value::parse(text)?)
    }
}

/// p50/p90/p99 of a latency distribution in µs — per-round sweep
/// timings in [`RungTiming`], echoed into `BENCH_<rung>.json` so the
/// bench trajectory records tail behaviour, not just the mean.
#[derive(Copy, Clone, Debug, PartialEq)]
pub struct LatencyPercentiles {
    pub p50_us: f64,
    pub p90_us: f64,
    pub p99_us: f64,
}

impl LatencyPercentiles {
    pub fn from_snapshot(snap: &crate::obs::HistogramSnapshot) -> Self {
        let (p50_us, p90_us, p99_us) = snap.percentiles_us();
        Self { p50_us, p90_us, p99_us }
    }

    /// Parse the optional `round_p*_us` triple off an object — all
    /// three or none (a partial triple is a malformed artifact).
    pub fn from_round_fields(v: &Value) -> Result<Option<Self>> {
        match (v.opt("round_p50_us"), v.opt("round_p90_us"), v.opt("round_p99_us")) {
            (Some(a), Some(b), Some(c)) => Ok(Some(Self {
                p50_us: a.as_f64()?,
                p90_us: b.as_f64()?,
                p99_us: c.as_f64()?,
            })),
            (None, None, None) => Ok(None),
            _ => anyhow::bail!("round_p50_us/round_p90_us/round_p99_us must appear together"),
        }
    }
}

/// Per-rung timing result exchanged between build profiles (the opt0
/// binary prints this as JSON; the harness parses it back).
#[derive(Clone, Debug)]
pub struct RungTiming {
    pub kind: String,
    pub threads: usize,
    pub seconds: f64,
    pub sweeps: usize,
    pub updates_per_sec: f64,
    /// `true` when produced by an `opt-level=0` build (the paper's
    /// "compiler optimization disabled" rows).
    pub opt_disabled: bool,
    /// Wall-time percentiles over the timed *rounds* of
    /// `time_sweeps_spec` (`None` in legacy artifacts and single-round
    /// runs, where a distribution is meaningless).
    pub round_latency: Option<LatencyPercentiles>,
}

impl RungTiming {
    pub fn new(kind: SweepKind, threads: usize, seconds: f64, sweeps: usize, updates: u64) -> Self {
        Self::labeled(kind.label(), threads, seconds, sweeps, updates)
    }

    /// [`Self::new`] from a negotiated plan label (covers widths the
    /// legacy enum cannot spell, e.g. `A.4w16`).
    pub fn labeled(label: &str, threads: usize, seconds: f64, sweeps: usize, updates: u64) -> Self {
        Self {
            kind: label.to_string(),
            threads,
            seconds,
            sweeps,
            updates_per_sec: updates as f64 / seconds.max(1e-12),
            opt_disabled: opt_level_is_zero(),
            round_latency: None,
        }
    }

    /// Attach per-round latency percentiles from a timing histogram
    /// (no-op on an empty snapshot — a distribution needs samples).
    pub fn with_round_latency(mut self, snap: &crate::obs::HistogramSnapshot) -> Self {
        if snap.count() > 0 {
            self.round_latency = Some(LatencyPercentiles::from_snapshot(snap));
        }
        self
    }

    pub fn to_json(&self) -> String {
        let mut fields = vec![
            ("kind", json::str_v(&self.kind)),
            ("threads", json::num(self.threads as f64)),
            ("seconds", json::num(self.seconds)),
            ("sweeps", json::num(self.sweeps as f64)),
            ("updates_per_sec", json::num(self.updates_per_sec)),
            ("opt_disabled", Value::Bool(self.opt_disabled)),
        ];
        if let Some(p) = self.round_latency {
            fields.push(("round_p50_us", json::num(p.p50_us)));
            fields.push(("round_p90_us", json::num(p.p90_us)));
            fields.push(("round_p99_us", json::num(p.p99_us)));
        }
        json::obj(fields).to_string()
    }

    pub fn from_json(text: &str) -> Result<Self> {
        let v = Value::parse(text)?;
        Ok(Self {
            kind: v.get("kind")?.as_str()?.to_string(),
            threads: v.get("threads")?.as_usize()?,
            seconds: v.get("seconds")?.as_f64()?,
            sweeps: v.get("sweeps")?.as_usize()?,
            updates_per_sec: v.get("updates_per_sec")?.as_f64()?,
            opt_disabled: v.get("opt_disabled")?.as_bool()?,
            round_latency: LatencyPercentiles::from_round_fields(&v)?,
        })
    }
}

/// Whether this binary was built without optimization (the paper's
/// "compiler optimization disabled" rows).  The `opt0` cargo profile
/// isn't directly observable at compile time, so the Makefile sets
/// `REPRO_OPT0=1` in the environment when building that profile.
pub fn opt_level_is_zero() -> bool {
    option_env!("REPRO_OPT0").is_some()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_validates() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn paper_scale_totals() {
        let c = RunConfig::paper();
        c.validate().unwrap();
        assert_eq!(c.n_spins_per_model(), 24_576);
        assert_eq!(c.total_spins(), 2_826_240);
        assert_eq!(c.total_updates(), 2_826_240u64 * 30_000);
    }

    #[test]
    fn rung_aware_validation_relaxes_layers_for_c_rungs() {
        let shallow = RunConfig { layers: 2, ..RunConfig::default() };
        assert!(shallow.validate().is_err(), "A-ladder geometry still rejects layers=2");
        shallow.validate_for(SweepKind::C1ReplicaBatch).unwrap();
        shallow.validate_for(SweepKind::C1ReplicaBatchW8).unwrap();
        assert!(shallow.validate_for(SweepKind::A4Full).is_err());
        // the common rules still apply to C-rungs
        let bad = RunConfig { layers: 2, width: 7, ..RunConfig::default() };
        assert!(bad.validate_for(SweepKind::C1ReplicaBatch).is_err());
        let one_layer = RunConfig { layers: 1, ..RunConfig::default() };
        assert!(one_layer.validate_for(SweepKind::C1ReplicaBatch).is_err());
    }

    #[test]
    fn rung_aware_validation_covers_the_accel_rungs() {
        use crate::engine::{Rung, SamplerSpec};
        let b1 = SamplerSpec::rung(Rung::B1);
        let b2 = SamplerSpec::rung(Rung::B2);
        let shallow = RunConfig { layers: 2, ..RunConfig::default() };
        shallow.validate_for_spec(&b1).unwrap();
        shallow.validate_for_spec(&b2).unwrap();
        // b1 takes any depth >= 2; b2's pair-packed tau ring needs even.
        let odd = RunConfig { layers: 9, ..RunConfig::default() };
        odd.validate_for_spec(&b1).unwrap();
        assert!(odd.validate_for_spec(&b2).is_err());
        let one = RunConfig { layers: 1, ..RunConfig::default() };
        assert!(one.validate_for_spec(&b1).is_err());
        // the common rules still apply
        let bad = RunConfig { layers: 2, width: 7, ..RunConfig::default() };
        assert!(bad.validate_for_spec(&b1).is_err());
    }

    #[test]
    fn validation_catches_bad_configs() {
        let mut c = RunConfig::default();
        c.layers = 30;
        assert!(c.validate().is_err());
        let mut c = RunConfig::default();
        c.width = 7;
        assert!(c.validate().is_err());
        let mut c = RunConfig::default();
        c.sweeps = 15;
        c.sweeps_per_round = 10;
        assert!(c.validate().is_err());
        let mut c = RunConfig::default();
        c.beta_hot = 6.0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn run_spec_roundtrips_json() {
        use crate::engine::{BackendPref, Rung, SamplerSpec, Width};
        let rs = RunSpec::new(
            RunConfig { n_models: 3, ..RunConfig::default() },
            SamplerSpec::rung(Rung::C1).w(16).on(BackendPref::Portable),
        );
        let back = RunSpec::from_json(&rs.to_json()).unwrap();
        assert_eq!(back.config.n_models, 3);
        assert_eq!(back.sampler.rung, Rung::C1);
        assert_eq!(back.sampler.width, Width::W(16));
        assert_eq!(back.sampler.backend, BackendPref::Portable);
        // The serialized form is versioned; future versions are refused.
        let v = Value::parse(&rs.to_json()).unwrap();
        assert_eq!(v.get("version").unwrap().as_usize().unwrap(), RUN_SPEC_VERSION);
        let mut m = match v {
            Value::Obj(m) => m,
            _ => unreachable!(),
        };
        m.insert("version".into(), json::num(99.0));
        assert!(RunSpec::from_json(&Value::Obj(m).to_string()).is_err());
    }

    #[test]
    fn run_spec_lowers_legacy_kinds() {
        use crate::engine::{Rung, Width};
        let rs = RunSpec::new(RunConfig::default(), SweepKind::C1ReplicaBatchW8);
        assert_eq!(rs.sampler.rung, Rung::C1);
        assert_eq!(rs.sampler.width, Width::W(8));
        rs.validate().unwrap();
        // The shallow-geometry relaxation follows the sampler's rung.
        let shallow =
            RunSpec::new(RunConfig { layers: 2, ..RunConfig::default() }, SweepKind::C1ReplicaBatch);
        shallow.validate().unwrap();
        let shallow_a =
            RunSpec::new(RunConfig { layers: 2, ..RunConfig::default() }, SweepKind::A4Full);
        assert!(shallow_a.validate().is_err());
    }

    #[test]
    fn rung_timing_roundtrips_json() {
        let t = RungTiming::new(SweepKind::A2Basic, 2, 1.5, 100, 1000);
        let back = RungTiming::from_json(&t.to_json()).unwrap();
        assert_eq!(back.kind, "A.2");
        assert_eq!(back.threads, 2);
        assert!((back.seconds - 1.5).abs() < 1e-12);
    }
}
