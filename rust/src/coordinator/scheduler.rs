//! Multi-threaded sweep scheduling.
//!
//! The paper's CPU results multi-thread by distributing Ising models
//! across cores ("CPU runs were performed on 1, 2, 4, 6, and 8 cores",
//! §4; threading details in their companion paper [16]).  This scheduler
//! reproduces that structure: the sweep phase of a tempering round is a
//! pool of replica jobs claimed by worker threads through an atomic
//! cursor (dynamic load balancing — cold replicas flip less and run
//! slightly faster, so static chunking would skew).  Exchanges happen on
//! the coordinator thread between rounds.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::sweep::{SweepStats, Sweeper};
use crate::tempering::PtEnsemble;

/// Sweep every replica of `pt` for `n_sweeps` at its own β, using
/// `n_threads` workers with dynamic (work-stealing) assignment.
pub fn parallel_sweep(pt: &mut PtEnsemble, n_sweeps: usize, n_threads: usize) {
    if n_threads <= 1 {
        pt.sweep_all(n_sweeps);
        return;
    }
    let (ladder, replicas, stats) = pt.split_mut();
    // One lockable job per replica; the Mutex is uncontended (each index
    // is claimed exactly once via the cursor) and exists to move the
    // mutable borrows across threads safely.
    let jobs: Vec<Mutex<(f32, &mut Box<dyn Sweeper + Send>, &mut SweepStats)>> = replicas
        .iter_mut()
        .zip(stats.iter_mut())
        .enumerate()
        .map(|(i, (r, s))| Mutex::new((ladder.beta(i), r, s)))
        .collect();
    let cursor = AtomicUsize::new(0);

    std::thread::scope(|scope| {
        for _ in 0..n_threads.min(jobs.len()) {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= jobs.len() {
                    break;
                }
                let mut guard = jobs[i].lock().expect("job mutex poisoned");
                let (beta, replica, stats) = &mut *guard;
                let s = replica.run(n_sweeps, *beta);
                stats.merge(&s);
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ising::builder::torus_workload;
    use crate::sweep::{make_sweeper, SweepKind};
    use crate::tempering::Ladder;

    fn ensemble(n: usize, kind: SweepKind) -> PtEnsemble {
        let ladder = Ladder::geometric(2.0, 0.2, n);
        let replicas = (0..n)
            .map(|i| {
                let wl = torus_workload(4, 4, 8, 21, 0.3);
                make_sweeper(kind, &wl.model, &wl.s0, 500 + i as u32).unwrap()
            })
            .collect();
        PtEnsemble::new(ladder, replicas, 1234)
    }

    /// Parallel sweeping must produce the same trajectories as serial
    /// (replicas are independent between exchanges; per-replica RNG).
    #[test]
    fn parallel_equals_serial() {
        let mut serial = ensemble(6, SweepKind::A2Basic);
        let mut parallel = ensemble(6, SweepKind::A2Basic);
        serial.sweep_all(10);
        super::parallel_sweep(&mut parallel, 10, 4);
        let a = serial.reports();
        let b = parallel.reports();
        for (ra, rb) in a.iter().zip(&b) {
            assert_eq!(ra.stats.flips, rb.stats.flips);
            assert_eq!(ra.energy, rb.energy);
        }
    }

    #[test]
    fn oversubscription_is_safe() {
        let mut pt = ensemble(3, SweepKind::A4Full);
        super::parallel_sweep(&mut pt, 5, 16); // more threads than jobs
        let total: u64 = pt.reports().iter().map(|r| r.stats.attempts).sum();
        assert_eq!(total, 3 * 5 * (4 * 4 * 8) as u64);
    }
}
