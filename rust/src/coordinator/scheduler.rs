//! Multi-threaded sweep scheduling over a persistent worker pool.
//!
//! The paper's CPU results multi-thread by distributing Ising models
//! across cores ("CPU runs were performed on 1, 2, 4, 6, and 8 cores",
//! §4; threading details in their companion paper [16]).  Earlier
//! revisions reproduced that with a `thread::scope` spawned *per round* —
//! fine for a benchmark, but a serving deployment runs thousands of
//! rounds, and spawn/join per round is pure overhead.  [`SweepPool`] is
//! the persistent replacement: long-lived workers fed batch jobs through
//! a channel, held by the coordinator across rounds, shut down gracefully
//! on drop.
//!
//! The sweep phase of a tempering round is a pool of jobs (one per
//! replica for the per-replica ensembles, one per lane-batch for the
//! C-rungs) claimed through an atomic cursor — dynamic load balancing,
//! because cold replicas flip less and run slightly faster than hot ones.
//! Exchanges happen on the coordinator thread between rounds.
//!
//! Panic safety: if a job panics mid-round the pool neither leaks nor
//! deadlocks — workers catch the unwind and keep serving, the round call
//! re-raises the first panic only *after* every job of the batch has
//! settled (so scoped borrows never escape), and `Drop` joins all
//! workers poison-safely.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, OnceLock};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::obs::Histogram;
use crate::sweep::c1_replica_batch::BatchSweeper;
use crate::sweep::{SweepStats, Sweeper};
use crate::tempering::{BatchedPtEnsemble, PtEnsemble};

/// A type-erased job sent to the workers.
type Task = Box<dyn FnOnce() + Send + 'static>;

/// Cumulative execution counters of a pool — the utilization data the
/// sampling service and [`super::RunReport`] expose (busy-worker
/// fraction, jobs queued through the pool).
#[derive(Copy, Clone, Debug, Default)]
pub struct PoolStats {
    /// Tasks executed to completion (inline or on a worker).
    pub jobs: u64,
    /// Total seconds spent inside tasks, summed across workers.
    pub busy_seconds: f64,
}

impl PoolStats {
    /// Fraction of worker capacity spent inside tasks over a run of
    /// `wall_seconds` on `threads` workers, clamped to [0, 1].
    pub fn busy_fraction(&self, threads: usize, wall_seconds: f64) -> f64 {
        if threads == 0 || wall_seconds <= 0.0 {
            0.0
        } else {
            (self.busy_seconds / (threads as f64 * wall_seconds)).min(1.0)
        }
    }
}

/// Atomic backing of [`PoolStats`], shared with the (lifetime-erased)
/// worker tasks through an `Arc` so no scoped borrow is needed.
#[derive(Default)]
struct PoolCounters {
    jobs: AtomicU64,
    busy_ns: AtomicU64,
    /// Optional per-task wall-time histogram (µs), installed by the
    /// service engine so `{"op":"stats"}` can report pool-task
    /// percentiles.  Absent outside the serving path: recording is then
    /// a single pointer check.
    task_hist: OnceLock<Arc<Histogram>>,
}

impl PoolCounters {
    fn record(&self, elapsed: std::time::Duration) {
        self.jobs.fetch_add(1, Ordering::Relaxed);
        self.busy_ns.fetch_add(elapsed.as_nanos() as u64, Ordering::Relaxed);
        if let Some(hist) = self.task_hist.get() {
            hist.record(elapsed.as_micros() as u64);
        }
    }
}

/// A persistent pool of sweep workers.
///
/// `new(1)` (or `new(0)`) spawns no threads at all: batches then run
/// inline on the caller, so a single `SweepPool` value works for every
/// thread count and the coordinator holds exactly one across all rounds.
pub struct SweepPool {
    tx: Option<Sender<Task>>,
    workers: Vec<JoinHandle<()>>,
    threads: usize,
    counters: Arc<PoolCounters>,
}

impl SweepPool {
    /// Spawn `n_threads` long-lived workers (none when `n_threads <= 1`).
    pub fn new(n_threads: usize) -> Self {
        let threads = n_threads.max(1);
        if threads == 1 {
            let counters = Arc::new(PoolCounters::default());
            return Self { tx: None, workers: Vec::new(), threads: 1, counters };
        }
        Self::spawn_workers(threads)
    }

    /// Spawn `n_threads.max(1)` long-lived workers — *always* threaded,
    /// even for one worker.  The service scheduler needs this: its
    /// fire-and-forget dispatches must run off the scheduler thread so
    /// admission and deadline polling stay live, which the inline regime
    /// of [`SweepPool::new`] cannot provide.
    pub fn new_threaded(n_threads: usize) -> Self {
        Self::spawn_workers(n_threads.max(1))
    }

    fn spawn_workers(threads: usize) -> Self {
        let counters = Arc::new(PoolCounters::default());
        let (tx, rx) = channel::<Task>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads)
            .map(|_| {
                let rx = Arc::clone(&rx);
                std::thread::spawn(move || loop {
                    // Tasks run outside the lock guard, so a panicking task
                    // cannot poison the receiver; recover anyway so one bad
                    // round can never wedge the whole pool.
                    let task = {
                        let guard = match rx.lock() {
                            Ok(g) => g,
                            Err(poisoned) => poisoned.into_inner(),
                        };
                        match guard.recv() {
                            Ok(t) => t,
                            Err(_) => break, // channel hung up: shutdown
                        }
                    };
                    let _ = catch_unwind(AssertUnwindSafe(task));
                })
            })
            .collect();
        Self { tx: Some(tx), workers, threads, counters }
    }

    /// Queue one owned task for asynchronous execution and return
    /// immediately.  The task runs on a worker (or inline when the pool
    /// has none), is execution-counted like batch tasks, and its panics
    /// are contained by the worker loop — a fire-and-forget dispatch
    /// must signal completion through its own channel (see the service
    /// scheduler), typically via a drop guard so the signal survives a
    /// panic inside the task.
    pub fn spawn(&self, task: Box<dyn FnOnce() + Send + 'static>) {
        let counters = Arc::clone(&self.counters);
        let wrapped: Task = Box::new(move || {
            let t0 = Instant::now();
            let _ = catch_unwind(AssertUnwindSafe(task));
            counters.record(t0.elapsed());
        });
        match &self.tx {
            // Inline pool, or workers already shut down (drop race):
            // run on the caller so the task is never silently lost.
            None => wrapped(),
            Some(tx) => {
                if let Err(err) = tx.send(wrapped) {
                    err.0();
                }
            }
        }
    }

    /// Install a per-task wall-time histogram (µs): every subsequent
    /// task — spawned, inline or batched — records its duration into it.
    /// Write-once; later calls are ignored.
    pub fn set_task_hist(&self, hist: Arc<Histogram>) {
        let _ = self.counters.task_hist.set(hist);
    }

    /// Worker count this pool was built for (1 = inline execution).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Cumulative execution counters since construction.
    pub fn stats(&self) -> PoolStats {
        PoolStats {
            jobs: self.counters.jobs.load(Ordering::Relaxed),
            busy_seconds: self.counters.busy_ns.load(Ordering::Relaxed) as f64 / 1e9,
        }
    }

    /// Run (and account) one closure inline — the single-threaded
    /// counterpart of a pooled task, so utilization metrics stay
    /// meaningful when the sweep phase bypasses the workers.
    pub fn run_inline<F: FnOnce()>(&self, f: F) {
        let t0 = Instant::now();
        f();
        self.counters.record(t0.elapsed());
    }

    /// Run a batch of scoped tasks to completion.
    ///
    /// Blocks until every task has finished (inline when the pool is
    /// single-threaded).  If any task panicked, the first panic payload is
    /// re-raised here — but only after all tasks of the batch have
    /// settled, so borrows captured by the tasks never outlive the call.
    pub fn run_batch<'env>(&self, tasks: Vec<Box<dyn FnOnce() + Send + 'env>>) {
        let Some(tx) = &self.tx else {
            for task in tasks {
                let t0 = Instant::now();
                task();
                self.counters.record(t0.elapsed());
            }
            return;
        };
        let (done_tx, done_rx) = channel::<Option<Box<dyn std::any::Any + Send>>>();
        // SAFETY INVARIANT: once the first lifetime-erased task has been
        // sent, control must not leave this function — not even by
        // unwinding — until every sent task has reported completion (each
        // wrapped task sends exactly one message, panic or not).  `drain`
        // enforces that in its Drop impl, so the invariant survives any
        // future code between the send loop and the normal drain below.
        let mut drain = DrainGuard { rx: &done_rx, tx: Some(done_tx), remaining: 0 };
        for task in tasks {
            let done = drain.tx.as_ref().expect("sender kept until sends finish").clone();
            let counters = Arc::clone(&self.counters);
            let wrapped: Box<dyn FnOnce() + Send + 'env> = Box::new(move || {
                let t0 = Instant::now();
                let result = catch_unwind(AssertUnwindSafe(task));
                counters.record(t0.elapsed());
                let _ = done.send(result.err());
            });
            // SAFETY: the DrainGuard above blocks (even on unwind) until
            // this task has either run to completion or been dropped
            // unexecuted, so the 'env borrows it captures cannot outlive
            // this call.
            let static_task: Task = unsafe {
                std::mem::transmute::<Box<dyn FnOnce() + Send + 'env>, Task>(wrapped)
            };
            if tx.send(static_task).is_err() {
                // Workers already gone (shutdown race); the unsent tasks
                // are dropped here and only sent ones are awaited.
                break;
            }
            drain.remaining += 1;
        }
        drain.tx.take();
        let mut first_panic = None;
        while drain.remaining > 0 {
            match drain.rx.recv() {
                Ok(payload) => {
                    drain.remaining -= 1;
                    if let Some(p) = payload {
                        if first_panic.is_none() {
                            first_panic = Some(p);
                        }
                    }
                }
                // All remaining senders dropped: every outstanding task
                // was dropped unexecuted — nothing left borrowing.
                Err(_) => drain.remaining = 0,
            }
        }
        drop(drain);
        if let Some(payload) = first_panic {
            resume_unwind(payload);
        }
    }
}

/// Completion-latch for [`SweepPool::run_batch`]: waits out every *sent*
/// task on drop, so the scoped borrows behind the lifetime-erasing
/// transmute can never be freed while a worker might still run them —
/// even if the coordinator unwinds mid-batch.
struct DrainGuard<'a> {
    rx: &'a Receiver<Option<Box<dyn std::any::Any + Send>>>,
    /// Held until all sends are done (tasks clone it), then dropped so the
    /// receiver can observe hang-up of dropped, unexecuted tasks.
    tx: Option<Sender<Option<Box<dyn std::any::Any + Send>>>>,
    /// Tasks sent but not yet reported back.
    remaining: usize,
}

impl Drop for DrainGuard<'_> {
    fn drop(&mut self) {
        self.tx.take();
        while self.remaining > 0 {
            match self.rx.recv() {
                Ok(_) => self.remaining -= 1,
                Err(_) => break, // all senders gone: no task holds borrows
            }
        }
    }
}

impl Drop for SweepPool {
    fn drop(&mut self) {
        // Hang up the job channel so idle workers drain out, then join
        // every worker — including any that caught a task panic.  Joining
        // never deadlocks: with the sender gone each worker's next recv
        // errors and its loop breaks.
        self.tx.take();
        for worker in self.workers.drain(..) {
            let _ = worker.join();
        }
    }
}

/// Run one closure over every job of a cursor-claimed pool: one worker
/// task per pool thread, each claiming job indices through an atomic
/// cursor (dynamic load balancing) and locking the job's Mutex to move
/// the mutable borrows across threads safely.  The Mutexes are
/// uncontended — each index is claimed exactly once.
fn run_cursor_jobs<J, F>(pool: &SweepPool, jobs: Vec<Mutex<J>>, body: F)
where
    J: Send,
    F: Fn(&mut J) + Sync,
{
    let cursor = AtomicUsize::new(0);
    let jobs_ref = &jobs;
    let cursor_ref = &cursor;
    let body_ref = &body;
    let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..pool.threads().min(jobs.len()))
        .map(|_| {
            Box::new(move || loop {
                let i = cursor_ref.fetch_add(1, Ordering::Relaxed);
                if i >= jobs_ref.len() {
                    break;
                }
                let mut guard = match jobs_ref[i].lock() {
                    Ok(g) => g,
                    Err(poisoned) => poisoned.into_inner(),
                };
                body_ref(&mut guard);
            }) as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    pool.run_batch(tasks);
}

/// Sweep every replica of `pt` for `n_sweeps` at its own β on the pool's
/// workers, with dynamic (cursor-claimed) assignment.
pub fn parallel_sweep_with_pool(pt: &mut PtEnsemble, n_sweeps: usize, pool: &SweepPool) {
    if pool.threads() <= 1 {
        pool.run_inline(|| pt.sweep_all(n_sweeps));
        return;
    }
    let (ladder, replicas, stats) = pt.split_mut();
    let jobs: Vec<Mutex<(f32, &mut Box<dyn Sweeper + Send>, &mut SweepStats)>> = replicas
        .iter_mut()
        .zip(stats.iter_mut())
        .enumerate()
        .map(|(i, (r, s))| Mutex::new((ladder.beta(i), r, s)))
        .collect();
    run_cursor_jobs(pool, jobs, |(beta, replica, stats)| {
        let s = replica.run(n_sweeps, *beta);
        stats.merge(&s);
    });
}

/// Sweep every lane-group of a [`BatchedPtEnsemble`] for `n_sweeps` on
/// the pool's workers (one job per group — the C-rung unit of work).
/// Groups may have heterogeneous widths (e.g. a `C.1w8` group next to a
/// `C.1` tail group), so the ladder-ordered stats slice is split by each
/// group's *active* replica count rather than a fixed chunk width.
pub fn parallel_sweep_batches(pt: &mut BatchedPtEnsemble, n_sweeps: usize, pool: &SweepPool) {
    if pool.threads() <= 1 {
        pool.run_inline(|| pt.sweep_all(n_sweeps));
        return;
    }
    let (betas, batches, stats, actives) = pt.split_mut();
    type BatchJob<'a> = (&'a [f32], &'a mut Box<dyn BatchSweeper + Send>, &'a mut [SweepStats]);
    let mut rest = stats;
    let mut jobs: Vec<Mutex<BatchJob<'_>>> = Vec::with_capacity(batches.len());
    for (b, batch) in batches.iter_mut().enumerate() {
        let (chunk, tail) = rest.split_at_mut(actives[b]);
        rest = tail;
        jobs.push(Mutex::new((betas[b].as_slice(), batch, chunk)));
    }
    run_cursor_jobs(pool, jobs, |(lane_betas, batch, chunk)| {
        let per_lane = batch.run(n_sweeps, *lane_betas);
        // Groups may be padded: only the chunk's active lanes have
        // stats slots.
        for (s, lane_stats) in chunk.iter_mut().zip(per_lane.iter()) {
            s.merge(lane_stats);
        }
    });
}

/// Sweep every replica of `pt` using a transient pool of `n_threads`
/// workers — the historical entry point, kept for callers that do not
/// hold a pool across rounds (tests, one-shot probes).  Prefer
/// [`parallel_sweep_with_pool`] in round loops.
pub fn parallel_sweep(pt: &mut PtEnsemble, n_sweeps: usize, n_threads: usize) {
    if n_threads <= 1 {
        pt.sweep_all(n_sweeps);
        return;
    }
    let pool = SweepPool::new(n_threads);
    parallel_sweep_with_pool(pt, n_sweeps, &pool);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ising::builder::torus_workload;
    use crate::sweep::{try_make_sweeper, ExpMode, SweepKind};
    use crate::tempering::{BatchedPtEnsemble, Ladder};

    fn ensemble(n: usize, kind: SweepKind) -> PtEnsemble {
        let ladder = Ladder::geometric(2.0, 0.2, n);
        let replicas = (0..n)
            .map(|i| {
                let wl = torus_workload(4, 4, 8, 21, 0.3);
                try_make_sweeper(kind, &wl.model, &wl.s0, 500 + i as u32).unwrap()
            })
            .collect();
        PtEnsemble::new(ladder, replicas, 1234)
    }

    fn batched(n: usize) -> BatchedPtEnsemble {
        let ladder = Ladder::geometric(2.0, 0.2, n);
        let wl = torus_workload(4, 4, 8, 21, 0.3);
        let models = vec![wl.model.clone(); n];
        let states = vec![wl.s0.clone(); n];
        let seeds: Vec<u32> = (0..n as u32).map(|i| 500 + i).collect();
        BatchedPtEnsemble::new(
            ladder,
            SweepKind::C1ReplicaBatch,
            &models,
            &states,
            &seeds,
            1234,
            ExpMode::Fast,
        )
        .unwrap()
    }

    /// Parallel sweeping must produce the same trajectories as serial
    /// (replicas are independent between exchanges; per-replica RNG).
    #[test]
    fn parallel_equals_serial() {
        let mut serial = ensemble(6, SweepKind::A2Basic);
        let mut parallel = ensemble(6, SweepKind::A2Basic);
        serial.sweep_all(10);
        super::parallel_sweep(&mut parallel, 10, 4);
        let a = serial.reports();
        let b = parallel.reports();
        for (ra, rb) in a.iter().zip(&b) {
            assert_eq!(ra.stats.flips, rb.stats.flips);
            assert_eq!(ra.energy, rb.energy);
        }
    }

    #[test]
    fn oversubscription_is_safe() {
        let mut pt = ensemble(3, SweepKind::A4Full);
        super::parallel_sweep(&mut pt, 5, 16); // more threads than jobs
        let total: u64 = pt.reports().iter().map(|r| r.stats.attempts).sum();
        assert_eq!(total, 3 * 5 * (4 * 4 * 8) as u64);
    }

    /// A persistent pool reused across rounds matches per-round spawning.
    #[test]
    fn persistent_pool_matches_transient_rounds() {
        let mut a = ensemble(5, SweepKind::A2Basic);
        let mut b = ensemble(5, SweepKind::A2Basic);
        let pool = SweepPool::new(3);
        for _ in 0..4 {
            super::parallel_sweep(&mut a, 5, 3);
            a.exchange();
            super::parallel_sweep_with_pool(&mut b, 5, &pool);
            b.exchange();
        }
        let ra = a.reports();
        let rb = b.reports();
        for (x, y) in ra.iter().zip(&rb) {
            assert_eq!(x.stats.flips, y.stats.flips);
            assert_eq!(x.energy, y.energy);
        }
    }

    /// Batched sweeping through the pool equals serial batched sweeping.
    #[test]
    fn batched_parallel_equals_batched_serial() {
        let mut serial = batched(6);
        let mut parallel = batched(6);
        let pool = SweepPool::new(4);
        serial.sweep_all(10);
        super::parallel_sweep_batches(&mut parallel, 10, &pool);
        let a = serial.reports();
        let b = parallel.reports();
        for (ra, rb) in a.iter().zip(&b) {
            assert_eq!(ra.stats.flips, rb.stats.flips);
            assert_eq!(ra.energy, rb.energy);
        }
    }

    /// Heterogeneous group layouts (different widths per group) must
    /// sweep identically through the pool: the stats slice is split by
    /// per-group active counts, not a fixed width.
    #[test]
    fn heterogeneous_batched_parallel_equals_serial() {
        use crate::engine::{Backend, BackendPref, GroupPlan, Resolved, Rung, SamplerSpec};
        let n = 10;
        let build = || {
            let ladder = Ladder::geometric(2.0, 0.2, n);
            let wl = torus_workload(4, 4, 8, 21, 0.3);
            let models = vec![wl.model.clone(); n];
            let states = vec![wl.s0.clone(); n];
            let seeds: Vec<u32> = (0..n as u32).map(|i| 500 + i).collect();
            let r = |w| Resolved { rung: Rung::C1, backend: Backend::Portable, width: w };
            BatchedPtEnsemble::with_groups(
                ladder,
                SamplerSpec::rung(Rung::C1).on(BackendPref::Portable),
                &[GroupPlan::new(r(8), 8), GroupPlan::new(r(4), 2)],
                &models,
                &states,
                &seeds,
                1234,
                ExpMode::Fast,
            )
            .unwrap()
        };
        let mut serial = build();
        let mut parallel = build();
        let pool = SweepPool::new(3);
        serial.sweep_all(10);
        super::parallel_sweep_batches(&mut parallel, 10, &pool);
        let a = serial.reports();
        let b = parallel.reports();
        for (ra, rb) in a.iter().zip(&b) {
            assert_eq!(ra.stats.flips, rb.stats.flips);
            assert_eq!(ra.energy, rb.energy);
        }
    }

    /// Regression (poison-safe shutdown): a panicking round must re-raise
    /// on the coordinator thread, leave the pool serving, and never leak
    /// or deadlock workers on drop.
    #[test]
    fn pool_survives_a_panicking_round() {
        fn tasks_for(hit: &AtomicUsize, poison: bool) -> Vec<Box<dyn FnOnce() + Send + '_>> {
            (0..6)
                .map(|i| {
                    Box::new(move || {
                        if poison && i == 2 {
                            panic!("round gone wrong");
                        }
                        hit.fetch_add(1, Ordering::Relaxed);
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect()
        }
        let pool = SweepPool::new(3);
        let hit = AtomicUsize::new(0);
        let result = catch_unwind(AssertUnwindSafe(|| pool.run_batch(tasks_for(&hit, true))));
        assert!(result.is_err(), "the task panic must propagate to the caller");
        assert_eq!(hit.load(Ordering::Relaxed), 5, "non-panicking tasks all ran");
        // The pool keeps serving after the failed round...
        pool.run_batch(tasks_for(&hit, false));
        assert_eq!(hit.load(Ordering::Relaxed), 11);
        // ...and dropping it joins every worker (the test would hang here
        // if shutdown deadlocked).
        drop(pool);
    }

    /// Utilization counters: every executed task is counted, with a
    /// non-zero busy time, in both the pooled and the inline regimes.
    #[test]
    fn pool_stats_count_jobs_and_busy_time() {
        let pool = SweepPool::new(3);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..5)
            .map(|_| {
                Box::new(|| std::thread::sleep(std::time::Duration::from_millis(2)))
                    as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_batch(tasks);
        let s = pool.stats();
        assert_eq!(s.jobs, 5);
        assert!(s.busy_seconds > 0.0);
        assert!(s.busy_fraction(3, 1.0) > 0.0);
        assert!(s.busy_fraction(3, 1e-12) <= 1.0, "fraction is clamped");

        let inline_pool = SweepPool::new(1);
        inline_pool.run_inline(|| {});
        inline_pool.run_batch(vec![Box::new(|| {}) as Box<dyn FnOnce() + Send + '_>]);
        assert_eq!(inline_pool.stats().jobs, 2);
    }

    /// An installed task histogram sees every execution path — inline,
    /// batched and spawned — once per task.
    #[test]
    fn task_histogram_records_every_execution_path() {
        let hist = Arc::new(Histogram::new());
        let pool = SweepPool::new(1);
        pool.set_task_hist(Arc::clone(&hist));
        pool.run_inline(|| {});
        pool.run_batch(vec![Box::new(|| {}) as Box<dyn FnOnce() + Send + '_>]);
        pool.spawn(Box::new(|| {}));
        assert_eq!(hist.snapshot().count(), 3);
        assert_eq!(pool.stats().jobs, 3, "histogram and job counter agree");
    }

    /// Fire-and-forget tasks all execute (panicking ones contained),
    /// are execution-counted, and a `new_threaded(1)` pool really runs
    /// them off the caller thread so the caller stays free.
    #[test]
    fn spawned_tasks_run_off_thread_and_are_counted() {
        use std::sync::mpsc::channel;
        let pool = SweepPool::new_threaded(1);
        assert_eq!(pool.threads(), 1);
        let (done_tx, done_rx) = channel::<usize>();
        let caller = std::thread::current().id();
        for i in 0..6 {
            let done = done_tx.clone();
            let pool_worker_differs = move || {
                assert_ne!(
                    std::thread::current().id(),
                    caller,
                    "new_threaded(1) must execute on a worker, not inline"
                );
                let _ = done.send(i);
                if i == 2 {
                    panic!("contained by the worker loop");
                }
            };
            pool.spawn(Box::new(pool_worker_differs));
        }
        drop(done_tx);
        let mut got: Vec<usize> = done_rx.iter().collect();
        got.sort_unstable();
        assert_eq!(got, vec![0, 1, 2, 3, 4, 5], "every spawned task ran, panic included");
        assert_eq!(pool.stats().jobs, 6, "spawned tasks are execution-counted");
        drop(pool); // joins the worker; would hang if shutdown broke
    }

    /// `spawn` on an inline pool falls back to caller-thread execution
    /// instead of dropping the task.
    #[test]
    fn spawn_on_inline_pool_runs_on_caller() {
        let pool = SweepPool::new(1);
        let hit = Arc::new(AtomicUsize::new(0));
        let h = Arc::clone(&hit);
        pool.spawn(Box::new(move || {
            h.fetch_add(1, Ordering::Relaxed);
        }));
        assert_eq!(hit.load(Ordering::Relaxed), 1);
        assert_eq!(pool.stats().jobs, 1);
    }

    #[test]
    fn single_threaded_pool_runs_inline() {
        let pool = SweepPool::new(1);
        assert_eq!(pool.threads(), 1);
        let mut ran = false;
        let ran_ref = &mut ran;
        pool.run_batch(vec![Box::new(move || *ran_ref = true) as Box<dyn FnOnce() + Send + '_>]);
        assert!(ran);
    }
}
