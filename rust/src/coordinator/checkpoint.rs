//! Ensemble checkpointing — save/resume long tempering runs.
//!
//! The paper's production context ("millions of the Metropolis sweeps ...
//! on millions of systems ... months of computation time on thousands of
//! multi-core computers" — AQUA@Home volunteer computing) requires runs
//! to survive interruption.  A checkpoint captures every replica's spin
//! state plus the run configuration; restoring rebuilds the ensemble and
//! re-derives the effective fields (h_eff is a pure function of state, so
//! it is never serialized).
//!
//! Note on RNG state: the CPU rungs serialize their full MT19937 state
//! (624 words per lane, hex-packed), so save → load → resume continues
//! the *identical* trajectory the checkpointing run produces — the
//! property the resume tests assert for both scalar and C-rung
//! ensembles.  Capturing is itself a (statistically invisible) bit-level
//! event: `capture` canonicalizes the live ensemble's effective fields
//! by re-deriving them from the states, because a resumed run can only
//! recompute fields, and incrementally maintained fields agree with that
//! recomputation only up to floating-point rounding.  A run with
//! periodic checkpoints therefore bit-diverges from the same seed run
//! without them (same distribution, different rounding path).  Rungs
//! that cannot serialize their generator (accelerator artifacts keep
//! theirs on device) checkpoint states only; restoring such a checkpoint
//! requires the caller to rebuild the ensemble with *fresh* sweeper
//! seeds for the resumed segment (offset by the checkpoint epoch, say) —
//! reusing the original seeds would replay the already-consumed uniform
//! stream and correlate the continuation with the recorded segment.

use std::path::Path;

use crate::sweep::{SweepKind, Sweeper};
use crate::tempering::{BatchedPtEnsemble, PtEnsembleImpl};
use crate::util::json::{self, Value};
use crate::Result;

use super::config::RunConfig;

/// A serializable snapshot of a tempering run.
#[derive(Clone, Debug)]
pub struct Checkpoint {
    pub kind: String,
    pub epoch: u64,
    pub sweeps_done: usize,
    pub config: RunConfig,
    /// Per-replica ±1 states in original order, ladder-ordered.
    pub states: Vec<Vec<f32>>,
    /// Serialized sweep-RNG states for bit-exact resume: one entry per
    /// replica (scalar ensembles) or per lane-batch (batched ensembles).
    /// Empty when the rung cannot serialize its generator.
    pub rngs: Vec<Vec<u32>>,
    /// Serialized exchange-RNG state (empty when not captured).
    pub swap_rng: Vec<u32>,
    /// Exchange-round counter at capture time (even/odd pairing parity).
    pub round: u64,
}

impl Checkpoint {
    /// Capture the current ensemble state, including the full RNG states
    /// (when every replica's rung can serialize its generator) so resume
    /// is bit-exact.
    ///
    /// Note: capture *canonicalizes* the live ensemble — every replica's
    /// effective fields are re-derived from its state (see the module
    /// docs), so taking a checkpoint perturbs the run's subsequent
    /// trajectory at the floating-point-rounding level (never its
    /// distribution).
    pub fn capture<S: Sweeper + ?Sized>(
        kind: SweepKind,
        epoch: u64,
        sweeps_done: usize,
        config: &RunConfig,
        pt: &mut PtEnsembleImpl<S>,
    ) -> Self {
        let states: Vec<Vec<f32>> = (0..pt.len()).map(|i| pt.state_of(i)).collect();
        // Canonicalize the live ensemble at the snapshot point: re-derive
        // every replica's effective fields from its state.  A resumed run
        // must recompute fields from the serialized states; incrementally
        // maintained fields agree with that recomputation only to rounding,
        // so without this step the live and resumed trajectories would
        // drift apart at the bit level.
        for (i, s) in states.iter().enumerate() {
            pt.set_state_of(i, s);
        }
        let rngs: Vec<Vec<u32>> =
            (0..pt.len()).filter_map(|i| pt.rng_state_of(i)).collect();
        let rngs = if rngs.len() == pt.len() { rngs } else { Vec::new() };
        Self {
            kind: kind.label().to_string(),
            epoch,
            sweeps_done,
            config: config.clone(),
            states,
            rngs,
            swap_rng: pt.swap_rng_state(),
            round: pt.round_index(),
        }
    }

    /// Capture a lane-batched (C-rung) ensemble: states per active
    /// replica, RNG states per lane-batch.
    pub fn capture_batched(
        epoch: u64,
        sweeps_done: usize,
        config: &RunConfig,
        pt: &mut BatchedPtEnsemble,
    ) -> Self {
        let states: Vec<Vec<f32>> = (0..pt.len()).map(|i| pt.state_of(i)).collect();
        // Same field canonicalization as `capture` (active lanes only —
        // padded lanes never influence them).
        for (i, s) in states.iter().enumerate() {
            pt.set_state_of(i, s);
        }
        Self {
            kind: pt.kind().label().to_string(),
            epoch,
            sweeps_done,
            config: config.clone(),
            states,
            rngs: pt.rng_states(),
            swap_rng: pt.swap_rng_state(),
            round: pt.round_index(),
        }
    }

    /// Restore the states into a freshly built ensemble (replica count,
    /// spin count and rung must match the checkpoint).  When the
    /// checkpoint carries RNG payloads they are restored too, making the
    /// resume bit-exact.
    ///
    /// When the checkpoint has **no** RNG payload (legacy format, or a
    /// rung that cannot serialize its generator), the generators keep
    /// whatever seeds the rebuilt ensemble was constructed with.  Do not
    /// rebuild with the pre-checkpoint sweeper seeds in that case: the
    /// resumed segment would replay the exact uniform stream the original
    /// run already consumed.  Derive fresh sweeper seeds for the resumed
    /// segment instead (e.g. offset them by [`Checkpoint::epoch`]).
    pub fn restore<S: Sweeper + ?Sized>(&self, pt: &mut PtEnsembleImpl<S>) -> Result<()> {
        if pt.len() != self.states.len() {
            anyhow::bail!(
                "checkpoint has {} replicas, ensemble has {}",
                self.states.len(),
                pt.len()
            );
        }
        if !pt.is_empty() && pt.kind_of(0).label() != self.kind {
            anyhow::bail!(
                "checkpoint was captured on rung {}, ensemble runs {} — resuming would \
                 continue a different algorithm",
                self.kind,
                pt.kind_of(0).label()
            );
        }
        for (i, s) in self.states.iter().enumerate() {
            if s.len() != pt.state_of(i).len() {
                anyhow::bail!("replica {i}: state length {} != model {}", s.len(), pt.state_of(i).len());
            }
            pt.set_state_of(i, s);
        }
        if !self.rngs.is_empty() {
            if self.rngs.len() != pt.len() {
                anyhow::bail!(
                    "checkpoint has {} RNG states, ensemble has {} replicas",
                    self.rngs.len(),
                    pt.len()
                );
            }
            for (i, words) in self.rngs.iter().enumerate() {
                if !pt.set_rng_state_of(i, words) {
                    anyhow::bail!("replica {i}: RNG payload does not match this rung");
                }
            }
        }
        if !self.swap_rng.is_empty() {
            if !pt.set_swap_rng_state(&self.swap_rng) {
                anyhow::bail!("malformed exchange-RNG payload");
            }
            pt.set_round_index(self.round);
        }
        Ok(())
    }

    /// Restore into a freshly built lane-batched ensemble.
    pub fn restore_batched(&self, pt: &mut BatchedPtEnsemble) -> Result<()> {
        if pt.len() != self.states.len() {
            anyhow::bail!(
                "checkpoint has {} replicas, batched ensemble has {}",
                self.states.len(),
                pt.len()
            );
        }
        if pt.kind().label() != self.kind {
            anyhow::bail!(
                "checkpoint was captured on rung {}, ensemble runs {} — resuming would \
                 continue a different algorithm",
                self.kind,
                pt.kind().label()
            );
        }
        for (i, s) in self.states.iter().enumerate() {
            if s.len() != pt.state_of(i).len() {
                anyhow::bail!("replica {i}: state length {} != model {}", s.len(), pt.state_of(i).len());
            }
            pt.set_state_of(i, s);
        }
        if !self.rngs.is_empty() && !pt.set_rng_states(&self.rngs) {
            anyhow::bail!(
                "checkpoint RNG payload ({} entries) does not match the ensemble's {} batches",
                self.rngs.len(),
                pt.n_batches()
            );
        }
        if !self.swap_rng.is_empty() {
            if !pt.set_swap_rng_state(&self.swap_rng) {
                anyhow::bail!("malformed exchange-RNG payload");
            }
            pt.set_round_index(self.round);
        }
        Ok(())
    }

    pub fn to_json(&self) -> String {
        // Spins are ±1; serialize compactly as sign bits per replica.
        // RNG payloads are hex-packed words (8 chars per u32).
        let states: Vec<Value> = self
            .states
            .iter()
            .map(|s| Value::Str(s.iter().map(|&x| if x > 0.0 { '1' } else { '0' }).collect()))
            .collect();
        let rngs: Vec<Value> =
            self.rngs.iter().map(|w| Value::Str(words_to_hex(w))).collect();
        json::obj(vec![
            ("kind", json::str_v(&self.kind)),
            ("epoch", json::num(self.epoch as f64)),
            ("sweeps_done", json::num(self.sweeps_done as f64)),
            ("config", config_to_json(&self.config)),
            ("states", Value::Arr(states)),
            ("rngs", Value::Arr(rngs)),
            ("swap_rng", Value::Str(words_to_hex(&self.swap_rng))),
            ("round", json::num(self.round as f64)),
        ])
        .to_string()
    }

    pub fn from_json(text: &str) -> Result<Self> {
        let v = Value::parse(text)?;
        let states = v
            .get("states")?
            .as_arr()?
            .iter()
            .map(|s| {
                Ok(s.as_str()?
                    .chars()
                    .map(|c| if c == '1' { 1.0f32 } else { -1.0 })
                    .collect())
            })
            .collect::<Result<Vec<Vec<f32>>>>()?;
        // RNG fields are optional: checkpoints written by earlier
        // revisions (states only) still load.
        let rngs = match v.opt("rngs") {
            Some(arr) => arr
                .as_arr()?
                .iter()
                .map(|s| hex_to_words(s.as_str()?))
                .collect::<Result<Vec<Vec<u32>>>>()?,
            None => Vec::new(),
        };
        let swap_rng = match v.opt("swap_rng") {
            Some(s) => hex_to_words(s.as_str()?)?,
            None => Vec::new(),
        };
        let round = match v.opt("round") {
            Some(r) => r.as_f64()? as u64,
            None => 0,
        };
        Ok(Self {
            kind: v.get("kind")?.as_str()?.to_string(),
            epoch: v.get("epoch")?.as_f64()? as u64,
            sweeps_done: v.get("sweeps_done")?.as_usize()?,
            config: config_from_json(v.get("config")?)?,
            states,
            rngs,
            swap_rng,
            round,
        })
    }

    /// Write atomically (tmp file + rename) so an interrupted save never
    /// corrupts the previous checkpoint.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let tmp = path.with_extension("tmp");
        std::fs::write(&tmp, self.to_json())?;
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("cannot read checkpoint {path:?}: {e}"))?;
        Self::from_json(&text).map_err(|e| anyhow::anyhow!("malformed checkpoint {path:?}: {e}"))
    }
}

fn words_to_hex(words: &[u32]) -> String {
    use std::fmt::Write as _;
    let mut s = String::with_capacity(8 * words.len());
    for w in words {
        let _ = write!(s, "{w:08x}");
    }
    s
}

fn hex_to_words(s: &str) -> Result<Vec<u32>> {
    if s.len() % 8 != 0 || !s.is_ascii() {
        anyhow::bail!("malformed hex word payload (length {})", s.len());
    }
    s.as_bytes()
        .chunks(8)
        .map(|chunk| {
            let text = std::str::from_utf8(chunk)?;
            u32::from_str_radix(text, 16).map_err(|e| anyhow::anyhow!("bad hex word {text:?}: {e}"))
        })
        .collect()
}

fn config_to_json(c: &RunConfig) -> Value {
    json::obj(vec![
        ("width", json::num(c.width as f64)),
        ("height", json::num(c.height as f64)),
        ("layers", json::num(c.layers as f64)),
        ("n_models", json::num(c.n_models as f64)),
        ("sweeps", json::num(c.sweeps as f64)),
        ("sweeps_per_round", json::num(c.sweeps_per_round as f64)),
        ("threads", json::num(c.threads as f64)),
        ("beta_cold", json::num(c.beta_cold as f64)),
        ("beta_hot", json::num(c.beta_hot as f64)),
        ("jtau", json::num(c.jtau as f64)),
        ("seed", json::num(c.seed as f64)),
    ])
}

fn config_from_json(v: &Value) -> Result<RunConfig> {
    Ok(RunConfig {
        width: v.get("width")?.as_usize()?,
        height: v.get("height")?.as_usize()?,
        layers: v.get("layers")?.as_usize()?,
        n_models: v.get("n_models")?.as_usize()?,
        sweeps: v.get("sweeps")?.as_usize()?,
        sweeps_per_round: v.get("sweeps_per_round")?.as_usize()?,
        threads: v.get("threads")?.as_usize()?,
        beta_cold: v.get("beta_cold")?.as_f64()? as f32,
        beta_hot: v.get("beta_hot")?.as_f64()? as f32,
        jtau: v.get("jtau")?.as_f64()? as f32,
        seed: v.get("seed")?.as_f64()? as u64,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{self, RunConfig};
    use crate::sweep::SweepKind;

    fn cfg() -> RunConfig {
        RunConfig { n_models: 3, sweeps: 20, sweeps_per_round: 10, ..RunConfig::default() }
    }

    #[test]
    fn roundtrips_through_json() {
        let cfg = cfg();
        let mut pt = coordinator::build_ensemble(&cfg, SweepKind::A2Basic).unwrap();
        pt.sweep_all(5);
        let ck = Checkpoint::capture(SweepKind::A2Basic, 3, 50, &cfg, &mut pt);
        let back = Checkpoint::from_json(&ck.to_json()).unwrap();
        assert_eq!(back.kind, "A.2");
        assert_eq!(back.epoch, 3);
        assert_eq!(back.states, ck.states);
        assert_eq!(back.config.n_models, 3);
    }

    #[test]
    fn restore_resumes_with_identical_states_and_energies() {
        let cfg = cfg();
        let mut pt = coordinator::build_ensemble(&cfg, SweepKind::A4Full).unwrap();
        pt.sweep_all(7);
        let energies: Vec<f64> = pt.reports().iter().map(|r| r.energy).collect();
        let ck = Checkpoint::capture(SweepKind::A4Full, 1, 7, &cfg, &mut pt);

        let mut fresh = coordinator::build_ensemble(&cfg, SweepKind::A4Full).unwrap();
        ck.restore(&mut fresh).unwrap();
        let restored: Vec<f64> = fresh.reports().iter().map(|r| r.energy).collect();
        assert_eq!(energies, restored);
        for i in 0..pt.len() {
            assert_eq!(pt.state_of(i), fresh.state_of(i));
        }
    }

    #[test]
    fn save_load_file_roundtrip_is_atomic() {
        let cfg = cfg();
        let mut pt = coordinator::build_ensemble(&cfg, SweepKind::A1Original).unwrap();
        pt.sweep_all(3);
        let ck = Checkpoint::capture(SweepKind::A1Original, 0, 3, &cfg, &mut pt);
        let dir = std::env::temp_dir().join("vectorising_ckpt_test");
        let path = dir.join("run.ckpt.json");
        ck.save(&path).unwrap();
        assert!(!path.with_extension("tmp").exists(), "tmp must be renamed away");
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.states, ck.states);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn hex_word_codec_roundtrips() {
        let words = vec![0u32, 1, 0xdead_beef, u32::MAX, 0x0000_00ff];
        let hex = words_to_hex(&words);
        assert_eq!(hex.len(), 8 * words.len());
        assert_eq!(hex_to_words(&hex).unwrap(), words);
        assert!(hex_to_words("abc").is_err()); // not a multiple of 8
        assert!(hex_to_words("zzzzzzzz").is_err());
    }

    #[test]
    fn rng_payload_survives_json_roundtrip() {
        let cfg = cfg();
        let mut pt = coordinator::build_ensemble(&cfg, SweepKind::A2Basic).unwrap();
        pt.sweep_all(5);
        pt.exchange();
        let ck = Checkpoint::capture(SweepKind::A2Basic, 1, 5, &cfg, &mut pt);
        assert_eq!(ck.rngs.len(), 3, "A.2 serializes its generator");
        assert!(!ck.swap_rng.is_empty());
        assert_eq!(ck.round, 1);
        let back = Checkpoint::from_json(&ck.to_json()).unwrap();
        assert_eq!(back.rngs, ck.rngs);
        assert_eq!(back.swap_rng, ck.swap_rng);
        assert_eq!(back.round, 1);
    }

    #[test]
    fn legacy_checkpoints_without_rng_fields_still_load() {
        let cfg = cfg();
        let mut pt = coordinator::build_ensemble(&cfg, SweepKind::A2Basic).unwrap();
        let ck = Checkpoint::capture(SweepKind::A2Basic, 0, 0, &cfg, &mut pt);
        // Strip the new fields the way an old writer would have.
        let v = crate::util::json::Value::parse(&ck.to_json()).unwrap();
        let mut m = match v {
            crate::util::json::Value::Obj(m) => m,
            _ => unreachable!(),
        };
        m.remove("rngs");
        m.remove("swap_rng");
        m.remove("round");
        let legacy = crate::util::json::Value::Obj(m).to_string();
        let back = Checkpoint::from_json(&legacy).unwrap();
        assert!(back.rngs.is_empty());
        assert!(back.swap_rng.is_empty());
        back.restore(&mut pt).unwrap(); // states-only restore still works
    }

    #[test]
    fn batched_capture_restores_states() {
        let cfg = cfg();
        let mut pt =
            coordinator::build_batched_ensemble(&cfg, SweepKind::C1ReplicaBatch).unwrap();
        pt.sweep_all(5);
        let ck = Checkpoint::capture_batched(1, 5, &cfg, &mut pt);
        assert_eq!(ck.kind, "C.1");
        assert_eq!(ck.states.len(), 3);
        assert_eq!(ck.rngs.len(), pt.n_batches());
        let mut fresh =
            coordinator::build_batched_ensemble(&cfg, SweepKind::C1ReplicaBatch).unwrap();
        ck.restore_batched(&mut fresh).unwrap();
        for i in 0..pt.len() {
            assert_eq!(pt.state_of(i), fresh.state_of(i));
        }
    }

    #[test]
    fn restore_rejects_mismatched_rung_kind() {
        // An RNG-bearing A.2 checkpoint must not resume an A.1 ensemble:
        // replica counts and state lengths match, and A.1 would even
        // accept the 625-word payload — only the kind check catches it.
        let cfg = cfg();
        let mut pt = coordinator::build_ensemble(&cfg, SweepKind::A2Basic).unwrap();
        pt.sweep_all(3);
        let ck = Checkpoint::capture(SweepKind::A2Basic, 0, 3, &cfg, &mut pt);
        let mut other = coordinator::build_ensemble(&cfg, SweepKind::A1Original).unwrap();
        let err = ck.restore(&mut other);
        assert!(err.is_err());
        let msg = format!("{:#}", err.err().unwrap());
        assert!(msg.contains("A.2") && msg.contains("A.1"), "unhelpful message: {msg}");
        // Batched likewise: a C.1 checkpoint cannot resume a C.1w8 ensemble.
        let mut b4 = coordinator::build_batched_ensemble(&cfg, SweepKind::C1ReplicaBatch).unwrap();
        let bck = Checkpoint::capture_batched(0, 0, &cfg, &mut b4);
        let mut b8 =
            coordinator::build_batched_ensemble(&cfg, SweepKind::C1ReplicaBatchW8).unwrap();
        assert!(bck.restore_batched(&mut b8).is_err());
    }

    #[test]
    fn restore_rejects_mismatched_shapes() {
        let cfg = cfg();
        let mut pt = coordinator::build_ensemble(&cfg, SweepKind::A2Basic).unwrap();
        let ck = Checkpoint::capture(SweepKind::A2Basic, 0, 0, &cfg, &mut pt);
        let mut bigger = coordinator::build_ensemble(
            &RunConfig { n_models: 5, ..cfg.clone() },
            SweepKind::A2Basic,
        )
        .unwrap();
        assert!(ck.restore(&mut bigger).is_err());
    }
}
