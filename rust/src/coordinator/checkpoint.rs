//! Ensemble checkpointing — save/resume long tempering runs.
//!
//! The paper's production context ("millions of the Metropolis sweeps ...
//! on millions of systems ... months of computation time on thousands of
//! multi-core computers" — AQUA@Home volunteer computing) requires runs
//! to survive interruption.  A checkpoint captures every replica's spin
//! state plus the full run description; restoring rebuilds the ensemble
//! and re-derives the effective fields (h_eff is a pure function of
//! state, so it is never serialized).
//!
//! **Schema v2** (`"schema": 2`) makes resume *spec-driven*: alongside
//! the legacy `kind` label a checkpoint carries the requested
//! [`SamplerSpec`] and the **resolved per-group plans** (`plans`:
//! `[{rung, width, backend, replicas}]` — the group layout of a batched
//! run, including heterogeneous layouts like `C.1w8 + C.1`).  Any plan
//! the builder can instantiate round-trips — including portable
//! `C.1w16` batches the legacy enum cannot spell — and
//! [`Checkpoint::run_spec`] reconstructs the whole [`RunSpec`], so
//! `repro run --resume ck.json` needs no sampler flags at all.  Schema
//! v1 files (a bare `kind` string) still load: the label parses as a
//! legacy `SweepKind` and lowers onto its spec via `From<SweepKind>`.
//!
//! Note on RNG state: the CPU rungs serialize their full MT19937 state
//! (624 words per lane, hex-packed), so save → load → resume continues
//! the *identical* trajectory the checkpointing run produces — the
//! property the resume tests assert for scalar, C-rung and portable
//! `C.1w16` ensembles.  Capturing is itself a (statistically invisible)
//! bit-level event: `capture` canonicalizes the live ensemble's
//! effective fields by re-deriving them from the states, because a
//! resumed run can only recompute fields, and incrementally maintained
//! fields agree with that recomputation only up to floating-point
//! rounding.  A run with periodic checkpoints therefore bit-diverges
//! from the same seed run without them (same distribution, different
//! rounding path).  Rungs that cannot serialize their generator
//! (accelerator artifacts keep theirs on device) checkpoint states
//! only; restoring such a checkpoint through [`Checkpoint::restore`] is
//! rejected with a structured [`NonResumableRng`] error that names the
//! fresh-seed procedure — rebuild with seeds offset by the checkpoint
//! epoch and use [`Checkpoint::restore_states_only`].

use std::path::Path;

use crate::engine::{EngineBuilder, GroupPlan, NonResumableRng, SamplerSpec, Width};
use crate::sweep::{SweepKind, Sweeper};
use crate::tempering::{BatchedPtEnsemble, PtEnsembleImpl};
use crate::util::json::{self, Value};
use crate::Result;

use super::config::{RunConfig, RunSpec};

/// Schema version written by this build.  Version-1 files (no `schema`
/// field) remain loadable; their `kind` label lowers onto a spec.
pub const CHECKPOINT_SCHEMA_VERSION: usize = 2;

/// A serializable snapshot of a tempering run.
#[derive(Clone, Debug)]
pub struct Checkpoint {
    /// Schema this snapshot was written under (2 for new captures, 1
    /// for loaded legacy files).
    pub schema: usize,
    /// Label of the rung(s) the run executes (`A.2`, `C.1w16`,
    /// `C.1w8+C.1`) — the v1 field, kept populated for old readers.
    pub kind: String,
    /// v2: the sampler spec the run was requested with.
    pub sampler: Option<SamplerSpec>,
    /// v2: the resolved group layout.  One entry per lane-group for
    /// batched ensembles (heterogeneous layouts included); a single
    /// entry covering all replicas for per-replica ensembles.  Empty
    /// for v1 files.
    pub plans: Vec<GroupPlan>,
    pub epoch: u64,
    pub sweeps_done: usize,
    pub config: RunConfig,
    /// Per-replica ±1 states in original order, ladder-ordered.
    pub states: Vec<Vec<f32>>,
    /// Serialized sweep-RNG states for bit-exact resume: one entry per
    /// replica (scalar ensembles) or per lane-group (batched ensembles).
    /// Empty when the rung cannot serialize its generator.
    pub rngs: Vec<Vec<u32>>,
    /// Serialized exchange-RNG state (empty when not captured).
    pub swap_rng: Vec<u32>,
    /// Exchange-round counter at capture time (even/odd pairing parity).
    pub round: u64,
}

impl Checkpoint {
    /// Capture a per-replica ensemble under a legacy [`SweepKind`] — a
    /// shim over [`Checkpoint::capture_spec`] via the `From<SweepKind>`
    /// lowering.
    pub fn capture<S: Sweeper + ?Sized>(
        kind: SweepKind,
        epoch: u64,
        sweeps_done: usize,
        config: &RunConfig,
        pt: &mut PtEnsembleImpl<S>,
    ) -> Self {
        Self::capture_spec(kind.spec(), epoch, sweeps_done, config, pt)
    }

    /// Capture the current ensemble state under a sampler spec,
    /// including the full RNG states (when every replica's rung can
    /// serialize its generator) so resume is bit-exact.
    ///
    /// Note: capture *canonicalizes* the live ensemble — every replica's
    /// effective fields are re-derived from its state (see the module
    /// docs), so taking a checkpoint perturbs the run's subsequent
    /// trajectory at the floating-point-rounding level (never its
    /// distribution).
    pub fn capture_spec<S: Sweeper + ?Sized>(
        spec: impl Into<SamplerSpec>,
        epoch: u64,
        sweeps_done: usize,
        config: &RunConfig,
        pt: &mut PtEnsembleImpl<S>,
    ) -> Self {
        let spec = spec.into();
        let states: Vec<Vec<f32>> = (0..pt.len()).map(|i| pt.state_of(i)).collect();
        // Canonicalize the live ensemble at the snapshot point: re-derive
        // every replica's effective fields from its state.  A resumed run
        // must recompute fields from the serialized states; incrementally
        // maintained fields agree with that recomputation only to rounding,
        // so without this step the live and resumed trajectories would
        // drift apart at the bit level.
        for (i, s) in states.iter().enumerate() {
            pt.set_state_of(i, s);
        }
        let rngs: Vec<Vec<u32>> = (0..pt.len()).filter_map(|i| pt.rng_state_of(i)).collect();
        let rngs = if rngs.len() == pt.len() { rngs } else { Vec::new() };
        // Serialize the plan of what is *actually running*: pin the live
        // ensemble's width before resolving, so a `width: auto` spec
        // resumed on a different host (auto would now negotiate another
        // width) still records plans consistent with the RNG payloads it
        // serializes.  A spec that no longer resolves degrades to a
        // label-only record.
        let mut live_spec = spec;
        if !pt.is_empty() {
            live_spec.width = Width::W(pt.width_of(0));
        }
        let (kind, plans) = match EngineBuilder::new(live_spec).layers(config.layers).plan() {
            Ok(plan) => (plan.label(), vec![GroupPlan::new(plan.resolved(), pt.len())]),
            Err(_) => (spec.rung.label().to_string(), Vec::new()),
        };
        Self {
            schema: CHECKPOINT_SCHEMA_VERSION,
            kind,
            sampler: Some(spec),
            plans,
            epoch,
            sweeps_done,
            config: config.clone(),
            states,
            rngs,
            swap_rng: pt.swap_rng_state(),
            round: pt.round_index(),
        }
    }

    /// Capture a lane-batched (C-rung) ensemble: states per active
    /// replica, RNG states per lane-group, plus the ensemble's resolved
    /// per-group plans (heterogeneous layouts included).
    pub fn capture_batched(
        epoch: u64,
        sweeps_done: usize,
        config: &RunConfig,
        pt: &mut BatchedPtEnsemble,
    ) -> Self {
        let states: Vec<Vec<f32>> = (0..pt.len()).map(|i| pt.state_of(i)).collect();
        // Same field canonicalization as `capture_spec` (active lanes only
        // — padded lanes never influence them).
        for (i, s) in states.iter().enumerate() {
            pt.set_state_of(i, s);
        }
        Self {
            schema: CHECKPOINT_SCHEMA_VERSION,
            kind: pt.label(),
            sampler: Some(pt.spec()),
            plans: pt.plans().to_vec(),
            epoch,
            sweeps_done,
            config: config.clone(),
            states,
            rngs: pt.rng_states(),
            swap_rng: pt.swap_rng_state(),
            round: pt.round_index(),
        }
    }

    /// The sampler spec this checkpoint resumes under: the recorded v2
    /// spec, or — for v1 files — the legacy `kind` label parsed as a
    /// [`SweepKind`] and lowered via `From<SweepKind>`.
    pub fn sampler_spec(&self) -> Result<SamplerSpec> {
        if let Some(s) = self.sampler {
            return Ok(s);
        }
        let kind: SweepKind = self.kind.parse().map_err(|e: crate::Error| {
            anyhow::anyhow!(
                "v1 checkpoint kind {:?} does not name a legacy rung, cannot derive a \
                 sampler spec: {e}",
                self.kind
            )
        })?;
        Ok(kind.spec())
    }

    /// The full run description this checkpoint was captured under —
    /// what `repro run --resume` rebuilds the ensemble from.
    pub fn run_spec(&self) -> Result<RunSpec> {
        Ok(RunSpec { config: self.config.clone(), sampler: self.sampler_spec()? })
    }

    /// Whether the run this checkpoint belongs to is lane-batched (the
    /// C-rungs) — decides which restore path a resume takes.
    pub fn is_batched(&self) -> bool {
        self.sampler_spec().map(|s| s.rung.is_replica_batch()).unwrap_or(false)
    }

    /// Reject RNG-less checkpoints of rungs that *cannot* serialize
    /// their generator: a bit-exact resume is impossible, and silently
    /// keeping the rebuilt ensemble's seeds would either replay the
    /// recorded uniform stream (if the caller reused the original
    /// seeds) or go unnoticed.  The structured error names the
    /// fresh-seed procedure and the epoch to offset by.
    fn ensure_resumable_rng(&self) -> Result<()> {
        let accel = match self.sampler {
            Some(s) => s.rung.is_accel(),
            None => self
                .kind
                .parse::<SweepKind>()
                .map(|k| k.spec().rung.is_accel())
                .unwrap_or(false),
        };
        if accel && self.rngs.is_empty() {
            return Err(NonResumableRng {
                label: self.kind.clone(),
                epoch: self.epoch,
                sweeps_done: self.sweeps_done,
            }
            .into());
        }
        Ok(())
    }

    /// Restore the states into a freshly built ensemble (replica count,
    /// spin count and rung/width must match the checkpoint).  When the
    /// checkpoint carries RNG payloads they are restored too, making the
    /// resume bit-exact.
    ///
    /// When the checkpoint has **no** RNG payload: a legacy (states-only)
    /// file restores states and leaves the generators as the rebuilt
    /// ensemble seeded them, but a checkpoint of a rung that *cannot*
    /// serialize its generator (the accelerator rungs) is rejected with
    /// a structured [`NonResumableRng`] error — resume those with fresh
    /// sweeper seeds offset by [`Checkpoint::epoch`] and
    /// [`Checkpoint::restore_states_only`].
    pub fn restore<S: Sweeper + ?Sized>(&self, pt: &mut PtEnsembleImpl<S>) -> Result<()> {
        self.ensure_resumable_rng()?;
        if !pt.is_empty() {
            if let Some(p) = self.plans.first() {
                // v2: compare the resolved rung × width (the width
                // accessor covers widths the legacy kind tag cannot
                // spell, e.g. A.4w16).
                let r = p.resolved;
                if pt.kind_of(0).spec().rung != r.rung || pt.width_of(0) != r.width {
                    anyhow::bail!(
                        "checkpoint was captured on {} (rung {} at width {}), ensemble runs {} \
                         at width {} — resuming would continue a different algorithm",
                        self.kind,
                        r.rung,
                        r.width,
                        pt.kind_of(0).label(),
                        pt.width_of(0)
                    );
                }
            } else if pt.kind_of(0).label() != self.kind {
                anyhow::bail!(
                    "checkpoint was captured on rung {}, ensemble runs {} — resuming would \
                     continue a different algorithm",
                    self.kind,
                    pt.kind_of(0).label()
                );
            }
        }
        self.restore_states_into(pt)?;
        if !self.rngs.is_empty() {
            if self.rngs.len() != pt.len() {
                anyhow::bail!(
                    "checkpoint has {} RNG states, ensemble has {} replicas",
                    self.rngs.len(),
                    pt.len()
                );
            }
            for (i, words) in self.rngs.iter().enumerate() {
                if !pt.set_rng_state_of(i, words) {
                    anyhow::bail!("replica {i}: RNG payload does not match this rung");
                }
            }
        }
        if !self.swap_rng.is_empty() {
            if !pt.set_swap_rng_state(&self.swap_rng) {
                anyhow::bail!("malformed exchange-RNG payload");
            }
            pt.set_round_index(self.round);
        }
        Ok(())
    }

    /// The fresh-seed resume path for rungs that cannot serialize their
    /// generator: restores the spin states **only** (no RNG, no exchange
    /// RNG, no round parity).  The caller must have rebuilt the ensemble
    /// with *fresh* sweeper seeds for the resumed segment — offset the
    /// base seed by [`Checkpoint::epoch`] — or the continuation replays
    /// the already-consumed uniform stream.
    pub fn restore_states_only<S: Sweeper + ?Sized>(
        &self,
        pt: &mut PtEnsembleImpl<S>,
    ) -> Result<()> {
        self.restore_states_into(pt)
    }

    fn restore_states_into<S: Sweeper + ?Sized>(&self, pt: &mut PtEnsembleImpl<S>) -> Result<()> {
        if pt.len() != self.states.len() {
            anyhow::bail!(
                "checkpoint has {} replicas, ensemble has {}",
                self.states.len(),
                pt.len()
            );
        }
        for (i, s) in self.states.iter().enumerate() {
            if s.len() != pt.state_of(i).len() {
                anyhow::bail!(
                    "replica {i}: state length {} != model {}",
                    s.len(),
                    pt.state_of(i).len()
                );
            }
            pt.set_state_of(i, s);
        }
        Ok(())
    }

    /// Restore into a freshly built lane-batched ensemble.  A v2
    /// checkpoint's group layout (per-group rung, width and active
    /// replica count) must match the ensemble's — the backend may
    /// differ, which is what makes resume portable across hosts
    /// (checkpoint on AVX2, resume on the portable lanes).
    pub fn restore_batched(&self, pt: &mut BatchedPtEnsemble) -> Result<()> {
        self.ensure_resumable_rng()?;
        if pt.len() != self.states.len() {
            anyhow::bail!(
                "checkpoint has {} replicas, batched ensemble has {}",
                self.states.len(),
                pt.len()
            );
        }
        if !self.plans.is_empty() {
            let pt_plans = pt.plans();
            let matches = self.plans.len() == pt_plans.len()
                && self.plans.iter().zip(pt_plans).all(|(a, b)| a.layout_matches(b));
            if !matches {
                anyhow::bail!(
                    "checkpoint group layout [{}] does not match the ensemble's [{}] — the \
                     per-group RNG payloads are width-dependent; rebuild the ensemble from the \
                     checkpoint's own plans (Checkpoint::run_spec + \
                     coordinator::build_batched_for_checkpoint)",
                    plans_summary(&self.plans),
                    plans_summary(pt_plans)
                );
            }
        } else if pt.label() != self.kind {
            anyhow::bail!(
                "checkpoint was captured on rung {}, ensemble runs {} — resuming would \
                 continue a different algorithm",
                self.kind,
                pt.label()
            );
        }
        for (i, s) in self.states.iter().enumerate() {
            if s.len() != pt.state_of(i).len() {
                anyhow::bail!(
                    "replica {i}: state length {} != model {}",
                    s.len(),
                    pt.state_of(i).len()
                );
            }
            pt.set_state_of(i, s);
        }
        if !self.rngs.is_empty() && !pt.set_rng_states(&self.rngs) {
            anyhow::bail!(
                "checkpoint RNG payload ({} entries) does not match the ensemble's {} groups",
                self.rngs.len(),
                pt.n_batches()
            );
        }
        if !self.swap_rng.is_empty() {
            if !pt.set_swap_rng_state(&self.swap_rng) {
                anyhow::bail!("malformed exchange-RNG payload");
            }
            pt.set_round_index(self.round);
        }
        Ok(())
    }

    /// JSON form (see [`Checkpoint::to_json`]); nested by the service's
    /// checkpointable run jobs.
    pub fn to_value(&self) -> Value {
        // Spins are ±1; serialize compactly as sign bits per replica.
        // RNG payloads are hex-packed words (8 chars per u32).
        let states: Vec<Value> = self
            .states
            .iter()
            .map(|s| Value::Str(s.iter().map(|&x| if x > 0.0 { '1' } else { '0' }).collect()))
            .collect();
        let rngs: Vec<Value> = self.rngs.iter().map(|w| Value::Str(words_to_hex(w))).collect();
        let mut pairs = vec![
            ("schema", json::num(self.schema as f64)),
            ("kind", json::str_v(&self.kind)),
        ];
        let sampler_v = self.sampler.map(|s| s.to_value());
        if let Some(sv) = sampler_v {
            pairs.push(("sampler", sv));
        }
        if !self.plans.is_empty() {
            pairs.push(("plans", Value::Arr(self.plans.iter().map(|p| p.to_value()).collect())));
        }
        pairs.extend([
            ("epoch", json::num(self.epoch as f64)),
            ("sweeps_done", json::num(self.sweeps_done as f64)),
            ("config", self.config.to_value()),
            ("states", Value::Arr(states)),
            ("rngs", Value::Arr(rngs)),
            ("swap_rng", Value::Str(words_to_hex(&self.swap_rng))),
            ("round", json::num(self.round as f64)),
        ]);
        json::obj(pairs)
    }

    pub fn to_json(&self) -> String {
        self.to_value().to_string()
    }

    /// Parse either schema: v2 (with `schema`/`sampler`/`plans`) or v1
    /// (a bare `kind` label; `rngs`/`swap_rng`/`round` optional as in
    /// the earliest states-only files).
    pub fn from_value(v: &Value) -> Result<Self> {
        let schema = match v.opt("schema") {
            Some(s) => s.as_usize()?,
            None => 1,
        };
        anyhow::ensure!(
            schema <= CHECKPOINT_SCHEMA_VERSION,
            "checkpoint schema {schema} is newer than this build speaks \
             ({CHECKPOINT_SCHEMA_VERSION})"
        );
        let states = v
            .get("states")?
            .as_arr()?
            .iter()
            .map(|s| {
                Ok(s.as_str()?
                    .chars()
                    .map(|c| if c == '1' { 1.0f32 } else { -1.0 })
                    .collect())
            })
            .collect::<Result<Vec<Vec<f32>>>>()?;
        // RNG fields are optional: checkpoints written by earlier
        // revisions (states only) still load.
        let rngs = match v.opt("rngs") {
            Some(arr) => arr
                .as_arr()?
                .iter()
                .map(|s| hex_to_words(s.as_str()?))
                .collect::<Result<Vec<Vec<u32>>>>()?,
            None => Vec::new(),
        };
        let swap_rng = match v.opt("swap_rng") {
            Some(s) => hex_to_words(s.as_str()?)?,
            None => Vec::new(),
        };
        let round = match v.opt("round") {
            Some(r) => r.as_f64()? as u64,
            None => 0,
        };
        let sampler = match v.opt("sampler") {
            Some(sv) => Some(SamplerSpec::from_value(sv)?),
            None => None,
        };
        let plans = GroupPlan::vec_from_opt(v.opt("plans"))?;
        Ok(Self {
            schema,
            kind: v.get("kind")?.as_str()?.to_string(),
            sampler,
            plans,
            epoch: v.get("epoch")?.as_f64()? as u64,
            sweeps_done: v.get("sweeps_done")?.as_usize()?,
            config: RunConfig::from_value(v.get("config")?)?,
            states,
            rngs,
            swap_rng,
            round,
        })
    }

    pub fn from_json(text: &str) -> Result<Self> {
        Self::from_value(&Value::parse(text)?)
    }

    /// Write atomically (tmp file + rename) so an interrupted save never
    /// corrupts the previous checkpoint.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let tmp = path.with_extension("tmp");
        std::fs::write(&tmp, self.to_json())?;
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("cannot read checkpoint {path:?}: {e}"))?;
        Self::from_json(&text).map_err(|e| anyhow::anyhow!("malformed checkpoint {path:?}: {e}"))
    }
}

fn plans_summary(plans: &[GroupPlan]) -> String {
    plans
        .iter()
        .map(|p| format!("{}x{}", p.resolved.label(), p.replicas))
        .collect::<Vec<_>>()
        .join(", ")
}

fn words_to_hex(words: &[u32]) -> String {
    use std::fmt::Write as _;
    let mut s = String::with_capacity(8 * words.len());
    for w in words {
        let _ = write!(s, "{w:08x}");
    }
    s
}

fn hex_to_words(s: &str) -> Result<Vec<u32>> {
    if s.len() % 8 != 0 || !s.is_ascii() {
        anyhow::bail!("malformed hex word payload (length {})", s.len());
    }
    s.as_bytes()
        .chunks(8)
        .map(|chunk| {
            let text = std::str::from_utf8(chunk)?;
            u32::from_str_radix(text, 16).map_err(|e| anyhow::anyhow!("bad hex word {text:?}: {e}"))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{self, RunConfig};
    use crate::engine::{Rung, Width};
    use crate::sweep::SweepKind;

    fn cfg() -> RunConfig {
        RunConfig { n_models: 3, sweeps: 20, sweeps_per_round: 10, ..RunConfig::default() }
    }

    #[test]
    fn roundtrips_through_json() {
        let cfg = cfg();
        let mut pt = coordinator::build_ensemble(&cfg, SweepKind::A2Basic).unwrap();
        pt.sweep_all(5);
        let ck = Checkpoint::capture(SweepKind::A2Basic, 3, 50, &cfg, &mut pt);
        let back = Checkpoint::from_json(&ck.to_json()).unwrap();
        assert_eq!(back.schema, CHECKPOINT_SCHEMA_VERSION);
        assert_eq!(back.kind, "A.2");
        assert_eq!(back.epoch, 3);
        assert_eq!(back.states, ck.states);
        assert_eq!(back.config.n_models, 3);
        // v2 carries the spec and the resolved plan.
        let s = back.sampler.expect("v2 spec");
        assert_eq!(s.rung, Rung::A2);
        assert_eq!(back.plans.len(), 1);
        assert_eq!(back.plans[0].resolved.width, 1);
        assert_eq!(back.plans[0].replicas, 3);
    }

    #[test]
    fn capture_records_the_live_width_not_a_renegotiated_auto() {
        // Regression: a `width: auto` spec must checkpoint the width the
        // ensemble is *actually running* — re-negotiating auto at capture
        // time would record plans that contradict the serialized RNG
        // payloads whenever a resumed run lands on a different host.
        let cfg = cfg();
        let spec = crate::engine::SamplerSpec::rung(Rung::A4); // width auto
        let mut pt = coordinator::build_ensemble(&cfg, spec).unwrap();
        pt.sweep_all(3);
        let live_w = pt.width_of(0);
        let ck = Checkpoint::capture_spec(spec, 0, 3, &cfg, &mut pt);
        assert_eq!(ck.plans.len(), 1);
        assert_eq!(ck.plans[0].resolved.width, live_w, "plan width == live ensemble width");
        // The recorded plan passes its own restore compatibility check.
        ck.restore(&mut pt).unwrap();
    }

    #[test]
    fn restore_resumes_with_identical_states_and_energies() {
        let cfg = cfg();
        let mut pt = coordinator::build_ensemble(&cfg, SweepKind::A4Full).unwrap();
        pt.sweep_all(7);
        let energies: Vec<f64> = pt.reports().iter().map(|r| r.energy).collect();
        let ck = Checkpoint::capture(SweepKind::A4Full, 1, 7, &cfg, &mut pt);

        let mut fresh = coordinator::build_ensemble(&cfg, SweepKind::A4Full).unwrap();
        ck.restore(&mut fresh).unwrap();
        let restored: Vec<f64> = fresh.reports().iter().map(|r| r.energy).collect();
        assert_eq!(energies, restored);
        for i in 0..pt.len() {
            assert_eq!(pt.state_of(i), fresh.state_of(i));
        }
    }

    #[test]
    fn save_load_file_roundtrip_is_atomic() {
        let cfg = cfg();
        let mut pt = coordinator::build_ensemble(&cfg, SweepKind::A1Original).unwrap();
        pt.sweep_all(3);
        let ck = Checkpoint::capture(SweepKind::A1Original, 0, 3, &cfg, &mut pt);
        let dir = std::env::temp_dir().join("vectorising_ckpt_test");
        let path = dir.join("run.ckpt.json");
        ck.save(&path).unwrap();
        assert!(!path.with_extension("tmp").exists(), "tmp must be renamed away");
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.states, ck.states);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn hex_word_codec_roundtrips() {
        let words = vec![0u32, 1, 0xdead_beef, u32::MAX, 0x0000_00ff];
        let hex = words_to_hex(&words);
        assert_eq!(hex.len(), 8 * words.len());
        assert_eq!(hex_to_words(&hex).unwrap(), words);
        assert!(hex_to_words("abc").is_err()); // not a multiple of 8
        assert!(hex_to_words("zzzzzzzz").is_err());
    }

    #[test]
    fn rng_payload_survives_json_roundtrip() {
        let cfg = cfg();
        let mut pt = coordinator::build_ensemble(&cfg, SweepKind::A2Basic).unwrap();
        pt.sweep_all(5);
        pt.exchange();
        let ck = Checkpoint::capture(SweepKind::A2Basic, 1, 5, &cfg, &mut pt);
        assert_eq!(ck.rngs.len(), 3, "A.2 serializes its generator");
        assert!(!ck.swap_rng.is_empty());
        assert_eq!(ck.round, 1);
        let back = Checkpoint::from_json(&ck.to_json()).unwrap();
        assert_eq!(back.rngs, ck.rngs);
        assert_eq!(back.swap_rng, ck.swap_rng);
        assert_eq!(back.round, 1);
    }

    #[test]
    fn legacy_checkpoints_without_rng_fields_still_load() {
        let cfg = cfg();
        let mut pt = coordinator::build_ensemble(&cfg, SweepKind::A2Basic).unwrap();
        let ck = Checkpoint::capture(SweepKind::A2Basic, 0, 0, &cfg, &mut pt);
        // Strip the newer fields the way an old writer would have.
        let v = crate::util::json::Value::parse(&ck.to_json()).unwrap();
        let mut m = match v {
            crate::util::json::Value::Obj(m) => m,
            _ => unreachable!(),
        };
        m.remove("schema");
        m.remove("sampler");
        m.remove("plans");
        m.remove("rngs");
        m.remove("swap_rng");
        m.remove("round");
        let legacy = crate::util::json::Value::Obj(m).to_string();
        let back = Checkpoint::from_json(&legacy).unwrap();
        assert_eq!(back.schema, 1);
        assert!(back.sampler.is_none());
        assert!(back.plans.is_empty());
        assert!(back.rngs.is_empty());
        assert!(back.swap_rng.is_empty());
        // The v1 kind label lowers onto the spec the run always meant.
        let spec = back.sampler_spec().unwrap();
        assert_eq!(spec.rung, Rung::A2);
        assert_eq!(spec.width, Width::W(1));
        back.restore(&mut pt).unwrap(); // states-only restore still works
    }

    #[test]
    fn future_schema_versions_are_refused() {
        let cfg = cfg();
        let mut pt = coordinator::build_ensemble(&cfg, SweepKind::A2Basic).unwrap();
        let ck = Checkpoint::capture(SweepKind::A2Basic, 0, 0, &cfg, &mut pt);
        let v = crate::util::json::Value::parse(&ck.to_json()).unwrap();
        let mut m = match v {
            crate::util::json::Value::Obj(m) => m,
            _ => unreachable!(),
        };
        m.insert("schema".into(), json::num(99.0));
        let err = Checkpoint::from_json(&Value::Obj(m).to_string()).err().unwrap();
        assert!(format!("{err:#}").contains("schema 99"));
    }

    #[test]
    fn batched_capture_restores_states() {
        let cfg = cfg();
        let mut pt =
            coordinator::build_batched_ensemble(&cfg, SweepKind::C1ReplicaBatch).unwrap();
        pt.sweep_all(5);
        let ck = Checkpoint::capture_batched(1, 5, &cfg, &mut pt);
        assert_eq!(ck.kind, "C.1");
        assert_eq!(ck.states.len(), 3);
        assert_eq!(ck.rngs.len(), pt.n_batches());
        assert_eq!(ck.plans.len(), pt.n_batches(), "one resolved plan per group");
        assert!(ck.is_batched());
        let mut fresh =
            coordinator::build_batched_ensemble(&cfg, SweepKind::C1ReplicaBatch).unwrap();
        ck.restore_batched(&mut fresh).unwrap();
        for i in 0..pt.len() {
            assert_eq!(pt.state_of(i), fresh.state_of(i));
        }
    }

    #[test]
    fn restore_rejects_mismatched_rung_kind() {
        // An RNG-bearing A.2 checkpoint must not resume an A.1 ensemble:
        // replica counts and state lengths match, and A.1 would even
        // accept the 625-word payload — only the plan check catches it.
        let cfg = cfg();
        let mut pt = coordinator::build_ensemble(&cfg, SweepKind::A2Basic).unwrap();
        pt.sweep_all(3);
        let ck = Checkpoint::capture(SweepKind::A2Basic, 0, 3, &cfg, &mut pt);
        let mut other = coordinator::build_ensemble(&cfg, SweepKind::A1Original).unwrap();
        let err = ck.restore(&mut other);
        assert!(err.is_err());
        let msg = format!("{:#}", err.err().unwrap());
        assert!(msg.contains("A.2") && msg.contains("A.1"), "unhelpful message: {msg}");
        // Batched likewise: a C.1 checkpoint cannot resume a C.1w8 ensemble.
        let mut b4 = coordinator::build_batched_ensemble(&cfg, SweepKind::C1ReplicaBatch).unwrap();
        let bck = Checkpoint::capture_batched(0, 0, &cfg, &mut b4);
        let mut b8 =
            coordinator::build_batched_ensemble(&cfg, SweepKind::C1ReplicaBatchW8).unwrap();
        assert!(bck.restore_batched(&mut b8).is_err());
    }

    #[test]
    fn restore_rejects_mismatched_shapes() {
        let cfg = cfg();
        let mut pt = coordinator::build_ensemble(&cfg, SweepKind::A2Basic).unwrap();
        let ck = Checkpoint::capture(SweepKind::A2Basic, 0, 0, &cfg, &mut pt);
        let mut bigger = coordinator::build_ensemble(
            &RunConfig { n_models: 5, ..cfg.clone() },
            SweepKind::A2Basic,
        )
        .unwrap();
        assert!(ck.restore(&mut bigger).is_err());
    }

    #[test]
    fn rngless_accel_checkpoints_are_rejected_with_the_procedure() {
        // An accelerator checkpoint carries states only (the generator
        // lives on device).  Restoring it must fail *structurally*, with
        // the fresh-seed procedure and the epoch offset as data.
        let cfg = cfg();
        let mut pt = coordinator::build_ensemble(&cfg, SweepKind::A2Basic).unwrap();
        pt.sweep_all(3);
        let mut ck = Checkpoint::capture(SweepKind::A2Basic, 7, 30, &cfg, &mut pt);
        ck.kind = "B.2".into();
        ck.sampler = Some(SweepKind::B2Accel.spec());
        ck.plans.clear();
        ck.rngs.clear();
        ck.swap_rng.clear();
        let err = ck.restore(&mut pt).err().expect("must reject");
        let nr = err
            .downcast_ref::<NonResumableRng>()
            .expect("structured NonResumableRng error");
        assert_eq!(nr.epoch, 7);
        assert_eq!(nr.sweeps_done, 30);
        assert_eq!(nr.label, "B.2");
        let msg = format!("{err:#}");
        assert!(msg.contains("FRESH"), "{msg}");
        assert!(msg.contains("(7)"), "{msg}");
        assert!(msg.contains("restore_states_only"), "{msg}");
        // The explicit fresh-seed path still restores the states.
        ck.restore_states_only(&mut pt).unwrap();
        // A v1 accel checkpoint (kind label only) is equally rejected.
        ck.sampler = None;
        ck.schema = 1;
        assert!(ck.restore(&mut pt).err().unwrap().downcast_ref::<NonResumableRng>().is_some());
    }
}
