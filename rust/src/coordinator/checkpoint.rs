//! Ensemble checkpointing — save/resume long tempering runs.
//!
//! The paper's production context ("millions of the Metropolis sweeps ...
//! on millions of systems ... months of computation time on thousands of
//! multi-core computers" — AQUA@Home volunteer computing) requires runs
//! to survive interruption.  A checkpoint captures every replica's spin
//! state plus the run configuration; restoring rebuilds the ensemble and
//! re-derives the effective fields (h_eff is a pure function of state, so
//! it is never serialized).
//!
//! Note on RNG state: MT19937 state is deliberately *not* checkpointed —
//! resuming re-seeds from `seed + resume_epoch`, which preserves the
//! statistical guarantees (independent streams) without serializing
//! 2,496-word generator states; bit-exact resume of a trajectory is not a
//! goal of checkpointing (it is covered by the deterministic-seed tests).

use std::path::Path;

use crate::sweep::{SweepKind, Sweeper};
use crate::tempering::PtEnsembleImpl;
use crate::util::json::{self, Value};
use crate::Result;

use super::config::RunConfig;

/// A serializable snapshot of a tempering run.
#[derive(Clone, Debug)]
pub struct Checkpoint {
    pub kind: String,
    pub epoch: u64,
    pub sweeps_done: usize,
    pub config: RunConfig,
    /// Per-replica ±1 states in original order, ladder-ordered.
    pub states: Vec<Vec<f32>>,
}

impl Checkpoint {
    /// Capture the current ensemble state.
    pub fn capture<S: Sweeper + ?Sized>(
        kind: SweepKind,
        epoch: u64,
        sweeps_done: usize,
        config: &RunConfig,
        pt: &mut PtEnsembleImpl<S>,
    ) -> Self {
        let states = (0..pt.len()).map(|i| pt.state_of(i)).collect();
        Self {
            kind: kind.label().to_string(),
            epoch,
            sweeps_done,
            config: config.clone(),
            states,
        }
    }

    /// Restore the states into a freshly built ensemble (replica count and
    /// spin count must match the checkpoint).
    pub fn restore<S: Sweeper + ?Sized>(&self, pt: &mut PtEnsembleImpl<S>) -> Result<()> {
        if pt.len() != self.states.len() {
            anyhow::bail!(
                "checkpoint has {} replicas, ensemble has {}",
                self.states.len(),
                pt.len()
            );
        }
        for (i, s) in self.states.iter().enumerate() {
            if s.len() != pt.state_of(i).len() {
                anyhow::bail!("replica {i}: state length {} != model {}", s.len(), pt.state_of(i).len());
            }
            pt.set_state_of(i, s);
        }
        Ok(())
    }

    pub fn to_json(&self) -> String {
        // Spins are ±1; serialize compactly as sign bits per replica.
        let states: Vec<Value> = self
            .states
            .iter()
            .map(|s| Value::Str(s.iter().map(|&x| if x > 0.0 { '1' } else { '0' }).collect()))
            .collect();
        json::obj(vec![
            ("kind", json::str_v(&self.kind)),
            ("epoch", json::num(self.epoch as f64)),
            ("sweeps_done", json::num(self.sweeps_done as f64)),
            ("config", config_to_json(&self.config)),
            ("states", Value::Arr(states)),
        ])
        .to_string()
    }

    pub fn from_json(text: &str) -> Result<Self> {
        let v = Value::parse(text)?;
        let states = v
            .get("states")?
            .as_arr()?
            .iter()
            .map(|s| {
                Ok(s.as_str()?
                    .chars()
                    .map(|c| if c == '1' { 1.0f32 } else { -1.0 })
                    .collect())
            })
            .collect::<Result<Vec<Vec<f32>>>>()?;
        Ok(Self {
            kind: v.get("kind")?.as_str()?.to_string(),
            epoch: v.get("epoch")?.as_f64()? as u64,
            sweeps_done: v.get("sweeps_done")?.as_usize()?,
            config: config_from_json(v.get("config")?)?,
            states,
        })
    }

    /// Write atomically (tmp file + rename) so an interrupted save never
    /// corrupts the previous checkpoint.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let tmp = path.with_extension("tmp");
        std::fs::write(&tmp, self.to_json())?;
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("cannot read checkpoint {path:?}: {e}"))?;
        Self::from_json(&text).map_err(|e| anyhow::anyhow!("malformed checkpoint {path:?}: {e}"))
    }
}

fn config_to_json(c: &RunConfig) -> Value {
    json::obj(vec![
        ("width", json::num(c.width as f64)),
        ("height", json::num(c.height as f64)),
        ("layers", json::num(c.layers as f64)),
        ("n_models", json::num(c.n_models as f64)),
        ("sweeps", json::num(c.sweeps as f64)),
        ("sweeps_per_round", json::num(c.sweeps_per_round as f64)),
        ("threads", json::num(c.threads as f64)),
        ("beta_cold", json::num(c.beta_cold as f64)),
        ("beta_hot", json::num(c.beta_hot as f64)),
        ("jtau", json::num(c.jtau as f64)),
        ("seed", json::num(c.seed as f64)),
    ])
}

fn config_from_json(v: &Value) -> Result<RunConfig> {
    Ok(RunConfig {
        width: v.get("width")?.as_usize()?,
        height: v.get("height")?.as_usize()?,
        layers: v.get("layers")?.as_usize()?,
        n_models: v.get("n_models")?.as_usize()?,
        sweeps: v.get("sweeps")?.as_usize()?,
        sweeps_per_round: v.get("sweeps_per_round")?.as_usize()?,
        threads: v.get("threads")?.as_usize()?,
        beta_cold: v.get("beta_cold")?.as_f64()? as f32,
        beta_hot: v.get("beta_hot")?.as_f64()? as f32,
        jtau: v.get("jtau")?.as_f64()? as f32,
        seed: v.get("seed")?.as_f64()? as u64,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{self, RunConfig};
    use crate::sweep::SweepKind;

    fn cfg() -> RunConfig {
        RunConfig { n_models: 3, sweeps: 20, sweeps_per_round: 10, ..RunConfig::default() }
    }

    #[test]
    fn roundtrips_through_json() {
        let cfg = cfg();
        let mut pt = coordinator::build_ensemble(&cfg, SweepKind::A2Basic).unwrap();
        pt.sweep_all(5);
        let ck = Checkpoint::capture(SweepKind::A2Basic, 3, 50, &cfg, &mut pt);
        let back = Checkpoint::from_json(&ck.to_json()).unwrap();
        assert_eq!(back.kind, "A.2");
        assert_eq!(back.epoch, 3);
        assert_eq!(back.states, ck.states);
        assert_eq!(back.config.n_models, 3);
    }

    #[test]
    fn restore_resumes_with_identical_states_and_energies() {
        let cfg = cfg();
        let mut pt = coordinator::build_ensemble(&cfg, SweepKind::A4Full).unwrap();
        pt.sweep_all(7);
        let energies: Vec<f64> = pt.reports().iter().map(|r| r.energy).collect();
        let ck = Checkpoint::capture(SweepKind::A4Full, 1, 7, &cfg, &mut pt);

        let mut fresh = coordinator::build_ensemble(&cfg, SweepKind::A4Full).unwrap();
        ck.restore(&mut fresh).unwrap();
        let restored: Vec<f64> = fresh.reports().iter().map(|r| r.energy).collect();
        assert_eq!(energies, restored);
        for i in 0..pt.len() {
            assert_eq!(pt.state_of(i), fresh.state_of(i));
        }
    }

    #[test]
    fn save_load_file_roundtrip_is_atomic() {
        let cfg = cfg();
        let mut pt = coordinator::build_ensemble(&cfg, SweepKind::A1Original).unwrap();
        pt.sweep_all(3);
        let ck = Checkpoint::capture(SweepKind::A1Original, 0, 3, &cfg, &mut pt);
        let dir = std::env::temp_dir().join("vectorising_ckpt_test");
        let path = dir.join("run.ckpt.json");
        ck.save(&path).unwrap();
        assert!(!path.with_extension("tmp").exists(), "tmp must be renamed away");
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.states, ck.states);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn restore_rejects_mismatched_shapes() {
        let cfg = cfg();
        let mut pt = coordinator::build_ensemble(&cfg, SweepKind::A2Basic).unwrap();
        let ck = Checkpoint::capture(SweepKind::A2Basic, 0, 0, &cfg, &mut pt);
        let mut bigger = coordinator::build_ensemble(
            &RunConfig { n_models: 5, ..cfg.clone() },
            SweepKind::A2Basic,
        )
        .unwrap();
        assert!(ck.restore(&mut bigger).is_err());
    }
}
