//! The L3 coordinator: builds a tempering ensemble from a [`RunConfig`]
//! (per-replica for the A-rungs, lane-batched for the C-rungs), schedules
//! sweep rounds over one persistent [`SweepPool`] held across rounds,
//! interleaves replica exchanges, and reports throughput + per-replica
//! statistics.
//!
//! This is the process-level frame the paper's workload ran in (AQUA@Home
//! distributed millions of such runs; here one process = one ladder of
//! "Ising models" as in §4's benchmark: 115 models, 30,000 sweeps).

pub mod checkpoint;
pub mod config;
pub mod metrics;
pub mod scheduler;

pub use checkpoint::Checkpoint;
pub use config::{RunConfig, RungTiming};
pub use metrics::{RunReport, Timer};
pub use scheduler::{PoolStats, SweepPool};

use crate::engine::{EngineBuilder, SamplerSpec};
use crate::ising::builder::{torus_workload, Workload};
use crate::sweep::{ExpMode, Sweeper};
use crate::tempering::{BatchedPtEnsemble, Ladder, PtEnsemble};
use crate::Result;

/// Build the workloads of a run — one per tempering replica, identical
/// topology, per-replica seeds (paper: 115 copies of the model at
/// different temperatures).
pub fn build_workloads(cfg: &RunConfig) -> Vec<Workload> {
    (0..cfg.n_models)
        .map(|_| torus_workload(cfg.width, cfg.height, cfg.layers, cfg.seed, cfg.jtau))
        .collect()
}

/// Build a CPU-rung ensemble for the configuration.  Takes anything that
/// lowers onto a [`SamplerSpec`] — a spec or a legacy
/// [`crate::sweep::SweepKind`]; every replica is constructed through the
/// capability-negotiated [`EngineBuilder`].
pub fn build_ensemble(cfg: &RunConfig, spec: impl Into<SamplerSpec>) -> Result<PtEnsemble> {
    let spec = spec.into();
    cfg.validate_for_spec(&spec)?;
    let ladder = Ladder::geometric(cfg.beta_cold, cfg.beta_hot, cfg.n_models);
    let replicas: Vec<Box<dyn Sweeper + Send>> = build_workloads(cfg)
        .iter()
        .enumerate()
        .map(|(i, wl)| {
            EngineBuilder::new(spec)
                .build(&wl.model, &wl.s0, cfg.seed as u32 + 1000 * i as u32)
                .map(|e| e.into_sweeper())
        })
        .collect::<Result<_>>()?;
    Ok(PtEnsemble::new(ladder, replicas, cfg.seed as u32 ^ 0x5a5a))
}

/// Build a lane-batched C-rung ensemble for the configuration: the same
/// ladder, workloads and per-replica seed convention as
/// [`build_ensemble`], grouped into plan-width lane batches.
pub fn build_batched_ensemble(
    cfg: &RunConfig,
    spec: impl Into<SamplerSpec>,
) -> Result<BatchedPtEnsemble> {
    let spec = spec.into();
    let exp = EngineBuilder::new(spec).layers(cfg.layers).plan()?.exp;
    build_batched_ensemble_with_exp(cfg, spec, exp)
}

/// [`build_batched_ensemble`] with an explicit exponential mode (tests
/// use this to align lane trajectories with the scalar rungs).
pub fn build_batched_ensemble_with_exp(
    cfg: &RunConfig,
    spec: impl Into<SamplerSpec>,
    exp: ExpMode,
) -> Result<BatchedPtEnsemble> {
    let spec = spec.into();
    cfg.validate_for_spec(&spec)?;
    let ladder = Ladder::geometric(cfg.beta_cold, cfg.beta_hot, cfg.n_models);
    let workloads = build_workloads(cfg);
    let models: Vec<_> = workloads.iter().map(|wl| wl.model.clone()).collect();
    let states: Vec<_> = workloads.iter().map(|wl| wl.s0.clone()).collect();
    let seeds: Vec<u32> = (0..cfg.n_models).map(|i| cfg.seed as u32 + 1000 * i as u32).collect();
    BatchedPtEnsemble::new(ladder, spec, &models, &states, &seeds, cfg.seed as u32 ^ 0x5a5a, exp)
}

/// Run a full simulation: rounds of (parallel sweep batch, exchange) over
/// one persistent [`SweepPool`] held across all rounds.  Replica-batch
/// (`c1`) specs run through the lane-batched ensemble.
pub fn run(cfg: &RunConfig, spec: impl Into<SamplerSpec>) -> Result<RunReport> {
    let spec = spec.into();
    if spec.rung.is_replica_batch() {
        return run_batched(cfg, spec);
    }
    let plan = EngineBuilder::new(spec).layers(cfg.layers).plan()?;
    let mut pt = build_ensemble(cfg, spec)?;
    let pool = scheduler::SweepPool::new(cfg.threads);
    let timer = Timer::start();
    let rounds = cfg.sweeps / cfg.sweeps_per_round;
    for _ in 0..rounds {
        scheduler::parallel_sweep_with_pool(&mut pt, cfg.sweeps_per_round, &pool);
        pt.exchange();
    }
    let wall = timer.seconds();
    let pstats = pool.stats();
    let rows: Vec<(f32, crate::sweep::SweepStats, f64)> =
        pt.reports().into_iter().map(|r| (r.beta, r.stats, r.energy)).collect();
    Ok(RunReport::from_stats(
        &plan.label(),
        cfg.threads,
        cfg.sweeps,
        wall,
        &rows,
        pt.swap_acceptance(),
    )
    .with_pool(pstats.jobs, pstats.busy_fraction(cfg.threads, wall)))
}

/// [`run`] over the lane-batched ensemble: one pool job per lane-batch,
/// exchanges (across batch boundaries included) on the coordinator
/// thread.
pub fn run_batched(cfg: &RunConfig, spec: impl Into<SamplerSpec>) -> Result<RunReport> {
    let spec = spec.into();
    let plan = EngineBuilder::new(spec).layers(cfg.layers).plan()?;
    let mut pt = build_batched_ensemble(cfg, spec)?;
    let pool = scheduler::SweepPool::new(cfg.threads);
    let timer = Timer::start();
    let rounds = cfg.sweeps / cfg.sweeps_per_round;
    for _ in 0..rounds {
        scheduler::parallel_sweep_batches(&mut pt, cfg.sweeps_per_round, &pool);
        pt.exchange();
    }
    let wall = timer.seconds();
    let pstats = pool.stats();
    let rows: Vec<(f32, crate::sweep::SweepStats, f64)> =
        pt.reports().into_iter().map(|r| (r.beta, r.stats, r.energy)).collect();
    Ok(RunReport::from_stats(
        &plan.label(),
        cfg.threads,
        cfg.sweeps,
        wall,
        &rows,
        pt.swap_acceptance(),
    )
    .with_pool(pstats.jobs, pstats.busy_fraction(cfg.threads, wall)))
}

/// Timing-only run used by the benchmark harness (no exchanges — the
/// paper's §4 measurement times the Metropolis sweeps themselves; PT
/// bookkeeping is excluded like the paper excludes its multi-threading
/// machinery from the per-sweep analysis).
pub fn time_sweeps(cfg: &RunConfig, spec: impl Into<SamplerSpec>) -> Result<RungTiming> {
    let spec = spec.into();
    let plan = EngineBuilder::new(spec).layers(cfg.layers).plan()?;
    let pool = scheduler::SweepPool::new(cfg.threads);
    if spec.rung.is_replica_batch() {
        let mut pt = build_batched_ensemble(cfg, spec)?;
        scheduler::parallel_sweep_batches(&mut pt, cfg.sweeps_per_round.min(cfg.sweeps), &pool);
        let timer = Timer::start();
        scheduler::parallel_sweep_batches(&mut pt, cfg.sweeps, &pool);
        let wall = timer.seconds();
        return Ok(RungTiming::labeled(
            &plan.label(),
            cfg.threads,
            wall,
            cfg.sweeps,
            cfg.total_updates(),
        ));
    }
    let mut pt = build_ensemble(cfg, spec)?;
    // Warm caches and reach a representative flip regime first.
    scheduler::parallel_sweep_with_pool(&mut pt, cfg.sweeps_per_round.min(cfg.sweeps), &pool);
    let timer = Timer::start();
    scheduler::parallel_sweep_with_pool(&mut pt, cfg.sweeps, &pool);
    let wall = timer.seconds();
    Ok(RungTiming::labeled(&plan.label(), cfg.threads, wall, cfg.sweeps, cfg.total_updates()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sweep::SweepKind;

    fn small() -> RunConfig {
        RunConfig { n_models: 4, sweeps: 20, sweeps_per_round: 10, ..RunConfig::default() }
    }

    #[test]
    fn run_produces_consistent_report() {
        let rep = run(&small(), SweepKind::A2Basic).unwrap();
        assert_eq!(rep.n_models, 4);
        assert_eq!(rep.flip_probs.len(), 4);
        let cfg = small();
        assert_eq!(rep.total_attempts, cfg.total_updates());
        assert!(rep.updates_per_sec > 0.0);
        // Ladder ordering: hottest replica flips most.
        assert!(rep.flip_probs.last().unwrap() > rep.flip_probs.first().unwrap());
        // Pool utilization rides along (2 rounds = 2 inline pool jobs).
        assert_eq!(rep.pool_jobs_queued, 2);
        assert!(rep.pool_busy_fraction > 0.0 && rep.pool_busy_fraction <= 1.0);
    }

    #[test]
    fn threads_do_not_change_totals() {
        let mut cfg = small();
        let r1 = run(&cfg, SweepKind::A4Full).unwrap();
        cfg.threads = 4;
        let r4 = run(&cfg, SweepKind::A4Full).unwrap();
        assert_eq!(r1.total_attempts, r4.total_attempts);
        assert_eq!(r1.total_flips, r4.total_flips); // deterministic per-replica RNG
    }

    #[test]
    fn time_sweeps_reports_throughput() {
        let t = time_sweeps(&small(), SweepKind::A3VecRng).unwrap();
        assert!(t.seconds > 0.0);
        assert!(t.updates_per_sec > 0.0);
        assert_eq!(t.kind, "A.3");
    }

    #[test]
    fn run_routes_c_rungs_through_the_batched_ensemble() {
        let rep = run(&small(), SweepKind::C1ReplicaBatch).unwrap();
        assert_eq!(rep.kind, "C.1");
        assert_eq!(rep.n_models, 4);
        let cfg = small();
        assert_eq!(rep.total_attempts, cfg.total_updates());
        assert!(rep.flip_probs.last().unwrap() > rep.flip_probs.first().unwrap());
    }

    #[test]
    fn batched_threads_do_not_change_totals() {
        let mut cfg = RunConfig { n_models: 10, sweeps: 20, sweeps_per_round: 10, ..RunConfig::default() };
        let r1 = run(&cfg, SweepKind::C1ReplicaBatch).unwrap();
        cfg.threads = 4;
        let r4 = run(&cfg, SweepKind::C1ReplicaBatch).unwrap();
        assert_eq!(r1.total_attempts, r4.total_attempts);
        assert_eq!(r1.total_flips, r4.total_flips); // deterministic per-lane RNG
        // 10 replicas at W=4 -> 3 lane-batches, so min(4 threads, 3 jobs)
        // = 3 worker tasks per round, 2 rounds.
        assert_eq!(r4.pool_jobs_queued, 6);
        assert!(r4.pool_busy_fraction > 0.0);
    }

    #[test]
    fn c_rungs_open_shallow_workloads() {
        // layers = 2 is exactly what the A-rungs must reject — the C-rungs
        // vectorize across replicas, so it runs (and batches at W=8).
        let cfg = RunConfig {
            layers: 2,
            n_models: 10,
            sweeps: 20,
            sweeps_per_round: 10,
            ..RunConfig::default()
        };
        assert!(run(&cfg, SweepKind::A4Full).is_err());
        let rep = run(&cfg, SweepKind::C1ReplicaBatchW8).unwrap();
        assert_eq!(rep.total_attempts, cfg.total_updates());
        assert!(rep.updates_per_sec > 0.0);
    }

    #[test]
    fn time_sweeps_covers_batched_rungs() {
        let t = time_sweeps(&small(), SweepKind::C1ReplicaBatch).unwrap();
        assert!(t.seconds > 0.0);
        assert_eq!(t.kind, "C.1");
    }
}
