//! The L3 coordinator: builds a tempering ensemble from a [`RunSpec`]
//! (per-replica for the A-rungs, lane-batched — possibly with
//! heterogeneous per-group plans — for the C-rungs), schedules sweep
//! rounds over one persistent [`SweepPool`] held across rounds,
//! interleaves replica exchanges, and reports throughput + per-replica
//! statistics.
//!
//! **Run API v1.** A run is described by a versioned, serializable
//! [`RunSpec`] (workload geometry + sampler spec) and can be
//! checkpointed and resumed through schema-v2 [`Checkpoint`]s, which
//! carry the spec and the resolved group layout — so
//! [`resume_run`]/`repro run --resume` need no sampler flags, and any
//! plan the builder can instantiate (portable `C.1w16` included)
//! round-trips bit-exactly.  The legacy `(RunConfig, SweepKind)` entry
//! points remain as shims lowering onto specs.
//!
//! This is the process-level frame the paper's workload ran in (AQUA@Home
//! distributed millions of such runs; here one process = one ladder of
//! "Ising models" as in §4's benchmark: 115 models, 30,000 sweeps).

pub mod checkpoint;
pub mod config;
pub mod metrics;
pub mod scheduler;

pub use checkpoint::{Checkpoint, CHECKPOINT_SCHEMA_VERSION};
pub use config::{LatencyPercentiles, RunConfig, RunSpec, RungTiming, RUN_SPEC_VERSION};
pub use metrics::{RunReport, Timer};
pub use scheduler::{PoolStats, SweepPool};

use std::path::{Path, PathBuf};

use crate::engine::{EngineBuilder, GroupPlan, SamplerSpec, Width};
use crate::ising::builder::{pm_torus_workload, torus_workload, Workload};
use crate::sweep::{ExpMode, SweepStats, Sweeper};
use crate::tempering::{BatchedPtEnsemble, Ladder, PtEnsemble};
use crate::Result;

/// Build the workloads of a run — one per tempering replica, identical
/// topology, per-replica seeds (paper: 115 copies of the model at
/// different temperatures).
pub fn build_workloads(cfg: &RunConfig) -> Vec<Workload> {
    (0..cfg.n_models)
        .map(|_| torus_workload(cfg.width, cfg.height, cfg.layers, cfg.seed, cfg.jtau))
        .collect()
}

/// Sampler-aware [`build_workloads`]: the multi-spin rung needs the
/// discrete ±J / zero-field workload (same torus, colouring and seed
/// conventions — see [`pm_torus_workload`]); every other rung keeps the
/// continuous-coupling builder.
pub fn build_workloads_spec(cfg: &RunConfig, spec: &SamplerSpec) -> Vec<Workload> {
    if !spec.rung.is_multispin() {
        return build_workloads(cfg);
    }
    (0..cfg.n_models)
        .map(|_| pm_torus_workload(cfg.width, cfg.height, cfg.layers, cfg.seed, cfg.jtau))
        .collect()
}

/// Per-replica RNG seeds of a run (the convention every ensemble — and
/// every checkpoint — shares).
fn replica_seeds(cfg: &RunConfig) -> Vec<u32> {
    (0..cfg.n_models).map(|i| cfg.seed as u32 + 1000 * i as u32).collect()
}

/// Build a CPU-rung ensemble for the configuration.  Takes anything that
/// lowers onto a [`SamplerSpec`] — a spec or a legacy
/// [`crate::sweep::SweepKind`]; every replica is constructed through the
/// capability-negotiated [`EngineBuilder`].
pub fn build_ensemble(cfg: &RunConfig, spec: impl Into<SamplerSpec>) -> Result<PtEnsemble> {
    let spec = spec.into();
    cfg.validate_for_spec(&spec)?;
    let ladder = Ladder::geometric(cfg.beta_cold, cfg.beta_hot, cfg.n_models);
    let seeds = replica_seeds(cfg);
    let replicas: Vec<Box<dyn Sweeper + Send>> = build_workloads_spec(cfg, &spec)
        .iter()
        .zip(&seeds)
        .map(|(wl, &seed)| {
            EngineBuilder::new(spec).build(&wl.model, &wl.s0, seed).map(|e| e.into_sweeper())
        })
        .collect::<Result<_>>()?;
    Ok(PtEnsemble::new(ladder, replicas, cfg.seed as u32 ^ 0x5a5a))
}

/// Build a lane-batched C-rung ensemble for the configuration: the same
/// ladder, workloads and per-replica seed convention as
/// [`build_ensemble`], partitioned into plan-width lane groups (a
/// `width: auto` spec may choose a heterogeneous layout — see
/// [`crate::tempering::batch::plan_groups`]).
pub fn build_batched_ensemble(
    cfg: &RunConfig,
    spec: impl Into<SamplerSpec>,
) -> Result<BatchedPtEnsemble> {
    let spec = spec.into();
    let exp = EngineBuilder::new(spec).layers(cfg.layers).plan()?.exp;
    build_batched_ensemble_with_exp(cfg, spec, exp)
}

/// [`build_batched_ensemble`] with an explicit exponential mode (tests
/// use this to align lane trajectories with the scalar rungs).
pub fn build_batched_ensemble_with_exp(
    cfg: &RunConfig,
    spec: impl Into<SamplerSpec>,
    exp: ExpMode,
) -> Result<BatchedPtEnsemble> {
    let spec = spec.into();
    cfg.validate_for_spec(&spec)?;
    let ladder = Ladder::geometric(cfg.beta_cold, cfg.beta_hot, cfg.n_models);
    let workloads = build_workloads(cfg);
    let models: Vec<_> = workloads.iter().map(|wl| wl.model.clone()).collect();
    let states: Vec<_> = workloads.iter().map(|wl| wl.s0.clone()).collect();
    let seeds = replica_seeds(cfg);
    BatchedPtEnsemble::new(ladder, spec, &models, &states, &seeds, cfg.seed as u32 ^ 0x5a5a, exp)
}

/// Build a batched ensemble with a checkpoint's recorded group layout:
/// each group keeps its recorded rung × width (the RNG payloads are
/// width-dependent), while the *backend* is re-resolved against this
/// host — so a run checkpointed on AVX2 resumes on SSE2/portable lanes
/// bit-exactly.
pub fn build_batched_for_checkpoint(
    cfg: &RunConfig,
    spec: SamplerSpec,
    ck_plans: &[GroupPlan],
) -> Result<BatchedPtEnsemble> {
    cfg.validate_for_spec(&spec)?;
    let exp = EngineBuilder::new(spec).layers(cfg.layers).plan()?.exp;
    let mut groups = Vec::with_capacity(ck_plans.len());
    for p in ck_plans {
        let gspec = SamplerSpec { width: Width::W(p.resolved.width), ..spec };
        let plan = EngineBuilder::new(gspec).layers(cfg.layers).exp(exp).plan()?;
        groups.push(GroupPlan::new(plan.resolved(), p.replicas));
    }
    let ladder = Ladder::geometric(cfg.beta_cold, cfg.beta_hot, cfg.n_models);
    let workloads = build_workloads(cfg);
    let models: Vec<_> = workloads.iter().map(|wl| wl.model.clone()).collect();
    let states: Vec<_> = workloads.iter().map(|wl| wl.s0.clone()).collect();
    let seeds = replica_seeds(cfg);
    BatchedPtEnsemble::with_groups(
        ladder,
        spec,
        &groups,
        &models,
        &states,
        &seeds,
        cfg.seed as u32 ^ 0x5a5a,
        exp,
    )
}

/// Checkpoint/resume options of a spec-driven run.
#[derive(Clone, Debug, Default)]
pub struct RunOptions {
    /// Save a schema-v2 checkpoint here (atomically) during the run.
    pub checkpoint: Option<PathBuf>,
    /// Rounds between saves (0 or 1 = after every round); the final
    /// round is always saved when `checkpoint` is set.
    pub checkpoint_every: usize,
    /// Resume from this checkpoint: restored into the freshly built
    /// ensemble before any sweeping, rounds continue from its
    /// `sweeps_done`.
    pub resume: Option<Checkpoint>,
}

/// Either flavour of ensemble behind one round-loop (the A-rungs sweep
/// per replica, the C-rungs per lane-group).
enum Built {
    Replicas { pt: PtEnsemble, plan_groups: Vec<GroupPlan>, label: String },
    Batched(BatchedPtEnsemble),
}

impl Built {
    fn sweep(&mut self, pool: &SweepPool, n_sweeps: usize) {
        match self {
            Built::Replicas { pt, .. } => scheduler::parallel_sweep_with_pool(pt, n_sweeps, pool),
            Built::Batched(pt) => scheduler::parallel_sweep_batches(pt, n_sweeps, pool),
        }
    }

    fn exchange(&mut self) {
        match self {
            Built::Replicas { pt, .. } => pt.exchange(),
            Built::Batched(pt) => pt.exchange(),
        }
    }

    fn rows(&mut self) -> Vec<(f32, SweepStats, f64)> {
        let reports = match self {
            Built::Replicas { pt, .. } => pt.reports(),
            Built::Batched(pt) => pt.reports(),
        };
        reports.into_iter().map(|r| (r.beta, r.stats, r.energy)).collect()
    }

    fn swap_acceptance(&self) -> f64 {
        match self {
            Built::Replicas { pt, .. } => pt.swap_acceptance(),
            Built::Batched(pt) => pt.swap_acceptance(),
        }
    }

    fn label(&self) -> String {
        match self {
            Built::Replicas { label, .. } => label.clone(),
            Built::Batched(pt) => pt.label(),
        }
    }

    fn plans(&self) -> Vec<GroupPlan> {
        match self {
            Built::Replicas { plan_groups, .. } => plan_groups.clone(),
            Built::Batched(pt) => pt.plans().to_vec(),
        }
    }

    fn capture(&mut self, rs: &RunSpec, epoch: u64, sweeps_done: usize) -> Checkpoint {
        match self {
            Built::Replicas { pt, .. } => {
                Checkpoint::capture_spec(rs.sampler, epoch, sweeps_done, &rs.config, pt)
            }
            Built::Batched(pt) => Checkpoint::capture_batched(epoch, sweeps_done, &rs.config, pt),
        }
    }

    fn restore(&mut self, ck: &Checkpoint) -> Result<()> {
        match self {
            Built::Replicas { pt, .. } => ck.restore(pt),
            Built::Batched(pt) => ck.restore_batched(pt),
        }
    }
}

/// Build the right ensemble flavour for a run spec.  When resuming, a
/// batched ensemble reuses the checkpoint's recorded group layout and a
/// per-replica ensemble pins the recorded width, so the rebuilt
/// ensemble always matches the RNG payloads regardless of what `auto`
/// would negotiate on this host.
fn build_for(rs: &RunSpec, resume: Option<&Checkpoint>) -> Result<Built> {
    let mut spec = rs.sampler;
    if rs.sampler.rung.is_replica_batch() {
        if let Some(ck) = resume {
            if !ck.plans.is_empty() {
                return Ok(Built::Batched(build_batched_for_checkpoint(
                    &rs.config, spec, &ck.plans,
                )?));
            }
        }
        return Ok(Built::Batched(build_batched_ensemble(&rs.config, spec)?));
    }
    if let Some(p) = resume.and_then(|ck| ck.plans.first()) {
        spec.width = Width::W(p.resolved.width);
    }
    let plan = EngineBuilder::new(spec).layers(rs.config.layers).plan()?;
    let pt = build_ensemble(&rs.config, spec)?;
    let plan_groups = vec![GroupPlan::new(plan.resolved(), rs.config.n_models)];
    Ok(Built::Replicas { pt, plan_groups, label: plan.label() })
}

/// Resume geometry check: every field that shapes the ensemble (and its
/// seeds) must match; `sweeps` and `threads` may differ so a resume can
/// extend a run or use a different core count.
fn check_resume_config(ck: &RunConfig, cfg: &RunConfig) -> Result<()> {
    let same = ck.width == cfg.width
        && ck.height == cfg.height
        && ck.layers == cfg.layers
        && ck.n_models == cfg.n_models
        && ck.sweeps_per_round == cfg.sweeps_per_round
        && ck.seed == cfg.seed
        && ck.beta_cold == cfg.beta_cold
        && ck.beta_hot == cfg.beta_hot
        && ck.jtau == cfg.jtau;
    anyhow::ensure!(
        same,
        "checkpoint workload ({}x{}x{} layers, {} models, seed {}) does not match the \
         requested run ({}x{}x{} layers, {} models, seed {})",
        ck.width,
        ck.height,
        ck.layers,
        ck.n_models,
        ck.seed,
        cfg.width,
        cfg.height,
        cfg.layers,
        cfg.n_models,
        cfg.seed
    );
    Ok(())
}

/// Run a full simulation described by a [`RunSpec`]: rounds of (parallel
/// sweep batch, exchange) over one persistent [`SweepPool`] held across
/// all rounds.  Replica-batch (`c1`) specs run through the lane-batched
/// ensemble (heterogeneous group layouts included); the report echoes
/// the resolved per-group plans.
pub fn run_spec(rs: &RunSpec) -> Result<RunReport> {
    run_spec_with(rs, &RunOptions::default())
}

/// [`run_spec`] with checkpointing and resume (see [`RunOptions`]).
pub fn run_spec_with(rs: &RunSpec, opts: &RunOptions) -> Result<RunReport> {
    Ok(run_spec_inner(rs, opts, false)?.0)
}

/// [`run_spec_with`] that additionally captures the final state as an
/// in-memory schema-v2 [`Checkpoint`] (the service's checkpointable run
/// jobs return it inline instead of writing to the server's disk).
pub fn run_spec_capturing(rs: &RunSpec, opts: &RunOptions) -> Result<(RunReport, Checkpoint)> {
    let (report, ck) = run_spec_inner(rs, opts, true)?;
    Ok((report, ck.expect("final capture requested")))
}

fn run_spec_inner(
    rs: &RunSpec,
    opts: &RunOptions,
    capture_final: bool,
) -> Result<(RunReport, Option<Checkpoint>)> {
    let cfg = &rs.config;
    rs.validate()?;
    if let Some(ck) = &opts.resume {
        check_resume_config(&ck.config, cfg)?;
    }
    let mut ens = build_for(rs, opts.resume.as_ref())?;
    let mut start_round = 0usize;
    if let Some(ck) = &opts.resume {
        ens.restore(ck)?;
        anyhow::ensure!(
            ck.sweeps_done % cfg.sweeps_per_round == 0,
            "checkpoint stopped mid-round ({} sweeps done, {} per round)",
            ck.sweeps_done,
            cfg.sweeps_per_round
        );
        start_round = ck.sweeps_done / cfg.sweeps_per_round;
    }
    let rounds = cfg.sweeps / cfg.sweeps_per_round;
    anyhow::ensure!(
        start_round <= rounds,
        "checkpoint has already completed {} sweeps, run asks for {}",
        start_round * cfg.sweeps_per_round,
        cfg.sweeps
    );
    let every = opts.checkpoint_every.max(1);
    let pool = SweepPool::new(cfg.threads);
    let timer = Timer::start();
    for r in start_round..rounds {
        ens.sweep(&pool, cfg.sweeps_per_round);
        ens.exchange();
        if let Some(path) = &opts.checkpoint {
            let done = r + 1;
            if done % every == 0 || done == rounds {
                ens.capture(rs, done as u64, done * cfg.sweeps_per_round).save(path)?;
            }
        }
    }
    let wall = timer.seconds();
    let pstats = pool.stats();
    let rows = ens.rows();
    let swept = (rounds - start_round) * cfg.sweeps_per_round;
    let report = RunReport::from_stats(
        &ens.label(),
        cfg.threads,
        swept,
        wall,
        &rows,
        ens.swap_acceptance(),
    )
    .with_pool(pstats.jobs, pstats.busy_fraction(cfg.threads, wall))
    .with_plans(ens.plans());
    let final_ck = capture_final
        .then(|| ens.capture(rs, rounds as u64, rounds * cfg.sweeps_per_round));
    Ok((report, final_ck))
}

/// Resume a run from a saved checkpoint: the checkpoint's own
/// [`RunSpec`] rebuilds the ensemble (no sampler flags needed — v1
/// files lower their `kind` label onto a spec), the recorded states and
/// RNG payloads restore, and the remaining rounds run.  `override_spec`
/// lets a caller extend the run (more sweeps) or change the thread
/// count — the workload geometry must match the checkpoint.
pub fn resume_run(
    path: &Path,
    override_spec: impl FnOnce(RunSpec) -> RunSpec,
    opts: &RunOptions,
) -> Result<RunReport> {
    let ck = Checkpoint::load(path)?;
    let rs = override_spec(ck.run_spec()?);
    let opts = RunOptions { resume: Some(ck), ..opts.clone() };
    run_spec_with(&rs, &opts)
}

/// Run a full simulation — the legacy `(RunConfig, spec)` shim over
/// [`run_spec`].
pub fn run(cfg: &RunConfig, spec: impl Into<SamplerSpec>) -> Result<RunReport> {
    run_spec(&RunSpec::new(cfg.clone(), spec))
}

/// [`run`] over the lane-batched ensemble (kept for callers that want
/// the batched path explicitly; [`run_spec`] routes `c1` specs here
/// automatically).
pub fn run_batched(cfg: &RunConfig, spec: impl Into<SamplerSpec>) -> Result<RunReport> {
    let spec = spec.into();
    anyhow::ensure!(
        spec.rung.is_replica_batch(),
        "{} is not a replica-batch rung",
        spec.rung.label()
    );
    run_spec(&RunSpec::new(cfg.clone(), spec))
}

/// Timing-only run used by the benchmark harness (no exchanges — the
/// paper's §4 measurement times the Metropolis sweeps themselves; PT
/// bookkeeping is excluded like the paper excludes its multi-threading
/// machinery from the per-sweep analysis).
pub fn time_sweeps_spec(rs: &RunSpec) -> Result<RungTiming> {
    use crate::obs::Histogram;
    let cfg = &rs.config;
    let plan = rs.plan()?;
    let pool = SweepPool::new(cfg.threads);
    // The timed span is chunked into rounds of `sweeps_per_round`: the
    // sweep trajectory is identical to one long call (chunking only
    // moves where the loop pauses to read the clock), and the per-round
    // wall times give the artifact its latency percentiles.
    let round = cfg.sweeps_per_round.min(cfg.sweeps).max(1);
    let hist = Histogram::new();
    if rs.sampler.rung.is_replica_batch() {
        let mut pt = build_batched_ensemble(cfg, rs.sampler)?;
        scheduler::parallel_sweep_batches(&mut pt, round, &pool);
        let timer = Timer::start();
        let mut left = cfg.sweeps;
        while left > 0 {
            let n = round.min(left);
            let t0 = std::time::Instant::now();
            scheduler::parallel_sweep_batches(&mut pt, n, &pool);
            hist.record(t0.elapsed().as_micros() as u64);
            left -= n;
        }
        let wall = timer.seconds();
        return Ok(RungTiming::labeled(
            &plan.label(),
            cfg.threads,
            wall,
            cfg.sweeps,
            cfg.total_updates(),
        )
        .with_round_latency(&hist.snapshot()));
    }
    let mut pt = build_ensemble(cfg, rs.sampler)?;
    // Warm caches and reach a representative flip regime first.
    scheduler::parallel_sweep_with_pool(&mut pt, round, &pool);
    let timer = Timer::start();
    let mut left = cfg.sweeps;
    while left > 0 {
        let n = round.min(left);
        let t0 = std::time::Instant::now();
        scheduler::parallel_sweep_with_pool(&mut pt, n, &pool);
        hist.record(t0.elapsed().as_micros() as u64);
        left -= n;
    }
    let wall = timer.seconds();
    Ok(RungTiming::labeled(&plan.label(), cfg.threads, wall, cfg.sweeps, cfg.total_updates())
        .with_round_latency(&hist.snapshot()))
}

/// [`time_sweeps_spec`] — the legacy `(RunConfig, spec)` shim.
pub fn time_sweeps(cfg: &RunConfig, spec: impl Into<SamplerSpec>) -> Result<RungTiming> {
    time_sweeps_spec(&RunSpec::new(cfg.clone(), spec))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{BackendPref, Rung};
    use crate::sweep::SweepKind;

    fn small() -> RunConfig {
        RunConfig { n_models: 4, sweeps: 20, sweeps_per_round: 10, ..RunConfig::default() }
    }

    #[test]
    fn run_produces_consistent_report() {
        let rep = run(&small(), SweepKind::A2Basic).unwrap();
        assert_eq!(rep.n_models, 4);
        assert_eq!(rep.flip_probs.len(), 4);
        let cfg = small();
        assert_eq!(rep.total_attempts, cfg.total_updates());
        assert!(rep.updates_per_sec > 0.0);
        // Ladder ordering: hottest replica flips most.
        assert!(rep.flip_probs.last().unwrap() > rep.flip_probs.first().unwrap());
        // Pool utilization rides along (2 rounds = 2 inline pool jobs).
        assert_eq!(rep.pool_jobs_queued, 2);
        assert!(rep.pool_busy_fraction > 0.0 && rep.pool_busy_fraction <= 1.0);
        // The Run API echo: one resolved plan covering every replica.
        assert_eq!(rep.plans.len(), 1);
        assert_eq!(rep.plans[0].resolved.width, 1);
        assert_eq!(rep.plans[0].replicas, 4);
    }

    #[test]
    fn threads_do_not_change_totals() {
        let mut cfg = small();
        let r1 = run(&cfg, SweepKind::A4Full).unwrap();
        cfg.threads = 4;
        let r4 = run(&cfg, SweepKind::A4Full).unwrap();
        assert_eq!(r1.total_attempts, r4.total_attempts);
        assert_eq!(r1.total_flips, r4.total_flips); // deterministic per-replica RNG
    }

    #[test]
    fn time_sweeps_reports_throughput() {
        let t = time_sweeps(&small(), SweepKind::A3VecRng).unwrap();
        assert!(t.seconds > 0.0);
        assert!(t.updates_per_sec > 0.0);
        assert_eq!(t.kind, "A.3");
    }

    #[test]
    fn run_routes_c_rungs_through_the_batched_ensemble() {
        let rep = run(&small(), SweepKind::C1ReplicaBatch).unwrap();
        assert_eq!(rep.kind, "C.1");
        assert_eq!(rep.n_models, 4);
        let cfg = small();
        assert_eq!(rep.total_attempts, cfg.total_updates());
        assert!(rep.flip_probs.last().unwrap() > rep.flip_probs.first().unwrap());
        assert_eq!(rep.plans.len(), 1);
        assert_eq!(rep.plans[0].replicas, 4);
    }

    #[test]
    fn batched_threads_do_not_change_totals() {
        let mut cfg =
            RunConfig { n_models: 10, sweeps: 20, sweeps_per_round: 10, ..RunConfig::default() };
        let r1 = run(&cfg, SweepKind::C1ReplicaBatch).unwrap();
        cfg.threads = 4;
        let r4 = run(&cfg, SweepKind::C1ReplicaBatch).unwrap();
        assert_eq!(r1.total_attempts, r4.total_attempts);
        assert_eq!(r1.total_flips, r4.total_flips); // deterministic per-lane RNG
        // 10 replicas at W=4 -> 3 lane-batches, so min(4 threads, 3 jobs)
        // = 3 worker tasks per round, 2 rounds.
        assert_eq!(r4.pool_jobs_queued, 6);
        assert!(r4.pool_busy_fraction > 0.0);
    }

    #[test]
    fn c_rungs_open_shallow_workloads() {
        // layers = 2 is exactly what the A-rungs must reject — the C-rungs
        // vectorize across replicas, so it runs (and batches at W=8).
        let cfg = RunConfig {
            layers: 2,
            n_models: 10,
            sweeps: 20,
            sweeps_per_round: 10,
            ..RunConfig::default()
        };
        assert!(run(&cfg, SweepKind::A4Full).is_err());
        let rep = run(&cfg, SweepKind::C1ReplicaBatchW8).unwrap();
        assert_eq!(rep.total_attempts, cfg.total_updates());
        assert!(rep.updates_per_sec > 0.0);
    }

    #[test]
    fn time_sweeps_covers_batched_rungs() {
        let t = time_sweeps(&small(), SweepKind::C1ReplicaBatch).unwrap();
        assert!(t.seconds > 0.0);
        assert_eq!(t.kind, "C.1");
    }

    #[test]
    fn run_spec_covers_widths_the_legacy_enum_cannot_spell() {
        // The acceptance scenario: a portable C.1w16 run end to end.
        let rs = RunSpec::new(
            small(),
            crate::engine::SamplerSpec::rung(Rung::C1).w(16).on(BackendPref::Portable),
        );
        let rep = run_spec(&rs).unwrap();
        assert_eq!(rep.kind, "C.1w16");
        assert_eq!(rep.plans.len(), 1);
        assert_eq!(rep.plans[0].resolved.width, 16);
        assert_eq!(rep.plans[0].replicas, 4);
        assert_eq!(rep.total_attempts, rs.config.total_updates());
    }

    #[test]
    fn run_spec_covers_the_multispin_rung() {
        // The m1 rung swaps in the ±J workload transparently and runs
        // through the per-replica ensemble end to end.
        let rs = RunSpec::new(small(), crate::engine::SamplerSpec::rung(Rung::M1));
        let rep = run_spec(&rs).unwrap();
        assert_eq!(rep.kind, "M.1");
        assert_eq!(rep.plans.len(), 1);
        assert_eq!(rep.plans[0].resolved.width, 64);
        assert_eq!(rep.total_attempts, rs.config.total_updates());
        assert!(rep.total_flips > 0);
        assert!(rep.flip_probs.last().unwrap() > rep.flip_probs.first().unwrap());
        // Shallow even layer counts are open to m1 (the A-ladder's
        // multiple-of-4 rule does not apply)...
        let shallow = RunSpec::new(
            RunConfig { layers: 6, ..small() },
            crate::engine::SamplerSpec::rung(Rung::M1),
        );
        assert_eq!(run_spec(&shallow).unwrap().total_attempts, shallow.config.total_updates());
        // ...but odd ones are not.
        let odd = RunSpec::new(
            RunConfig { layers: 9, ..small() },
            crate::engine::SamplerSpec::rung(Rung::M1),
        );
        assert!(run_spec(&odd).is_err());
    }

    #[test]
    fn run_spec_covers_the_accel_rungs() {
        // The B-rungs run through the per-replica ensemble on the
        // software device — full PT run, plan echo, checkpointable.
        let rs = RunSpec::new(small(), crate::engine::SamplerSpec::rung(Rung::B2));
        let rep = run_spec(&rs).unwrap();
        assert_eq!(rep.kind, "B.2");
        assert_eq!(rep.plans.len(), 1);
        assert_eq!(rep.plans[0].resolved.width, 32);
        assert_eq!(rep.total_attempts, rs.config.total_updates());
        assert!(rep.total_flips > 0);
        // b2 needs an even depth; the structured rejection routes the
        // caller at b1.
        let odd = RunSpec::new(
            RunConfig { layers: 9, ..small() },
            crate::engine::SamplerSpec::rung(Rung::B2),
        );
        assert!(run_spec(&odd).is_err());
        let odd_b1 =
            RunSpec::new(RunConfig { layers: 9, ..small() }, crate::engine::SamplerSpec::rung(Rung::B1));
        assert_eq!(run_spec(&odd_b1).unwrap().total_attempts, odd_b1.config.total_updates());
    }

    #[test]
    fn m1_checkpoint_resumes_bit_exactly() {
        let dir = std::env::temp_dir().join("vectorising_coordinator_m1_resume");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let m1 = crate::engine::SamplerSpec::rung(Rung::M1);
        let cfg = RunConfig { n_models: 3, sweeps: 40, sweeps_per_round: 10, ..small() };
        let ref_report = run_spec(&RunSpec::new(cfg.clone(), m1)).unwrap();
        // First half, checkpointed, then resumed for the second half.
        let half_path = dir.join("half.ck.json");
        let half = RunSpec::new(RunConfig { sweeps: 20, ..cfg }, m1);
        run_spec_with(
            &half,
            &RunOptions { checkpoint: Some(half_path.clone()), checkpoint_every: 2, resume: None },
        )
        .unwrap();
        let resumed = resume_run(
            &half_path,
            |mut rs| {
                rs.config.sweeps = 40;
                rs
            },
            &RunOptions::default(),
        )
        .unwrap();
        assert_eq!(resumed.sweeps, 20);
        for (a, b) in ref_report.energies.iter().zip(&resumed.energies) {
            assert_eq!(a.to_bits(), b.to_bits(), "resumed energies must be bit-exact");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn checkpointed_run_resumes_bit_exactly_via_run_spec() {
        let dir = std::env::temp_dir().join("vectorising_coordinator_resume");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("run.ck.json");
        let cfg = RunConfig { n_models: 5, sweeps: 40, sweeps_per_round: 10, ..small() };
        // Reference: the full run, checkpointing every 2 rounds (the
        // capture canonicalization at round 2 is part of the trajectory).
        let full = RunSpec::new(cfg.clone(), SweepKind::C1ReplicaBatch);
        let ref_report = run_spec_with(
            &full,
            &RunOptions {
                checkpoint: Some(path.clone()),
                checkpoint_every: 2,
                resume: None,
            },
        )
        .unwrap();
        // First half only (2 rounds), checkpointed at its end.
        let half =
            RunSpec::new(RunConfig { sweeps: 20, ..cfg.clone() }, SweepKind::C1ReplicaBatch);
        let half_path = dir.join("half.ck.json");
        run_spec_with(
            &half,
            &RunOptions {
                checkpoint: Some(half_path.clone()),
                checkpoint_every: 2,
                resume: None,
            },
        )
        .unwrap();
        // Resume from the half checkpoint — the spec comes from the file;
        // extend the target back to the full 40 sweeps.
        let resumed = resume_run(
            &half_path,
            |mut rs| {
                rs.config.sweeps = 40;
                rs
            },
            &RunOptions { checkpoint: Some(path.clone()), checkpoint_every: 2, resume: None },
        )
        .unwrap();
        assert_eq!(resumed.sweeps, 20, "the resumed segment ran rounds 3..4");
        for (a, b) in ref_report.energies.iter().zip(&resumed.energies) {
            assert_eq!(a.to_bits(), b.to_bits(), "resumed energies must be bit-exact");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
