//! The L3 coordinator: builds a tempering ensemble from a [`RunConfig`],
//! schedules sweep rounds across worker threads, interleaves replica
//! exchanges, and reports throughput + per-replica statistics.
//!
//! This is the process-level frame the paper's workload ran in (AQUA@Home
//! distributed millions of such runs; here one process = one ladder of
//! "Ising models" as in §4's benchmark: 115 models, 30,000 sweeps).

pub mod checkpoint;
pub mod config;
pub mod metrics;
pub mod scheduler;

pub use checkpoint::Checkpoint;
pub use config::{RunConfig, RungTiming};
pub use metrics::{RunReport, Timer};

use crate::ising::builder::{torus_workload, Workload};
use crate::sweep::{make_sweeper, SweepKind, Sweeper};
use crate::tempering::{Ladder, PtEnsemble};
use crate::Result;

/// Build the workloads of a run — one per tempering replica, identical
/// topology, per-replica seeds (paper: 115 copies of the model at
/// different temperatures).
pub fn build_workloads(cfg: &RunConfig) -> Vec<Workload> {
    (0..cfg.n_models)
        .map(|_| torus_workload(cfg.width, cfg.height, cfg.layers, cfg.seed, cfg.jtau))
        .collect()
}

/// Build a CPU-rung ensemble for the configuration.
pub fn build_ensemble(cfg: &RunConfig, kind: SweepKind) -> Result<PtEnsemble> {
    cfg.validate()?;
    let ladder = Ladder::geometric(cfg.beta_cold, cfg.beta_hot, cfg.n_models);
    let replicas: Vec<Box<dyn Sweeper + Send>> = build_workloads(cfg)
        .iter()
        .enumerate()
        .map(|(i, wl)| make_sweeper(kind, &wl.model, &wl.s0, cfg.seed as u32 + 1000 * i as u32))
        .collect::<Result<_>>()?;
    Ok(PtEnsemble::new(ladder, replicas, cfg.seed as u32 ^ 0x5a5a))
}

/// Run a full simulation: rounds of (parallel sweep batch, exchange).
/// Returns the run report with timing and per-replica statistics.
pub fn run(cfg: &RunConfig, kind: SweepKind) -> Result<RunReport> {
    let mut pt = build_ensemble(cfg, kind)?;
    let timer = Timer::start();
    let rounds = cfg.sweeps / cfg.sweeps_per_round;
    for _ in 0..rounds {
        scheduler::parallel_sweep(&mut pt, cfg.sweeps_per_round, cfg.threads);
        pt.exchange();
    }
    let wall = timer.seconds();
    let rows: Vec<(f32, crate::sweep::SweepStats, f64)> =
        pt.reports().into_iter().map(|r| (r.beta, r.stats, r.energy)).collect();
    Ok(RunReport::from_stats(
        kind.label(),
        cfg.threads,
        cfg.sweeps,
        wall,
        &rows,
        pt.swap_acceptance(),
    ))
}

/// Timing-only run used by the benchmark harness (no exchanges — the
/// paper's §4 measurement times the Metropolis sweeps themselves; PT
/// bookkeeping is excluded like the paper excludes its multi-threading
/// machinery from the per-sweep analysis).
pub fn time_sweeps(cfg: &RunConfig, kind: SweepKind) -> Result<RungTiming> {
    let mut pt = build_ensemble(cfg, kind)?;
    // Warm caches and reach a representative flip regime first.
    scheduler::parallel_sweep(&mut pt, cfg.sweeps_per_round.min(cfg.sweeps), cfg.threads);
    let timer = Timer::start();
    scheduler::parallel_sweep(&mut pt, cfg.sweeps, cfg.threads);
    let wall = timer.seconds();
    Ok(RungTiming::new(kind, cfg.threads, wall, cfg.sweeps, cfg.total_updates()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> RunConfig {
        RunConfig { n_models: 4, sweeps: 20, sweeps_per_round: 10, ..RunConfig::default() }
    }

    #[test]
    fn run_produces_consistent_report() {
        let rep = run(&small(), SweepKind::A2Basic).unwrap();
        assert_eq!(rep.n_models, 4);
        assert_eq!(rep.flip_probs.len(), 4);
        let cfg = small();
        assert_eq!(rep.total_attempts, cfg.total_updates());
        assert!(rep.updates_per_sec > 0.0);
        // Ladder ordering: hottest replica flips most.
        assert!(rep.flip_probs.last().unwrap() > rep.flip_probs.first().unwrap());
    }

    #[test]
    fn threads_do_not_change_totals() {
        let mut cfg = small();
        let r1 = run(&cfg, SweepKind::A4Full).unwrap();
        cfg.threads = 4;
        let r4 = run(&cfg, SweepKind::A4Full).unwrap();
        assert_eq!(r1.total_attempts, r4.total_attempts);
        assert_eq!(r1.total_flips, r4.total_flips); // deterministic per-replica RNG
    }

    #[test]
    fn time_sweeps_reports_throughput() {
        let t = time_sweeps(&small(), SweepKind::A3VecRng).unwrap();
        assert!(t.seconds > 0.0);
        assert!(t.updates_per_sec > 0.0);
        assert_eq!(t.kind, "A.3");
    }
}
