//! Run metrics: wall-clock timing and throughput accounting.

use std::time::Instant;

use crate::engine::GroupPlan;
use crate::sweep::SweepStats;
use crate::util::json::{self, Value};
use crate::Result;

/// Simple wall-clock stopwatch.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn seconds(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// Aggregated outcome of a coordinator run (serializable so harness
/// invocations across build profiles can exchange it as JSON).
#[derive(Clone, Debug)]
pub struct RunReport {
    pub kind: String,
    pub threads: usize,
    pub n_models: usize,
    pub sweeps: usize,
    pub wall_seconds: f64,
    /// Single-spin Metropolis updates per second (the paper's implicit
    /// throughput unit: total spins × sweeps / time).
    pub updates_per_sec: f64,
    pub total_flips: u64,
    pub total_attempts: u64,
    pub swap_acceptance: f64,
    /// Per-replica (ladder-ordered) flip probabilities — Fig 14's input.
    pub flip_probs: Vec<f64>,
    /// Per-replica measured group-wait probabilities (CPU rungs only).
    pub wait_probs: Vec<f64>,
    /// Per-replica final energies.
    pub energies: Vec<f64>,
    /// Fraction of pool worker capacity spent inside sweep tasks
    /// (0 when the run did not go through a [`super::SweepPool`]) —
    /// the same utilization figure the sampling service dashboards read.
    pub pool_busy_fraction: f64,
    /// Sweep tasks queued through the pool during the run.
    pub pool_jobs_queued: u64,
    /// The resolved per-group plans the run executed —
    /// `[{rung, width, backend, replicas}]`, one entry per lane-group
    /// (heterogeneous layouts list every group).  Empty in reports
    /// parsed from pre-Run-API payloads.  The legacy `kind` field stays
    /// populated alongside (with the legacy label whenever a single
    /// legacy-spellable plan is in use).
    pub plans: Vec<GroupPlan>,
}

impl RunReport {
    pub fn from_stats(
        kind: &str,
        threads: usize,
        sweeps: usize,
        wall_seconds: f64,
        per_replica: &[(f32, SweepStats, f64)],
        swap_acceptance: f64,
    ) -> Self {
        let total_flips = per_replica.iter().map(|r| r.1.flips).sum();
        let total_attempts: u64 = per_replica.iter().map(|r| r.1.attempts).sum();
        Self {
            kind: kind.to_string(),
            threads,
            n_models: per_replica.len(),
            sweeps,
            wall_seconds,
            updates_per_sec: total_attempts as f64 / wall_seconds.max(1e-12),
            total_flips,
            total_attempts,
            swap_acceptance,
            flip_probs: per_replica.iter().map(|r| r.1.flip_prob()).collect(),
            wait_probs: per_replica.iter().map(|r| r.1.wait_prob()).collect(),
            energies: per_replica.iter().map(|r| r.2).collect(),
            pool_busy_fraction: 0.0,
            pool_jobs_queued: 0,
            plans: Vec::new(),
        }
    }

    /// Attach pool utilization (busy-worker fraction, jobs queued) so the
    /// harness and the service dashboards share one report schema.
    pub fn with_pool(mut self, jobs_queued: u64, busy_fraction: f64) -> Self {
        self.pool_jobs_queued = jobs_queued;
        self.pool_busy_fraction = busy_fraction;
        self
    }

    /// Attach the resolved per-group plans (the Run API v1 echo).
    pub fn with_plans(mut self, plans: Vec<GroupPlan>) -> Self {
        self.plans = plans;
        self
    }

    pub fn to_json(&self) -> String {
        self.to_value().to_string()
    }

    /// JSON form (nested by the service's checkpointable run jobs).
    pub fn to_value(&self) -> Value {
        let mut pairs = vec![
            ("kind", json::str_v(&self.kind)),
            ("threads", json::num(self.threads as f64)),
            ("n_models", json::num(self.n_models as f64)),
            ("sweeps", json::num(self.sweeps as f64)),
            ("wall_seconds", json::num(self.wall_seconds)),
            ("updates_per_sec", json::num(self.updates_per_sec)),
            ("total_flips", json::num(self.total_flips as f64)),
            ("total_attempts", json::num(self.total_attempts as f64)),
            ("swap_acceptance", json::num(self.swap_acceptance)),
            ("flip_probs", json::arr_f64(&self.flip_probs)),
            ("wait_probs", json::arr_f64(&self.wait_probs)),
            ("energies", json::arr_f64(&self.energies)),
            ("pool_busy_fraction", json::num(self.pool_busy_fraction)),
            ("pool_jobs_queued", json::num(self.pool_jobs_queued as f64)),
        ];
        if !self.plans.is_empty() {
            pairs.push(("plans", Value::Arr(self.plans.iter().map(|p| p.to_value()).collect())));
        }
        json::obj(pairs)
    }

    pub fn from_json(text: &str) -> Result<Self> {
        Self::from_value(&Value::parse(text)?)
    }

    /// Parse the JSON form back (see [`RunReport::to_value`]).
    pub fn from_value(v: &Value) -> Result<Self> {
        let f64s = |key: &str| -> Result<Vec<f64>> {
            v.get(key)?.as_arr()?.iter().map(|x| x.as_f64()).collect()
        };
        Ok(Self {
            kind: v.get("kind")?.as_str()?.to_string(),
            threads: v.get("threads")?.as_usize()?,
            n_models: v.get("n_models")?.as_usize()?,
            sweeps: v.get("sweeps")?.as_usize()?,
            wall_seconds: v.get("wall_seconds")?.as_f64()?,
            updates_per_sec: v.get("updates_per_sec")?.as_f64()?,
            total_flips: v.get("total_flips")?.as_f64()? as u64,
            total_attempts: v.get("total_attempts")?.as_f64()? as u64,
            swap_acceptance: v.get("swap_acceptance")?.as_f64()?,
            flip_probs: f64s("flip_probs")?,
            wait_probs: f64s("wait_probs")?,
            energies: f64s("energies")?,
            // Absent in payloads from pre-service builds: default to 0.
            pool_busy_fraction: v
                .opt("pool_busy_fraction")
                .map(|x| x.as_f64())
                .transpose()?
                .unwrap_or(0.0),
            pool_jobs_queued: v
                .opt("pool_jobs_queued")
                .map(|x| x.as_f64())
                .transpose()?
                .unwrap_or(0.0) as u64,
            plans: GroupPlan::vec_from_opt(v.opt("plans"))?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_aggregates() {
        let mk = |flips, attempts| SweepStats { attempts, flips, groups: attempts, groups_with_flip: flips };
        let rows = vec![(1.0f32, mk(10, 100), -5.0), (0.5, mk(30, 100), -2.0)];
        let rep = RunReport::from_stats("A.2", 2, 50, 2.0, &rows, 0.25);
        assert_eq!(rep.total_flips, 40);
        assert_eq!(rep.total_attempts, 200);
        assert!((rep.updates_per_sec - 100.0).abs() < 1e-9);
        assert_eq!(rep.flip_probs, vec![0.1, 0.3]);
        assert_eq!(rep.energies, vec![-5.0, -2.0]);
        let back = RunReport::from_json(&rep.to_json()).unwrap();
        assert_eq!(back.n_models, 2);
        assert_eq!(back.flip_probs, rep.flip_probs);
    }

    #[test]
    fn plans_echo_roundtrips_and_defaults_empty() {
        use crate::engine::{Backend, Resolved, Rung};
        let mk = |flips, attempts| SweepStats {
            attempts,
            flips,
            groups: attempts,
            groups_with_flip: flips,
        };
        let rows = vec![(1.0f32, mk(10, 100), -5.0)];
        let plans = vec![
            GroupPlan::new(Resolved { rung: Rung::C1, backend: Backend::Avx2, width: 8 }, 8),
            GroupPlan::new(Resolved { rung: Rung::C1, backend: Backend::Sse2, width: 4 }, 2),
        ];
        let rep =
            RunReport::from_stats("C.1w8+C.1", 1, 50, 2.0, &rows, 0.25).with_plans(plans.clone());
        let back = RunReport::from_json(&rep.to_json()).unwrap();
        assert_eq!(back.plans, plans, "heterogeneous group plans echo through JSON");
        assert_eq!(back.kind, "C.1w8+C.1");
        // Pre-Run-API payloads (no plans key) default to empty.
        let legacy = r#"{"kind":"A.2","threads":1,"n_models":1,"sweeps":5,
            "wall_seconds":1.0,"updates_per_sec":10.0,"total_flips":1,
            "total_attempts":10,"swap_acceptance":0.0,
            "flip_probs":[0.1],"wait_probs":[0.1],"energies":[-1.0]}"#;
        assert!(RunReport::from_json(legacy).unwrap().plans.is_empty());
    }

    #[test]
    fn pool_fields_roundtrip_and_default() {
        let mk = |flips, attempts| SweepStats {
            attempts,
            flips,
            groups: attempts,
            groups_with_flip: flips,
        };
        let rows = vec![(1.0f32, mk(10, 100), -5.0)];
        let rep = RunReport::from_stats("A.2", 2, 50, 2.0, &rows, 0.25).with_pool(12, 0.75);
        assert_eq!(rep.pool_jobs_queued, 12);
        assert!((rep.pool_busy_fraction - 0.75).abs() < 1e-12);
        let back = RunReport::from_json(&rep.to_json()).unwrap();
        assert_eq!(back.pool_jobs_queued, 12);
        assert!((back.pool_busy_fraction - 0.75).abs() < 1e-12);

        // Payloads from pre-service builds lack the pool keys: default 0.
        let legacy = r#"{"kind":"A.2","threads":1,"n_models":1,"sweeps":5,
            "wall_seconds":1.0,"updates_per_sec":10.0,"total_flips":1,
            "total_attempts":10,"swap_acceptance":0.0,
            "flip_probs":[0.1],"wait_probs":[0.1],"energies":[-1.0]}"#;
        let old = RunReport::from_json(legacy).unwrap();
        assert_eq!(old.pool_jobs_queued, 0);
        assert_eq!(old.pool_busy_fraction, 0.0);
    }
}
